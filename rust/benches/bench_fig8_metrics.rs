//! Bench: Fig 8 (tCDP-vs-EDP design comparison across clusters).
use xrcarbon::bench::Bencher;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::fig08_tcdp_vs_edp;

fn main() {
    let mut ctx = Ctx::auto();
    println!("[engine: {}]", ctx.backend);
    let r = Bencher::new("fig8/full").quick().run(|| {
        fig08_tcdp_vs_edp::run(ctx.engine.as_mut()).unwrap()
    });
    println!("{}", r.report());
}
