//! Bench: the trace-scenario fan-out — a 24-segment diurnal trace
//! crossed with the Fig 7 grid, swept over a warm profile cache (every
//! trace segment is a phase-B overlay over the same cached phase-A
//! profile) versus the fused reference that re-contracts the space for
//! every lowered segment.
//!
//! Emits `BENCH_trace.json`. The CI smoke gate
//! (`tools/check_bench_gate.py`) consumes one pseudo-entry:
//!
//! * `trace/warm_contractions_avoided` — `samples` = cache hits of the
//!   warm trace sweep, `throughput` = hits / profile chunks. The floor
//!   is 1.0×: the trace axis multiplies phase-B overlays, never phase-A
//!   profiling, so a warm sweep must avoid **every** contraction no
//!   matter how many segments the traces lower into (the stats are
//!   deterministic counters, not timings).
//!
//! `trace/segment_fanout` (`samples` = work items, `throughput` = items
//! per profile chunk) is informational: how many per-segment overlays
//! rode on each cached contraction.
//!
//! Set `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use std::time::Duration;

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::carbon::CiTrace;
use xrcarbon::dse::cache::ProfileCache;
use xrcarbon::dse::sweep::{sweep_fused, sweep_with_cache, SweepConfig};
use xrcarbon::dse::ScenarioGrid;
use xrcarbon::experiments::sweep_fig7::profile_cluster;
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::workloads::Cluster;

/// Counter pseudo-entry: `samples` carries a count, `throughput` a
/// ratio; timings are zero (this row is data, not a measurement).
fn counter(name: &str, samples: usize, ratio: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        samples,
        mean: Duration::ZERO,
        p50: Duration::ZERO,
        p95: Duration::ZERO,
        throughput: Some(ratio),
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let space = profile_cluster(Cluster::Ai5);
    // Fig 7's three embodied-share scenarios, each carrying the
    // 24-segment diurnal world-grid trace: 3 scenarios × 24 lowered
    // segments over one 121-config profile chunk.
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j).cross(
        ScenarioGrid::new().with_trace("trace=diurnal-world", CiTrace::diurnal_world()),
    );
    let dir = xrcarbon::testkit::test_dir("bench_trace");

    // Populate the cache once, then every warm iteration serves phase A
    // from disk and pays only the per-segment overlays.
    std::fs::remove_dir_all(&dir).ok();
    let cache = ProfileCache::open(&dir).unwrap();
    sweep_with_cache(&HostEngineFactory, &space.base, &grid, &SweepConfig::default(), Some(&cache))
        .unwrap();
    let mut last = None;
    let warm = Bencher::new("trace/warm_sweep_24seg").quick_if_env().run(|| {
        let out = sweep_with_cache(
            &HostEngineFactory,
            &space.base,
            &grid,
            &SweepConfig::default(),
            Some(&cache),
        )
        .unwrap();
        last = Some(out);
    });
    println!("{}", warm.report());
    let out = last.expect("warm bench ran at least once");
    let stats = out.cache.expect("cached sweep reports stats");
    let avoided_ratio = stats.hits as f64 / out.profile_chunks.max(1) as f64;
    let fanout = out.items as f64 / out.profile_chunks.max(1) as f64;
    println!(
        "warm trace sweep: {} of {} chunk contraction(s) avoided ({avoided_ratio:.2}x floor \
         metric), {} overlay item(s) ({fanout:.0} per chunk), {} miss(es)",
        stats.hits, out.profile_chunks, out.items, stats.misses
    );

    // Fused reference: the engine re-contracts the space for every
    // lowered segment — the cost the trace axis would multiply without
    // the two-phase split.
    let fused = Bencher::new("trace/fused_sweep_24seg")
        .quick_if_env()
        .run(|| sweep_fused(&HostEngineFactory, &space.base, &grid, &SweepConfig::default()).unwrap());
    println!("{}", fused.report());
    let speedup = fused.mean.as_secs_f64() / warm.mean.as_secs_f64().max(1e-12);
    println!("warm two-phase vs fused per-segment: {speedup:.2}x wall clock");

    results.push(warm);
    results.push(fused);
    results.push(counter("trace/warm_contractions_avoided", stats.hits, avoided_ratio));
    results.push(counter("trace/segment_fanout", out.items, fanout));

    std::fs::remove_dir_all(&dir).ok();
    write_json(&results, "BENCH_trace.json").expect("writing BENCH_trace.json");
    println!("[json] wrote BENCH_trace.json ({} benchmarks)", results.len());
}
