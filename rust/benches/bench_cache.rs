//! Bench: the persistent profile cache and search checkpoints — (a) a
//! cold sweep (empty cache: every chunk contracted and written back)
//! versus a warm sweep (every chunk served from disk, zero engine
//! contractions), and (b) a cold adaptive search versus one resumed from
//! a mid-run checkpoint (the resumed run only evaluates the remaining
//! generations).
//!
//! Emits `BENCH_cache.json`. The CI smoke gate
//! (`tools/check_bench_gate.py`) consumes one pseudo-entry:
//!
//! * `cache/warm_contractions_avoided` — `samples` = cache hits of the
//!   warm sweep, `throughput` = hits / profile chunks. The floor is
//!   1.0×: a warm sweep over a cached space must avoid **every** phase-A
//!   contraction (the stats are deterministic counters, not timings).
//!
//! `cache/resume_evaluations_carried` is informational: how many
//! evaluations the resumed search inherited from the checkpoint instead
//! of recomputing.
//!
//! Set `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use std::time::Duration;

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::carbon::FabGrid;
use xrcarbon::dse::cache::ProfileCache;
use xrcarbon::dse::search::{search, SearchConfig, SearchDriver, SimulatorEvaluator};
use xrcarbon::dse::sweep::{sweep_with_cache, SweepConfig};
use xrcarbon::dse::{ScenarioGrid, SearchSpace};
use xrcarbon::experiments::sweep_fig7::profile_cluster;
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::workloads::{cluster_workloads, Cluster};

/// Counter pseudo-entry: `samples` carries a count, `throughput` a
/// ratio; timings are zero (this row is data, not a measurement).
fn counter(name: &str, samples: usize, ratio: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        samples,
        mean: Duration::ZERO,
        p50: Duration::ZERO,
        p95: Duration::ZERO,
        throughput: Some(ratio),
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let cluster = Cluster::Ai5;
    let space = profile_cluster(cluster);
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
    let dir = xrcarbon::testkit::test_dir("bench_cache");

    // (a) Cold sweep: every iteration starts from an empty cache, pays
    // the full phase-A contraction and the write-back.
    let cold = Bencher::new("cache/cold_sweep_grid121").quick_if_env().run(|| {
        std::fs::remove_dir_all(&dir).ok();
        let cache = ProfileCache::open(&dir).unwrap();
        let cfg = SweepConfig::default();
        sweep_with_cache(&HostEngineFactory, &space.base, &grid, &cfg, Some(&cache)).unwrap()
    });
    println!("{}", cold.report());

    // Warm sweep: populate once, then every iteration is served from
    // disk — zero engine contractions (asserted via the stats delta).
    std::fs::remove_dir_all(&dir).ok();
    let cache = ProfileCache::open(&dir).unwrap();
    sweep_with_cache(&HostEngineFactory, &space.base, &grid, &SweepConfig::default(), Some(&cache))
        .unwrap();
    let mut last = None;
    let warm = Bencher::new("cache/warm_sweep_grid121").quick_if_env().run(|| {
        let out = sweep_with_cache(
            &HostEngineFactory,
            &space.base,
            &grid,
            &SweepConfig::default(),
            Some(&cache),
        )
        .unwrap();
        last = Some(out);
    });
    println!("{}", warm.report());
    let out = last.expect("warm bench ran at least once");
    let stats = out.cache.expect("cached sweep reports stats");
    let avoided_ratio = stats.hits as f64 / out.profile_chunks.max(1) as f64;
    let speedup = cold.mean.as_secs_f64() / warm.mean.as_secs_f64();
    println!(
        "warm sweep: {} of {} chunk contraction(s) avoided ({avoided_ratio:.2}x floor metric), \
         {} miss(es), {speedup:.2}x wall clock vs cold",
        stats.hits, out.profile_chunks, stats.misses
    );
    results.push(cold);
    results.push(warm);
    results.push(counter("cache/warm_contractions_avoided", stats.hits, avoided_ratio));

    // (b) Cold search vs search resumed from a mid-run checkpoint. The
    // resumed run re-pays only the generations after the interrupt.
    let sspace = SearchSpace::fig7_grid();
    let evaluator =
        SimulatorEvaluator { workloads: cluster_workloads(cluster), fab: FabGrid::Coal };
    let scfg = SearchConfig::default();
    let cold_search = Bencher::new("cache/search_cold_grid121").quick_if_env().run(|| {
        search(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid, &scfg).unwrap()
    });
    println!("{}", cold_search.report());

    // Count the full run's loop iterations, then checkpoint halfway.
    let mut probe = SearchDriver::new(&sspace, &scfg);
    let mut steps = 0usize;
    while !probe
        .step(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid, None)
        .unwrap()
    {
        steps += 1;
    }
    let mut half = SearchDriver::new(&sspace, &scfg);
    for _ in 0..steps / 2 {
        if half
            .step(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid, None)
            .unwrap()
        {
            break;
        }
    }
    let ck = half.checkpoint();
    let carried = ck.evaluated.len();
    let resumed = Bencher::new("cache/search_resumed_grid121").quick_if_env().run(|| {
        SearchDriver::resume(&sspace, &scfg, &ck)
            .unwrap()
            .run(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid)
            .unwrap()
    });
    println!("{}", resumed.report());
    let total = probe.evaluations().max(1);
    let resume_speedup = cold_search.mean.as_secs_f64() / resumed.mean.as_secs_f64();
    println!(
        "resumed search: {carried}/{total} evaluation(s) carried by the checkpoint \
         ({resume_speedup:.2}x wall clock vs cold)"
    );
    results.push(cold_search);
    results.push(resumed);
    results.push(counter(
        "cache/resume_evaluations_carried",
        carried,
        carried as f64 / total as f64,
    ));

    std::fs::remove_dir_all(&dir).ok();
    write_json(&results, "BENCH_cache.json").expect("writing BENCH_cache.json");
    println!("[json] wrote BENCH_cache.json ({} benchmarks)", results.len());
}
