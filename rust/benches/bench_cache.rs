//! Bench: the persistent profile cache and search checkpoints — (a) a
//! cold sweep (empty cache: every chunk contracted and written back)
//! versus a warm sweep (every chunk served from disk, zero engine
//! contractions), (b) warm reads through the binary sidecar versus the
//! JSON-only legacy envelope, and (c) a cold adaptive search versus one
//! resumed from a mid-run checkpoint (the resumed run only evaluates the
//! remaining generations).
//!
//! Emits `BENCH_cache.json`. The CI smoke gate
//! (`tools/check_bench_gate.py`) consumes two pseudo-entries:
//!
//! * `cache/warm_contractions_avoided` — `samples` = cache hits of the
//!   warm sweep, `throughput` = hits / profile chunks. The floor is
//!   1.0×: a warm sweep over a cached space must avoid **every** phase-A
//!   contraction (the stats are deterministic counters, not timings).
//! * `cache/warm_read_speedup` — `throughput` = JSON-envelope warm-read
//!   time / binary-sidecar warm-read time for one chunk (memory layer
//!   disabled on both sides). Gated ≥ 2.0×: the raw-bits sidecar must
//!   keep a decisive decode advantage over the ~10-bytes-per-f32 JSON
//!   parse.
//!
//! `cache/warm_read_bytes` (`samples` = sidecar bytes, `throughput` =
//! JSON bytes / sidecar bytes) and `cache/resume_evaluations_carried`
//! (how many evaluations the resumed search inherited from the
//! checkpoint) are informational.
//!
//! Set `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use std::time::Duration;

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::carbon::FabGrid;
use xrcarbon::dse::cache::{CacheConfig, ProfileCache};
use xrcarbon::dse::search::{search, SearchConfig, SearchDriver, SimulatorEvaluator};
use xrcarbon::dse::sweep::{sweep_with_cache, SweepConfig};
use xrcarbon::dse::{ScenarioGrid, SearchSpace};
use xrcarbon::experiments::sweep_fig7::profile_cluster;
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::workloads::{cluster_workloads, Cluster};

/// Counter pseudo-entry: `samples` carries a count, `throughput` a
/// ratio; timings are zero (this row is data, not a measurement).
fn counter(name: &str, samples: usize, ratio: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        samples,
        mean: Duration::ZERO,
        p50: Duration::ZERO,
        p95: Duration::ZERO,
        throughput: Some(ratio),
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let cluster = Cluster::Ai5;
    let space = profile_cluster(cluster);
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
    let dir = xrcarbon::testkit::test_dir("bench_cache");

    // (a) Cold sweep: every iteration starts from an empty cache, pays
    // the full phase-A contraction and the write-back.
    let cold = Bencher::new("cache/cold_sweep_grid121").quick_if_env().run(|| {
        std::fs::remove_dir_all(&dir).ok();
        let cache = ProfileCache::open(&dir).unwrap();
        let cfg = SweepConfig::default();
        sweep_with_cache(&HostEngineFactory, &space.base, &grid, &cfg, Some(&cache)).unwrap()
    });
    println!("{}", cold.report());

    // Warm sweep: populate once, then every iteration is served from
    // disk — zero engine contractions (asserted via the stats delta).
    std::fs::remove_dir_all(&dir).ok();
    let cache = ProfileCache::open(&dir).unwrap();
    sweep_with_cache(&HostEngineFactory, &space.base, &grid, &SweepConfig::default(), Some(&cache))
        .unwrap();
    let mut last = None;
    let warm = Bencher::new("cache/warm_sweep_grid121").quick_if_env().run(|| {
        let out = sweep_with_cache(
            &HostEngineFactory,
            &space.base,
            &grid,
            &SweepConfig::default(),
            Some(&cache),
        )
        .unwrap();
        last = Some(out);
    });
    println!("{}", warm.report());
    let out = last.expect("warm bench ran at least once");
    let stats = out.cache.expect("cached sweep reports stats");
    let avoided_ratio = stats.hits as f64 / out.profile_chunks.max(1) as f64;
    let speedup = cold.mean.as_secs_f64() / warm.mean.as_secs_f64();
    println!(
        "warm sweep: {} of {} chunk contraction(s) avoided ({avoided_ratio:.2}x floor metric), \
         {} miss(es), {speedup:.2}x wall clock vs cold",
        stats.hits, out.profile_chunks, stats.misses
    );
    results.push(cold);
    results.push(warm);
    results.push(counter("cache/warm_contractions_avoided", stats.hits, avoided_ratio));

    // (b) Warm-read microbench: the same cached chunk decoded straight
    // from disk — binary sidecar vs the JSON-only legacy mode, memory
    // layer disabled on both sides so every iteration pays the real
    // read + decode. The 121-config space is a single chunk.
    let key = ProfileCache::key_for_chunk(&space.base.tasks, &space.base.configs, "host");
    let nomem = CacheConfig { mem_entries: 0, ..CacheConfig::default() };
    let cache_bin = ProfileCache::open_with(&dir, nomem).unwrap();
    let cache_json =
        ProfileCache::open_with(&dir, CacheConfig { binary_sidecars: false, ..nomem }).unwrap();
    assert!(cache_bin.load(&key, "host").is_some(), "cached chunk present with sidecar");
    let bin_bytes = std::fs::metadata(cache_bin.sidecar_path(&key)).map(|m| m.len()).unwrap_or(0);
    let json_bytes =
        std::fs::metadata(cache_bin.envelope_path(&key)).map(|m| m.len()).unwrap_or(0);
    let warm_bin = Bencher::new("cache/warm_read_binary")
        .quick_if_env()
        .run(|| cache_bin.load(&key, "host").expect("sidecar read"));
    println!("{}", warm_bin.report());
    let warm_json = Bencher::new("cache/warm_read_json")
        .quick_if_env()
        .run(|| cache_json.load(&key, "host").expect("json read"));
    println!("{}", warm_json.report());
    let read_speedup = warm_json.mean.as_secs_f64() / warm_bin.mean.as_secs_f64().max(1e-12);
    let bytes_ratio = json_bytes as f64 / bin_bytes.max(1) as f64;
    println!(
        "warm read: binary {bin_bytes} B vs JSON {json_bytes} B ({bytes_ratio:.2}x smaller), \
         {read_speedup:.2}x faster decode"
    );
    results.push(warm_bin);
    results.push(warm_json);
    results.push(counter("cache/warm_read_speedup", 1, read_speedup));
    results.push(counter("cache/warm_read_bytes", bin_bytes as usize, bytes_ratio));

    // (c) Cold search vs search resumed from a mid-run checkpoint. The
    // resumed run re-pays only the generations after the interrupt.
    let sspace = SearchSpace::fig7_grid();
    let evaluator =
        SimulatorEvaluator { workloads: cluster_workloads(cluster), fab: FabGrid::Coal };
    let scfg = SearchConfig::default();
    let cold_search = Bencher::new("cache/search_cold_grid121").quick_if_env().run(|| {
        search(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid, &scfg).unwrap()
    });
    println!("{}", cold_search.report());

    // Count the full run's loop iterations, then checkpoint halfway.
    let mut probe = SearchDriver::new(&sspace, &scfg);
    let mut steps = 0usize;
    while !probe
        .step(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid, None)
        .unwrap()
    {
        steps += 1;
    }
    let mut half = SearchDriver::new(&sspace, &scfg);
    for _ in 0..steps / 2 {
        if half
            .step(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid, None)
            .unwrap()
        {
            break;
        }
    }
    let ck = half.checkpoint();
    let carried = ck.evaluated.len();
    let resumed = Bencher::new("cache/search_resumed_grid121").quick_if_env().run(|| {
        SearchDriver::resume(&sspace, &scfg, &ck)
            .unwrap()
            .run(&HostEngineFactory, &sspace, &evaluator, &space.base, &grid)
            .unwrap()
    });
    println!("{}", resumed.report());
    let total = probe.evaluations().max(1);
    let resume_speedup = cold_search.mean.as_secs_f64() / resumed.mean.as_secs_f64();
    println!(
        "resumed search: {carried}/{total} evaluation(s) carried by the checkpoint \
         ({resume_speedup:.2}x wall clock vs cold)"
    );
    results.push(cold_search);
    results.push(resumed);
    results.push(counter(
        "cache/resume_evaluations_carried",
        carried,
        carried as f64 / total as f64,
    ));

    std::fs::remove_dir_all(&dir).ok();
    write_json(&results, "BENCH_cache.json").expect("writing BENCH_cache.json");
    println!("[json] wrote BENCH_cache.json ({} benchmarks)", results.len());
}
