//! Bench: the Fig 7 headline DSE (121 configs × 5 clusters × 3 scenarios)
//! end-to-end, plus a single-cluster exploration, on the auto engine.
use xrcarbon::bench::Bencher;
use xrcarbon::carbon::FabGrid;
use xrcarbon::dse::{design_grid, explore, lifetime_for_ratio, profile_configs, profiles_to_rows};
use xrcarbon::experiments::common::{default_use_grid, rows_request, suite_task, Ctx};
use xrcarbon::experiments::fig07_dse_clusters;
use xrcarbon::workloads::{cluster_workloads, Cluster};

fn main() {
    let mut ctx = Ctx::auto();
    println!("[engine: {}]", ctx.backend);

    // Single-cluster exploration (profile + evaluate 121 configs).
    let grid = design_grid();
    let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();
    let ws = cluster_workloads(Cluster::Ai5);
    let profiles = profile_configs(&configs, &ws);
    let rows = profiles_to_rows(&configs, &profiles, FabGrid::Coal);
    let ci = default_use_grid().g_per_joule();
    let lt = lifetime_for_ratio(&rows, &suite_task(&ws), 0.65, ci);
    let r = Bencher::new("fig7/explore_5ai_121configs")
        .throughput(121)
        .run(|| {
            let req = rows_request(rows.clone(), &ws, lt, 1.0);
            explore(ctx.engine.as_mut(), &req).unwrap()
        });
    println!("{}", r.report());

    // Full figure (dominated by 6x grid profiling).
    let r = Bencher::new("fig7/full_3x5x121").quick().run(|| {
        fig07_dse_clusters::run(ctx.engine.as_mut()).unwrap()
    });
    println!("{}", r.report());
}
