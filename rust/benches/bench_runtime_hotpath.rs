//! Bench: the L3 hot path in isolation — pack → engine execute → unpack —
//! for both engines and both artifact variants, plus the accelerator
//! simulator and fleet generator substrates.
use xrcarbon::accel::{network, production_accelerators, simulate, Workload};
use xrcarbon::bench::Bencher;
use xrcarbon::matrixform::{ConfigRow, EvalRequest, PackedProblem, TaskMatrix};
#[cfg(feature = "pjrt")]
use xrcarbon::runtime::PjrtEngine;
use xrcarbon::runtime::{evaluate, HostEngine};
use xrcarbon::testkit::Rng;
use xrcarbon::workloads::{generate_fleet, FleetConfig};

fn request(c: usize) -> EvalRequest {
    let mut rng = Rng::new(1);
    let k = 16;
    let tm = TaskMatrix::single_task(
        "t",
        (0..k).map(|i| format!("k{i}")).collect(),
        &(0..k).map(|_| rng.below(30) as f64).collect::<Vec<_>>(),
    );
    EvalRequest {
        tasks: tm,
        configs: (0..c)
            .map(|i| ConfigRow {
                name: format!("cfg{i}"),
                f_clk: 1e9,
                d_k: (0..k).map(|_| rng.range(1e-4, 1e-2)).collect(),
                e_dyn: (0..k).map(|_| rng.range(1e-3, 1e-1)).collect(),
                leak_w: 0.01,
                c_comp: vec![rng.range(50.0, 500.0), rng.range(10.0, 100.0), 20.0],
            })
            .collect(),
        online: vec![1.0, 1.0, 1.0],
        qos: vec![f64::INFINITY],
        ci_use_g_per_j: 1.2e-4,
        lifetime_s: 1e7,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

fn main() {
    for &c in &[121usize, 1024] {
        let req = request(c);
        #[cfg(feature = "pjrt")]
        if let Ok(mut pjrt) = PjrtEngine::load("artifacts") {
            let r = Bencher::new(&format!("runtime/pjrt_eval_c{c}"))
                .throughput(c as u64)
                .run(|| evaluate(&mut pjrt, &req).unwrap());
            println!("{}", r.report());
        }
        let mut host = HostEngine::new();
        let r = Bencher::new(&format!("runtime/host_eval_c{c}"))
            .throughput(c as u64)
            .run(|| evaluate(&mut host, &req).unwrap());
        println!("{}", r.report());
        let r = Bencher::new(&format!("runtime/pack_only_c{c}"))
            .throughput(c as u64)
            .run(|| PackedProblem::from_request(&req));
        println!("{}", r.report());
    }
    // Substrates.
    let a2 = &production_accelerators()[1];
    let rn50 = network(Workload::Rn50);
    let r = Bencher::new("substrate/simulate_rn50").run(|| simulate(a2, &rn50));
    println!("{}", r.report());
    let r = Bencher::new("substrate/fleet_50dev_5days").quick().run(|| {
        generate_fleet(&FleetConfig { devices: 50, days: 5, ..Default::default() })
    });
    println!("{}", r.report());
}
