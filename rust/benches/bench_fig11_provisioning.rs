//! Bench: Fig 11/13 core-provisioning optimization over the top-10 apps.
use xrcarbon::bench::Bencher;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{fig11_provisioning_savings, fig13_core_configs};

fn main() {
    let mut ctx = Ctx::auto();
    println!("[engine: {}]", ctx.backend);
    let r = Bencher::new("fig11/top10_provisioning").throughput(10).run(|| {
        fig11_provisioning_savings::run(ctx.engine.as_mut()).unwrap()
    });
    println!("{}", r.report());
    let r = Bencher::new("fig13/core_configs").run(|| {
        fig13_core_configs::run(ctx.engine.as_mut()).unwrap()
    });
    println!("{}", r.report());
}
