//! Bench: the PR 7 hot-loop optimizations, each against the exact code
//! it replaced.
//!
//! 1. **Phase A, vector vs scalar** — the lane-blocked contraction
//!    kernel (`HostEngine::new()`, 8 configs per pass over the columnar
//!    views) against the per-config scalar oracle
//!    (`HostEngine::scalar_oracle()`) on one dense packed batch.
//! 2. **Phase B, batched vs single overlays** — one
//!    `ScenarioOverlay::apply_batch` pass (reused scratch, hoisted
//!    shared embodied-carbon fold) against the same overlays applied
//!    one `apply` at a time.
//! 3. **Scheduling, pool vs spawn** — the same multi-chunk sweep run on
//!    the persistent `WorkerPool` (`HostEngineFactory` opts in) and on
//!    the per-call scoped-spawn scheduler (`ScopedSpawn` adapter),
//!    which pays thread spawn + engine build every call. A sweep per
//!    iteration stands in for search generations: both go through the
//!    same `fan_out`.
//!
//! All three pairs are bit-identical by construction (locked by
//! `rust/tests/hotloop_props.rs`); this bench asserts cheap bit-equality
//! on the way and measures the speedups. Emits `BENCH_hotloop.json`
//! with three ratio pseudo-entries the CI smoke gate
//! (`tools/check_bench_gate.py`) floors at 1.0×:
//!
//! * `hotloop/vector_speedup` — scalar mean / lane-kernel mean;
//! * `hotloop/overlay_batch_speedup` — single-apply mean / batch mean;
//! * `hotloop/pool_speedup` — scoped-spawn mean / pool mean.
//!
//! Set `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use std::time::Duration;

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::carbon::{OverlayScratch, ScenarioOverlay};
use xrcarbon::dse::sweep::{sweep, SweepConfig};
use xrcarbon::dse::ScenarioGrid;
use xrcarbon::matrixform::{ConfigRow, EvalRequest, PackedProblem, TaskMatrix};
use xrcarbon::runtime::{profile_request, Engine, HostEngine, HostEngineFactory, ScopedSpawn};

/// Counter pseudo-entry: `samples` carries a count, `throughput` a
/// ratio; timings are zero (this row is data, not a measurement).
fn counter(name: &str, samples: usize, ratio: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        samples,
        mean: Duration::ZERO,
        p50: Duration::ZERO,
        p95: Duration::ZERO,
        throughput: Some(ratio),
    }
}

/// A dense request at the padded shape ceiling (8 tasks × 32 kernels ×
/// 8 components) so the contraction does maximal arithmetic per config —
/// the regime the lane kernel targets.
fn fat_request(c: usize) -> EvalRequest {
    let kernels: Vec<String> = (0..32).map(|k| format!("k{k}")).collect();
    let tasks: Vec<String> = (0..8).map(|t| format!("t{t}")).collect();
    let mut tm = TaskMatrix::new(tasks, kernels);
    for ti in 0..8 {
        for ki in 0..32 {
            tm.set(ti, ki, ((ti * 7 + ki * 3) % 23 + 1) as f64);
        }
    }
    EvalRequest {
        tasks: tm,
        configs: (0..c)
            .map(|i| ConfigRow {
                name: format!("cfg{i}"),
                f_clk: 1e9 + i as f64 * 1e5,
                d_k: (0..32).map(|k| 1e-4 * ((i + k) % 13 + 1) as f64).collect(),
                e_dyn: (0..32).map(|k| 1e-3 * ((i + 2 * k) % 7 + 1) as f64).collect(),
                leak_w: 0.05 + (i % 11) as f64 * 0.01,
                c_comp: (0..8).map(|j| 50.0 + ((i + j) % 17) as f64 * 5.0).collect(),
            })
            .collect(),
        online: vec![1.0; 8],
        qos: vec![f64::INFINITY; 8],
        ci_use_g_per_j: 1.2e-4,
        lifetime_s: 1e7,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    // -- 1. Phase A: lane-blocked kernel vs scalar oracle --
    let packed = PackedProblem::from_request(&fat_request(1000));
    let mut lanes_eng = HostEngine::new();
    let mut scalar_eng = HostEngine::scalar_oracle();
    // The invariant the speedup is only allowed to exist under.
    let a = lanes_eng.profile(&packed).unwrap();
    let b = scalar_eng.profile(&packed).unwrap();
    assert!(
        a.energy.iter().zip(&b.energy).all(|(x, y)| x.to_bits() == y.to_bits())
            && a.delay.iter().zip(&b.delay).all(|(x, y)| x.to_bits() == y.to_bits())
            && a.d_task.iter().zip(&b.d_task).all(|(x, y)| x.to_bits() == y.to_bits()),
        "lane kernel diverged from the scalar oracle"
    );
    let vector = Bencher::new("hotloop/profile_vector_1000cfg")
        .quick_if_env()
        .run(|| std::hint::black_box(lanes_eng.profile(std::hint::black_box(&packed)).unwrap()));
    println!("{}", vector.report());
    let scalar = Bencher::new("hotloop/profile_scalar_1000cfg")
        .quick_if_env()
        .run(|| std::hint::black_box(scalar_eng.profile(std::hint::black_box(&packed)).unwrap()));
    println!("{}", scalar.report());
    let vector_speedup = scalar.mean.as_secs_f64() / vector.mean.as_secs_f64().max(1e-12);
    println!("phase A vector vs scalar: {vector_speedup:.2}x");

    // -- 2. Phase B: batched overlays vs one-at-a-time --
    let base = fat_request(1000);
    let prof = profile_request(&mut HostEngine::new(), &base).unwrap();
    // A realistic fan-out: 48 scenarios over one profile (think 2 grids
    // × 24 trace segments), all sharing the base `online` mask so the
    // batch may hoist the embodied-carbon fold.
    let overlays: Vec<ScenarioOverlay> = (0..48)
        .map(|s| {
            let mut req = fat_request(0);
            req.lifetime_s = 1e6 * (s % 8 + 1) as f64;
            req.beta = 0.25 * (s % 5 + 1) as f64;
            req.ci_use_g_per_j = 1e-4 + s as f64 * 1e-6;
            ScenarioOverlay::from_request(&req)
        })
        .collect();
    let mut scratch = OverlayScratch::new();
    {
        // Bit-equality spot check before timing anything.
        let batched = ScenarioOverlay::apply_batch(&overlays, &prof, &mut scratch);
        for (ov, res) in overlays.iter().zip(&batched) {
            let single = ov.apply(&prof);
            assert_eq!(single.metrics, res.metrics, "overlay batch diverged from apply()");
        }
    }
    let batch = Bencher::new("hotloop/overlay_batch_48x1000cfg").quick_if_env().run(|| {
        std::hint::black_box(ScenarioOverlay::apply_batch(
            std::hint::black_box(&overlays),
            &prof,
            &mut scratch,
        ))
    });
    println!("{}", batch.report());
    let single = Bencher::new("hotloop/overlay_single_48x1000cfg").quick_if_env().run(|| {
        let out: Vec<_> =
            overlays.iter().map(|ov| ov.apply(std::hint::black_box(&prof))).collect();
        std::hint::black_box(out)
    });
    println!("{}", single.report());
    let overlay_speedup = single.mean.as_secs_f64() / batch.mean.as_secs_f64().max(1e-12);
    println!("phase B batched vs single: {overlay_speedup:.2}x");

    // -- 3. Scheduling: persistent pool vs per-call scoped spawn --
    // 300 configs → 3 profile chunks on 3 workers; the spawn baseline
    // pays 3 thread spawns + engine builds per sweep, the pool pays them
    // once for the whole bench.
    let space = fat_request(300);
    let grid = ScenarioGrid::new().with_beta("b=1", 1.0).with_beta("b=2", 2.0);
    let cfg = SweepConfig { threads: 3 };
    let pool_out = sweep(&HostEngineFactory, &space, &grid, &cfg).unwrap();
    let spawn_out = sweep(&ScopedSpawn(HostEngineFactory), &space, &grid, &cfg).unwrap();
    for (p, s) in pool_out.scenarios.iter().zip(&spawn_out.scenarios) {
        assert_eq!(
            p.outcome.result.metrics, s.outcome.result.metrics,
            "pool scheduler diverged from scoped spawn"
        );
    }
    let pool = Bencher::new("hotloop/sweep_pool_3x100cfg")
        .quick_if_env()
        .run(|| std::hint::black_box(sweep(&HostEngineFactory, &space, &grid, &cfg).unwrap()));
    println!("{}", pool.report());
    let spawn = Bencher::new("hotloop/sweep_spawn_3x100cfg").quick_if_env().run(|| {
        std::hint::black_box(sweep(&ScopedSpawn(HostEngineFactory), &space, &grid, &cfg).unwrap())
    });
    println!("{}", spawn.report());
    let pool_speedup = spawn.mean.as_secs_f64() / pool.mean.as_secs_f64().max(1e-12);
    println!("scheduling pool vs spawn: {pool_speedup:.2}x");

    results.push(vector);
    results.push(scalar);
    results.push(counter("hotloop/vector_speedup", 1000, vector_speedup));
    results.push(batch);
    results.push(single);
    results.push(counter("hotloop/overlay_batch_speedup", overlays.len(), overlay_speedup));
    results.push(pool);
    results.push(spawn);
    results.push(counter("hotloop/pool_speedup", pool_out.profile_chunks, pool_speedup));

    write_json(&results, "BENCH_hotloop.json").expect("writing BENCH_hotloop.json");
    println!("[json] wrote BENCH_hotloop.json ({} benchmarks)", results.len());
}
