//! Bench: the service-layer concurrency story — N identical sweep
//! clients racing over one shared [`ProfileCache`] + [`Coalescer`]
//! (exactly how the exploration server's executor threads share them),
//! versus the same N clients with coalescing disabled.
//!
//! Emits `BENCH_service.json`. The CI smoke gate
//! (`tools/check_bench_gate.py`) consumes one pseudo-entry:
//!
//! * `service/coalesced_contractions_avoided` — `samples` = phase-A
//!   contractions the N-client run avoided (`N·chunks − cache writes`),
//!   `throughput` = that count over the ideal `(N−1)·chunks`. The floor
//!   is 1.0×: with coalescing on, every unique chunk must be contracted
//!   **exactly once** across all clients — the leader computes, every
//!   concurrent duplicate waits on the in-flight slot, every later
//!   arrival hits the cache. The stats are deterministic counters, not
//!   timings, so 1.0 is an exact identity, not a tuned threshold.
//!
//! `service/uncoalesced_duplicate_contractions` (how many duplicate
//! contractions the coalescer-free baseline performed; `throughput` =
//! its writes / chunks, ≥ 1.0 by construction) is informational — on a
//! fast machine the baseline's races can collapse by timing luck, which
//! is exactly why the *gate* rides on the coalesced identity instead.
//!
//! Set `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use std::time::Duration;

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::dse::cache::ProfileCache;
use xrcarbon::dse::coalesce::Coalescer;
use xrcarbon::dse::sweep::{SweepConfig, SweepDriver};
use xrcarbon::dse::ScenarioGrid;
use xrcarbon::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
use xrcarbon::runtime::HostEngineFactory;

/// Concurrent identical clients (the server's executor fan-in shape).
const CLIENTS: usize = 4;

/// Counter pseudo-entry: `samples` carries a count, `throughput` a
/// ratio; timings are zero (this row is data, not a measurement).
fn counter(name: &str, samples: usize, ratio: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        samples,
        mean: Duration::ZERO,
        p50: Duration::ZERO,
        p95: Duration::ZERO,
        throughput: Some(ratio),
    }
}

/// Deterministic synthetic request sized to span several profile
/// chunks (chunking is ~1024 configs), so the coalescer is exercised
/// per chunk, not just once.
fn request(n: usize) -> EvalRequest {
    let k = 2usize;
    let mut tasks =
        TaskMatrix::new(vec!["t0".into()], (0..k).map(|i| format!("k{i}")).collect());
    for ki in 0..k {
        tasks.set(0, ki, 3.0 + ki as f64);
    }
    EvalRequest {
        tasks,
        configs: (0..n)
            .map(|i| {
                let x = (i as f64 + 1.0) / n as f64;
                ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1.0e9 + 1.0e6 * i as f64,
                    d_k: (0..k).map(|j| 1e-3 * (1.0 + x + j as f64 * 0.1)).collect(),
                    e_dyn: (0..k).map(|j| 1e-2 * (1.0 + 0.5 * x + j as f64 * 0.05)).collect(),
                    leak_w: 0.05 * x,
                    c_comp: vec![120.0 * x, 40.0, 15.0],
                }
            })
            .collect(),
        online: vec![1.0, 1.0, 1.0],
        qos: vec![f64::INFINITY],
        ci_use_g_per_j: 1.1e-4,
        lifetime_s: 2.0 * 3.156e7,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let req = request(2500);
    let grid = ScenarioGrid::new().with_lifetime("lt=1y", 3.156e7).with_beta("beta=2", 2.0);
    let cfg = SweepConfig { threads: 1 };
    let dir = xrcarbon::testkit::test_dir("bench_service");

    // Probe: one cold run to learn the chunk count.
    std::fs::remove_dir_all(&dir).ok();
    let probe_cache = ProfileCache::open(&dir).unwrap();
    let probe = SweepDriver::new(&HostEngineFactory, &req, &grid, &cfg)
        .run_with(&HostEngineFactory, Some(&probe_cache), None, None)
        .unwrap();
    let chunks = probe.profile_chunks;
    assert!(chunks >= 2, "request should span several chunks, got {chunks}");

    // Coalesced: every iteration starts cold — fresh directory, fresh
    // cache + coalescer shared by CLIENTS racing identical sweeps.
    let mut last = None;
    let coalesced = Bencher::new("service/concurrent_sweeps_x4_coalesced").quick_if_env().run(
        || {
            std::fs::remove_dir_all(&dir).ok();
            let cache = ProfileCache::open(&dir).unwrap();
            let co = Coalescer::new();
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..CLIENTS)
                    .map(|_| {
                        s.spawn(|| {
                            SweepDriver::new(&HostEngineFactory, &req, &grid, &cfg)
                                .run_with(&HostEngineFactory, Some(&cache), Some(&co), None)
                                .unwrap()
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            });
            last = Some((cache.stats(), co.stats()));
        },
    );
    println!("{}", coalesced.report());
    let (cs, co) = last.expect("coalesced bench ran at least once");

    // Uncoalesced baseline: same shared cache, no coalescer — racing
    // cold misses each contract on their own.
    let mut last_base = None;
    let uncoalesced = Bencher::new("service/concurrent_sweeps_x4_uncoalesced")
        .quick_if_env()
        .run(|| {
            std::fs::remove_dir_all(&dir).ok();
            let cache = ProfileCache::open(&dir).unwrap();
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..CLIENTS)
                    .map(|_| {
                        s.spawn(|| {
                            SweepDriver::new(&HostEngineFactory, &req, &grid, &cfg)
                                .run_with(&HostEngineFactory, Some(&cache), None, None)
                                .unwrap()
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            });
            last_base = Some(cache.stats());
        });
    println!("{}", uncoalesced.report());
    let bs = last_base.expect("baseline bench ran at least once");

    // The deterministic identity the gate rides on: CLIENTS·chunks
    // lookups, cache writes = actual contractions, the rest avoided.
    let lookups = CLIENTS * chunks;
    let avoided = lookups - cs.writes;
    let ideal = (CLIENTS - 1) * chunks;
    let ratio = avoided as f64 / ideal as f64;
    println!(
        "coalesced: {avoided}/{ideal} duplicate contraction(s) avoided ({ratio:.2}x floor \
         metric) — {} write(s) for {chunks} chunk(s), coalescer {:?}",
        cs.writes, co
    );
    let dup = bs.writes.saturating_sub(chunks);
    println!(
        "uncoalesced baseline: {} write(s) for {chunks} chunk(s) ({dup} duplicate(s))",
        bs.writes
    );
    results.push(coalesced);
    results.push(uncoalesced);
    results.push(counter("service/coalesced_contractions_avoided", avoided, ratio));
    results.push(counter(
        "service/uncoalesced_duplicate_contractions",
        dup,
        bs.writes as f64 / chunks.max(1) as f64,
    ));

    std::fs::remove_dir_all(&dir).ok();
    write_json(&results, "BENCH_service.json").expect("writing BENCH_service.json");
    println!("[json] wrote BENCH_service.json ({} benchmarks)", results.len());
}
