//! Bench: Fig 15/16 3D-stacking studies.
use xrcarbon::accel::Workload;
use xrcarbon::bench::Bencher;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{fig15_stacking, fig16_stacking_kernels};

fn main() {
    let mut ctx = Ctx::auto();
    println!("[engine: {}]", ctx.backend);
    let r = Bencher::new("fig15/sr512").run(|| {
        fig15_stacking::run(ctx.engine.as_mut(), Workload::Sr512).unwrap()
    });
    println!("{}", r.report());
    let r = Bencher::new("fig16/five_kernels").quick().run(|| {
        fig16_stacking_kernels::run(ctx.engine.as_mut()).unwrap()
    });
    println!("{}", r.report());
}
