//! Bench: Fig 10 lifetime sweep (11 whole-life evaluations of A-1..A-4).
use xrcarbon::bench::Bencher;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::fig10_lifetime_crossover as fig10;

fn main() {
    let mut ctx = Ctx::auto();
    println!("[engine: {}]", ctx.backend);
    let axis = fig10::default_axis();
    let r = Bencher::new("fig10/sweep_11pts").throughput(axis.len() as u64).run(|| {
        fig10::run(ctx.engine.as_mut(), &axis).unwrap()
    });
    println!("{}", r.report());
}
