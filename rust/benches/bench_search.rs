//! Bench: adaptive search vs exhaustive enumeration — (a) the 121-point
//! Fig 7 anchor (profile-everything + sweep versus profile-on-demand
//! search, wall clock and evaluations) and (b) the ~10k-point expanded
//! 2-D/3-D space, where only the search is affordable and the metric is
//! coverage (candidates evaluated vs space size).
//!
//! Emits `BENCH_search.json` with two pseudo-entries the CI smoke gate
//! (`tools/check_bench_gate.py`) consumes:
//!
//! * `search/evaluations_vs_exhaustive` — `samples` = candidates the
//!   anchor search evaluated, `throughput` = 121 / evaluations
//!   (evaluations-saved ratio; the gate requires ≥ 121/72 ≈ 1.67×, the
//!   ≤ 60 % anchor budget);
//! * `search/expanded_coverage` — `samples` = candidates evaluated on
//!   the expanded space, `throughput` = space / evaluations (gate: ≥ 5×).
//!
//! Set `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use std::time::Duration;

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::carbon::FabGrid;
use xrcarbon::dse::search::{search, SearchConfig, SimulatorEvaluator};
use xrcarbon::dse::sweep::{sweep, SweepConfig};
use xrcarbon::dse::SearchSpace;
use xrcarbon::experiments::search_fig7::{expanded_grid, run_expanded};
use xrcarbon::experiments::sweep_fig7::profile_cluster;
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::workloads::{cluster_workloads, Cluster};

/// Counter pseudo-entry: `samples` carries a count, `throughput` a
/// ratio; timings are zero (this row is data, not a measurement).
fn counter(name: &str, samples: usize, ratio: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        samples,
        mean: Duration::ZERO,
        p50: Duration::ZERO,
        p95: Duration::ZERO,
        throughput: Some(ratio),
    }
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let cluster = Cluster::Ai5;

    // Shared scenario calibration (an input to both paths, not part of
    // the unit under test).
    let space = profile_cluster(cluster);
    let grid = xrcarbon::dse::ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);

    // (a) Exhaustive: profile all 121 candidates, then sweep.
    let ex = Bencher::new("search/exhaustive_grid121").quick_if_env().run(|| {
        let s = profile_cluster(cluster);
        sweep(&HostEngineFactory, &s.base, &grid, &SweepConfig::default()).unwrap()
    });
    println!("{}", ex.report());

    // Adaptive: profile only what the search visits.
    let evaluator =
        SimulatorEvaluator { workloads: cluster_workloads(cluster), fab: FabGrid::Coal };
    let mut evals = 0usize;
    let ad = Bencher::new("search/adaptive_grid121").quick_if_env().run(|| {
        let out = search(
            &HostEngineFactory,
            &SearchSpace::fig7_grid(),
            &evaluator,
            &space.base,
            &grid,
            &SearchConfig::default(),
        )
        .unwrap();
        evals = out.evaluations;
        out
    });
    println!("{}", ad.report());
    let saved = 121.0 / evals.max(1) as f64;
    let wall = ex.mean.as_secs_f64() / ad.mean.as_secs_f64();
    println!(
        "anchor: {evals}/121 candidates evaluated ({saved:.2}x evaluations saved, {wall:.2}x wall clock)"
    );
    results.push(ex);
    results.push(ad);
    results.push(counter("search/evaluations_vs_exhaustive", evals, saved));

    // (b) Expanded 2-D/3-D space: search is the only affordable path —
    // report coverage and wall clock, capturing the outcome of the last
    // benched run (deterministic: every run is identical for the seed).
    let mut expanded = None;
    let exp = Bencher::new("search/adaptive_expanded10k").quick_if_env().run(|| {
        let f = run_expanded(&HostEngineFactory, Cluster::Xr5, &SearchConfig::default()).unwrap();
        expanded = Some(f.outcome);
    });
    println!("{}", exp.report());
    let out = expanded.expect("bench ran at least once");
    let coverage = out.space_size as f64 / out.evaluations.max(1) as f64;
    println!(
        "expanded: {}/{} candidates evaluated ({coverage:.1}x saved), converged={}, grid scenarios={}",
        out.evaluations,
        out.space_size,
        out.converged,
        expanded_grid().cardinality(),
    );
    results.push(exp);
    results.push(counter("search/expanded_coverage", out.evaluations, coverage));

    write_json(&results, "BENCH_search.json").expect("writing BENCH_search.json");
    println!("[json] wrote BENCH_search.json ({} benchmarks)", results.len());
}
