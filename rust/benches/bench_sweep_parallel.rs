//! Bench: the multi-scenario sweep coordinator — (a) thread scaling of
//! the two-phase path and (b) the headline fused-vs-two-phase comparison
//! (PR 1 per-scenario engine fan-out vs profile-once + scenario
//! overlays). Acceptance: ≥ 2× engine-work speedup on a grid of ≥ 6
//! scenarios (this one has 9).
//!
//! The design space is the 121-point grid replicated ×32 (3872 configs —
//! four 1024-variant chunks, so phase A has real work to fan out) and the
//! scenario grid is the Fig 7 embodied-share preset crossed with a
//! 3-point β axis — 9 scenarios (36 engine items for the fused
//! per-scenario sweep vs 4 engine items total for the two-phase sweep).
//! Profiling (the simulator) runs once, outside the timed region; the
//! sweep coordinator is the unit under test.
//!
//! Emits `BENCH_sweep.json` (see `bench::write_json`); set
//! `XRCARBON_BENCH_QUICK=1` for the short sampling mode CI uses.

use xrcarbon::bench::{write_json, BenchResult, Bencher};
use xrcarbon::dse::grid::ScenarioGrid;
use xrcarbon::dse::sweep::{sweep, sweep_fused, SweepConfig};
use xrcarbon::experiments::sweep_fig7::profile_cluster;
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::workloads::Cluster;

fn main() {
    let space = profile_cluster(Cluster::Ai5);

    // Replicate the space ×32: four large-variant chunks, so the
    // two-phase profile pass parallelizes and fused items fill the
    // artifact batches.
    let mut big = Vec::with_capacity(space.rows.len() * 32);
    for rep in 0..32 {
        for row in &space.rows {
            let mut r = row.clone();
            r.name = format!("{}#{rep}", r.name);
            big.push(r);
        }
    }
    let mut base = space.base.clone();
    base.configs = big;

    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j)
        .with_beta("b=0.5", 0.5)
        .with_beta("b=1", 1.0)
        .with_beta("b=2", 2.0);
    println!(
        "[space: {} configs x {} scenarios]",
        base.configs.len(),
        grid.cardinality()
    );

    let mut results: Vec<BenchResult> = Vec::new();
    let items = (base.configs.len() * grid.cardinality()) as u64;

    // (a) Thread scaling of the two-phase path.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut means = Vec::new();
    for threads in [1usize, 2, 4, hw.min(8)] {
        if means.iter().any(|&(t, _)| t == threads) {
            continue;
        }
        let r = Bencher::new(&format!("sweep/two_phase_threads={threads}"))
            .quick_if_env()
            .throughput(items)
            .run(|| sweep(&HostEngineFactory, &base, &grid, &SweepConfig { threads }).unwrap());
        println!("{}", r.report());
        means.push((threads, r.mean.as_secs_f64()));
        results.push(r);
    }
    let t1 = means[0].1;
    for &(threads, mean) in &means[1..] {
        let speedup = t1 / mean;
        println!("two-phase speedup @ {threads} threads: {speedup:.2}x");
    }

    // (b) Fused (PR 1 per-scenario engine fan-out) vs two-phase
    // (profile once + overlays), same thread budget. The engine-work
    // ratio is ~N_scenarios:1, so wall clock must show ≥ 2×.
    for threads in [1usize, 4] {
        let fused = Bencher::new(&format!("sweep/fused_per_scenario_threads={threads}"))
            .quick_if_env()
            .throughput(items)
            .run(|| {
                sweep_fused(&HostEngineFactory, &base, &grid, &SweepConfig { threads }).unwrap()
            });
        println!("{}", fused.report());
        let two = means
            .iter()
            .find(|&&(t, _)| t == threads)
            .map(|&(_, m)| m)
            .unwrap_or(t1);
        let speedup = fused.mean.as_secs_f64() / two;
        println!(
            "fused/two-phase speedup @ {threads} threads: {speedup:.2}x (target >= 2.0, grid = {} scenarios)",
            grid.cardinality()
        );
        results.push(fused);
    }

    write_json(&results, "BENCH_sweep.json").expect("writing BENCH_sweep.json");
    println!("[json] wrote BENCH_sweep.json ({} benchmarks)", results.len());
}
