//! Bench: parallel scenario-sweep scaling, 1 → N worker threads on the
//! Fig 7-preset grid (acceptance: ≥2× wall-clock speedup at 4 threads).
//!
//! The design space is the 121-point grid replicated ×8 (968 configs, one
//! full 1024-variant chunk per scenario) and the scenario grid is the
//! Fig 7 embodied-share preset crossed with a 3-point β axis — 9
//! scenarios, 9 work items — so each thread count has real work to
//! schedule. Profiling (the simulator) runs once, outside the timed
//! region; the sweep coordinator is the unit under test.

use xrcarbon::bench::Bencher;
use xrcarbon::dse::grid::ScenarioGrid;
use xrcarbon::dse::sweep::{sweep, SweepConfig};
use xrcarbon::experiments::sweep_fig7::profile_cluster;
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::workloads::Cluster;

fn main() {
    let space = profile_cluster(Cluster::Ai5);

    // Replicate the space ×8 so each (scenario × chunk) item fills the
    // large artifact variant.
    let mut big = Vec::with_capacity(space.rows.len() * 8);
    for rep in 0..8 {
        for row in &space.rows {
            let mut r = row.clone();
            r.name = format!("{}#{rep}", r.name);
            big.push(r);
        }
    }
    let mut base = space.base.clone();
    base.configs = big;

    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j)
        .with_beta("b=0.5", 0.5)
        .with_beta("b=1", 1.0)
        .with_beta("b=2", 2.0);
    println!(
        "[space: {} configs x {} scenarios]",
        base.configs.len(),
        grid.cardinality()
    );

    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut means = Vec::new();
    for threads in [1usize, 2, 4, hw.min(8)] {
        if means.iter().any(|&(t, _)| t == threads) {
            continue;
        }
        let r = Bencher::new(&format!("sweep/fig7x3beta_threads={threads}"))
            .throughput((base.configs.len() * grid.cardinality()) as u64)
            .run(|| sweep(&HostEngineFactory, &base, &grid, &SweepConfig { threads }).unwrap());
        println!("{}", r.report());
        means.push((threads, r.mean.as_secs_f64()));
    }

    let t1 = means[0].1;
    for &(threads, mean) in &means[1..] {
        let speedup = t1 / mean;
        let target = if threads >= 4 { " (target >= 2.0)" } else { "" };
        println!("speedup @ {threads} threads: {speedup:.2}x{target}");
    }
}
