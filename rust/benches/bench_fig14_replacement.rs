//! Bench: Fig 14 replacement-period sweep (pure host-side model).
use xrcarbon::bench::Bencher;
use xrcarbon::experiments::fig14_replacement;

fn main() {
    let r = Bencher::new("fig14/three_panels").run(fig14_replacement::run);
    println!("{}", r.report());
}
