//! Bench: Fig 1/2 retrospective analyses (host-side carbon model).
use xrcarbon::bench::Bencher;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{fig01_metric_comparison, fig02_retrospective};

fn main() {
    let r = Bencher::new("fig2/cpu_panel").run(fig02_retrospective::run_cpus);
    println!("{}", r.report());
    let r = Bencher::new("fig2/soc_panel").run(fig02_retrospective::run_socs);
    println!("{}", r.report());
    let mut ctx = Ctx::auto();
    let r = Bencher::new("fig1/metric_suite_a1_a4").run(|| {
        fig01_metric_comparison::run(&mut ctx).unwrap()
    });
    println!("{}", r.report());
}
