//! Minimal command-line parsing (offline substitute for `clap`).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional…]`
//! with typed accessors, defaults, and a generated usage string.

mod args;

pub use args::{parse_byte_size, parse_cache_budget, Args, CliError};
