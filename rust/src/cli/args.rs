//! Tiny argv parser: subcommand + `--key value` / `--flag` options.

use std::collections::BTreeMap;

/// Parse error.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum CliError {
    /// An option that expects a value was last on the line, or was
    /// directly followed by another `--option` token (which would
    /// otherwise be silently swallowed as its value).
    #[error("option --{0} expects a value")]
    MissingValue(String),
    /// A value failed to parse as the requested type.
    #[error("option --{0}: cannot parse '{1}' as {2}")]
    BadValue(String, String, &'static str),
    /// An option name not in the valued or flag lists. Rejected loudly: a
    /// mistyped valued option would otherwise become a flag and its value
    /// a stray positional.
    #[error("unknown option --{0}; valued options: {1}; flags: {2}")]
    UnknownOption(String, String, String),
}

impl CliError {
    fn unknown(name: &str) -> CliError {
        CliError::UnknownOption(name.to_string(), VALUED.join(", "), FLAGS.join(", "))
    }
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-option token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans (no value).
    pub flags: Vec<String>,
    /// Remaining positional tokens after the subcommand.
    pub positional: Vec<String>,
}

/// Option names that take a value.
const VALUED: &[&str] = &[
    "cluster", "metric", "out", "artifacts", "engine", "seed", "beta", "ratio",
    "lifetime", "hours", "devices", "days", "workload", "cores", "csv-dir",
    "threads", "preset", "space", "max-evals", "cache-dir", "cache-budget", "resume",
    "trace", "addr", "state-dir", "executors", "auth-token",
];

/// Flag names (no value). Anything after `--` that is in neither list is
/// rejected with [`CliError::UnknownOption`].
const FLAGS: &[&str] = &["cpus", "csv", "help", "search", "socs"];

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if VALUED.contains(&name) {
                    match it.next() {
                        // A following `--option` token is another option,
                        // not this option's value: `sweep --preset --search`
                        // must not set preset="--search" and drop the flag.
                        // (Single-dash values — negative numbers — stay
                        // accepted.)
                        Some(v) if !v.starts_with("--") => {
                            out.options.insert(name.to_string(), v);
                        }
                        _ => return Err(CliError::MissingValue(name.to_string())),
                    }
                } else if FLAGS.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    return Err(CliError::unknown(name));
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(String::as_str).unwrap_or(default)
    }

    /// f64 option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.to_string(), v.clone(), "f64")),
        }
    }

    /// usize option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.to_string(), v.clone(), "usize")),
        }
    }

    /// u64 option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(key.to_string(), v.clone(), "u64")),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Byte size with optional K/M/G suffix (powers of two). `None` for
/// anything that does not parse as a `u64` count of bytes — including
/// values whose suffixed product overflows `u64` (`checked_mul`, not a
/// silent wrap: `20000000000G` used to be representable garbage).
pub fn parse_byte_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1u64 << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1u64 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s, 1),
    };
    num.parse::<u64>().ok()?.checked_mul(mult)
}

/// `--cache-budget` value: a byte size that must be **positive**. A
/// budget of `0` is rejected rather than interpreted — it would mean
/// "evict everything but the newest entry on every write", which nobody
/// asks for on purpose; "no eviction" is spelled by omitting the option
/// entirely (the cache's `budget_bytes: None` default).
pub fn parse_cache_budget(s: &str) -> Result<u64, CliError> {
    match parse_byte_size(s) {
        None => Err(CliError::BadValue(
            "cache-budget".to_string(),
            s.to_string(),
            "byte size (e.g. 67108864, 64M, 2G)",
        )),
        Some(0) => Err(CliError::BadValue(
            "cache-budget".to_string(),
            s.to_string(),
            "positive byte size (0 would evict every entry but the newest; omit \
             --cache-budget to disable eviction)",
        )),
        Some(n) => Ok(n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig7 --cluster 5ai --ratio 0.98 --csv");
        assert_eq!(a.command.as_deref(), Some("fig7"));
        assert_eq!(a.get("cluster", "all"), "5ai");
        assert_eq!(a.get_f64("ratio", 0.0).unwrap(), 0.98);
        assert!(a.has_flag("csv"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("fig7");
        assert_eq!(a.get("cluster", "all"), "all");
        assert_eq!(a.get_usize("devices", 400).unwrap(), 400);
    }

    #[test]
    fn positionals_collected() {
        let a = parse("bench one two --csv three");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["one", "two", "three"]);
        assert!(a.has_flag("csv"));
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(vec!["x".into(), "--cluster".into()]).unwrap_err();
        assert_eq!(e, CliError::MissingValue("cluster".into()));
    }

    #[test]
    fn unknown_option_rejected_with_known_lists() {
        let e = Args::parse(vec!["fig7".into(), "--verbose".into()]).unwrap_err();
        assert!(matches!(e, CliError::UnknownOption(ref n, _, _) if n == "verbose"));
        let msg = e.to_string();
        assert!(msg.contains("--verbose"), "{msg}");
        assert!(msg.contains("cluster"), "{msg}");
        assert!(msg.contains("csv"), "{msg}");
    }

    #[test]
    fn mistyped_valued_option_does_not_swallow_value() {
        // Before: "--cluser" became a flag and "5ai" a stray positional.
        let tokens = vec!["fig7".into(), "--cluser".into(), "5ai".into()];
        let e = Args::parse(tokens).unwrap_err();
        assert!(matches!(e, CliError::UnknownOption(ref n, _, _) if n == "cluser"));
    }

    #[test]
    fn valued_option_does_not_swallow_a_following_option() {
        // Before: "--preset" swallowed "--search" as its value, silently
        // setting preset="--search" and dropping the flag.
        let e = Args::parse(
            vec!["sweep".into(), "--preset".into(), "--search".into()],
        )
        .unwrap_err();
        assert_eq!(e, CliError::MissingValue("preset".into()));
        // A flag followed by a valued option is unaffected…
        let a = parse("sweep --search --preset fig10");
        assert!(a.has_flag("search"));
        assert_eq!(a.get("preset", "fig7"), "fig10");
        // …and single-dash values (negative numbers) still parse.
        let a = Args::parse(vec!["x".into(), "--beta".into(), "-1.5".into()]).unwrap();
        assert_eq!(a.get_f64("beta", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn trace_option_is_registered() {
        let a = parse("sweep --preset trace --trace diurnal-renewable");
        assert_eq!(a.get("preset", "fig7"), "trace");
        assert_eq!(a.get("trace", ""), "diurnal-renewable");
    }

    #[test]
    fn cache_options_are_registered() {
        let a = parse("sweep --cache-dir .cache/profiles --resume ckpt.json --cache-budget 512M");
        assert_eq!(a.get("cache-dir", ""), ".cache/profiles");
        assert_eq!(a.get("resume", ""), "ckpt.json");
        assert_eq!(a.get("cache-budget", ""), "512M");
    }

    #[test]
    fn search_options_are_registered() {
        // The sweep --search surface: the flag plus its valued knobs.
        let a = parse("sweep --search --space expanded --seed 7 --max-evals 500");
        assert!(a.has_flag("search"));
        assert_eq!(a.get("space", "fig7"), "expanded");
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.get_usize("max-evals", 0).unwrap(), 500);
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse("x --ratio notanumber");
        assert!(matches!(a.get_f64("ratio", 1.0), Err(CliError::BadValue(..))));
    }

    #[test]
    fn serve_options_are_registered() {
        let a = parse("serve --addr 127.0.0.1:7878 --state-dir .state --executors 4");
        assert_eq!(a.get("addr", ""), "127.0.0.1:7878");
        assert_eq!(a.get("state-dir", ""), ".state");
        assert_eq!(a.get_usize("executors", 2).unwrap(), 4);
    }

    #[test]
    fn byte_sizes_parse_with_and_without_suffix() {
        assert_eq!(parse_byte_size("1024"), Some(1024));
        assert_eq!(parse_byte_size("64K"), Some(64 << 10));
        assert_eq!(parse_byte_size("64k"), Some(64 << 10));
        assert_eq!(parse_byte_size("512M"), Some(512 << 20));
        assert_eq!(parse_byte_size("2G"), Some(2u64 << 30));
        assert_eq!(parse_byte_size(" 8m "), Some(8 << 20));
        assert_eq!(parse_byte_size(""), None);
        assert_eq!(parse_byte_size("M"), None);
        assert_eq!(parse_byte_size("1.5G"), None);
        assert_eq!(parse_byte_size("-3"), None);
    }

    #[test]
    fn byte_sizes_reject_overflow_at_both_boundaries() {
        // Exactly u64::MAX in plain bytes is representable…
        assert_eq!(parse_byte_size("18446744073709551615"), Some(u64::MAX));
        // …one past it is not (u64 parse fails)…
        assert_eq!(parse_byte_size("18446744073709551616"), None);
        // …and a suffixed product past u64::MAX must fail via checked_mul,
        // not wrap: 2^34 G = 2^64 bytes.
        assert_eq!(parse_byte_size("17179869184G"), None);
        assert_eq!(parse_byte_size("999999999999G"), None);
        // The largest suffixed values that still fit do fit.
        assert_eq!(parse_byte_size("17179869183G"), Some(17179869183u64 << 30));
    }

    #[test]
    fn cache_budget_rejects_zero_and_garbage() {
        assert_eq!(parse_cache_budget("64M").unwrap(), 64 << 20);
        assert_eq!(parse_cache_budget("18446744073709551615").unwrap(), u64::MAX);
        assert!(matches!(parse_cache_budget("0"), Err(CliError::BadValue(..))));
        assert!(matches!(parse_cache_budget("0K"), Err(CliError::BadValue(..))));
        assert!(matches!(parse_cache_budget("nope"), Err(CliError::BadValue(..))));
        assert!(matches!(
            parse_cache_budget("18446744073709551616"),
            Err(CliError::BadValue(..))
        ));
        let msg = parse_cache_budget("0").unwrap_err().to_string();
        assert!(msg.contains("cache-budget"), "{msg}");
        assert!(msg.contains("omit"), "{msg}");
    }
}
