//! Cross-job coalescing of identical in-flight profile computations.
//!
//! N clients asking a warm cache for the same chunk already cost zero
//! engine contractions; N clients asking a *cold* cache for the same
//! chunk used to cost N. The [`Coalescer`] closes that gap: profile
//! requests are keyed by the existing [`CacheKey`] content hash, the
//! first requester of a key becomes its **leader** (and computes), and
//! every concurrent requester becomes a **waiter** that blocks until the
//! leader publishes the finished [`DesignProfile`] — one phase-A
//! contraction per unique chunk, however many jobs ask.
//!
//! Protocol (the order is load-bearing):
//!
//! 1. A requester that misses the cache calls [`Coalescer::begin`]. If
//!    no computation for the key is in flight it receives a
//!    [`LeadGuard`]; otherwise a [`Waiter`].
//! 2. A leader **re-checks the cache after winning leadership**: the
//!    previous leader stores to the cache *before* retiring its
//!    in-flight entry, so "absent from the in-flight map" can mean
//!    "already in the cache" — the re-check turns that race into a hit.
//! 3. A leader that computed stores the profile to the shared cache,
//!    then calls [`LeadGuard::publish`], which wakes every waiter and
//!    only then removes the in-flight entry (store-before-retire is the
//!    invariant step 2 relies on).
//! 4. A leader that dies without publishing (engine error, fail-fast
//!    abort, panic) poisons its slot on [`Drop`], so waiters return
//!    `None` instead of blocking forever and fall back to computing
//!    themselves.
//!
//! Deadlock freedom: a driver step publishes every key it leads before
//! it waits on any key it follows, so the wait graph between concurrent
//! jobs is leader→waiter only and acyclic. Bit-identity is free: phase-A
//! contraction is deterministic per engine, so a waiter's profile is the
//! same bits it would have computed itself.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};

use super::cache::CacheKey;
use crate::matrixform::DesignProfile;

/// One in-flight computation: state under a mutex plus a condvar the
/// waiters park on.
#[derive(Debug)]
enum SlotState {
    Pending,
    Done(DesignProfile),
    Failed,
}

type Slot = Arc<(Mutex<SlotState>, Condvar)>;

/// Counter snapshot of a [`Coalescer`] (process lifetime, aggregated
/// across every job that shares the instance).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoalesceStats {
    /// `begin` calls — cache-missing profile requests that entered the
    /// coalescer.
    pub requests: u64,
    /// Requests that won leadership of their key.
    pub led: u64,
    /// Leaders resolved by the post-leadership cache re-check (the
    /// store-before-retire race, turned into a hit).
    pub lead_cache_hits: u64,
    /// Leaders that went on to compute (published after an engine
    /// contraction).
    pub computed: u64,
    /// Leaders that died without publishing.
    pub lead_failures: u64,
    /// Requests that joined an in-flight computation as waiters.
    pub waited: u64,
    /// Waits resolved with a published profile.
    pub served_from_wait: u64,
    /// Waits resolved by a failed leader (the waiter recomputes).
    pub failed_waits: u64,
}

impl CoalesceStats {
    /// Duplicate engine contractions avoided by coalescing alone:
    /// every request served by someone else's in-flight computation.
    pub fn coalesced_avoided(&self) -> u64 {
        self.served_from_wait + self.lead_cache_hits
    }
}

/// Shared in-flight map over profile-chunk keys. One instance per
/// service/process, shared by reference across every concurrent job.
#[derive(Debug, Default)]
pub struct Coalescer {
    inflight: Mutex<HashMap<CacheKey, Slot>>,
    requests: AtomicU64,
    led: AtomicU64,
    lead_cache_hits: AtomicU64,
    computed: AtomicU64,
    lead_failures: AtomicU64,
    waited: AtomicU64,
    served_from_wait: AtomicU64,
    failed_waits: AtomicU64,
}

/// `begin`'s verdict: compute it yourself, or wait for whoever is.
pub enum Admission<'a> {
    /// This requester owns the computation for the key.
    Lead(LeadGuard<'a>),
    /// An identical computation is in flight; block on [`Waiter::wait`].
    Wait(Waiter<'a>),
}

impl Coalescer {
    /// Fresh coalescer with zeroed counters and an empty in-flight map.
    pub fn new() -> Coalescer {
        Coalescer::default()
    }

    // xrverify: model(coalescer)
    // Fenced: the admission protocol verified exhaustively by
    // tools/xrverify/model_coalescer.py (3 requesters, one key, leader
    // death injected; every interleaving). The check-then-insert below
    // is ONE critical section — splitting it is the model's
    // `begin_race` seeded bug. Editing fenced code without re-reviewing
    // the model is a V001 finding.

    /// Admit a cache-missing request for `key`: the first requester
    /// leads, everyone else waits.
    pub fn begin(&self, key: CacheKey) -> Admission<'_> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut map = self.inflight.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(slot) = map.get(&key) {
            self.waited.fetch_add(1, Ordering::Relaxed);
            return Admission::Wait(Waiter { co: self, slot: slot.clone() });
        }
        let slot: Slot = Arc::new((Mutex::new(SlotState::Pending), Condvar::new()));
        map.insert(key, slot.clone());
        drop(map);
        self.led.fetch_add(1, Ordering::Relaxed);
        Admission::Lead(LeadGuard { co: self, key, slot, resolved: false })
    }
    // xrverify: endmodel(coalescer)

    /// Counter snapshot.
    pub fn stats(&self) -> CoalesceStats {
        CoalesceStats {
            requests: self.requests.load(Ordering::Relaxed),
            led: self.led.load(Ordering::Relaxed),
            lead_cache_hits: self.lead_cache_hits.load(Ordering::Relaxed),
            computed: self.computed.load(Ordering::Relaxed),
            lead_failures: self.lead_failures.load(Ordering::Relaxed),
            waited: self.waited.load(Ordering::Relaxed),
            served_from_wait: self.served_from_wait.load(Ordering::Relaxed),
            failed_waits: self.failed_waits.load(Ordering::Relaxed),
        }
    }
}

// xrverify: model(coalescer)
/// Leadership of one in-flight key. Publish exactly once; dropping the
/// guard without publishing poisons the slot so waiters fall back to
/// computing themselves instead of blocking forever.
pub struct LeadGuard<'a> {
    co: &'a Coalescer,
    key: CacheKey,
    slot: Slot,
    resolved: bool,
}

impl LeadGuard<'_> {
    fn resolve(&mut self, state: SlotState) {
        *self.slot.0.lock().unwrap_or_else(PoisonError::into_inner) = state;
        self.slot.1.notify_all();
        self.co
            .inflight
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .remove(&self.key);
        self.resolved = true;
    }

    /// Publish a freshly computed profile to every waiter and retire
    /// the in-flight entry. Call *after* the profile was stored to the
    /// shared cache: retirement is the signal "check the cache" for
    /// requesters that arrive later.
    pub fn publish(mut self, profile: &DesignProfile) {
        self.co.computed.fetch_add(1, Ordering::Relaxed);
        self.resolve(SlotState::Done(profile.clone()));
    }

    /// Publish a profile the post-leadership cache re-check produced
    /// (no computation happened; counted separately).
    pub fn publish_cached(mut self, profile: &DesignProfile) {
        self.co.lead_cache_hits.fetch_add(1, Ordering::Relaxed);
        self.resolve(SlotState::Done(profile.clone()));
    }
}

impl Drop for LeadGuard<'_> {
    fn drop(&mut self) {
        if self.resolved {
            return;
        }
        // Leader died without publishing: fail the waiters so they
        // recompute instead of parking forever.
        self.co.lead_failures.fetch_add(1, Ordering::Relaxed);
        self.resolve(SlotState::Failed);
    }
}

/// A ticket on someone else's in-flight computation.
pub struct Waiter<'a> {
    co: &'a Coalescer,
    slot: Slot,
}

impl Waiter<'_> {
    /// Block until the leader resolves the slot. `Some(profile)` on a
    /// publish (bit-identical to computing it locally — phase A is
    /// deterministic per engine); `None` when the leader failed, in
    /// which case the caller recomputes.
    pub fn wait(self) -> Option<DesignProfile> {
        let (lock, cv) = &*self.slot;
        let mut st = lock.lock().unwrap_or_else(PoisonError::into_inner);
        while matches!(*st, SlotState::Pending) {
            st = cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        match &*st {
            SlotState::Done(profile) => {
                self.co.served_from_wait.fetch_add(1, Ordering::Relaxed);
                Some(profile.clone())
            }
            SlotState::Failed => {
                self.co.failed_waits.fetch_add(1, Ordering::Relaxed);
                None
            }
            SlotState::Pending => unreachable!("loop exits only on a resolved slot"),
        }
    }
}
// xrverify: endmodel(coalescer)

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::C_VARIANTS;

    fn key(lo: u64) -> CacheKey {
        // Any 32-hex-char string round-trips into a key; synthesize
        // distinct ones from the low word.
        CacheKey::from_hex(&format!("{:016x}{:016x}", 0u64, lo)).unwrap()
    }

    fn tiny_profile(tag: f32) -> DesignProfile {
        let c_pad = C_VARIANTS[0];
        DesignProfile {
            energy: vec![tag; c_pad],
            delay: vec![2.0 * tag; c_pad],
            d_task: vec![0.5; c_pad * crate::matrixform::T_PAD],
            c_comp: vec![1.0; c_pad * crate::matrixform::J_PAD],
            c_pad,
            c: 1,
            t: 1,
            names: vec!["cfg0".into()],
        }
    }

    #[test]
    fn second_requester_waits_and_gets_the_leaders_bits() {
        let co = Coalescer::new();
        let k = key(1);
        let lead = match co.begin(k) {
            Admission::Lead(g) => g,
            Admission::Wait(_) => panic!("first requester must lead"),
        };
        let wait = match co.begin(k) {
            Admission::Wait(w) => w,
            Admission::Lead(_) => panic!("second requester must wait"),
        };
        let profile = tiny_profile(3.5);
        std::thread::scope(|s| {
            let h = s.spawn(move || wait.wait());
            lead.publish(&profile);
            let got = h.join().unwrap().expect("published profile reaches the waiter");
            assert_eq!(
                got.energy.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                profile.energy.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        });
        let s = co.stats();
        assert_eq!((s.requests, s.led, s.waited, s.computed, s.served_from_wait), (2, 1, 1, 1, 1));
        assert_eq!(s.coalesced_avoided(), 1);
        // The entry retired with the publish: the next requester leads.
        assert!(matches!(co.begin(k), Admission::Lead(_)));
    }

    #[test]
    fn dropped_leader_fails_waiters_instead_of_wedging_them() {
        let co = Coalescer::new();
        let k = key(2);
        let lead = match co.begin(k) {
            Admission::Lead(g) => g,
            Admission::Wait(_) => panic!("first requester must lead"),
        };
        let wait = match co.begin(k) {
            Admission::Wait(w) => w,
            Admission::Lead(_) => panic!("second requester must wait"),
        };
        drop(lead); // engine error / fail-fast abort path
        assert!(wait.wait().is_none(), "failed leader yields None, not a hang");
        let s = co.stats();
        assert_eq!((s.lead_failures, s.failed_waits, s.computed), (1, 1, 0));
        // The key is free again: the waiter's retry can lead.
        assert!(matches!(co.begin(k), Admission::Lead(_)));
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let co = Coalescer::new();
        let a = co.begin(key(3));
        let b = co.begin(key(4));
        assert!(matches!(a, Admission::Lead(_)));
        assert!(matches!(b, Admission::Lead(_)));
    }

    #[test]
    fn many_concurrent_requesters_one_computation() {
        let co = Coalescer::new();
        let k = key(5);
        let profile = tiny_profile(1.25);
        let done = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| match co.begin(k) {
                    Admission::Lead(g) => {
                        g.publish(&profile);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                    Admission::Wait(w) => {
                        if w.wait().is_some() {
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 8, "every requester resolved");
        let s = co.stats();
        assert_eq!(s.requests, 8);
        // At most one computation can be in flight per key at a time;
        // late arrivals after retirement may lead again, but in this
        // test every leader publishes instantly, so served waiters plus
        // leaders account for all eight requests with zero failures.
        assert_eq!(s.led + s.waited, 8);
        assert_eq!(s.lead_failures, 0);
        assert_eq!(s.served_from_wait, s.waited);
    }
}
