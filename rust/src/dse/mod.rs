//! The design-space-exploration coordinator (paper §3, §5.1–§5.3).
//!
//! This is the system's Layer-3 contribution: it enumerates the hardware
//! design space, profiles every candidate on the target workloads with the
//! accelerator simulator, assembles §3.3 matrix batches, streams them
//! through the XLA runtime (splitting across artifact variants when the
//! space exceeds one batch), applies the §3.2 constraints, and extracts
//! optimal designs, distribution statistics and Pareto fronts.
//!
//! * [`space`]    — the 11×11 MAC×SRAM grid (121 configs) and the
//!   parametric [`SearchSpace`] (MAC × SRAM × 2-D/3-D × clock) the
//!   adaptive search explores;
//! * [`profile`]  — accelerator-simulator profiling → [`ConfigRow`]s
//!   (parallelized with scoped threads; the simulator is the expensive
//!   part of batch assembly);
//! * [`explore`]  — end-to-end exploration for a workload cluster and
//!   carbon scenario; summary statistics (best/mean/p5/p95);
//! * [`batching`] — request splitting/merging across batch variants;
//! * [`pareto`]   — β sweeps and Pareto-front extraction (Table 1);
//! * [`scenario`] — embodied-ratio ↔ operational-lifetime calibration
//!   (the 98 %/65 %/25 % scenarios of Fig 7);
//! * [`grid`]     — labeled scenario cross-products (CI × lifetime × QoS
//!   × β × power cap × CI-trace) with presets for the Fig 7/10/11 sweeps
//!   and the named time-varying trace axis (`ScenarioGrid::traces`);
//!   trace scenarios lower into per-segment `ci_use` overrides
//!   (`SweepScenario::lower`) recombined by `carbon::combine_segments`;
//! * [`sweep`]    — the two-phase parallel multi-scenario coordinator:
//!   profiles config chunks once across per-thread engines (phase A),
//!   then fans cheap scenario overlays over the cached profiles (phase
//!   B), bit-identical to the sequential and fused per-scenario paths.
//!   Phase A is an explicit state machine (`SweepDriver`) with
//!   fingerprinted per-chunk checkpoints (`SweepCheckpoint`), so a
//!   sweep over a giant space interrupted at any chunk resumes
//!   bit-identically through the profile cache;
//! * [`search`]   — adaptive Pareto-guided search over a
//!   [`SearchSpace`]: seeded lattice sampling, successive-halving
//!   refinement around the pooled Pareto archive, generations batched
//!   through the two-phase coordinator — the scaling replacement for
//!   exhaustive enumeration on large 2-D/3-D spaces. The loop is an
//!   explicit state machine (`SearchDriver`) with
//!   `checkpoint()`/`resume()` so interrupted or budget-extended runs
//!   continue bit-identically;
//! * [`cache`]    — the persistent, content-addressed profile cache
//!   (`ProfileCache`): phase-A [`crate::matrixform::DesignProfile`]s
//!   keyed by a stable `ConfigRow`-level content hash (shape constants
//!   and schema version included), serialized as versioned bit-exact
//!   JSON envelopes with binary sidecars for fast warm reads, fronted
//!   by an in-memory LRU and kept under an optional on-disk size budget
//!   by LRU/generation-stamped eviction — warm-start sweeps skip every
//!   cached contraction. Safe for concurrent clients: writes and the
//!   eviction pass coordinate through an advisory directory lock;
//! * [`coalesce`] — cross-job coalescing of identical in-flight profile
//!   requests (`Coalescer`), keyed by the cache's content hash: N
//!   concurrent jobs asking for the same cold chunk trigger exactly one
//!   phase-A contraction, the rest wait for the leader's published
//!   bits. The service layer shares one instance across every job.

pub mod batching;
pub mod cache;
pub mod coalesce;
pub mod explore;
pub mod grid;
pub mod pareto;
pub mod profile;
pub mod scenario;
pub mod search;
pub mod space;
pub mod sweep;

pub use batching::{evaluate_chunked, profile_chunk_requests, profile_chunked};
pub use cache::{CacheConfig, CacheKey, ProfileCache, PROFILE_SCHEMA};
pub use coalesce::{Admission, CoalesceStats, Coalescer, LeadGuard, Waiter};
pub use explore::{explore, summarize, ExploreOutcome, ExploreStats};
pub use grid::{AxisPoint, ScenarioGrid, SweepScenario, TracePoint};
pub use pareto::{beta_sweep, pareto_front, BetaPoint};
pub use profile::{profile_configs, profiles_to_rows};
pub use scenario::{lifetime_for_ratio, Scenario};
pub use search::{
    evaluator_digest, exhaustive_front, grid_digest, pooled_objectives, read_checkpoint, search,
    search_resumable, write_checkpoint, ArchivePoint, PointEval, ReplayEvaluator, SearchBest,
    SearchCheckpoint, SearchConfig, SearchDriver, SearchOutcome, SimulatorEvaluator,
    SpaceEvaluator, CHECKPOINT_SCHEMA,
};
pub use space::{design_grid, DesignPoint, SearchSpace, SpaceIndex};
pub use sweep::{
    read_sweep_checkpoint, sweep, sweep_fingerprint, sweep_fused, sweep_resumable,
    sweep_sequential, sweep_with_cache, write_sweep_checkpoint, ScenarioResult, SweepCheckpoint,
    SweepConfig, SweepDriver, SweepOutcome, TraceMeta, SWEEP_CHECKPOINT_SCHEMA,
};
