//! Profiling candidate configurations on workload kernels and converting
//! simulator output into §3.3 matrix rows.
//!
//! This is the expensive half of batch assembly (the Fig 6 simulator runs
//! once per config × kernel), so it fans out across scoped threads.

use crate::accel::{network, simulate, AcceleratorConfig, KernelProfile, Workload};
use crate::carbon::FabGrid;
use crate::matrixform::ConfigRow;

/// Profile every `(config, workload)` pair. Returns `profiles[config][kernel]`.
pub fn profile_configs(
    configs: &[AcceleratorConfig],
    workloads: &[Workload],
) -> Vec<Vec<KernelProfile>> {
    // Build each network once (they are immutable inputs to all configs).
    let graphs: Vec<_> = workloads.iter().map(|&w| network(w)).collect();

    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = configs.len().div_ceil(n_threads).max(1);

    let mut out: Vec<Vec<KernelProfile>> = Vec::with_capacity(configs.len());
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for chunk_cfgs in configs.chunks(chunk) {
            let graphs = &graphs;
            handles.push(s.spawn(move || {
                chunk_cfgs
                    .iter()
                    .map(|cfg| graphs.iter().map(|g| simulate(cfg, g)).collect::<Vec<_>>())
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("profiling thread panicked"));
        }
    });
    out
}

/// Convert profiles into [`ConfigRow`]s.
///
/// Component vector layout (J = 3): `[logic die, SRAM, base/IO]` — the
/// provisioning knob for accelerators distinguishes compute silicon from
/// memory silicon (Fig 15's K/M axes).
pub fn profiles_to_rows(
    configs: &[AcceleratorConfig],
    profiles: &[Vec<KernelProfile>],
    fab: FabGrid,
) -> Vec<ConfigRow> {
    assert_eq!(configs.len(), profiles.len());
    configs
        .iter()
        .zip(profiles)
        .map(|(cfg, profs)| {
            let total = cfg.embodied_g(fab);
            // Split by area share.
            let logic_mm2 = cfg.num_macs as f64 * crate::accel::config::MAC_AREA_MM2_7NM;
            let sram_mm2 = cfg.sram_area_mm2();
            let base_mm2 = crate::accel::config::BASE_AREA_MM2;
            let sum = logic_mm2 + sram_mm2 + base_mm2;
            let c_comp = vec![
                total * logic_mm2 / sum,
                total * sram_mm2 / sum,
                total * base_mm2 / sum,
            ];
            ConfigRow {
                name: cfg.name.clone(),
                f_clk: cfg.freq_hz,
                d_k: profs.iter().map(|p| p.delay_s).collect(),
                e_dyn: profs.iter().map(|p| p.dynamic_j).collect(),
                leak_w: cfg.leakage_w(),
                c_comp,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::production_accelerators;

    #[test]
    fn profiles_cover_grid() {
        let configs = production_accelerators().to_vec();
        let wls = [Workload::Rn18, Workload::Sr256];
        let profs = profile_configs(&configs, &wls);
        assert_eq!(profs.len(), 4);
        assert_eq!(profs[0].len(), 2);
        for row in &profs {
            for p in row {
                assert!(p.delay_s > 0.0 && p.energy_j() > 0.0);
            }
        }
    }

    #[test]
    fn parallel_profiling_matches_serial() {
        let configs = production_accelerators().to_vec();
        let wls = [Workload::Rn50];
        let par = profile_configs(&configs, &wls);
        for (cfg, row) in configs.iter().zip(&par) {
            let serial = simulate(cfg, &network(Workload::Rn50));
            assert_eq!(row[0], serial, "{} parallel != serial", cfg.name);
        }
    }

    #[test]
    fn rows_preserve_embodied_total() {
        let configs = production_accelerators().to_vec();
        let wls = [Workload::Rn18];
        let profs = profile_configs(&configs, &wls);
        let rows = profiles_to_rows(&configs, &profs, FabGrid::Coal);
        for (cfg, row) in configs.iter().zip(&rows) {
            let total: f64 = row.c_comp.iter().sum();
            assert!(
                (total - cfg.embodied_g(FabGrid::Coal)).abs() < 1e-6,
                "{}: {} vs {}",
                cfg.name,
                total,
                cfg.embodied_g(FabGrid::Coal)
            );
            assert_eq!(row.d_k.len(), 1);
            assert!(row.leak_w > 0.0);
        }
    }
}
