//! Design-space enumeration and parametric search spaces.
//!
//! The paper's Fig 7 sweep is a fixed 11×11 MAC×SRAM grid (121 points).
//! [`SearchSpace`] generalizes it into a parametric axis product —
//! MAC count × SRAM size × (2-D | stacked-SRAM 3-D) × clock — that
//! [`super::search`] explores adaptively instead of exhaustively:
//! [`SearchSpace::fig7_grid`] reproduces the legacy grid exactly
//! (same labels, same [`AcceleratorConfig`]s, so results are
//! bit-comparable against the exhaustive sweep), while
//! [`SearchSpace::expanded_2d3d`] opens the ~10k-point 2-D/3-D space of
//! §5.6 that exhaustive enumeration can no longer afford.

use crate::accel::AcceleratorConfig;
use crate::testkit::Rng;

/// One grid point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Grid label ("K0512_M2.0", "3D_K2048_M8.0_F1.2").
    pub label: String,
    /// MAC count.
    pub num_macs: u32,
    /// SRAM bytes.
    pub sram_bytes: u64,
    /// The full configuration.
    pub config: AcceleratorConfig,
}

/// Index tuple into a [`SearchSpace`]: `[mac, sram, stacking, clock]`
/// positions along the four axes.
pub type SpaceIndex = [usize; 4];

/// Geometric axis: `count` points from `start`, each `2^(1/per_octave)`
/// apart (the paper's half-octave grid uses `per_octave = 2`).
fn octave_axis(start: f64, count: usize, per_octave: u32) -> Vec<f64> {
    let step = 2f64.powf(1.0 / per_octave as f64);
    let mut v = Vec::with_capacity(count);
    let mut x = start;
    for _ in 0..count {
        v.push(x);
        x *= step;
    }
    v
}

/// Half-octave MAC axis: 128 … 4096, 11 points.
pub fn mac_axis() -> Vec<u32> {
    octave_axis(128.0, 11, 2).into_iter().map(|x| x.round() as u32).collect()
}

/// Half-octave SRAM axis: 0.5 MB … 16 MB, 11 points.
pub fn sram_axis() -> Vec<u64> {
    octave_axis(0.5, 11, 2).into_iter().map(|x| (x * 1024.0 * 1024.0).round() as u64).collect()
}

/// Eighth-octave MAC axis: 128 … 4096, 41 points (expanded space).
pub fn mac_axis_fine() -> Vec<u32> {
    octave_axis(128.0, 41, 8).into_iter().map(|x| x.round() as u32).collect()
}

/// Quarter-octave SRAM axis: 0.5 MB … 16 MB, 21 points (expanded space).
pub fn sram_axis_fine() -> Vec<u64> {
    octave_axis(0.5, 21, 4).into_iter().map(|x| (x * 1024.0 * 1024.0).round() as u64).collect()
}

/// A parametric accelerator design space: the cross-product of a MAC
/// axis, an SRAM axis, a stacking axis (2-D baseline and/or stacked-SRAM
/// 3-D with the F2F interface) and a clock axis. Candidates are addressed
/// by [`SpaceIndex`] and materialized lazily through [`Self::point`] —
/// the adaptive search never builds the full cross-product.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// MAC-count axis.
    pub mac: Vec<u32>,
    /// SRAM-bytes axis.
    pub sram: Vec<u64>,
    /// Stacking axis (`false` = 2-D LPDDR, `true` = 3-D stacked SRAM).
    pub stacking: Vec<bool>,
    /// Clock axis, Hz.
    pub clock: Vec<f64>,
}

impl SearchSpace {
    /// The paper's Fig 7 grid as a search space: 11×11 MAC×SRAM, 2-D,
    /// 1 GHz. [`Self::enumerate`] reproduces [`design_grid`] exactly.
    pub fn fig7_grid() -> Self {
        SearchSpace {
            mac: mac_axis(),
            sram: sram_axis(),
            stacking: vec![false],
            clock: vec![1.0e9],
        }
    }

    /// The expanded 2-D/3-D space: 41 MAC × 21 SRAM × {2-D, 3-D} ×
    /// 6 clocks = 10 332 candidates — large enough that profiling every
    /// point is off the table, which is what [`super::search`] is for.
    pub fn expanded_2d3d() -> Self {
        SearchSpace {
            mac: mac_axis_fine(),
            sram: sram_axis_fine(),
            stacking: vec![false, true],
            clock: vec![0.6e9, 0.8e9, 1.0e9, 1.2e9, 1.4e9, 1.6e9],
        }
    }

    /// Axis lengths `[mac, sram, stacking, clock]`.
    pub fn dims(&self) -> [usize; 4] {
        [self.mac.len(), self.sram.len(), self.stacking.len(), self.clock.len()]
    }

    /// Total number of candidates in the cross-product.
    pub fn len(&self) -> usize {
        self.dims().iter().product()
    }

    /// True when any axis is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the candidate at an index tuple. 2-D points at the
    /// default 1 GHz clock keep the legacy grid label ("K0512_M2.0");
    /// non-default axes append their markers ("3D_" prefix, "_F1.2"
    /// clock suffix) so labels stay unique across the whole space.
    pub fn point(&self, idx: SpaceIndex) -> DesignPoint {
        let m = self.mac[idx[0]];
        let s = self.sram[idx[1]];
        let stacked = self.stacking[idx[2]];
        let f = self.clock[idx[3]];
        let mb = s as f64 / (1024.0 * 1024.0);
        let mut label = format!("K{m:04}_M{mb:.1}");
        if (f - 1.0e9).abs() > 1.0 {
            label = format!("{label}_F{:.1}", f / 1e9);
        }
        if stacked {
            label = format!("3D_{label}");
        }
        let mut config = if stacked {
            AcceleratorConfig::new_3d(&label, m, s)
        } else {
            AcceleratorConfig::new_2d(&label, m, s)
        };
        config.freq_hz = f;
        DesignPoint { label, num_macs: m, sram_bytes: s, config }
    }

    /// Draw a uniform index tuple (seeded sampling for search restarts).
    pub fn sample(&self, rng: &mut Rng) -> SpaceIndex {
        let d = self.dims();
        [rng.below(d[0]), rng.below(d[1]), rng.below(d[2]), rng.below(d[3])]
    }

    /// Enumerate every candidate, axis-major in `[mac ▸ sram ▸ stacking ▸
    /// clock]` order (the legacy MAC-major grid order).
    pub fn enumerate(&self) -> Vec<DesignPoint> {
        let mut out = Vec::with_capacity(self.len());
        for mi in 0..self.mac.len() {
            for si in 0..self.sram.len() {
                for bi in 0..self.stacking.len() {
                    for fi in 0..self.clock.len() {
                        out.push(self.point([mi, si, bi, fi]));
                    }
                }
            }
        }
        out
    }
}

/// The full 11×11 grid (121 candidate accelerators), MAC-major order —
/// the exhaustive Fig 7 space, now a [`SearchSpace::fig7_grid`] view.
pub fn design_grid() -> Vec<DesignPoint> {
    SearchSpace::fig7_grid().enumerate()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_121_points() {
        assert_eq!(design_grid().len(), 121);
        assert_eq!(mac_axis().len(), 11);
        assert_eq!(sram_axis().len(), 11);
    }

    #[test]
    fn axes_span_paper_ranges() {
        let m = mac_axis();
        assert_eq!(m[0], 128);
        assert!((4000..4200).contains(&m[10]), "mac max = {}", m[10]);
        let s = sram_axis();
        assert_eq!(s[0], 512 * 1024);
        assert!((s[10] as f64 / (1024.0 * 1024.0) - 16.0).abs() < 0.5);
    }

    #[test]
    fn labels_are_unique() {
        let grid = design_grid();
        let mut labels: Vec<&str> = grid.iter().map(|p| p.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 121);
    }

    #[test]
    fn grid_is_monotone_in_embodied() {
        // More silicon -> more embodied carbon along both axes.
        use crate::carbon::FabGrid;
        let grid = design_grid();
        let e = |i: usize| grid[i].config.embodied_g(FabGrid::Coal);
        // Same MACs, growing SRAM: indices 0..11.
        assert!(e(10) > e(0));
        // Same SRAM, growing MACs: stride 11.
        assert!(e(110) > e(0));
    }

    #[test]
    fn fig7_space_matches_legacy_grid() {
        // The SearchSpace view must reproduce the exhaustive grid
        // bit-for-bit: same labels, same configuration knobs.
        let space = SearchSpace::fig7_grid();
        assert_eq!(space.len(), 121);
        assert_eq!(space.dims(), [11, 11, 1, 1]);
        for (mi, &m) in space.mac.iter().enumerate() {
            for (si, &s) in space.sram.iter().enumerate() {
                let p = space.point([mi, si, 0, 0]);
                let mb = s as f64 / (1024.0 * 1024.0);
                assert_eq!(p.label, format!("K{m:04}_M{mb:.1}"));
                assert_eq!(p.config.num_macs, m);
                assert_eq!(p.config.sram_bytes, s);
                assert_eq!(p.config.freq_hz, 1.0e9);
                assert!(!p.config.stacked_sram);
            }
        }
    }

    #[test]
    fn expanded_space_shape_and_labels() {
        let space = SearchSpace::expanded_2d3d();
        assert_eq!(space.dims(), [41, 21, 2, 6]);
        assert_eq!(space.len(), 10_332);
        assert_eq!(space.mac[0], 128);
        assert_eq!(space.mac[40], 4096);
        assert_eq!(space.sram[20], 16 * 1024 * 1024);
        // Labels stay unique across the whole cross-product.
        let mut labels: Vec<String> = space.enumerate().into_iter().map(|p| p.label).collect();
        let n = labels.len();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), n);
    }

    #[test]
    fn stacked_points_use_f2f_interface() {
        let space = SearchSpace::expanded_2d3d();
        let flat = space.point([10, 10, 0, 2]);
        let stacked = space.point([10, 10, 1, 2]);
        assert!(!flat.config.stacked_sram);
        assert!(stacked.config.stacked_sram);
        assert!(stacked.label.starts_with("3D_"), "{}", stacked.label);
        assert!(stacked.config.mem.bandwidth() > flat.config.mem.bandwidth());
        assert_eq!(flat.num_macs, stacked.num_macs);
    }

    #[test]
    fn clock_axis_shows_in_label_and_config() {
        let space = SearchSpace::expanded_2d3d();
        let slow = space.point([0, 0, 0, 0]);
        assert_eq!(slow.config.freq_hz, 0.6e9);
        assert!(slow.label.ends_with("_F0.6"), "{}", slow.label);
        // 1 GHz keeps the legacy label (no suffix).
        let nominal = space.point([0, 0, 0, 2]);
        assert_eq!(nominal.config.freq_hz, 1.0e9);
        assert_eq!(nominal.label, "K0128_M0.5");
    }

    #[test]
    fn sampling_is_seeded_and_in_range() {
        let space = SearchSpace::expanded_2d3d();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..64 {
            let ia = space.sample(&mut a);
            assert_eq!(ia, space.sample(&mut b));
            for (x, d) in ia.iter().zip(space.dims()) {
                assert!(*x < d);
            }
        }
    }
}
