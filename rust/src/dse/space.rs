//! Design-space enumeration: the paper's 121-point MAC×SRAM grid.

use crate::accel::AcceleratorConfig;

/// One grid point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Grid label ("K0512_M2.0").
    pub label: String,
    /// MAC count.
    pub num_macs: u32,
    /// SRAM bytes.
    pub sram_bytes: u64,
    /// The full configuration.
    pub config: AcceleratorConfig,
}

/// Half-octave MAC axis: 128 … 4096, 11 points.
pub fn mac_axis() -> Vec<u32> {
    let mut v = Vec::with_capacity(11);
    let mut x = 128.0f64;
    for _ in 0..11 {
        v.push(x.round() as u32);
        x *= std::f64::consts::SQRT_2;
    }
    v
}

/// Half-octave SRAM axis: 0.5 MB … 16 MB, 11 points.
pub fn sram_axis() -> Vec<u64> {
    let mut v = Vec::with_capacity(11);
    let mut x = 0.5f64;
    for _ in 0..11 {
        v.push((x * 1024.0 * 1024.0).round() as u64);
        x *= std::f64::consts::SQRT_2;
    }
    v
}

/// The full 11×11 grid (121 candidate accelerators), MAC-major order.
pub fn design_grid() -> Vec<DesignPoint> {
    let mut out = Vec::with_capacity(121);
    for &m in &mac_axis() {
        for &s in &sram_axis() {
            let mb = s as f64 / (1024.0 * 1024.0);
            let label = format!("K{m:04}_M{mb:.1}");
            out.push(DesignPoint {
                label: label.clone(),
                num_macs: m,
                sram_bytes: s,
                config: AcceleratorConfig::new_2d(&label, m, s),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_121_points() {
        assert_eq!(design_grid().len(), 121);
        assert_eq!(mac_axis().len(), 11);
        assert_eq!(sram_axis().len(), 11);
    }

    #[test]
    fn axes_span_paper_ranges() {
        let m = mac_axis();
        assert_eq!(m[0], 128);
        assert!((4000..4200).contains(&m[10]), "mac max = {}", m[10]);
        let s = sram_axis();
        assert_eq!(s[0], 512 * 1024);
        assert!((s[10] as f64 / (1024.0 * 1024.0) - 16.0).abs() < 0.5);
    }

    #[test]
    fn labels_are_unique() {
        let grid = design_grid();
        let mut labels: Vec<&str> = grid.iter().map(|p| p.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 121);
    }

    #[test]
    fn grid_is_monotone_in_embodied() {
        // More silicon -> more embodied carbon along both axes.
        use crate::carbon::FabGrid;
        let grid = design_grid();
        let e = |i: usize| grid[i].config.embodied_g(FabGrid::Coal);
        // Same MACs, growing SRAM: indices 0..11.
        assert!(e(10) > e(0));
        // Same SRAM, growing MACs: stride 11.
        assert!(e(110) > e(0));
    }
}
