//! Parallel multi-scenario sweep coordinator — two-phase since PR 2.
//!
//! Evaluates one design space under every scenario of a [`ScenarioGrid`].
//! The scenario axes (`ci_use`, `lifetime`, `β`, `qos`, `p_max`) never
//! touch the O(C×T×K) engine contraction, so [`sweep`] splits the work:
//!
//! * **Phase A** — profile each config chunk **once** into a
//!   scenario-invariant [`DesignProfile`], fanning chunks across worker
//!   threads (engines are `!Send`, so each worker builds its own through
//!   an [`EngineFactory`]). Chunk boundaries are exactly the engine-call
//!   boundaries `evaluate_chunked` uses sequentially.
//! * **Phase B** — apply a cheap pure-Rust [`ScenarioOverlay`] per
//!   (scenario × chunk), merging chunk results scenario-major in chunk
//!   order.
//!
//! Engine work drops from O(N_scenarios × C × T × K) to
//! O(C × T × K + N_scenarios × C), yet on the host engine the output
//! stays **bit-identical** to both the sequential path
//! ([`sweep_sequential`]) and the PR 1 per-scenario fused fan-out (kept
//! as [`sweep_fused`] for benchmarking) — locked by
//! `rust/tests/coordinator_props.rs`. (PJRT composes within the existing
//! ≤ 1e-5 pjrt-vs-host envelope; see `runtime/pjrt.rs`.)

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::carbon::ScenarioOverlay;
use crate::matrixform::{DesignProfile, EvalRequest, EvalResult, MetricRow, PackedProblem};
use crate::runtime::{evaluate_fused, profile_request, CacheStats, Engine, EngineFactory};

use super::batching::{chunk_neutral, chunk_size, merge, num_chunks, shallow};
use super::cache::{CacheKey, ProfileCache};
use super::explore::{explore, summarize, ExploreOutcome};
use super::grid::ScenarioGrid;

/// Sweep execution knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepConfig {
    /// Worker threads; 0 (the default) = one per available CPU, capped by
    /// the number of engine work items. For the two-phase [`sweep`] the
    /// knob applies to phase A (profile chunks) — a space that fits one
    /// engine batch profiles on a single worker regardless, and phase B
    /// overlays are cheap enough to stay sequential.
    pub threads: usize,
}

/// One scenario's evaluated outcome.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// Scenario label from the grid.
    pub label: String,
    /// Full exploration outcome (per-config results, optima, stats).
    pub outcome: ExploreOutcome,
}

/// Aggregated sweep result, scenario order = grid enumeration order.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Per-scenario results.
    pub scenarios: Vec<ScenarioResult>,
    /// Engine label ("host", "pjrt").
    pub engine: &'static str,
    /// Worker threads actually used (phase A for the two-phase path).
    pub threads: usize,
    /// (scenario × config-chunk) overlay applications the sweep merged.
    pub items: usize,
    /// Config chunks the engine contracted (once for [`sweep`], once per
    /// scenario for [`sweep_fused`]).
    pub profile_chunks: usize,
    /// Per-run profile-cache delta when the sweep ran against a
    /// [`ProfileCache`] (`hits` = phase-A engine contractions avoided);
    /// `None` on uncached paths.
    pub cache: Option<CacheStats>,
}

impl SweepOutcome {
    /// Cross-scenario argmin: `(scenario index, config index, tCDP)` of
    /// the feasible design minimizing tCDP over the whole sweep.
    pub fn best(&self) -> Option<(usize, usize, f64)> {
        let mut best: Option<(usize, usize, f64)> = None;
        for (si, s) in self.scenarios.iter().enumerate() {
            if let Some(ci) = s.outcome.result.argmin_feasible(MetricRow::Tcdp) {
                let v = s.outcome.result.metric(MetricRow::Tcdp, ci);
                match best {
                    Some((_, _, bv)) if bv <= v => {}
                    _ => best = Some((si, ci, v)),
                }
            }
        }
        best
    }
}

/// Fan `items` across up to `threads` worker threads, one engine per
/// worker, shared atomic work queue; results return in item order.
fn fan_out<T, R, F>(
    factory: &dyn EngineFactory,
    items: &[T],
    threads: usize,
    f: F,
) -> crate::Result<(Vec<R>, usize)>
where
    T: Sync,
    R: Send,
    F: Fn(&mut dyn Engine, &T) -> crate::Result<R> + Sync,
{
    let n_items = items.len();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = if threads == 0 { hw } else { threads };
    let n_workers = threads.min(n_items).max(1);

    if n_workers == 1 {
        // Single-worker path: same items, same order, no thread overhead.
        let mut engine = factory.build()?;
        let mut out = Vec::with_capacity(n_items);
        for item in items {
            out.push(f(engine.as_mut(), item)?);
        }
        return Ok((out, 1));
    }

    let mut slots: Vec<Option<R>> = (0..n_items).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| -> crate::Result<()> {
        let mut handles = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let next = &next;
            let f = &f;
            handles.push(s.spawn(move || -> crate::Result<Vec<(usize, R)>> {
                let mut engine = factory.build()?;
                let mut done = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    done.push((i, f(engine.as_mut(), &items[i])?));
                }
                Ok(done)
            }));
        }
        for h in handles {
            for (i, res) in h.join().expect("sweep worker panicked")? {
                slots[i] = Some(res);
            }
        }
        Ok(())
    })?;
    let out = slots.into_iter().map(|s| s.expect("work item left unevaluated")).collect();
    Ok((out, n_workers))
}

/// Run the two-phase sweep: profile config chunks once in parallel
/// (phase A), then fold a cheap scenario overlay over the cached profiles
/// for every grid scenario (phase B), merging deterministically.
pub fn sweep(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
) -> crate::Result<SweepOutcome> {
    sweep_with_cache(factory, base, grid, cfg, None)
}

/// One phase-A work unit that missed the cache: the chunk's slot in the
/// profile list, its packed batch and its content key.
struct MissItem {
    slot: usize,
    packed: PackedProblem,
    key: CacheKey,
}

/// [`sweep`] with an optional persistent [`ProfileCache`] in front of
/// phase A: each chunk is looked up by content key first; only misses
/// reach the engine (fanned across workers exactly like the uncached
/// path) and are written back. Cached profiles are bit-exact copies of
/// what the engine would produce, so with or without the cache — and
/// cold or warm — the outcome is bit-identical on the host engine
/// (locked by `rust/tests/cache_props.rs`). The outcome's `cache` field
/// carries this run's hit/miss delta.
pub fn sweep_with_cache(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
    cache: Option<&ProfileCache>,
) -> crate::Result<SweepOutcome> {
    let scenarios = grid.scenarios();
    let n_scenarios = scenarios.len();

    // Phase A — the only part that touches the engine hot loop (one
    // config clone per chunk, same as the fused item builder).
    let chunk_reqs = chunk_neutral(&base.tasks, &base.configs);
    let (profiles, threads_used, cache_delta): (Vec<DesignProfile>, usize, Option<CacheStats>) =
        match cache {
            None => {
                let (profiles, threads) =
                    fan_out(factory, &chunk_reqs, cfg.threads, profile_request)?;
                (profiles, threads, None)
            }
            Some(cache) => {
                let engine_label = factory.label();
                let before = cache.stats();
                let mut slots: Vec<Option<DesignProfile>> =
                    (0..chunk_reqs.len()).map(|_| None).collect();
                let mut misses: Vec<MissItem> = Vec::new();
                for (slot, req) in chunk_reqs.iter().enumerate() {
                    let packed = PackedProblem::from_request(req);
                    let key = ProfileCache::key_for_packed(&packed, engine_label);
                    match cache.load(&key, engine_label) {
                        Some(profile) => slots[slot] = Some(profile),
                        None => misses.push(MissItem { slot, packed, key }),
                    }
                }
                // Only the misses touch the engine; a fully warm cache
                // performs zero phase-A contractions.
                let (computed, threads) = if misses.is_empty() {
                    (Vec::new(), 1)
                } else {
                    fan_out(factory, &misses, cfg.threads, |engine, item: &MissItem| {
                        let raw = engine.profile(&item.packed)?;
                        Ok(DesignProfile::from_parts(
                            &item.packed,
                            raw.energy,
                            raw.delay,
                            raw.d_task,
                        ))
                    })?
                };
                for (item, profile) in misses.iter().zip(computed) {
                    // A failed write-back (disk full, permissions) must
                    // not abort a sweep whose engine work succeeded —
                    // the profile is used anyway and the failure shows
                    // up as `write_errors` on the stats surface.
                    let _ = cache.store(&item.key, &profile, engine_label);
                    slots[item.slot] = Some(profile);
                }
                let profiles =
                    slots.into_iter().map(|s| s.expect("chunk left unprofiled")).collect();
                (profiles, threads, Some(cache.stats().since(&before)))
            }
        };

    // Phase B — (scenario × chunk) overlays in the same scenario-major,
    // chunk-ascending order the fused paths merge, so results are
    // bit-identical to them.
    let shell = shallow(base);
    let results: Vec<ScenarioResult> = scenarios
        .into_iter()
        .map(|sc| {
            let overlay = ScenarioOverlay::from_request(&sc.apply(&shell));
            let mut merged: Option<EvalResult> = None;
            for prof in &profiles {
                let res = overlay.apply(prof);
                merged = Some(match merged {
                    None => res,
                    Some(acc) => merge(acc, res),
                });
            }
            ScenarioResult {
                label: sc.label,
                // An empty design space profiles into zero chunks; each
                // scenario then reports the empty outcome.
                outcome: summarize(
                    merged.unwrap_or_else(|| EvalResult::empty(base.tasks.num_tasks())),
                ),
            }
        })
        .collect();

    Ok(SweepOutcome {
        scenarios: results,
        engine: factory.label(),
        threads: threads_used,
        items: profiles.len() * n_scenarios,
        profile_chunks: profiles.len(),
        cache: cache_delta,
    })
}

/// One fanned-out unit of fused work: a config chunk under one scenario.
struct SweepItem {
    scenario: usize,
    req: EvalRequest,
}

/// Build the (scenario × config-chunk) item list for the fused path.
/// Chunk boundaries are exactly the ones `evaluate_chunked` would use
/// sequentially — one engine call per item — so merging item results in
/// order reproduces the sequential result bit-for-bit (a remainder chunk
/// must run as one padded batch here, not be re-chunked, or the PJRT path
/// would route it through a different artifact variant than the
/// sequential run).
fn build_items(
    base: &EvalRequest,
    grid: &ScenarioGrid,
) -> (Vec<SweepItem>, Vec<super::grid::SweepScenario>) {
    let scenarios = grid.scenarios();
    let mut items = Vec::new();
    for (si, sc) in scenarios.iter().enumerate() {
        let req = sc.apply(base);
        if req.configs.is_empty() {
            // No configs, no engine items; the merge below falls back to
            // the empty result for every scenario.
            continue;
        }
        let cs = chunk_size(req.configs.len());
        if req.configs.len() <= cs {
            items.push(SweepItem { scenario: si, req });
        } else {
            for chunk in req.configs.chunks(cs) {
                items.push(SweepItem {
                    scenario: si,
                    req: EvalRequest { configs: chunk.to_vec(), ..shallow(&req) },
                });
            }
        }
    }
    (items, scenarios)
}

/// The PR 1 per-scenario fused fan-out: every (scenario × config-chunk)
/// item re-runs the engine with the scenario folded into the graph.
/// Engine work is O(N_scenarios × C × T × K); kept as the baseline the
/// two-phase [`sweep`] is benchmarked against
/// (`benches/bench_sweep_parallel.rs`) and as a second bit-identity
/// oracle in the property tests.
pub fn sweep_fused(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
) -> crate::Result<SweepOutcome> {
    let (items, scenarios) = build_items(base, grid);
    let n_scenarios = scenarios.len();
    let n_items = items.len();
    let (slots, threads_used) = fan_out(factory, &items, cfg.threads, |engine, item| {
        evaluate_fused(engine, &item.req)
    })?;

    // Order-preserving merge: items were emitted scenario-major in chunk
    // order, so folding each scenario's slots left-to-right reproduces the
    // sequential `evaluate_chunked` merge exactly.
    let mut merged: Vec<Option<EvalResult>> = (0..n_scenarios).map(|_| None).collect();
    for (item, res) in items.iter().zip(slots) {
        let slot = &mut merged[item.scenario];
        *slot = Some(match slot.take() {
            None => res,
            Some(acc) => merge(acc, res),
        });
    }

    let scenarios = scenarios
        .into_iter()
        .zip(merged)
        .map(|(sc, res)| ScenarioResult {
            label: sc.label,
            outcome: summarize(
                res.unwrap_or_else(|| EvalResult::empty(base.tasks.num_tasks())),
            ),
        })
        .collect();

    Ok(SweepOutcome {
        scenarios,
        engine: factory.label(),
        threads: threads_used,
        items: n_items,
        profile_chunks: num_chunks(base.configs.len()),
        cache: None,
    })
}

/// Sequential reference path: one engine, scenarios in grid order. The
/// parallel [`sweep`] and [`sweep_fused`] must match this bit-for-bit.
pub fn sweep_sequential(
    engine: &mut dyn Engine,
    base: &EvalRequest,
    grid: &ScenarioGrid,
) -> crate::Result<SweepOutcome> {
    let scenarios = grid.scenarios();
    let n = scenarios.len();
    let mut out = Vec::with_capacity(n);
    for sc in scenarios {
        let req = sc.apply(base);
        out.push(ScenarioResult { label: sc.label, outcome: explore(engine, &req)? });
    }
    Ok(SweepOutcome {
        scenarios: out,
        engine: engine.name(),
        threads: 1,
        items: n,
        profile_chunks: num_chunks(base.configs.len()),
        cache: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, TaskMatrix};
    use crate::runtime::{HostEngine, HostEngineFactory};

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k".into()], &[3.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![(i + 1) as f64 * 1e-3],
                    e_dyn: vec![0.01 + i as f64 * 1e-4],
                    leak_w: 0.01,
                    c_comp: vec![100.0 + i as f64],
                })
                .collect(),
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    fn grid() -> ScenarioGrid {
        ScenarioGrid::new()
            .with_lifetime("short", 1e5)
            .with_lifetime("long", 1e7)
            .with_beta("b=0.5", 0.5)
            .with_beta("b=2", 2.0)
    }

    fn assert_outcomes_identical(a: &SweepOutcome, b: &SweepOutcome) {
        assert_eq!(a.scenarios.len(), b.scenarios.len());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.outcome.result.names, y.outcome.result.names);
            // Bit-identical, not approximately equal.
            assert_eq!(x.outcome.result.metrics, y.outcome.result.metrics);
            assert_eq!(x.outcome.result.d_task, y.outcome.result.d_task);
            assert_eq!(x.outcome.optimal, y.outcome.optimal);
            assert_eq!(x.outcome.stats.best.to_bits(), y.outcome.stats.best.to_bits());
            assert_eq!(x.outcome.stats.mean.to_bits(), y.outcome.stats.mean.to_bits());
            assert_eq!(x.outcome.stats.feasible, y.outcome.stats.feasible);
        }
    }

    #[test]
    fn parallel_matches_sequential_small_space() {
        let req = request(9);
        let par = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 }).unwrap();
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid()).unwrap();
        assert_eq!(par.scenarios.len(), 4);
        assert_eq!(par.profile_chunks, 1);
        assert_outcomes_identical(&par, &seq);
    }

    #[test]
    fn parallel_matches_sequential_chunked_space() {
        // 2500 configs -> 3 profile chunks, 4 scenarios -> 12 overlay
        // applications (but only 3 engine calls on the two-phase path).
        let req = request(2500);
        let par = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 }).unwrap();
        assert_eq!(par.items, 12);
        assert_eq!(par.profile_chunks, 3);
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid()).unwrap();
        assert_outcomes_identical(&par, &seq);
    }

    #[test]
    fn two_phase_matches_fused_fan_out() {
        // The tentpole invariant at the coordinator level: caching the
        // profile and overlaying scenarios equals re-running the engine
        // per scenario, bit-for-bit.
        for c in [9usize, 400] {
            let req = request(c);
            let two = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 }).unwrap();
            let fused =
                sweep_fused(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 })
                    .unwrap();
            assert_eq!(two.items, fused.items, "c={c}");
            assert_outcomes_identical(&two, &fused);
        }
    }

    #[test]
    fn warm_cached_sweep_is_bit_identical_with_zero_contractions() {
        let dir = crate::testkit::test_dir("sweep_cache_warm");
        std::fs::remove_dir_all(&dir).ok();
        let cache = crate::dse::cache::ProfileCache::open(&dir).unwrap();
        let req = request(2500); // 3 profile chunks
        let cfg = SweepConfig { threads: 2 };

        let plain = sweep(&HostEngineFactory, &req, &grid(), &cfg).unwrap();
        let cold = sweep_with_cache(&HostEngineFactory, &req, &grid(), &cfg, Some(&cache)).unwrap();
        let warm = sweep_with_cache(&HostEngineFactory, &req, &grid(), &cfg, Some(&cache)).unwrap();
        assert_outcomes_identical(&plain, &cold);
        assert_outcomes_identical(&cold, &warm);

        let cs = cold.cache.expect("cold run reports cache stats");
        assert_eq!((cs.hits, cs.misses, cs.writes), (0, 3, 3));
        let ws = warm.cache.expect("warm run reports cache stats");
        assert_eq!((ws.hits, ws.misses, ws.writes), (3, 0, 0));
        assert_eq!(ws.contractions_avoided(), warm.profile_chunks);
        assert!(plain.cache.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_design_space_sweeps_to_empty_scenarios() {
        // Regression: zero configs used to panic inside packing; now
        // every path reports empty per-scenario outcomes.
        let req = request(0);
        let par = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        assert_eq!(par.scenarios.len(), 4);
        assert_eq!(par.profile_chunks, 0);
        assert_eq!(par.items, 0);
        assert!(par.best().is_none());
        for s in &par.scenarios {
            assert_eq!(s.outcome.result.c, 0);
            assert_eq!(s.outcome.stats.feasible, 0);
        }
        let fused =
            sweep_fused(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid()).unwrap();
        assert_outcomes_identical(&par, &fused);
        assert_outcomes_identical(&par, &seq);
    }

    #[test]
    fn single_thread_config_uses_one_worker() {
        let req = request(5);
        let out = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 1 }).unwrap();
        assert_eq!(out.threads, 1);
        assert_eq!(out.engine, "host");
        assert_eq!(out.scenarios.len(), 4);
    }

    #[test]
    fn scenario_order_matches_grid_enumeration() {
        let req = request(3);
        let out = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        let labels: Vec<&str> = out.scenarios.iter().map(|s| s.label.as_str()).collect();
        let expect: Vec<String> = grid().scenarios().into_iter().map(|s| s.label).collect();
        assert_eq!(labels, expect.iter().map(String::as_str).collect::<Vec<_>>());
    }

    #[test]
    fn best_is_global_argmin_across_scenarios() {
        let req = request(7);
        let out = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        let (si, ci, v) = out.best().expect("feasible design exists");
        for s in &out.scenarios {
            for i in 0..s.outcome.result.c {
                if s.outcome.result.metric(MetricRow::Feasible, i) > 0.5 {
                    assert!(s.outcome.result.metric(MetricRow::Tcdp, i) >= v);
                }
            }
        }
        assert!(out.scenarios[si].outcome.result.metric(MetricRow::Tcdp, ci) == v);
    }

    #[test]
    fn longer_lifetime_lowers_amortized_embodied() {
        // Scenario semantics flow through the sweep: the long-lifetime
        // scenario must report lower tCDP than the short one (same space).
        let req = request(4);
        let g = ScenarioGrid::new().with_lifetime("short", 1e5).with_lifetime("long", 1e7);
        let out = sweep(&HostEngineFactory, &req, &g, &SweepConfig::default()).unwrap();
        assert!(out.scenarios[0].outcome.stats.best > out.scenarios[1].outcome.stats.best);
    }
}
