//! Parallel multi-scenario sweep coordinator — two-phase since PR 2,
//! chunk-resumable since PR 5.
//!
//! Evaluates one design space under every scenario of a [`ScenarioGrid`].
//! The scenario axes (`ci_use`, `lifetime`, `β`, `qos`, `p_max`) never
//! touch the O(C×T×K) engine contraction, so [`sweep`] splits the work:
//!
//! * **Phase A** — profile each config chunk **once** into a
//!   scenario-invariant [`DesignProfile`], fanning chunks across worker
//!   threads (engines are `!Send`, so each worker builds its own through
//!   an [`EngineFactory`]; factories that opt into pooling via
//!   `EngineFactory::shared` run on a persistent
//!   [`WorkerPool`](crate::runtime::WorkerPool) that keeps workers and
//!   their engines alive across chunks, sweeps and search generations).
//!   Chunk boundaries are exactly the engine-call boundaries
//!   `evaluate_chunked` uses sequentially.
//! * **Phase B** — apply cheap pure-Rust [`ScenarioOverlay`]s, batched
//!   per profile chunk ([`ScenarioOverlay::apply_batch`] folds every
//!   lowered scenario of the grid over a chunk in one pass), merging
//!   chunk results scenario-major in chunk order.
//!
//! Engine work drops from O(N_scenarios × C × T × K) to
//! O(C × T × K + N_scenarios × C), yet on the host engine the output
//! stays **bit-identical** to both the sequential path
//! ([`sweep_sequential`]) and the PR 1 per-scenario fused fan-out (kept
//! as [`sweep_fused`] for benchmarking) — locked by
//! `rust/tests/coordinator_props.rs`. (PJRT composes within the existing
//! ≤ 1e-5 pjrt-vs-host envelope; see `runtime/pjrt.rs`.)
//!
//! Phase A is an explicit state machine ([`SweepDriver`]): chunks are
//! keyed by their [`ConfigRow`]-level content hash (no packing on the
//! coordinator — misses pack *inside* the workers), looked up in the
//! [`ProfileCache`] when one is in play, and processed in batched
//! [`SweepDriver::step`]s. Between any two steps the driver snapshots
//! into a [`SweepCheckpoint`] — per-chunk progress plus a fingerprint of
//! the whole problem (chunk keys, scenario grid, base scenario knobs,
//! engine) — and [`sweep_resumable`] persists one per step, so a sweep
//! over a giant space interrupted at any chunk resumes bit-identically:
//! completed chunks come back from the cache, only the remainder is
//! contracted, and a checkpoint from a *different* problem (another
//! cluster, grid or engine) is rejected, never silently blended.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use crate::carbon::{combine_segments, OverlayScratch, ScenarioOverlay};
use crate::configfmt::{parse, ContentHasher, Json};
use crate::matrixform::{
    ConfigRow, DesignProfile, EvalRequest, EvalResult, MetricRow, ProfileRequest, TaskMatrix,
};
use crate::runtime::{evaluate_fused, profile_request, CacheStats, Engine, EngineFactory};

use super::batching::{chunk_ranges, chunk_size, evaluate_chunked, merge, num_chunks, shallow};
use super::cache::{atomic_write, splice_digest, strip_and_verify_digest, CacheKey, ProfileCache};
use super::coalesce::{Admission, Coalescer, LeadGuard, Waiter};
use super::explore::{explore, summarize, ExploreOutcome};
use super::grid::ScenarioGrid;
use super::search::grid_digest;

/// Sweep execution knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepConfig {
    /// Worker threads; 0 (the default) = one per available CPU, capped by
    /// the number of engine work items. For the two-phase [`sweep`] the
    /// knob applies to phase A (profile chunks) — a space that fits one
    /// engine batch profiles on a single worker regardless, and phase B
    /// overlays are cheap enough to stay sequential.
    pub threads: usize,
}

/// One scenario's evaluated outcome.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// Scenario label from the grid.
    pub label: String,
    /// Full exploration outcome (per-config results, optima, stats).
    /// For a trace scenario this is the time-weighted combination of
    /// the per-segment evaluations (`carbon::combine_segments`).
    pub outcome: ExploreOutcome,
    /// Trace metadata when the scenario carried a CI trace. Filled by
    /// the two-phase driver (the production path — the static collapse
    /// costs one extra overlay fold); the fused/sequential oracle paths
    /// leave it `None`, and bit-identity comparisons ignore it.
    pub trace: Option<TraceMeta>,
}

/// Summary of one trace scenario: the trace's intensity profile plus
/// the outcome of its *static collapse* (the same scenario at the
/// trace's time-weighted mean CI), so reports can show the
/// trace-vs-static delta. By linearity of `C_op` in `CI_use` the delta
/// is f32-rounding-sized; the interesting signal is the swing *across*
/// grids (see EXPERIMENTS.md §Trace).
#[derive(Debug, Clone, Copy)]
pub struct TraceMeta {
    /// Number of trace segments the scenario lowered into.
    pub segments: usize,
    /// Time-weighted mean intensity, g/kWh.
    pub mean_ci_g_per_kwh: f64,
    /// Lowest segment intensity, g/kWh.
    pub min_ci_g_per_kwh: f64,
    /// Highest segment intensity, g/kWh.
    pub max_ci_g_per_kwh: f64,
    /// Best feasible tCDP of the static mean-CI collapse.
    pub static_best_tcdp: f64,
    /// Feasible-design count of the static collapse.
    pub static_feasible: usize,
}

/// Aggregated sweep result, scenario order = grid enumeration order.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Per-scenario results.
    pub scenarios: Vec<ScenarioResult>,
    /// Engine label ("host", "pjrt").
    pub engine: &'static str,
    /// Worker threads actually used (phase A for the two-phase path).
    pub threads: usize,
    /// (scenario × config-chunk) overlay applications the sweep merged.
    pub items: usize,
    /// Config chunks the engine contracted (once for [`sweep`], once per
    /// scenario for [`sweep_fused`]).
    pub profile_chunks: usize,
    /// Per-run profile-cache delta when the sweep ran against a
    /// [`ProfileCache`] (`hits` = phase-A engine contractions avoided);
    /// `None` on uncached paths.
    pub cache: Option<CacheStats>,
}

impl SweepOutcome {
    /// Cross-scenario argmin: `(scenario index, config index, tCDP)` of
    /// the feasible design minimizing tCDP over the whole sweep.
    pub fn best(&self) -> Option<(usize, usize, f64)> {
        let mut best: Option<(usize, usize, f64)> = None;
        for (si, s) in self.scenarios.iter().enumerate() {
            if let Some(ci) = s.outcome.result.argmin_feasible(MetricRow::Tcdp) {
                let v = s.outcome.result.metric(MetricRow::Tcdp, ci);
                match best {
                    Some((_, _, bv)) if bv <= v => {}
                    _ => best = Some((si, ci, v)),
                }
            }
        }
        best
    }
}

/// Fan owned `items` across worker engines; results return in item
/// order. Dispatches to the calling thread's persistent
/// [`WorkerPool`](crate::runtime::WorkerPool) when the factory opts in
/// (`EngineFactory::shared`) and falls back to per-call scoped spawning
/// otherwise. Both schedulers share one contract: order-preserving
/// merge, fail-fast on the first error (workers check a shared abort
/// flag before claiming each item instead of draining the queue), and
/// deterministic lowest-item-index error selection — so for a
/// deterministic engine the results, and the reported error, are
/// independent of thread count and scheduler.
fn fan_out<T, R, F>(
    factory: &dyn EngineFactory,
    items: Vec<T>,
    threads: usize,
    f: F,
) -> crate::Result<(Vec<R>, usize)>
where
    T: Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&mut dyn Engine, &T) -> crate::Result<R> + Send + Sync + 'static,
{
    let n_items = items.len();
    if n_items == 0 {
        return Ok((Vec::new(), 1));
    }
    let threads = resolve_threads(threads);
    if let Some(pool) = crate::runtime::shared_pool(factory, threads) {
        // Persistent scheduler: even a single-item batch goes through
        // the pool so its long-lived engines are reused instead of a
        // fresh one being built per call.
        return pool.fan_out(items, f);
    }
    let n_workers = threads.min(n_items).max(1);
    if n_workers == 1 {
        // Single-worker path: same items, same order, no thread overhead.
        let mut engine = factory.build()?;
        let mut out = Vec::with_capacity(n_items);
        for item in &items {
            out.push(f(engine.as_mut(), item)?);
        }
        return Ok((out, 1));
    }
    scoped_fan_out(factory, &items, n_workers, &f)
}

/// Per-call scoped-spawn scheduler — the fallback for factories that do
/// not opt into pooling: one engine per spawned worker, shared atomic
/// work queue, shared abort flag for fail-fast.
fn scoped_fan_out<T, R, F>(
    factory: &dyn EngineFactory,
    items: &[T],
    n_workers: usize,
    f: &F,
) -> crate::Result<(Vec<R>, usize)>
where
    T: Sync,
    R: Send,
    F: Fn(&mut dyn Engine, &T) -> crate::Result<R> + Sync,
{
    let n_items = items.len();
    let mut slots: Vec<Option<R>> = (0..n_items).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let mut first_err: Option<(usize, anyhow::Error)> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (next, abort) = (&next, &abort);
            handles.push(s.spawn(move || -> Vec<(usize, crate::Result<R>)> {
                let mut done = Vec::new();
                let mut engine = match factory.build() {
                    Ok(e) => e,
                    Err(e) => {
                        // Attribute the build failure to the next
                        // unclaimed item so nobody evaluates it and the
                        // error surfaces at a definite index.
                        abort.store(true, Ordering::Relaxed);
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i < n_items {
                            done.push((i, Err(e)));
                        }
                        return done;
                    }
                };
                loop {
                    if abort.load(Ordering::Relaxed) {
                        break; // fail-fast: a sibling already failed
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_items {
                        break;
                    }
                    let res = f(engine.as_mut(), &items[i]);
                    let failed = res.is_err();
                    if failed {
                        abort.store(true, Ordering::Relaxed);
                    }
                    done.push((i, res));
                    if failed {
                        break;
                    }
                }
                done
            }));
        }
        for h in handles {
            let results = match h.join() {
                Ok(r) => r,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            for (i, res) in results {
                match res {
                    Ok(r) => slots[i] = Some(r),
                    Err(e) => {
                        if first_err.as_ref().is_none_or(|(j, _)| i < *j) {
                            first_err = Some((i, e));
                        }
                    }
                }
            }
        }
    });
    if let Some((_, e)) = first_err {
        return Err(e);
    }
    let out = slots.into_iter().map(|s| s.expect("work item left unevaluated")).collect();
    Ok((out, n_workers))
}

/// `0 = auto` thread resolution shared by the fan-out and the driver's
/// step batching.
fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    }
}

/// Run the two-phase sweep: profile config chunks once in parallel
/// (phase A), then fold a cheap scenario overlay over the cached profiles
/// for every grid scenario (phase B), merging deterministically.
pub fn sweep(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
) -> crate::Result<SweepOutcome> {
    sweep_with_cache(factory, base, grid, cfg, None)
}

/// [`sweep`] with an optional persistent [`ProfileCache`] in front of
/// phase A: each chunk is looked up by content key first; only misses
/// reach the engine (fanned across workers exactly like the uncached
/// path, which is also where they are packed and written back). Cached
/// profiles are bit-exact copies of what the engine would produce, so
/// with or without the cache — and cold or warm — the outcome is
/// bit-identical on the host engine (locked by
/// `rust/tests/cache_props.rs`). The outcome's `cache` field carries
/// this run's hit/miss delta.
pub fn sweep_with_cache(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
    cache: Option<&ProfileCache>,
) -> crate::Result<SweepOutcome> {
    SweepDriver::new(factory, base, grid, cfg).run(factory, cache, None)
}

/// [`sweep_with_cache`] with checkpoint/resume plumbing for the *sweep
/// phase itself* (the search loop has its own checkpoints): start from
/// `resume_from` when given (validated against this problem's
/// fingerprint), and persist a [`SweepCheckpoint`] to `save_to` after
/// every step. Per-chunk profile payloads persist in `cache` (which is
/// why a cache is mandatory here), so an interrupted run resumes by
/// re-reading completed chunks from disk and contracting only the rest —
/// bit-identical to an uninterrupted run.
pub fn sweep_resumable(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
    cache: &ProfileCache,
    resume_from: Option<&SweepCheckpoint>,
    save_to: Option<&Path>,
) -> crate::Result<SweepOutcome> {
    let driver = match resume_from {
        Some(ck) => SweepDriver::resume(factory, base, grid, cfg, ck)?,
        None => SweepDriver::new(factory, base, grid, cfg),
    };
    driver.run(factory, Some(cache), save_to)
}

/// Checkpoint envelope schema version — bump on any layout *or*
/// fingerprint-semantics change so stale checkpoints are rejected
/// instead of silently resumed into a different problem. v2: the grid
/// digest hashes the trace axis (every scenario now contributes a trace
/// marker, changing all fingerprints).
pub const SWEEP_CHECKPOINT_SCHEMA: u32 = 2;

/// A snapshot of phase-A progress inside one sweep: how many chunks are
/// done plus a fingerprint binding the checkpoint to its exact problem —
/// the per-chunk content keys (design space at `ConfigRow` resolution),
/// the scenario grid digest, the base request's scenario knobs and the
/// engine label. Profile *payloads* are not in the envelope; they live
/// in the [`ProfileCache`], which is what makes the checkpoint O(1) in
/// space size. A resumed sweep whose cache lost entries (eviction)
/// recomputes them — still bit-identical, just slower.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepCheckpoint {
    /// Envelope schema ([`SWEEP_CHECKPOINT_SCHEMA`]).
    pub schema: u32,
    /// Content fingerprint of the whole problem (see
    /// [`sweep_fingerprint`]). Resuming under any other problem —
    /// another workload cluster with a coincidentally identical grid,
    /// another grid, another engine — is an error.
    pub fingerprint: String,
    /// Engine label echo (already inside the fingerprint; kept readable
    /// for humans and error messages).
    pub engine: String,
    /// Chunks completed (prefix of the chunk order).
    pub chunks_done: usize,
    /// Total chunks of the space.
    pub total_chunks: usize,
}

impl SweepCheckpoint {
    /// Render the versioned envelope (digest spliced in, rendered once).
    pub fn to_json_string(&self) -> String {
        let body = Json::obj(vec![
            ("schema", Json::Num(self.schema as f64)),
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("engine", Json::Str(self.engine.clone())),
            ("chunks_done", Json::Num(self.chunks_done as f64)),
            ("total_chunks", Json::Num(self.total_chunks as f64)),
        ])
        .to_string();
        splice_digest(&body)
    }

    /// Parse and validate an envelope (integrity digest first, then
    /// schema and fields). Any defect is a typed error, never a partial
    /// checkpoint.
    pub fn from_json_str(text: &str) -> crate::Result<SweepCheckpoint> {
        let mut doc = parse(text).map_err(|e| anyhow::anyhow!("sweep checkpoint: {e}"))?;
        strip_and_verify_digest(&mut doc, "sweep checkpoint")?;
        let bad = |f: &str| anyhow::anyhow!("sweep checkpoint: missing or invalid field `{f}`");
        let schema = doc
            .get("schema")
            .and_then(Json::as_usize)
            .and_then(|s| u32::try_from(s).ok())
            .ok_or_else(|| bad("schema"))?;
        if schema != SWEEP_CHECKPOINT_SCHEMA {
            anyhow::bail!(
                "sweep checkpoint: schema {schema} != supported {SWEEP_CHECKPOINT_SCHEMA} — \
                 re-run the sweep from scratch"
            );
        }
        let fingerprint =
            doc.get("fingerprint").and_then(Json::as_str).ok_or_else(|| bad("fingerprint"))?;
        let engine = doc.get("engine").and_then(Json::as_str).ok_or_else(|| bad("engine"))?;
        let chunks_done =
            doc.get("chunks_done").and_then(Json::as_usize).ok_or_else(|| bad("chunks_done"))?;
        let total_chunks =
            doc.get("total_chunks").and_then(Json::as_usize).ok_or_else(|| bad("total_chunks"))?;
        if chunks_done > total_chunks {
            return Err(bad("chunks_done"));
        }
        Ok(SweepCheckpoint {
            schema,
            fingerprint: fingerprint.to_string(),
            engine: engine.to_string(),
            chunks_done,
            total_chunks,
        })
    }
}

/// Write a sweep checkpoint (temp file + rename).
pub fn write_sweep_checkpoint(path: impl AsRef<Path>, ck: &SweepCheckpoint) -> crate::Result<()> {
    atomic_write(path.as_ref(), &ck.to_json_string())
}

/// Read a sweep checkpoint back from disk.
pub fn read_sweep_checkpoint(path: impl AsRef<Path>) -> crate::Result<SweepCheckpoint> {
    let text = std::fs::read_to_string(path.as_ref())?;
    SweepCheckpoint::from_json_str(&text)
}

/// Content fingerprint of one sweep problem: chunk content keys (tasks +
/// configs + engine + schema, via [`ProfileCache::key_for_chunk`]), the
/// scenario-grid digest, and the base request's scenario knobs (which
/// phase B folds in but the chunk keys deliberately exclude). Two
/// workload clusters sharing a scenario grid fingerprint differently
/// because their profiled rows differ — the checkpoint-fingerprint gap
/// the search loop closes with its evaluator probe is closed here by
/// construction.
pub fn sweep_fingerprint(
    base: &EvalRequest,
    grid: &ScenarioGrid,
    engine: &str,
    keys: &[CacheKey],
) -> String {
    let mut h = ContentHasher::new();
    h.write(b"xrcarbon-sweep");
    h.write_u64(SWEEP_CHECKPOINT_SCHEMA as u64);
    h.write_str(engine);
    h.write_str(&grid_digest(grid));
    for v in [base.ci_use_g_per_j, base.lifetime_s, base.beta, base.p_max_w] {
        h.write_u64(v.to_bits());
    }
    h.write_f64s(&base.online);
    h.write_f64s(&base.qos);
    h.write_u64(keys.len() as u64);
    for k in keys {
        h.write_str(&k.hex());
    }
    h.finish_hex()
}

/// Neutral chunk request over a borrowed slice of the space, one chunk
/// at a time — the coordinator builds one per cache miss as the owned
/// work item the (possibly pooled) workers receive; only the missed
/// chunks are ever cloned, never the whole space.
fn neutral_chunk(tasks: &TaskMatrix, configs: &[ConfigRow]) -> EvalRequest {
    ProfileRequest { tasks: tasks.clone(), configs: Vec::new() }.chunk_eval(configs.to_vec())
}

/// Phase A of one sweep as an explicit state machine: construct with
/// [`SweepDriver::new`] (or [`SweepDriver::resume`]), advance one
/// batched step at a time with [`SweepDriver::step`], snapshot between
/// steps with [`SweepDriver::checkpoint`], and build the
/// [`SweepOutcome`] (phase B overlays) with [`SweepDriver::outcome`]
/// once done. The one-shot entry points ([`sweep`], [`sweep_with_cache`],
/// [`sweep_resumable`]) drive it to completion.
pub struct SweepDriver<'a> {
    base: &'a EvalRequest,
    grid: &'a ScenarioGrid,
    cfg: SweepConfig,
    engine: &'static str,
    /// Chunk boundaries (index ranges into `base.configs`).
    ranges: Vec<std::ops::Range<usize>>,
    /// Per-chunk content keys — computed lazily (only cache lookups and
    /// checkpoints need them; a plain uncached sweep never hashes the
    /// design space at all). No packing either way.
    keys: std::cell::OnceCell<Vec<CacheKey>>,
    /// Problem fingerprint — lazy for the same reason (checkpoint /
    /// resume only).
    fingerprint: std::cell::OnceCell<String>,
    profiles: Vec<Option<DesignProfile>>,
    cursor: usize,
    threads_used: usize,
}

impl<'a> SweepDriver<'a> {
    /// Fresh driver over one problem. Chunk boundaries are computed
    /// here; content keys and the fingerprint are derived on first use.
    pub fn new(
        factory: &dyn EngineFactory,
        base: &'a EvalRequest,
        grid: &'a ScenarioGrid,
        cfg: &SweepConfig,
    ) -> Self {
        let ranges = chunk_ranges(base.configs.len());
        let n = ranges.len();
        SweepDriver {
            base,
            grid,
            cfg: *cfg,
            engine: factory.label(),
            ranges,
            keys: std::cell::OnceCell::new(),
            fingerprint: std::cell::OnceCell::new(),
            profiles: (0..n).map(|_| None).collect(),
            cursor: 0,
            threads_used: 1,
        }
    }

    /// The per-chunk content keys (computed once, on first use).
    fn chunk_keys(&self) -> &[CacheKey] {
        self.keys.get_or_init(|| {
            self.ranges
                .iter()
                .map(|r| {
                    ProfileCache::key_for_chunk(
                        &self.base.tasks,
                        &self.base.configs[r.clone()],
                        self.engine,
                    )
                })
                .collect()
        })
    }

    /// This problem's content fingerprint (computed once, on first use).
    fn problem_fingerprint(&self) -> &str {
        self.fingerprint
            .get_or_init(|| sweep_fingerprint(self.base, self.grid, self.engine, self.chunk_keys()))
    }

    /// Rebuild a driver from a checkpoint. The checkpoint must carry
    /// this exact problem's fingerprint — resuming a sweep recorded
    /// under a different design space, scenario grid, base request or
    /// engine is an error, not a silent blend. Progress itself comes
    /// back from the profile cache (completed chunks are warm hits), so
    /// the counter in the envelope is a validated expectation, not
    /// trusted state.
    pub fn resume(
        factory: &dyn EngineFactory,
        base: &'a EvalRequest,
        grid: &'a ScenarioGrid,
        cfg: &SweepConfig,
        ck: &SweepCheckpoint,
    ) -> crate::Result<Self> {
        let driver = Self::new(factory, base, grid, cfg);
        if ck.schema != SWEEP_CHECKPOINT_SCHEMA {
            anyhow::bail!(
                "sweep checkpoint schema {} != supported {}",
                ck.schema,
                SWEEP_CHECKPOINT_SCHEMA
            );
        }
        if ck.fingerprint != driver.problem_fingerprint() {
            anyhow::bail!(
                "sweep checkpoint does not match this problem (engine '{}', {} chunk(s)): it \
                 was recorded under a different design space, scenario grid, base request or \
                 engine ('{}', {} chunk(s))",
                driver.engine,
                driver.total_chunks(),
                ck.engine,
                ck.total_chunks
            );
        }
        Ok(driver)
    }

    /// True once every chunk is profiled.
    pub fn is_done(&self) -> bool {
        self.cursor >= self.ranges.len()
    }

    /// Chunks completed so far.
    pub fn chunks_done(&self) -> usize {
        self.cursor
    }

    /// Total chunks of this problem.
    pub fn total_chunks(&self) -> usize {
        self.ranges.len()
    }

    /// Snapshot phase-A progress (valid between any two steps).
    pub fn checkpoint(&self) -> SweepCheckpoint {
        SweepCheckpoint {
            schema: SWEEP_CHECKPOINT_SCHEMA,
            fingerprint: self.problem_fingerprint().to_string(),
            engine: self.engine.to_string(),
            chunks_done: self.cursor,
            total_chunks: self.ranges.len(),
        }
    }

    /// Profile the next batch of chunks (one per worker thread): cache
    /// lookups first, then one fan-out over the misses — which pack and
    /// contract *inside the workers*. The coordinator builds each
    /// miss's neutral chunk request up front and writes results back to
    /// the cache once they return (pooled workers outlive the borrow of
    /// `cache`, and the store is cheap next to a contraction). Returns
    /// `true` when phase A is complete.
    pub fn step(
        &mut self,
        factory: &dyn EngineFactory,
        cache: Option<&ProfileCache>,
    ) -> crate::Result<bool> {
        self.step_with(factory, cache, None)
    }

    /// [`Self::step`] with an optional cross-job [`Coalescer`]: each
    /// miss is admitted per content key — the first job in wins
    /// leadership of the chunk and computes it, every concurrent job
    /// waits for the leader's published bits instead of re-contracting.
    /// The order is load-bearing: every led chunk is computed, stored
    /// and published *before* this step waits on any followed chunk, so
    /// the cross-job wait graph is leader→waiter only and deadlock-free,
    /// and the store-before-publish/retire sequence means a requester
    /// that arrives after retirement finds the profile in the cache.
    /// With a deterministic engine a waited-for profile is bit-identical
    /// to computing it locally, so coalescing never changes results.
    pub fn step_with(
        &mut self,
        factory: &dyn EngineFactory,
        cache: Option<&ProfileCache>,
        coalescer: Option<&Coalescer>,
    ) -> crate::Result<bool> {
        if factory.label() != self.engine {
            anyhow::bail!(
                "engine '{}' does not match the '{}' this sweep was keyed under",
                factory.label(),
                self.engine
            );
        }
        if self.is_done() {
            return Ok(true);
        }
        // Materialize keys only when a cache or coalescer is in play —
        // the plain uncached path never hashes the design space.
        if cache.is_some() || coalescer.is_some() {
            self.chunk_keys();
        }
        let batch = resolve_threads(self.cfg.threads).max(1);
        let end = (self.cursor + batch).min(self.ranges.len());
        let mut hits: Vec<(usize, DesignProfile)> = Vec::new();
        let mut misses: Vec<usize> = Vec::new();
        match cache {
            Some(c) => {
                let keys = self.keys.get().expect("keys materialized above");
                for i in self.cursor..end {
                    match c.load(&keys[i], self.engine) {
                        Some(profile) => hits.push((i, profile)),
                        None => misses.push(i),
                    }
                }
            }
            None => misses.extend(self.cursor..end),
        }
        for (i, profile) in hits {
            self.profiles[i] = Some(profile);
        }
        if !misses.is_empty() {
            // Partition the misses: chunks this job leads (it computes
            // them) vs chunks an identical concurrent job already has in
            // flight (this job waits). Without a coalescer every miss is
            // a local compute, exactly the old behavior.
            let mut compute: Vec<usize> = Vec::new();
            let mut guards: Vec<Option<LeadGuard<'_>>> = Vec::new();
            let mut waits: Vec<(usize, Waiter<'_>)> = Vec::new();
            match coalescer {
                Some(co) => {
                    let keys = self.keys.get().expect("keys materialized above");
                    for &i in &misses {
                        match co.begin(keys[i]) {
                            Admission::Lead(g) => {
                                // Re-check the cache after winning
                                // leadership: the previous leader stores
                                // before retiring its in-flight entry,
                                // so "absent from the map" can mean
                                // "already in the cache".
                                match cache.and_then(|c| c.load(&keys[i], self.engine)) {
                                    Some(p) => {
                                        g.publish_cached(&p);
                                        self.profiles[i] = Some(p);
                                    }
                                    None => {
                                        compute.push(i);
                                        guards.push(Some(g));
                                    }
                                }
                            }
                            Admission::Wait(w) => waits.push((i, w)),
                        }
                    }
                }
                None => {
                    guards = misses.iter().map(|_| None).collect();
                    compute = misses;
                }
            }
            if !compute.is_empty() {
                let ranges = &self.ranges;
                let items: Vec<EvalRequest> = compute
                    .iter()
                    .map(|&i| {
                        neutral_chunk(&self.base.tasks, &self.base.configs[ranges[i].clone()])
                    })
                    .collect();
                // Packing happens inside the workers (the coordinator
                // only hashed `ConfigRow`s for the key); the closure
                // captures nothing, so it runs on pooled workers
                // unchanged. On error the guards drop unpublished,
                // poisoning their slots so cross-job waiters recompute
                // instead of hanging.
                let (computed, threads) =
                    fan_out(factory, items, self.cfg.threads, |eng, req: &EvalRequest| {
                        profile_request(eng, req)
                    })?;
                self.threads_used = self.threads_used.max(threads);
                for ((&i, profile), guard) in compute.iter().zip(computed).zip(guards) {
                    // A failed write-back (disk full, permissions) must
                    // not abort a sweep whose engine work succeeded —
                    // the profile is used anyway and the failure shows
                    // up as `write_errors` on the stats surface. Store
                    // BEFORE publish: retirement of the in-flight entry
                    // is the "check the cache" signal.
                    if let (Some(c), Some(keys)) = (cache, self.keys.get()) {
                        let _ = c.store(&keys[i], &profile, self.engine);
                    }
                    if let Some(g) = guard {
                        g.publish(&profile);
                    }
                    self.profiles[i] = Some(profile);
                }
            }
            for (i, w) in waits {
                if let Some(profile) = w.wait() {
                    // The leader stored before publishing — no second
                    // store, no second contraction.
                    self.profiles[i] = Some(profile);
                    continue;
                }
                // The leader died without publishing (engine error,
                // fail-fast abort in its job). Fall back deterministically:
                // re-check the cache, then compute locally — a real
                // engine failure reproduces here and surfaces as this
                // job's own error.
                let keys = self.keys.get().expect("keys materialized above");
                if let Some(p) = cache.and_then(|c| c.load(&keys[i], self.engine)) {
                    self.profiles[i] = Some(p);
                    continue;
                }
                let item =
                    neutral_chunk(&self.base.tasks, &self.base.configs[self.ranges[i].clone()]);
                let (mut computed, threads) =
                    fan_out(factory, vec![item], self.cfg.threads, |eng, req: &EvalRequest| {
                        profile_request(eng, req)
                    })?;
                self.threads_used = self.threads_used.max(threads);
                let profile = computed.pop().expect("one item in, one profile out");
                if let Some(c) = cache {
                    let _ = c.store(&keys[i], &profile, self.engine);
                }
                self.profiles[i] = Some(profile);
            }
        }
        self.cursor = end;
        Ok(self.is_done())
    }

    /// Phase B: fold the scenario overlays over the completed profiles,
    /// merging (scenario × chunk) results in the same scenario-major,
    /// chunk-ascending order the fused paths use — bit-identical to them.
    /// Every scenario's lowered overlays (one for a static scenario, one
    /// per segment for a trace, plus the trace's static mean-CI collapse
    /// for the [`TraceMeta`] report — not counted in `items`) flatten
    /// into **one** overlay batch, so each profile chunk is traversed by
    /// a single [`ScenarioOverlay::apply_batch`] pass over the whole
    /// grid; per-segment results then combine in trace order (the
    /// DESIGN.md §3.4 contract). Panics if phase A is incomplete (drive
    /// [`Self::step`] to done first); `cache_delta` is attached verbatim
    /// as the outcome's `cache` field.
    pub fn outcome(&self, cache_delta: Option<CacheStats>) -> SweepOutcome {
        assert!(self.is_done(), "sweep phase A incomplete: call step() until done");
        let profiles: Vec<&DesignProfile> =
            self.profiles.iter().map(|p| p.as_ref().expect("chunk left unprofiled")).collect();
        let scenarios = self.grid.scenarios();
        let shell = shallow(self.base);

        // How to slice the flat overlay batch back per scenario.
        struct Plan {
            label: String,
            /// This scenario's first overlay in the flat batch.
            first: usize,
            /// Lowered segment weights (len 1 for static scenarios).
            weights: Vec<f32>,
            /// Trace ingredients: segments, mean/min/max CI (g/kWh). The
            /// static collapse sits at `first + weights.len()`.
            trace: Option<(usize, f64, f64, f64)>,
        }
        let mut overlays: Vec<ScenarioOverlay> = Vec::new();
        let mut plans: Vec<Plan> = Vec::with_capacity(scenarios.len());
        let mut items = 0usize;
        for sc in scenarios {
            let first = overlays.len();
            let lowered = sc.lower();
            items += lowered.len() * profiles.len();
            let weights: Vec<f32> = lowered.iter().map(|&(_, w)| w).collect();
            for (seg, _) in &lowered {
                overlays.push(ScenarioOverlay::from_request(&seg.apply(&shell)));
            }
            let trace = sc.trace.as_ref().map(|tr| {
                let collapse = sc.static_collapse().apply(&shell);
                overlays.push(ScenarioOverlay::from_request(&collapse));
                (tr.len(), tr.mean_g_per_kwh(), tr.min_g_per_kwh(), tr.max_g_per_kwh())
            });
            plans.push(Plan { label: sc.label, first, weights, trace });
        }

        // xrlint: region(bit-identical)
        // One batched pass per chunk, merged chunk-ascending per overlay
        // — the same (scenario-major, chunk order) merge the fused and
        // sequential paths use. An empty design space profiles into zero
        // chunks; every slot then reports the empty result.
        let mut merged: Vec<Option<EvalResult>> = (0..overlays.len()).map(|_| None).collect();
        let mut scratch = OverlayScratch::new();
        for &prof in &profiles {
            let batch = ScenarioOverlay::apply_batch(&overlays, prof, &mut scratch);
            for (slot, res) in merged.iter_mut().zip(batch) {
                *slot = Some(match slot.take() {
                    None => res,
                    Some(acc) => merge(acc, res),
                });
            }
        }
        let t = self.base.tasks.num_tasks();
        let mut take = |i: usize| merged[i].take().unwrap_or_else(|| EvalResult::empty(t));

        let results: Vec<ScenarioResult> = plans
            .into_iter()
            .map(|plan| {
                let n_segs = plan.weights.len();
                let (combined, trace) = match plan.trace {
                    None => (take(plan.first), None),
                    Some((segments, mean, min, max)) => {
                        let segs: Vec<EvalResult> =
                            (0..n_segs).map(|gi| take(plan.first + gi)).collect();
                        let combined = combine_segments(&segs, &plan.weights);
                        let st = summarize(take(plan.first + n_segs));
                        let meta = TraceMeta {
                            segments,
                            mean_ci_g_per_kwh: mean,
                            min_ci_g_per_kwh: min,
                            max_ci_g_per_kwh: max,
                            static_best_tcdp: st.stats.best,
                            static_feasible: st.stats.feasible,
                        };
                        (combined, Some(meta))
                    }
                };
                ScenarioResult { label: plan.label, outcome: summarize(combined), trace }
            })
            .collect();
        // xrlint: endregion(bit-identical)
        SweepOutcome {
            scenarios: results,
            engine: self.engine,
            threads: self.threads_used,
            items,
            profile_chunks: profiles.len(),
            cache: cache_delta,
        }
    }

    /// Drive phase A to completion (persisting a checkpoint after every
    /// step when `save_to` is given) and build the outcome. A failed
    /// checkpoint write must not discard the in-flight sweep (the engine
    /// work already happened; completed chunks are in the cache) — warn
    /// once and keep going uncheckpointed, mirroring the cache layer's
    /// degrade-on-write-failure policy.
    pub fn run(
        self,
        factory: &dyn EngineFactory,
        cache: Option<&ProfileCache>,
        save_to: Option<&Path>,
    ) -> crate::Result<SweepOutcome> {
        self.run_with(factory, cache, None, save_to)
    }

    /// [`Self::run`] through [`Self::step_with`]: the service layer's
    /// entry point, sharing one [`Coalescer`] across every concurrent
    /// job so N identical cold sweeps trigger one phase-A contraction
    /// per unique chunk.
    pub fn run_with(
        mut self,
        factory: &dyn EngineFactory,
        cache: Option<&ProfileCache>,
        coalescer: Option<&Coalescer>,
        save_to: Option<&Path>,
    ) -> crate::Result<SweepOutcome> {
        let before = cache.map(|c| c.stats());
        let mut sink = save_to;
        loop {
            let done = self.step_with(factory, cache, coalescer)?;
            if let Some(path) = sink {
                if let Err(e) = write_sweep_checkpoint(path, &self.checkpoint()) {
                    eprintln!(
                        "[sweep checkpoint] write to {} failed ({e}); continuing without \
                         checkpoints",
                        path.display()
                    );
                    sink = None;
                }
            }
            if done {
                break;
            }
        }
        let delta = match (cache, before) {
            (Some(c), Some(b)) => Some(c.stats().since(&b)),
            _ => None,
        };
        Ok(self.outcome(delta))
    }
}

/// One fanned-out unit of fused work: a config chunk under one lowered
/// (scenario, trace-segment) pair.
struct SweepItem {
    scenario: usize,
    segment: usize,
    req: EvalRequest,
}

/// Build the (scenario × trace-segment × config-chunk) item list for the
/// fused path: every scenario lowers through [`SweepScenario::lower`]
/// (one segment for static scenarios) before chunking. Chunk boundaries
/// are exactly the ones `evaluate_chunked` would use sequentially — one
/// engine call per item — so merging item results in order reproduces
/// the sequential result bit-for-bit (a remainder chunk must run as one
/// padded batch here, not be re-chunked, or the PJRT path would route it
/// through a different artifact variant than the sequential run). Also
/// returns each scenario's lowered segment weights.
///
/// [`SweepScenario::lower`]: super::grid::SweepScenario::lower
fn build_items(
    base: &EvalRequest,
    grid: &ScenarioGrid,
) -> (Vec<SweepItem>, Vec<super::grid::SweepScenario>, Vec<Vec<f32>>) {
    let scenarios = grid.scenarios();
    let mut items = Vec::new();
    let mut weights = Vec::with_capacity(scenarios.len());
    for (si, sc) in scenarios.iter().enumerate() {
        let lowered = sc.lower();
        weights.push(lowered.iter().map(|&(_, w)| w).collect::<Vec<f32>>());
        for (gi, (seg, _)) in lowered.iter().enumerate() {
            let req = seg.apply(base);
            if req.configs.is_empty() {
                // No configs, no engine items; the merge below falls
                // back to the empty result for every segment.
                continue;
            }
            let cs = chunk_size(req.configs.len());
            if req.configs.len() <= cs {
                items.push(SweepItem { scenario: si, segment: gi, req });
            } else {
                for chunk in req.configs.chunks(cs) {
                    items.push(SweepItem {
                        scenario: si,
                        segment: gi,
                        req: EvalRequest { configs: chunk.to_vec(), ..shallow(&req) },
                    });
                }
            }
        }
    }
    (items, scenarios, weights)
}

/// The PR 1 per-scenario fused fan-out: every (scenario × trace-segment
/// × config-chunk) item re-runs the engine with the scenario folded into
/// the graph. Engine work is O(N_scenarios × C × T × K) — and another
/// ×N_segments for trace scenarios, which is exactly the cost the
/// two-phase path avoids; kept as the baseline the two-phase [`sweep`]
/// is benchmarked against (`benches/bench_sweep_parallel.rs`,
/// `benches/bench_trace.rs`) and as a second bit-identity oracle in the
/// property tests.
pub fn sweep_fused(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SweepConfig,
) -> crate::Result<SweepOutcome> {
    let (items, scenarios, weights) = build_items(base, grid);
    let n_items = items.len();
    // The fan-out takes the items by value (pooled workers need owned
    // work), so remember each item's (scenario, segment) slot first.
    let meta: Vec<(usize, usize)> = items.iter().map(|it| (it.scenario, it.segment)).collect();
    let (slots, threads_used) =
        fan_out(factory, items, cfg.threads, |engine, item: &SweepItem| {
            evaluate_fused(engine, &item.req)
        })?;

    // Order-preserving merge: items were emitted scenario-major,
    // segment-major, in chunk order, so folding each (scenario, segment)
    // slot left-to-right reproduces the sequential `evaluate_chunked`
    // merge exactly; segments then combine in trace order.
    let mut merged: Vec<Vec<Option<EvalResult>>> =
        weights.iter().map(|w| (0..w.len()).map(|_| None).collect()).collect();
    for (&(si, gi), res) in meta.iter().zip(slots) {
        let slot = &mut merged[si][gi];
        *slot = Some(match slot.take() {
            None => res,
            Some(acc) => merge(acc, res),
        });
    }

    let empty = || EvalResult::empty(base.tasks.num_tasks());
    let scenarios = scenarios
        .into_iter()
        .zip(merged)
        .zip(weights)
        .map(|((sc, segs), w)| {
            let segs: Vec<EvalResult> =
                segs.into_iter().map(|r| r.unwrap_or_else(empty)).collect();
            let res = if sc.trace.is_none() {
                segs.into_iter().next().unwrap_or_else(empty)
            } else {
                combine_segments(&segs, &w)
            };
            ScenarioResult { label: sc.label, outcome: summarize(res), trace: None }
        })
        .collect();

    Ok(SweepOutcome {
        scenarios,
        engine: factory.label(),
        threads: threads_used,
        items: n_items,
        profile_chunks: num_chunks(base.configs.len()),
        cache: None,
    })
}

/// Sequential reference path: one engine, scenarios in grid order,
/// trace scenarios evaluated segment by segment and combined in trace
/// order. The parallel [`sweep`] and [`sweep_fused`] must match this
/// bit-for-bit.
pub fn sweep_sequential(
    engine: &mut dyn Engine,
    base: &EvalRequest,
    grid: &ScenarioGrid,
) -> crate::Result<SweepOutcome> {
    let scenarios = grid.scenarios();
    let mut items = 0usize;
    let mut out = Vec::with_capacity(scenarios.len());
    for sc in scenarios {
        let lowered = sc.lower();
        items += lowered.len();
        let outcome = if sc.trace.is_none() {
            explore(engine, &lowered[0].0.apply(base))?
        } else {
            // Chunks merge per segment (inside `evaluate_chunked`), then
            // segments combine — the same order as the other paths.
            let mut segs = Vec::with_capacity(lowered.len());
            let mut weights = Vec::with_capacity(lowered.len());
            for (seg, w) in &lowered {
                segs.push(evaluate_chunked(engine, &seg.apply(base))?);
                weights.push(*w);
            }
            summarize(combine_segments(&segs, &weights))
        };
        out.push(ScenarioResult { label: sc.label, outcome, trace: None });
    }
    Ok(SweepOutcome {
        scenarios: out,
        engine: engine.name(),
        threads: 1,
        items,
        profile_chunks: num_chunks(base.configs.len()),
        cache: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, TaskMatrix};
    use crate::runtime::{HostEngine, HostEngineFactory};

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k".into()], &[3.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![(i + 1) as f64 * 1e-3],
                    e_dyn: vec![0.01 + i as f64 * 1e-4],
                    leak_w: 0.01,
                    c_comp: vec![100.0 + i as f64],
                })
                .collect(),
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    fn grid() -> ScenarioGrid {
        ScenarioGrid::new()
            .with_lifetime("short", 1e5)
            .with_lifetime("long", 1e7)
            .with_beta("b=0.5", 0.5)
            .with_beta("b=2", 2.0)
    }

    fn assert_outcomes_identical(a: &SweepOutcome, b: &SweepOutcome) {
        assert_eq!(a.scenarios.len(), b.scenarios.len());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.outcome.result.names, y.outcome.result.names);
            // Bit-identical, not approximately equal.
            assert_eq!(x.outcome.result.metrics, y.outcome.result.metrics);
            assert_eq!(x.outcome.result.d_task, y.outcome.result.d_task);
            assert_eq!(x.outcome.optimal, y.outcome.optimal);
            assert_eq!(x.outcome.stats.best.to_bits(), y.outcome.stats.best.to_bits());
            assert_eq!(x.outcome.stats.mean.to_bits(), y.outcome.stats.mean.to_bits());
            assert_eq!(x.outcome.stats.feasible, y.outcome.stats.feasible);
        }
    }

    #[test]
    fn parallel_matches_sequential_small_space() {
        let req = request(9);
        let par = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 }).unwrap();
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid()).unwrap();
        assert_eq!(par.scenarios.len(), 4);
        assert_eq!(par.profile_chunks, 1);
        assert_outcomes_identical(&par, &seq);
    }

    #[test]
    fn parallel_matches_sequential_chunked_space() {
        // 2500 configs -> 3 profile chunks, 4 scenarios -> 12 overlay
        // applications (but only 3 engine calls on the two-phase path).
        let req = request(2500);
        let par = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 }).unwrap();
        assert_eq!(par.items, 12);
        assert_eq!(par.profile_chunks, 3);
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid()).unwrap();
        assert_outcomes_identical(&par, &seq);
    }

    #[test]
    fn two_phase_matches_fused_fan_out() {
        // The tentpole invariant at the coordinator level: caching the
        // profile and overlaying scenarios equals re-running the engine
        // per scenario, bit-for-bit.
        for c in [9usize, 400] {
            let req = request(c);
            let two =
                sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 }).unwrap();
            let fused =
                sweep_fused(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 4 })
                    .unwrap();
            assert_eq!(two.items, fused.items, "c={c}");
            assert_outcomes_identical(&two, &fused);
        }
    }

    #[test]
    fn warm_cached_sweep_is_bit_identical_with_zero_contractions() {
        let dir = crate::testkit::test_dir("sweep_cache_warm");
        std::fs::remove_dir_all(&dir).ok();
        let cache = crate::dse::cache::ProfileCache::open(&dir).unwrap();
        let req = request(2500); // 3 profile chunks
        let cfg = SweepConfig { threads: 2 };

        let plain = sweep(&HostEngineFactory, &req, &grid(), &cfg).unwrap();
        let cold = sweep_with_cache(&HostEngineFactory, &req, &grid(), &cfg, Some(&cache)).unwrap();
        let warm = sweep_with_cache(&HostEngineFactory, &req, &grid(), &cfg, Some(&cache)).unwrap();
        assert_outcomes_identical(&plain, &cold);
        assert_outcomes_identical(&cold, &warm);

        let cs = cold.cache.expect("cold run reports cache stats");
        assert_eq!((cs.hits, cs.misses, cs.writes), (0, 3, 3));
        let ws = warm.cache.expect("warm run reports cache stats");
        assert_eq!((ws.hits, ws.misses, ws.writes), (3, 0, 0));
        // Same-process warm run: the in-memory LRU serves every chunk.
        assert_eq!(ws.mem_hits, 3);
        assert_eq!(ws.contractions_avoided(), warm.profile_chunks);
        assert!(plain.cache.is_none());

        // A cold-memory process (fresh cache instance) still avoids all
        // contractions via the binary sidecars.
        let fresh = crate::dse::cache::ProfileCache::open(&dir).unwrap();
        let disk_warm =
            sweep_with_cache(&HostEngineFactory, &req, &grid(), &cfg, Some(&fresh)).unwrap();
        assert_outcomes_identical(&cold, &disk_warm);
        let ds = disk_warm.cache.unwrap();
        assert_eq!((ds.hits, ds.mem_hits, ds.misses), (3, 0, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_scenarios_match_fused_and_sequential_bitwise() {
        let req = request(400);
        let g = ScenarioGrid::new()
            .with_lifetime("short", 1e5)
            .with_trace("trace=diurnal", crate::carbon::CiTrace::diurnal_world())
            .with_trace("trace=flat", crate::carbon::CiTrace::flat(440.0));
        let two = sweep(&HostEngineFactory, &req, &g, &SweepConfig { threads: 4 }).unwrap();
        let fused =
            sweep_fused(&HostEngineFactory, &req, &g, &SweepConfig { threads: 4 }).unwrap();
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &g).unwrap();
        assert_eq!(two.items, fused.items);
        assert_outcomes_identical(&two, &fused);
        assert_outcomes_identical(&two, &seq);
        // Phase A ran once; phase B did (24 + 1) segment overlays/chunk.
        assert_eq!(two.items, 25 * two.profile_chunks);
        // Only the two-phase (production) path fills TraceMeta.
        let m = two.scenarios[0].trace.expect("trace scenario carries meta");
        assert_eq!(m.segments, 24);
        assert!((m.mean_ci_g_per_kwh - 440.0).abs() < 1e-9);
        assert!(m.min_ci_g_per_kwh < m.max_ci_g_per_kwh);
        assert!(fused.scenarios[0].trace.is_none());
        assert!(seq.scenarios[0].trace.is_none());
    }

    #[test]
    fn trace_outcome_sits_within_f32_rounding_of_its_static_collapse() {
        // Operational carbon is linear in CI, so the time-weighted trace
        // result equals the static mean-CI result up to f32 rounding —
        // the delta the report surfaces must be tiny, never structural.
        let req = request(50);
        let g = ScenarioGrid::new()
            .with_trace("trace=diurnal", crate::carbon::CiTrace::diurnal_renewable());
        let out = sweep(&HostEngineFactory, &req, &g, &SweepConfig::default()).unwrap();
        let s = &out.scenarios[0];
        let m = s.trace.expect("meta");
        let rel = (s.outcome.stats.best - m.static_best_tcdp).abs() / m.static_best_tcdp;
        assert!(rel < 1e-4, "trace vs static best diverged: rel={rel}");
        assert_eq!(s.outcome.stats.feasible, m.static_feasible);
    }

    #[test]
    fn warm_trace_sweep_over_fig7_grid_avoids_every_contraction() {
        // Acceptance criterion: a 24-segment diurnal trace crossed with
        // the fig7 grid over a warm profile cache performs zero phase-A
        // contractions — traces are pure phase-B work.
        let dir = crate::testkit::test_dir("sweep_trace_warm");
        std::fs::remove_dir_all(&dir).ok();
        let cache = crate::dse::cache::ProfileCache::open(&dir).unwrap();
        let req = request(2500); // 3 profile chunks
        let trace = crate::carbon::CiTrace::diurnal_world();
        assert_eq!(trace.len(), 24);
        let g = ScenarioGrid::fig7(&req.configs, &req.tasks, req.ci_use_g_per_j)
            .cross(ScenarioGrid::new().with_trace("trace=diurnal-world", trace));
        let cfg = SweepConfig { threads: 2 };

        let cold = sweep_with_cache(&HostEngineFactory, &req, &g, &cfg, Some(&cache)).unwrap();
        let warm = sweep_with_cache(&HostEngineFactory, &req, &g, &cfg, Some(&cache)).unwrap();
        assert_outcomes_identical(&cold, &warm);
        // 3 fig7 scenarios × 24 segments × 3 chunks of phase-B overlays…
        assert_eq!(warm.items, 3 * 24 * 3);
        let cs = cold.cache.unwrap();
        assert_eq!((cs.hits, cs.misses, cs.writes), (0, 3, 3));
        // …but zero warm phase-A contractions: all 3 chunks come back
        // from the cache regardless of how many trace segments fan out.
        let ws = warm.cache.unwrap();
        assert_eq!((ws.hits, ws.misses), (3, 0));
        assert_eq!(ws.contractions_avoided(), warm.profile_chunks);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_design_space_sweeps_to_empty_scenarios() {
        // Regression: zero configs used to panic inside packing; now
        // every path reports empty per-scenario outcomes.
        let req = request(0);
        let par = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        assert_eq!(par.scenarios.len(), 4);
        assert_eq!(par.profile_chunks, 0);
        assert_eq!(par.items, 0);
        assert!(par.best().is_none());
        for s in &par.scenarios {
            assert_eq!(s.outcome.result.c, 0);
            assert_eq!(s.outcome.stats.feasible, 0);
        }
        let fused =
            sweep_fused(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid()).unwrap();
        assert_outcomes_identical(&par, &fused);
        assert_outcomes_identical(&par, &seq);
    }

    #[test]
    fn single_thread_config_uses_one_worker() {
        let req = request(5);
        let out = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig { threads: 1 }).unwrap();
        assert_eq!(out.threads, 1);
        assert_eq!(out.engine, "host");
        assert_eq!(out.scenarios.len(), 4);
    }

    #[test]
    fn scenario_order_matches_grid_enumeration() {
        let req = request(3);
        let out = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        let labels: Vec<&str> = out.scenarios.iter().map(|s| s.label.as_str()).collect();
        let expect: Vec<String> = grid().scenarios().into_iter().map(|s| s.label).collect();
        assert_eq!(labels, expect.iter().map(String::as_str).collect::<Vec<_>>());
    }

    #[test]
    fn best_is_global_argmin_across_scenarios() {
        let req = request(7);
        let out = sweep(&HostEngineFactory, &req, &grid(), &SweepConfig::default()).unwrap();
        let (si, ci, v) = out.best().expect("feasible design exists");
        for s in &out.scenarios {
            for i in 0..s.outcome.result.c {
                if s.outcome.result.metric(MetricRow::Feasible, i) > 0.5 {
                    assert!(s.outcome.result.metric(MetricRow::Tcdp, i) >= v);
                }
            }
        }
        assert!(out.scenarios[si].outcome.result.metric(MetricRow::Tcdp, ci) == v);
    }

    #[test]
    fn longer_lifetime_lowers_amortized_embodied() {
        // Scenario semantics flow through the sweep: the long-lifetime
        // scenario must report lower tCDP than the short one (same space).
        let req = request(4);
        let g = ScenarioGrid::new().with_lifetime("short", 1e5).with_lifetime("long", 1e7);
        let out = sweep(&HostEngineFactory, &req, &g, &SweepConfig::default()).unwrap();
        assert!(out.scenarios[0].outcome.stats.best > out.scenarios[1].outcome.stats.best);
    }

    #[test]
    fn sweep_checkpoint_roundtrips_and_rejects_corruption() {
        let req = request(2500);
        let g = grid();
        let d = SweepDriver::new(&HostEngineFactory, &req, &g, &SweepConfig { threads: 1 });
        let ck = d.checkpoint();
        assert_eq!(ck.total_chunks, 3);
        assert_eq!(ck.chunks_done, 0);
        let text = ck.to_json_string();
        assert_eq!(SweepCheckpoint::from_json_str(&text).unwrap(), ck);
        // Corruption: truncation, tampering, missing digest.
        assert!(SweepCheckpoint::from_json_str(&text[..text.len() / 2]).is_err());
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            o.insert("chunks_done".into(), Json::Num(2.0));
        }
        assert!(SweepCheckpoint::from_json_str(&doc.to_string()).is_err());
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            o.remove("digest");
        }
        assert!(SweepCheckpoint::from_json_str(&doc.to_string()).is_err());
        // Stale schema (re-rendered with a fresh digest so only the
        // schema check can reject it).
        let stale = SweepCheckpoint { schema: SWEEP_CHECKPOINT_SCHEMA + 1, ..ck.clone() };
        assert!(SweepCheckpoint::from_json_str(&stale.to_json_string()).is_err());
    }

    #[test]
    fn interrupted_sweep_resumes_bit_identically_from_any_chunk() {
        let dir = crate::testkit::test_dir("sweep_resume");
        std::fs::remove_dir_all(&dir).ok();
        let req = request(2500); // 3 chunks
        let g = grid();
        let cfg = SweepConfig { threads: 1 }; // one chunk per step
        let reference = sweep(&HostEngineFactory, &req, &g, &cfg).unwrap();

        for interrupt_after in 0..=3usize {
            let cache = crate::dse::cache::ProfileCache::open(&dir).unwrap();
            // Phase 1: run `interrupt_after` steps, then "crash".
            let mut d = SweepDriver::new(&HostEngineFactory, &req, &g, &cfg);
            for _ in 0..interrupt_after {
                if d.step(&HostEngineFactory, Some(&cache)).unwrap() {
                    break;
                }
            }
            let ck =
                SweepCheckpoint::from_json_str(&d.checkpoint().to_json_string()).unwrap();
            assert_eq!(ck.chunks_done, interrupt_after.min(3));

            // Phase 2: a fresh process (fresh cache instance = cold
            // memory) resumes and finishes.
            let cache2 = crate::dse::cache::ProfileCache::open(&dir).unwrap();
            let resumed = SweepDriver::resume(&HostEngineFactory, &req, &g, &cfg, &ck)
                .unwrap()
                .run(&HostEngineFactory, Some(&cache2), None)
                .unwrap();
            assert_outcomes_identical(&reference, &resumed);
            // Completed chunks came back from disk, the rest was paid.
            let stats = resumed.cache.unwrap();
            assert_eq!(stats.hits, interrupt_after.min(3), "interrupt={interrupt_after}");
            assert_eq!(stats.misses, 3 - interrupt_after.min(3));
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn sweep_resume_rejects_a_different_problem_sharing_the_grid() {
        let req = request(100);
        let g = grid();
        let cfg = SweepConfig::default();
        let d = SweepDriver::new(&HostEngineFactory, &req, &g, &cfg);
        let ck = d.checkpoint();

        // Same grid, different design space ("another workload cluster"):
        // rejected by the fingerprint.
        let mut other = request(100);
        other.configs[17].d_k[0] *= 1.5;
        assert!(SweepDriver::resume(&HostEngineFactory, &other, &g, &cfg, &ck).is_err());
        // Same space, different base scenario knobs: rejected.
        let mut rescoped = request(100);
        rescoped.qos = vec![0.5];
        assert!(SweepDriver::resume(&HostEngineFactory, &rescoped, &g, &cfg, &ck).is_err());
        // Different grid: rejected.
        let other_grid = ScenarioGrid::new().with_lifetime("short", 2e5);
        assert!(SweepDriver::resume(&HostEngineFactory, &req, &other_grid, &cfg, &ck).is_err());
        // Different engine label: rejected.
        struct RelabeledHost;
        impl crate::runtime::EngineFactory for RelabeledHost {
            fn build(&self) -> crate::Result<Box<dyn crate::runtime::Engine>> {
                Ok(Box::new(crate::runtime::HostEngine::new()))
            }
            fn label(&self) -> &'static str {
                "host-v2"
            }
        }
        assert!(SweepDriver::resume(&RelabeledHost, &req, &g, &cfg, &ck).is_err());
        // The matching problem still resumes.
        assert!(SweepDriver::resume(&HostEngineFactory, &req, &g, &cfg, &ck).is_ok());
    }

    #[test]
    fn sweep_resumable_writes_and_honors_checkpoints() {
        let dir = crate::testkit::test_dir("sweep_resumable");
        std::fs::remove_dir_all(&dir).ok();
        let cache = crate::dse::cache::ProfileCache::open(&dir).unwrap();
        let ckpt = dir.join("sweep.ckpt.json");
        let req = request(2500);
        let g = grid();
        let cfg = SweepConfig { threads: 2 };

        let plain = sweep(&HostEngineFactory, &req, &g, &cfg).unwrap();
        let saved = sweep_resumable(
            &HostEngineFactory,
            &req,
            &g,
            &cfg,
            &cache,
            None,
            Some(ckpt.as_path()),
        )
        .unwrap();
        assert_outcomes_identical(&plain, &saved);
        let ck = read_sweep_checkpoint(&ckpt).unwrap();
        assert_eq!((ck.chunks_done, ck.total_chunks), (3, 3));

        // Resuming the finished checkpoint re-reads every chunk from the
        // cache and reproduces the outcome with zero contractions.
        let resumed = sweep_resumable(
            &HostEngineFactory,
            &req,
            &g,
            &cfg,
            &cache,
            Some(&ck),
            Some(ckpt.as_path()),
        )
        .unwrap();
        assert_outcomes_identical(&plain, &resumed);
        let stats = resumed.cache.unwrap();
        assert_eq!((stats.hits, stats.misses), (3, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fan_out_fails_fast_and_reports_lowest_failing_item() {
        use std::sync::Arc;
        // Regression: the first failure used to leave sibling workers
        // draining the whole queue before the error surfaced. Both
        // schedulers — the persistent pool (`HostEngineFactory` opts in)
        // and the scoped-spawn fallback — must abandon it, and both must
        // report the lowest-indexed failure deterministically.
        let scoped = crate::runtime::ScopedSpawn(HostEngineFactory);
        let factories: [&dyn EngineFactory; 2] = [&HostEngineFactory, &scoped];
        for factory in factories {
            let processed = Arc::new(AtomicUsize::new(0));
            let p = Arc::clone(&processed);
            let items: Vec<usize> = (0..64).collect();
            let err = fan_out(factory, items, 2, move |_eng, &i: &usize| {
                p.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(1));
                if i == 3 {
                    anyhow::bail!("boom at {i}");
                }
                Ok(i)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "boom at 3");
            // Generous bound — what matters is "not all 64".
            assert!(
                processed.load(Ordering::SeqCst) < 48,
                "fan-out drained the queue after a failure"
            );
        }
    }

    #[test]
    fn pooled_and_scoped_schedulers_sweep_bit_identically() {
        let req = request(2500); // 3 profile chunks
        let cfg = SweepConfig { threads: 2 };
        let pooled = sweep(&HostEngineFactory, &req, &grid(), &cfg).unwrap();
        let spawned =
            sweep(&crate::runtime::ScopedSpawn(HostEngineFactory), &req, &grid(), &cfg).unwrap();
        assert_outcomes_identical(&pooled, &spawned);
    }
}
