//! Persistent, content-addressed profile cache — the warm-start layer
//! under the two-phase sweep coordinator.
//!
//! Phase A of the pipeline (the O(C×T×K) engine contraction of a config
//! chunk into a scenario-invariant [`DesignProfile`]) never depends on
//! the scenario, yet every process restart used to re-pay it from
//! scratch. A [`ProfileCache`] keys each *packed chunk* by a stable
//! content hash of
//!
//! * the packed design-space tensors (`N`, `p_leak`, `p_dyn`, `f_clk`,
//!   `d_k`, `c_comp`, config names — exactly the inputs the contraction
//!   reads; scenario knobs are excluded by construction),
//! * the artifact-manifest shape constants ([`T_PAD`], [`K_PAD`],
//!   [`J_PAD`], [`NUM_METRICS`], the batch variants) and the packed
//!   dims,
//! * the engine label (host and PJRT numerics differ), and
//! * the envelope schema version ([`PROFILE_SCHEMA`]).
//!
//! Profiles are serialized through [`crate::configfmt`] as a versioned
//! JSON envelope. Every `f32` buffer travels as raw `u32` bit patterns
//! (exactly representable as JSON integers), so a cache round-trip is
//! **bit-exact** and a warm-start sweep is bit-identical to the cold run
//! on the host engine — locked by `rust/tests/cache_props.rs`.
//!
//! The trust model is asymmetric: a stored profile is only ever used
//! when its envelope passes every check (schema version, key echo,
//! engine label, shape constants, buffer lengths, integral bit values).
//! Anything else — truncated file, stale schema, foreign key, wrong
//! shape — is *rejected and recomputed*, never trusted; rejections are
//! counted on the [`CacheStats`] surface. Writes go through a
//! temp-file + rename so a crashed writer can at worst leave a stray
//! temp file, not a half-written envelope under a valid key.

use std::path::{Path, PathBuf};

use crate::configfmt::{parse, Json};
use crate::matrixform::{
    DesignProfile, EvalRequest, PackedProblem, C_VARIANTS, J_PAD, K_PAD, NUM_METRICS, T_PAD,
};
use crate::runtime::{CacheCounters, CacheStats};

/// Envelope schema version. Bump on any change to the envelope layout
/// *or* to the profile semantics (what the engine contraction computes);
/// older entries are then rejected and recomputed.
pub const PROFILE_SCHEMA: u32 = 1;

/// 128-bit content key of one packed profile chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    hi: u64,
    lo: u64,
}

impl CacheKey {
    /// Fixed-width lowercase hex rendering (32 chars) — the on-disk
    /// file stem and the envelope's `key` echo.
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }
}

impl std::fmt::Display for CacheKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Two independently-seeded FNV-1a streams fed the same bytes — a cheap
/// dependency-free 128-bit content hash (collision odds are negligible
/// at cache scale, and a colliding entry would still have to pass the
/// shape checks). Shared with the search checkpoints (`dse::search`)
/// for grid and envelope digests — one hash core, not three.
pub(crate) struct KeyHasher {
    a: u64,
    b: u64,
}

const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

impl KeyHasher {
    pub(crate) fn new() -> Self {
        // Offset bases: the standard FNV-1a basis and a second stream
        // seeded from it (any fixed distinct constant works).
        KeyHasher { a: 0xCBF2_9CE4_8422_2325, b: 0x9AE1_6A3B_2F90_404F }
    }

    pub(crate) fn write(&mut self, bytes: &[u8]) {
        for &byte in bytes {
            self.a = (self.a ^ byte as u64).wrapping_mul(FNV_PRIME);
            self.b = (self.b ^ byte as u64).wrapping_mul(FNV_PRIME).rotate_left(1);
        }
    }

    pub(crate) fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub(crate) fn write_f32s(&mut self, xs: &[f32]) {
        self.write_u64(xs.len() as u64);
        for x in xs {
            self.write(&x.to_bits().to_le_bytes());
        }
    }

    pub(crate) fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub(crate) fn finish(self) -> CacheKey {
        CacheKey { hi: self.a, lo: self.b }
    }
}

/// On-disk, content-addressed store of [`DesignProfile`]s with a
/// thread-safe stats surface. One JSON envelope per key under `dir`.
#[derive(Debug)]
pub struct ProfileCache {
    dir: PathBuf,
    counters: CacheCounters,
}

impl ProfileCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<ProfileCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(ProfileCache { dir, counters: CacheCounters::new() })
    }

    /// The backing directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot of this cache's hit/miss/write counters (process
    /// lifetime; use [`CacheStats::since`] for per-run deltas).
    pub fn stats(&self) -> CacheStats {
        self.counters.snapshot()
    }

    /// Content key of one packed chunk for one engine. Hashes exactly
    /// the scenario-invariant inputs of the phase-A contraction plus the
    /// shape constants and schema version — the scenario knobs
    /// (`online`, `qos`, scalars) are deliberately excluded, which is
    /// what makes one cached profile serve every scenario overlay.
    pub fn key_for_packed(packed: &PackedProblem, engine: &str) -> CacheKey {
        let mut h = KeyHasher::new();
        h.write(b"xrcarbon-profile");
        h.write_u64(PROFILE_SCHEMA as u64);
        // Artifact-manifest shape constants: a rebuilt artifact set with
        // different padding must never alias old entries.
        for dim in [T_PAD, K_PAD, J_PAD, NUM_METRICS] {
            h.write_u64(dim as u64);
        }
        for v in C_VARIANTS {
            h.write_u64(v as u64);
        }
        h.write_str(engine);
        for dim in [packed.c_pad, packed.c, packed.t, packed.k] {
            h.write_u64(dim as u64);
        }
        h.write_f32s(&packed.n);
        h.write_f32s(&packed.p_leak);
        h.write_f32s(&packed.p_dyn);
        h.write_f32s(&packed.f_clk);
        h.write_f32s(&packed.d_k);
        h.write_f32s(&packed.c_comp);
        h.write_u64(packed.names.len() as u64);
        for name in &packed.names {
            h.write_str(name);
        }
        h.finish()
    }

    /// Convenience: pack a (non-empty) chunk request and key it.
    pub fn key_for_request(req: &EvalRequest, engine: &str) -> CacheKey {
        Self::key_for_packed(&PackedProblem::from_request(req), engine)
    }

    fn path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.profile.json", key.hex()))
    }

    /// Look a profile up. `Some` only for an envelope that passes every
    /// validation check; absent entries and read errors are plain misses,
    /// while corrupted/stale *content* is additionally counted as
    /// rejected (`rejected` means "an envelope was validated and
    /// refused", not "I/O failed") — either way the caller recomputes.
    pub fn load(&self, key: &CacheKey, engine: &str) -> Option<DesignProfile> {
        let path = self.path_for(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => {
                // NotFound, permissions, transient I/O — nothing was
                // validated, so this is a miss, not a rejection.
                self.counters.record_miss();
                return None;
            }
        };
        match decode_envelope(&text, key, engine) {
            Some(profile) => {
                self.counters.record_hit();
                Some(profile)
            }
            None => {
                self.counters.record_rejected();
                None
            }
        }
    }

    /// Write a profile back under its key (temp file + rename, so
    /// concurrent readers never observe a partial envelope). Failures
    /// are counted on the stats surface either way, so callers for whom
    /// the cache is an optimization (the sweep) can ignore the error and
    /// degrade to uncached behavior.
    pub fn store(
        &self,
        key: &CacheKey,
        profile: &DesignProfile,
        engine: &str,
    ) -> crate::Result<()> {
        match atomic_write(&self.path_for(key), &encode_envelope(key, profile, engine)) {
            Ok(()) => {
                self.counters.record_write();
                Ok(())
            }
            Err(e) => {
                self.counters.record_write_error();
                Err(e)
            }
        }
    }
}

/// Crash-safe file write shared by the cache and the search
/// checkpoints: write to a uniquely-named sibling temp file (pid + a
/// process-wide counter, so concurrent writers of the same path never
/// share one), then rename into place — readers can never observe a
/// partial document.
pub(crate) fn atomic_write(path: &Path, text: &str) -> crate::Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp.{}.{seq}", std::process::id()));
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// `f32` buffer → JSON array of `u32` bit patterns (exact integers).
fn bits_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(x.to_bits() as f64)).collect())
}

/// JSON array of `u32` bit patterns → `f32` buffer of an exact length.
/// `None` on length mismatch, non-integral entries or out-of-`u32`-range
/// values (the strict [`Json::as_i64`] is what makes this safe).
fn parse_bits(v: Option<&Json>, expect_len: usize) -> Option<Vec<f32>> {
    let arr = v?.as_arr()?;
    if arr.len() != expect_len {
        return None;
    }
    arr.iter()
        .map(|j| j.as_i64().and_then(|i| u32::try_from(i).ok()).map(f32::from_bits))
        .collect()
}

fn get_usize(obj: &Json, key: &str) -> Option<usize> {
    obj.get(key)?.as_usize()
}

/// Content digest over the envelope's *payload* (buffers, names, dims):
/// the `key` echo only proves which request the entry claims to serve,
/// while this proves the stored numbers themselves are the ones that
/// were written — a flipped digit in a bit value is structurally valid
/// JSON and would otherwise be trusted.
fn payload_digest(profile: &DesignProfile) -> String {
    let mut h = KeyHasher::new();
    for dim in [profile.c, profile.c_pad, profile.t] {
        h.write_u64(dim as u64);
    }
    h.write_f32s(&profile.energy);
    h.write_f32s(&profile.delay);
    h.write_f32s(&profile.d_task);
    h.write_f32s(&profile.c_comp);
    h.write_u64(profile.names.len() as u64);
    for name in &profile.names {
        h.write_str(name);
    }
    h.finish().hex()
}

/// Render the versioned envelope for one profile.
fn encode_envelope(key: &CacheKey, profile: &DesignProfile, engine: &str) -> String {
    let names = Json::Arr(profile.names.iter().map(|n| Json::Str(n.clone())).collect());
    let doc = Json::obj(vec![
        ("schema", Json::Num(PROFILE_SCHEMA as f64)),
        ("key", Json::Str(key.hex())),
        ("engine", Json::Str(engine.to_string())),
        ("payload", Json::Str(payload_digest(profile))),
        (
            "shape",
            Json::obj(vec![
                ("t_pad", Json::Num(T_PAD as f64)),
                ("j_pad", Json::Num(J_PAD as f64)),
            ]),
        ),
        (
            "profile",
            Json::obj(vec![
                ("c", Json::Num(profile.c as f64)),
                ("c_pad", Json::Num(profile.c_pad as f64)),
                ("t", Json::Num(profile.t as f64)),
                ("names", names),
                ("energy", bits_arr(&profile.energy)),
                ("delay", bits_arr(&profile.delay)),
                ("d_task", bits_arr(&profile.d_task)),
                ("c_comp", bits_arr(&profile.c_comp)),
            ]),
        ),
    ]);
    doc.to_string()
}

/// Parse and fully validate an envelope; `None` means "reject and
/// recompute" (never a panic — cache contents are untrusted input).
fn decode_envelope(text: &str, key: &CacheKey, engine: &str) -> Option<DesignProfile> {
    let doc = parse(text).ok()?;
    if doc.get("schema")?.as_i64()? != PROFILE_SCHEMA as i64 {
        return None;
    }
    if doc.get("key")?.as_str()? != key.hex() {
        return None;
    }
    if doc.get("engine")?.as_str()? != engine {
        return None;
    }
    let shape = doc.get("shape")?;
    if get_usize(shape, "t_pad")? != T_PAD || get_usize(shape, "j_pad")? != J_PAD {
        return None;
    }

    let prof = doc.get("profile")?;
    let c = get_usize(prof, "c")?;
    let c_pad = get_usize(prof, "c_pad")?;
    let t = get_usize(prof, "t")?;
    if c > c_pad || t > T_PAD || !C_VARIANTS.contains(&c_pad) {
        return None;
    }
    let names_json = prof.get("names")?.as_arr()?;
    if names_json.len() != c {
        return None;
    }
    let names: Option<Vec<String>> =
        names_json.iter().map(|j| j.as_str().map(str::to_string)).collect();
    let profile = DesignProfile {
        energy: parse_bits(prof.get("energy"), c_pad)?,
        delay: parse_bits(prof.get("delay"), c_pad)?,
        d_task: parse_bits(prof.get("d_task"), c_pad * T_PAD)?,
        c_comp: parse_bits(prof.get("c_comp"), c_pad * J_PAD)?,
        c_pad,
        c,
        t,
        names: names?,
    };
    // Integrity: the stored payload digest must match a recomputation
    // over what we just parsed — structurally-valid value corruption
    // (a flipped bit digit, an edited name) is rejected here.
    if doc.get("payload")?.as_str()? != payload_digest(&profile) {
        return None;
    }
    Some(profile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, ProfileRequest, TaskMatrix};
    use crate::runtime::profile_request;
    use crate::runtime::HostEngine;
    use crate::testkit::test_dir;

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[3.0, 1.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![1e-3, (i + 1) as f64 * 2e-3],
                    e_dyn: vec![0.01, 0.02],
                    leak_w: 0.1,
                    c_comp: vec![10.0, 20.0 + i as f64],
                })
                .collect(),
            online: vec![1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    fn profile_of(req: &EvalRequest) -> DesignProfile {
        let neutral = ProfileRequest::from_eval(req).to_eval();
        profile_request(&mut HostEngine::new(), &neutral).unwrap()
    }

    #[test]
    fn key_is_stable_and_content_sensitive() {
        let req = request(5);
        let k1 = ProfileCache::key_for_request(&req, "host");
        let k2 = ProfileCache::key_for_request(&req.clone(), "host");
        assert_eq!(k1, k2);
        assert_eq!(k1.hex().len(), 32);

        // Any design-space change moves the key…
        let mut other = request(5);
        other.configs[3].d_k[1] *= 1.0 + 1e-3;
        assert_ne!(k1, ProfileCache::key_for_request(&other, "host"));
        let mut renamed = request(5);
        renamed.configs[0].name = "renamed".into();
        assert_ne!(k1, ProfileCache::key_for_request(&renamed, "host"));
        // …as does the engine label…
        assert_ne!(k1, ProfileCache::key_for_request(&req, "pjrt"));
        // …while scenario knobs do NOT (profiles are scenario-invariant).
        let mut scenario = request(5);
        scenario.lifetime_s = 42.0;
        scenario.beta = 3.0;
        scenario.ci_use_g_per_j = 9e-9;
        scenario.qos = vec![0.25];
        scenario.online = vec![1.0, 0.0];
        assert_eq!(k1, ProfileCache::key_for_request(&scenario, "host"));
    }

    #[test]
    fn store_load_roundtrip_is_bit_exact() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open(&dir).unwrap();
        let req = request(7);
        let mut prof = profile_of(&req);
        // Exercise the full f32 domain, including values plain decimal
        // JSON could not round-trip reliably.
        prof.energy[0] = f32::NAN;
        prof.energy[1] = f32::INFINITY;
        prof.delay[2] = -0.0;
        prof.d_task[3] = f32::MIN_POSITIVE / 2.0; // subnormal

        let key = ProfileCache::key_for_request(&req, "host");
        cache.store(&key, &prof, "host").unwrap();
        let back = cache.load(&key, "host").expect("stored profile loads");
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.energy), bits(&prof.energy));
        assert_eq!(bits(&back.delay), bits(&prof.delay));
        assert_eq!(bits(&back.d_task), bits(&prof.d_task));
        assert_eq!(bits(&back.c_comp), bits(&prof.c_comp));
        assert_eq!(back.names, prof.names);
        assert_eq!((back.c, back.c_pad, back.t), (prof.c, prof.c_pad, prof.t));

        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.writes, s.rejected), (1, 0, 1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn absent_entry_is_a_miss() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open(&dir).unwrap();
        let key = ProfileCache::key_for_request(&request(2), "host");
        assert!(cache.load(&key, "host").is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.rejected), (0, 1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_schema_and_corruption_are_rejected_never_trusted() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open(&dir).unwrap();
        let req = request(3);
        let prof = profile_of(&req);
        let key = ProfileCache::key_for_request(&req, "host");
        let path = dir.join(format!("{}.profile.json", key.hex()));
        cache.store(&key, &prof, "host").unwrap();

        // (a) stale schema version.
        let mut doc = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(o) = &mut doc {
            o.insert("schema".into(), Json::Num(999.0));
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (b) truncated file (invalid JSON).
        let text = encode_envelope(&key, &prof, "host");
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (c) buffer-length mismatch.
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            if let Some(Json::Obj(p)) = o.get_mut("profile") {
                p.insert("energy".into(), Json::Arr(vec![Json::Num(0.0)]));
            }
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (d) non-integral bit value (would have been rounded by the old
        // lenient as_i64 — now rejected).
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            if let Some(Json::Obj(p)) = o.get_mut("profile") {
                if let Some(Json::Arr(xs)) = p.get_mut("energy") {
                    xs[0] = Json::Num(2.7);
                }
            }
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (e) structurally-valid *value* corruption: one energy bit
        // pattern swapped for a different valid integer — only the
        // payload digest catches this.
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            if let Some(Json::Obj(p)) = o.get_mut("profile") {
                if let Some(Json::Arr(xs)) = p.get_mut("energy") {
                    xs[0] = Json::Num(123456.0);
                }
            }
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (f) engine mismatch on an otherwise-valid envelope.
        std::fs::write(&path, &text).unwrap();
        assert!(cache.load(&key, "pjrt").is_none());
        // …and the intact envelope still loads for the right engine.
        assert!(cache.load(&key, "host").is_some());

        let s = cache.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.rejected, 6);
        assert_eq!(s.misses, 6); // every rejection is also a miss
        std::fs::remove_dir_all(&dir).ok();
    }
}
