//! Persistent, content-addressed profile cache — the warm-start layer
//! under the two-phase sweep coordinator.
//!
//! Phase A of the pipeline (the O(C×T×K) engine contraction of a config
//! chunk into a scenario-invariant [`DesignProfile`]) never depends on
//! the scenario, yet every process restart used to re-pay it from
//! scratch. A [`ProfileCache`] keys each *config chunk* by a stable
//! content hash of
//!
//! * the chunk's design-space content at [`ConfigRow`] level (task
//!   matrix, per-config clocks/delays/energies/leakage/embodied rows and
//!   names — exactly the inputs packing and the contraction read;
//!   scenario knobs are excluded by construction, and no packing is
//!   needed to compute a key, so warm lookups never touch the packer),
//! * the artifact-manifest shape constants ([`T_PAD`], [`K_PAD`],
//!   [`J_PAD`], [`NUM_METRICS`], the batch variants),
//! * the engine label (host and PJRT numerics differ), and
//! * the envelope schema version ([`PROFILE_SCHEMA`]).
//!
//! Each entry is stored twice, as two files sharing the key stem:
//!
//! * `<key>.profile.json` — the readable, versioned JSON envelope
//!   (source of truth; every `f32` travels as raw `u32` bit patterns, so
//!   round-trips are **bit-exact**), and
//! * `<key>.profile.bin` — a binary sidecar
//!   ([`crate::configfmt::BinWriter`]) holding the same bits raw with a
//!   digest trailer: the warm-read fast path (~4 bytes per value and a
//!   cursor scan instead of ~10 bytes per value and a JSON parse).
//!
//! Reads consult an **in-memory LRU layer** first (repeated same-process
//! sweeps skip disk entirely), then the sidecar, then the JSON envelope;
//! a valid JSON envelope with a missing or corrupt sidecar is served
//! *and* its sidecar is repaired in place, so legacy JSON-only caches
//! upgrade themselves on first use.
//!
//! The trust model is asymmetric: a stored profile is only ever used
//! when its envelope passes every check (schema version, key echo,
//! engine label, shape constants, buffer lengths, digests). Anything
//! else — truncated file, stale schema, foreign key, wrong shape — is
//! *rejected and recomputed*, never trusted; rejections are counted on
//! the [`CacheStats`] surface. Writes go through a temp-file + rename so
//! a crashed writer can at worst leave a stray temp file, not a
//! half-written envelope under a valid key.
//!
//! With a [`CacheConfig::budget_bytes`] set, the on-disk store is kept
//! under the budget by an LRU/generation-stamped eviction policy:
//! entries touched this process are ranked by access recency, entries
//! only known from disk by their write generation (file mtime), and the
//! oldest are removed first — never the most recently written — with
//! every eviction counted on [`CacheStats::evictions`].
//!
//! The store is safe to share between clients (threads of one service
//! process or whole separate processes on one directory): writers hold
//! a shared advisory lock on `<dir>/.lock` while their files land, and
//! the eviction pass holds it exclusively for its scan+delete window,
//! so it can never observe — let alone delete — half of an in-flight
//! write. An entry whose write generation cannot be read ranks as
//! newest and is never picked as a victim: it could be another client's
//! just-written entry.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::configfmt::{parse, BinReader, BinWriter, ContentHasher, Json};
use crate::matrixform::{
    ConfigRow, DesignProfile, EvalRequest, TaskMatrix, C_VARIANTS, J_PAD, K_PAD, NUM_METRICS,
    T_PAD,
};
use crate::runtime::{CacheCounters, CacheStats};

/// Envelope schema version. Bump on any change to the envelope layout,
/// the key derivation *or* the profile semantics (what the engine
/// contraction computes); older entries are then rejected and recomputed.
/// (v1: packed-tensor keys, JSON-only envelopes. v2: `ConfigRow`-level
/// keys + binary sidecars.)
pub const PROFILE_SCHEMA: u32 = 2;

/// Magic of the binary sidecar envelope.
const SIDECAR_MAGIC: [u8; 4] = *b"XRCP";

/// 128-bit content key of one profile chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    hi: u64,
    lo: u64,
}

impl CacheKey {
    /// Fixed-width lowercase hex rendering (32 chars) — the on-disk
    /// file stem and the envelope's `key` echo.
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }

    /// Parse the fixed-width hex rendering back (file stems → keys).
    pub fn from_hex(s: &str) -> Option<CacheKey> {
        if s.len() != 32 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(CacheKey { hi, lo })
    }
}

impl std::fmt::Display for CacheKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Finish a [`ContentHasher`] into a [`CacheKey`].
fn finish_key(h: ContentHasher) -> CacheKey {
    let (hi, lo) = h.finish128();
    CacheKey { hi, lo }
}

/// Cache behavior knobs (see [`ProfileCache::open_with`]).
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    /// On-disk size budget in bytes over all envelope + sidecar files;
    /// `None` (the default) disables eviction entirely. The budget is a
    /// target, not a hard invariant: the most recently written entry is
    /// never evicted, so a budget smaller than one entry degrades to
    /// "keep exactly the newest".
    pub budget_bytes: Option<u64>,
    /// In-memory LRU capacity in entries (0 disables the memory layer).
    /// Entries are bit-exact copies of what disk holds, so the layer is
    /// transparent to results — it only removes the re-read + re-parse
    /// from repeated same-process lookups.
    pub mem_entries: usize,
    /// Write and consult binary sidecars (default true). `false` forces
    /// the JSON-only legacy behavior — kept for the warm-read benchmark
    /// baseline and as an escape hatch.
    pub binary_sidecars: bool,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { budget_bytes: None, mem_entries: 256, binary_sidecars: true }
    }
}

/// In-memory LRU of validated profiles above the on-disk store.
#[derive(Debug, Default)]
struct MemLru {
    cap: usize,
    tick: u64,
    map: BTreeMap<CacheKey, (u64, DesignProfile)>,
}

impl MemLru {
    fn get(&mut self, key: &CacheKey) -> Option<DesignProfile> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|slot| {
            slot.0 = tick;
            slot.1.clone()
        })
    }

    fn put(&mut self, key: CacheKey, profile: DesignProfile) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        self.map.insert(key, (self.tick, profile));
        while self.map.len() > self.cap {
            let oldest = self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    self.map.remove(&k);
                }
                None => break,
            }
        }
    }
}

/// On-disk accounting for the size-budget eviction policy. `approx` is
/// an overestimate (overwrites are double-counted) that only triggers a
/// rescan; evictions always work off a fresh directory scan.
#[derive(Debug, Default)]
struct DiskTracker {
    approx_bytes: u64,
    scanned: bool,
    /// In-process access recency per key (hits and writes). Entries not
    /// in this map were last touched by an earlier process; eviction
    /// falls back to their write generation (file mtime) — the
    /// "generation-stamped GC" half of the policy.
    touched: BTreeMap<CacheKey, u64>,
    tick: u64,
}

/// On-disk, content-addressed store of [`DesignProfile`]s with an
/// in-memory LRU layer and a thread-safe stats surface. One JSON
/// envelope (+ binary sidecar) per key under `dir`.
#[derive(Debug)]
pub struct ProfileCache {
    dir: PathBuf,
    cfg: CacheConfig,
    counters: CacheCounters,
    mem: Mutex<MemLru>,
    disk: Mutex<DiskTracker>,
}

impl ProfileCache {
    /// Open (creating if needed) a cache directory with default config
    /// (no size budget, 256-entry memory layer, binary sidecars on).
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<ProfileCache> {
        Self::open_with(dir, CacheConfig::default())
    }

    /// Open (creating if needed) a cache directory with explicit knobs.
    pub fn open_with(dir: impl AsRef<Path>, cfg: CacheConfig) -> crate::Result<ProfileCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(ProfileCache {
            dir,
            cfg,
            counters: CacheCounters::new(),
            mem: Mutex::new(MemLru { cap: cfg.mem_entries, ..MemLru::default() }),
            disk: Mutex::new(DiskTracker::default()),
        })
    }

    /// The backing directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The configuration this cache was opened with.
    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    /// Snapshot of this cache's hit/miss/write counters (process
    /// lifetime; use [`CacheStats::since`] for per-run deltas).
    pub fn stats(&self) -> CacheStats {
        self.counters.snapshot()
    }

    /// Content key of one config chunk for one engine. Hashes exactly
    /// the scenario-invariant inputs of the phase-A contraction (at
    /// [`ConfigRow`] resolution — packing is deterministic in these, so
    /// no packed tensors are needed) plus the shape constants and schema
    /// version. The scenario knobs (`online`, `qos`, scalars) are
    /// deliberately excluded, which is what makes one cached profile
    /// serve every scenario overlay.
    pub fn key_for_chunk(tasks: &TaskMatrix, configs: &[ConfigRow], engine: &str) -> CacheKey {
        let mut h = ContentHasher::new();
        h.write(b"xrcarbon-profile");
        h.write_u64(PROFILE_SCHEMA as u64);
        // Artifact-manifest shape constants: a rebuilt artifact set with
        // different padding must never alias old entries.
        for dim in [T_PAD, K_PAD, J_PAD, NUM_METRICS] {
            h.write_u64(dim as u64);
        }
        for v in C_VARIANTS {
            h.write_u64(v as u64);
        }
        h.write_str(engine);
        h.write_u64(tasks.tasks.len() as u64);
        for t in &tasks.tasks {
            h.write_str(t);
        }
        h.write_u64(tasks.kernels.len() as u64);
        for k in &tasks.kernels {
            h.write_str(k);
        }
        h.write_f64s(&tasks.n);
        h.write_u64(configs.len() as u64);
        for c in configs {
            h.write_str(&c.name);
            h.write_u64(c.f_clk.to_bits());
            h.write_f64s(&c.d_k);
            h.write_f64s(&c.e_dyn);
            h.write_u64(c.leak_w.to_bits());
            h.write_f64s(&c.c_comp);
        }
        finish_key(h)
    }

    /// Convenience: key a whole (single-chunk) request.
    pub fn key_for_request(req: &EvalRequest, engine: &str) -> CacheKey {
        Self::key_for_chunk(&req.tasks, &req.configs, engine)
    }

    /// Path of the JSON envelope for `key`.
    pub fn envelope_path(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.profile.json", key.hex()))
    }

    /// Path of the binary sidecar for `key`.
    pub fn sidecar_path(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.profile.bin", key.hex()))
    }

    fn touch(&self, key: &CacheKey) {
        let mut disk = self.disk.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        disk.tick += 1;
        let tick = disk.tick;
        disk.touched.insert(*key, tick);
    }

    /// Look a profile up: memory LRU, then binary sidecar, then JSON
    /// envelope. `Some` only for an entry that passes every validation
    /// check; absent entries and read errors are plain misses, while
    /// corrupted/stale *content* is additionally counted as rejected
    /// (`rejected` means "an envelope was validated and refused", not
    /// "I/O failed") — either way the caller recomputes. A valid JSON
    /// envelope behind a bad/missing sidecar is a hit (the sidecar is
    /// repaired best-effort); a bad sidecar with no valid JSON behind it
    /// is a rejection.
    pub fn load(&self, key: &CacheKey, engine: &str) -> Option<DesignProfile> {
        if self.cfg.mem_entries > 0 {
            let mut mem = self.mem.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Some(profile) = mem.get(key) {
                drop(mem);
                self.counters.record_mem_hit();
                self.touch(key);
                return Some(profile);
            }
        }

        // Fast path: the binary sidecar. `sidecar_seen` distinguishes
        // "no sidecar" (fall through silently) from "sidecar refused"
        // (a rejection if the JSON fallback cannot serve either).
        let mut sidecar_seen = false;
        if self.cfg.binary_sidecars {
            if let Ok(bytes) = std::fs::read(self.sidecar_path(key)) {
                sidecar_seen = true;
                if let Some(profile) = decode_sidecar(&bytes, key, engine) {
                    self.remember(key, &profile);
                    self.counters.record_hit();
                    self.touch(key);
                    return Some(profile);
                }
            }
        }

        // Readable fallback: the JSON envelope.
        match std::fs::read_to_string(self.envelope_path(key)) {
            Ok(text) => match decode_envelope(&text, key, engine) {
                Some(profile) => {
                    // Recency first: a concurrent eviction pass must
                    // rank this entry as freshly used before any repair
                    // bytes land on disk.
                    self.touch(key);
                    if self.cfg.binary_sidecars {
                        // Repair/upgrade the sidecar in place (legacy
                        // JSON-only entries, crashed sidecar writes).
                        // Best-effort: a failure just leaves the slow
                        // path in play. Repair bytes count toward the
                        // size budget like any other write — a fully
                        // warm run over a legacy JSON-only cache must
                        // not grow past the budget unnoticed.
                        let written = {
                            let _dir = self.lock_dir(false);
                            self.write_sidecar(key, &profile, engine).ok()
                        };
                        if let Some(written) = written {
                            self.account_write(written);
                        }
                    }
                    self.remember(key, &profile);
                    self.counters.record_hit();
                    Some(profile)
                }
                None => {
                    self.counters.record_rejected();
                    None
                }
            },
            Err(_) => {
                // NotFound, permissions, transient I/O — nothing JSON
                // was validated. If a sidecar existed and was refused,
                // the entry as a whole was validated-and-refused.
                if sidecar_seen {
                    self.counters.record_rejected();
                } else {
                    self.counters.record_miss();
                }
                None
            }
        }
    }

    fn remember(&self, key: &CacheKey, profile: &DesignProfile) {
        if self.cfg.mem_entries > 0 {
            let mut mem = self.mem.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            mem.put(*key, profile.clone());
        }
    }

    /// Write the binary sidecar for an entry, returning the bytes
    /// written (budget accounting). On the repair path the JSON
    /// envelope's engine echo was just validated against `engine` (and
    /// the key itself binds the engine), so echoing the requested label
    /// is sound.
    fn write_sidecar(
        &self,
        key: &CacheKey,
        profile: &DesignProfile,
        engine: &str,
    ) -> crate::Result<u64> {
        let bytes = encode_sidecar(key, profile, engine);
        atomic_write_bytes(&self.sidecar_path(key), &bytes)?;
        Ok(bytes.len() as u64)
    }

    // xrverify: model(cache_eviction)
    // Fenced: the store window + budget/eviction pass verified
    // exhaustively by tools/xrverify/model_cache.py (every interleaving
    // of two handles over one directory, bounded config). Editing this
    // region without re-reviewing the model is a V001 finding.

    /// Write a profile back under its key: the JSON envelope (source of
    /// truth; temp file + rename, so concurrent readers never observe a
    /// partial envelope) plus the binary sidecar (best-effort — a
    /// missing sidecar only costs speed). Failures of the JSON write are
    /// counted on the stats surface either way, so callers for whom the
    /// cache is an optimization (the sweep) can ignore the error and
    /// degrade to uncached behavior.
    pub fn store(
        &self,
        key: &CacheKey,
        profile: &DesignProfile,
        engine: &str,
    ) -> crate::Result<()> {
        // Recency BEFORE the files become visible on disk: a concurrent
        // worker's eviction pass scanning the directory between our
        // rename and a later touch would otherwise rank this entry as
        // untouched (rank 0) and evict the freshest write first.
        self.touch(key);
        let text = encode_envelope(key, profile, engine);
        let mut written = text.len() as u64;
        {
            // Shared directory lock for the write window: a concurrent
            // eviction pass (exclusive) can never scan or delete while
            // this entry's files are landing.
            let _dir = self.lock_dir(false);
            match atomic_write(&self.envelope_path(key), &text) {
                Ok(()) => self.counters.record_write(),
                Err(e) => {
                    self.counters.record_write_error();
                    return Err(e);
                }
            }
            if self.cfg.binary_sidecars {
                if let Ok(bytes) = self.write_sidecar(key, profile, engine) {
                    written += bytes;
                }
            }
        }
        self.remember(key, profile);
        self.account_write(written);
        Ok(())
    }

    /// Add `bytes` to the approximate on-disk total and run the
    /// eviction policy when it crosses the budget.
    fn account_write(&self, bytes: u64) {
        let Some(budget) = self.cfg.budget_bytes else { return };
        let mut disk = self.disk.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if !disk.scanned {
            // First write of this process: fold pre-existing entries in.
            disk.approx_bytes = scan_entries(&self.dir).iter().map(|e| e.bytes).sum();
            disk.scanned = true;
        }
        disk.approx_bytes += bytes;
        if disk.approx_bytes <= budget {
            return;
        }
        // Over (possibly only approximately — overwrites double-count):
        // rescan for the exact picture, then evict oldest-first. The
        // exclusive directory lock keeps every other client's store out
        // of the scan+delete window, so the scan only ever sees complete
        // entries and a concurrent writer can never lose a file
        // mid-write.
        let _dir = self.lock_dir(true);
        let mut entries = scan_entries(&self.dir);
        let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
        entries.sort_by(|a, b| eviction_order(&disk.touched, a, b));
        let mut idx = 0usize;
        let mut remaining = entries.len();
        while total > budget && remaining > 1 && idx < entries.len() {
            let victim = &entries[idx];
            idx += 1;
            if never_evict(&disk.touched, victim) {
                continue;
            }
            std::fs::remove_file(self.envelope_path(&victim.key)).ok();
            std::fs::remove_file(self.sidecar_path(&victim.key)).ok();
            total = total.saturating_sub(victim.bytes);
            disk.touched.remove(&victim.key);
            self.counters.record_eviction();
            remaining -= 1;
        }
        disk.approx_bytes = total;
    }

    /// Advisory cross-process lock over the cache directory. Writers
    /// take it shared (many stores in flight at once is fine — atomic
    /// temp+rename keeps them from clobbering each other); the eviction
    /// pass takes it exclusive so its scan+delete window can never
    /// interleave with a half-landed write from another client. `None`
    /// inside the guard when the lock could not be taken (an exotic
    /// filesystem): callers proceed unlocked, degrading to the old
    /// single-process behavior rather than failing the operation.
    fn lock_dir(&self, exclusive: bool) -> DirLock {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .truncate(false)
            .read(true)
            .write(true)
            .open(self.dir.join(".lock"))
            .ok();
        let file =
            file.filter(|f| if exclusive { f.lock() } else { f.lock_shared() }.is_ok());
        DirLock { _file: file }
    }
    // xrverify: endmodel(cache_eviction)

    /// Total bytes of envelope + sidecar files currently on disk
    /// (fresh directory scan — test/report surface).
    pub fn disk_bytes(&self) -> u64 {
        scan_entries(&self.dir).iter().map(|e| e.bytes).sum()
    }

    /// Number of distinct entries currently on disk (fresh scan).
    pub fn disk_entries(&self) -> usize {
        scan_entries(&self.dir).len()
    }
}

/// RAII guard for the advisory `.lock` file: the OS lock releases when
/// the handle drops (and with it on process death, so a crashed client
/// can never wedge the directory).
struct DirLock {
    _file: Option<std::fs::File>,
}

/// One on-disk entry (envelope + sidecar) as seen by a directory scan.
struct DiskEntry {
    key: CacheKey,
    bytes: u64,
    /// Newest mtime across the entry's files — its write generation.
    /// `None` when no generation could be read: the entry's age is
    /// unknown, so eviction must assume it was written a moment ago.
    mtime: Option<std::time::SystemTime>,
}

// xrverify: model(cache_eviction)
/// Victim ordering of the eviction pass: in-process recency rank first
/// (untouched entries evict before anything touched this process), then
/// write generation oldest-first — an *unknown* generation ranking
/// newest within its class — then key for determinism.
fn eviction_order(
    touched: &BTreeMap<CacheKey, u64>,
    a: &DiskEntry,
    b: &DiskEntry,
) -> std::cmp::Ordering {
    let ra = touched.get(&a.key).copied().unwrap_or(0);
    let rb = touched.get(&b.key).copied().unwrap_or(0);
    let ga = (a.mtime.is_none(), a.mtime.unwrap_or(std::time::SystemTime::UNIX_EPOCH));
    let gb = (b.mtime.is_none(), b.mtime.unwrap_or(std::time::SystemTime::UNIX_EPOCH));
    ra.cmp(&rb).then(ga.cmp(&gb)).then(a.key.cmp(&b.key))
}

/// A foreign entry (never touched by this process) whose write
/// generation could not be read must be assumed just-written by another
/// client: it is never selected as an eviction victim. (The old policy
/// ranked it at `UNIX_EPOCH` — the *first* victim, exactly wrong.)
fn never_evict(touched: &BTreeMap<CacheKey, u64>, e: &DiskEntry) -> bool {
    e.mtime.is_none() && !touched.contains_key(&e.key)
}
// xrverify: endmodel(cache_eviction)

fn scan_entries(dir: &Path) -> Vec<DiskEntry> {
    let mut map: BTreeMap<CacheKey, DiskEntry> = BTreeMap::new();
    let Ok(rd) = std::fs::read_dir(dir) else { return Vec::new() };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stem = match name.strip_suffix(".profile.json") {
            Some(s) => s,
            None => match name.strip_suffix(".profile.bin") {
                Some(s) => s,
                None => continue, // temp files, foreign files
            },
        };
        let Some(key) = CacheKey::from_hex(stem) else { continue };
        let Ok(meta) = entry.metadata() else { continue };
        let mtime = meta.modified().ok();
        let e = map.entry(key).or_insert(DiskEntry { key, bytes: 0, mtime });
        e.bytes += meta.len();
        e.mtime = match (e.mtime, mtime) {
            (Some(a), Some(b)) => Some(a.max(b)),
            // Any file with an unreadable write generation poisons the
            // whole entry: age unknown, never evict.
            _ => None,
        };
    }
    map.into_values().collect()
}

/// Crash-safe file write shared by the cache, the search checkpoints and
/// the sweep checkpoints: write to a uniquely-named sibling temp file
/// (pid + a process-wide counter, so concurrent writers of the same path
/// never share one), then rename into place — readers can never observe
/// a partial document.
pub(crate) fn atomic_write(path: &Path, text: &str) -> crate::Result<()> {
    atomic_write_bytes(path, text.as_bytes())
}

/// Byte-level flavor of [`atomic_write`] (binary sidecars).
pub(crate) fn atomic_write_bytes(path: &Path, bytes: &[u8]) -> crate::Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".tmp.{}.{seq}", std::process::id()));
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Digest of a rendered envelope body (the document *without* its
/// `digest` member) — shared by the search and sweep checkpoints.
pub(crate) fn body_digest(body: &str) -> String {
    let mut h = ContentHasher::new();
    h.write_str(body);
    h.finish_hex()
}

/// Splice an integrity digest into an already-rendered JSON object
/// document — the render-once counterpart of the old
/// render-hash-rerender cycle. Parse order is irrelevant (objects are
/// `BTreeMap`s), so the member goes right after the opening brace.
pub(crate) fn splice_digest(body: &str) -> String {
    debug_assert!(body.starts_with('{'), "checkpoint body must be a JSON object");
    if body == "{}" {
        return format!("{{\"digest\":\"{}\"}}", body_digest(body));
    }
    format!("{{\"digest\":\"{}\",{}", body_digest(body), &body[1..])
}

/// Remove and verify the `digest` member of a parsed envelope: the
/// stored digest must match a recomputation over the re-rendered
/// remainder (deterministic writer + sorted keys make the round-trip
/// byte-stable), so any post-write edit to the payload is rejected.
pub(crate) fn strip_and_verify_digest(doc: &mut Json, what: &str) -> crate::Result<()> {
    let stored = match doc {
        Json::Obj(o) => o.remove("digest"),
        _ => None,
    }
    .and_then(|d| d.as_str().map(str::to_string))
    .ok_or_else(|| anyhow::anyhow!("{what}: missing or invalid field `digest`"))?;
    if stored != body_digest(&doc.to_string()) {
        anyhow::bail!(
            "{what}: integrity digest mismatch — the file was edited or corrupted; \
             re-run from scratch"
        );
    }
    Ok(())
}

/// `f32` buffer → JSON array of `u32` bit patterns (exact integers).
fn bits_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(x.to_bits() as f64)).collect())
}

/// JSON array of `u32` bit patterns → `f32` buffer of an exact length.
/// `None` on length mismatch, non-integral entries or out-of-`u32`-range
/// values (the strict [`Json::as_i64`] is what makes this safe).
fn parse_bits(v: Option<&Json>, expect_len: usize) -> Option<Vec<f32>> {
    let arr = v?.as_arr()?;
    if arr.len() != expect_len {
        return None;
    }
    arr.iter()
        .map(|j| j.as_i64().and_then(|i| u32::try_from(i).ok()).map(f32::from_bits))
        .collect()
}

fn get_usize(obj: &Json, key: &str) -> Option<usize> {
    obj.get(key)?.as_usize()
}

/// Content digest over the envelope's *payload* (buffers, names, dims):
/// the `key` echo only proves which request the entry claims to serve,
/// while this proves the stored numbers themselves are the ones that
/// were written — a flipped digit in a bit value is structurally valid
/// JSON and would otherwise be trusted.
fn payload_digest(profile: &DesignProfile) -> String {
    let mut h = ContentHasher::new();
    for dim in [profile.c, profile.c_pad, profile.t] {
        h.write_u64(dim as u64);
    }
    h.write_f32s(&profile.energy);
    h.write_f32s(&profile.delay);
    h.write_f32s(&profile.d_task);
    h.write_f32s(&profile.c_comp);
    h.write_u64(profile.names.len() as u64);
    for name in &profile.names {
        h.write_str(name);
    }
    h.finish_hex()
}

/// Render the versioned JSON envelope for one profile.
fn encode_envelope(key: &CacheKey, profile: &DesignProfile, engine: &str) -> String {
    let names = Json::Arr(profile.names.iter().map(|n| Json::Str(n.clone())).collect());
    let doc = Json::obj(vec![
        ("schema", Json::Num(PROFILE_SCHEMA as f64)),
        ("key", Json::Str(key.hex())),
        ("engine", Json::Str(engine.to_string())),
        ("payload", Json::Str(payload_digest(profile))),
        (
            "shape",
            Json::obj(vec![
                ("t_pad", Json::Num(T_PAD as f64)),
                ("j_pad", Json::Num(J_PAD as f64)),
            ]),
        ),
        (
            "profile",
            Json::obj(vec![
                ("c", Json::Num(profile.c as f64)),
                ("c_pad", Json::Num(profile.c_pad as f64)),
                ("t", Json::Num(profile.t as f64)),
                ("names", names),
                ("energy", bits_arr(&profile.energy)),
                ("delay", bits_arr(&profile.delay)),
                ("d_task", bits_arr(&profile.d_task)),
                ("c_comp", bits_arr(&profile.c_comp)),
            ]),
        ),
    ]);
    doc.to_string()
}

/// Parse and fully validate a JSON envelope; `None` means "reject and
/// recompute" (never a panic — cache contents are untrusted input).
fn decode_envelope(text: &str, key: &CacheKey, engine: &str) -> Option<DesignProfile> {
    let doc = parse(text).ok()?;
    if doc.get("schema")?.as_i64()? != PROFILE_SCHEMA as i64 {
        return None;
    }
    if doc.get("key")?.as_str()? != key.hex() {
        return None;
    }
    if doc.get("engine")?.as_str()? != engine {
        return None;
    }
    let shape = doc.get("shape")?;
    if get_usize(shape, "t_pad")? != T_PAD || get_usize(shape, "j_pad")? != J_PAD {
        return None;
    }

    let prof = doc.get("profile")?;
    let c = get_usize(prof, "c")?;
    let c_pad = get_usize(prof, "c_pad")?;
    let t = get_usize(prof, "t")?;
    if c > c_pad || t > T_PAD || !C_VARIANTS.contains(&c_pad) {
        return None;
    }
    let names_json = prof.get("names")?.as_arr()?;
    if names_json.len() != c {
        return None;
    }
    let names: Option<Vec<String>> =
        names_json.iter().map(|j| j.as_str().map(str::to_string)).collect();
    let profile = DesignProfile {
        energy: parse_bits(prof.get("energy"), c_pad)?,
        delay: parse_bits(prof.get("delay"), c_pad)?,
        d_task: parse_bits(prof.get("d_task"), c_pad * T_PAD)?,
        c_comp: parse_bits(prof.get("c_comp"), c_pad * J_PAD)?,
        c_pad,
        c,
        t,
        names: names?,
    };
    // Integrity: the stored payload digest must match a recomputation
    // over what we just parsed — structurally-valid value corruption
    // (a flipped bit digit, an edited name) is rejected here.
    if doc.get("payload")?.as_str()? != payload_digest(&profile) {
        return None;
    }
    Some(profile)
}

/// Render the binary sidecar for one profile: raw little-endian `f32`
/// bits with a whole-envelope digest trailer.
fn encode_sidecar(key: &CacheKey, profile: &DesignProfile, engine: &str) -> Vec<u8> {
    let mut w = BinWriter::new(SIDECAR_MAGIC, PROFILE_SCHEMA);
    w.put_u64(key.hi);
    w.put_u64(key.lo);
    w.put_str(engine);
    w.put_u32(T_PAD as u32);
    w.put_u32(J_PAD as u32);
    w.put_u32(profile.c as u32);
    w.put_u32(profile.c_pad as u32);
    w.put_u32(profile.t as u32);
    w.put_f32_bits(&profile.energy);
    w.put_f32_bits(&profile.delay);
    w.put_f32_bits(&profile.d_task);
    w.put_f32_bits(&profile.c_comp);
    w.put_u32(profile.names.len() as u32);
    for name in &profile.names {
        w.put_str(name);
    }
    w.finish()
}

/// Parse and fully validate a binary sidecar; `None` means "fall back to
/// the JSON envelope" (and reject-and-recompute if that fails too). The
/// digest trailer already proves byte integrity; the field checks prove
/// the envelope belongs to (key, engine) and the current shapes.
fn decode_sidecar(bytes: &[u8], key: &CacheKey, engine: &str) -> Option<DesignProfile> {
    let mut r = BinReader::open(bytes, SIDECAR_MAGIC, PROFILE_SCHEMA)?;
    if r.take_u64()? != key.hi || r.take_u64()? != key.lo {
        return None;
    }
    if r.take_str()? != engine {
        return None;
    }
    if r.take_u32()? as usize != T_PAD || r.take_u32()? as usize != J_PAD {
        return None;
    }
    let c = r.take_u32()? as usize;
    let c_pad = r.take_u32()? as usize;
    let t = r.take_u32()? as usize;
    if c > c_pad || t > T_PAD || !C_VARIANTS.contains(&c_pad) {
        return None;
    }
    let energy = r.take_f32_bits(c_pad)?;
    let delay = r.take_f32_bits(c_pad)?;
    let d_task = r.take_f32_bits(c_pad * T_PAD)?;
    let c_comp = r.take_f32_bits(c_pad * J_PAD)?;
    let n_names = r.take_u32()? as usize;
    if n_names != c {
        return None;
    }
    let mut names = Vec::with_capacity(c);
    for _ in 0..c {
        names.push(r.take_str()?);
    }
    if !r.at_end() {
        return None;
    }
    Some(DesignProfile { energy, delay, d_task, c_comp, c_pad, c, t, names })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, ProfileRequest, TaskMatrix};
    use crate::runtime::profile_request;
    use crate::runtime::HostEngine;
    use crate::testkit::test_dir;

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[3.0, 1.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![1e-3, (i + 1) as f64 * 2e-3],
                    e_dyn: vec![0.01, 0.02],
                    leak_w: 0.1,
                    c_comp: vec![10.0, 20.0 + i as f64],
                })
                .collect(),
            online: vec![1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    fn profile_of(req: &EvalRequest) -> DesignProfile {
        let neutral = ProfileRequest::from_eval(req).to_eval();
        profile_request(&mut HostEngine::new(), &neutral).unwrap()
    }

    /// Config with the memory layer off — unit tests that target the
    /// disk paths must not be masked by same-process memory hits.
    fn no_mem() -> CacheConfig {
        CacheConfig { mem_entries: 0, ..CacheConfig::default() }
    }

    fn assert_profiles_bit_equal(a: &DesignProfile, b: &DesignProfile) {
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.energy), bits(&b.energy));
        assert_eq!(bits(&a.delay), bits(&b.delay));
        assert_eq!(bits(&a.d_task), bits(&b.d_task));
        assert_eq!(bits(&a.c_comp), bits(&b.c_comp));
        assert_eq!(a.names, b.names);
        assert_eq!((a.c, a.c_pad, a.t), (b.c, b.c_pad, b.t));
    }

    #[test]
    fn key_is_stable_and_content_sensitive() {
        let req = request(5);
        let k1 = ProfileCache::key_for_request(&req, "host");
        let k2 = ProfileCache::key_for_request(&req.clone(), "host");
        assert_eq!(k1, k2);
        assert_eq!(k1.hex().len(), 32);
        assert_eq!(CacheKey::from_hex(&k1.hex()), Some(k1));
        assert_eq!(CacheKey::from_hex("nothex"), None);

        // Any design-space change moves the key…
        let mut other = request(5);
        other.configs[3].d_k[1] *= 1.0 + 1e-3;
        assert_ne!(k1, ProfileCache::key_for_request(&other, "host"));
        let mut renamed = request(5);
        renamed.configs[0].name = "renamed".into();
        assert_ne!(k1, ProfileCache::key_for_request(&renamed, "host"));
        let mut energized = request(5);
        energized.configs[2].e_dyn[0] *= 2.0;
        assert_ne!(k1, ProfileCache::key_for_request(&energized, "host"));
        let mut tasked = request(5);
        tasked.tasks.set(0, 0, 4.0);
        assert_ne!(k1, ProfileCache::key_for_request(&tasked, "host"));
        // …as does the engine label…
        assert_ne!(k1, ProfileCache::key_for_request(&req, "pjrt"));
        // …while scenario knobs do NOT (profiles are scenario-invariant).
        let mut scenario = request(5);
        scenario.lifetime_s = 42.0;
        scenario.beta = 3.0;
        scenario.ci_use_g_per_j = 9e-9;
        scenario.qos = vec![0.25];
        scenario.online = vec![1.0, 0.0];
        assert_eq!(k1, ProfileCache::key_for_request(&scenario, "host"));
    }

    #[test]
    fn store_load_roundtrip_is_bit_exact_through_every_layer() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open(&dir).unwrap();
        let req = request(7);
        let mut prof = profile_of(&req);
        // Exercise the full f32 domain, including values plain decimal
        // JSON could not round-trip reliably.
        prof.energy[0] = f32::NAN;
        prof.energy[1] = f32::INFINITY;
        prof.delay[2] = -0.0;
        prof.d_task[3] = f32::MIN_POSITIVE / 2.0; // subnormal

        let key = ProfileCache::key_for_request(&req, "host");
        cache.store(&key, &prof, "host").unwrap();

        // (1) Same-process load: served by the memory LRU.
        let back = cache.load(&key, "host").expect("stored profile loads");
        assert_profiles_bit_equal(&back, &prof);
        let s = cache.stats();
        assert_eq!((s.hits, s.mem_hits, s.misses, s.writes, s.rejected), (1, 1, 0, 1, 0));

        // (2) Fresh instance (cold memory): served by the binary sidecar.
        let fresh = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let back = fresh.load(&key, "host").expect("sidecar loads");
        assert_profiles_bit_equal(&back, &prof);

        // (3) Sidecar deleted: served by the JSON fallback, bit-exact,
        // and the sidecar is repaired in place.
        std::fs::remove_file(fresh.sidecar_path(&key)).unwrap();
        let fresh2 = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let back = fresh2.load(&key, "host").expect("json fallback loads");
        assert_profiles_bit_equal(&back, &prof);
        assert!(fresh2.sidecar_path(&key).exists(), "sidecar repaired after fallback");
        let s = fresh2.stats();
        assert_eq!((s.hits, s.mem_hits, s.rejected), (1, 0, 0));
        // …and each fresh instance saw exactly one (disk) hit.
        assert_eq!((fresh.stats().hits, fresh.stats().mem_hits), (1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn memory_layer_survives_disk_loss_and_is_bounded() {
        let dir = test_dir("cache_unit");
        let cache =
            ProfileCache::open_with(&dir, CacheConfig { mem_entries: 2, ..CacheConfig::default() })
                .unwrap();
        let reqs: Vec<EvalRequest> = (0..3).map(|i| request(3 + i)).collect();
        let keys: Vec<CacheKey> =
            reqs.iter().map(|r| ProfileCache::key_for_request(r, "host")).collect();
        let profs: Vec<DesignProfile> = reqs.iter().map(profile_of).collect();
        for (k, p) in keys.iter().zip(&profs) {
            cache.store(k, p, "host").unwrap();
        }
        // Disk wiped: the two most recently stored entries still serve
        // from memory (bit-exact); the first was LRU-evicted from the
        // bounded memory layer and is now a miss.
        for k in &keys {
            std::fs::remove_file(cache.envelope_path(k)).unwrap();
            std::fs::remove_file(cache.sidecar_path(k)).unwrap();
        }
        assert!(cache.load(&keys[0], "host").is_none(), "mem layer holds only 2 entries");
        for i in [1usize, 2] {
            let back = cache.load(&keys[i], "host").expect("served from memory");
            assert_profiles_bit_equal(&back, &profs[i]);
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.mem_hits, s.misses), (2, 2, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn absent_entry_is_a_miss() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open(&dir).unwrap();
        let key = ProfileCache::key_for_request(&request(2), "host");
        assert!(cache.load(&key, "host").is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.rejected), (0, 1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_schema_and_corruption_are_rejected_never_trusted() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let req = request(3);
        let prof = profile_of(&req);
        let key = ProfileCache::key_for_request(&req, "host");
        let path = cache.envelope_path(&key);
        cache.store(&key, &prof, "host").unwrap();
        // These cases target the JSON envelope; drop the sidecar so the
        // fast path cannot mask the corruption (the load's repair step
        // would resurrect it, so it is re-deleted per case).
        let drop_sidecar = || {
            std::fs::remove_file(cache.sidecar_path(&key)).ok();
        };
        drop_sidecar();

        // (a) stale schema version.
        let mut doc = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(o) = &mut doc {
            o.insert("schema".into(), Json::Num(999.0));
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (b) truncated file (invalid JSON).
        let text = encode_envelope(&key, &prof, "host");
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (c) buffer-length mismatch.
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            if let Some(Json::Obj(p)) = o.get_mut("profile") {
                p.insert("energy".into(), Json::Arr(vec![Json::Num(0.0)]));
            }
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (d) non-integral bit value (would have been rounded by the old
        // lenient as_i64 — now rejected).
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            if let Some(Json::Obj(p)) = o.get_mut("profile") {
                if let Some(Json::Arr(xs)) = p.get_mut("energy") {
                    xs[0] = Json::Num(2.7);
                }
            }
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (e) structurally-valid *value* corruption: one energy bit
        // pattern swapped for a different valid integer — only the
        // payload digest catches this.
        let mut doc = parse(&text).unwrap();
        if let Json::Obj(o) = &mut doc {
            if let Some(Json::Obj(p)) = o.get_mut("profile") {
                if let Some(Json::Arr(xs)) = p.get_mut("energy") {
                    xs[0] = Json::Num(123456.0);
                }
            }
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(cache.load(&key, "host").is_none());

        // (f) engine mismatch on an otherwise-valid envelope.
        std::fs::write(&path, &text).unwrap();
        assert!(cache.load(&key, "pjrt").is_none());
        // …and the intact envelope still loads for the right engine
        // (which also repairs the sidecar).
        assert!(cache.load(&key, "host").is_some());
        assert!(cache.sidecar_path(&key).exists());

        let s = cache.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.rejected, 6);
        assert_eq!(s.misses, 6); // every rejection is also a miss
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_sidecar_falls_back_to_json_and_repairs() {
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let req = request(4);
        let prof = profile_of(&req);
        let key = ProfileCache::key_for_request(&req, "host");
        cache.store(&key, &prof, "host").unwrap();
        let bin = cache.sidecar_path(&key);

        // Truncated, bit-flipped and garbage sidecars all fall back to
        // the (intact) JSON envelope: still a hit, bit-exact, repaired.
        let good = std::fs::read(&bin).unwrap();
        for variant in 0..3 {
            let bad = match variant {
                0 => good[..good.len() / 2].to_vec(),
                1 => {
                    let mut b = good.clone();
                    b[20] ^= 0xFF;
                    b
                }
                _ => b"not a sidecar".to_vec(),
            };
            std::fs::write(&bin, &bad).unwrap();
            let back = cache.load(&key, "host").expect("json fallback");
            assert_profiles_bit_equal(&back, &prof);
            let repaired = std::fs::read(&bin).unwrap();
            assert_eq!(repaired, good, "sidecar repaired to canonical bytes");
        }
        // A bad sidecar with the JSON envelope gone is a rejection.
        std::fs::write(&bin, b"junk").unwrap();
        std::fs::remove_file(cache.envelope_path(&key)).unwrap();
        assert!(cache.load(&key, "host").is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.rejected, s.misses), (3, 1, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eviction_keeps_disk_under_budget_and_spares_recent_entries() {
        let dir = test_dir("cache_unit");
        // Probe one entry's footprint, then budget for about two.
        let probe = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let req0 = request(1);
        let key0 = ProfileCache::key_for_request(&req0, "host");
        probe.store(&key0, &profile_of(&req0), "host").unwrap();
        let per_entry = probe.disk_bytes();
        assert!(per_entry > 0);
        std::fs::remove_dir_all(&dir).ok();

        let budget = per_entry * 5 / 2; // fits 2, not 3
        let cache = ProfileCache::open_with(
            &dir,
            CacheConfig { budget_bytes: Some(budget), mem_entries: 0, ..CacheConfig::default() },
        )
        .unwrap();
        // Same shape, distinct content (distinct keys, ~equal sizes).
        let reqs: Vec<EvalRequest> = (0..5)
            .map(|i| {
                let mut r = request(1);
                r.configs[0].d_k[0] = 1e-3 * (i + 1) as f64;
                r
            })
            .collect();
        let keys: Vec<CacheKey> =
            reqs.iter().map(|r| ProfileCache::key_for_request(r, "host")).collect();
        for (k, r) in keys.iter().zip(&reqs) {
            cache.store(k, &profile_of(r), "host").unwrap();
        }
        // Budget respected (within the one-entry slack the policy
        // guarantees), evictions counted and the newest entry survives.
        assert!(cache.disk_bytes() <= budget, "{} > {budget}", cache.disk_bytes());
        assert!(cache.disk_entries() < 5);
        assert!(cache.envelope_path(&keys[4]).exists(), "newest entry never evicted");
        let s = cache.stats();
        assert_eq!(s.evictions, 5 - cache.disk_entries());
        assert!(s.evictions >= 3, "expected ≥3 evictions, got {}", s.evictions);
        // Evicted entries are plain misses; surviving ones still load.
        assert!(cache.load(&keys[4], "host").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unreadable_write_generation_ranks_newest_and_is_never_a_victim() {
        // Regression for the eviction-order bug: a metadata/mtime read
        // failure used to rank an entry at UNIX_EPOCH — the *first*
        // eviction victim, exactly wrong for a just-written entry from
        // another process. Unknown generation must rank newest within
        // its recency class and never be picked at all.
        let k = |lo: u64| CacheKey { hi: 0, lo };
        let t = |s: u64| std::time::SystemTime::UNIX_EPOCH + std::time::Duration::from_secs(s);
        let touched: BTreeMap<CacheKey, u64> = BTreeMap::new();
        let mut entries = vec![
            DiskEntry { key: k(1), bytes: 10, mtime: None },
            DiskEntry { key: k(2), bytes: 10, mtime: Some(t(2_000_000)) },
            DiskEntry { key: k(3), bytes: 10, mtime: Some(t(1_000_000)) },
        ];
        entries.sort_by(|a, b| eviction_order(&touched, a, b));
        let order: Vec<u64> = entries.iter().map(|e| e.key.lo).collect();
        assert_eq!(order, vec![3, 2, 1], "unknown generation sorts newest, not oldest");
        assert!(never_evict(&touched, &entries[2]), "unknown foreign entry is protected");
        assert!(!never_evict(&touched, &entries[0]), "known-old entries stay evictable");
        // An entry this process touched is rankable by its recency tick
        // even if its mtime read failed — it stays evictable.
        let touched: BTreeMap<CacheKey, u64> = [(k(1), 7u64)].into_iter().collect();
        assert!(!never_evict(&touched, &entries[2]));
    }

    #[test]
    fn scan_merges_unknown_generation_as_poisoning() {
        // scan_entries merges per-file mtimes into one entry-level
        // generation; a None from either file must poison the pair.
        let dir = test_dir("cache_unit");
        let cache = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let req = request(2);
        let key = ProfileCache::key_for_request(&req, "host");
        cache.store(&key, &profile_of(&req), "host").unwrap();
        let entries = scan_entries(&dir);
        assert_eq!(entries.len(), 1);
        assert!(entries[0].mtime.is_some(), "healthy files carry a generation");
        assert!(entries[0].bytes > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_entries_evict_by_write_generation_oldest_first() {
        let dir = test_dir("cache_unit");
        // A writer lays down three entries and back-dates two, standing
        // in for older processes' writes.
        let writer = ProfileCache::open_with(&dir, no_mem()).unwrap();
        let reqs: Vec<EvalRequest> = (0..3)
            .map(|i| {
                let mut r = request(1);
                r.configs[0].d_k[0] = 1e-3 * (i + 1) as f64;
                r
            })
            .collect();
        let keys: Vec<CacheKey> =
            reqs.iter().map(|r| ProfileCache::key_for_request(r, "host")).collect();
        for (k, r) in keys.iter().zip(&reqs) {
            writer.store(k, &profile_of(r), "host").unwrap();
        }
        let per_entry = writer.disk_bytes() / 3;
        let now = std::time::SystemTime::now();
        for (i, k) in keys.iter().enumerate().take(2) {
            let old = now - std::time::Duration::from_secs(3600 * (2 - i as u64));
            for p in [writer.envelope_path(k), writer.sidecar_path(k)] {
                std::fs::File::options().write(true).open(p).unwrap().set_modified(old).unwrap();
            }
        }
        // A second handle (fresh recency map — a new process as far as
        // eviction ranking goes) stores one more entry under a budget
        // that fits two: the back-dated foreign entries go first,
        // oldest first, and the handle's own just-written entry — plus
        // the freshest foreign one — survive.
        let budget = per_entry * 5 / 2;
        let b = ProfileCache::open_with(
            &dir,
            CacheConfig { budget_bytes: Some(budget), mem_entries: 0, ..CacheConfig::default() },
        )
        .unwrap();
        let mut r3 = request(1);
        r3.configs[0].d_k[0] = 5e-3;
        let k3 = ProfileCache::key_for_request(&r3, "host");
        b.store(&k3, &profile_of(&r3), "host").unwrap();
        assert!(b.envelope_path(&k3).exists(), "own just-written entry survives");
        assert!(!b.envelope_path(&keys[0]).exists(), "oldest foreign entry evicted first");
        assert!(!b.envelope_path(&keys[1]).exists(), "next-oldest foreign entry evicted second");
        assert!(b.envelope_path(&keys[2]).exists(), "freshest foreign entry spared");
        assert!(b.disk_bytes() <= budget);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn digest_splice_roundtrips_and_detects_tampering() {
        let body = Json::obj(vec![("a", Json::Num(1.0)), ("z", Json::Str("x".into()))])
            .to_string();
        let doc_text = splice_digest(&body);
        let mut doc = parse(&doc_text).unwrap();
        strip_and_verify_digest(&mut doc, "test").expect("intact envelope verifies");
        assert_eq!(doc.to_string(), body, "stripping the digest restores the body");
        // Tampering with any member breaks verification.
        let mut tampered = parse(&doc_text).unwrap();
        if let Json::Obj(o) = &mut tampered {
            o.insert("a".into(), Json::Num(2.0));
        }
        let mut reparsed = parse(&tampered.to_string()).unwrap();
        assert!(strip_and_verify_digest(&mut reparsed, "test").is_err());
        // A digest-less document is refused outright.
        let mut bare = parse(&body).unwrap();
        assert!(strip_and_verify_digest(&mut bare, "test").is_err());
    }
}
