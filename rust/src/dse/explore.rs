//! End-to-end exploration: evaluate a request, apply constraints, extract
//! optima per figure-of-merit and distribution statistics (the bars, dots
//! and error bars of Fig 7).

use std::collections::HashMap;

use crate::carbon::MetricKind;
use crate::matrixform::{EvalRequest, EvalResult, MetricRow};
use crate::runtime::Engine;

use super::batching::evaluate_chunked;

/// Distribution statistics of the tCDP across feasible designs.
#[derive(Debug, Clone, Copy)]
pub struct ExploreStats {
    /// Lowest (best) tCDP.
    pub best: f64,
    /// Mean tCDP.
    pub mean: f64,
    /// 5th percentile.
    pub p5: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Number of feasible designs.
    pub feasible: usize,
}

/// Outcome of one exploration run.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    /// Raw per-config results.
    pub result: EvalResult,
    /// Feasible argmin per figure-of-merit.
    pub optimal: HashMap<&'static str, usize>,
    /// tCDP distribution statistics.
    pub stats: ExploreStats,
}

/// Map a [`MetricKind`] onto its runtime metrics row.
pub fn metric_row(kind: MetricKind) -> MetricRow {
    match kind {
        MetricKind::Edp => MetricRow::Edp,
        MetricKind::Cdp => MetricRow::Cdp,
        MetricKind::Cep => MetricRow::Cep,
        MetricKind::Ce2p => MetricRow::Ce2p,
        MetricKind::C2ep => MetricRow::C2ep,
        MetricKind::Tcdp => MetricRow::Tcdp,
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Run the exploration.
pub fn explore(engine: &mut dyn Engine, req: &EvalRequest) -> crate::Result<ExploreOutcome> {
    let result = evaluate_chunked(engine, req)?;
    Ok(summarize(result))
}

/// Constraint-aware summary of an evaluated space: feasible argmin per
/// figure-of-merit plus tCDP distribution statistics. Shared by the
/// sequential [`explore`] path and the parallel sweep coordinator
/// ([`super::sweep`]), so both produce identical outcomes for identical
/// evaluation results.
pub fn summarize(result: EvalResult) -> ExploreOutcome {
    let mut optimal = HashMap::new();
    for kind in MetricKind::ALL {
        if let Some(idx) = result.argmin_feasible(metric_row(kind)) {
            optimal.insert(kind.label(), idx);
        }
    }

    let feas = result.row(MetricRow::Feasible).to_vec();
    let tcdp = result.row(MetricRow::Tcdp);
    let mut feasible_tcdp: Vec<f64> = tcdp
        .iter()
        .zip(&feas)
        .filter(|(_, &f)| f > 0.5)
        .map(|(&v, _)| v)
        .collect();
    feasible_tcdp.sort_by(|a, b| a.total_cmp(b));

    let stats = ExploreStats {
        best: feasible_tcdp.first().copied().unwrap_or(f64::NAN),
        mean: if feasible_tcdp.is_empty() {
            f64::NAN
        } else {
            feasible_tcdp.iter().sum::<f64>() / feasible_tcdp.len() as f64
        },
        p5: percentile(&feasible_tcdp, 0.05),
        p95: percentile(&feasible_tcdp, 0.95),
        feasible: feasible_tcdp.len(),
    };

    ExploreOutcome { result, optimal, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, TaskMatrix};
    use crate::runtime::HostEngine;

    fn request() -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k".into()], &[10.0]);
        // Three designs: cheap-slow, balanced, fast-expensive.
        let mk = |name: &str, d: f64, e: f64, emb: f64| ConfigRow {
            name: name.into(),
            f_clk: 1e9,
            d_k: vec![d],
            e_dyn: vec![e],
            leak_w: 0.0,
            c_comp: vec![emb],
        };
        EvalRequest {
            tasks: tm,
            configs: vec![
                mk("cheap", 8e-3, 0.02, 20.0),
                mk("balanced", 3e-3, 0.03, 400.0),
                mk("fast", 1e-3, 0.06, 1600.0),
            ],
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-2,
            lifetime_s: 10.0,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    #[test]
    fn optima_and_stats_populated() {
        let out = explore(&mut HostEngine::new(), &request()).unwrap();
        assert_eq!(out.stats.feasible, 3);
        assert!(out.stats.best <= out.stats.mean);
        assert!(out.stats.p5 <= out.stats.p95);
        for kind in MetricKind::ALL {
            assert!(out.optimal.contains_key(kind.label()), "{} missing", kind.label());
        }
    }

    #[test]
    fn edp_and_tcdp_optima_can_differ() {
        // The Fig 1 phenomenon: fastest design wins EDP; carbon-aware
        // metrics prefer the cheaper silicon.
        let out = explore(&mut HostEngine::new(), &request()).unwrap();
        let edp_idx = out.optimal["EDP"];
        assert_eq!(out.result.names[edp_idx], "fast");
        let cdp_idx = out.optimal["CDP"];
        assert_ne!(out.result.names[cdp_idx], "fast");
    }

    #[test]
    fn infeasible_configs_excluded_from_stats() {
        let mut req = request();
        req.qos = vec![0.05]; // cheap (0.08) fails QoS
        let out = explore(&mut HostEngine::new(), &req).unwrap();
        assert_eq!(out.stats.feasible, 2);
        let best_idx = out.optimal["tCDP"];
        assert_ne!(out.result.names[best_idx], "cheap");
    }
}
