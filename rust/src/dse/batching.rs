//! Splitting oversized requests across artifact batch variants and
//! merging the results back in order — for both the fused evaluation
//! path ([`evaluate_chunked`]) and phase A of the two-phase pipeline
//! ([`profile_chunked`], [`profile_chunk_requests`]).

use crate::matrixform::{
    ConfigRow, DesignProfile, EvalRequest, EvalResult, ProfileRequest, TaskMatrix, NUM_METRICS,
};
use crate::runtime::{evaluate, profile_request, Engine};

/// Largest single-batch size any artifact variant supports.
pub const MAX_BATCH: usize = 1024;
/// Small artifact variant, used as the chunk size for mid-sized requests.
pub const SMALL_BATCH: usize = 128;

/// Padding-aware chunk size: mid-sized requests run as several
/// small-variant batches instead of one mostly-padding large batch
/// (measured: 200 configs = 0.36 ms chunked vs 0.90 ms padded to 1024;
/// ≥~700 configs the large variant wins back — see EXPERIMENTS.md §Perf).
/// Well-defined for `n = 0` (returns [`SMALL_BATCH`]; the chunkers emit
/// zero chunks for an empty space, so the value is never dereferenced).
pub(crate) fn chunk_size(n: usize) -> usize {
    if n <= SMALL_BATCH || n > MAX_BATCH {
        // Single small batch, or big sweeps: fill the large variant.
        if n <= SMALL_BATCH {
            SMALL_BATCH
        } else {
            MAX_BATCH
        }
    } else if n <= 4 * SMALL_BATCH {
        SMALL_BATCH
    } else {
        MAX_BATCH
    }
}

/// Evaluate a request of any size, chunking across engine calls when the
/// config count exceeds (or poorly fits) the artifact variants.
pub fn evaluate_chunked(engine: &mut dyn Engine, req: &EvalRequest) -> crate::Result<EvalResult> {
    if req.configs.is_empty() {
        // Zero configs means zero engine calls and an empty result — not
        // a panic inside request validation/packing. The config-free half
        // of `EvalRequest::validate` still applies (the component
        // dimension J is defined by the config rows, so the online mask
        // cannot be checked here).
        assert_eq!(req.qos.len(), req.tasks.num_tasks(), "qos len != tasks");
        assert!(req.lifetime_s > 0.0, "non-positive lifetime");
        assert!(req.beta >= 0.0, "negative beta");
        return Ok(EvalResult::empty(req.tasks.num_tasks()));
    }
    let max_batch = chunk_size(req.configs.len());
    if req.configs.len() <= max_batch {
        return evaluate(engine, req);
    }
    let mut merged: Option<EvalResult> = None;
    for chunk in req.configs.chunks(max_batch) {
        let sub = EvalRequest { configs: chunk.to_vec(), ..shallow(req) };
        let res = evaluate(engine, &sub)?;
        merged = Some(match merged {
            None => res,
            Some(acc) => merge(acc, res),
        });
    }
    Ok(merged.expect("nonempty request"))
}

/// Number of engine-call chunks a space of `n` configs splits into
/// (zero for an empty space).
pub(crate) fn num_chunks(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let cs = chunk_size(n);
    if n <= cs {
        1
    } else {
        n.div_ceil(cs)
    }
}

/// Engine-call chunk boundaries of a space of `n` configs, as index
/// ranges — the same boundaries [`evaluate_chunked`] and
/// [`chunk_neutral`] use, without materializing any request. The sweep
/// coordinator keys chunks off these ranges so warm lookups clone no
/// configs at all.
pub(crate) fn chunk_ranges(n: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let cs = chunk_size(n);
    if n <= cs {
        return vec![0..n];
    }
    (0..n).step_by(cs).map(|start| start..(start + cs).min(n)).collect()
}

/// Phase A chunk list: the scenario-invariant space split at exactly the
/// engine-call boundaries [`evaluate_chunked`] uses, each as a neutral
/// packed-ready request (scenario knobs inert — profiling only reads the
/// design-space tensors). Keeping the boundaries identical is what makes
/// per-chunk overlay merges bit-identical to the fused chunked path.
pub fn profile_chunk_requests(req: &ProfileRequest) -> Vec<EvalRequest> {
    chunk_neutral(&req.tasks, &req.configs)
}

/// Shared phase-A chunker over a borrowed space — exactly one config
/// clone per chunk (the sweep coordinator feeds `base` in directly
/// without materializing an owned [`ProfileRequest`] first).
pub(crate) fn chunk_neutral(tasks: &TaskMatrix, configs: &[ConfigRow]) -> Vec<EvalRequest> {
    if configs.is_empty() {
        // An empty space profiles into zero chunks (mirrors
        // `num_chunks(0) == 0`); callers fold nothing instead of
        // panicking on a zero-config engine batch.
        return Vec::new();
    }
    let shell = ProfileRequest { tasks: tasks.clone(), configs: Vec::new() };
    let cs = chunk_size(configs.len());
    if configs.len() <= cs {
        return vec![shell.chunk_eval(configs.to_vec())];
    }
    configs.chunks(cs).map(|chunk| shell.chunk_eval(chunk.to_vec())).collect()
}

/// Profile an arbitrary-size space on one engine: one scenario-invariant
/// [`DesignProfile`] per chunk, in request order. Scenario overlays apply
/// per chunk and merge left-to-right (see `dse::sweep`).
pub fn profile_chunked(
    engine: &mut dyn Engine,
    req: &ProfileRequest,
) -> crate::Result<Vec<DesignProfile>> {
    profile_chunk_requests(req)
        .iter()
        .map(|r| profile_request(engine, r))
        .collect()
}

/// Clone everything but the config rows (chunk builders fill those in).
pub(crate) fn shallow(req: &EvalRequest) -> EvalRequest {
    EvalRequest {
        tasks: req.tasks.clone(),
        configs: Vec::new(),
        online: req.online.clone(),
        qos: req.qos.clone(),
        ci_use_g_per_j: req.ci_use_g_per_j,
        lifetime_s: req.lifetime_s,
        beta: req.beta,
        p_max_w: req.p_max_w,
    }
}

/// Concatenate two results in order (row-major metric rows re-packed).
pub(crate) fn merge(a: EvalResult, b: EvalResult) -> EvalResult {
    assert_eq!(a.t, b.t, "task-count mismatch in merge");
    let c = a.c + b.c;
    let mut metrics = vec![0.0f64; NUM_METRICS * c];
    for row in 0..NUM_METRICS {
        metrics[row * c..row * c + a.c].copy_from_slice(&a.metrics[row * a.c..(row + 1) * a.c]);
        metrics[row * c + a.c..(row + 1) * c]
            .copy_from_slice(&b.metrics[row * b.c..(row + 1) * b.c]);
    }
    let mut d_task = a.d_task.clone();
    d_task.extend_from_slice(&b.d_task);
    let mut names = a.names.clone();
    names.extend(b.names.iter().cloned());
    EvalResult { names, metrics, d_task, c, t: a.t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, MetricRow, TaskMatrix};
    use crate::runtime::HostEngine;

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k".into()], &[2.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![(i + 1) as f64 * 1e-3],
                    e_dyn: vec![0.01],
                    leak_w: 0.0,
                    c_comp: vec![100.0],
                })
                .collect(),
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    #[test]
    fn chunked_matches_unchunked_ordering() {
        // 2500 configs -> 3 chunks; delays must stay in request order.
        let req = request(2500);
        let res = evaluate_chunked(&mut HostEngine::new(), &req).unwrap();
        assert_eq!(res.c, 2500);
        for i in [0usize, 1023, 1024, 2047, 2048, 2499] {
            let d = res.metric(MetricRow::Delay, i);
            let expect = 2.0 * (i + 1) as f64 * 1e-3;
            assert!((d - expect).abs() < expect * 1e-5, "i={i} d={d} expect={expect}");
            assert_eq!(res.names[i], format!("cfg{i}"));
        }
    }

    #[test]
    fn empty_request_yields_empty_result_without_engine_calls() {
        // Regression: this used to panic inside request validation.
        let mut req = request(0);
        assert!(req.configs.is_empty());
        let res = evaluate_chunked(&mut HostEngine::new(), &req).unwrap();
        assert_eq!(res.c, 0);
        assert_eq!(res.t, 1);
        assert!(res.names.is_empty() && res.metrics.is_empty() && res.d_task.is_empty());
        assert_eq!(res.argmin_feasible(MetricRow::Tcdp), None);

        let preq = ProfileRequest::from_eval(&req);
        assert!(profile_chunk_requests(&preq).is_empty());
        let profiles = profile_chunked(&mut HostEngine::new(), &preq).unwrap();
        assert!(profiles.is_empty());

        // The summary layer composes with the empty result.
        let out = crate::dse::explore::summarize(res);
        assert_eq!(out.stats.feasible, 0);
        assert!(out.optimal.is_empty());

        // Shared-shell variant exercised through chunk_neutral directly.
        req.configs.clear();
        assert!(chunk_neutral(&req.tasks, &req.configs).is_empty());
    }

    #[test]
    fn zero_size_chunk_helpers_are_well_defined() {
        assert_eq!(num_chunks(0), 0);
        assert_eq!(chunk_size(0), SMALL_BATCH);
        assert_eq!(num_chunks(1), 1);
        assert_eq!(num_chunks(SMALL_BATCH), 1);
        assert_eq!(num_chunks(MAX_BATCH + 1), 2);
    }

    #[test]
    fn chunk_ranges_match_chunk_neutral_boundaries() {
        assert!(chunk_ranges(0).is_empty());
        for n in [1usize, 7, SMALL_BATCH, SMALL_BATCH + 1, 4 * SMALL_BATCH, 2500] {
            let ranges = chunk_ranges(n);
            assert_eq!(ranges.len(), num_chunks(n), "n={n}");
            // Contiguous cover of 0..n in order.
            assert_eq!(ranges.first().unwrap().start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "n={n}");
            }
            // Same boundaries the request chunker produces.
            let req = request(n);
            let chunks = chunk_neutral(&req.tasks, &req.configs);
            assert_eq!(chunks.len(), ranges.len(), "n={n}");
            for (r, c) in ranges.iter().zip(&chunks) {
                assert_eq!(c.configs.len(), r.len(), "n={n}");
                assert_eq!(c.configs[0].name, format!("cfg{}", r.start), "n={n}");
            }
        }
    }

    #[test]
    fn small_requests_take_single_batch() {
        let req = request(7);
        let res = evaluate_chunked(&mut HostEngine::new(), &req).unwrap();
        assert_eq!(res.c, 7);
        assert_eq!(res.names.len(), 7);
    }

    #[test]
    fn profile_chunks_share_fused_boundaries() {
        // 2500 configs -> 3 chunks of 1024/1024/452, names in order.
        let req = request(2500);
        let preq = ProfileRequest::from_eval(&req);
        let chunks = profile_chunk_requests(&preq);
        assert_eq!(chunks.len(), 3);
        assert_eq!(num_chunks(2500), 3);
        assert_eq!(chunks[0].configs.len(), 1024);
        assert_eq!(chunks[2].configs.len(), 452);
        assert_eq!(chunks[1].configs[0].name, "cfg1024");
        assert_eq!(num_chunks(7), 1);

        let profiles = profile_chunked(&mut HostEngine::new(), &preq).unwrap();
        assert_eq!(profiles.len(), 3);
        assert_eq!(profiles[0].c, 1024);
        assert_eq!(profiles[2].c, 452);
        assert_eq!(profiles[2].names[0], "cfg2048");
        // Per-config delay survives the profile path: d = 2 * (i+1) ms.
        let d = profiles[0].delay[3] as f64;
        let expect = 2.0 * 4.0 * 1e-3;
        assert!((d - expect).abs() < expect * 1e-5, "d={d}");
    }
}
