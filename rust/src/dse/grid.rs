//! Scenario grids: labeled cross-products of sweep axes.
//!
//! The paper's headline results come from re-running the same design
//! space under many *scenarios* — carbon-intensity grids, lifetimes, QoS
//! targets, β weights, power caps. A [`ScenarioGrid`] declares one axis
//! per knob; its cross-product enumerates every [`SweepScenario`], each
//! of which rewrites a base [`EvalRequest`] without touching the design
//! space itself. Empty axes inherit the base request's value and
//! contribute nothing to the scenario label, so a default grid has
//! exactly one scenario: the base request.
//!
//! Named presets reproduce the paper's sweeps: [`ScenarioGrid::fig7`]
//! (embodied-share scenarios as lifetime calibrations),
//! [`ScenarioGrid::lifetime_decades`] (the Fig 10 operational-lifetime
//! axis) and [`ScenarioGrid::fig11`] (provisioning lifetimes × QoS
//! on/off), plus [`ScenarioGrid::use_grids`] for CI diversity.
//!
//! Since PR 6 a grid also carries a **trace axis** ([`TracePoint`]): a
//! scenario may hold a time-varying [`CiTrace`] instead of a static CI.
//! The sweep coordinator expands such a scenario via
//! [`SweepScenario::lower`] into one per-segment scenario per trace
//! segment (each a plain `ci_use` override) and recombines the
//! per-segment results with `carbon::combine_segments` — see DESIGN.md
//! §3.4. The trace axis nests innermost, so grids without traces
//! enumerate exactly as before.

use crate::carbon::{CiTrace, UseGrid};
use crate::matrixform::{ConfigRow, EvalRequest, TaskMatrix};

use super::scenario::lifetime_for_ratio;

/// Seconds in a calendar year (provisioning-study lifetimes).
pub const YEAR_S: f64 = 365.0 * 24.0 * 3600.0;

/// One labeled point on a sweep axis.
#[derive(Debug, Clone)]
pub struct AxisPoint {
    /// Short label, unique within its axis ("98% embodied", "LT=1e6s").
    pub label: String,
    /// The axis value (unit depends on the axis).
    pub value: f64,
}

impl AxisPoint {
    /// New labeled point.
    pub fn new(label: &str, value: f64) -> Self {
        AxisPoint { label: label.to_string(), value }
    }
}

/// One labeled point on the trace axis: a named time-varying CI trace.
#[derive(Debug, Clone)]
pub struct TracePoint {
    /// Short label, unique within the axis ("trace=diurnal-world").
    pub label: String,
    /// The carbon-intensity trace.
    pub trace: CiTrace,
}

/// One scenario of a sweep: the per-axis overrides to apply to a base
/// request. `None` means "inherit the base request's value".
#[derive(Debug, Clone)]
pub struct SweepScenario {
    /// Display label — the non-inherited axis labels joined with " | "
    /// ("base" when every axis is inherited).
    pub label: String,
    /// Use-phase carbon intensity override, g/J.
    pub ci_use_g_per_j: Option<f64>,
    /// Operational-lifetime override, s.
    pub lifetime_s: Option<f64>,
    /// Multiplier on every per-task QoS bound (∞ disables finite bounds).
    pub qos_scale: Option<f64>,
    /// β override for the scalarized objective.
    pub beta: Option<f64>,
    /// Average-power-cap override, W.
    pub p_max_w: Option<f64>,
    /// Time-varying CI trace. When set, the sweep paths evaluate through
    /// [`SweepScenario::lower`] — one per-segment `ci_use` override per
    /// trace segment, recombined by time weight — and the trace
    /// supersedes any static `ci_use_g_per_j` override on this scenario.
    pub trace: Option<CiTrace>,
}

impl SweepScenario {
    /// Rewrite a base request under this scenario. The design space
    /// (tasks, configs, online mask) is untouched. A trace-carrying
    /// scenario collapses to its time-weighted mean CI here — the sweep
    /// paths never call `apply` on one directly (they lower it first);
    /// this fallback keeps external callers sensible.
    pub fn apply(&self, base: &EvalRequest) -> EvalRequest {
        let mut req = base.clone();
        if let Some(v) = self.ci_use_g_per_j {
            req.ci_use_g_per_j = v;
        }
        if let Some(tr) = &self.trace {
            req.ci_use_g_per_j = tr.mean_g_per_j();
        }
        if let Some(v) = self.lifetime_s {
            req.lifetime_s = v;
        }
        if let Some(s) = self.qos_scale {
            for q in req.qos.iter_mut() {
                // `qos=off` is scale ∞; a base bound of 0.0 would make
                // `0.0 × ∞ = NaN`, which the overlay feasibility check
                // treats as violated — set the bound directly instead
                // of multiplying.
                *q = if s.is_infinite() { f64::INFINITY } else { *q * s };
            }
        }
        if let Some(v) = self.beta {
            req.beta = v;
        }
        if let Some(v) = self.p_max_w {
            req.p_max_w = v;
        }
        req
    }

    /// Expand this scenario into its evaluation sequence: `(per-segment
    /// scenario, time weight)` pairs, one per trace segment, each a
    /// plain static scenario with the segment's intensity as its
    /// `ci_use` override. A traceless scenario lowers to itself with
    /// weight 1. Weights are the f32 values `carbon::combine_segments`
    /// consumes, in trace-segment order.
    pub fn lower(&self) -> Vec<(SweepScenario, f32)> {
        match &self.trace {
            None => vec![(self.clone(), 1.0)],
            Some(tr) => {
                let weights = tr.weights();
                (0..tr.len())
                    .map(|i| {
                        let mut sc = self.clone();
                        sc.ci_use_g_per_j = Some(tr.segment_g_per_j(i));
                        sc.trace = None;
                        (sc, weights[i])
                    })
                    .collect()
            }
        }
    }

    /// Number of per-segment evaluations [`Self::lower`] produces.
    pub fn lowered_len(&self) -> usize {
        self.trace.as_ref().map_or(1, CiTrace::len)
    }

    /// The static collapse of a trace scenario: same knobs, trace
    /// replaced by its time-weighted mean intensity. Identity for
    /// traceless scenarios. The sweep reports the trace-vs-static delta
    /// against this scenario's outcome.
    pub fn static_collapse(&self) -> SweepScenario {
        let mut sc = self.clone();
        if let Some(tr) = sc.trace.take() {
            sc.ci_use_g_per_j = Some(tr.mean_g_per_j());
        }
        sc
    }
}

/// A cross-product grid of sweep axes (see module docs).
#[derive(Debug, Clone, Default)]
pub struct ScenarioGrid {
    /// Use-phase carbon-intensity axis, g/J.
    pub ci: Vec<AxisPoint>,
    /// Operational-lifetime axis, s.
    pub lifetime: Vec<AxisPoint>,
    /// QoS-scale axis (multiplier on the base request's bounds).
    pub qos_scale: Vec<AxisPoint>,
    /// β axis.
    pub beta: Vec<AxisPoint>,
    /// Average-power-cap axis, W.
    pub p_max: Vec<AxisPoint>,
    /// Time-varying CI trace axis (nests innermost in enumeration).
    pub trace: Vec<TracePoint>,
}

/// Expand an axis into its iteration points (a single inherited point
/// when the axis is empty).
fn points(axis: &[AxisPoint]) -> Vec<Option<&AxisPoint>> {
    if axis.is_empty() {
        vec![None]
    } else {
        axis.iter().map(Some).collect()
    }
}

/// Suffix `label` (`#2`, `#3`, …) until `taken` no longer claims it.
fn dedupe_label(label: String, taken: impl Fn(&str) -> bool) -> String {
    if !taken(&label) {
        return label;
    }
    let mut k = 2usize;
    loop {
        let candidate = format!("{label}#{k}");
        if !taken(&candidate) {
            return candidate;
        }
        k += 1;
    }
}

/// Append `incoming` points to `axis`, renaming label collisions.
fn extend_axis(axis: &mut Vec<AxisPoint>, incoming: Vec<AxisPoint>) {
    for mut p in incoming {
        p.label = dedupe_label(p.label, |l| axis.iter().any(|q| q.label == l));
        axis.push(p);
    }
}

impl ScenarioGrid {
    /// Empty grid: one scenario that inherits the base request verbatim.
    pub fn new() -> Self {
        ScenarioGrid::default()
    }

    /// Append a carbon-intensity point (g/J).
    pub fn with_ci(mut self, label: &str, g_per_j: f64) -> Self {
        self.ci.push(AxisPoint::new(label, g_per_j));
        self
    }

    /// Append an operational-lifetime point (s).
    pub fn with_lifetime(mut self, label: &str, lifetime_s: f64) -> Self {
        self.lifetime.push(AxisPoint::new(label, lifetime_s));
        self
    }

    /// Append a QoS-scale point (multiplier on the base bounds).
    pub fn with_qos_scale(mut self, label: &str, scale: f64) -> Self {
        self.qos_scale.push(AxisPoint::new(label, scale));
        self
    }

    /// Append a β point.
    pub fn with_beta(mut self, label: &str, beta: f64) -> Self {
        self.beta.push(AxisPoint::new(label, beta));
        self
    }

    /// Append an average-power-cap point (W).
    pub fn with_p_max(mut self, label: &str, p_max_w: f64) -> Self {
        self.p_max.push(AxisPoint::new(label, p_max_w));
        self
    }

    /// Append a time-varying CI trace point.
    pub fn with_trace(mut self, label: &str, trace: CiTrace) -> Self {
        self.trace.push(TracePoint { label: label.to_string(), trace });
        self
    }

    /// Concatenate another grid's axes onto this one (axis-wise union —
    /// the cross-product cardinalities multiply for disjoint axes).
    /// Incoming labels that collide with existing ones on the same axis
    /// are suffixed (`"label#2"`, `"label#3"`, …) so crossed grids keep
    /// unique scenario labels — report tables and checkpoint digests key
    /// on them.
    pub fn cross(mut self, other: ScenarioGrid) -> Self {
        extend_axis(&mut self.ci, other.ci);
        extend_axis(&mut self.lifetime, other.lifetime);
        extend_axis(&mut self.qos_scale, other.qos_scale);
        extend_axis(&mut self.beta, other.beta);
        extend_axis(&mut self.p_max, other.p_max);
        for mut p in other.trace {
            p.label = dedupe_label(p.label, |l| self.trace.iter().any(|q| q.label == l));
            self.trace.push(p);
        }
        self
    }

    /// Number of scenarios the cross-product enumerates (empty axes count
    /// as one inherited point).
    pub fn cardinality(&self) -> usize {
        [&self.ci, &self.lifetime, &self.qos_scale, &self.beta, &self.p_max]
            .iter()
            .map(|axis| axis.len().max(1))
            .product::<usize>()
            * self.trace.len().max(1)
    }

    /// Enumerate every scenario, axis-major in declaration order (ci ▸
    /// lifetime ▸ qos ▸ β ▸ p_max ▸ trace), matching
    /// [`Self::cardinality`]. The trace axis is innermost so grids
    /// without traces enumerate exactly as before PR 6.
    pub fn scenarios(&self) -> Vec<SweepScenario> {
        let trace_points: Vec<Option<&TracePoint>> = if self.trace.is_empty() {
            vec![None]
        } else {
            self.trace.iter().map(Some).collect()
        };
        let mut out = Vec::with_capacity(self.cardinality());
        for ci in points(&self.ci) {
            for lt in points(&self.lifetime) {
                for qs in points(&self.qos_scale) {
                    for beta in points(&self.beta) {
                        for pm in points(&self.p_max) {
                            for tr in &trace_points {
                                let mut parts: Vec<&str> = [ci, lt, qs, beta, pm]
                                    .iter()
                                    .filter_map(|p| p.map(|a| a.label.as_str()))
                                    .collect();
                                if let Some(tp) = tr {
                                    parts.push(tp.label.as_str());
                                }
                                let label = if parts.is_empty() {
                                    "base".to_string()
                                } else {
                                    parts.join(" | ")
                                };
                                out.push(SweepScenario {
                                    label,
                                    ci_use_g_per_j: ci.map(|a| a.value),
                                    lifetime_s: lt.map(|a| a.value),
                                    qos_scale: qs.map(|a| a.value),
                                    beta: beta.map(|a| a.value),
                                    p_max_w: pm.map(|a| a.value),
                                    trace: tr.map(|tp| tp.trace.clone()),
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Fig 7 preset: the 98 % / 65 % / 25 % embodied-share scenarios,
    /// realized as operational-lifetime calibrations over the profiled
    /// design space (see [`super::scenario`]).
    pub fn fig7(rows: &[ConfigRow], tasks: &TaskMatrix, ci_use_g_per_j: f64) -> Self {
        let mut g = ScenarioGrid::new();
        for r in [0.98, 0.65, 0.25] {
            g = g.with_lifetime(
                &format!("{:.0}% embodied", r * 100.0),
                lifetime_for_ratio(rows, tasks, r, ci_use_g_per_j),
            );
        }
        g
    }

    /// Fig 10 preset: operational lifetime swept over whole decades,
    /// `10^lo .. 10^hi` seconds inclusive.
    pub fn lifetime_decades(lo: i32, hi: i32) -> Self {
        assert!(lo <= hi, "empty lifetime axis");
        let mut g = ScenarioGrid::new();
        for e in lo..=hi {
            g = g.with_lifetime(&format!("LT=1e{e}s"), 10f64.powi(e));
        }
        g
    }

    /// Fig 11 preset: provisioning-study scenarios — device lifetime 1–3
    /// years crossed with the 72 FPS QoS bound enforced or lifted.
    pub fn fig11() -> Self {
        let mut g = ScenarioGrid::new();
        for years in 1..=3 {
            g = g.with_lifetime(&format!("{years}y"), years as f64 * YEAR_S);
        }
        g.with_qos_scale("qos=on", 1.0).with_qos_scale("qos=off", f64::INFINITY)
    }

    /// Trace-diversity preset: the named diurnal/seasonal/marginal
    /// traces plus the static world-average reference (`flat-world`) as
    /// a same-grid comparison point.
    pub fn traces() -> Self {
        let mut g = ScenarioGrid::new();
        for name in [
            "diurnal-renewable",
            "diurnal-world",
            "diurnal-coal",
            "seasonal-world",
            "marginal-world",
            "flat-world",
        ] {
            g = g.with_trace(
                &format!("trace={name}"),
                CiTrace::by_name(name).expect("named trace preset"),
            );
        }
        g
    }

    /// CI-diversity preset: the named use-phase grids.
    pub fn use_grids() -> Self {
        let mut g = ScenarioGrid::new();
        for (label, ug) in [
            ("ci=world", UseGrid::WorldAverage),
            ("ci=us", UseGrid::UnitedStates),
            ("ci=coal", UseGrid::Coal),
            ("ci=renewable", UseGrid::Renewable),
        ] {
            g = g.with_ci(label, ug.g_per_joule());
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, TaskMatrix};

    fn base_request() -> EvalRequest {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[4.0]);
        EvalRequest {
            tasks,
            configs: vec![ConfigRow {
                name: "c".into(),
                f_clk: 1e9,
                d_k: vec![1e-3],
                e_dyn: vec![0.02],
                leak_w: 0.0,
                c_comp: vec![100.0],
            }],
            online: vec![1.0],
            qos: vec![0.01],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: 25.0,
        }
    }

    #[test]
    fn empty_grid_is_the_base_scenario() {
        let g = ScenarioGrid::new();
        assert_eq!(g.cardinality(), 1);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 1);
        assert_eq!(sc[0].label, "base");
        let base = base_request();
        let applied = sc[0].apply(&base);
        assert_eq!(applied.lifetime_s, base.lifetime_s);
        assert_eq!(applied.ci_use_g_per_j, base.ci_use_g_per_j);
        assert_eq!(applied.beta, base.beta);
        assert_eq!(applied.qos, base.qos);
        assert_eq!(applied.p_max_w, base.p_max_w);
    }

    #[test]
    fn cross_product_cardinality_and_unique_labels() {
        // Mirrors space.rs::labels_are_unique for the scenario dimension.
        let g = ScenarioGrid::new()
            .with_ci("ci=world", 1.2e-4)
            .with_ci("ci=coal", 2.3e-4)
            .with_lifetime("1y", YEAR_S)
            .with_lifetime("3y", 3.0 * YEAR_S)
            .with_lifetime("5y", 5.0 * YEAR_S)
            .with_beta("b=1", 1.0)
            .with_beta("b=2", 2.0);
        assert_eq!(g.cardinality(), 12);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 12);
        let mut labels: Vec<&str> = sc.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 12, "scenario labels must be unique");
    }

    #[test]
    fn apply_overrides_only_named_axes() {
        let g = ScenarioGrid::new().with_lifetime("short", 5.0).with_beta("b0", 0.0);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 1);
        let base = base_request();
        let req = sc[0].apply(&base);
        assert_eq!(req.lifetime_s, 5.0);
        assert_eq!(req.beta, 0.0);
        // Untouched knobs inherit.
        assert_eq!(req.ci_use_g_per_j, base.ci_use_g_per_j);
        assert_eq!(req.p_max_w, base.p_max_w);
        assert_eq!(req.configs.len(), base.configs.len());
    }

    #[test]
    fn qos_scale_scales_and_disables() {
        let g = ScenarioGrid::new()
            .with_qos_scale("x2", 2.0)
            .with_qos_scale("off", f64::INFINITY);
        let sc = g.scenarios();
        let base = base_request();
        let scaled = sc[0].apply(&base);
        assert!((scaled.qos[0] - 0.02).abs() < 1e-15);
        let off = sc[1].apply(&base);
        assert_eq!(off.qos[0], f64::INFINITY);
    }

    #[test]
    fn fig7_preset_orders_lifetimes() {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[100.0]);
        let rows = vec![ConfigRow {
            name: "a".into(),
            f_clk: 1e9,
            d_k: vec![1e-3],
            e_dyn: vec![0.05],
            leak_w: 0.01,
            c_comp: vec![400.0],
        }];
        let g = ScenarioGrid::fig7(&rows, &tasks, 1.2e-4);
        assert_eq!(g.cardinality(), 3);
        // Higher embodied share ⇒ shorter operational lifetime.
        assert!(g.lifetime[0].value < g.lifetime[1].value);
        assert!(g.lifetime[1].value < g.lifetime[2].value);
        assert!(g.lifetime.iter().all(|p| p.value > 0.0));
    }

    #[test]
    fn qos_off_with_zero_base_bound_disables_instead_of_nan() {
        // Regression (fig11 preset with a degenerate zero bound):
        // `0.0 × ∞ = NaN`, and the overlay treats a NaN bound as
        // violated — "QoS off" silently became "always infeasible".
        let mut base = base_request();
        base.qos = vec![0.0];
        let off: Vec<SweepScenario> = ScenarioGrid::fig11()
            .scenarios()
            .into_iter()
            .filter(|s| s.label.contains("qos=off"))
            .collect();
        assert_eq!(off.len(), 3);
        for sc in off {
            let req = sc.apply(&base);
            assert_eq!(req.qos[0], f64::INFINITY, "{}: bound must be disabled, not NaN", sc.label);
        }
        // Finite scales still multiply (0.0 stays 0.0).
        let on = ScenarioGrid::new().with_qos_scale("qos=on", 1.0).scenarios();
        assert_eq!(on[0].apply(&base).qos[0], 0.0);
    }

    #[test]
    fn trace_axis_nests_innermost_and_counts() {
        let g = ScenarioGrid::new()
            .with_lifetime("1y", YEAR_S)
            .with_lifetime("3y", 3.0 * YEAR_S)
            .with_trace("trace=flat", CiTrace::flat(440.0))
            .with_trace("trace=diurnal", CiTrace::diurnal_world());
        assert_eq!(g.cardinality(), 4);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 4);
        assert_eq!(sc[0].label, "1y | trace=flat");
        assert_eq!(sc[1].label, "1y | trace=diurnal");
        assert_eq!(sc[2].label, "3y | trace=flat");
        assert!(sc[1].trace.as_ref().is_some_and(|t| t.len() == 24));
    }

    #[test]
    fn lower_expands_trace_to_per_segment_ci_overrides() {
        let trace = CiTrace::diurnal_world();
        let sc = SweepScenario {
            label: "t".into(),
            ci_use_g_per_j: Some(9.9e-4), // superseded by the trace
            lifetime_s: Some(1e6),
            qos_scale: None,
            beta: None,
            p_max_w: None,
            trace: Some(trace.clone()),
        };
        let lowered = sc.lower();
        assert_eq!(lowered.len(), 24);
        assert_eq!(sc.lowered_len(), 24);
        for (i, (seg, w)) in lowered.iter().enumerate() {
            assert_eq!(seg.ci_use_g_per_j, Some(trace.segment_g_per_j(i)));
            assert!(seg.trace.is_none());
            assert_eq!(seg.lifetime_s, Some(1e6));
            assert_eq!(*w, trace.weights()[i]);
        }
        // Static collapse folds the trace into its mean CI.
        let st = sc.static_collapse();
        assert!(st.trace.is_none());
        assert_eq!(st.ci_use_g_per_j, Some(trace.mean_g_per_j()));
        // A traceless scenario lowers to itself with weight 1.
        let plain = ScenarioGrid::new().with_lifetime("1y", YEAR_S).scenarios().remove(0);
        let l = plain.lower();
        assert_eq!(l.len(), 1);
        assert_eq!(l[0].1, 1.0);
        assert_eq!(plain.static_collapse().label, plain.label);
    }

    #[test]
    fn trace_apply_falls_back_to_mean_ci() {
        let base = base_request();
        let g = ScenarioGrid::new().with_trace("trace=diurnal", CiTrace::diurnal_world());
        let req = g.scenarios()[0].apply(&base);
        assert_eq!(req.ci_use_g_per_j, CiTrace::diurnal_world().mean_g_per_j());
    }

    #[test]
    fn cross_renames_colliding_axis_labels() {
        // Regression: crossing two grids sharing axis labels used to
        // produce duplicate scenario labels that collide in report keys.
        let a = ScenarioGrid::new()
            .with_lifetime("1y", YEAR_S)
            .with_trace("trace=flat", CiTrace::flat(440.0));
        let b = ScenarioGrid::new()
            .with_lifetime("1y", 2.0 * YEAR_S)
            .with_lifetime("1y", 3.0 * YEAR_S)
            .with_trace("trace=flat", CiTrace::flat(30.0));
        let g = a.cross(b);
        assert_eq!(g.cardinality(), 6);
        let mut labels: Vec<String> = g.scenarios().into_iter().map(|s| s.label).collect();
        assert_eq!(labels.len(), 6);
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 6, "crossed labels must stay unique: {labels:?}");
        assert_eq!(g.lifetime[1].label, "1y#2");
        assert_eq!(g.lifetime[2].label, "1y#3");
        assert_eq!(g.trace[1].label, "trace=flat#2");
        // Values survive the rename.
        assert_eq!(g.lifetime[2].value, 3.0 * YEAR_S);
    }

    #[test]
    fn traces_preset_resolves_all_names() {
        let g = ScenarioGrid::traces();
        assert_eq!(g.cardinality(), 6);
        assert!(g.scenarios().iter().all(|s| s.trace.is_some()));
    }

    #[test]
    fn preset_cross_products_compose() {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[10.0]);
        let rows = vec![ConfigRow {
            name: "a".into(),
            f_clk: 1e9,
            d_k: vec![1e-3],
            e_dyn: vec![0.05],
            leak_w: 0.0,
            c_comp: vec![50.0],
        }];
        let g = ScenarioGrid::fig7(&rows, &tasks, 1.2e-4).cross(ScenarioGrid::use_grids());
        assert_eq!(g.cardinality(), 12);
        assert_eq!(g.scenarios().len(), 12);
        assert_eq!(ScenarioGrid::fig11().cardinality(), 6);
        assert_eq!(ScenarioGrid::lifetime_decades(3, 8).cardinality(), 6);
    }
}
