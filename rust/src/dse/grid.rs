//! Scenario grids: labeled cross-products of sweep axes.
//!
//! The paper's headline results come from re-running the same design
//! space under many *scenarios* — carbon-intensity grids, lifetimes, QoS
//! targets, β weights, power caps. A [`ScenarioGrid`] declares one axis
//! per knob; its cross-product enumerates every [`SweepScenario`], each
//! of which rewrites a base [`EvalRequest`] without touching the design
//! space itself. Empty axes inherit the base request's value and
//! contribute nothing to the scenario label, so a default grid has
//! exactly one scenario: the base request.
//!
//! Named presets reproduce the paper's sweeps: [`ScenarioGrid::fig7`]
//! (embodied-share scenarios as lifetime calibrations),
//! [`ScenarioGrid::lifetime_decades`] (the Fig 10 operational-lifetime
//! axis) and [`ScenarioGrid::fig11`] (provisioning lifetimes × QoS
//! on/off), plus [`ScenarioGrid::use_grids`] for CI diversity.

use crate::carbon::UseGrid;
use crate::matrixform::{ConfigRow, EvalRequest, TaskMatrix};

use super::scenario::lifetime_for_ratio;

/// Seconds in a calendar year (provisioning-study lifetimes).
pub const YEAR_S: f64 = 365.0 * 24.0 * 3600.0;

/// One labeled point on a sweep axis.
#[derive(Debug, Clone)]
pub struct AxisPoint {
    /// Short label, unique within its axis ("98% embodied", "LT=1e6s").
    pub label: String,
    /// The axis value (unit depends on the axis).
    pub value: f64,
}

impl AxisPoint {
    /// New labeled point.
    pub fn new(label: &str, value: f64) -> Self {
        AxisPoint { label: label.to_string(), value }
    }
}

/// One scenario of a sweep: the per-axis overrides to apply to a base
/// request. `None` means "inherit the base request's value".
#[derive(Debug, Clone)]
pub struct SweepScenario {
    /// Display label — the non-inherited axis labels joined with " | "
    /// ("base" when every axis is inherited).
    pub label: String,
    /// Use-phase carbon intensity override, g/J.
    pub ci_use_g_per_j: Option<f64>,
    /// Operational-lifetime override, s.
    pub lifetime_s: Option<f64>,
    /// Multiplier on every per-task QoS bound (∞ disables finite bounds).
    pub qos_scale: Option<f64>,
    /// β override for the scalarized objective.
    pub beta: Option<f64>,
    /// Average-power-cap override, W.
    pub p_max_w: Option<f64>,
}

impl SweepScenario {
    /// Rewrite a base request under this scenario. The design space
    /// (tasks, configs, online mask) is untouched.
    pub fn apply(&self, base: &EvalRequest) -> EvalRequest {
        let mut req = base.clone();
        if let Some(v) = self.ci_use_g_per_j {
            req.ci_use_g_per_j = v;
        }
        if let Some(v) = self.lifetime_s {
            req.lifetime_s = v;
        }
        if let Some(s) = self.qos_scale {
            for q in req.qos.iter_mut() {
                *q *= s;
            }
        }
        if let Some(v) = self.beta {
            req.beta = v;
        }
        if let Some(v) = self.p_max_w {
            req.p_max_w = v;
        }
        req
    }
}

/// A cross-product grid of sweep axes (see module docs).
#[derive(Debug, Clone, Default)]
pub struct ScenarioGrid {
    /// Use-phase carbon-intensity axis, g/J.
    pub ci: Vec<AxisPoint>,
    /// Operational-lifetime axis, s.
    pub lifetime: Vec<AxisPoint>,
    /// QoS-scale axis (multiplier on the base request's bounds).
    pub qos_scale: Vec<AxisPoint>,
    /// β axis.
    pub beta: Vec<AxisPoint>,
    /// Average-power-cap axis, W.
    pub p_max: Vec<AxisPoint>,
}

/// Expand an axis into its iteration points (a single inherited point
/// when the axis is empty).
fn points(axis: &[AxisPoint]) -> Vec<Option<&AxisPoint>> {
    if axis.is_empty() {
        vec![None]
    } else {
        axis.iter().map(Some).collect()
    }
}

impl ScenarioGrid {
    /// Empty grid: one scenario that inherits the base request verbatim.
    pub fn new() -> Self {
        ScenarioGrid::default()
    }

    /// Append a carbon-intensity point (g/J).
    pub fn with_ci(mut self, label: &str, g_per_j: f64) -> Self {
        self.ci.push(AxisPoint::new(label, g_per_j));
        self
    }

    /// Append an operational-lifetime point (s).
    pub fn with_lifetime(mut self, label: &str, lifetime_s: f64) -> Self {
        self.lifetime.push(AxisPoint::new(label, lifetime_s));
        self
    }

    /// Append a QoS-scale point (multiplier on the base bounds).
    pub fn with_qos_scale(mut self, label: &str, scale: f64) -> Self {
        self.qos_scale.push(AxisPoint::new(label, scale));
        self
    }

    /// Append a β point.
    pub fn with_beta(mut self, label: &str, beta: f64) -> Self {
        self.beta.push(AxisPoint::new(label, beta));
        self
    }

    /// Append an average-power-cap point (W).
    pub fn with_p_max(mut self, label: &str, p_max_w: f64) -> Self {
        self.p_max.push(AxisPoint::new(label, p_max_w));
        self
    }

    /// Concatenate another grid's axes onto this one (axis-wise union —
    /// the cross-product cardinalities multiply for disjoint axes).
    pub fn cross(mut self, other: ScenarioGrid) -> Self {
        self.ci.extend(other.ci);
        self.lifetime.extend(other.lifetime);
        self.qos_scale.extend(other.qos_scale);
        self.beta.extend(other.beta);
        self.p_max.extend(other.p_max);
        self
    }

    /// Number of scenarios the cross-product enumerates (empty axes count
    /// as one inherited point).
    pub fn cardinality(&self) -> usize {
        [&self.ci, &self.lifetime, &self.qos_scale, &self.beta, &self.p_max]
            .iter()
            .map(|axis| axis.len().max(1))
            .product()
    }

    /// Enumerate every scenario, axis-major in declaration order (ci ▸
    /// lifetime ▸ qos ▸ β ▸ p_max), matching [`Self::cardinality`].
    pub fn scenarios(&self) -> Vec<SweepScenario> {
        let mut out = Vec::with_capacity(self.cardinality());
        for ci in points(&self.ci) {
            for lt in points(&self.lifetime) {
                for qs in points(&self.qos_scale) {
                    for beta in points(&self.beta) {
                        for pm in points(&self.p_max) {
                            let parts: Vec<&str> = [ci, lt, qs, beta, pm]
                                .iter()
                                .filter_map(|p| p.map(|a| a.label.as_str()))
                                .collect();
                            let label = if parts.is_empty() {
                                "base".to_string()
                            } else {
                                parts.join(" | ")
                            };
                            out.push(SweepScenario {
                                label,
                                ci_use_g_per_j: ci.map(|a| a.value),
                                lifetime_s: lt.map(|a| a.value),
                                qos_scale: qs.map(|a| a.value),
                                beta: beta.map(|a| a.value),
                                p_max_w: pm.map(|a| a.value),
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Fig 7 preset: the 98 % / 65 % / 25 % embodied-share scenarios,
    /// realized as operational-lifetime calibrations over the profiled
    /// design space (see [`super::scenario`]).
    pub fn fig7(rows: &[ConfigRow], tasks: &TaskMatrix, ci_use_g_per_j: f64) -> Self {
        let mut g = ScenarioGrid::new();
        for r in [0.98, 0.65, 0.25] {
            g = g.with_lifetime(
                &format!("{:.0}% embodied", r * 100.0),
                lifetime_for_ratio(rows, tasks, r, ci_use_g_per_j),
            );
        }
        g
    }

    /// Fig 10 preset: operational lifetime swept over whole decades,
    /// `10^lo .. 10^hi` seconds inclusive.
    pub fn lifetime_decades(lo: i32, hi: i32) -> Self {
        assert!(lo <= hi, "empty lifetime axis");
        let mut g = ScenarioGrid::new();
        for e in lo..=hi {
            g = g.with_lifetime(&format!("LT=1e{e}s"), 10f64.powi(e));
        }
        g
    }

    /// Fig 11 preset: provisioning-study scenarios — device lifetime 1–3
    /// years crossed with the 72 FPS QoS bound enforced or lifted.
    pub fn fig11() -> Self {
        let mut g = ScenarioGrid::new();
        for years in 1..=3 {
            g = g.with_lifetime(&format!("{years}y"), years as f64 * YEAR_S);
        }
        g.with_qos_scale("qos=on", 1.0).with_qos_scale("qos=off", f64::INFINITY)
    }

    /// CI-diversity preset: the named use-phase grids.
    pub fn use_grids() -> Self {
        let mut g = ScenarioGrid::new();
        for (label, ug) in [
            ("ci=world", UseGrid::WorldAverage),
            ("ci=us", UseGrid::UnitedStates),
            ("ci=coal", UseGrid::Coal),
            ("ci=renewable", UseGrid::Renewable),
        ] {
            g = g.with_ci(label, ug.g_per_joule());
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, TaskMatrix};

    fn base_request() -> EvalRequest {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[4.0]);
        EvalRequest {
            tasks,
            configs: vec![ConfigRow {
                name: "c".into(),
                f_clk: 1e9,
                d_k: vec![1e-3],
                e_dyn: vec![0.02],
                leak_w: 0.0,
                c_comp: vec![100.0],
            }],
            online: vec![1.0],
            qos: vec![0.01],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: 25.0,
        }
    }

    #[test]
    fn empty_grid_is_the_base_scenario() {
        let g = ScenarioGrid::new();
        assert_eq!(g.cardinality(), 1);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 1);
        assert_eq!(sc[0].label, "base");
        let base = base_request();
        let applied = sc[0].apply(&base);
        assert_eq!(applied.lifetime_s, base.lifetime_s);
        assert_eq!(applied.ci_use_g_per_j, base.ci_use_g_per_j);
        assert_eq!(applied.beta, base.beta);
        assert_eq!(applied.qos, base.qos);
        assert_eq!(applied.p_max_w, base.p_max_w);
    }

    #[test]
    fn cross_product_cardinality_and_unique_labels() {
        // Mirrors space.rs::labels_are_unique for the scenario dimension.
        let g = ScenarioGrid::new()
            .with_ci("ci=world", 1.2e-4)
            .with_ci("ci=coal", 2.3e-4)
            .with_lifetime("1y", YEAR_S)
            .with_lifetime("3y", 3.0 * YEAR_S)
            .with_lifetime("5y", 5.0 * YEAR_S)
            .with_beta("b=1", 1.0)
            .with_beta("b=2", 2.0);
        assert_eq!(g.cardinality(), 12);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 12);
        let mut labels: Vec<&str> = sc.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 12, "scenario labels must be unique");
    }

    #[test]
    fn apply_overrides_only_named_axes() {
        let g = ScenarioGrid::new().with_lifetime("short", 5.0).with_beta("b0", 0.0);
        let sc = g.scenarios();
        assert_eq!(sc.len(), 1);
        let base = base_request();
        let req = sc[0].apply(&base);
        assert_eq!(req.lifetime_s, 5.0);
        assert_eq!(req.beta, 0.0);
        // Untouched knobs inherit.
        assert_eq!(req.ci_use_g_per_j, base.ci_use_g_per_j);
        assert_eq!(req.p_max_w, base.p_max_w);
        assert_eq!(req.configs.len(), base.configs.len());
    }

    #[test]
    fn qos_scale_scales_and_disables() {
        let g = ScenarioGrid::new()
            .with_qos_scale("x2", 2.0)
            .with_qos_scale("off", f64::INFINITY);
        let sc = g.scenarios();
        let base = base_request();
        let scaled = sc[0].apply(&base);
        assert!((scaled.qos[0] - 0.02).abs() < 1e-15);
        let off = sc[1].apply(&base);
        assert_eq!(off.qos[0], f64::INFINITY);
    }

    #[test]
    fn fig7_preset_orders_lifetimes() {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[100.0]);
        let rows = vec![ConfigRow {
            name: "a".into(),
            f_clk: 1e9,
            d_k: vec![1e-3],
            e_dyn: vec![0.05],
            leak_w: 0.01,
            c_comp: vec![400.0],
        }];
        let g = ScenarioGrid::fig7(&rows, &tasks, 1.2e-4);
        assert_eq!(g.cardinality(), 3);
        // Higher embodied share ⇒ shorter operational lifetime.
        assert!(g.lifetime[0].value < g.lifetime[1].value);
        assert!(g.lifetime[1].value < g.lifetime[2].value);
        assert!(g.lifetime.iter().all(|p| p.value > 0.0));
    }

    #[test]
    fn preset_cross_products_compose() {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[10.0]);
        let rows = vec![ConfigRow {
            name: "a".into(),
            f_clk: 1e9,
            d_k: vec![1e-3],
            e_dyn: vec![0.05],
            leak_w: 0.0,
            c_comp: vec![50.0],
        }];
        let g = ScenarioGrid::fig7(&rows, &tasks, 1.2e-4).cross(ScenarioGrid::use_grids());
        assert_eq!(g.cardinality(), 12);
        assert_eq!(g.scenarios().len(), 12);
        assert_eq!(ScenarioGrid::fig11().cardinality(), 6);
        assert_eq!(ScenarioGrid::lifetime_decades(3, 8).cardinality(), 6);
    }
}
