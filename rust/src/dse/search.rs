//! Adaptive Pareto-guided design-space search (the scaling answer to the
//! exhaustive Fig 7 sweep).
//!
//! The exhaustive path profiles every candidate of a fixed grid; this
//! module searches a parametric [`SearchSpace`] (MAC × SRAM × 2-D/3-D ×
//! clock) by **adaptive lattice refinement**:
//!
//! 1. **Seed** — evaluate a coarse sub-lattice (stride chosen so each
//!    axis contributes ~[`SearchConfig::init_points_per_axis`] points,
//!    endpoints always included) plus
//!    [`SearchConfig::random_samples`] seeded draws from
//!    [`crate::testkit::Rng`].
//! 2. **Guide** — pool every feasible `(scenario, candidate)` objective
//!    pair `(F₁ = C_op·D, F₂ = C_emb·D)` across the whole
//!    [`ScenarioGrid`], keep the pooled [`pareto_front`] as the archive,
//!    and take the archive members plus each scenario's
//!    [`SearchConfig::guide_top_k`] tCDP leaders (plus the incumbent
//!    best) as the guide set.
//! 3. **Refine** — evaluate the unevaluated lattice neighbours of every
//!    guide at the current stride (axis steps on all four axes, diagonal
//!    steps on the MAC×SRAM plane). When no neighbour is left the stride
//!    halves (successive halving); at stride 1 an empty neighbour set
//!    means the frontier converged.
//!
//! Each generation is evaluated as one batch through the two-phase sweep
//! coordinator ([`sweep`]): candidate rows are profiled once per
//! generation (simulator in parallel threads, engine chunks fanned over
//! workers) and every grid scenario is a cheap overlay — so a search
//! over S scenarios costs `evaluations·(T·K + S)` engine work, not
//! `S·evaluations·T·K`, and inherits the coordinator's bit-identical
//! determinism: for a fixed seed the outcome is the same across runs
//! *and thread counts* (per-candidate metrics are position-independent
//! in the batch, the control loop is single-threaded, and all state is
//! kept in deterministically ordered containers).
//!
//! On the 121-point Fig 7 grid the search reproduces the exhaustive
//! feasible-tCDP optimum exactly while evaluating ≲ 55 % of the grid
//! (locked at ≤ 60 % by `rust/tests/experiments_e2e.rs`); on the
//! ~10k-point [`SearchSpace::expanded_2d3d`] space it converges after
//! evaluating a few percent of the candidates (`bench_search` reports
//! the evaluations-saved ratio in `BENCH_search.json`).

use std::collections::{BTreeMap, BTreeSet};

use crate::accel::Workload;
use crate::carbon::FabGrid;
use crate::matrixform::{ConfigRow, EvalRequest, MetricRow};
use crate::runtime::EngineFactory;
use crate::testkit::Rng;

use super::batching::shallow;
use super::grid::ScenarioGrid;
use super::pareto::pareto_front;
use super::profile::{profile_configs, profiles_to_rows};
use super::space::{DesignPoint, SearchSpace, SpaceIndex};
use super::sweep::{sweep, SweepConfig, SweepOutcome};

/// Builds §3.3 rows for a generation of candidates. The search calls
/// this once per generation with every fresh candidate, so
/// implementations can batch the expensive part (the accelerator
/// simulator fans out across threads in [`SimulatorEvaluator`]).
pub trait SpaceEvaluator {
    /// Rows for `points`, in order; `rows[i].name` must equal
    /// `points[i].label`.
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow>;
}

/// The production evaluator: profile candidates on a workload set with
/// the Fig 6 simulator and split embodied carbon into the §3.3
/// component vector.
pub struct SimulatorEvaluator {
    /// Kernels to profile on (one [`ConfigRow::d_k`] entry per kernel).
    pub workloads: Vec<Workload>,
    /// Fab grid for the embodied model.
    pub fab: FabGrid,
}

impl SpaceEvaluator for SimulatorEvaluator {
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow> {
        let configs: Vec<_> = points.iter().map(|p| p.config.clone()).collect();
        let profiles = profile_configs(&configs, &self.workloads);
        profiles_to_rows(&configs, &profiles, self.fab)
    }
}

/// Replays already-profiled rows by candidate label — for callers that
/// hold the profiled space (the Fig 7 anchor, which profiles the full
/// grid for its exhaustive reference anyway) and for oracle tests that
/// must feed the search bit-identical rows without re-running the
/// simulator. Panics on a label the row set does not cover.
pub struct ReplayEvaluator {
    by_name: BTreeMap<String, ConfigRow>,
}

impl ReplayEvaluator {
    /// Index `rows` by name.
    pub fn new(rows: &[ConfigRow]) -> Self {
        ReplayEvaluator {
            by_name: rows.iter().map(|r| (r.name.clone(), r.clone())).collect(),
        }
    }
}

impl SpaceEvaluator for ReplayEvaluator {
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow> {
        points
            .iter()
            .map(|p| {
                self.by_name
                    .get(&p.label)
                    .unwrap_or_else(|| panic!("no profiled row for candidate '{}'", p.label))
                    .clone()
            })
            .collect()
    }
}

/// Closure evaluators for tests and synthetic landscapes: any
/// `Fn(&DesignPoint) -> ConfigRow` is a per-point [`SpaceEvaluator`].
impl<F> SpaceEvaluator for F
where
    F: Fn(&DesignPoint) -> ConfigRow,
{
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow> {
        points.iter().map(self).collect()
    }
}

/// Search knobs. The defaults are the validated operating point: on the
/// 121-grid they hold evaluations under 60 % while finding the
/// exhaustive optimum exactly.
#[derive(Debug, Clone, Copy)]
pub struct SearchConfig {
    /// Seed for the random-sample half of the initial generation.
    pub seed: u64,
    /// Target lattice points per axis in the seed generation (sets the
    /// initial stride: the largest power of two ≤
    /// `(max_axis-1)/(init_points_per_axis-1)`).
    pub init_points_per_axis: usize,
    /// Seeded uniform samples added to the seed generation.
    pub random_samples: usize,
    /// Per-scenario tCDP leaders added to the guide set each round.
    pub guide_top_k: usize,
    /// Refine around every archive member (not just the tCDP leaders) —
    /// this is what converges the whole Pareto frontier.
    pub frontier: bool,
    /// Hard cap on evaluated candidates (0 = unbounded). Hitting the cap
    /// stops the search with `converged = false`.
    pub max_evals: usize,
    /// Worker threads for the per-generation sweep (0 = auto).
    pub threads: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            seed: 0xC0FFEE,
            init_points_per_axis: 6,
            random_samples: 8,
            guide_top_k: 2,
            frontier: true,
            max_evals: 0,
            threads: 0,
        }
    }
}

/// One feasible `(scenario, candidate)` pair on the pooled Pareto
/// archive.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchivePoint {
    /// Scenario position in grid enumeration order.
    pub scenario: usize,
    /// Scenario label from the grid.
    pub scenario_label: String,
    /// Candidate index tuple.
    pub index: SpaceIndex,
    /// Candidate label.
    pub name: String,
    /// `F₁ = C_op·D`.
    pub f1: f64,
    /// `F₂ = C_emb·D`.
    pub f2: f64,
    /// Scalarized `tCDP`.
    pub tcdp: f64,
}

/// The feasible-tCDP incumbent.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchBest {
    /// Scenario position in grid enumeration order.
    pub scenario: usize,
    /// Scenario label.
    pub scenario_label: String,
    /// Candidate index tuple.
    pub index: SpaceIndex,
    /// Candidate label.
    pub name: String,
    /// Its tCDP (bit-comparable against the exhaustive sweep — per-config
    /// arithmetic is batch-position-independent).
    pub tcdp: f64,
}

/// Search result.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Feasible-tCDP optimum over every evaluated `(scenario, candidate)`
    /// pair; `None` when nothing feasible was found.
    pub best: Option<SearchBest>,
    /// Pooled Pareto archive (non-dominated `(F₁, F₂)` pairs), sorted by
    /// ascending `F₁`.
    pub archive: Vec<ArchivePoint>,
    /// Candidates actually evaluated (profiled + engine-batched).
    pub evaluations: usize,
    /// Cross-product cardinality of the space.
    pub space_size: usize,
    /// Evaluation batches run.
    pub generations: usize,
    /// True when the frontier converged (stride-1 neighbourhood of every
    /// guide exhausted); false when `max_evals` (or the generation guard)
    /// stopped the search first.
    pub converged: bool,
    /// Engine label from the sweep coordinator.
    pub engine: &'static str,
    /// Worker threads the per-generation sweeps used.
    pub threads: usize,
}

/// Per-(candidate, scenario) record.
#[derive(Debug, Clone, Copy)]
struct PointEval {
    f1: f64,
    f2: f64,
    tcdp: f64,
    feasible: bool,
}

/// Runaway guard: no realistic space needs more refinement batches.
const MAX_GENERATIONS: usize = 1024;

/// One pooled feasible objective point: `(f1, f2, tcdp, scenario, index)`.
type Pooled = (f64, f64, f64, usize, SpaceIndex);

/// Feasible objective points of the evaluated set, in deterministic
/// (index, scenario) order — the pool the archive, guides and incumbent
/// are derived from. Non-finite tCDP values are excluded to mirror
/// `EvalResult::argmin_feasible`, so the incumbent can never name a
/// candidate the exhaustive path would reject.
fn feasible_pool(evaluated: &BTreeMap<SpaceIndex, Vec<PointEval>>) -> Vec<Pooled> {
    let mut pool = Vec::new();
    for (&idx, evs) in evaluated {
        for (si, ev) in evs.iter().enumerate() {
            if ev.feasible && ev.tcdp.is_finite() {
                pool.push((ev.f1, ev.f2, ev.tcdp, si, idx));
            }
        }
    }
    pool
}

/// Feasible-tCDP incumbent: ties break to the earliest scenario, then
/// the smallest index tuple — the same order [`SweepOutcome::best`] and
/// `argmin_feasible` resolve ties in, so search and exhaustive agree.
fn incumbent(pool: &[Pooled]) -> Option<&Pooled> {
    pool.iter().min_by(|a, b| a.2.total_cmp(&b.2).then(a.3.cmp(&b.3)).then(a.4.cmp(&b.4)))
}

/// Largest power of two ≤ `(max_dim − 1) / (points_per_axis − 1)`.
fn init_stride(dims: [usize; 4], points_per_axis: usize) -> usize {
    let max_dim = dims.iter().copied().max().unwrap_or(1);
    let target = ((max_dim.saturating_sub(1)) / points_per_axis.saturating_sub(1).max(1)).max(1);
    let mut stride = 1;
    while stride * 2 <= target {
        stride *= 2;
    }
    stride
}

/// Per-axis lattice positions at `stride`, endpoints always included.
fn lattice_axis(len: usize, stride: usize) -> Vec<usize> {
    let mut ax: Vec<usize> = (0..len).step_by(stride).collect();
    if *ax.last().expect("non-empty axis") != len - 1 {
        ax.push(len - 1);
    }
    ax
}

/// The seed lattice, axis-major in `[mac ▸ sram ▸ stacking ▸ clock]`
/// order.
fn lattice(dims: [usize; 4], stride: usize) -> Vec<SpaceIndex> {
    let axes: Vec<Vec<usize>> = dims.iter().map(|&d| lattice_axis(d, stride)).collect();
    let mut out = Vec::new();
    for &a in &axes[0] {
        for &b in &axes[1] {
            for &c in &axes[2] {
                for &d in &axes[3] {
                    out.push([a, b, c, d]);
                }
            }
        }
    }
    out
}

/// Lattice neighbours of `pt` at `stride`: ± one step on each axis, plus
/// the diagonal steps on the MAC×SRAM plane (axes 0 and 1) — the two
/// axes with enough resolution for a basin to sit between axis lines.
fn neighbors(pt: SpaceIndex, dims: [usize; 4], stride: usize) -> Vec<SpaceIndex> {
    let mut out = Vec::with_capacity(12);
    let step = stride as isize;
    for ax in 0..4 {
        for delta in [-step, step] {
            let v = pt[ax] as isize + delta;
            if v >= 0 && (v as usize) < dims[ax] {
                let mut q = pt;
                q[ax] = v as usize;
                out.push(q);
            }
        }
    }
    for da in [-step, step] {
        for db in [-step, step] {
            let a = pt[0] as isize + da;
            let b = pt[1] as isize + db;
            if a >= 0 && (a as usize) < dims[0] && b >= 0 && (b as usize) < dims[1] {
                let mut q = pt;
                q[0] = a as usize;
                q[1] = b as usize;
                out.push(q);
            }
        }
    }
    out
}

/// Pooled feasible objective points of an exhaustively-swept outcome, in
/// `(scenario, config)` scan order: the exhaustive counterpart of the
/// search archive. Used by the oracle tests and the Fig 7 anchor to
/// check `archive ⊆ exhaustive front`.
pub fn pooled_objectives(outcome: &SweepOutcome) -> Vec<(usize, String, f64, f64)> {
    let mut pool = Vec::new();
    for (si, sc) in outcome.scenarios.iter().enumerate() {
        let res = &sc.outcome.result;
        for i in 0..res.c {
            if res.metric(MetricRow::Feasible, i) > 0.5 {
                let d = res.metric(MetricRow::Delay, i);
                pool.push((
                    si,
                    res.names[i].clone(),
                    res.metric(MetricRow::COp, i) * d,
                    res.metric(MetricRow::CEmb, i) * d,
                ));
            }
        }
    }
    pool
}

/// `(scenario, name)` pairs of the pooled Pareto front of an exhaustive
/// sweep.
pub fn exhaustive_front(outcome: &SweepOutcome) -> BTreeSet<(usize, String)> {
    let pool = pooled_objectives(outcome);
    let pts: Vec<(f64, f64)> = pool.iter().map(|p| (p.2, p.3)).collect();
    pareto_front(&pts).into_iter().map(|i| (pool[i].0, pool[i].1.clone())).collect()
}

/// Run the adaptive search. `base` supplies everything but the configs
/// (task matrix matching the evaluator's kernel set, QoS bounds, online
/// mask, scenario defaults); `grid` is the scenario cross-product every
/// candidate is scored under.
pub fn search(
    factory: &dyn EngineFactory,
    space: &SearchSpace,
    evaluator: &dyn SpaceEvaluator,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SearchConfig,
) -> crate::Result<SearchOutcome> {
    assert!(!space.is_empty(), "search space has an empty axis");
    let dims = space.dims();
    let scenario_labels: Vec<String> =
        grid.scenarios().into_iter().map(|s| s.label).collect();
    let n_scenarios = scenario_labels.len();

    let mut rng = Rng::new(cfg.seed);
    let mut stride = init_stride(dims, cfg.init_points_per_axis);
    let mut evaluated: BTreeMap<SpaceIndex, Vec<PointEval>> = BTreeMap::new();
    let mut names: BTreeMap<SpaceIndex, String> = BTreeMap::new();
    let mut generations = 0usize;
    let mut converged = false;
    let mut engine: &'static str = factory.label();
    let mut threads_used = 1usize;

    // Seed generation: coarse lattice + seeded uniform samples.
    let mut pending = lattice(dims, stride);
    for _ in 0..cfg.random_samples {
        pending.push(space.sample(&mut rng));
    }

    loop {
        // Fresh candidates in first-seen order.
        let mut fresh: Vec<SpaceIndex> = Vec::new();
        let mut seen: BTreeSet<SpaceIndex> = BTreeSet::new();
        for &p in &pending {
            if !evaluated.contains_key(&p) && seen.insert(p) {
                fresh.push(p);
            }
        }
        if cfg.max_evals > 0 {
            let budget = cfg.max_evals.saturating_sub(evaluated.len());
            fresh.truncate(budget);
        }

        if !fresh.is_empty() {
            generations += 1;
            let points: Vec<DesignPoint> = fresh.iter().map(|&i| space.point(i)).collect();
            let rows = evaluator.rows(&points);
            assert_eq!(rows.len(), points.len(), "evaluator returned wrong row count");
            let req = EvalRequest { configs: rows, ..shallow(base) };
            let out = sweep(factory, &req, grid, &SweepConfig { threads: cfg.threads })?;
            engine = out.engine;
            threads_used = threads_used.max(out.threads);
            for (si, sc) in out.scenarios.iter().enumerate() {
                let res = &sc.outcome.result;
                for (ci, &idx) in fresh.iter().enumerate() {
                    let d = res.metric(MetricRow::Delay, ci);
                    let ev = PointEval {
                        f1: res.metric(MetricRow::COp, ci) * d,
                        f2: res.metric(MetricRow::CEmb, ci) * d,
                        tcdp: res.metric(MetricRow::Tcdp, ci),
                        feasible: res.metric(MetricRow::Feasible, ci) > 0.5,
                    };
                    evaluated
                        .entry(idx)
                        .or_insert_with(|| Vec::with_capacity(n_scenarios))
                        .push(ev);
                    if si == 0 {
                        names.insert(idx, res.names[ci].clone());
                    }
                }
            }
        }

        let pool = feasible_pool(&evaluated);
        let front_pts: Vec<(f64, f64)> = pool.iter().map(|p| (p.0, p.1)).collect();
        let front_idx = pareto_front(&front_pts);

        // Guide set: archive members (frontier mode), per-scenario tCDP
        // leaders, and the incumbent best.
        let mut guides: BTreeSet<SpaceIndex> = BTreeSet::new();
        if cfg.frontier {
            for &i in &front_idx {
                guides.insert(pool[i].4);
            }
        }
        for si in 0..n_scenarios {
            let mut sc: Vec<&Pooled> = pool.iter().filter(|p| p.3 == si).collect();
            sc.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.4.cmp(&b.4)));
            for p in sc.into_iter().take(cfg.guide_top_k) {
                guides.insert(p.4);
            }
        }
        if let Some(best) = incumbent(&pool) {
            guides.insert(best.4);
        }

        // Next round: unevaluated lattice neighbours of the guides.
        pending = Vec::new();
        for &g in &guides {
            for nb in neighbors(g, dims, stride) {
                if !evaluated.contains_key(&nb) {
                    pending.push(nb);
                }
            }
        }

        if pending.is_empty() {
            if stride > 1 {
                stride /= 2;
                continue;
            }
            converged = true;
            break;
        }
        if cfg.max_evals > 0 && evaluated.len() >= cfg.max_evals {
            break;
        }
        if generations >= MAX_GENERATIONS {
            break;
        }
    }

    // Final archive + incumbent from the full evaluated set.
    let pool = feasible_pool(&evaluated);
    let front_pts: Vec<(f64, f64)> = pool.iter().map(|p| (p.0, p.1)).collect();
    let mut front_idx = pareto_front(&front_pts);
    front_idx.sort_by(|&a, &b| pool[a].0.total_cmp(&pool[b].0).then(pool[a].4.cmp(&pool[b].4)));
    let archive: Vec<ArchivePoint> = front_idx
        .into_iter()
        .map(|i| {
            let p = &pool[i];
            ArchivePoint {
                scenario: p.3,
                scenario_label: scenario_labels[p.3].clone(),
                index: p.4,
                name: names[&p.4].clone(),
                f1: p.0,
                f2: p.1,
                tcdp: p.2,
            }
        })
        .collect();
    let best = incumbent(&pool).map(|p| SearchBest {
        scenario: p.3,
        scenario_label: scenario_labels[p.3].clone(),
        index: p.4,
        name: names[&p.4].clone(),
        tcdp: p.2,
    });

    Ok(SearchOutcome {
        best,
        archive,
        evaluations: evaluated.len(),
        space_size: space.len(),
        generations,
        converged,
        engine,
        threads: threads_used,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::TaskMatrix;
    use crate::runtime::HostEngineFactory;

    /// Synthetic smooth landscape: delay falls with MACs/SRAM/clock,
    /// energy grows with MACs and clock (3-D cheaper), embodied grows
    /// with silicon (3-D cheaper via yield) — the qualitative shape of
    /// the real simulator surface, in closed form.
    fn synth_row(p: &DesignPoint) -> ConfigRow {
        let m = p.num_macs as f64;
        let s = p.sram_bytes as f64 / (1024.0 * 1024.0);
        let f = p.config.freq_hz;
        let stacked = p.config.stacked_sram;
        let d = 40.0 / (m.powf(0.7) * s.powf(0.15)) * (1.0e9 / f);
        let e = 2e-4 * m.powf(0.3) * (f / 1.0e9).powi(2) * if stacked { 0.6 } else { 1.0 }
            + 1e-3 / s.powf(0.1);
        let emb_scale = if stacked { 0.82 } else { 1.0 };
        ConfigRow {
            name: p.label.clone(),
            f_clk: f,
            d_k: vec![d],
            e_dyn: vec![e],
            leak_w: 1e-6 * m + 1e-4 * s,
            c_comp: vec![0.4 * m * emb_scale, 55.0 * s * emb_scale, 90.0],
        }
    }

    fn synth_space() -> SearchSpace {
        SearchSpace {
            mac: vec![128, 256, 512, 1024, 2048, 3072, 4096],
            sram: [0.5f64, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0]
                .iter()
                .map(|&mb| (mb * 1024.0 * 1024.0) as u64)
                .collect(),
            stacking: vec![false, true],
            clock: vec![0.8e9, 1.0e9, 1.2e9],
        }
    }

    fn synth_base() -> EvalRequest {
        EvalRequest {
            tasks: TaskMatrix::single_task("t", vec!["k".into()], &[1.0]),
            configs: Vec::new(),
            online: vec![1.0, 1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    fn synth_grid() -> ScenarioGrid {
        ScenarioGrid::new()
            .with_lifetime("lt=2e5s", 2e5)
            .with_lifetime("lt=2e7s", 2e7)
            .with_beta("b=1", 1.0)
    }

    fn synth_cfg() -> SearchConfig {
        // 7-point axes: 4 points/axis gives stride 2 (stride 1 would be
        // the exhaustive lattice).
        SearchConfig { init_points_per_axis: 4, ..SearchConfig::default() }
    }

    /// Exhaustive reference over the same space/grid.
    fn exhaustive(space: &SearchSpace) -> SweepOutcome {
        let rows: Vec<ConfigRow> = space.enumerate().iter().map(synth_row).collect();
        let req = EvalRequest { configs: rows, ..synth_base() };
        crate::dse::sweep::sweep(&HostEngineFactory, &req, &synth_grid(), &SweepConfig::default())
            .unwrap()
    }

    #[test]
    fn finds_exhaustive_optimum_with_partial_coverage() {
        let space = synth_space();
        let ex = exhaustive(&space);
        let (esi, eci, etcdp) = ex.best().expect("feasible optimum");
        let ex_name = ex.scenarios[esi].outcome.result.names[eci].clone();

        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        assert!(out.converged);
        let best = out.best.expect("search found a feasible best");
        assert_eq!(best.name, ex_name);
        assert_eq!(best.scenario, esi);
        assert_eq!(best.tcdp.to_bits(), etcdp.to_bits(), "search tCDP must be bit-exact");
        assert!(
            out.evaluations * 10 < out.space_size * 6,
            "evaluated {}/{} (>60%)",
            out.evaluations,
            out.space_size
        );
        assert!(out.generations >= 1);
    }

    #[test]
    fn archive_is_subset_of_exhaustive_front() {
        let space = synth_space();
        let ex = exhaustive(&space);
        let front = exhaustive_front(&ex);
        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        assert!(!out.archive.is_empty());
        for a in &out.archive {
            assert!(
                front.contains(&(a.scenario, a.name.clone())),
                "archive point ({}, {}) not on the exhaustive front",
                a.scenario_label,
                a.name
            );
        }
        // Archive is sorted by ascending F1 and mutually non-dominated.
        for w in out.archive.windows(2) {
            assert!(w[0].f1 <= w[1].f1);
            assert!(w[0].f2 >= w[1].f2, "archive not a front: {w:?}");
        }
    }

    #[test]
    fn deterministic_across_runs_and_thread_counts() {
        let space = synth_space();
        let run = |threads: usize| {
            search(
                &HostEngineFactory,
                &space,
                &synth_row,
                &synth_base(),
                &synth_grid(),
                &SearchConfig { threads, ..synth_cfg() },
            )
            .unwrap()
        };
        let a = run(1);
        let b = run(1);
        let c = run(4);
        for other in [&b, &c] {
            assert_eq!(a.evaluations, other.evaluations);
            assert_eq!(a.generations, other.generations);
            assert_eq!(a.best, other.best);
            assert_eq!(a.archive, other.archive);
            assert_eq!(a.converged, other.converged);
        }
    }

    #[test]
    fn seed_changes_trajectory_not_correctness() {
        let space = synth_space();
        let ex = exhaustive(&space);
        let (_, eci, _) = ex.best().unwrap();
        let ex_name = ex.scenarios[ex.best().unwrap().0].outcome.result.names[eci].clone();
        for seed in [1u64, 7, 42] {
            let out = search(
                &HostEngineFactory,
                &space,
                &synth_row,
                &synth_base(),
                &synth_grid(),
                &SearchConfig { seed, ..synth_cfg() },
            )
            .unwrap();
            assert_eq!(out.best.unwrap().name, ex_name, "seed {seed}");
        }
    }

    #[test]
    fn max_evals_caps_the_search() {
        let space = synth_space();
        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &SearchConfig { max_evals: 20, ..synth_cfg() },
        )
        .unwrap();
        assert!(out.evaluations <= 20, "evaluated {}", out.evaluations);
        assert!(!out.converged);
        assert!(out.best.is_some(), "partial search still reports an incumbent");
    }

    #[test]
    fn infeasible_space_yields_no_best() {
        let space = synth_space();
        let mut base = synth_base();
        base.qos = vec![0.0]; // nothing can meet a zero delay bound
        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &base,
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        assert!(out.best.is_none());
        assert!(out.archive.is_empty());
        assert!(out.converged, "infeasible search still terminates");
    }

    #[test]
    fn init_stride_matches_axis_resolution() {
        assert_eq!(init_stride([11, 11, 1, 1], 6), 2);
        assert_eq!(init_stride([41, 21, 2, 6], 6), 8);
        assert_eq!(init_stride([7, 7, 2, 3], 4), 2);
        assert_eq!(init_stride([2, 2, 1, 1], 6), 1);
    }

    #[test]
    fn lattice_includes_endpoints() {
        assert_eq!(lattice_axis(11, 2), vec![0, 2, 4, 6, 8, 10]);
        assert_eq!(lattice_axis(11, 4), vec![0, 4, 8, 10]);
        assert_eq!(lattice_axis(1, 2), vec![0]);
        let l = lattice([11, 11, 1, 1], 4);
        assert_eq!(l.len(), 16);
        assert!(l.contains(&[10, 10, 0, 0]));
    }

    #[test]
    fn neighbors_respect_bounds_and_stride() {
        let nb = neighbors([0, 0, 0, 0], [11, 11, 2, 3], 2);
        assert!(nb.contains(&[2, 0, 0, 0]));
        assert!(nb.contains(&[0, 2, 0, 0]));
        assert!(nb.contains(&[2, 2, 0, 0])); // diagonal on mac×sram
        assert!(nb.iter().all(|q| q.iter().zip([11, 11, 2, 3]).all(|(&v, d)| v < d)));
        // stacking axis has no stride-2 neighbour from 0 in a 2-long axis
        assert!(!nb.iter().any(|q| q[2] != 0));
        let nb1 = neighbors([5, 5, 0, 1], [11, 11, 2, 3], 1);
        assert!(nb1.contains(&[5, 5, 1, 1]));
        assert!(nb1.contains(&[5, 5, 0, 0]));
        assert!(nb1.contains(&[4, 4, 0, 1]));
        // 2 (mac) + 2 (sram) + 1 (stacking, lower edge) + 2 (clock) axis
        // moves plus 4 mac×sram diagonals.
        assert_eq!(nb1.len(), 11);
    }
}
