//! Adaptive Pareto-guided design-space search (the scaling answer to the
//! exhaustive Fig 7 sweep).
//!
//! The exhaustive path profiles every candidate of a fixed grid; this
//! module searches a parametric [`SearchSpace`] (MAC × SRAM × 2-D/3-D ×
//! clock) by **adaptive lattice refinement**:
//!
//! 1. **Seed** — evaluate a coarse sub-lattice (stride chosen so each
//!    axis contributes ~[`SearchConfig::init_points_per_axis`] points,
//!    endpoints always included) plus
//!    [`SearchConfig::random_samples`] seeded draws from
//!    [`crate::testkit::Rng`].
//! 2. **Guide** — pool every feasible `(scenario, candidate)` objective
//!    pair `(F₁ = C_op·D, F₂ = C_emb·D)` across the whole
//!    [`ScenarioGrid`], keep the pooled [`pareto_front`] as the archive,
//!    and take the archive members plus each scenario's
//!    [`SearchConfig::guide_top_k`] tCDP leaders (plus the incumbent
//!    best) as the guide set.
//! 3. **Refine** — evaluate the unevaluated lattice neighbours of every
//!    guide at the current stride (axis steps on all four axes, diagonal
//!    steps on the MAC×SRAM plane). When no neighbour is left the stride
//!    halves (successive halving); at stride 1 an empty neighbour set
//!    means the frontier converged.
//!
//! Each generation is evaluated as one batch through the two-phase sweep
//! coordinator ([`sweep`]): candidate rows are profiled once per
//! generation (simulator in parallel threads, engine chunks fanned over
//! workers) and every grid scenario is a cheap overlay — so a search
//! over S scenarios costs `evaluations·(T·K + S)` engine work, not
//! `S·evaluations·T·K`, and inherits the coordinator's bit-identical
//! determinism: for a fixed seed the outcome is the same across runs
//! *and thread counts* (per-candidate metrics are position-independent
//! in the batch, the control loop is single-threaded, and all state is
//! kept in deterministically ordered containers).
//!
//! On the 121-point Fig 7 grid the search reproduces the exhaustive
//! feasible-tCDP optimum exactly while evaluating ≲ 55 % of the grid
//! (locked at ≤ 60 % by `rust/tests/experiments_e2e.rs`); on the
//! ~10k-point [`SearchSpace::expanded_2d3d`] space it converges after
//! evaluating a few percent of the candidates (`bench_search` reports
//! the evaluations-saved ratio in `BENCH_search.json`).

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::accel::Workload;
use crate::carbon::FabGrid;
use crate::configfmt::{parse, ContentHasher, Json};
use crate::matrixform::{ConfigRow, EvalRequest, MetricRow};
use crate::runtime::EngineFactory;
use crate::testkit::{parse_seed, Rng, RngState};

use super::batching::shallow;
use super::cache::{splice_digest, strip_and_verify_digest, ProfileCache};
use super::grid::ScenarioGrid;
use super::pareto::pareto_front;
use super::profile::{profile_configs, profiles_to_rows};
use super::space::{DesignPoint, SearchSpace, SpaceIndex};
use super::sweep::{sweep_with_cache, SweepConfig, SweepOutcome};

/// Builds §3.3 rows for a generation of candidates. The search calls
/// this once per generation with every fresh candidate, so
/// implementations can batch the expensive part (the accelerator
/// simulator fans out across threads in [`SimulatorEvaluator`]).
pub trait SpaceEvaluator {
    /// Rows for `points`, in order; `rows[i].name` must equal
    /// `points[i].label`.
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow>;
}

/// The production evaluator: profile candidates on a workload set with
/// the Fig 6 simulator and split embodied carbon into the §3.3
/// component vector.
pub struct SimulatorEvaluator {
    /// Kernels to profile on (one [`ConfigRow::d_k`] entry per kernel).
    pub workloads: Vec<Workload>,
    /// Fab grid for the embodied model.
    pub fab: FabGrid,
}

impl SpaceEvaluator for SimulatorEvaluator {
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow> {
        let configs: Vec<_> = points.iter().map(|p| p.config.clone()).collect();
        let profiles = profile_configs(&configs, &self.workloads);
        profiles_to_rows(&configs, &profiles, self.fab)
    }
}

/// Replays already-profiled rows by candidate label — for callers that
/// hold the profiled space (the Fig 7 anchor, which profiles the full
/// grid for its exhaustive reference anyway) and for oracle tests that
/// must feed the search bit-identical rows without re-running the
/// simulator. Panics on a label the row set does not cover.
pub struct ReplayEvaluator {
    by_name: BTreeMap<String, ConfigRow>,
}

impl ReplayEvaluator {
    /// Index `rows` by name.
    pub fn new(rows: &[ConfigRow]) -> Self {
        ReplayEvaluator {
            by_name: rows.iter().map(|r| (r.name.clone(), r.clone())).collect(),
        }
    }
}

impl SpaceEvaluator for ReplayEvaluator {
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow> {
        points
            .iter()
            .map(|p| {
                self.by_name
                    .get(&p.label)
                    .unwrap_or_else(|| panic!("no profiled row for candidate '{}'", p.label))
                    .clone()
            })
            .collect()
    }
}

/// Closure evaluators for tests and synthetic landscapes: any
/// `Fn(&DesignPoint) -> ConfigRow` is a per-point [`SpaceEvaluator`].
impl<F> SpaceEvaluator for F
where
    F: Fn(&DesignPoint) -> ConfigRow,
{
    fn rows(&self, points: &[DesignPoint]) -> Vec<ConfigRow> {
        points.iter().map(self).collect()
    }
}

/// Search knobs. The defaults are the validated operating point: on the
/// 121-grid they hold evaluations under 60 % while finding the
/// exhaustive optimum exactly.
#[derive(Debug, Clone, Copy)]
pub struct SearchConfig {
    /// Seed for the random-sample half of the initial generation.
    pub seed: u64,
    /// Target lattice points per axis in the seed generation (sets the
    /// initial stride: the largest power of two ≤
    /// `(max_axis-1)/(init_points_per_axis-1)`).
    pub init_points_per_axis: usize,
    /// Seeded uniform samples added to the seed generation.
    pub random_samples: usize,
    /// Per-scenario tCDP leaders added to the guide set each round.
    pub guide_top_k: usize,
    /// Refine around every archive member (not just the tCDP leaders) —
    /// this is what converges the whole Pareto frontier.
    pub frontier: bool,
    /// Hard cap on evaluated candidates (0 = unbounded). Hitting the cap
    /// stops the search with `converged = false`.
    pub max_evals: usize,
    /// Worker threads for the per-generation sweep (0 = auto).
    pub threads: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            seed: 0xC0FFEE,
            init_points_per_axis: 6,
            random_samples: 8,
            guide_top_k: 2,
            frontier: true,
            max_evals: 0,
            threads: 0,
        }
    }
}

/// One feasible `(scenario, candidate)` pair on the pooled Pareto
/// archive.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchivePoint {
    /// Scenario position in grid enumeration order.
    pub scenario: usize,
    /// Scenario label from the grid.
    pub scenario_label: String,
    /// Candidate index tuple.
    pub index: SpaceIndex,
    /// Candidate label.
    pub name: String,
    /// `F₁ = C_op·D`.
    pub f1: f64,
    /// `F₂ = C_emb·D`.
    pub f2: f64,
    /// Scalarized `tCDP`.
    pub tcdp: f64,
}

/// The feasible-tCDP incumbent.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchBest {
    /// Scenario position in grid enumeration order.
    pub scenario: usize,
    /// Scenario label.
    pub scenario_label: String,
    /// Candidate index tuple.
    pub index: SpaceIndex,
    /// Candidate label.
    pub name: String,
    /// Its tCDP (bit-comparable against the exhaustive sweep — per-config
    /// arithmetic is batch-position-independent).
    pub tcdp: f64,
}

/// Search result.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Feasible-tCDP optimum over every evaluated `(scenario, candidate)`
    /// pair; `None` when nothing feasible was found.
    pub best: Option<SearchBest>,
    /// Pooled Pareto archive (non-dominated `(F₁, F₂)` pairs), sorted by
    /// ascending `F₁`.
    pub archive: Vec<ArchivePoint>,
    /// Candidates actually evaluated (profiled + engine-batched).
    pub evaluations: usize,
    /// Cross-product cardinality of the space.
    pub space_size: usize,
    /// Evaluation batches run.
    pub generations: usize,
    /// True when the frontier converged (stride-1 neighbourhood of every
    /// guide exhausted); false when `max_evals` (or the generation guard)
    /// stopped the search first.
    pub converged: bool,
    /// Engine label from the sweep coordinator.
    pub engine: &'static str,
    /// Worker threads the per-generation sweeps used.
    pub threads: usize,
}

/// Per-(candidate, scenario) record (public because it round-trips
/// through [`SearchCheckpoint`]s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PointEval {
    /// `F₁ = C_op·D`.
    pub f1: f64,
    /// `F₂ = C_emb·D`.
    pub f2: f64,
    /// Scalarized `tCDP`.
    pub tcdp: f64,
    /// Constraint mask outcome.
    pub feasible: bool,
}

/// Runaway guard: no realistic space needs more refinement batches.
const MAX_GENERATIONS: usize = 1024;

/// One pooled feasible objective point: `(f1, f2, tcdp, scenario, index)`.
type Pooled = (f64, f64, f64, usize, SpaceIndex);

/// Feasible objective points of the evaluated set, in deterministic
/// (index, scenario) order — the pool the archive, guides and incumbent
/// are derived from. Non-finite tCDP values are excluded to mirror
/// `EvalResult::argmin_feasible`, so the incumbent can never name a
/// candidate the exhaustive path would reject.
fn feasible_pool(evaluated: &BTreeMap<SpaceIndex, Vec<PointEval>>) -> Vec<Pooled> {
    let mut pool = Vec::new();
    for (&idx, evs) in evaluated {
        for (si, ev) in evs.iter().enumerate() {
            if ev.feasible && ev.tcdp.is_finite() {
                pool.push((ev.f1, ev.f2, ev.tcdp, si, idx));
            }
        }
    }
    pool
}

/// Feasible-tCDP incumbent: ties break to the earliest scenario, then
/// the smallest index tuple — the same order [`SweepOutcome::best`] and
/// `argmin_feasible` resolve ties in, so search and exhaustive agree.
fn incumbent(pool: &[Pooled]) -> Option<&Pooled> {
    pool.iter().min_by(|a, b| a.2.total_cmp(&b.2).then(a.3.cmp(&b.3)).then(a.4.cmp(&b.4)))
}

/// Largest power of two ≤ `(max_dim − 1) / (points_per_axis − 1)`.
fn init_stride(dims: [usize; 4], points_per_axis: usize) -> usize {
    let max_dim = dims.iter().copied().max().unwrap_or(1);
    let target = ((max_dim.saturating_sub(1)) / points_per_axis.saturating_sub(1).max(1)).max(1);
    let mut stride = 1;
    while stride * 2 <= target {
        stride *= 2;
    }
    stride
}

/// Per-axis lattice positions at `stride`, endpoints always included.
fn lattice_axis(len: usize, stride: usize) -> Vec<usize> {
    let mut ax: Vec<usize> = (0..len).step_by(stride).collect();
    if *ax.last().expect("non-empty axis") != len - 1 {
        ax.push(len - 1);
    }
    ax
}

/// The seed lattice, axis-major in `[mac ▸ sram ▸ stacking ▸ clock]`
/// order.
fn lattice(dims: [usize; 4], stride: usize) -> Vec<SpaceIndex> {
    let axes: Vec<Vec<usize>> = dims.iter().map(|&d| lattice_axis(d, stride)).collect();
    let mut out = Vec::new();
    for &a in &axes[0] {
        for &b in &axes[1] {
            for &c in &axes[2] {
                for &d in &axes[3] {
                    out.push([a, b, c, d]);
                }
            }
        }
    }
    out
}

/// Lattice neighbours of `pt` at `stride`: ± one step on each axis, plus
/// the diagonal steps on the MAC×SRAM plane (axes 0 and 1) — the two
/// axes with enough resolution for a basin to sit between axis lines.
fn neighbors(pt: SpaceIndex, dims: [usize; 4], stride: usize) -> Vec<SpaceIndex> {
    let mut out = Vec::with_capacity(12);
    let step = stride as isize;
    for ax in 0..4 {
        for delta in [-step, step] {
            let v = pt[ax] as isize + delta;
            if v >= 0 && (v as usize) < dims[ax] {
                let mut q = pt;
                q[ax] = v as usize;
                out.push(q);
            }
        }
    }
    for da in [-step, step] {
        for db in [-step, step] {
            let a = pt[0] as isize + da;
            let b = pt[1] as isize + db;
            if a >= 0 && (a as usize) < dims[0] && b >= 0 && (b as usize) < dims[1] {
                let mut q = pt;
                q[0] = a as usize;
                q[1] = b as usize;
                out.push(q);
            }
        }
    }
    out
}

/// Pooled feasible objective points of an exhaustively-swept outcome, in
/// `(scenario, config)` scan order: the exhaustive counterpart of the
/// search archive. Used by the oracle tests and the Fig 7 anchor to
/// check `archive ⊆ exhaustive front`.
pub fn pooled_objectives(outcome: &SweepOutcome) -> Vec<(usize, String, f64, f64)> {
    let mut pool = Vec::new();
    for (si, sc) in outcome.scenarios.iter().enumerate() {
        let res = &sc.outcome.result;
        for i in 0..res.c {
            if res.metric(MetricRow::Feasible, i) > 0.5 {
                let d = res.metric(MetricRow::Delay, i);
                pool.push((
                    si,
                    res.names[i].clone(),
                    res.metric(MetricRow::COp, i) * d,
                    res.metric(MetricRow::CEmb, i) * d,
                ));
            }
        }
    }
    pool
}

/// `(scenario, name)` pairs of the pooled Pareto front of an exhaustive
/// sweep.
pub fn exhaustive_front(outcome: &SweepOutcome) -> BTreeSet<(usize, String)> {
    let pool = pooled_objectives(outcome);
    let pts: Vec<(f64, f64)> = pool.iter().map(|p| (p.2, p.3)).collect();
    pareto_front(&pts).into_iter().map(|i| (pool[i].0, pool[i].1.clone())).collect()
}

/// Checkpoint envelope schema version — bump on any layout *or*
/// search-semantics change so stale checkpoints are rejected instead of
/// silently resumed into a different trajectory. (v1: no evaluator
/// fingerprint. v2: `eval_digest` member binds the checkpoint to its
/// evaluator + base request. v3: [`grid_digest`] hashes the trace axis —
/// every scenario contributes a trace marker, changing all digests.)
pub const CHECKPOINT_SCHEMA: u32 = 3;

/// A serializable snapshot of the search loop at a generation boundary:
/// everything [`SearchDriver::step`] reads — the evaluated set, candidate
/// names, pending frontier, stride, generation counter, RNG state and
/// termination flags. A search resumed from a checkpoint continues
/// **bit-identically** to the uninterrupted run (locked by
/// `rust/tests/cache_props.rs`); all `f64`/`u64` payloads travel as raw
/// bits (hex strings) through [`crate::configfmt`].
#[derive(Debug, Clone, PartialEq)]
pub struct SearchCheckpoint {
    /// Envelope schema ([`CHECKPOINT_SCHEMA`]).
    pub schema: u32,
    /// Seed echo — resuming under a different seed is an error, not a
    /// silent trajectory change.
    pub seed: u64,
    /// Budget echo (`SearchConfig::max_evals` at checkpoint time, 0 =
    /// unbounded). Resume itself allows a *different* budget — that is
    /// the budget-extension path — but callers that default the knob
    /// (the CLI) inherit this instead of silently uncapping.
    pub max_evals: usize,
    /// Space dims echo (resume validates them against the space).
    pub dims: [usize; 4],
    /// Current lattice stride.
    pub stride: usize,
    /// Evaluation batches run so far.
    pub generations: usize,
    /// Whether the frontier already converged.
    pub converged: bool,
    /// Whether the search already terminated.
    pub done: bool,
    /// Content digest of the scenario grid the evaluations were
    /// recorded under (`None` until the first step ran). Stepping a
    /// resumed search under a grid with different labels *or values* is
    /// an error — the per-candidate eval vectors are indexed by scenario
    /// position and their numbers embed the scenario knobs.
    pub grid_digest: Option<String>,
    /// Content digest of the evaluator + base request the evaluations
    /// were recorded under (`None` until the first step): the evaluator
    /// is probed on a small fixed set of space-corner candidates and its
    /// rows are hashed together with the base request. Two workload
    /// clusters sharing a coincidentally identical scenario grid digest
    /// differently here (their profiled rows differ), so resuming a
    /// checkpoint under the wrong cluster is an error, not a silent
    /// blend of two problems' numerics.
    pub eval_digest: Option<String>,
    /// Engine label the evaluations were recorded under (`None` until
    /// the first step). Host and PJRT numerics differ, so resuming on a
    /// different engine is an error, not a silent blend.
    pub engine: Option<String>,
    /// PRNG state (bit-exact).
    pub rng: RngState,
    /// Candidates queued for the next generation, in first-seen order.
    pub pending: Vec<SpaceIndex>,
    /// Evaluated candidates → per-scenario objective records.
    pub evaluated: BTreeMap<SpaceIndex, Vec<PointEval>>,
    /// Evaluated candidates → labels.
    pub names: BTreeMap<SpaceIndex, String>,
}

fn hex_u64(v: u64) -> Json {
    Json::Str(format!("{v:#018x}"))
}

fn hex_f64(v: f64) -> Json {
    hex_u64(v.to_bits())
}

fn idx_json(idx: &SpaceIndex) -> Json {
    Json::Arr(idx.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn bad(field: &str) -> anyhow::Error {
    anyhow::anyhow!("checkpoint: missing or invalid field `{field}`")
}

/// Order-sensitive content digest of a scenario grid: every scenario's
/// label plus the raw bits of each override value. Two grids with the
/// same shape but different calibrations (e.g. `ScenarioGrid::fig7` for
/// two different clusters) digest differently, which is what lets a
/// checkpoint refuse to resume under the wrong grid.
pub fn grid_digest(grid: &ScenarioGrid) -> String {
    let mut h = ContentHasher::new();
    for sc in grid.scenarios() {
        h.write_str(&sc.label);
        for v in [sc.ci_use_g_per_j, sc.lifetime_s, sc.qos_scale, sc.beta, sc.p_max_w] {
            match v {
                Some(x) => {
                    h.write(&[1]);
                    h.write_u64(x.to_bits());
                }
                None => h.write(&[0]),
            }
        }
        // The trace axis is content too: segment durations and
        // intensities, in trace order (two same-mean traces with
        // different shapes must digest differently).
        match &sc.trace {
            Some(tr) => {
                h.write(&[1]);
                h.write_u64(tr.len() as u64);
                for s in tr.segments() {
                    h.write_u64(s.duration_s.to_bits());
                    h.write_u64(s.g_per_kwh.to_bits());
                }
            }
            None => h.write(&[0]),
        }
    }
    h.finish_hex()
}

/// The deterministic probe set for the evaluator fingerprint: the
/// corners of the space (every combination of first/last position per
/// axis, deduplicated) — at most 16 points, stable across interrupt
/// timing because it depends on the dims alone.
fn probe_indices(dims: [usize; 4]) -> Vec<SpaceIndex> {
    let mut out: BTreeSet<SpaceIndex> = BTreeSet::new();
    for mask in 0..16u32 {
        let mut idx = [0usize; 4];
        for (ax, slot) in idx.iter_mut().enumerate() {
            *slot = if mask & (1 << ax) != 0 { dims[ax] - 1 } else { 0 };
        }
        out.insert(idx);
    }
    out.into_iter().collect()
}

/// Content digest of the evaluator + base request: the §3.3 rows the
/// evaluator produces for the probe set (names, clocks, per-kernel
/// delays/energies, leakage, embodied components — all as raw bits)
/// plus everything of the base request the recorded evaluations embed
/// (task matrix, online mask, QoS bounds, scenario defaults). Checked
/// once per driver lifetime on the first [`SearchDriver::step`] — a
/// resumed checkpoint recorded under a different workload cluster or
/// base request fails here even when the scenario grid digests match.
pub fn evaluator_digest(
    space: &SearchSpace,
    evaluator: &dyn SpaceEvaluator,
    base: &EvalRequest,
) -> String {
    let points: Vec<DesignPoint> =
        probe_indices(space.dims()).into_iter().map(|i| space.point(i)).collect();
    let rows = evaluator.rows(&points);
    let mut h = ContentHasher::new();
    h.write(b"xrcarbon-evaluator");
    h.write_u64(base.tasks.tasks.len() as u64);
    for t in &base.tasks.tasks {
        h.write_str(t);
    }
    h.write_u64(base.tasks.kernels.len() as u64);
    for k in &base.tasks.kernels {
        h.write_str(k);
    }
    h.write_f64s(&base.tasks.n);
    h.write_f64s(&base.online);
    h.write_f64s(&base.qos);
    for v in [base.ci_use_g_per_j, base.lifetime_s, base.beta, base.p_max_w] {
        h.write_u64(v.to_bits());
    }
    h.write_u64(rows.len() as u64);
    for r in &rows {
        h.write_str(&r.name);
        h.write_u64(r.f_clk.to_bits());
        h.write_f64s(&r.d_k);
        h.write_f64s(&r.e_dyn);
        h.write_u64(r.leak_w.to_bits());
        h.write_f64s(&r.c_comp);
    }
    h.finish_hex()
}

fn take_u64(v: Option<&Json>, field: &str) -> crate::Result<u64> {
    v.and_then(Json::as_str).and_then(parse_seed).ok_or_else(|| bad(field))
}

fn take_usize(v: Option<&Json>, field: &str) -> crate::Result<usize> {
    v.and_then(Json::as_usize).ok_or_else(|| bad(field))
}

fn take_f64_bits(v: Option<&Json>, field: &str) -> crate::Result<f64> {
    take_u64(v, field).map(f64::from_bits)
}

fn take_idx(v: &Json, field: &str) -> crate::Result<SpaceIndex> {
    let arr = v.as_arr().ok_or_else(|| bad(field))?;
    if arr.len() != 4 {
        return Err(bad(field));
    }
    let mut idx = [0usize; 4];
    for (slot, j) in idx.iter_mut().zip(arr) {
        *slot = j.as_usize().ok_or_else(|| bad(field))?;
    }
    Ok(idx)
}

/// Borrowed view of everything a checkpoint envelope renders — the
/// shared body builder behind [`SearchCheckpoint::to_json_string`] and
/// [`SearchDriver::checkpoint_string`], so the driver can serialize
/// **without cloning the evaluated map** (the old per-generation path
/// cloned every eval vector just to render and drop them).
struct CheckpointView<'a> {
    schema: u32,
    seed: u64,
    max_evals: usize,
    dims: [usize; 4],
    stride: usize,
    generations: usize,
    converged: bool,
    done: bool,
    grid_digest: Option<&'a str>,
    eval_digest: Option<&'a str>,
    engine: Option<&'a str>,
    rng: RngState,
    pending: &'a [SpaceIndex],
    evaluated: &'a BTreeMap<SpaceIndex, Vec<PointEval>>,
    names: &'a BTreeMap<SpaceIndex, String>,
}

/// Render a checkpoint body (no digest member). The integrity digest is
/// spliced into the rendered string afterwards — one render total, not
/// the render-for-digest + render-for-file double the old path paid.
fn checkpoint_body(v: &CheckpointView) -> Json {
    let evaluated = Json::Arr(
        v.evaluated
            .iter()
            .map(|(idx, evs)| {
                Json::obj(vec![
                    ("idx", idx_json(idx)),
                    ("name", Json::Str(v.names.get(idx).cloned().unwrap_or_default())),
                    (
                        "evals",
                        Json::Arr(
                            evs.iter()
                                .map(|ev| {
                                    Json::obj(vec![
                                        ("f1", hex_f64(ev.f1)),
                                        ("f2", hex_f64(ev.f2)),
                                        ("tcdp", hex_f64(ev.tcdp)),
                                        ("feasible", Json::Bool(ev.feasible)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    let rng_s = Json::Arr(v.rng.s.iter().map(|&w| hex_u64(w)).collect());
    let rng = Json::obj(vec![
        ("s", rng_s),
        ("gauss_spare", v.rng.gauss_spare_bits.map(hex_u64).unwrap_or(Json::Null)),
    ]);
    let opt_str = |s: Option<&str>| s.map(|x| Json::Str(x.to_string())).unwrap_or(Json::Null);
    Json::obj(vec![
        ("schema", Json::Num(v.schema as f64)),
        ("seed", hex_u64(v.seed)),
        ("max_evals", Json::Num(v.max_evals as f64)),
        ("dims", Json::Arr(v.dims.iter().map(|&d| Json::Num(d as f64)).collect())),
        ("stride", Json::Num(v.stride as f64)),
        ("generations", Json::Num(v.generations as f64)),
        ("converged", Json::Bool(v.converged)),
        ("done", Json::Bool(v.done)),
        ("grid_digest", opt_str(v.grid_digest)),
        ("eval_digest", opt_str(v.eval_digest)),
        ("engine", opt_str(v.engine)),
        ("rng", rng),
        ("pending", Json::Arr(v.pending.iter().map(idx_json).collect())),
        ("evaluated", evaluated),
    ])
}

impl SearchCheckpoint {
    fn view(&self) -> CheckpointView<'_> {
        CheckpointView {
            schema: self.schema,
            seed: self.seed,
            max_evals: self.max_evals,
            dims: self.dims,
            stride: self.stride,
            generations: self.generations,
            converged: self.converged,
            done: self.done,
            grid_digest: self.grid_digest.as_deref(),
            eval_digest: self.eval_digest.as_deref(),
            engine: self.engine.as_deref(),
            rng: self.rng,
            pending: &self.pending,
            evaluated: &self.evaluated,
            names: &self.names,
        }
    }

    /// Render the envelope as a JSON document string (body rendered
    /// once, integrity digest spliced in).
    pub fn to_json_string(&self) -> String {
        splice_digest(&checkpoint_body(&self.view()).to_string())
    }

    /// Parse and validate an envelope. Any structural defect — stale
    /// schema, missing field, non-integral counter, malformed bits — is
    /// a typed error, never a partial checkpoint.
    pub fn from_json_str(text: &str) -> crate::Result<SearchCheckpoint> {
        let mut doc = parse(text).map_err(|e| anyhow::anyhow!("checkpoint: {e}"))?;
        // Integrity first: the stored digest must match a recomputation
        // over the re-rendered remainder of the document (deterministic
        // writer + sorted keys make the round-trip byte-stable), so a
        // structurally-valid edit anywhere in the payload is rejected.
        strip_and_verify_digest(&mut doc, "checkpoint")?;
        // Full-range check before narrowing: 2^32 + 1 must not alias 1.
        let schema = u32::try_from(take_usize(doc.get("schema"), "schema")?)
            .map_err(|_| bad("schema"))?;
        if schema != CHECKPOINT_SCHEMA {
            anyhow::bail!(
                "checkpoint: schema {schema} != supported {CHECKPOINT_SCHEMA} — \
                 re-run the search from scratch"
            );
        }
        let seed = take_u64(doc.get("seed"), "seed")?;
        let max_evals = take_usize(doc.get("max_evals"), "max_evals")?;
        let dims_arr = doc.get("dims").ok_or_else(|| bad("dims"))?;
        let dims4 = take_idx(dims_arr, "dims")?;
        let stride = take_usize(doc.get("stride"), "stride")?;
        if stride == 0 {
            return Err(bad("stride"));
        }
        let generations = take_usize(doc.get("generations"), "generations")?;
        let converged =
            doc.get("converged").and_then(Json::as_bool).ok_or_else(|| bad("converged"))?;
        let done = doc.get("done").and_then(Json::as_bool).ok_or_else(|| bad("done"))?;
        let grid_digest = match doc.get("grid_digest") {
            None | Some(Json::Null) => None,
            some => Some(
                some.and_then(Json::as_str).ok_or_else(|| bad("grid_digest"))?.to_string(),
            ),
        };
        let eval_digest = match doc.get("eval_digest") {
            None | Some(Json::Null) => None,
            some => Some(
                some.and_then(Json::as_str).ok_or_else(|| bad("eval_digest"))?.to_string(),
            ),
        };
        let engine = match doc.get("engine") {
            None | Some(Json::Null) => None,
            some => Some(
                some.and_then(Json::as_str).ok_or_else(|| bad("engine"))?.to_string(),
            ),
        };

        let rng_obj = doc.get("rng").ok_or_else(|| bad("rng"))?;
        let s_arr = rng_obj.get("s").and_then(Json::as_arr).ok_or_else(|| bad("rng.s"))?;
        if s_arr.len() != 4 {
            return Err(bad("rng.s"));
        }
        let mut s = [0u64; 4];
        for (slot, j) in s.iter_mut().zip(s_arr) {
            *slot = take_u64(Some(j), "rng.s")?;
        }
        let gauss_spare_bits = match rng_obj.get("gauss_spare") {
            None | Some(Json::Null) => None,
            some => Some(take_u64(some, "rng.gauss_spare")?),
        };

        let pending_arr =
            doc.get("pending").and_then(Json::as_arr).ok_or_else(|| bad("pending"))?;
        let mut pending = Vec::with_capacity(pending_arr.len());
        for j in pending_arr {
            pending.push(take_idx(j, "pending")?);
        }

        let eval_arr =
            doc.get("evaluated").and_then(Json::as_arr).ok_or_else(|| bad("evaluated"))?;
        let mut evaluated = BTreeMap::new();
        let mut names = BTreeMap::new();
        for entry in eval_arr {
            let idx_val = entry.get("idx").ok_or_else(|| bad("evaluated.idx"))?;
            let idx = take_idx(idx_val, "evaluated.idx")?;
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("evaluated.name"))?
                .to_string();
            let evs_arr = entry
                .get("evals")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad("evaluated.evals"))?;
            let mut evs = Vec::with_capacity(evs_arr.len());
            for ev in evs_arr {
                evs.push(PointEval {
                    f1: take_f64_bits(ev.get("f1"), "evals.f1")?,
                    f2: take_f64_bits(ev.get("f2"), "evals.f2")?,
                    tcdp: take_f64_bits(ev.get("tcdp"), "evals.tcdp")?,
                    feasible: ev
                        .get("feasible")
                        .and_then(Json::as_bool)
                        .ok_or_else(|| bad("evals.feasible"))?,
                });
            }
            if evaluated.insert(idx, evs).is_some() {
                return Err(bad("evaluated (duplicate idx)"));
            }
            names.insert(idx, name);
        }

        Ok(SearchCheckpoint {
            schema,
            seed,
            max_evals,
            dims: dims4,
            stride,
            generations,
            converged,
            done,
            grid_digest,
            eval_digest,
            engine,
            rng: RngState { s, gauss_spare_bits },
            pending,
            evaluated,
            names,
        })
    }
}

/// Write a checkpoint to disk (temp file + rename: a crash mid-write
/// can never leave a half-written envelope under the final name).
pub fn write_checkpoint(path: impl AsRef<Path>, ck: &SearchCheckpoint) -> crate::Result<()> {
    super::cache::atomic_write(path.as_ref(), &ck.to_json_string())
}

/// Read a checkpoint back from disk.
pub fn read_checkpoint(path: impl AsRef<Path>) -> crate::Result<SearchCheckpoint> {
    let text = std::fs::read_to_string(path.as_ref())?;
    SearchCheckpoint::from_json_str(&text)
}

/// The search loop as an explicit state machine: construct with
/// [`SearchDriver::new`] (or [`SearchDriver::resume`]), advance one
/// generation at a time with [`SearchDriver::step`], snapshot anywhere
/// between steps with [`SearchDriver::checkpoint`], and extract the
/// [`SearchOutcome`] with [`SearchDriver::outcome`] once done. The
/// one-shot [`search`] entry point drives it to completion.
#[derive(Debug)]
pub struct SearchDriver {
    cfg: SearchConfig,
    dims: [usize; 4],
    rng: Rng,
    stride: usize,
    evaluated: BTreeMap<SpaceIndex, Vec<PointEval>>,
    names: BTreeMap<SpaceIndex, String>,
    pending: Vec<SpaceIndex>,
    generations: usize,
    converged: bool,
    done: bool,
    grid_digest: Option<String>,
    eval_digest: Option<String>,
    /// Whether this driver instance already probed the evaluator —
    /// the probe runs once per process, on the first step.
    eval_checked: bool,
    bound_engine: Option<String>,
    engine: &'static str,
    threads_used: usize,
}

impl SearchDriver {
    /// Fresh driver: seed-generation candidates (coarse lattice plus
    /// seeded uniform samples) are queued, nothing evaluated yet.
    pub fn new(space: &SearchSpace, cfg: &SearchConfig) -> Self {
        assert!(!space.is_empty(), "search space has an empty axis");
        let dims = space.dims();
        let mut rng = Rng::new(cfg.seed);
        let stride = init_stride(dims, cfg.init_points_per_axis);
        let mut pending = lattice(dims, stride);
        for _ in 0..cfg.random_samples {
            pending.push(space.sample(&mut rng));
        }
        SearchDriver {
            cfg: *cfg,
            dims,
            rng,
            stride,
            evaluated: BTreeMap::new(),
            names: BTreeMap::new(),
            pending,
            generations: 0,
            converged: false,
            done: false,
            grid_digest: None,
            eval_digest: None,
            eval_checked: false,
            bound_engine: None,
            engine: "unknown",
            threads_used: 1,
        }
    }

    /// Rebuild a driver from a checkpoint. The checkpoint must match
    /// this space's dims and the config's seed — a mismatch is an error
    /// (a silently different trajectory would defeat the determinism
    /// contract).
    pub fn resume(
        space: &SearchSpace,
        cfg: &SearchConfig,
        ck: &SearchCheckpoint,
    ) -> crate::Result<Self> {
        assert!(!space.is_empty(), "search space has an empty axis");
        if ck.schema != CHECKPOINT_SCHEMA {
            anyhow::bail!("checkpoint schema {} != supported {}", ck.schema, CHECKPOINT_SCHEMA);
        }
        if ck.dims != space.dims() {
            anyhow::bail!(
                "checkpoint dims {:?} do not match search space dims {:?}",
                ck.dims,
                space.dims()
            );
        }
        if ck.seed != cfg.seed {
            anyhow::bail!(
                "checkpoint seed {:#x} != configured seed {:#x} (pass the original seed)",
                ck.seed,
                cfg.seed
            );
        }
        for idx in ck.pending.iter().chain(ck.evaluated.keys()) {
            if idx.iter().zip(space.dims()).any(|(&v, d)| v >= d) {
                anyhow::bail!("checkpoint index {idx:?} out of bounds for the space");
            }
        }
        // A budget- or generation-capped stop (done without convergence)
        // reopens when the resuming config grants headroom — that is the
        // budget-extended-resume contract. A converged search stays done
        // regardless of budget.
        let mut done = ck.done;
        if done
            && !ck.converged
            && !ck.pending.is_empty()
            && (cfg.max_evals == 0 || ck.evaluated.len() < cfg.max_evals)
            && ck.generations < MAX_GENERATIONS
        {
            done = false;
        }
        Ok(SearchDriver {
            cfg: *cfg,
            dims: ck.dims,
            rng: Rng::from_state(ck.rng),
            stride: ck.stride,
            evaluated: ck.evaluated.clone(),
            names: ck.names.clone(),
            pending: ck.pending.clone(),
            generations: ck.generations,
            converged: ck.converged,
            done,
            grid_digest: ck.grid_digest.clone(),
            eval_digest: ck.eval_digest.clone(),
            eval_checked: false,
            bound_engine: ck.engine.clone(),
            engine: "unknown",
            threads_used: 1,
        })
    }

    /// Snapshot the loop state (valid between any two [`Self::step`]
    /// calls, including after termination). Clones the evaluated map —
    /// use [`Self::checkpoint_string`] when the snapshot is only being
    /// persisted.
    pub fn checkpoint(&self) -> SearchCheckpoint {
        SearchCheckpoint {
            schema: CHECKPOINT_SCHEMA,
            seed: self.cfg.seed,
            max_evals: self.cfg.max_evals,
            dims: self.dims,
            stride: self.stride,
            generations: self.generations,
            converged: self.converged,
            done: self.done,
            grid_digest: self.grid_digest.clone(),
            eval_digest: self.eval_digest.clone(),
            engine: self.bound_engine.clone(),
            rng: self.rng.state(),
            pending: self.pending.clone(),
            evaluated: self.evaluated.clone(),
            names: self.names.clone(),
        }
    }

    /// Render the checkpoint envelope straight from borrowed driver
    /// state — no clone of the evaluated map, body rendered once with
    /// the integrity digest spliced in. Byte-identical to
    /// `self.checkpoint().to_json_string()` (locked by a unit test).
    pub fn checkpoint_string(&self) -> String {
        splice_digest(
            &checkpoint_body(&CheckpointView {
                schema: CHECKPOINT_SCHEMA,
                seed: self.cfg.seed,
                max_evals: self.cfg.max_evals,
                dims: self.dims,
                stride: self.stride,
                generations: self.generations,
                converged: self.converged,
                done: self.done,
                grid_digest: self.grid_digest.as_deref(),
                eval_digest: self.eval_digest.as_deref(),
                engine: self.bound_engine.as_deref(),
                rng: self.rng.state(),
                pending: &self.pending,
                evaluated: &self.evaluated,
                names: &self.names,
            })
            .to_string(),
        )
    }

    /// True once the search terminated (converged or budget-stopped).
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Candidates evaluated so far.
    pub fn evaluations(&self) -> usize {
        self.evaluated.len()
    }

    /// Advance the loop by one iteration: evaluate the pending
    /// generation (if any fresh candidates survive dedup/budget),
    /// recompute the guide set, queue the next generation and apply the
    /// termination rules. Returns `true` when the search is done.
    /// `cache` fronts the per-generation profile phase — an exact re-run
    /// (same seed/space/evaluator) serves every generation from disk.
    pub fn step(
        &mut self,
        factory: &dyn EngineFactory,
        space: &SearchSpace,
        evaluator: &dyn SpaceEvaluator,
        base: &EvalRequest,
        grid: &ScenarioGrid,
        cache: Option<&ProfileCache>,
    ) -> crate::Result<bool> {
        // Label first so even a no-op step on a resumed-finished driver
        // reports the real engine in its outcome.
        self.engine = factory.label();
        // Recorded evaluations embed the scenario knobs and the engine's
        // numerics, so neither may change across steps/resumes — a
        // mismatch is an error, never a silent blend of two problems.
        let digest = grid_digest(grid);
        if let Some(expect) = &self.grid_digest {
            if *expect != digest {
                anyhow::bail!(
                    "scenario grid (labels/values) does not match the one this \
                     search's evaluations were recorded under"
                );
            }
        } else {
            self.grid_digest = Some(digest);
        }
        if let Some(recorded) = self.bound_engine.as_deref() {
            if recorded != factory.label() {
                anyhow::bail!(
                    "engine '{}' does not match the '{recorded}' this search's \
                     evaluations were recorded under (force it with --engine)",
                    factory.label()
                );
            }
        } else {
            self.bound_engine = Some(factory.label().to_string());
        }
        if self.done {
            return Ok(true);
        }
        assert_eq!(space.dims(), self.dims, "space changed under the driver");
        // Evaluator + base-request fingerprint, once per driver
        // lifetime: the recorded evaluations embed the evaluator's rows
        // (e.g. which workload cluster they were profiled on), so a
        // resumed checkpoint must refuse an evaluator whose probe rows
        // differ — two clusters sharing an identical scenario grid are
        // otherwise indistinguishable.
        if !self.eval_checked {
            let digest = evaluator_digest(space, evaluator, base);
            if let Some(expect) = &self.eval_digest {
                if *expect != digest {
                    anyhow::bail!(
                        "evaluator/base request does not match the one this search's \
                         evaluations were recorded under (different workload cluster, \
                         profiling, or base request?)"
                    );
                }
            } else {
                self.eval_digest = Some(digest);
            }
            self.eval_checked = true;
        }
        let n_scenarios = grid.cardinality();

        // Fresh candidates in first-seen order.
        let mut fresh: Vec<SpaceIndex> = Vec::new();
        let mut seen: BTreeSet<SpaceIndex> = BTreeSet::new();
        for &p in &self.pending {
            if !self.evaluated.contains_key(&p) && seen.insert(p) {
                fresh.push(p);
            }
        }
        if self.cfg.max_evals > 0 {
            let budget = self.cfg.max_evals.saturating_sub(self.evaluated.len());
            fresh.truncate(budget);
        }

        if !fresh.is_empty() {
            self.generations += 1;
            let points: Vec<DesignPoint> = fresh.iter().map(|&i| space.point(i)).collect();
            let rows = evaluator.rows(&points);
            assert_eq!(rows.len(), points.len(), "evaluator returned wrong row count");
            let req = EvalRequest { configs: rows, ..shallow(base) };
            let out = sweep_with_cache(
                factory,
                &req,
                grid,
                &SweepConfig { threads: self.cfg.threads },
                cache,
            )?;
            self.engine = out.engine;
            self.threads_used = self.threads_used.max(out.threads);
            for (si, sc) in out.scenarios.iter().enumerate() {
                let res = &sc.outcome.result;
                for (ci, &idx) in fresh.iter().enumerate() {
                    let d = res.metric(MetricRow::Delay, ci);
                    let ev = PointEval {
                        f1: res.metric(MetricRow::COp, ci) * d,
                        f2: res.metric(MetricRow::CEmb, ci) * d,
                        tcdp: res.metric(MetricRow::Tcdp, ci),
                        feasible: res.metric(MetricRow::Feasible, ci) > 0.5,
                    };
                    self.evaluated
                        .entry(idx)
                        .or_insert_with(|| Vec::with_capacity(n_scenarios))
                        .push(ev);
                    if si == 0 {
                        self.names.insert(idx, res.names[ci].clone());
                    }
                }
            }
        }

        let pool = feasible_pool(&self.evaluated);
        let front_pts: Vec<(f64, f64)> = pool.iter().map(|p| (p.0, p.1)).collect();
        let front_idx = pareto_front(&front_pts);

        // Guide set: archive members (frontier mode), per-scenario tCDP
        // leaders, and the incumbent best.
        let mut guides: BTreeSet<SpaceIndex> = BTreeSet::new();
        if self.cfg.frontier {
            for &i in &front_idx {
                guides.insert(pool[i].4);
            }
        }
        for si in 0..n_scenarios {
            let mut sc: Vec<&Pooled> = pool.iter().filter(|p| p.3 == si).collect();
            sc.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.4.cmp(&b.4)));
            for p in sc.into_iter().take(self.cfg.guide_top_k) {
                guides.insert(p.4);
            }
        }
        if let Some(best) = incumbent(&pool) {
            guides.insert(best.4);
        }

        // Next round: unevaluated lattice neighbours of the guides.
        self.pending = Vec::new();
        for &g in &guides {
            for nb in neighbors(g, self.dims, self.stride) {
                if !self.evaluated.contains_key(&nb) {
                    self.pending.push(nb);
                }
            }
        }

        if self.pending.is_empty() {
            if self.stride > 1 {
                self.stride /= 2;
                return Ok(false);
            }
            self.converged = true;
            self.done = true;
            return Ok(true);
        }
        if self.cfg.max_evals > 0 && self.evaluated.len() >= self.cfg.max_evals {
            self.done = true;
            return Ok(true);
        }
        if self.generations >= MAX_GENERATIONS {
            self.done = true;
            return Ok(true);
        }
        Ok(false)
    }

    /// Final (or in-flight) archive + incumbent from the evaluated set.
    /// Panics if `grid` differs from the one the evaluations were
    /// recorded under (scenario indices/labels would dangle).
    pub fn outcome(&self, space: &SearchSpace, grid: &ScenarioGrid) -> SearchOutcome {
        if let Some(expect) = &self.grid_digest {
            assert_eq!(
                &grid_digest(grid),
                expect,
                "scenario grid changed between evaluation and outcome"
            );
        }
        let scenario_labels: Vec<String> =
            grid.scenarios().into_iter().map(|s| s.label).collect();
        let pool = feasible_pool(&self.evaluated);
        let front_pts: Vec<(f64, f64)> = pool.iter().map(|p| (p.0, p.1)).collect();
        let mut front_idx = pareto_front(&front_pts);
        front_idx
            .sort_by(|&a, &b| pool[a].0.total_cmp(&pool[b].0).then(pool[a].4.cmp(&pool[b].4)));
        let archive: Vec<ArchivePoint> = front_idx
            .into_iter()
            .map(|i| {
                let p = &pool[i];
                ArchivePoint {
                    scenario: p.3,
                    scenario_label: scenario_labels[p.3].clone(),
                    index: p.4,
                    name: self.names[&p.4].clone(),
                    f1: p.0,
                    f2: p.1,
                    tcdp: p.2,
                }
            })
            .collect();
        let best = incumbent(&pool).map(|p| SearchBest {
            scenario: p.3,
            scenario_label: scenario_labels[p.3].clone(),
            index: p.4,
            name: self.names[&p.4].clone(),
            tcdp: p.2,
        });

        SearchOutcome {
            best,
            archive,
            evaluations: self.evaluated.len(),
            space_size: space.len(),
            generations: self.generations,
            converged: self.converged,
            engine: self.engine,
            threads: self.threads_used,
        }
    }

    /// Drive to completion and build the outcome (uncached profiling;
    /// [`search_resumable`] threads a [`ProfileCache`] through when one
    /// is in play).
    pub fn run(
        mut self,
        factory: &dyn EngineFactory,
        space: &SearchSpace,
        evaluator: &dyn SpaceEvaluator,
        base: &EvalRequest,
        grid: &ScenarioGrid,
    ) -> crate::Result<SearchOutcome> {
        while !self.step(factory, space, evaluator, base, grid, None)? {}
        Ok(self.outcome(space, grid))
    }
}

/// Run the adaptive search. `base` supplies everything but the configs
/// (task matrix matching the evaluator's kernel set, QoS bounds, online
/// mask, scenario defaults); `grid` is the scenario cross-product every
/// candidate is scored under.
pub fn search(
    factory: &dyn EngineFactory,
    space: &SearchSpace,
    evaluator: &dyn SpaceEvaluator,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SearchConfig,
) -> crate::Result<SearchOutcome> {
    SearchDriver::new(space, cfg).run(factory, space, evaluator, base, grid)
}

/// [`search`] with resume/checkpoint/cache plumbing: start from
/// `resume_from` when given (validated against the space and seed),
/// persist a checkpoint after *every* generation when `save_to` is
/// given — so an interrupted or budget-extended run can continue
/// bit-identically — and front the per-generation profile phase with
/// `cache` when one is given.
#[allow(clippy::too_many_arguments)]
pub fn search_resumable(
    factory: &dyn EngineFactory,
    space: &SearchSpace,
    evaluator: &dyn SpaceEvaluator,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    cfg: &SearchConfig,
    resume_from: Option<&SearchCheckpoint>,
    save_to: Option<&Path>,
    cache: Option<&ProfileCache>,
) -> crate::Result<SearchOutcome> {
    let mut driver = match resume_from {
        Some(ck) => SearchDriver::resume(space, cfg, ck)?,
        None => SearchDriver::new(space, cfg),
    };
    let mut sink = save_to;
    loop {
        let evals_before = driver.evaluations();
        let done = driver.step(factory, space, evaluator, base, grid, cache)?;
        // Persist after every generation that evaluated something, and
        // always at termination. Stride-halving/no-op steps change no
        // evaluated state worth the full-serialization cost — resuming
        // from the previous checkpoint replays them deterministically.
        // A failed write must not discard the in-memory search (the
        // engine work already happened; the previous checkpoint is still
        // valid) — warn once and keep going uncheckpointed, mirroring
        // the cache layer's degrade-on-write-failure policy.
        if let Some(path) = sink {
            if done || driver.evaluations() > evals_before {
                if let Err(e) =
                    super::cache::atomic_write(path, &driver.checkpoint_string())
                {
                    eprintln!(
                        "[checkpoint] write to {} failed ({e}); continuing without checkpoints",
                        path.display()
                    );
                    sink = None;
                }
            }
        }
        if done {
            break;
        }
    }
    Ok(driver.outcome(space, grid))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::TaskMatrix;
    use crate::runtime::HostEngineFactory;

    /// Synthetic smooth landscape: delay falls with MACs/SRAM/clock,
    /// energy grows with MACs and clock (3-D cheaper), embodied grows
    /// with silicon (3-D cheaper via yield) — the qualitative shape of
    /// the real simulator surface, in closed form.
    fn synth_row(p: &DesignPoint) -> ConfigRow {
        let m = p.num_macs as f64;
        let s = p.sram_bytes as f64 / (1024.0 * 1024.0);
        let f = p.config.freq_hz;
        let stacked = p.config.stacked_sram;
        let d = 40.0 / (m.powf(0.7) * s.powf(0.15)) * (1.0e9 / f);
        let e = 2e-4 * m.powf(0.3) * (f / 1.0e9).powi(2) * if stacked { 0.6 } else { 1.0 }
            + 1e-3 / s.powf(0.1);
        let emb_scale = if stacked { 0.82 } else { 1.0 };
        ConfigRow {
            name: p.label.clone(),
            f_clk: f,
            d_k: vec![d],
            e_dyn: vec![e],
            leak_w: 1e-6 * m + 1e-4 * s,
            c_comp: vec![0.4 * m * emb_scale, 55.0 * s * emb_scale, 90.0],
        }
    }

    fn synth_space() -> SearchSpace {
        SearchSpace {
            mac: vec![128, 256, 512, 1024, 2048, 3072, 4096],
            sram: [0.5f64, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0]
                .iter()
                .map(|&mb| (mb * 1024.0 * 1024.0) as u64)
                .collect(),
            stacking: vec![false, true],
            clock: vec![0.8e9, 1.0e9, 1.2e9],
        }
    }

    fn synth_base() -> EvalRequest {
        EvalRequest {
            tasks: TaskMatrix::single_task("t", vec!["k".into()], &[1.0]),
            configs: Vec::new(),
            online: vec![1.0, 1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    fn synth_grid() -> ScenarioGrid {
        ScenarioGrid::new()
            .with_lifetime("lt=2e5s", 2e5)
            .with_lifetime("lt=2e7s", 2e7)
            .with_beta("b=1", 1.0)
    }

    fn synth_cfg() -> SearchConfig {
        // 7-point axes: 4 points/axis gives stride 2 (stride 1 would be
        // the exhaustive lattice).
        SearchConfig { init_points_per_axis: 4, ..SearchConfig::default() }
    }

    /// Exhaustive reference over the same space/grid.
    fn exhaustive(space: &SearchSpace) -> SweepOutcome {
        let rows: Vec<ConfigRow> = space.enumerate().iter().map(synth_row).collect();
        let req = EvalRequest { configs: rows, ..synth_base() };
        crate::dse::sweep::sweep(&HostEngineFactory, &req, &synth_grid(), &SweepConfig::default())
            .unwrap()
    }

    #[test]
    fn finds_exhaustive_optimum_with_partial_coverage() {
        let space = synth_space();
        let ex = exhaustive(&space);
        let (esi, eci, etcdp) = ex.best().expect("feasible optimum");
        let ex_name = ex.scenarios[esi].outcome.result.names[eci].clone();

        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        assert!(out.converged);
        let best = out.best.expect("search found a feasible best");
        assert_eq!(best.name, ex_name);
        assert_eq!(best.scenario, esi);
        assert_eq!(best.tcdp.to_bits(), etcdp.to_bits(), "search tCDP must be bit-exact");
        assert!(
            out.evaluations * 10 < out.space_size * 6,
            "evaluated {}/{} (>60%)",
            out.evaluations,
            out.space_size
        );
        assert!(out.generations >= 1);
    }

    #[test]
    fn archive_is_subset_of_exhaustive_front() {
        let space = synth_space();
        let ex = exhaustive(&space);
        let front = exhaustive_front(&ex);
        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        assert!(!out.archive.is_empty());
        for a in &out.archive {
            assert!(
                front.contains(&(a.scenario, a.name.clone())),
                "archive point ({}, {}) not on the exhaustive front",
                a.scenario_label,
                a.name
            );
        }
        // Archive is sorted by ascending F1 and mutually non-dominated.
        for w in out.archive.windows(2) {
            assert!(w[0].f1 <= w[1].f1);
            assert!(w[0].f2 >= w[1].f2, "archive not a front: {w:?}");
        }
    }

    #[test]
    fn deterministic_across_runs_and_thread_counts() {
        let space = synth_space();
        let run = |threads: usize| {
            search(
                &HostEngineFactory,
                &space,
                &synth_row,
                &synth_base(),
                &synth_grid(),
                &SearchConfig { threads, ..synth_cfg() },
            )
            .unwrap()
        };
        let a = run(1);
        let b = run(1);
        let c = run(4);
        for other in [&b, &c] {
            assert_eq!(a.evaluations, other.evaluations);
            assert_eq!(a.generations, other.generations);
            assert_eq!(a.best, other.best);
            assert_eq!(a.archive, other.archive);
            assert_eq!(a.converged, other.converged);
        }
    }

    #[test]
    fn seed_changes_trajectory_not_correctness() {
        let space = synth_space();
        let ex = exhaustive(&space);
        let (_, eci, _) = ex.best().unwrap();
        let ex_name = ex.scenarios[ex.best().unwrap().0].outcome.result.names[eci].clone();
        for seed in [1u64, 7, 42] {
            let out = search(
                &HostEngineFactory,
                &space,
                &synth_row,
                &synth_base(),
                &synth_grid(),
                &SearchConfig { seed, ..synth_cfg() },
            )
            .unwrap();
            assert_eq!(out.best.unwrap().name, ex_name, "seed {seed}");
        }
    }

    #[test]
    fn max_evals_caps_the_search() {
        let space = synth_space();
        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &SearchConfig { max_evals: 20, ..synth_cfg() },
        )
        .unwrap();
        assert!(out.evaluations <= 20, "evaluated {}", out.evaluations);
        assert!(!out.converged);
        assert!(out.best.is_some(), "partial search still reports an incumbent");
    }

    #[test]
    fn infeasible_space_yields_no_best() {
        let space = synth_space();
        let mut base = synth_base();
        base.qos = vec![0.0]; // nothing can meet a zero delay bound
        let out = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &base,
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        assert!(out.best.is_none());
        assert!(out.archive.is_empty());
        assert!(out.converged, "infeasible search still terminates");
    }

    /// Outcomes bit-identical up to run-environment fields (threads).
    fn outcomes_identical(a: &SearchOutcome, b: &SearchOutcome) {
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.generations, b.generations);
        assert_eq!(a.converged, b.converged);
        assert_eq!(a.best, b.best);
        assert_eq!(a.archive, b.archive);
        assert_eq!(a.space_size, b.space_size);
    }

    #[test]
    fn driver_run_equals_one_shot_search() {
        let space = synth_space();
        let one_shot = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &synth_cfg(),
        )
        .unwrap();
        let driver = SearchDriver::new(&space, &synth_cfg());
        let stepped = driver
            .run(&HostEngineFactory, &space, &synth_row, &synth_base(), &synth_grid())
            .unwrap();
        outcomes_identical(&one_shot, &stepped);
    }

    #[test]
    fn interrupted_resumed_search_is_bit_identical() {
        let space = synth_space();
        let cfg = synth_cfg();
        let full = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &cfg,
        )
        .unwrap();

        for interrupt_after in [0usize, 1, 2, 5] {
            // Phase 1: run `interrupt_after` steps, then "crash".
            let mut d = SearchDriver::new(&space, &cfg);
            let mut finished_early = false;
            let (base, grid) = (synth_base(), synth_grid());
            for _ in 0..interrupt_after {
                if d.step(&HostEngineFactory, &space, &synth_row, &base, &grid, None).unwrap() {
                    finished_early = true;
                    break;
                }
            }
            // Serialize through the JSON envelope (the real resume path).
            let ck = SearchCheckpoint::from_json_str(&d.checkpoint().to_json_string()).unwrap();
            assert_eq!(ck, d.checkpoint());

            // Phase 2: a fresh process resumes and finishes.
            let resumed = SearchDriver::resume(&space, &cfg, &ck)
                .unwrap()
                .run(&HostEngineFactory, &space, &synth_row, &synth_base(), &synth_grid())
                .unwrap();
            outcomes_identical(&full, &resumed);
            let _ = finished_early;
        }
    }

    #[test]
    fn budget_extended_resume_continues_where_same_budget_resume_stops() {
        let space = synth_space();
        let capped = SearchConfig { max_evals: 20, ..synth_cfg() };
        let stopped = {
            let mut d = SearchDriver::new(&space, &capped);
            let (base, grid) = (synth_base(), synth_grid());
            while !d.step(&HostEngineFactory, &space, &synth_row, &base, &grid, None).unwrap() {}
            d
        };
        let ck = stopped.checkpoint();
        assert!(ck.done && !ck.converged && !ck.pending.is_empty());

        // Same budget: the resume reproduces the truncated outcome and
        // evaluates nothing new.
        let same = SearchDriver::resume(&space, &capped, &ck)
            .unwrap()
            .run(&HostEngineFactory, &space, &synth_row, &synth_base(), &synth_grid())
            .unwrap();
        assert_eq!(same.evaluations, ck.evaluated.len());
        assert!(!same.converged);

        // Raised budget: the search reopens, continues the checkpointed
        // trajectory and converges past the old cap.
        let extended_cfg = SearchConfig { max_evals: 0, ..capped };
        let extended = SearchDriver::resume(&space, &extended_cfg, &ck)
            .unwrap()
            .run(&HostEngineFactory, &space, &synth_row, &synth_base(), &synth_grid())
            .unwrap();
        assert!(extended.evaluations > ck.evaluated.len());
        assert!(extended.converged);
    }

    #[test]
    fn resume_rejects_mismatched_checkpoints() {
        let space = synth_space();
        let cfg = synth_cfg();
        let mut d = SearchDriver::new(&space, &cfg);
        let (base, grid) = (synth_base(), synth_grid());
        d.step(&HostEngineFactory, &space, &synth_row, &base, &grid, None).unwrap();
        let ck = d.checkpoint();

        // Wrong seed.
        let other_seed = SearchConfig { seed: ck.seed ^ 1, ..cfg };
        assert!(SearchDriver::resume(&space, &other_seed, &ck).is_err());
        // Wrong space shape.
        let mut small = synth_space();
        small.mac.pop();
        assert!(SearchDriver::resume(&small, &cfg, &ck).is_err());
        // Stale schema.
        let mut stale = ck.clone();
        stale.schema = CHECKPOINT_SCHEMA + 1;
        assert!(SearchDriver::resume(&space, &cfg, &stale).is_err());
        let mut doc = stale.to_json_string();
        assert!(SearchCheckpoint::from_json_str(&doc).is_err());
        // Corrupted document.
        doc.truncate(doc.len() / 2);
        assert!(SearchCheckpoint::from_json_str(&doc).is_err());
        // Structurally-valid tampering (edited stride, stale digest) is
        // caught by the integrity digest.
        let mut tampered = parse(&ck.to_json_string()).unwrap();
        if let Json::Obj(o) = &mut tampered {
            o.insert("stride".to_string(), Json::Num(64.0));
        }
        assert!(SearchCheckpoint::from_json_str(&tampered.to_string()).is_err());
        // A digest-less document is refused outright.
        let mut stripped = parse(&ck.to_json_string()).unwrap();
        if let Json::Obj(o) = &mut stripped {
            o.remove("digest");
        }
        assert!(SearchCheckpoint::from_json_str(&stripped.to_string()).is_err());
        // The intact checkpoint still resumes…
        let mut resumed = SearchDriver::resume(&space, &cfg, &ck).unwrap();
        // …but stepping it under a different grid is an error (the
        // recorded eval vectors embed the scenario knobs) — whether the
        // cardinality changes…
        let bigger = synth_grid().with_beta("b=2", 2.0);
        assert!(resumed
            .step(&HostEngineFactory, &space, &synth_row, &base, &bigger, None)
            .is_err());
        // …or only a value does (same labels/shape, one lifetime moved).
        let recalibrated = ScenarioGrid::new()
            .with_lifetime("lt=2e5s", 3e5)
            .with_lifetime("lt=2e7s", 2e7)
            .with_beta("b=1", 1.0);
        assert!(resumed
            .step(&HostEngineFactory, &space, &synth_row, &base, &recalibrated, None)
            .is_err());
        // A different engine label is also refused.
        struct RelabeledHost;
        impl crate::runtime::EngineFactory for RelabeledHost {
            fn build(&self) -> crate::Result<Box<dyn crate::runtime::Engine>> {
                Ok(Box::new(crate::runtime::HostEngine::new()))
            }
            fn label(&self) -> &'static str {
                "host-v2"
            }
        }
        assert!(resumed.step(&RelabeledHost, &space, &synth_row, &base, &grid, None).is_err());
        // The matching grid + engine still step fine.
        assert!(resumed.step(&HostEngineFactory, &space, &synth_row, &base, &grid, None).is_ok());
    }

    #[test]
    fn checkpoint_file_roundtrip_and_resumable_entry() {
        let dir = crate::testkit::test_dir("search_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("search.ckpt.json");
        let space = synth_space();
        let cfg = synth_cfg();

        // A run with a checkpoint sink terminates with a `done` file.
        let direct = search(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &cfg,
        )
        .unwrap();
        let saved = search_resumable(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &cfg,
            None,
            Some(path.as_path()),
            None,
        )
        .unwrap();
        outcomes_identical(&direct, &saved);
        let ck = read_checkpoint(&path).unwrap();
        assert!(ck.done);
        assert_eq!(ck.generations, direct.generations);

        // Resuming a finished checkpoint reproduces the outcome without
        // re-evaluating anything.
        let resumed = search_resumable(
            &HostEngineFactory,
            &space,
            &synth_row,
            &synth_base(),
            &synth_grid(),
            &cfg,
            Some(&ck),
            None,
            None,
        )
        .unwrap();
        outcomes_identical(&direct, &resumed);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_a_different_cluster_sharing_the_grid() {
        // The checkpoint-fingerprint regression: two workload clusters
        // produce different profiled rows but can share a bit-identical
        // scenario grid — the grid digest alone cannot tell them apart.
        let space = synth_space();
        let cfg = synth_cfg();
        let (base, grid) = (synth_base(), synth_grid());

        // "Cluster B": same labels, same grid, different delays.
        let other_cluster = |p: &DesignPoint| {
            let mut row = synth_row(p);
            for d in &mut row.d_k {
                *d *= 1.25;
            }
            row
        };

        let mut d = SearchDriver::new(&space, &cfg);
        d.step(&HostEngineFactory, &space, &synth_row, &base, &grid, None).unwrap();
        let ck = d.checkpoint();
        assert!(ck.eval_digest.is_some());
        assert_eq!(ck.grid_digest.as_deref(), Some(grid_digest(&grid)).as_deref());

        // Resuming under cluster B constructs fine (dims/seed match)…
        let mut resumed = SearchDriver::resume(&space, &cfg, &ck).unwrap();
        // …but the first step refuses to blend the two problems.
        let err = resumed
            .step(&HostEngineFactory, &space, &other_cluster, &base, &grid, None)
            .unwrap_err();
        assert!(err.to_string().contains("evaluator"), "{err}");

        // A changed base request (same evaluator) is refused too.
        let mut rescoped = synth_base();
        rescoped.qos = vec![5.0];
        let mut resumed = SearchDriver::resume(&space, &cfg, &ck).unwrap();
        assert!(resumed
            .step(&HostEngineFactory, &space, &synth_row, &rescoped, &grid, None)
            .is_err());

        // The original evaluator + base still steps fine and finishes
        // identically to an uninterrupted run.
        let full = search(&HostEngineFactory, &space, &synth_row, &base, &grid, &cfg).unwrap();
        let resumed_out = SearchDriver::resume(&space, &cfg, &ck)
            .unwrap()
            .run(&HostEngineFactory, &space, &synth_row, &base, &grid)
            .unwrap();
        outcomes_identical(&full, &resumed_out);
    }

    #[test]
    fn grid_digest_is_sensitive_to_the_trace_axis() {
        use crate::carbon::CiTrace;
        let base = synth_grid();
        let with_diurnal = synth_grid()
            .cross(ScenarioGrid::new().with_trace("trace=d", CiTrace::diurnal_world()));
        // A flat trace with the same mean intensity but a different
        // shape must digest differently from the diurnal one.
        let with_flat =
            synth_grid().cross(ScenarioGrid::new().with_trace("trace=d", CiTrace::flat(440.0)));
        let d0 = grid_digest(&base);
        let d1 = grid_digest(&with_diurnal);
        let d2 = grid_digest(&with_flat);
        assert_ne!(d0, d1);
        assert_ne!(d1, d2);
        // Determinism.
        assert_eq!(d1, grid_digest(&with_diurnal));
    }

    #[test]
    fn checkpoint_string_matches_cloned_render_byte_for_byte() {
        let space = synth_space();
        let cfg = synth_cfg();
        let (base, grid) = (synth_base(), synth_grid());
        let mut d = SearchDriver::new(&space, &cfg);
        // Before any step, after one step, and at termination.
        loop {
            assert_eq!(d.checkpoint_string(), d.checkpoint().to_json_string());
            let ck = SearchCheckpoint::from_json_str(&d.checkpoint_string()).unwrap();
            assert_eq!(ck, d.checkpoint());
            if d.step(&HostEngineFactory, &space, &synth_row, &base, &grid, None).unwrap() {
                break;
            }
        }
        assert_eq!(d.checkpoint_string(), d.checkpoint().to_json_string());
    }

    #[test]
    fn probe_indices_are_the_space_corners() {
        let p = probe_indices([11, 21, 2, 6]);
        assert_eq!(p.len(), 16);
        assert!(p.contains(&[0, 0, 0, 0]));
        assert!(p.contains(&[10, 20, 1, 5]));
        // Degenerate axes deduplicate.
        let p1 = probe_indices([1, 1, 1, 1]);
        assert_eq!(p1, vec![[0, 0, 0, 0]]);
        let p2 = probe_indices([2, 1, 1, 1]);
        assert_eq!(p2.len(), 2);
    }

    #[test]
    fn init_stride_matches_axis_resolution() {
        assert_eq!(init_stride([11, 11, 1, 1], 6), 2);
        assert_eq!(init_stride([41, 21, 2, 6], 6), 8);
        assert_eq!(init_stride([7, 7, 2, 3], 4), 2);
        assert_eq!(init_stride([2, 2, 1, 1], 6), 1);
    }

    #[test]
    fn lattice_includes_endpoints() {
        assert_eq!(lattice_axis(11, 2), vec![0, 2, 4, 6, 8, 10]);
        assert_eq!(lattice_axis(11, 4), vec![0, 4, 8, 10]);
        assert_eq!(lattice_axis(1, 2), vec![0]);
        let l = lattice([11, 11, 1, 1], 4);
        assert_eq!(l.len(), 16);
        assert!(l.contains(&[10, 10, 0, 0]));
    }

    #[test]
    fn neighbors_respect_bounds_and_stride() {
        let nb = neighbors([0, 0, 0, 0], [11, 11, 2, 3], 2);
        assert!(nb.contains(&[2, 0, 0, 0]));
        assert!(nb.contains(&[0, 2, 0, 0]));
        assert!(nb.contains(&[2, 2, 0, 0])); // diagonal on mac×sram
        assert!(nb.iter().all(|q| q.iter().zip([11, 11, 2, 3]).all(|(&v, d)| v < d)));
        // stacking axis has no stride-2 neighbour from 0 in a 2-long axis
        assert!(!nb.iter().any(|q| q[2] != 0));
        let nb1 = neighbors([5, 5, 0, 1], [11, 11, 2, 3], 1);
        assert!(nb1.contains(&[5, 5, 1, 1]));
        assert!(nb1.contains(&[5, 5, 0, 0]));
        assert!(nb1.contains(&[4, 4, 0, 1]));
        // 2 (mac) + 2 (sram) + 1 (stacking, lower edge) + 2 (clock) axis
        // moves plus 4 mac×sram diagonals.
        assert_eq!(nb1.len(), 11);
    }
}
