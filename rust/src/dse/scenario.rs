//! Carbon scenarios: the 98 % / 65 % / 25 % embodied-to-total ratios of
//! Fig 7, realized as operational-lifetime calibrations.
//!
//! The paper holds "same hardware lifetime and utilization" within each
//! sub-figure and varies the embodied share across sub-figures. Given the
//! profiled rows, [`lifetime_for_ratio`] solves for the operational
//! lifetime that produces a target embodied share for the *average*
//! design, so a whole exploration runs under a consistent scenario.

use crate::matrixform::{ConfigRow, TaskMatrix};

/// One carbon scenario for an exploration run.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Display name ("98% embodied").
    pub name: String,
    /// Use-phase carbon intensity, g/J.
    pub ci_use_g_per_j: f64,
    /// Operational lifetime (LT − D_idle), s.
    pub lifetime_s: f64,
    /// β for the scalarized objective.
    pub beta: f64,
}

/// Per-config task totals under a task matrix: `(energy_j, delay_s)`.
pub fn config_totals(row: &ConfigRow, tasks: &TaskMatrix) -> (f64, f64) {
    let k = tasks.num_kernels();
    assert_eq!(row.d_k.len(), k);
    let mut energy = 0.0;
    let mut delay = 0.0;
    for t in 0..tasks.num_tasks() {
        for ki in 0..k {
            let n = tasks.get(t, ki);
            if n == 0.0 {
                continue;
            }
            delay += n * row.d_k[ki];
            energy += n * (row.leak_w * row.d_k[ki] + row.e_dyn[ki]);
        }
    }
    (energy, delay)
}

/// Solve for the operational lifetime (s) that makes embodied carbon a
/// `ratio` share of total life-cycle carbon for the average config:
///
/// `C_emb/(C_emb+C_op) = r  ⇒  LT = Σemb·D·(1−r) / (r·CI·E)` (averaged).
pub fn lifetime_for_ratio(
    rows: &[ConfigRow],
    tasks: &TaskMatrix,
    ratio: f64,
    ci_use_g_per_j: f64,
) -> f64 {
    assert!((0.0..1.0).contains(&ratio) && ratio > 0.0, "ratio must be in (0,1)");
    assert!(!rows.is_empty());
    let mut acc = 0.0;
    let mut contributing = 0usize;
    for row in rows {
        let (energy, delay) = config_totals(row, tasks);
        let emb: f64 = row.c_comp.iter().sum();
        if energy > 0.0 {
            acc += emb * delay / (ci_use_g_per_j * energy);
            contributing += 1;
        }
    }
    // Zero-energy rows have no operational carbon at any lifetime, so
    // they carry no calibration signal — averaging over `rows.len()`
    // would silently deflate the lifetime (to 0.0 for an all-zero
    // space, which the overlay then divides by).
    assert!(
        contributing > 0,
        "lifetime_for_ratio: no config consumes energy — the embodied share is lifetime-\
         independent and cannot be calibrated"
    );
    let avg = acc / contributing as f64;
    avg * (1.0 - ratio) / ratio
}

/// The three Fig 7 scenarios for a profiled design space.
pub fn fig7_scenarios(rows: &[ConfigRow], tasks: &TaskMatrix, ci_use_g_per_j: f64) -> Vec<Scenario> {
    [0.98, 0.65, 0.25]
        .into_iter()
        .map(|r| Scenario {
            name: format!("{:.0}% embodied", r * 100.0),
            ci_use_g_per_j,
            lifetime_s: lifetime_for_ratio(rows, tasks, r, ci_use_g_per_j),
            beta: 1.0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> (Vec<ConfigRow>, TaskMatrix) {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[100.0]);
        let rows = vec![
            ConfigRow {
                name: "a".into(),
                f_clk: 1e9,
                d_k: vec![1e-3],
                e_dyn: vec![0.05],
                leak_w: 0.01,
                c_comp: vec![400.0],
            },
            ConfigRow {
                name: "b".into(),
                f_clk: 1e9,
                d_k: vec![5e-4],
                e_dyn: vec![0.08],
                leak_w: 0.02,
                c_comp: vec![900.0],
            },
        ];
        (rows, tasks)
    }

    #[test]
    fn totals_hand_check() {
        let (rows, tasks) = rows();
        let (e, d) = config_totals(&rows[0], &tasks);
        assert!((d - 0.1).abs() < 1e-12);
        let expect_e = 100.0 * (0.01 * 1e-3 + 0.05);
        assert!((e - expect_e).abs() < 1e-12);
    }

    #[test]
    fn ratio_calibration_is_self_consistent() {
        let (rows, tasks) = rows();
        let ci = 1.2e-4;
        for target in [0.98, 0.65, 0.25] {
            let lt = lifetime_for_ratio(&rows, &tasks, target, ci);
            // Recompute the achieved average ratio at that lifetime.
            let mut acc = 0.0;
            for row in &rows {
                let (e, d) = config_totals(row, &tasks);
                let emb: f64 = row.c_comp.iter().sum();
                let c_emb = emb * d / lt;
                let c_op = ci * e;
                acc += c_emb / (c_emb + c_op);
            }
            let achieved = acc / rows.len() as f64;
            // Averaging across configs skews slightly; stay within a few %.
            assert!(
                (achieved - target).abs() < 0.12,
                "target {target} achieved {achieved} (lt={lt})"
            );
        }
    }

    #[test]
    fn longer_lifetime_means_lower_embodied_share() {
        let (rows, tasks) = rows();
        let lt98 = lifetime_for_ratio(&rows, &tasks, 0.98, 1e-4);
        let lt25 = lifetime_for_ratio(&rows, &tasks, 0.25, 1e-4);
        assert!(lt98 < lt25, "98% embodied needs shorter op lifetime");
    }

    #[test]
    fn fig7_scenarios_are_ordered() {
        let (rows, tasks) = rows();
        let sc = fig7_scenarios(&rows, &tasks, 1e-4);
        assert_eq!(sc.len(), 3);
        assert!(sc[0].lifetime_s < sc[1].lifetime_s);
        assert!(sc[1].lifetime_s < sc[2].lifetime_s);
    }

    #[test]
    #[should_panic(expected = "ratio")]
    fn bad_ratio_rejected() {
        let (rows, tasks) = rows();
        lifetime_for_ratio(&rows, &tasks, 1.5, 1e-4);
    }

    fn zero_energy_row(name: &str) -> ConfigRow {
        ConfigRow {
            name: name.into(),
            f_clk: 1e9,
            d_k: vec![1e-3],
            e_dyn: vec![0.0],
            leak_w: 0.0,
            c_comp: vec![250.0],
        }
    }

    #[test]
    fn zero_energy_rows_do_not_deflate_the_calibration() {
        // Regression: rows skipped in the accumulator were still counted
        // in the denominator, shrinking the calibrated lifetime by the
        // zero-energy fraction of the space.
        let (rows, tasks) = rows();
        let without = lifetime_for_ratio(&rows, &tasks, 0.65, 1e-4);
        let mut padded = rows.clone();
        padded.push(zero_energy_row("idle1"));
        padded.push(zero_energy_row("idle2"));
        let with = lifetime_for_ratio(&padded, &tasks, 0.65, 1e-4);
        assert_eq!(
            without.to_bits(),
            with.to_bits(),
            "zero-energy rows changed the calibration: {without} vs {with}"
        );
    }

    #[test]
    #[should_panic(expected = "no config consumes energy")]
    fn all_zero_energy_space_panics_instead_of_returning_zero() {
        // Regression: this returned lifetime 0.0, which the overlay then
        // divided by.
        let (_, tasks) = rows();
        let rows = vec![zero_energy_row("idle1"), zero_energy_row("idle2")];
        lifetime_for_ratio(&rows, &tasks, 0.65, 1e-4);
    }
}
