//! β sweeps and Pareto-front extraction (paper §3.2, Table 1).
//!
//! When the relative scaling between embodied and operational carbon is
//! uncertain, the paper sweeps β over `(C_op + β·C_emb)·D` and reads the
//! carbon-efficient optimum off the Pareto front of
//! `F₁ = C_op·D` versus `F₂ = C_emb·D`.

use crate::matrixform::{EvalRequest, MetricRow};
use crate::runtime::Engine;

use super::batching::evaluate_chunked;

/// One β sample of the sweep.
#[derive(Debug, Clone)]
pub struct BetaPoint {
    /// The β value.
    pub beta: f64,
    /// Index of the scalarized-optimal feasible design.
    pub best_idx: usize,
    /// Name of that design.
    pub best_name: String,
    /// F₁ = C_op·D of the chosen design.
    pub f1: f64,
    /// F₂ = C_emb·D of the chosen design.
    pub f2: f64,
}

/// Sweep β and record the scalarized optimum at each point.
pub fn beta_sweep(
    engine: &mut dyn Engine,
    base: &EvalRequest,
    betas: &[f64],
) -> crate::Result<Vec<BetaPoint>> {
    let mut out = Vec::with_capacity(betas.len());
    for &beta in betas {
        let mut req = base.clone();
        req.beta = beta;
        let res = evaluate_chunked(engine, &req)?;
        let idx = res
            .argmin_feasible(MetricRow::Tcdp)
            .ok_or_else(|| anyhow::anyhow!("no feasible design at beta={beta}"))?;
        let c_op = res.metric(MetricRow::COp, idx);
        let c_emb = res.metric(MetricRow::CEmb, idx);
        let d = res.metric(MetricRow::Delay, idx);
        out.push(BetaPoint {
            beta,
            best_idx: idx,
            best_name: res.names[idx].clone(),
            f1: c_op * d,
            f2: c_emb * d,
        });
    }
    Ok(out)
}

/// Indices of the non-dominated points of a `(f1, f2)` set
/// (minimization in both objectives; ties kept once).
///
/// Tie semantics, locked by `ties_collapse_to_one_representative` and
/// `prop_front_matches_naive_oracle`:
///
/// * a point that ties a front point on **one** coordinate and is worse
///   on the other is strictly dominated and excluded;
/// * exact duplicates of a front point keep exactly **one**
///   representative — the earliest original index (the sort below is
///   stable, so among equal `(f1, f2)` keys the smallest index comes
///   first and is the one pushed).
///
/// Returned indices are in ascending-`f1` scan order.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // Sort by f1 asc, then f2 asc; scan keeping strictly improving f2.
    // A duplicate of the previous front point arrives with f2 ==
    // best_f2 and is skipped — that is the "ties kept once" collapse.
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[a].1.total_cmp(&points[b].1))
    });
    let mut front = Vec::new();
    let mut best_f2 = f64::INFINITY;
    for &i in &idx {
        if points[i].1 < best_f2 {
            front.push(i);
            best_f2 = points[i].1;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, TaskMatrix};
    use crate::runtime::HostEngine;
    use crate::testkit::{forall, Rng};

    #[test]
    fn pareto_front_basic() {
        let pts = [(1.0, 5.0), (2.0, 2.0), (3.0, 3.0), (5.0, 1.0), (1.5, 4.0)];
        let front = pareto_front(&pts);
        // (3,3) dominated by (2,2); others on the front.
        assert_eq!(front, vec![0, 4, 1, 3]);
    }

    #[test]
    fn ties_collapse_to_one_representative() {
        // Regression lock for the documented "ties kept once" rule:
        // duplicates of a front point must keep exactly one
        // representative — the earliest original index — not zero and
        // not all of them.
        let pts = [(2.0, 2.0), (1.0, 5.0), (2.0, 2.0), (5.0, 1.0), (2.0, 2.0)];
        let front = pareto_front(&pts);
        let dup_reps: Vec<usize> =
            front.iter().copied().filter(|&i| pts[i] == (2.0, 2.0)).collect();
        assert_eq!(dup_reps, vec![0], "exactly the earliest duplicate survives");
        assert_eq!(front, vec![1, 0, 3]);

        // A whole set of identical points keeps a single representative.
        let same = [(3.0, 3.0); 4];
        assert_eq!(pareto_front(&same), vec![0]);

        // One-coordinate ties are strict dominance, not duplicates.
        let partial = [(1.0, 4.0), (1.0, 5.0), (2.0, 4.0)];
        assert_eq!(pareto_front(&partial), vec![0]);
    }

    /// O(n²) reference: strictly-dominated points out, exact duplicates
    /// collapsed to their earliest index.
    fn naive_front(pts: &[(f64, f64)]) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, p) in pts.iter().enumerate() {
            let dominated = pts
                .iter()
                .any(|q| q.0 <= p.0 && q.1 <= p.1 && (q.0 < p.0 || q.1 < p.1));
            let dup_of_earlier = pts[..i].iter().any(|q| q == p);
            if !dominated && !dup_of_earlier {
                out.push(i);
            }
        }
        out
    }

    #[test]
    fn prop_front_matches_naive_oracle() {
        // Small integer lattices force heavy coordinate ties and exact
        // duplicates — the cases the sort-scan's tie handling must get
        // right.
        forall(
            |r: &mut Rng| {
                (0..r.below(12) + 1)
                    .map(|_| (r.below(4) as f64, r.below(4) as f64))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let mut got = pareto_front(pts);
                let mut want = naive_front(pts);
                got.sort_unstable();
                want.sort_unstable();
                got == want
            },
        );
    }

    #[test]
    fn prop_front_has_no_dominated_point() {
        forall(
            |r: &mut Rng| {
                (0..r.below(20) + 2)
                    .map(|_| (r.range(0.0, 10.0), r.range(0.0, 10.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let front = pareto_front(pts);
                front.iter().all(|&i| {
                    !pts.iter().enumerate().any(|(jdx, p)| {
                        jdx != i
                            && p.0 <= pts[i].0
                            && p.1 <= pts[i].1
                            && (p.0 < pts[i].0 || p.1 < pts[i].1)
                    })
                })
            },
        );
    }

    #[test]
    fn prop_every_non_front_point_is_dominated() {
        forall(
            |r: &mut Rng| {
                (0..r.below(15) + 2)
                    .map(|_| (r.range(0.0, 4.0).round(), r.range(0.0, 4.0).round()))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let front = pareto_front(pts);
                (0..pts.len()).all(|i| {
                    front.contains(&i)
                        || pts.iter().any(|p| {
                            p.0 <= pts[i].0 && p.1 <= pts[i].1 && (p.0 < pts[i].0 || p.1 < pts[i].1)
                        })
                        // Duplicate of a front point also counts as covered.
                        || front.iter().any(|&f| pts[f] == pts[i])
                })
            },
        );
    }

    #[test]
    fn beta_sweep_walks_from_operational_to_embodied_optimum() {
        // Design "eff" has low operational carbon, "lean" low embodied:
        // β→0 must pick "eff", large β must pick "lean" (Table 1 limits).
        let tm = TaskMatrix::single_task("t", vec!["k".into()], &[1.0]);
        let mk = |name: &str, e: f64, emb: f64| ConfigRow {
            name: name.into(),
            f_clk: 1e9,
            d_k: vec![1e-3],
            e_dyn: vec![e],
            leak_w: 0.0,
            c_comp: vec![emb],
        };
        let base = EvalRequest {
            tasks: tm,
            configs: vec![mk("eff", 0.01, 1000.0), mk("lean", 0.10, 50.0)],
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.0,
            lifetime_s: 1.0,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        };
        let sweep =
            beta_sweep(&mut HostEngine::new(), &base, &[0.0, 0.01, 1.0, 100.0]).unwrap();
        assert_eq!(sweep[0].best_name, "eff");
        assert_eq!(sweep.last().unwrap().best_name, "lean");
        // F2 (embodied side) decreases as beta grows.
        assert!(sweep[0].f2 >= sweep.last().unwrap().f2);
    }
}
