//! `xrcarbon` CLI — regenerate any paper figure/table from the command
//! line. The leader process loads the AOT artifacts once (PJRT CPU) and
//! runs the requested experiment; `--engine host` forces the pure-Rust
//! mirror.

use std::path::{Path, PathBuf};

use xrcarbon::cli::{parse_cache_budget, Args};
use xrcarbon::dse::cache::{CacheConfig, ProfileCache};
use xrcarbon::dse::search::{read_checkpoint, SearchConfig};
use xrcarbon::dse::sweep::{
    read_sweep_checkpoint, sweep_resumable, sweep_with_cache, SweepCheckpoint, SweepConfig,
    SweepOutcome,
};
use xrcarbon::dse::ScenarioGrid;
use xrcarbon::matrixform::EvalRequest;
use xrcarbon::experiments::{
    common::Ctx, fig01_metric_comparison, fig02_retrospective, fig03_fleet_categories,
    fig04_power_embodied, fig07_dse_clusters, fig08_tcdp_vs_edp, fig09_accelerators,
    fig10_lifetime_crossover, fig11_provisioning_savings, fig12_tlp_breakdown,
    fig13_core_configs, fig14_replacement, fig15_stacking, fig16_stacking_kernels, search_fig7,
    sweep_fig7, table5_vr_soc,
};
use xrcarbon::report::{search_archive_table, sweep_best_table, sweep_table, trace_table, write_csv};
use xrcarbon::runtime::{auto_factory, EngineFactory, HostEngineFactory};
use xrcarbon::workloads::{Cluster, FleetConfig};

const USAGE: &str = "\
xrcarbon — carbon-efficient XR design space exploration (tCDP)

USAGE: xrcarbon <command> [--engine pjrt|host] [--csv-dir DIR] [options]

COMMANDS
  fig1        metric-choice comparison on A-1..A-4
  fig2        retrospective CPU/SoC analysis (use --cpus / --socs to limit)
  fig3        VR fleet app categorization          [--devices N --days N --seed N]
  fig4        per-app power + embodied split       [--devices N --days N]
  fig7        the 121-config DSE across clusters and carbon scenarios
  fig8        tCDP-designed vs EDP-designed accelerators
  fig9        A-1..A-4 delay and embodied carbon
  fig10       carbon efficiency vs operational lifetime (crossovers)
  fig11       CPU core-provisioning carbon savings
  fig12       TLP time breakdown
  fig13       carbon-optimal core configurations
  fig14       replacement-period study (1h/3h/12h daily use)
  fig15       3D stacking vs 2D baseline           [--workload SR-512]
  fig16       3D stacking per XR kernel
  table5      VR SoC embodied-carbon calibration
  sweep       parallel two-phase multi-scenario sweep (profile once, overlay
              each scenario)                       [--preset NAME
                                                    --cluster all|10xr|10ai|5xr|5ai
                                                    --threads N (0 = auto; applies
                                                      to the profile phase, so it
                                                      only helps spaces spanning
                                                      several engine chunks)]
              presets: fig7     98%/65%/25% embodied-share scenarios
                       fig10    operational lifetime 1e3..1e8 s (alias: lifetime)
                       fig11    provisioning lifetimes 1-3y x QoS on/off
                       ci       CI diversity (world|us|coal|renewable grids)
                       trace    time-varying CI traces (diurnal renewable/
                                world/coal, seasonal, marginal, fleet mix);
                                prints the trace-vs-static comparison table
              --trace NAME  (with --preset trace) sweep one named trace
                        instead of the whole study grid: diurnal-renewable,
                        diurnal-world, diurnal-coal, seasonal-world,
                        marginal-world, flat-world, flat-renewable, flat-coal
              --cache-dir DIR  persistent profile cache: phase-A design
                        profiles are content-addressed on disk (JSON
                        envelope + binary sidecar, in-memory LRU in
                        front), so repeat sweeps over a cached space
                        perform zero engine contractions (the table
                        title shows hits/misses); plain sweeps also
                        checkpoint phase A to DIR/sweep_<preset>.ckpt.json
                        per chunk batch, and --search writes a checkpoint
                        to DIR/search_<space>.ckpt.json after every
                        generation
              --cache-budget N[K|M|G]  on-disk size budget for the cache:
                        least-recently-used entries are evicted past it
                        (evictions show up in the table title); requires
                        --cache-dir
              --resume CKPT.json  (without --search) continue an
                        interrupted sweep from its phase-A checkpoint:
                        completed chunks are re-read from the cache,
                        only the remainder is contracted, bit-identical
                        to an uninterrupted run; requires --cache-dir,
                        and a checkpoint from a different space/grid/
                        engine/cluster is rejected
              --search  adaptive Pareto-guided search instead of exhaustive
                        enumeration                [--space fig7|expanded
                                                    --seed N  --max-evals N
                                                    --resume CKPT.json]
                        fig7:     121-point anchor, prints exhaustive-vs-search
                        expanded: ~10k-point 2-D/3-D space (MAC x SRAM x
                                  stacking x clock), search only
                        --resume continues an interrupted search from its
                        checkpoint, bit-identical to an uninterrupted run
                        (--seed and --max-evals default to the checkpoint's
                        values; a conflicting seed/space/engine/grid is an
                        error; pass a larger --max-evals to extend a
                        budget-capped search)
  serve       resident exploration server: queue sweep/search jobs over
              HTTP, poll progress, fetch results  [--addr HOST:PORT
                                                    (default 127.0.0.1:7878)
                                                    --state-dir DIR (required)
                                                    --executors N (default 2)
                                                    --cache-dir DIR
                                                    --cache-budget N[K|M|G]
                                                    --threads N
                                                    --auth-token TOKEN]
              jobs persist under --state-dir as spec + checkpoint files;
              a restarted server resumes every unfinished job
              bit-identically. Endpoints: POST /v1/sweep, POST /v1/search,
              GET /v1/jobs/<id>, GET /v1/jobs/<id>/result, GET /v1/stats
              with --auth-token every request must carry
              `Authorization: Bearer TOKEN` or it is rejected with 401
  all         run everything above in order

GLOBAL OPTIONS
  --help          print this usage text and exit
  --engine pjrt|host  evaluation backend (auto-detects when omitted)
  --csv-dir DIR   also write each table as CSV under DIR
  --csv           reserved alias for CSV output (parsed, tables print
                  to stdout regardless)
  accepted for figure scripts (parsed; figure-specific wiring):
  --metric NAME --out PATH --artifacts DIR --beta X --ratio X
  --lifetime S --hours N --cores N
";

fn fleet_cfg(args: &Args) -> anyhow::Result<FleetConfig> {
    Ok(FleetConfig {
        devices: args.get_usize("devices", 400)?,
        days: args.get_usize("days", 30)?,
        seed: args.get_u64("seed", 0x5EED)?,
        ..Default::default()
    })
}

fn ctx_for(args: &Args) -> Ctx {
    match args.get("engine", "auto") {
        "host" => Ctx::host(),
        _ => Ctx::auto(),
    }
}

fn factory_for(args: &Args) -> Box<dyn EngineFactory> {
    match args.get("engine", "auto") {
        "host" => Box::new(HostEngineFactory),
        _ => auto_factory(xrcarbon::experiments::common::ARTIFACTS_DIR),
    }
}

fn cluster_for(args: &Args) -> anyhow::Result<Cluster> {
    let name = args.get("cluster", "5ai");
    Cluster::parse(name).ok_or_else(|| anyhow::anyhow!("unknown cluster '{name}'"))
}

/// Open the profile cache the CLI flags describe (`--cache-dir` plus the
/// optional `--cache-budget` eviction knob).
fn open_cache(args: &Args) -> anyhow::Result<Option<ProfileCache>> {
    let budget = match args.options.get("cache-budget") {
        Some(s) => Some(parse_cache_budget(s)?),
        None => None,
    };
    match args.options.get("cache-dir") {
        Some(dir) => Ok(Some(ProfileCache::open_with(
            dir,
            CacheConfig { budget_bytes: budget, ..CacheConfig::default() },
        )?)),
        None => {
            if budget.is_some() {
                anyhow::bail!("--cache-budget requires --cache-dir");
            }
            Ok(None)
        }
    }
}

/// One preset sweep, resumable when a cache is in play.
#[allow(clippy::too_many_arguments)]
fn preset_sweep(
    factory: &dyn EngineFactory,
    base: &EvalRequest,
    grid: &ScenarioGrid,
    threads: usize,
    cache: Option<&ProfileCache>,
    resume: Option<&SweepCheckpoint>,
    save_to: Option<&Path>,
) -> anyhow::Result<SweepOutcome> {
    let cfg = SweepConfig { threads };
    match cache {
        Some(cache) => Ok(sweep_resumable(factory, base, grid, &cfg, cache, resume, save_to)?),
        None => Ok(sweep_with_cache(factory, base, grid, &cfg, None)?),
    }
}

fn run_search(args: &Args) -> anyhow::Result<()> {
    // Scenario grids are fixed per search space; a silently ignored
    // --preset would hand back results for the wrong grid.
    if args.options.contains_key("preset") {
        anyhow::bail!("--preset is incompatible with --search (choose --space fig7|expanded)");
    }
    if args.options.contains_key("trace") {
        anyhow::bail!("--trace is incompatible with --search (trace scenarios ride the exhaustive sweep: --preset trace)");
    }
    let factory = factory_for(args);
    println!("[engine: {}]", factory.label());
    let space_name = args.get("space", "fig7").to_string();

    // --resume continues an interrupted run from its checkpoint;
    // --cache-dir makes this run interruptible by persisting one after
    // every generation.
    let resume = match args.options.get("resume") {
        Some(path) => {
            let ck = read_checkpoint(path)?;
            println!(
                "[resume] {path}: {} evaluation(s), generation {}",
                ck.evaluated.len(),
                ck.generations
            );
            Some(ck)
        }
        None => None,
    };
    // Without explicit flags, a resumed run inherits the checkpoint's
    // seed and budget: forgetting --seed must not fail the resume (the
    // checkpoint already stores it — a *wrong* explicit seed still
    // errors), and forgetting --max-evals must not silently uncap a
    // capped search (passing a larger value is the budget-extension
    // path).
    let default_seed = resume.as_ref().map(|ck| ck.seed).unwrap_or(0xC0FFEE);
    let default_max_evals = resume.as_ref().map(|ck| ck.max_evals).unwrap_or(0);
    let cfg = SearchConfig {
        threads: args.get_usize("threads", 0)?,
        seed: args.get_u64("seed", default_seed)?,
        max_evals: args.get_usize("max-evals", default_max_evals)?,
        ..SearchConfig::default()
    };
    // --cache-dir does double duty under --search: profile cache for
    // every profile phase AND the checkpoint sink. open_cache() creates
    // the directory, so the checkpoint path's parent exists before the
    // first write; --cache-budget applies to the profile cache here too.
    let cache = open_cache(args)?;
    let save_to: Option<PathBuf> = match args.options.get("cache-dir") {
        Some(dir) => Some(Path::new(dir).join(format!("search_{space_name}.ckpt.json"))),
        // A resumed run without --cache-dir keeps checkpointing to
        // the file it resumed from — a second interrupt must not
        // lose the progress made since the first one.
        None => args.options.get("resume").map(PathBuf::from),
    };
    let cache = cache.as_ref();

    match space_name.as_str() {
        "fig7" => {
            // Anchor mode: exhaustive reference + search on the 121 grid.
            let f = search_fig7::run_resumable(
                factory.as_ref(),
                cluster_for(args)?,
                &cfg,
                resume.as_ref(),
                save_to.as_deref(),
                cache,
            )?;
            emit(args, "search_fig7", &f.table)?;
            print!("{}", search_archive_table(&f.outcome).render());
        }
        "expanded" => {
            let f = search_fig7::run_expanded_resumable(
                factory.as_ref(),
                cluster_for(args)?,
                &cfg,
                resume.as_ref(),
                save_to.as_deref(),
                cache,
            )?;
            emit(args, "search_expanded", &f.table)?;
            print!("{}", f.archive_table.render());
        }
        other => anyhow::bail!("unknown search space '{other}' (fig7|expanded)"),
    }
    if let Some(path) = &save_to {
        println!("[checkpoint] wrote {}", path.display());
    }
    Ok(())
}

fn run_sweep(args: &Args) -> anyhow::Result<()> {
    if args.has_flag("search") {
        return run_search(args);
    }
    // Search-only options must not be silently ignored on the exhaustive
    // path (plain sweeps are deterministic without a seed); `--resume`
    // without `--search` is the *sweep-phase* resume below.
    for opt in ["space", "max-evals", "seed"] {
        if args.options.contains_key(opt) {
            anyhow::bail!("--{opt} requires --search (within the sweep subcommand)");
        }
    }
    let factory = factory_for(args);
    println!("[engine: {}]", factory.label());
    let threads = args.get_usize("threads", 0)?;
    // Persistent profile cache: repeat sweeps over the same design space
    // skip every phase-A engine contraction (the table title proves it).
    let cache = open_cache(args)?;
    let cache = cache.as_ref();
    let preset = args.get("preset", "fig7").to_string();
    // A --trace silently dropped by a non-trace preset would hand back
    // results for the wrong scenario grid.
    if args.options.contains_key("trace") && preset != "trace" {
        anyhow::bail!("--trace requires --preset trace");
    }
    // Sweep-phase checkpointing: with a cache, phase-A progress persists
    // per chunk batch and `--resume` continues an interrupted run
    // bit-identically (the checkpoint's fingerprint rejects a different
    // space/grid/engine/cluster).
    let resume = match args.options.get("resume") {
        Some(path) => {
            if cache.is_none() {
                anyhow::bail!(
                    "--resume without --search resumes the sweep phase and requires \
                     --cache-dir (completed chunks are re-read from the profile cache)"
                );
            }
            let ck = read_sweep_checkpoint(path)?;
            println!("[resume] {path}: {}/{} chunk(s) done", ck.chunks_done, ck.total_chunks);
            Some(ck)
        }
        None => None,
    };
    let save_to: Option<PathBuf> = args
        .options
        .get("cache-dir")
        .map(|dir| Path::new(dir).join(format!("sweep_{preset}.ckpt.json")));
    let resume = resume.as_ref();
    let save_to = save_to.as_deref();
    match preset.as_str() {
        "fig7" => {
            let f = sweep_fig7::run_resumable(
                factory.as_ref(),
                cluster_for(args)?,
                threads,
                cache,
                resume,
                save_to,
            )?;
            emit(args, "sweep_fig7", &f.table)?;
            print!("{}", sweep_best_table(&f.outcome).render());
        }
        "fig10" | "lifetime" => {
            let space = sweep_fig7::profile_cluster(cluster_for(args)?);
            let grid = ScenarioGrid::lifetime_decades(3, 8);
            let out = preset_sweep(
                factory.as_ref(),
                &space.base,
                &grid,
                threads,
                cache,
                resume,
                save_to,
            )?;
            emit(args, "sweep_fig10", &sweep_table(&out))?;
            print!("{}", sweep_best_table(&out).render());
        }
        "ci" => {
            let space = sweep_fig7::profile_cluster(cluster_for(args)?);
            // The CI axis does not override lifetime, so replace the
            // preset placeholder with a concrete 2-year operational life.
            let mut base = space.base.clone();
            base.lifetime_s = 2.0 * xrcarbon::dse::grid::YEAR_S;
            let grid = ScenarioGrid::use_grids();
            let out =
                preset_sweep(factory.as_ref(), &base, &grid, threads, cache, resume, save_to)?;
            emit(args, "sweep_ci", &sweep_table(&out))?;
            print!("{}", sweep_best_table(&out).render());
        }
        "fig11" => {
            // One task per app and T_PAD = 8: sweep the top-4 apps jointly
            // (Fig 11 proper iterates apps one at a time — see fig11).
            let apps = xrcarbon::workloads::top10_apps();
            let base = xrcarbon::experiments::common::provisioning_request(
                &apps[..4],
                &xrcarbon::soc::VrSoc::default(),
                2.0 * xrcarbon::dse::grid::YEAR_S,
                true,
            );
            let grid = ScenarioGrid::fig11();
            let out =
                preset_sweep(factory.as_ref(), &base, &grid, threads, cache, resume, save_to)?;
            emit(args, "sweep_fig11", &sweep_table(&out))?;
            print!("{}", sweep_best_table(&out).render());
        }
        "trace" => {
            let space = sweep_fig7::profile_cluster(cluster_for(args)?);
            // Traces override CI but not lifetime: pin a 2-year life.
            let mut base = space.base.clone();
            base.lifetime_s = 2.0 * xrcarbon::dse::grid::YEAR_S;
            let grid = match args.options.get("trace") {
                Some(name) => {
                    let trace = xrcarbon::carbon::CiTrace::by_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown trace '{name}' (known: {})",
                            xrcarbon::carbon::CiTrace::preset_names().join(", ")
                        )
                    })?;
                    ScenarioGrid::new().with_trace(&format!("trace={name}"), trace)
                }
                None => xrcarbon::experiments::trace_study::trace_grid(),
            };
            let out =
                preset_sweep(factory.as_ref(), &base, &grid, threads, cache, resume, save_to)?;
            emit(args, "sweep_trace", &sweep_table(&out))?;
            print!("{}", trace_table(&out).render());
            print!("{}", sweep_best_table(&out).render());
        }
        other => {
            anyhow::bail!("unknown sweep preset '{other}' (fig7|fig10|lifetime|fig11|ci|trace)")
        }
    }
    Ok(())
}

fn run_serve(args: &Args) -> anyhow::Result<()> {
    let state_dir = args.options.get("state-dir").ok_or_else(|| {
        anyhow::anyhow!(
            "serve requires --state-dir DIR (job specs, checkpoints and results live there)"
        )
    })?;
    let cache_budget = match args.options.get("cache-budget") {
        Some(s) => Some(parse_cache_budget(s)?),
        None => None,
    };
    let cfg = xrcarbon::service::ServiceConfig {
        state_dir: PathBuf::from(state_dir),
        cache_dir: args.options.get("cache-dir").map(PathBuf::from),
        cache_budget,
        threads: args.get_usize("threads", 0)?,
        engine: args.get("engine", "auto").to_string(),
        auth_token: args.options.get("auth-token").cloned(),
    };
    let service = std::sync::Arc::new(xrcarbon::service::Service::open(cfg)?);
    let addr = args.get("addr", "127.0.0.1:7878");
    let executors = args.get_usize("executors", 2)?.max(1);
    xrcarbon::service::serve(service, addr, executors)
}

fn emit(args: &Args, name: &str, table: &xrcarbon::report::Table) -> anyhow::Result<()> {
    print!("{}", table.render());
    if let Some(dir) = args.options.get("csv-dir") {
        let path = format!("{dir}/{name}.csv");
        write_csv(table, &path)?;
        println!("[csv] wrote {path}");
    }
    println!();
    Ok(())
}

fn run_one(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "fig1" => {
            let mut ctx = ctx_for(args);
            println!("[engine: {}]", ctx.backend);
            let f = fig01_metric_comparison::run(&mut ctx)?;
            emit(args, "fig1", &f.table)?;
        }
        "fig2" => {
            if !args.has_flag("socs") {
                emit(args, "fig2a", &fig02_retrospective::run_cpus().table)?;
            }
            if !args.has_flag("cpus") {
                emit(args, "fig2b", &fig02_retrospective::run_socs().table)?;
            }
        }
        "fig3" => emit(args, "fig3", &fig03_fleet_categories::run(&fleet_cfg(args)?).table)?,
        "fig4" => emit(
            args,
            "fig4",
            &fig04_power_embodied::run(&fleet_cfg(args)?, &xrcarbon::soc::VrSoc::default()).table,
        )?,
        "fig7" => {
            let mut ctx = ctx_for(args);
            println!("[engine: {}]", ctx.backend);
            emit(args, "fig7", &fig07_dse_clusters::run(ctx.engine.as_mut())?.table)?;
        }
        "fig8" => {
            let mut ctx = ctx_for(args);
            emit(args, "fig8", &fig08_tcdp_vs_edp::run(ctx.engine.as_mut())?.table)?;
        }
        "fig9" => emit(args, "fig9", &fig09_accelerators::run().table)?,
        "fig10" => {
            let mut ctx = ctx_for(args);
            let axis = fig10_lifetime_crossover::default_axis();
            emit(args, "fig10", &fig10_lifetime_crossover::run(ctx.engine.as_mut(), &axis)?.table)?;
        }
        "fig11" => {
            let mut ctx = ctx_for(args);
            emit(args, "fig11", &fig11_provisioning_savings::run(ctx.engine.as_mut())?.table)?;
        }
        "fig12" => emit(args, "fig12", &fig12_tlp_breakdown::run(&fleet_cfg(args)?).table)?,
        "fig13" => {
            let mut ctx = ctx_for(args);
            emit(args, "fig13", &fig13_core_configs::run(ctx.engine.as_mut())?.table)?;
        }
        "fig14" => emit(args, "fig14", &fig14_replacement::run().table)?,
        "fig15" => {
            let mut ctx = ctx_for(args);
            let w = xrcarbon::accel::Workload::parse(args.get("workload", "SR-512"))
                .ok_or_else(|| anyhow::anyhow!("unknown workload"))?;
            emit(args, "fig15", &fig15_stacking::run(ctx.engine.as_mut(), w)?.table)?;
        }
        "fig16" => {
            let mut ctx = ctx_for(args);
            emit(args, "fig16", &fig16_stacking_kernels::run(ctx.engine.as_mut())?.table)?;
        }
        "table5" => emit(args, "table5", &table5_vr_soc::run().table)?,
        "sweep" => run_sweep(args)?,
        "serve" => run_serve(args)?,
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.command.clone() else {
        println!("{USAGE}");
        return Ok(());
    };
    if args.has_flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    if cmd == "all" {
        for c in [
            "table5", "fig1", "fig2", "fig3", "fig4", "fig9", "fig12", "fig14", "fig13",
            "fig11", "fig10", "fig15", "fig16", "fig8", "fig7", "sweep",
        ] {
            println!("===== {c} =====");
            run_one(c, &args)?;
        }
        return Ok(());
    }
    run_one(&cmd, &args)
}
