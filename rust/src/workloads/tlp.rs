//! Thread-level parallelism math (paper Fig 12, footnote 5).
//!
//! The paper quantifies TLP as `TLP = Σᵢ cᵢ·i / (1 − c₀)` where `cᵢ` is
//! the fraction of time exactly `i` cores are concurrently busy. The same
//! distribution drives the core-count provisioning study (Fig 13): with
//! fewer cores than runnable threads, runnable work serializes and the
//! frame rate drops.

/// Distribution of concurrently-busy core counts (index = #busy cores,
/// 0..=8 for the octa-core VR SoC).
#[derive(Debug, Clone, PartialEq)]
pub struct TlpDistribution {
    /// `frac[i]` = fraction of wall time with exactly `i` cores busy.
    /// Must sum to 1.
    pub frac: [f64; 9],
}

impl TlpDistribution {
    /// Construct and validate (sums to 1 within tolerance).
    pub fn new(frac: [f64; 9]) -> Self {
        let sum: f64 = frac.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "TLP distribution sums to {sum}, not 1");
        assert!(frac.iter().all(|&f| f >= 0.0), "negative TLP fraction");
        TlpDistribution { frac }
    }

    /// The paper's average TLP: `Σᵢ cᵢ·i / (1 − c₀)` (busy-time average).
    pub fn average(&self) -> f64 {
        let busy: f64 = self.frac.iter().enumerate().map(|(i, &f)| i as f64 * f).sum();
        let denom = 1.0 - self.frac[0];
        if denom <= 0.0 {
            0.0
        } else {
            busy / denom
        }
    }

    /// Execution-time stretch when only `cores` are enabled: intervals
    /// with `i > cores` busy threads serialize by `i / cores`
    /// (work-conserving scheduler, perfectly divisible work).
    pub fn slowdown(&self, cores: usize) -> f64 {
        assert!(cores >= 1, "need at least one core");
        self.frac
            .iter()
            .enumerate()
            .map(|(i, &f)| if i <= cores { f } else { f * i as f64 / cores as f64 })
            .sum()
    }

    /// Frame rate with `cores` enabled, given the rate on all 8 cores.
    pub fn fps(&self, fps_all_cores: f64, cores: usize) -> f64 {
        fps_all_cores / self.slowdown(cores)
    }

    /// Smallest core count whose frame rate still meets `qos_fps`.
    /// Returns 8 if even the full configuration misses QoS.
    pub fn min_cores_for_qos(&self, fps_all_cores: f64, qos_fps: f64) -> usize {
        for c in 1..=8 {
            if self.fps(fps_all_cores, c) >= qos_fps {
                return c;
            }
        }
        8
    }

    /// Average number of busy cores (including idle time) — the CPU-side
    /// hardware utilization used for the Fig 4 embodied split.
    pub fn mean_busy_cores(&self) -> f64 {
        self.frac.iter().enumerate().map(|(i, &f)| i as f64 * f).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_busy(i: usize) -> TlpDistribution {
        let mut f = [0.0; 9];
        f[i] = 1.0;
        TlpDistribution::new(f)
    }

    #[test]
    fn average_matches_footnote_formula() {
        // 50% idle, 50% at 4 cores: TLP = (4*0.5)/(1-0.5) = 4.
        let mut f = [0.0; 9];
        f[0] = 0.5;
        f[4] = 0.5;
        let d = TlpDistribution::new(f);
        assert!((d.average() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn slowdown_is_one_with_enough_cores() {
        let d = uniform_busy(4);
        assert!((d.slowdown(4) - 1.0).abs() < 1e-12);
        assert!((d.slowdown(8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slowdown_serializes_excess_threads() {
        let d = uniform_busy(8);
        assert!((d.slowdown(4) - 2.0).abs() < 1e-12);
        assert!((d.slowdown(2) - 4.0).abs() < 1e-12);
        assert!((d.slowdown(1) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn slowdown_monotone_decreasing_in_cores() {
        let mut f = [0.0; 9];
        f[0] = 0.1;
        f[2] = 0.3;
        f[5] = 0.4;
        f[8] = 0.2;
        let d = TlpDistribution::new(f);
        let mut last = f64::INFINITY;
        for c in 1..=8 {
            let s = d.slowdown(c);
            assert!(s <= last + 1e-12);
            last = s;
        }
        assert!((d.slowdown(8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn qos_core_search() {
        let mut f = [0.0; 9];
        f[4] = 0.8;
        f[8] = 0.2;
        let d = TlpDistribution::new(f);
        // fps_8 = 80, QoS 72: slowdown(c) must be <= 80/72 = 1.111.
        // slowdown(4) = 0.8 + 0.2*2 = 1.2 (miss); slowdown(5) = 0.8+0.2*1.6
        // = 1.12 (miss); slowdown(6) = 0.8+0.2*8/6 = 1.0667 (hit).
        assert_eq!(d.min_cores_for_qos(80.0, 72.0), 6);
    }

    #[test]
    fn qos_unreachable_returns_eight() {
        let d = uniform_busy(8);
        assert_eq!(d.min_cores_for_qos(60.0, 72.0), 8);
    }

    #[test]
    #[should_panic(expected = "sums to")]
    fn bad_distribution_rejected() {
        TlpDistribution::new([0.5; 9]);
    }
}
