//! VR application workloads and fleet telemetry (paper §2.2, §4.1, §4.3).
//!
//! The paper characterizes the top-100 applications running on deployed
//! Meta Quest / Quest 2 headsets via adb + Simpleperf + Perfetto. That
//! telemetry is proprietary, so this module implements the substitution
//! documented in `DESIGN.md` §4: a **seeded synthetic fleet generator**
//! ([`fleet`]) whose per-app distributions are calibrated to the
//! aggregates the paper publishes (top-10 apps ≥ 85 % of compute cycles,
//! mean power ≈ 70 % of TDP, per-app TLP between 3.5 and 4.2), plus the
//! same aggregation pipeline the paper ran on the real data.
//!
//! * [`apps`] — the top-10 named applications (categories G/SG/B/M) with
//!   power and thread-level-parallelism distributions;
//! * [`tlp`] — TLP math: average TLP (the paper's footnote-5 formula),
//!   core-count slowdown and FPS models;
//! * [`fleet`] — synthetic deployed-fleet trace generation + aggregation;
//! * [`clusters`] — the Table 4 DSE kernel clusters.

pub mod apps;
pub mod clusters;
pub mod fleet;
pub mod tlp;

pub use apps::{top10_apps, AppCategory, VrApp};
pub use clusters::{Cluster, cluster_workloads};
pub use fleet::{generate_fleet, regional_usage_shares, FleetConfig, FleetSummary};
pub use tlp::TlpDistribution;
