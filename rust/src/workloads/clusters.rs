//! The Table 4 design-space-exploration kernel clusters.

use crate::accel::Workload;

/// A DSE workload cluster (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Cluster {
    /// Every kernel in Table 3 (the "All" normalization baseline of Fig 7).
    All,
    /// 10 XR-dominant kernels.
    XrDominant10,
    /// 10 AI-dominant kernels.
    AiDominant10,
    /// 5 XR kernels.
    Xr5,
    /// 5 AI kernels.
    Ai5,
}

impl Cluster {
    /// Figure 7 x-axis order.
    pub const ALL: [Cluster; 5] = [
        Cluster::All,
        Cluster::XrDominant10,
        Cluster::AiDominant10,
        Cluster::Xr5,
        Cluster::Ai5,
    ];

    /// Display label.
    pub fn label(self) -> &'static str {
        match self {
            Cluster::All => "All",
            Cluster::XrDominant10 => "10 XR-dominant",
            Cluster::AiDominant10 => "10 AI-dominant",
            Cluster::Xr5 => "5 XR",
            Cluster::Ai5 => "5 AI",
        }
    }

    /// Parse a CLI name ("all", "10xr", "10ai", "5xr", "5ai").
    pub fn parse(s: &str) -> Option<Cluster> {
        match s.to_ascii_lowercase().as_str() {
            "all" => Some(Cluster::All),
            "10xr" | "xr10" => Some(Cluster::XrDominant10),
            "10ai" | "ai10" => Some(Cluster::AiDominant10),
            "5xr" | "xr5" => Some(Cluster::Xr5),
            "5ai" | "ai5" => Some(Cluster::Ai5),
            _ => None,
        }
    }
}

/// The kernels in a cluster, exactly as listed in Table 4.
pub fn cluster_workloads(c: Cluster) -> Vec<Workload> {
    use Workload::*;
    match c {
        Cluster::All => Workload::ALL.to_vec(),
        Cluster::XrDominant10 => {
            vec![Agg3d, Et, Jlp, Hrn, Unet, EFan, Dn, Sr256, Sr512, Sr1024]
        }
        Cluster::AiDominant10 => {
            vec![Rn18, Rn50, Rn152, Gn, Mn2, Agg3d, Et, Unet, Jlp, Hrn]
        }
        Cluster::Xr5 => vec![Agg3d, Hrn, Dn, Sr512, Sr1024],
        Cluster::Ai5 => vec![Rn18, Rn50, Rn152, Gn, Mn2],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_sizes_match_table4() {
        assert_eq!(cluster_workloads(Cluster::All).len(), 15);
        assert_eq!(cluster_workloads(Cluster::XrDominant10).len(), 10);
        assert_eq!(cluster_workloads(Cluster::AiDominant10).len(), 10);
        assert_eq!(cluster_workloads(Cluster::Xr5).len(), 5);
        assert_eq!(cluster_workloads(Cluster::Ai5).len(), 5);
    }

    #[test]
    fn ai5_is_pure_ai() {
        assert!(cluster_workloads(Cluster::Ai5).iter().all(|w| !w.is_xr()));
    }

    #[test]
    fn xr5_is_pure_xr() {
        assert!(cluster_workloads(Cluster::Xr5).iter().all(|w| w.is_xr()));
    }

    #[test]
    fn ai_dominant_is_half_ai() {
        let ws = cluster_workloads(Cluster::AiDominant10);
        let ai = ws.iter().filter(|w| !w.is_xr()).count();
        assert_eq!(ai, 5);
    }

    #[test]
    fn parse_roundtrip() {
        for c in Cluster::ALL {
            let s = match c {
                Cluster::All => "all",
                Cluster::XrDominant10 => "10xr",
                Cluster::AiDominant10 => "10ai",
                Cluster::Xr5 => "5xr",
                Cluster::Ai5 => "5ai",
            };
            assert_eq!(Cluster::parse(s), Some(c));
        }
        assert_eq!(Cluster::parse("bogus"), None);
    }
}
