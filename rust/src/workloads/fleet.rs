//! Synthetic deployed-fleet telemetry (substitute for the paper's
//! adb/Simpleperf/Perfetto measurements on in-the-wild Quest devices).
//!
//! A seeded generator produces per-device sessions: app selection follows
//! a Zipf popularity law over a 100-app catalog (the top 10 are the named
//! apps of [`super::apps`], the tail is synthesized per category), session
//! lengths and power draws are truncated normals, and per-second TLP
//! states are sampled from each app's busy-core distribution. The
//! aggregation pipeline then computes exactly what the paper reports:
//! compute-cycle shares (Fig 3), per-app power percentiles (Fig 4) and
//! TLP time breakdowns (Fig 12).

use super::apps::{top10_apps, AppCategory, VrApp};
use super::tlp::TlpDistribution;
use crate::testkit::Rng;

/// Fleet-generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Number of simulated devices.
    pub devices: usize,
    /// Observation window, days.
    pub days: usize,
    /// Mean sessions per device-day.
    pub sessions_per_day: f64,
    /// Mean session length, minutes.
    pub session_minutes: f64,
    /// Zipf exponent for app popularity (calibrated so the top-10 share
    /// lands at the paper's ≥ 85 %).
    pub zipf_s: f64,
    /// Headset TDP, W.
    pub tdp_w: f64,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            devices: 400,
            days: 30,
            sessions_per_day: 1.2,
            session_minutes: 38.0,
            zipf_s: 1.6,
            tdp_w: 8.3,
            seed: 0x5EED,
        }
    }
}

/// Aggregated per-app statistics.
#[derive(Debug, Clone)]
pub struct AppStats {
    /// App name.
    pub name: String,
    /// Category.
    pub category: AppCategory,
    /// Share of fleet compute cycles (0..1).
    pub cycle_share: f64,
    /// Power stats as fractions of TDP: (p5, mean, p95).
    pub power_frac: (f64, f64, f64),
    /// Observed busy-core distribution.
    pub tlp: TlpDistribution,
    /// Observed GPU busy fraction.
    pub gpu_util: f64,
}

/// Fleet aggregation output.
#[derive(Debug, Clone)]
pub struct FleetSummary {
    /// Per-app stats, catalog order (index 0..9 = named top-10 apps, then
    /// the synthesized tail).
    pub apps: Vec<AppStats>,
    /// Total observed session seconds.
    pub total_seconds: f64,
    /// Share of compute cycles covered by the top 10 apps.
    pub top10_cycle_share: f64,
    /// Category share of the full catalog, by cycles: (G, SG, B, M).
    pub category_share: [f64; 4],
}

/// Full 100-app catalog: the named top-10 plus a synthesized tail whose
/// category mix follows Fig 3 (gaming-heavy).
pub fn catalog(rng: &mut Rng) -> Vec<VrApp> {
    let mut apps = top10_apps();
    let categories = [
        (AppCategory::Gaming, 0.48),
        (AppCategory::SocialGaming, 0.22),
        (AppCategory::Browser, 0.12),
        (AppCategory::Media, 0.18),
    ];
    let weights: Vec<f64> = categories.iter().map(|&(_, w)| w).collect();
    for i in 10..100 {
        let (cat, _) = categories[rng.categorical(&weights)];
        let power = rng.truncated_normal(0.66, 0.08, 0.35, 0.95);
        // Tail apps reuse a representative TLP shape per category, jittered.
        let base = match cat {
            AppCategory::Gaming => [0.09, 0.0, 0.11, 0.22, 0.30, 0.17, 0.11, 0.0, 0.0],
            AppCategory::SocialGaming => [0.10, 0.05, 0.10, 0.21, 0.26, 0.13, 0.09, 0.04, 0.02],
            AppCategory::Browser => [0.08, 0.06, 0.14, 0.18, 0.21, 0.10, 0.07, 0.12, 0.04],
            AppCategory::Media => [0.13, 0.10, 0.0, 0.33, 0.29, 0.09, 0.06, 0.0, 0.0],
        };
        let mut f = base;
        // Small deterministic jitter, renormalized.
        for x in f.iter_mut() {
            *x = (*x + rng.range(-0.01, 0.01)).max(0.0);
        }
        let sum: f64 = f.iter().sum();
        for x in f.iter_mut() {
            *x /= sum;
        }
        let name: &'static str = Box::leak(format!("{}-tail{}", cat.label(), i).into_boxed_str());
        apps.push(VrApp {
            name,
            category: cat,
            power_frac_mean: power,
            power_frac_std: 0.06,
            fps_all_cores: rng.range(72.0, 95.0),
            gpu_util: match cat {
                AppCategory::Gaming => rng.range(0.5, 0.75),
                AppCategory::SocialGaming => rng.range(0.4, 0.65),
                AppCategory::Browser => rng.range(0.2, 0.4),
                AppCategory::Media => rng.range(0.25, 0.45),
            },
            tlp: TlpDistribution::new(f),
        });
    }
    apps
}

/// Generate a fleet trace and aggregate it.
pub fn generate_fleet(cfg: &FleetConfig) -> FleetSummary {
    let mut rng = Rng::new(cfg.seed);
    let apps = catalog(&mut rng);
    let n_apps = apps.len();

    // Accumulators.
    let mut seconds = vec![0.0f64; n_apps];
    let mut cycles = vec![0.0f64; n_apps]; // busy-core-seconds (compute cycles proxy)
    let mut power_samples: Vec<Vec<f64>> = vec![Vec::new(); n_apps];
    let mut tlp_time = vec![[0.0f64; 9]; n_apps];
    let mut gpu_busy = vec![0.0f64; n_apps];

    for d in 0..cfg.devices {
        let mut dev_rng = rng.fork(d as u64);
        let n_sessions =
            (cfg.days as f64 * cfg.sessions_per_day * dev_rng.range(0.6, 1.4)).round() as usize;
        for _ in 0..n_sessions {
            let app_idx = dev_rng.zipf(n_apps, cfg.zipf_s);
            let app = &apps[app_idx];
            let dur_s = dev_rng.truncated_normal(
                cfg.session_minutes * 60.0,
                cfg.session_minutes * 25.0,
                300.0,
                4.0 * 3600.0,
            );
            seconds[app_idx] += dur_s;
            // One power observation per session (session-mean power).
            let p = dev_rng.truncated_normal(app.power_frac_mean, app.power_frac_std, 0.2, 1.0);
            power_samples[app_idx].push(p);
            // TLP states: sample the busy-core distribution in coarse slots
            // (one per simulated minute) instead of per second — the
            // aggregate converges identically and 60x cheaper.
            let slots = (dur_s / 60.0).ceil() as usize;
            for _ in 0..slots {
                let busy = dev_rng.categorical(&app.tlp.frac);
                tlp_time[app_idx][busy] += dur_s / slots as f64;
                cycles[app_idx] += busy as f64 * dur_s / slots as f64;
            }
            gpu_busy[app_idx] += app.gpu_util * dur_s;
        }
    }

    let total_seconds: f64 = seconds.iter().sum();
    let total_cycles: f64 = cycles.iter().sum();

    let mut stats = Vec::with_capacity(n_apps);
    for i in 0..n_apps {
        let mut ps = power_samples[i].clone();
        ps.sort_by(|a, b| a.total_cmp(b));
        let pct = |q: f64| -> f64 {
            if ps.is_empty() {
                return 0.0;
            }
            let idx = ((ps.len() - 1) as f64 * q).round() as usize;
            ps[idx]
        };
        let mean = if ps.is_empty() { 0.0 } else { ps.iter().sum::<f64>() / ps.len() as f64 };
        let t: f64 = tlp_time[i].iter().sum();
        let frac = if t > 0.0 {
            let mut f = [0.0; 9];
            for (j, &v) in tlp_time[i].iter().enumerate() {
                f[j] = v / t;
            }
            f
        } else {
            let mut f = [0.0; 9];
            f[0] = 1.0;
            f
        };
        stats.push(AppStats {
            name: apps[i].name.to_string(),
            category: apps[i].category,
            cycle_share: if total_cycles > 0.0 { cycles[i] / total_cycles } else { 0.0 },
            power_frac: (pct(0.05), mean, pct(0.95)),
            tlp: TlpDistribution::new(frac),
            gpu_util: if seconds[i] > 0.0 { gpu_busy[i] / seconds[i] } else { 0.0 },
        });
    }

    let top10_cycle_share = stats.iter().take(10).map(|s| s.cycle_share).sum();
    let mut category_share = [0.0; 4];
    for s in &stats {
        let k = match s.category {
            AppCategory::Gaming => 0,
            AppCategory::SocialGaming => 1,
            AppCategory::Browser => 2,
            AppCategory::Media => 3,
        };
        category_share[k] += s.cycle_share;
    }

    FleetSummary { apps: stats, total_seconds, top10_cycle_share, category_share }
}

/// Per-region usage shares for a deployed fleet: the fraction of total
/// expected session-seconds spent by devices homed in each of `regions`
/// grid regions.
///
/// Devices are assigned to regions with a mildly skewed popularity law
/// (region 0 is the largest market) and weighted by their expected usage,
/// so the shares feed directly into a [`crate::carbon::FleetMix`] — each
/// region carries its own carbon-intensity trace and the mix flattens to
/// a single usage-weighted trace. Deterministic in `cfg.seed`.
pub fn regional_usage_shares(cfg: &FleetConfig, regions: usize) -> Vec<f64> {
    assert!(regions > 0, "regional_usage_shares: need at least one region");
    let mut rng = Rng::new(cfg.seed ^ 0x9E67_0A5F_1D3C_8B24);
    let mut usage = vec![0.0f64; regions];
    for d in 0..cfg.devices {
        let mut dev_rng = rng.fork(d as u64);
        let region = dev_rng.zipf(regions, 1.1);
        let n_sessions = cfg.days as f64 * cfg.sessions_per_day * dev_rng.range(0.6, 1.4);
        let mean_s = cfg.session_minutes * 60.0 * dev_rng.range(0.7, 1.3);
        usage[region] += n_sessions * mean_s;
    }
    let total: f64 = usage.iter().sum();
    if total <= 0.0 {
        // Degenerate fleet (zero devices/days): fall back to uniform.
        return vec![1.0 / regions as f64; regions];
    }
    for u in usage.iter_mut() {
        *u /= total;
    }
    usage
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_fleet() -> FleetSummary {
        generate_fleet(&FleetConfig { devices: 120, days: 10, ..Default::default() })
    }

    #[test]
    fn top10_covers_85pct_of_cycles() {
        // Fig 3: "Top 10 applications cover >85% of the total compute
        // cycles".
        let s = small_fleet();
        assert!(s.top10_cycle_share > 0.80, "top-10 share = {}", s.top10_cycle_share);
    }

    #[test]
    fn gaming_is_dominant_category() {
        // Fig 3: gaming most dominant, then social gaming.
        let s = small_fleet();
        let [g, sg, b, m] = s.category_share;
        assert!(g > sg && g > b && g > m, "shares = {:?}", s.category_share);
        assert!(sg > b, "social {sg} !> browser {b}");
    }

    #[test]
    fn power_percentiles_bracket_mean() {
        let s = small_fleet();
        for a in s.apps.iter().take(10) {
            let (p5, mean, p95) = a.power_frac;
            assert!(p5 <= mean && mean <= p95, "{}: {:?}", a.name, a.power_frac);
            assert!((0.3..0.95).contains(&mean), "{} mean={}", a.name, mean);
        }
    }

    #[test]
    fn observed_tlp_matches_app_model() {
        // The aggregated busy-core distribution converges to the per-app
        // generator distribution.
        let s = small_fleet();
        let model = top10_apps();
        for (obs, m) in s.apps.iter().take(4).zip(model.iter().take(4)) {
            let d = (obs.tlp.average() - m.tlp.average()).abs();
            assert!(d < 0.35, "{}: observed {} vs model {}", m.name, obs.tlp.average(), m.tlp.average());
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = generate_fleet(&FleetConfig { devices: 40, days: 5, ..Default::default() });
        let b = generate_fleet(&FleetConfig { devices: 40, days: 5, ..Default::default() });
        assert_eq!(a.top10_cycle_share, b.top10_cycle_share);
        assert_eq!(a.total_seconds, b.total_seconds);
    }

    #[test]
    fn different_seed_changes_trace() {
        let a = generate_fleet(&FleetConfig { devices: 40, days: 5, ..Default::default() });
        let b = generate_fleet(&FleetConfig { devices: 40, days: 5, seed: 99, ..Default::default() });
        assert_ne!(a.total_seconds, b.total_seconds);
    }

    #[test]
    fn catalog_has_100_apps() {
        let mut rng = Rng::new(1);
        assert_eq!(catalog(&mut rng).len(), 100);
    }

    #[test]
    fn regional_shares_sum_to_one_and_are_deterministic() {
        let cfg = FleetConfig { devices: 80, days: 5, ..Default::default() };
        let a = regional_usage_shares(&cfg, 4);
        let b = regional_usage_shares(&cfg, 4);
        assert_eq!(a.len(), 4);
        assert_eq!(a, b);
        let sum: f64 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "shares sum to {sum}");
        assert!(a.iter().all(|&s| (0.0..=1.0).contains(&s)), "shares = {a:?}");
    }

    #[test]
    fn region_zero_is_the_largest_market() {
        let shares = regional_usage_shares(&FleetConfig::default(), 4);
        for (r, &s) in shares.iter().enumerate().skip(1) {
            assert!(shares[0] > s, "region 0 ({}) !> region {r} ({s})", shares[0]);
        }
    }

    #[test]
    fn zero_device_fleet_falls_back_to_uniform_shares() {
        let cfg = FleetConfig { devices: 0, ..Default::default() };
        let shares = regional_usage_shares(&cfg, 5);
        assert!(shares.iter().all(|&s| (s - 0.2).abs() < 1e-12), "{shares:?}");
    }

    #[test]
    #[should_panic(expected = "at least one region")]
    fn zero_regions_rejected() {
        regional_usage_shares(&FleetConfig::default(), 0);
    }
}
