//! The top-10 deployed VR applications (Figs 3, 4, 12, 13).
//!
//! Names follow the paper's anonymized scheme — `G-n` general gaming,
//! `SG-n` social gaming, `B-n & S-n` browser/virtual desktop (+ system
//! services), `M-n` streaming & media. Per-app power fractions and TLP
//! distributions are calibrated to the published aggregates: mean power
//! ≈ 70 % of the 8.3 W TDP, busy-time TLP between ≈ 3.5 and ≈ 4.15
//! (Fig 12), and the Fig 13 optimal core counts.

use super::tlp::TlpDistribution;

/// Application category (Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AppCategory {
    /// General gaming.
    Gaming,
    /// Social gaming.
    SocialGaming,
    /// Browser & virtual desktop (bundled with system services).
    Browser,
    /// Streaming & media.
    Media,
}

impl AppCategory {
    /// Short label used in figures ("G", "SG", "B", "M").
    pub fn label(self) -> &'static str {
        match self {
            AppCategory::Gaming => "G",
            AppCategory::SocialGaming => "SG",
            AppCategory::Browser => "B",
            AppCategory::Media => "M",
        }
    }
}

/// One deployed VR application.
#[derive(Debug, Clone)]
pub struct VrApp {
    /// Anonymized name ("G-2", "B-1 & S-1", ...).
    pub name: &'static str,
    /// Category.
    pub category: AppCategory,
    /// Mean power as a fraction of headset TDP (Fig 4 top).
    pub power_frac_mean: f64,
    /// Std-dev of the power fraction (drives the p5/p95 bars).
    pub power_frac_std: f64,
    /// Frame rate achieved with all 8 cores enabled.
    pub fps_all_cores: f64,
    /// GPU busy fraction (for the Fig 4 utilized/unused embodied split).
    pub gpu_util: f64,
    /// Concurrently-busy-core distribution (Fig 12).
    pub tlp: TlpDistribution,
}

/// QoS floor for the headset (Quest-class 72 Hz refresh).
pub const QOS_FPS: f64 = 72.0;

/// The top-10 application set, popularity order.
pub fn top10_apps() -> Vec<VrApp> {
    vec![
        VrApp {
            name: "G-1",
            category: AppCategory::Gaming,
            power_frac_mean: 0.74,
            power_frac_std: 0.06,
            fps_all_cores: 88.0,
            gpu_util: 0.68,
            tlp: TlpDistribution::new([0.08, 0.0, 0.10, 0.20, 0.30, 0.18, 0.10, 0.04, 0.0]),
        },
        VrApp {
            name: "G-2",
            category: AppCategory::Gaming,
            power_frac_mean: 0.72,
            power_frac_std: 0.05,
            fps_all_cores: 90.0,
            gpu_util: 0.66,
            tlp: TlpDistribution::new([0.08, 0.0, 0.08, 0.14, 0.64, 0.04, 0.02, 0.0, 0.0]),
        },
        VrApp {
            name: "SG-1",
            category: AppCategory::SocialGaming,
            power_frac_mean: 0.71,
            power_frac_std: 0.07,
            fps_all_cores: 75.0,
            gpu_util: 0.60,
            tlp: TlpDistribution::new([0.10, 0.04, 0.10, 0.19, 0.26, 0.13, 0.10, 0.05, 0.03]),
        },
        VrApp {
            name: "G-3",
            category: AppCategory::Gaming,
            power_frac_mean: 0.70,
            power_frac_std: 0.05,
            fps_all_cores: 92.0,
            gpu_util: 0.64,
            tlp: TlpDistribution::new([0.10, 0.0, 0.12, 0.24, 0.28, 0.16, 0.10, 0.0, 0.0]),
        },
        VrApp {
            name: "B-1 & S-1",
            category: AppCategory::Browser,
            power_frac_mean: 0.64,
            power_frac_std: 0.08,
            fps_all_cores: 74.0,
            gpu_util: 0.30,
            tlp: TlpDistribution::new([0.08, 0.06, 0.14, 0.16, 0.21, 0.10, 0.07, 0.14, 0.04]),
        },
        VrApp {
            name: "M-1",
            category: AppCategory::Media,
            power_frac_mean: 0.60,
            power_frac_std: 0.05,
            fps_all_cores: 85.0,
            gpu_util: 0.35,
            tlp: TlpDistribution::new([0.12, 0.10, 0.0, 0.32, 0.30, 0.10, 0.06, 0.0, 0.0]),
        },
        VrApp {
            name: "G-4",
            category: AppCategory::Gaming,
            power_frac_mean: 0.68,
            power_frac_std: 0.06,
            fps_all_cores: 86.0,
            gpu_util: 0.62,
            tlp: TlpDistribution::new([0.09, 0.0, 0.12, 0.22, 0.30, 0.17, 0.10, 0.0, 0.0]),
        },
        VrApp {
            name: "SG-2",
            category: AppCategory::SocialGaming,
            power_frac_mean: 0.69,
            power_frac_std: 0.07,
            fps_all_cores: 78.0,
            gpu_util: 0.55,
            tlp: TlpDistribution::new([0.10, 0.05, 0.10, 0.22, 0.26, 0.13, 0.09, 0.04, 0.01]),
        },
        VrApp {
            name: "M-2",
            category: AppCategory::Media,
            power_frac_mean: 0.58,
            power_frac_std: 0.05,
            fps_all_cores: 87.0,
            gpu_util: 0.33,
            tlp: TlpDistribution::new([0.14, 0.10, 0.0, 0.34, 0.28, 0.09, 0.05, 0.0, 0.0]),
        },
        VrApp {
            name: "G-5",
            category: AppCategory::Gaming,
            power_frac_mean: 0.73,
            power_frac_std: 0.06,
            fps_all_cores: 84.0,
            gpu_util: 0.65,
            tlp: TlpDistribution::new([0.08, 0.0, 0.11, 0.21, 0.30, 0.18, 0.12, 0.0, 0.0]),
        },
    ]
}

/// The four applications the paper profiles in depth (Figs 12/13).
pub fn fig12_apps() -> Vec<VrApp> {
    top10_apps()
        .into_iter()
        .filter(|a| matches!(a.name, "G-2" | "M-1" | "B-1 & S-1" | "SG-1"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_apps_and_categories() {
        let apps = top10_apps();
        assert_eq!(apps.len(), 10);
        let gaming = apps.iter().filter(|a| a.category == AppCategory::Gaming).count();
        let social = apps.iter().filter(|a| a.category == AppCategory::SocialGaming).count();
        // Fig 3: gaming dominates, then social gaming.
        assert!(gaming > social);
        assert!(social >= 2);
    }

    #[test]
    fn mean_power_near_70pct_of_tdp() {
        // Fig 4: "Most applications utilize approximately 70% of the
        // device's TDP budget".
        let apps = top10_apps();
        let mean: f64 = apps.iter().map(|a| a.power_frac_mean).sum::<f64>() / apps.len() as f64;
        assert!((0.62..0.75).contains(&mean), "mean power fraction = {mean}");
    }

    #[test]
    fn fig12_tlp_range() {
        // Paper: "TLP ranges from 3.52 to 4.15 ... with 3.9 average TLP."
        let apps = fig12_apps();
        assert_eq!(apps.len(), 4);
        let tlps: Vec<f64> = apps.iter().map(|a| a.tlp.average()).collect();
        for (a, t) in apps.iter().zip(&tlps) {
            assert!((3.4..4.3).contains(t), "{} TLP = {t}", a.name);
        }
        let avg = tlps.iter().sum::<f64>() / 4.0;
        assert!((3.7..4.1).contains(&avg), "average TLP = {avg}");
    }

    #[test]
    fn fig13_optimal_core_counts() {
        // Paper Fig 13 stars: 4-core for G-2 and M-1, 7-core for B-1 & S-1,
        // 6-core for SG-1 (QoS-preserving minimum).
        let apps = top10_apps();
        let min_cores = |name: &str| {
            let a = apps.iter().find(|a| a.name == name).unwrap();
            a.tlp.min_cores_for_qos(a.fps_all_cores, QOS_FPS)
        };
        assert_eq!(min_cores("G-2"), 4);
        assert_eq!(min_cores("M-1"), 4);
        assert_eq!(min_cores("B-1 & S-1"), 7);
        assert_eq!(min_cores("SG-1"), 6);
    }

    #[test]
    fn at_least_three_cores_idle_on_average() {
        // Fig 12 discussion: "There are at least three unused cores at any
        // point in time" — mean busy cores ≤ 5 for every profiled app.
        for a in fig12_apps() {
            assert!(a.tlp.mean_busy_cores() <= 5.0, "{} busy={}", a.name, a.tlp.mean_busy_cores());
        }
    }

    #[test]
    fn all_apps_meet_qos_at_full_core_count() {
        for a in top10_apps() {
            assert!(a.fps_all_cores >= QOS_FPS, "{} below QoS at 8 cores", a.name);
        }
    }
}
