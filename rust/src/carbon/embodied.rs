//! Embodied carbon: the ACT die equation, multi-die designs (chiplets and
//! 3D stacks), and provisioning-aware component vectors (§3.3.3).

use super::intensity::FabGrid;
use super::process::ProcessNode;
use super::yield_model::YieldModel;

/// One die in a design (monolithic part, chiplet, or a layer of a 3D
/// stack).
#[derive(Debug, Clone)]
pub struct Die {
    /// Descriptive name ("logic", "sram-l1", "ccd0", ...).
    pub name: String,
    /// Die area in cm².
    pub area_cm2: f64,
    /// Technology node the die is fabbed on.
    pub node: ProcessNode,
    /// Yield model for this die.
    pub yield_model: YieldModel,
}

impl Die {
    /// Convenience constructor.
    pub fn new(name: &str, area_cm2: f64, node: ProcessNode, yield_model: YieldModel) -> Self {
        Die { name: name.to_string(), area_cm2, node, yield_model }
    }

    /// Embodied carbon of this die in gCO₂e for a given fab grid:
    /// `(CI_fab·EPA + GPA + MPA) × A / Y(A)`.
    pub fn embodied_g(&self, grid: FabGrid) -> f64 {
        let y = self.yield_model.yield_for(self.area_cm2);
        self.node.carbon_per_cm2(grid, y) * self.area_cm2
    }
}

/// A chip design: one or more dies plus a packaging overhead factor.
///
/// Chiplet CPUs (Fig 2's AMD parts) and the paper's 3D-stacked
/// accelerators (§5.6) are both multi-die designs; for the 3D study the
/// paper states TSV/stacking carbon is excluded, which corresponds to
/// `packaging_overhead = 0`.
#[derive(Debug, Clone)]
pub struct ChipDesign {
    /// Design name.
    pub name: String,
    /// Constituent dies.
    pub dies: Vec<Die>,
    /// Fab grid the dies are manufactured on.
    pub fab_grid: FabGrid,
    /// Extra embodied carbon for packaging/assembly as a fraction of die
    /// carbon (0 = ignore, matching the paper's 3D assumption).
    pub packaging_overhead: f64,
}

impl ChipDesign {
    /// Single-die design helper.
    pub fn monolithic(name: &str, area_cm2: f64, node: ProcessNode, y: YieldModel, grid: FabGrid) -> Self {
        ChipDesign {
            name: name.to_string(),
            dies: vec![Die::new(name, area_cm2, node, y)],
            fab_grid: grid,
            packaging_overhead: 0.0,
        }
    }

    /// Total embodied carbon in gCO₂e.
    pub fn embodied_g(&self) -> f64 {
        let dies: f64 = self.dies.iter().map(|d| d.embodied_g(self.fab_grid)).sum();
        dies * (1.0 + self.packaging_overhead)
    }

    /// Total silicon area (cm²), across all dies.
    pub fn total_area_cm2(&self) -> f64 {
        self.dies.iter().map(|d| d.area_cm2).sum()
    }

    /// Footprint area (cm²): max die area — the 2D outline a stacked design
    /// occupies (form-factor constraint of §5.6).
    pub fn footprint_cm2(&self) -> f64 {
        self.dies.iter().map(|d| d.area_cm2).fold(0.0, f64::max)
    }
}

/// Stand-alone ACT embodied equation (gCO₂e) for callers that do not need
/// the [`Die`] struct.
pub fn embodied_carbon(node: ProcessNode, grid: FabGrid, area_cm2: f64, yield_frac: f64) -> f64 {
    node.carbon_per_cm2(grid, yield_frac) * area_cm2
}

/// Overall embodied carbon of a provisioned system (§3.3.3):
/// `[C_emb,x1 … C_emb,xi] × online-mask`, where the mask marks components
/// that are actually powered/provisioned (1) versus dark silicon that a
/// carbon-aware design would not have paid for (0).
///
/// Panics if the vectors disagree in length or the mask has entries
/// outside [0, 1] (fractional provisioning is allowed — e.g. a core online
/// for part of the product's life).
pub fn provisioned_embodied_g(per_component_g: &[f64], online: &[f64]) -> f64 {
    assert_eq!(per_component_g.len(), online.len(), "component/mask length mismatch");
    per_component_g
        .iter()
        .zip(online)
        .map(|(&c, &m)| {
            assert!((0.0..=1.0).contains(&m), "mask entry {m} outside [0,1]");
            assert!(c >= 0.0, "negative embodied carbon");
            c * m
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vr_soc_cpu_dies() -> (Die, Die) {
        // Table 5: gold cores 0.3 cm², silver 0.15 cm², 7nm, 85% yield.
        let gold = Die::new("cpu-gold", 0.3, ProcessNode::N7, YieldModel::Fixed(0.85));
        let silver = Die::new("cpu-silver", 0.15, ProcessNode::N7, YieldModel::Fixed(0.85));
        (gold, silver)
    }

    #[test]
    fn table5_gold_and_silver() {
        let (gold, silver) = vr_soc_cpu_dies();
        assert!((gold.embodied_g(FabGrid::Coal) - 895.89).abs() < 0.5);
        assert!((silver.embodied_g(FabGrid::Coal) - 447.94).abs() < 0.3);
    }

    #[test]
    fn embodied_scales_linearly_with_area_at_fixed_yield() {
        let a = embodied_carbon(ProcessNode::N7, FabGrid::Coal, 1.0, 0.85);
        let b = embodied_carbon(ProcessNode::N7, FabGrid::Coal, 2.0, 0.85);
        assert!((b / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn chiplet_design_beats_monolithic_with_murphy_yield() {
        // Re-partitioning a large die into 4 chiplets raises yield and
        // lowers total embodied carbon (the paper's AMD observation).
        let grid = FabGrid::Taiwan;
        let m = YieldModel::Murphy { d0: 0.15 };
        let mono = ChipDesign::monolithic("mono", 6.0, ProcessNode::N14, m, grid);
        let chiplet = ChipDesign {
            name: "chiplet".into(),
            dies: (0..4)
                .map(|i| Die::new(&format!("ccd{i}"), 1.5, ProcessNode::N14, m))
                .collect(),
            fab_grid: grid,
            packaging_overhead: 0.05,
        };
        assert!(chiplet.embodied_g() < mono.embodied_g());
        assert_eq!(chiplet.total_area_cm2(), mono.total_area_cm2());
    }

    #[test]
    fn stacked_design_footprint_is_max_die() {
        let grid = FabGrid::Coal;
        let stack = ChipDesign {
            name: "3d".into(),
            dies: vec![
                Die::new("logic", 0.5, ProcessNode::N7, YieldModel::Fixed(0.9)),
                Die::new("sram", 0.4, ProcessNode::N7, YieldModel::Fixed(0.95)),
            ],
            fab_grid: grid,
            packaging_overhead: 0.0,
        };
        assert!((stack.footprint_cm2() - 0.5).abs() < 1e-12);
        assert!((stack.total_area_cm2() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn provisioning_masks_components() {
        let comps = [100.0, 200.0, 300.0];
        assert_eq!(provisioned_embodied_g(&comps, &[1.0, 1.0, 1.0]), 600.0);
        assert_eq!(provisioned_embodied_g(&comps, &[1.0, 0.0, 1.0]), 400.0);
        assert_eq!(provisioned_embodied_g(&comps, &[0.5, 0.0, 0.0]), 50.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn provisioning_length_mismatch_panics() {
        provisioned_embodied_g(&[1.0], &[1.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn provisioning_bad_mask_panics() {
        provisioned_embodied_g(&[1.0], &[1.5]);
    }
}
