//! Die-yield and gross-die-per-wafer models (paper §4.2: "incorporated more
//! die placement and yield models [15, 35]").
//!
//! * Murphy's model \[Murphy '64\]: `Y = ((1 − e^{−AD}) / (AD))²`
//! * Negative binomial (clustered defects): `Y = (1 + AD/α)^{−α}`
//! * Fixed yield (the paper's 80 % CPU / 85 % VR SoC assumptions)
//! * de Vries \[TSM '05\] gross-die-per-wafer placement formula.

/// Die-yield model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum YieldModel {
    /// Constant yield irrespective of area (the paper's retrospective
    /// analysis uses fixed 80 % for monolithic CPUs and 85 % for the VR
    /// SoC).
    Fixed(f64),
    /// Murphy's 1964 model with defect density `d0` (defects/cm²).
    Murphy { d0: f64 },
    /// Negative-binomial model with defect density `d0` and clustering
    /// parameter `alpha` (α→∞ recovers Poisson).
    NegBinomial { d0: f64, alpha: f64 },
}

impl YieldModel {
    /// Yield fraction in (0, 1] for a die of `area_cm2`.
    pub fn yield_for(self, area_cm2: f64) -> f64 {
        assert!(area_cm2 >= 0.0, "area must be non-negative");
        match self {
            YieldModel::Fixed(y) => {
                assert!(y > 0.0 && y <= 1.0, "fixed yield must be in (0,1]");
                y
            }
            YieldModel::Murphy { d0 } => {
                let ad = area_cm2 * d0;
                if ad < 1e-12 {
                    return 1.0;
                }
                let t = (1.0 - (-ad).exp()) / ad;
                t * t
            }
            YieldModel::NegBinomial { d0, alpha } => {
                assert!(alpha > 0.0, "alpha must be positive");
                (1.0 + area_cm2 * d0 / alpha).powf(-alpha)
            }
        }
    }
}

/// Gross die per wafer (de Vries, IEEE TSM 2005): first-order placement
/// count for square-ish dies on a circular wafer.
///
/// `d_wafer_mm` is the wafer diameter (300 mm standard), `die_area_mm2`
/// the die area. Uses the well-known correction
/// `N = π(d/2)²/A − πd/√(2A)`.
pub fn gross_die_per_wafer(d_wafer_mm: f64, die_area_mm2: f64) -> f64 {
    assert!(die_area_mm2 > 0.0, "die area must be positive");
    let r = d_wafer_mm / 2.0;
    let full = std::f64::consts::PI * r * r / die_area_mm2;
    let edge = std::f64::consts::PI * d_wafer_mm / (2.0 * die_area_mm2).sqrt();
    (full - edge).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_yield_ignores_area() {
        let y = YieldModel::Fixed(0.8);
        assert_eq!(y.yield_for(0.1), 0.8);
        assert_eq!(y.yield_for(5.0), 0.8);
    }

    #[test]
    fn murphy_decreases_with_area() {
        let m = YieldModel::Murphy { d0: 0.18 };
        let small = m.yield_for(0.5);
        let big = m.yield_for(6.0);
        assert!(small > big);
        assert!(small <= 1.0 && big > 0.0);
    }

    #[test]
    fn murphy_tiny_die_is_near_one() {
        let m = YieldModel::Murphy { d0: 0.18 };
        assert!((m.yield_for(1e-6) - 1.0).abs() < 1e-4);
        assert_eq!(m.yield_for(0.0), 1.0);
    }

    #[test]
    fn negbinomial_approaches_poisson_for_large_alpha() {
        let area = 1.0;
        let d0 = 0.2;
        let nb = YieldModel::NegBinomial { d0, alpha: 1e6 }.yield_for(area);
        let poisson = (-area * d0).exp();
        assert!((nb - poisson).abs() < 1e-4, "nb={nb} poisson={poisson}");
    }

    #[test]
    fn clustering_raises_yield() {
        // More clustered defects (small alpha) waste fewer dies.
        let area = 2.0;
        let d0 = 0.3;
        let clustered = YieldModel::NegBinomial { d0, alpha: 1.0 }.yield_for(area);
        let spread = YieldModel::NegBinomial { d0, alpha: 100.0 }.yield_for(area);
        assert!(clustered > spread);
    }

    #[test]
    fn chiplets_beat_monolithic_on_murphy() {
        // The Fig-2 chiplet argument: 4 dies of area A/4 yield better than
        // one die of area A, so good-silicon carbon per cm² is lower.
        let m = YieldModel::Murphy { d0: 0.15 };
        let mono = m.yield_for(8.0);
        let chiplet = m.yield_for(2.0);
        assert!(chiplet > mono * 1.3);
    }

    #[test]
    fn gross_die_per_wafer_sane() {
        // ~100 mm² die on a 300 mm wafer: ~600 gross dies (textbook value).
        let n = gross_die_per_wafer(300.0, 100.0);
        assert!((550.0..680.0).contains(&n), "n={n}");
        // Bigger dies -> fewer.
        assert!(gross_die_per_wafer(300.0, 400.0) < n / 3.0);
    }

    #[test]
    fn gross_die_never_negative() {
        assert_eq!(gross_die_per_wafer(300.0, 1e6), 0.0);
    }
}
