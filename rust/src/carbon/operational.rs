//! Operational carbon and embodied-carbon amortization (§3.3.3).

use super::intensity::UseGrid;

/// Operational carbon (gCO₂e) for a total energy `energy_j` (J) on a
/// use-phase grid: `CI_use × ||E||₁`.
pub fn operational_carbon(grid: UseGrid, energy_j: f64) -> f64 {
    assert!(energy_j >= 0.0, "energy must be non-negative");
    grid.g_per_joule() * energy_j
}

/// Amortized embodied carbon (gCO₂e) attributed to a workload occupying
/// `task_delay_s` of the hardware's *operational* lifetime
/// `LT − D_idle` (both in seconds):
///
/// ```text
/// C_embodied = C_embodied,overall × ||D||₁ / (LT − D_idle)
/// ```
///
/// The paper amortizes over operational (non-idle) time so embodied carbon
/// is not hidden by shelf/idle time.
pub fn amortized_embodied(overall_g: f64, task_delay_s: f64, operational_lifetime_s: f64) -> f64 {
    assert!(overall_g >= 0.0, "embodied carbon must be non-negative");
    assert!(task_delay_s >= 0.0, "task delay must be non-negative");
    assert!(operational_lifetime_s > 0.0, "operational lifetime must be positive");
    overall_g * task_delay_s / operational_lifetime_s
}

/// Operational lifetime in seconds for a device used `hours_per_day` for
/// `years` (the Fig 4 assumption: 1 h daily × 3 years).
pub fn operational_lifetime_s(hours_per_day: f64, years: f64) -> f64 {
    assert!(hours_per_day > 0.0 && hours_per_day <= 24.0);
    assert!(years > 0.0);
    hours_per_day * 3600.0 * 365.25 * years
}

/// Fraction of total life-cycle carbon that is embodied, given the
/// amortized embodied and operational carbon for the same workload window.
pub fn embodied_ratio(embodied_g: f64, operational_g: f64) -> f64 {
    let total = embodied_g + operational_g;
    if total <= 0.0 {
        return 0.0;
    }
    embodied_g / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operational_carbon_matches_hand_calc() {
        // 1 kWh on the world-average grid = 440 g.
        let c = operational_carbon(UseGrid::WorldAverage, 3.6e6);
        assert!((c - 440.0).abs() < 1e-9);
    }

    #[test]
    fn amortization_is_linear_in_delay() {
        let full = amortized_embodied(1000.0, 100.0, 100.0);
        assert!((full - 1000.0).abs() < 1e-12);
        let half = amortized_embodied(1000.0, 50.0, 100.0);
        assert!((half - 500.0).abs() < 1e-12);
    }

    #[test]
    fn idle_time_concentrates_embodied() {
        // Shorter operational lifetime (more idle) -> larger amortized share
        // for the same task.
        let busy = amortized_embodied(1000.0, 10.0, 1000.0);
        let idle_heavy = amortized_embodied(1000.0, 10.0, 100.0);
        assert!(idle_heavy > busy * 9.9);
    }

    #[test]
    fn lifetime_seconds_for_fig4_assumption() {
        // 1 h/day for 3 years ≈ 1096 hours.
        let s = operational_lifetime_s(1.0, 3.0);
        assert!((s / 3600.0 - 1095.75).abs() < 0.1);
    }

    #[test]
    fn embodied_ratio_bounds() {
        assert_eq!(embodied_ratio(0.0, 0.0), 0.0);
        assert!((embodied_ratio(30.0, 70.0) - 0.3).abs() < 1e-12);
        assert_eq!(embodied_ratio(5.0, 0.0), 1.0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_lifetime_panics() {
        amortized_embodied(1.0, 1.0, 0.0);
    }
}
