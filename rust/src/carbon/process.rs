//! Per-technology-node fab footprint parameters (ACT-style).
//!
//! Values follow the trends published by ACT (Gupta et al., ISCA'22) and
//! imec's EDTM'22 CMOS sustainability study: fab energy per area (EPA)
//! grows steeply with EUV-era nodes, direct gas emissions per area (GPA)
//! and materials per area (MPA) grow more slowly. The 7 nm row is
//! **calibrated exactly** against Table 5 of the paper: with a coal fab
//! grid (820 gCO₂/kWh), 85 % yield and the paper's gold-core area of
//! 0.3 cm², embodied carbon must equal 895.89 gCO₂e, i.e.
//! `(CI_fab·EPA + GPA + MPA) = 895.89 × 0.85 / 0.3 = 2538.355 g/cm²`.

use super::intensity::FabGrid;

/// Technology nodes covered by the retrospective analysis (Fig 2) and the
/// accelerator design space (§5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProcessNode {
    /// 32 nm planar (Sandy Bridge era server CPUs).
    N32,
    /// 28 nm planar.
    N28,
    /// 22 nm FinFET.
    N22,
    /// 14 nm FinFET.
    N14,
    /// 10 nm.
    N10,
    /// 7 nm (VR SoC node in the paper; calibration anchor).
    N7,
    /// 5 nm.
    N5,
}

/// Fab footprint parameters for one node.
#[derive(Debug, Clone, Copy)]
pub struct ProcessParams {
    /// Fab energy per processed wafer area, kWh / cm².
    pub epa_kwh_per_cm2: f64,
    /// Direct (scope-1) gas emissions per area, gCO₂e / cm².
    pub gpa_g_per_cm2: f64,
    /// Procured-materials footprint per area, gCO₂e / cm².
    pub mpa_g_per_cm2: f64,
    /// Defect density used by the Murphy / negative-binomial yield models,
    /// defects / cm². Denser nodes have higher effective defectivity.
    pub defect_density_per_cm2: f64,
    /// Logic transistor density relative to 7 nm (used to scale a design's
    /// area when re-targeting nodes).
    pub density_vs_7nm: f64,
}

impl ProcessNode {
    /// All nodes, oldest first.
    pub const ALL: [ProcessNode; 7] = [
        ProcessNode::N32,
        ProcessNode::N28,
        ProcessNode::N22,
        ProcessNode::N14,
        ProcessNode::N10,
        ProcessNode::N7,
        ProcessNode::N5,
    ];

    /// Human-readable label ("7nm" etc.).
    pub fn label(self) -> &'static str {
        match self {
            ProcessNode::N32 => "32nm",
            ProcessNode::N28 => "28nm",
            ProcessNode::N22 => "22nm",
            ProcessNode::N14 => "14nm",
            ProcessNode::N10 => "10nm",
            ProcessNode::N7 => "7nm",
            ProcessNode::N5 => "5nm",
        }
    }

    /// Fab footprint parameters for this node.
    ///
    /// 7 nm EPA/GPA/MPA are the Table 5 calibration anchor:
    /// `820 × 2.150 + 275 + 500 = 2538.0 ≈ 2538.355 g/cm²` — the small
    /// residual is folded into EPA (2.15043 kWh/cm²).
    pub fn params(self) -> ProcessParams {
        match self {
            ProcessNode::N32 => ProcessParams {
                epa_kwh_per_cm2: 0.85,
                gpa_g_per_cm2: 130.0,
                mpa_g_per_cm2: 390.0,
                defect_density_per_cm2: 0.10,
                density_vs_7nm: 0.065,
            },
            ProcessNode::N28 => ProcessParams {
                epa_kwh_per_cm2: 0.95,
                gpa_g_per_cm2: 145.0,
                mpa_g_per_cm2: 400.0,
                defect_density_per_cm2: 0.10,
                density_vs_7nm: 0.09,
            },
            ProcessNode::N22 => ProcessParams {
                epa_kwh_per_cm2: 1.30,
                gpa_g_per_cm2: 180.0,
                mpa_g_per_cm2: 460.0,
                defect_density_per_cm2: 0.12,
                density_vs_7nm: 0.14,
            },
            ProcessNode::N14 => ProcessParams {
                // FinFET-era jump in fab energy (imec EDTM'22 trend).
                epa_kwh_per_cm2: 1.85,
                gpa_g_per_cm2: 300.0,
                mpa_g_per_cm2: 507.0,
                defect_density_per_cm2: 0.13,
                density_vs_7nm: 0.28,
            },
            ProcessNode::N10 => ProcessParams {
                epa_kwh_per_cm2: 1.92,
                gpa_g_per_cm2: 260.0,
                mpa_g_per_cm2: 510.0,
                defect_density_per_cm2: 0.15,
                density_vs_7nm: 0.55,
            },
            ProcessNode::N7 => ProcessParams {
                // Calibration anchor — see module docs.
                epa_kwh_per_cm2: 2.150_433,
                gpa_g_per_cm2: 275.0,
                mpa_g_per_cm2: 500.0,
                defect_density_per_cm2: 0.18,
                density_vs_7nm: 1.0,
            },
            ProcessNode::N5 => ProcessParams {
                epa_kwh_per_cm2: 2.75,
                gpa_g_per_cm2: 310.0,
                mpa_g_per_cm2: 540.0,
                defect_density_per_cm2: 0.21,
                density_vs_7nm: 1.8,
            },
        }
    }

    /// Carbon footprint per good cm² on this node for a fab grid and yield:
    /// `(CI_fab·EPA + GPA + MPA) / Y` in gCO₂e/cm².
    pub fn carbon_per_cm2(self, grid: FabGrid, yield_frac: f64) -> f64 {
        assert!(yield_frac > 0.0 && yield_frac <= 1.0, "yield must be in (0,1]");
        let p = self.params();
        (grid.g_per_kwh() * p.epa_kwh_per_cm2 + p.gpa_g_per_cm2 + p.mpa_g_per_cm2) / yield_frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_calibration_anchor() {
        // Gold CPU cores: 0.3 cm², 7nm, coal grid, 85% yield -> 895.89 g.
        let per_cm2 = ProcessNode::N7.carbon_per_cm2(FabGrid::Coal, 0.85);
        let gold = per_cm2 * 0.3;
        assert!((gold - 895.89).abs() < 0.5, "gold core embodied = {gold}");
        // Silver cores: half the area -> half the carbon.
        let silver = per_cm2 * 0.15;
        assert!((silver - 447.94).abs() < 0.3, "silver core embodied = {silver}");
    }

    #[test]
    fn newer_nodes_carry_more_carbon_per_area() {
        let mut last = 0.0;
        for node in ProcessNode::ALL {
            let c = node.carbon_per_cm2(FabGrid::Coal, 0.9);
            assert!(c > last, "{} not monotonic", node.label());
            last = c;
        }
    }

    #[test]
    fn density_increases_with_node() {
        let mut last = 0.0;
        for node in ProcessNode::ALL {
            let d = node.params().density_vs_7nm;
            assert!(d > last);
            last = d;
        }
    }

    #[test]
    #[should_panic(expected = "yield")]
    fn zero_yield_rejected() {
        let _ = ProcessNode::N7.carbon_per_cm2(FabGrid::Coal, 0.0);
    }

    #[test]
    fn cleaner_grid_lowers_embodied() {
        let coal = ProcessNode::N7.carbon_per_cm2(FabGrid::Coal, 0.85);
        let renewable = ProcessNode::N7.carbon_per_cm2(FabGrid::Renewable, 0.85);
        assert!(renewable < coal * 0.35, "renewable={renewable} coal={coal}");
    }
}
