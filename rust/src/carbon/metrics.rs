//! Carbon-efficiency metric suite (§3.1–§3.2, Table 1, Fig 1).
//!
//! The paper contrasts the classic energy-delay product (EDP) with the
//! ACT-era carbon metrics (CDP, CEP, CE²P, C²EP — all on *embodied*
//! carbon) and proposes **tCDP = C_total × D**, where C_total is the sum
//! of operational carbon and embodied carbon *amortized over operational
//! lifetime*. The β-scalarized objective
//! `(C_operational + β·C_embodied) × D` sweeps the Pareto front between
//! operational- and embodied-dominant regimes.

/// Raw per-design quantities every metric is computed from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricInputs {
    /// Total task energy, J (||E||₁).
    pub energy_j: f64,
    /// Total task delay, s (||D||₁).
    pub delay_s: f64,
    /// Operational carbon for the task window, gCO₂e.
    pub c_operational_g: f64,
    /// Amortized embodied carbon for the task window, gCO₂e.
    pub c_embodied_g: f64,
}

/// The full metric suite for one design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricSet {
    /// Energy-delay product, J·s (carbon-oblivious baseline).
    pub edp: f64,
    /// Embodied-carbon × delay (ACT's CDP), g·s.
    pub cdp: f64,
    /// Embodied-carbon × energy (ACT's CEP), g·J.
    pub cep: f64,
    /// Embodied-carbon × energy², g·J².
    pub ce2p: f64,
    /// Embodied-carbon² × energy, g²·J.
    pub c2ep: f64,
    /// Total life-cycle carbon × delay (the paper's tCDP), g·s.
    pub tcdp: f64,
    /// Total life-cycle carbon, g.
    pub c_total_g: f64,
}

impl MetricInputs {
    /// Compute the whole suite.
    pub fn metrics(&self) -> MetricSet {
        let MetricInputs { energy_j: e, delay_s: d, c_operational_g: co, c_embodied_g: ce } = *self;
        assert!(e >= 0.0 && d >= 0.0 && co >= 0.0 && ce >= 0.0, "negative metric input: {self:?}");
        MetricSet {
            edp: e * d,
            cdp: ce * d,
            cep: ce * e,
            ce2p: ce * e * e,
            c2ep: ce * ce * e,
            tcdp: (co + ce) * d,
            c_total_g: co + ce,
        }
    }

    /// The β-scalarized objective of §3.2:
    /// `F₁ + β·F₂ = (C_operational + β·C_embodied) × D`.
    pub fn scalarized(&self, beta: f64) -> f64 {
        assert!(beta >= 0.0, "beta must be non-negative");
        (self.c_operational_g + beta * self.c_embodied_g) * self.delay_s
    }
}

/// Which figure-of-merit to optimize a design for (Figs 1, 2, 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MetricKind {
    /// Energy-delay product (carbon-oblivious).
    Edp,
    /// Embodied carbon-delay product.
    Cdp,
    /// Embodied carbon-energy product.
    Cep,
    /// Embodied carbon-energy² product.
    Ce2p,
    /// Embodied carbon²-energy product.
    C2ep,
    /// Total-carbon-delay product (the paper's proposal).
    Tcdp,
}

impl MetricKind {
    /// All metrics in the Fig 1 comparison order.
    pub const ALL: [MetricKind; 6] = [
        MetricKind::Edp,
        MetricKind::Cdp,
        MetricKind::Cep,
        MetricKind::Ce2p,
        MetricKind::C2ep,
        MetricKind::Tcdp,
    ];

    /// Display label.
    pub fn label(self) -> &'static str {
        match self {
            MetricKind::Edp => "EDP",
            MetricKind::Cdp => "CDP",
            MetricKind::Cep => "CEP",
            MetricKind::Ce2p => "CE2P",
            MetricKind::C2ep => "C2EP",
            MetricKind::Tcdp => "tCDP",
        }
    }

    /// Extract this metric's value from a computed [`MetricSet`].
    pub fn value(self, m: &MetricSet) -> f64 {
        match self {
            MetricKind::Edp => m.edp,
            MetricKind::Cdp => m.cdp,
            MetricKind::Cep => m.cep,
            MetricKind::Ce2p => m.ce2p,
            MetricKind::C2ep => m.c2ep,
            MetricKind::Tcdp => m.tcdp,
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<MetricKind> {
        match s.to_ascii_lowercase().as_str() {
            "edp" => Some(MetricKind::Edp),
            "cdp" => Some(MetricKind::Cdp),
            "cep" => Some(MetricKind::Cep),
            "ce2p" => Some(MetricKind::Ce2p),
            "c2ep" => Some(MetricKind::C2ep),
            "tcdp" => Some(MetricKind::Tcdp),
            _ => None,
        }
    }
}

/// The β regimes of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BetaRegime {
    /// β → 0: clean fab, operational-carbon-dominant system
    /// (objective degenerates to `C_operational × D`).
    OperationalOnly,
    /// 0 < β < 1: operational-carbon dominance range.
    OperationalDominant,
    /// β = 1: both carbons in CO₂e with known relation — exact tCDP.
    Exact,
    /// 1 < β < ∞: embodied-carbon dominance range.
    EmbodiedDominant,
    /// β → ∞: 100 % renewable use grid
    /// (objective degenerates to `C_embodied × D`).
    EmbodiedOnly,
}

/// Classify a β value into its Table 1 regime.
pub fn beta_regime(beta: f64) -> BetaRegime {
    assert!(beta >= 0.0 && !beta.is_nan(), "beta must be a non-negative number");
    if beta == 0.0 {
        BetaRegime::OperationalOnly
    } else if beta < 1.0 {
        BetaRegime::OperationalDominant
    } else if beta == 1.0 {
        BetaRegime::Exact
    } else if beta.is_infinite() {
        BetaRegime::EmbodiedOnly
    } else {
        BetaRegime::EmbodiedDominant
    }
}

/// Index of the minimum value (the "metric-optimal" star in Figs 1/2).
/// Ties resolve to the first occurrence; non-finite values never win.
pub fn argmin(values: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &v) in values.iter().enumerate() {
        if !v.is_finite() {
            continue;
        }
        match best {
            Some((_, bv)) if bv <= v => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Rng};

    fn inputs(e: f64, d: f64, co: f64, ce: f64) -> MetricInputs {
        MetricInputs { energy_j: e, delay_s: d, c_operational_g: co, c_embodied_g: ce }
    }

    #[test]
    fn tcdp_is_total_carbon_times_delay() {
        let m = inputs(10.0, 2.0, 3.0, 7.0).metrics();
        assert!((m.tcdp - 20.0).abs() < 1e-12);
        assert!((m.c_total_g - 10.0).abs() < 1e-12);
    }

    #[test]
    fn suite_matches_definitions() {
        let m = inputs(4.0, 3.0, 1.0, 2.0).metrics();
        assert_eq!(m.edp, 12.0);
        assert_eq!(m.cdp, 6.0);
        assert_eq!(m.cep, 8.0);
        assert_eq!(m.ce2p, 32.0);
        assert_eq!(m.c2ep, 16.0);
    }

    #[test]
    fn scalarized_beta_one_equals_tcdp() {
        let i = inputs(5.0, 2.5, 4.0, 6.0);
        assert!((i.scalarized(1.0) - i.metrics().tcdp).abs() < 1e-12);
    }

    #[test]
    fn scalarized_limits_match_table1() {
        let i = inputs(5.0, 2.0, 4.0, 6.0);
        // β→0: C_op · D.
        assert!((i.scalarized(0.0) - 8.0).abs() < 1e-12);
        // Large β: dominated by C_emb · D (per unit β).
        let big = i.scalarized(1e9) / 1e9;
        assert!((big - 12.0).abs() < 1e-6);
    }

    #[test]
    fn beta_regimes() {
        assert_eq!(beta_regime(0.0), BetaRegime::OperationalOnly);
        assert_eq!(beta_regime(0.5), BetaRegime::OperationalDominant);
        assert_eq!(beta_regime(1.0), BetaRegime::Exact);
        assert_eq!(beta_regime(7.0), BetaRegime::EmbodiedDominant);
        assert_eq!(beta_regime(f64::INFINITY), BetaRegime::EmbodiedOnly);
    }

    #[test]
    fn metric_kind_roundtrip() {
        for k in MetricKind::ALL {
            assert_eq!(MetricKind::parse(k.label()), Some(k));
        }
        assert_eq!(MetricKind::parse("nope"), None);
    }

    #[test]
    fn argmin_basic_and_nonfinite() {
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(argmin(&[f64::NAN, 5.0, f64::INFINITY]), Some(1));
        assert_eq!(argmin(&[]), None);
        assert_eq!(argmin(&[f64::NAN]), None);
    }

    #[test]
    fn prop_scalarized_monotonic_in_beta() {
        forall(
            |r: &mut Rng| {
                (
                    inputs(r.range(0.0, 100.0), r.range(0.0, 10.0), r.range(0.0, 50.0), r.range(0.0, 50.0)),
                    r.range(0.0, 5.0),
                    r.range(0.0, 5.0),
                )
            },
            |(i, b1, b2)| {
                let (lo, hi) = if b1 <= b2 { (*b1, *b2) } else { (*b2, *b1) };
                i.scalarized(lo) <= i.scalarized(hi) + 1e-9
            },
        );
    }

    #[test]
    fn prop_tcdp_between_pure_objectives_scaled() {
        // (C_op + C_emb)·D >= max(C_op·D, C_emb·D) always.
        forall(
            |r: &mut Rng| inputs(r.range(0.0, 10.0), r.range(0.0, 10.0), r.range(0.0, 10.0), r.range(0.0, 10.0)),
            |i| {
                let t = i.metrics().tcdp;
                t + 1e-12 >= i.c_operational_g * i.delay_s && t + 1e-12 >= i.c_embodied_g * i.delay_s
            },
        );
    }
}
