//! Electrical-grid carbon intensities.
//!
//! Two roles in the paper's model: `CI_fab` (where the part is
//! manufactured — Taiwan for TSMC-fabbed AMD/Qualcomm parts, US for Intel,
//! coal-heavy worst case for the VR SoC calibration) and `CI_use` (where
//! the device operates). Values are in gCO₂ per kWh, in line with the
//! sources ACT cites (IEA country averages).

/// Fab-location electrical grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FabGrid {
    /// Coal-dominated grid (paper's VR SoC assumption; ~820 g/kWh).
    Coal,
    /// Taiwan average grid (TSMC; ~560 g/kWh).
    Taiwan,
    /// US average grid (Intel fabs; ~380 g/kWh).
    UnitedStates,
    /// South Korea average (Samsung; ~430 g/kWh).
    Korea,
    /// Fully renewable / offset fab ("clean fab" scenario, Table 1).
    Renewable,
}

impl FabGrid {
    /// Grid carbon intensity in gCO₂/kWh.
    pub fn g_per_kwh(self) -> f64 {
        match self {
            FabGrid::Coal => 820.0,
            FabGrid::Taiwan => 560.0,
            FabGrid::UnitedStates => 380.0,
            FabGrid::Korea => 430.0,
            FabGrid::Renewable => 30.0,
        }
    }
}

/// Use-phase electrical grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UseGrid {
    /// World average (~440 g/kWh).
    WorldAverage,
    /// US average (~380 g/kWh).
    UnitedStates,
    /// Wind/solar-dominated grid (~30 g/kWh) — the "100 % renewable
    /// energy-grid" row of Table 1 (β → ∞).
    Renewable,
    /// Coal-dominated grid (~820 g/kWh) — operational-carbon-dominant.
    Coal,
    /// Custom intensity: the bits of an f64 g/kWh value (construct via
    /// [`UseGrid::custom`]). Carrying bits instead of the float keeps
    /// `Eq`/`Hash` derivable without truncating fractional intensities
    /// (trace segments and marginal-intensity data are fractional).
    Custom(u64),
}

impl UseGrid {
    /// Custom use-phase intensity from a (possibly fractional) g/kWh
    /// value.
    pub fn custom(g_per_kwh: f64) -> Self {
        assert!(
            g_per_kwh.is_finite() && g_per_kwh >= 0.0,
            "custom carbon intensity must be non-negative and finite (got {g_per_kwh})"
        );
        UseGrid::Custom(g_per_kwh.to_bits())
    }

    /// Grid carbon intensity in gCO₂/kWh.
    pub fn g_per_kwh(self) -> f64 {
        match self {
            UseGrid::WorldAverage => 440.0,
            UseGrid::UnitedStates => 380.0,
            UseGrid::Renewable => 30.0,
            UseGrid::Coal => 820.0,
            UseGrid::Custom(bits) => f64::from_bits(bits),
        }
    }

    /// Grid carbon intensity in gCO₂ per joule (the unit the batched
    /// runtime graph consumes: energies there are in J).
    pub fn g_per_joule(self) -> f64 {
        self.g_per_kwh() / 3.6e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn joule_conversion() {
        // 1 kWh = 3.6e6 J.
        let g_per_j = UseGrid::WorldAverage.g_per_joule();
        assert!((g_per_j * 3.6e6 - 440.0).abs() < 1e-9);
    }

    #[test]
    fn ordering_of_grids() {
        assert!(FabGrid::Renewable.g_per_kwh() < FabGrid::UnitedStates.g_per_kwh());
        assert!(FabGrid::UnitedStates.g_per_kwh() < FabGrid::Taiwan.g_per_kwh());
        assert!(FabGrid::Taiwan.g_per_kwh() < FabGrid::Coal.g_per_kwh());
    }

    #[test]
    fn custom_grid_passthrough() {
        assert_eq!(UseGrid::custom(123.0).g_per_kwh(), 123.0);
    }

    #[test]
    fn custom_grid_keeps_fractional_intensities() {
        // Regression: `Custom(u32)` truncated to whole g/kWh; the
        // bits-carrying variant round-trips any finite f64 exactly.
        for v in [123.456, 31.07, 817.25, 0.0] {
            assert_eq!(UseGrid::custom(v).g_per_kwh(), v);
            assert_eq!(UseGrid::custom(v).g_per_joule(), v / 3.6e6);
        }
    }

    #[test]
    fn custom_grid_stays_hashable_and_comparable() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(UseGrid::custom(123.456));
        set.insert(UseGrid::custom(123.456));
        set.insert(UseGrid::custom(123.457));
        set.insert(UseGrid::WorldAverage);
        assert_eq!(set.len(), 3);
        assert_eq!(UseGrid::custom(99.5), UseGrid::custom(99.5));
        assert_ne!(UseGrid::custom(99.5), UseGrid::custom(99.6));
    }

    #[test]
    #[should_panic(expected = "non-negative and finite")]
    fn custom_grid_rejects_nan() {
        UseGrid::custom(f64::NAN);
    }
}
