//! Hardware replacement-frequency model (§5.5, Fig 14).
//!
//! A device generation consumes embodied carbon up front and operational
//! carbon over its life; each replacement buys the 1.21×/year average
//! energy-efficiency improvement the paper cites from ACT. Given a fixed
//! service horizon, replacing every `R` years costs
//!
//! ```text
//! C(R) = (H/R)·C_emb + Σ_gen Σ_year CI_use · E_year / eff(gen)
//! ```
//!
//! where `eff(gen) = improvement^(R·gen)` — hardware bought later is more
//! efficient. Short `R` amortizes efficiency gains; long `R` amortizes
//! embodied carbon. The optimum shifts with daily usage exactly as Fig 14
//! shows.

use super::intensity::UseGrid;

/// Inputs for the replacement study.
#[derive(Debug, Clone, Copy)]
pub struct ReplacementScenario {
    /// Embodied carbon per device generation, gCO₂e.
    pub embodied_g: f64,
    /// Average power while in use for generation-0 hardware, W.
    pub active_power_w: f64,
    /// Daily usage, hours.
    pub hours_per_day: f64,
    /// Use-phase grid.
    pub grid: UseGrid,
    /// Annual energy-efficiency improvement factor (paper: 1.21).
    pub annual_efficiency_gain: f64,
    /// Service horizon considered, years (total time the user needs a
    /// working device; replacements tile this horizon).
    pub horizon_years: f64,
}

impl Default for ReplacementScenario {
    fn default() -> Self {
        ReplacementScenario {
            embodied_g: 0.0,
            active_power_w: 0.0,
            hours_per_day: 1.0,
            grid: UseGrid::WorldAverage,
            annual_efficiency_gain: 1.21,
            horizon_years: 10.0,
        }
    }
}

/// Total life-cycle carbon (gCO₂e) over the horizon when replacing the
/// device every `lifetime_years`.
pub fn total_carbon_g(s: &ReplacementScenario, lifetime_years: f64) -> f64 {
    assert!(lifetime_years > 0.0, "lifetime must be positive");
    assert!(s.annual_efficiency_gain >= 1.0, "efficiency gain must be >= 1");
    let generations = (s.horizon_years / lifetime_years).ceil().max(1.0) as usize;
    let seconds_per_year = 3600.0 * 365.25 * s.hours_per_day;
    let mut total = 0.0;
    for g in 0..generations {
        let gen_start = g as f64 * lifetime_years;
        let gen_end = (gen_start + lifetime_years).min(s.horizon_years);
        if gen_end <= gen_start {
            break;
        }
        total += s.embodied_g;
        // Power of hardware bought at `gen_start`: baseline / gain^years.
        let power = s.active_power_w / s.annual_efficiency_gain.powf(gen_start);
        let energy_j = power * seconds_per_year * (gen_end - gen_start);
        total += s.grid.g_per_joule() * energy_j;
    }
    total
}

/// Sweep candidate lifetimes and return `(lifetime, total_carbon)` pairs.
pub fn sweep_lifetimes(s: &ReplacementScenario, lifetimes_years: &[f64]) -> Vec<(f64, f64)> {
    lifetimes_years.iter().map(|&lt| (lt, total_carbon_g(s, lt))).collect()
}

/// The carbon-optimal lifetime among the candidates.
pub fn optimal_lifetime(s: &ReplacementScenario, lifetimes_years: &[f64]) -> f64 {
    sweep_lifetimes(s, lifetimes_years)
        .into_iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(lt, _)| lt)
        .expect("at least one candidate lifetime")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quest_like(hours: f64) -> ReplacementScenario {
        ReplacementScenario {
            embodied_g: 6000.0, // VR SoC-class embodied carbon (Table 5 scaled to die)
            active_power_w: 5.8, // ~70% of the 8.3 W TDP (Fig 4)
            hours_per_day: hours,
            grid: UseGrid::WorldAverage,
            annual_efficiency_gain: 1.21,
            horizon_years: 10.0,
        }
    }

    const CANDIDATES: [f64; 5] = [1.0, 2.0, 3.0, 4.0, 5.0];

    #[test]
    fn light_use_favors_long_lifetime() {
        // 1 h/day: embodied dominates -> 5-year optimum (paper Fig 14 left).
        assert_eq!(optimal_lifetime(&quest_like(1.0), &CANDIDATES), 5.0);
    }

    #[test]
    fn heavy_use_favors_short_lifetime() {
        // 12 h/day: operational dominates; frequent replacement reaps the
        // 1.21x/yr efficiency gains (paper Fig 14 right: short optimum).
        let opt = optimal_lifetime(&quest_like(12.0), &CANDIDATES);
        assert!(opt < 5.0, "expected short optimum, got {opt}");
        // And the optimum shrinks monotonically as daily usage grows.
        let o1 = optimal_lifetime(&quest_like(1.0), &CANDIDATES);
        let o3 = optimal_lifetime(&quest_like(3.0), &CANDIDATES);
        assert!(o1 >= o3 && o3 >= opt, "o1={o1} o3={o3} o12={opt}");
    }

    #[test]
    fn no_efficiency_gain_always_favors_longest() {
        let mut s = quest_like(12.0);
        s.annual_efficiency_gain = 1.0;
        assert_eq!(optimal_lifetime(&s, &CANDIDATES), 5.0);
    }

    #[test]
    fn total_carbon_decomposes() {
        // One generation exactly covering the horizon.
        let mut s = quest_like(1.0);
        s.horizon_years = 3.0;
        let c = total_carbon_g(&s, 3.0);
        let energy_j = 5.8 * 3600.0 * 365.25 * 1.0 * 3.0;
        let expect = 6000.0 + UseGrid::WorldAverage.g_per_joule() * energy_j;
        assert!((c - expect).abs() < 1e-6);
    }

    #[test]
    fn partial_last_generation_is_prorated() {
        let mut s = quest_like(1.0);
        s.horizon_years = 5.0;
        // Replacing every 2 years: 3 generations, last one only 1 year long.
        let c = total_carbon_g(&s, 2.0);
        assert!(c > 3.0 * s.embodied_g); // 3 embodied payments present.
        let full3gen = {
            let mut s6 = s;
            s6.horizon_years = 6.0;
            total_carbon_g(&s6, 2.0)
        };
        assert!(c < full3gen); // but less operational than a full 6 years.
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_lifetime_rejected() {
        total_carbon_g(&quest_like(1.0), 0.0);
    }
}
