//! Phase B of the two-phase evaluation pipeline: scenario overlays.
//!
//! A [`ScenarioOverlay`] holds exactly the scenario-dependent half of an
//! evaluation request — `(ci_use, lifetime, β, qos, p_max, online)` — and
//! applies it to a scenario-invariant [`DesignProfile`] (phase A output)
//! to produce the full metric row set plus feasibility. The arithmetic is
//! f32 in the *same order* as the fused engine graph
//! (`runtime/host.rs::Engine::execute`, mirroring
//! `python/compile/kernels/ref.py`), so on the host engine
//! overlay-composed results are **bit-identical** to the fused path —
//! locked by
//! `rust/tests/coordinator_props.rs::prop_profile_overlay_reuse_bit_identical_to_fused`.
//! (On PJRT the compiled HLO may fuse/reassociate the carbon rows, so the
//! composition is only guaranteed inside the existing ≤ 1e-5 pjrt-vs-host
//! envelope; see `runtime/pjrt.rs`.)
//!
//! Cost: O(C·J) per overlay application versus the engine's O(C·T·K)
//! contraction, which is what lets multi-scenario sweeps profile once and
//! fan only overlays across the scenario grid.
//!
//! Overlays apply one at a time ([`ScenarioOverlay::apply`]) or batched
//! ([`ScenarioOverlay::apply_batch`]): the batch walks a profile's row
//! block **once** for S overlays through a caller-provided
//! [`OverlayScratch`] (no per-scenario metric allocation), hoists the
//! `c_emb_overall` component contraction when every overlay shares one
//! `online` mask, and is bit-identical to S sequential `apply` calls —
//! identical f32 operations on identical inputs, per overlay (locked by
//! `rust/tests/hotloop_props.rs::prop_apply_batch_bit_identical_to_apply`).

use crate::matrixform::{
    DesignProfile, EvalRequest, EvalResult, PackedProblem, J_PAD, NUM_METRICS, T_PAD,
};

/// Reusable scratch for overlay application: one `[S × NUM_METRICS ×
/// c_pad]` f32 slab, grown on demand and retained across calls so a
/// sweep's phase B allocates it once per driver instead of once per
/// (scenario × chunk).
#[derive(Debug, Default)]
pub struct OverlayScratch {
    metrics: Vec<f32>,
}

impl OverlayScratch {
    /// Empty scratch; buffers are sized lazily by the first batch.
    pub fn new() -> Self {
        OverlayScratch::default()
    }
}

/// The scenario-dependent half of an evaluation request, padded f32.
#[derive(Debug, Clone)]
pub struct ScenarioOverlay {
    /// Use-phase carbon intensity, g/J.
    pub ci_use: f32,
    /// Operational lifetime (LT − D_idle), s.
    pub lifetime: f32,
    /// β of the scalarized objective.
    pub beta: f32,
    /// Average-power cap, W.
    pub p_max: f32,
    /// Component online mask (zero-padded to `J_PAD`).
    pub online: [f32; J_PAD],
    /// Per-task delay bounds, s (∞-padded to `T_PAD`).
    pub qos: [f32; T_PAD],
}

impl ScenarioOverlay {
    /// Extract the scenario half of a request, with the same f64→f32
    /// casts and padding values `PackedProblem::from_request` applies.
    pub fn from_request(req: &EvalRequest) -> Self {
        assert!(req.online.len() <= J_PAD, "too many components");
        assert!(req.qos.len() <= T_PAD, "too many tasks");
        let mut online = [0.0f32; J_PAD];
        for (ji, v) in req.online.iter().enumerate() {
            online[ji] = *v as f32;
        }
        let mut qos = [f32::INFINITY; T_PAD];
        for (ti, q) in req.qos.iter().enumerate() {
            qos[ti] = *q as f32;
        }
        ScenarioOverlay {
            ci_use: req.ci_use_g_per_j as f32,
            lifetime: req.lifetime_s as f32,
            beta: req.beta as f32,
            p_max: req.p_max_w as f32,
            online,
            qos,
        }
    }

    /// Extract the scenario half of an already-packed batch (the f32
    /// casts happened at packing time).
    pub fn from_packed(p: &PackedProblem) -> Self {
        let mut online = [0.0f32; J_PAD];
        online.copy_from_slice(&p.online);
        let mut qos = [f32::INFINITY; T_PAD];
        qos.copy_from_slice(&p.qos);
        ScenarioOverlay {
            ci_use: p.scalars[0],
            lifetime: p.scalars[1],
            beta: p.scalars[2],
            p_max: p.scalars[3],
            online,
            qos,
        }
    }

    // xrlint: region(bit-identical)
    /// Apply this scenario to a profile: the fused engine's carbon and
    /// feasibility arithmetic, operation for operation (keep in lockstep
    /// with `runtime/host.rs::fold_carbon` — the bit-identity tests fail
    /// loudly otherwise). Allocates a fresh scratch; hot paths applying
    /// many overlays should use [`Self::apply_with`] or
    /// [`Self::apply_batch`] with a reused [`OverlayScratch`].
    pub fn apply(&self, prof: &DesignProfile) -> EvalResult {
        self.apply_with(prof, &mut OverlayScratch::new())
    }

    /// [`Self::apply`] with a caller-provided scratch (no allocation
    /// beyond the unpacked result).
    pub fn apply_with(&self, prof: &DesignProfile, scratch: &mut OverlayScratch) -> EvalResult {
        Self::apply_batch(std::slice::from_ref(self), prof, scratch)
            .into_iter()
            .next()
            .expect("one overlay in, one result out")
    }

    /// Apply S overlays to one profile's row block in a single pass.
    ///
    /// The config loop is outermost so each config's `energy`/`delay`/
    /// `c_comp` row is loaded once for all S scenarios, and when every
    /// overlay carries the **same** `online` mask the `c_emb_overall`
    /// component contraction is computed once per config and shared —
    /// identical input bits through the identical f32 operation order,
    /// so the hoist (like the batching itself) is bit-identical to S
    /// sequential [`Self::apply`] calls. Results come back in overlay
    /// order.
    pub fn apply_batch(
        overlays: &[ScenarioOverlay],
        prof: &DesignProfile,
        scratch: &mut OverlayScratch,
    ) -> Vec<EvalResult> {
        let s = overlays.len();
        let c_pad = prof.c_pad;
        let slab = NUM_METRICS * c_pad;
        scratch.metrics.clear();
        scratch.metrics.resize(s * slab, 0.0);
        // `online` masks are exact f32 arrays (0.0/1.0 provisioning
        // flags), so equality means the hoisted contraction is the same
        // operation sequence every overlay would run itself.
        let shared_online = s > 1 && overlays.windows(2).all(|w| w[0].online == w[1].online);
        for ci in 0..c_pad {
            let energy = prof.energy[ci];
            let delay = prof.delay[ci];
            let mut shared_emb = 0.0f32;
            if shared_online {
                for ji in 0..J_PAD {
                    shared_emb += prof.c_comp[ci * J_PAD + ji] * overlays[0].online[ji];
                }
            }
            for (si, ov) in overlays.iter().enumerate() {
                let c_op = ov.ci_use * energy;
                let c_emb_overall = if shared_online {
                    shared_emb
                } else {
                    let mut acc = 0.0f32;
                    for ji in 0..J_PAD {
                        acc += prof.c_comp[ci * J_PAD + ji] * ov.online[ji];
                    }
                    acc
                };
                let c_emb = c_emb_overall * delay / ov.lifetime;

                let c_total = c_op + c_emb;
                let tcdp = (c_op + ov.beta * c_emb) * delay;
                let edp = energy * delay;
                let cdp = c_emb * delay;
                let cep = c_emb * energy;
                let ce2p = cep * energy;
                let c2ep = c_emb * cep;

                let mut qos_ok = true;
                for ti in 0..T_PAD {
                    if !(prof.d_task[ci * T_PAD + ti] <= ov.qos[ti]) {
                        qos_ok = false;
                    }
                }
                let avg_power = energy / delay.max(1e-30);
                let feasible = if qos_ok && avg_power <= ov.p_max { 1.0 } else { 0.0 };

                let rows = [
                    energy, delay, c_op, c_emb, c_total, tcdp, edp, cdp, cep, ce2p, c2ep, feasible,
                ];
                let m = &mut scratch.metrics[si * slab..(si + 1) * slab];
                for (row, v) in rows.iter().enumerate() {
                    m[row * c_pad + ci] = *v;
                }
            }
        }
        (0..s).map(|si| prof.unpack(&scratch.metrics[si * slab..(si + 1) * slab])).collect()
    }
    // xrlint: endregion(bit-identical)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, MetricRow, ProfileRequest, TaskMatrix};
    use crate::runtime::{evaluate_fused, profile_request, HostEngine};

    fn request() -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[10.0, 5.0]);
        EvalRequest {
            tasks: tm,
            configs: vec![
                ConfigRow {
                    name: "fast".into(),
                    f_clk: 1e9,
                    d_k: vec![1e-3, 2e-3],
                    e_dyn: vec![0.05, 0.10],
                    leak_w: 0.02,
                    c_comp: vec![500.0, 100.0],
                },
                ConfigRow {
                    name: "slow".into(),
                    f_clk: 5e8,
                    d_k: vec![4e-3, 8e-3],
                    e_dyn: vec![0.02, 0.04],
                    leak_w: 0.01,
                    c_comp: vec![120.0, 30.0],
                },
            ],
            online: vec![1.0, 1.0],
            qos: vec![0.03],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 3.0e6,
            beta: 1.0,
            p_max_w: 40.0,
        }
    }

    #[test]
    fn from_request_pads_like_packing() {
        let req = request();
        let ov = ScenarioOverlay::from_request(&req);
        let packed = PackedProblem::from_request(&req);
        let from_packed = ScenarioOverlay::from_packed(&packed);
        assert_eq!(ov.ci_use.to_bits(), from_packed.ci_use.to_bits());
        assert_eq!(ov.lifetime.to_bits(), from_packed.lifetime.to_bits());
        assert_eq!(ov.beta.to_bits(), from_packed.beta.to_bits());
        assert_eq!(ov.p_max.to_bits(), from_packed.p_max.to_bits());
        assert_eq!(ov.online, from_packed.online);
        assert_eq!(ov.qos[0], 0.03f64 as f32);
        assert_eq!(ov.qos[1], f32::INFINITY);
        assert_eq!(ov.online[2], 0.0);
    }

    #[test]
    fn overlay_on_profile_matches_fused_engine_bitwise() {
        let req = request();
        let mut host = HostEngine::new();
        let prof = profile_request(&mut host, &ProfileRequest::from_eval(&req).to_eval()).unwrap();
        let two = ScenarioOverlay::from_request(&req).apply(&prof);
        let fused = evaluate_fused(&mut host, &req).unwrap();
        assert_eq!(two.names, fused.names);
        for (a, b) in two.metrics.iter().zip(&fused.metrics) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in two.d_task.iter().zip(&fused.d_task) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn apply_batch_matches_sequential_apply_bitwise() {
        let req = request();
        let mut host = HostEngine::new();
        let prof = profile_request(&mut host, &ProfileRequest::from_eval(&req).to_eval()).unwrap();
        // Mixed masks (hoist off) and shared masks (hoist on) both ride
        // through the same batch entry point.
        let mut variants = Vec::new();
        for (i, online) in
            [vec![1.0, 1.0], vec![1.0, 0.0], vec![1.0, 1.0], vec![0.0, 1.0]].iter().enumerate()
        {
            let mut r = req.clone();
            r.online = online.clone();
            r.lifetime_s = 1e6 * (i + 1) as f64;
            r.beta = 0.5 * (i + 1) as f64;
            variants.push(ScenarioOverlay::from_request(&r));
        }
        let shared: Vec<ScenarioOverlay> = (0..5)
            .map(|i| {
                let mut r = req.clone();
                r.lifetime_s = 2e6 * (i + 1) as f64;
                ScenarioOverlay::from_request(&r)
            })
            .collect();
        let mut scratch = OverlayScratch::new();
        for overlays in [&variants, &shared] {
            let batched = ScenarioOverlay::apply_batch(overlays, &prof, &mut scratch);
            assert_eq!(batched.len(), overlays.len());
            for (ov, b) in overlays.iter().zip(&batched) {
                let single = ov.apply(&prof);
                assert_eq!(single.names, b.names);
                assert_eq!(single.metrics, b.metrics);
                assert_eq!(single.d_task, b.d_task);
            }
        }
        // Scratch reuse across differently-sized batches stays clean.
        let lone = ScenarioOverlay::apply_batch(
            std::slice::from_ref(&variants[1]),
            &prof,
            &mut scratch,
        );
        assert_eq!(lone[0].metrics, variants[1].apply(&prof).metrics);
        assert!(ScenarioOverlay::apply_batch(&[], &prof, &mut scratch).is_empty());
    }

    #[test]
    fn one_profile_many_scenarios() {
        // The point of the split: scenario knobs change the carbon rows
        // without re-running the engine contraction.
        let req = request();
        let mut host = HostEngine::new();
        let prof = profile_request(&mut host, &ProfileRequest::from_eval(&req).to_eval()).unwrap();

        let mut long_life = req.clone();
        long_life.lifetime_s = 3.0e8;
        let a = ScenarioOverlay::from_request(&req).apply(&prof);
        let b = ScenarioOverlay::from_request(&long_life).apply(&prof);
        // Invariant rows are untouched…
        assert_eq!(a.metric(MetricRow::Energy, 0), b.metric(MetricRow::Energy, 0));
        assert_eq!(a.metric(MetricRow::Delay, 0), b.metric(MetricRow::Delay, 0));
        // …while the amortized embodied carbon shrinks with lifetime.
        assert!(b.metric(MetricRow::CEmb, 0) < a.metric(MetricRow::CEmb, 0));
    }

    #[test]
    fn online_mask_lives_in_the_overlay() {
        let req = request();
        let mut host = HostEngine::new();
        let prof = profile_request(&mut host, &ProfileRequest::from_eval(&req).to_eval()).unwrap();
        let mut masked = req.clone();
        masked.online = vec![1.0, 0.0];
        let res = ScenarioOverlay::from_request(&masked).apply(&prof);
        // Only the logic component (500 g) remains online for "fast".
        let c_emb = res.metric(MetricRow::CEmb, 0);
        assert!((c_emb - 500.0 * 0.02 / 3.0e6).abs() < 1e-9, "c_emb={c_emb}");
    }
}
