//! Time-varying use-phase carbon intensity: piecewise-constant traces
//! and fleet-mix scenarios.
//!
//! The paper evaluates operational carbon at a single `CI_use`, but grid
//! intensity varies by hour (solar troughs, evening peaks), by season and
//! by accounting convention (average vs. marginal). Because operational
//! carbon is *linear* in `CI_use` (`C_op = CI_use × E`), a piecewise-
//! constant [`CiTrace`] lowers exactly onto the existing scenario
//! machinery: evaluate the space once per segment intensity (phase B
//! overlays only — the scenario-invariant profiles are reused across all
//! segments) and combine the per-segment results with the segments'
//! time weights. [`combine_segments`] performs that combination in the
//! fused graph's f32 arithmetic, in segment order, so a trace scenario's
//! host result is bit-identical to combining per-segment *fused*
//! evaluations — the same invariant the two-phase sweep already locks
//! per scenario (see DESIGN.md §3.4 for the full contract).
//!
//! [`FleetMix`] extends the same linearity across device populations:
//! cohorts of devices operating under different regional traces weight
//! into one equivalent trace, with shares grounded in the synthetic
//! fleet telemetry (`workloads::fleet::regional_usage_shares`).

use crate::matrixform::{EvalResult, MetricRow};

/// Joules per kWh (the runtime consumes g/J; sources quote g/kWh).
const J_PER_KWH: f64 = 3.6e6;

/// One piecewise-constant segment of a carbon-intensity trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CiSegment {
    /// Segment duration, s (only the *relative* duration matters — the
    /// trace normalizes durations into time weights).
    pub duration_s: f64,
    /// Grid carbon intensity over the segment, gCO₂/kWh.
    pub g_per_kwh: f64,
}

/// A periodic carbon-intensity trace: piecewise-constant gCO₂/kWh
/// samples over one period (a day, a year). Construction validates that
/// every segment has positive finite duration and non-negative finite
/// intensity, so downstream weights are always well-formed.
#[derive(Debug, Clone, PartialEq)]
pub struct CiTrace {
    segments: Vec<CiSegment>,
}

impl CiTrace {
    /// New trace from validated segments. Panics on an empty segment
    /// list, non-positive/non-finite durations or negative/non-finite
    /// intensities.
    pub fn new(segments: Vec<CiSegment>) -> Self {
        assert!(!segments.is_empty(), "carbon-intensity trace needs at least one segment");
        for (i, s) in segments.iter().enumerate() {
            assert!(
                s.duration_s.is_finite() && s.duration_s > 0.0,
                "trace segment {i}: duration must be positive and finite (got {})",
                s.duration_s
            );
            assert!(
                s.g_per_kwh.is_finite() && s.g_per_kwh >= 0.0,
                "trace segment {i}: intensity must be non-negative and finite (got {})",
                s.g_per_kwh
            );
        }
        CiTrace { segments }
    }

    /// Single-segment trace at a constant intensity (the static
    /// reference point of a trace axis).
    pub fn flat(g_per_kwh: f64) -> Self {
        CiTrace::new(vec![CiSegment { duration_s: 24.0 * 3600.0, g_per_kwh }])
    }

    /// One segment per entry, each one hour long (diurnal traces).
    pub fn hourly(g_per_kwh: &[f64]) -> Self {
        CiTrace::new(
            g_per_kwh.iter().map(|&g| CiSegment { duration_s: 3600.0, g_per_kwh: g }).collect(),
        )
    }

    /// 24-hour sinusoidal diurnal shape: `base × (1 + swing·cos(2π(h −
    /// peak_hour)/24))`, sampled hourly. `swing` must stay below 1 so
    /// intensities remain positive.
    pub fn diurnal(base_g_per_kwh: f64, swing: f64, peak_hour: f64) -> Self {
        assert!((0.0..1.0).contains(&swing), "diurnal swing must be in [0,1)");
        let samples: Vec<f64> = (0..24)
            .map(|h| {
                let phase = 2.0 * std::f64::consts::PI * (h as f64 - peak_hour) / 24.0;
                base_g_per_kwh * (1.0 + swing * phase.cos())
            })
            .collect();
        CiTrace::hourly(&samples)
    }

    /// The trace's segments.
    pub fn segments(&self) -> &[CiSegment] {
        &self.segments
    }

    /// Number of segments.
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    /// A trace is never empty (enforced by [`CiTrace::new`]).
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Total period, s.
    pub fn period_s(&self) -> f64 {
        self.segments.iter().map(|s| s.duration_s).sum()
    }

    /// Normalized per-segment time weights, as the f32 values
    /// [`combine_segments`] consumes (computed in f64, cast once).
    pub fn weights(&self) -> Vec<f32> {
        let period = self.period_s();
        self.segments.iter().map(|s| (s.duration_s / period) as f32).collect()
    }

    /// Time-weighted mean intensity, g/kWh (the trace's static collapse).
    pub fn mean_g_per_kwh(&self) -> f64 {
        let period = self.period_s();
        self.segments.iter().map(|s| s.duration_s * s.g_per_kwh).sum::<f64>() / period
    }

    /// Lowest segment intensity, g/kWh.
    pub fn min_g_per_kwh(&self) -> f64 {
        self.segments.iter().map(|s| s.g_per_kwh).fold(f64::INFINITY, f64::min)
    }

    /// Highest segment intensity, g/kWh.
    pub fn max_g_per_kwh(&self) -> f64 {
        self.segments.iter().map(|s| s.g_per_kwh).fold(0.0, f64::max)
    }

    /// Time-weighted mean intensity in g/J (the base-request unit).
    pub fn mean_g_per_j(&self) -> f64 {
        self.mean_g_per_kwh() / J_PER_KWH
    }

    /// A segment's intensity in g/J.
    pub fn segment_g_per_j(&self, i: usize) -> f64 {
        self.segments[i].g_per_kwh / J_PER_KWH
    }

    /// Diurnal preset for a solar-heavy renewable grid: deep midday
    /// trough, steep evening peak as the sun drops off the mix.
    pub fn diurnal_renewable() -> Self {
        CiTrace::diurnal(180.0, 0.65, 19.0)
    }

    /// Diurnal preset for the world-average grid (moderate swing,
    /// evening peak).
    pub fn diurnal_world() -> Self {
        CiTrace::diurnal(440.0, 0.25, 19.0)
    }

    /// Diurnal preset for a coal-dominated grid: baseload generation
    /// barely follows demand, so the swing is small and the base high.
    pub fn diurnal_coal() -> Self {
        CiTrace::diurnal(760.0, 0.08, 19.0)
    }

    /// Seasonal preset: twelve 30-day segments, winter-peaking around
    /// the world average (heating load leans on fossil generation).
    pub fn seasonal_world() -> Self {
        let segments = (0..12)
            .map(|m| {
                let phase = 2.0 * std::f64::consts::PI * m as f64 / 12.0;
                CiSegment {
                    duration_s: 30.0 * 24.0 * 3600.0,
                    g_per_kwh: 440.0 * (1.0 + 0.18 * phase.cos()),
                }
            })
            .collect();
        CiTrace::new(segments)
    }

    /// Marginal-intensity preset: the *marginal* generator displaced by
    /// an extra watt is usually a gas peaker, so marginal intensity sits
    /// well above the world *average* with a modest evening swing —
    /// the average-vs-marginal accounting variant.
    pub fn marginal_world() -> Self {
        CiTrace::diurnal(650.0, 0.15, 20.0)
    }

    /// Names accepted by [`CiTrace::by_name`] (the CLI `--trace` values).
    pub fn preset_names() -> &'static [&'static str] {
        &[
            "diurnal-renewable",
            "diurnal-world",
            "diurnal-coal",
            "seasonal-world",
            "marginal-world",
            "flat-world",
            "flat-renewable",
            "flat-coal",
        ]
    }

    /// Look up a named preset.
    pub fn by_name(name: &str) -> Option<CiTrace> {
        Some(match name {
            "diurnal-renewable" => CiTrace::diurnal_renewable(),
            "diurnal-world" => CiTrace::diurnal_world(),
            "diurnal-coal" => CiTrace::diurnal_coal(),
            "seasonal-world" => CiTrace::seasonal_world(),
            "marginal-world" => CiTrace::marginal_world(),
            "flat-world" => CiTrace::flat(440.0),
            "flat-renewable" => CiTrace::flat(30.0),
            "flat-coal" => CiTrace::flat(820.0),
            _ => return None,
        })
    }
}

/// One cohort of a device fleet: a population share operating under a
/// regional carbon-intensity trace.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetCohort {
    /// Cohort label ("us", "eu-renewable").
    pub label: String,
    /// Population share (relative weight; [`FleetMix::flatten`]
    /// normalizes).
    pub share: f64,
    /// The cohort's regional trace.
    pub trace: CiTrace,
}

/// A fleet mix: device cohorts under different regional traces. Because
/// operational carbon is linear in `CI_use`, the expected per-device
/// fleet carbon equals evaluation under one *equivalent* trace whose
/// segment weights are the share-scaled cohort weights —
/// [`FleetMix::flatten`] builds exactly that trace.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetMix {
    /// The cohorts (non-empty, positive shares).
    pub cohorts: Vec<FleetCohort>,
}

impl FleetMix {
    /// New mix over validated cohorts.
    pub fn new(cohorts: Vec<FleetCohort>) -> Self {
        assert!(!cohorts.is_empty(), "fleet mix needs at least one cohort");
        for c in &cohorts {
            assert!(
                c.share.is_finite() && c.share > 0.0,
                "fleet cohort '{}': share must be positive and finite (got {})",
                c.label,
                c.share
            );
        }
        FleetMix { cohorts }
    }

    /// Collapse the mix into one equivalent trace: each cohort's
    /// segments enter with duration `share × (segment / cohort period)`,
    /// so the flattened weights are exactly the share-scaled cohort time
    /// weights (durations become dimensionless fractions — only the
    /// weights matter downstream).
    pub fn flatten(&self) -> CiTrace {
        let total: f64 = self.cohorts.iter().map(|c| c.share).sum();
        let mut segments = Vec::new();
        for c in &self.cohorts {
            let period = c.trace.period_s();
            for s in c.trace.segments() {
                segments.push(CiSegment {
                    duration_s: (c.share / total) * (s.duration_s / period),
                    g_per_kwh: s.g_per_kwh,
                });
            }
        }
        CiTrace::new(segments)
    }
}

/// Metric rows that depend on `ci_use` (the operational-carbon family:
/// `C_op = ci·E`, `C_total = C_op + C_emb`, `tCDP = (C_op + β·C_emb)·D`).
/// Every other row — and `d_task` — is bitwise identical across a
/// trace's segments, because only the overlay's `ci_use` knob varies.
const CI_DEPENDENT_ROWS: [MetricRow; 3] = [MetricRow::COp, MetricRow::CTotal, MetricRow::Tcdp];

/// Combine per-segment evaluation results into the trace's time-weighted
/// result, in the fused graph's exact f32 order: for each ci-dependent
/// row and config, accumulate `acc += wₛ · vₛ` in f32, segments in trace
/// order (segment values round-trip f64↔f32 exactly — they were produced
/// in f32). All ci-independent rows, `d_task` and names are taken
/// verbatim from segment 0. This is the *only* cross-segment combiner in
/// the codebase; every sweep path lowers traces through it, which is
/// what makes trace results bit-identical across the two-phase, fused
/// and sequential paths.
// xrlint: region(bit-identical)
pub fn combine_segments(segments: &[EvalResult], weights: &[f32]) -> EvalResult {
    assert!(!segments.is_empty(), "combine_segments: no segment results");
    assert_eq!(
        segments.len(),
        weights.len(),
        "combine_segments: {} segment(s) vs {} weight(s)",
        segments.len(),
        weights.len()
    );
    let mut out = segments[0].clone();
    for (i, s) in segments.iter().enumerate().skip(1) {
        assert_eq!(s.c, out.c, "combine_segments: segment {i} has a different config count");
        assert_eq!(s.t, out.t, "combine_segments: segment {i} has a different task count");
        debug_assert_eq!(s.names, out.names, "combine_segments: segment {i} names differ");
    }
    for row in CI_DEPENDENT_ROWS {
        let r = row as usize;
        for ci in 0..out.c {
            let mut acc = 0.0f32;
            for (s, &w) in segments.iter().zip(weights) {
                acc += w * s.metrics[r * s.c + ci] as f32;
            }
            out.metrics[r * out.c + ci] = acc as f64;
        }
    }
    out
}
// xrlint: endregion(bit-identical)

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_presets_have_24_hourly_segments() {
        for t in [CiTrace::diurnal_renewable(), CiTrace::diurnal_world(), CiTrace::diurnal_coal()] {
            assert_eq!(t.len(), 24);
            assert!((t.period_s() - 24.0 * 3600.0).abs() < 1e-6);
            assert!(t.min_g_per_kwh() > 0.0);
            let w: f32 = t.weights().iter().sum();
            assert!((w - 1.0).abs() < 1e-5, "weights sum to {w}");
        }
        assert_eq!(CiTrace::seasonal_world().len(), 12);
    }

    #[test]
    fn renewable_grid_has_the_deep_trough_and_the_low_mean() {
        let r = CiTrace::diurnal_renewable();
        let c = CiTrace::diurnal_coal();
        let w = CiTrace::diurnal_world();
        // Solar trough well below 100 g/kWh; coal barely moves.
        assert!(r.min_g_per_kwh() < 100.0, "renewable min {}", r.min_g_per_kwh());
        assert!(c.min_g_per_kwh() > 600.0, "coal min {}", c.min_g_per_kwh());
        assert!(r.mean_g_per_kwh() < w.mean_g_per_kwh());
        assert!(w.mean_g_per_kwh() < c.mean_g_per_kwh());
        // Swing ratio: renewable ~4.7x, coal ~1.17x.
        assert!(r.max_g_per_kwh() / r.min_g_per_kwh() > 3.0);
        assert!(c.max_g_per_kwh() / c.min_g_per_kwh() < 1.3);
    }

    #[test]
    fn diurnal_mean_is_the_base_intensity() {
        // Σ cos(2π(h−p)/24) over a full period is 0, so the hourly mean
        // is the base.
        let t = CiTrace::diurnal(500.0, 0.4, 19.0);
        assert!((t.mean_g_per_kwh() - 500.0).abs() < 1e-9);
        assert!((t.mean_g_per_j() * 3.6e6 - 500.0).abs() < 1e-9);
    }

    #[test]
    fn every_preset_name_resolves() {
        for name in CiTrace::preset_names() {
            assert!(CiTrace::by_name(name).is_some(), "preset '{name}' missing");
        }
        assert!(CiTrace::by_name("no-such-trace").is_none());
    }

    #[test]
    #[should_panic(expected = "duration must be positive")]
    fn zero_duration_segment_rejected() {
        CiTrace::new(vec![CiSegment { duration_s: 0.0, g_per_kwh: 100.0 }]);
    }

    #[test]
    #[should_panic(expected = "at least one segment")]
    fn empty_trace_rejected() {
        CiTrace::new(Vec::new());
    }

    fn result(c_op: &[f64]) -> EvalResult {
        // 2-config, 1-task result with distinguishable rows; only the
        // ci-dependent rows vary across "segments".
        let c = c_op.len();
        let mut metrics = vec![0.0; 12 * c];
        for ci in 0..c {
            metrics[MetricRow::Energy as usize * c + ci] = 10.0 + ci as f64;
            metrics[MetricRow::Delay as usize * c + ci] = 0.5;
            metrics[MetricRow::COp as usize * c + ci] = c_op[ci];
            metrics[MetricRow::CEmb as usize * c + ci] = 3.0;
            metrics[MetricRow::CTotal as usize * c + ci] = c_op[ci] + 3.0;
            metrics[MetricRow::Tcdp as usize * c + ci] = (c_op[ci] + 3.0) * 0.5;
            metrics[MetricRow::Edp as usize * c + ci] = 7.0;
            metrics[MetricRow::Feasible as usize * c + ci] = 1.0;
        }
        EvalResult {
            names: (0..c).map(|i| format!("c{i}")).collect(),
            metrics,
            d_task: vec![0.5; c],
            c,
            t: 1,
        }
    }

    #[test]
    fn combine_weights_ci_rows_and_copies_the_rest() {
        let a = result(&[2.0, 4.0]);
        let b = result(&[6.0, 8.0]);
        let out = combine_segments(&[a.clone(), b], &[0.25, 0.75]);
        // f32 weighted sum, exact for these values.
        assert_eq!(out.metric(MetricRow::COp, 0), (0.25f32 * 2.0 + 0.75 * 6.0) as f64);
        assert_eq!(out.metric(MetricRow::COp, 1), (0.25f32 * 4.0 + 0.75 * 8.0) as f64);
        assert_eq!(out.metric(MetricRow::CTotal, 0), (0.25f32 * 5.0 + 0.75 * 9.0) as f64);
        // ci-independent rows come from segment 0, bitwise.
        assert_eq!(out.metric(MetricRow::Energy, 1), a.metric(MetricRow::Energy, 1));
        assert_eq!(out.metric(MetricRow::Edp, 0), 7.0);
        assert_eq!(out.d_task, a.d_task);
        assert_eq!(out.names, a.names);
    }

    #[test]
    fn single_segment_combine_is_the_identity() {
        let a = result(&[2.5, 4.5]);
        let out = combine_segments(std::slice::from_ref(&a), &[1.0]);
        assert_eq!(out.metrics, a.metrics);
        assert_eq!(out.d_task, a.d_task);
    }

    #[test]
    fn combine_order_is_segment_major() {
        // f32 addition is not associative: the contract fixes the
        // accumulation order to trace order, so a permuted segment list
        // may differ in the last ulp. Assert the canonical order result.
        let segs = [result(&[1.0e-3]), result(&[7.7e2]), result(&[3.3e-1])];
        let w = [0.3f32, 0.4, 0.3];
        let expect = ((0.3f32 * 1.0e-3f32 + 0.4f32 * 7.7e2f32) + 0.3f32 * 3.3e-1f32) as f64;
        let out = combine_segments(&segs, &w);
        assert_eq!(out.metric(MetricRow::COp, 0), expect);
    }

    #[test]
    #[should_panic(expected = "different config count")]
    fn combine_rejects_mismatched_shapes() {
        combine_segments(&[result(&[1.0]), result(&[1.0, 2.0])], &[0.5, 0.5]);
    }

    #[test]
    fn fleet_mix_flattens_to_share_weighted_trace() {
        let mix = FleetMix::new(vec![
            FleetCohort { label: "renewable".into(), share: 1.0, trace: CiTrace::flat(30.0) },
            FleetCohort { label: "coal".into(), share: 3.0, trace: CiTrace::flat(820.0) },
        ]);
        let t = mix.flatten();
        assert_eq!(t.len(), 2);
        let w: f32 = t.weights().iter().sum();
        assert!((w - 1.0).abs() < 1e-6);
        // Mean = 0.25·30 + 0.75·820.
        assert!((t.mean_g_per_kwh() - (0.25 * 30.0 + 0.75 * 820.0)).abs() < 1e-9);
    }

    #[test]
    fn fleet_mix_preserves_cohort_diurnal_structure() {
        let mix = FleetMix::new(vec![
            FleetCohort {
                label: "a".into(),
                share: 0.5,
                trace: CiTrace::diurnal_renewable(),
            },
            FleetCohort { label: "b".into(), share: 0.5, trace: CiTrace::diurnal_coal() },
        ]);
        let t = mix.flatten();
        assert_eq!(t.len(), 48);
        let lo = CiTrace::diurnal_renewable().mean_g_per_kwh();
        let hi = CiTrace::diurnal_coal().mean_g_per_kwh();
        let m = t.mean_g_per_kwh();
        assert!(lo < m && m < hi, "{lo} < {m} < {hi}");
    }

    #[test]
    #[should_panic(expected = "share must be positive")]
    fn fleet_mix_rejects_zero_share() {
        FleetMix::new(vec![FleetCohort {
            label: "x".into(),
            share: 0.0,
            trace: CiTrace::flat(100.0),
        }]);
    }
}
