//! ACT-style life-cycle carbon model (paper §3.3, §4.2).
//!
//! The paper computes embodied carbon per die with the ACT equation
//!
//! ```text
//! C_embodied = (CI_fab × EPA + MPA + GPA) × A / Y
//! ```
//!
//! and operational carbon as `CI_use × E`. This module provides:
//!
//! * [`process`] — per-technology-node fab footprint constants
//!   (EPA/GPA/MPA), calibrated so Table 5 of the paper reproduces exactly
//!   at 7 nm / coal grid / 85 % yield;
//! * [`intensity`] — electrical-grid carbon intensities for fab locations
//!   and use-phase grids;
//! * [`yield_model`] — fixed, Murphy and negative-binomial die-yield models
//!   plus the de Vries gross-die-per-wafer formula;
//! * [`embodied`] — the embodied-carbon equation, multi-die (chiplet /
//!   3D-stack) aggregation and provisioning-aware component vectors;
//! * [`operational`] — use-phase carbon and lifetime amortization;
//! * [`metrics`] — EDP and the carbon metric suite (CDP, CEP, CE²P, C²EP,
//!   tCDP) with the β-scalarized objective of §3.2 (Table 1);
//! * [`overlay`] — phase B of the two-phase evaluation pipeline: applies
//!   the scenario knobs `(ci_use, lifetime, β, qos, p_max, online)` to a
//!   scenario-invariant design profile, bit-identical to the fused path;
//! * [`trace`] — time-varying `CI_use`: piecewise-constant diurnal /
//!   seasonal / marginal traces with named grid presets, fleet-mix
//!   weighting across regional cohorts, and the f32 segment combiner
//!   that keeps trace results bit-identical to per-segment fused
//!   evaluation;
//! * [`replacement`] — the hardware-replacement-frequency model behind
//!   Fig 14.

pub mod embodied;
pub mod intensity;
pub mod metrics;
pub mod operational;
pub mod overlay;
pub mod process;
pub mod replacement;
pub mod trace;
pub mod yield_model;

pub use embodied::{embodied_carbon, ChipDesign, Die};
pub use intensity::{FabGrid, UseGrid};
pub use metrics::{beta_regime, BetaRegime, MetricInputs, MetricKind, MetricSet};
pub use operational::{amortized_embodied, operational_carbon};
pub use overlay::{OverlayScratch, ScenarioOverlay};
pub use trace::{combine_segments, CiSegment, CiTrace, FleetCohort, FleetMix};
pub use process::{ProcessNode, ProcessParams};
pub use yield_model::{gross_die_per_wafer, YieldModel};
