//! DSE-as-a-service: the resident exploration server (DESIGN.md §3.6).
//!
//! A long-lived process that accepts sweep/search jobs over a tiny
//! std-only HTTP/1.1 surface, runs them on the existing
//! [`crate::dse::sweep::SweepDriver`] / [`crate::dse::search::SearchDriver`]
//! state machines, and serves the same tables the CLI prints — as
//! structured JSON ([`crate::report::Table::to_json`]) next to the
//! rendered text. No new dependencies: the listener is a
//! `std::net::TcpListener`, the JSON is [`crate::configfmt`].
//!
//! The load-bearing design decision: **a job *is* a resumable
//! checkpoint**. Submitting a job persists its spec under the state
//! directory (`job_<id>.spec.json`, digest-sealed like every other
//! envelope in this repo); each driver step persists the corresponding
//! sweep/search checkpoint (`job_<id>.ckpt.json`); completion persists
//! the result (`job_<id>.result.json`) and deletes the checkpoint. A
//! killed server therefore loses nothing: [`Service::open`] re-queues
//! every spec without a result, and the drivers' fingerprint-validated
//! resume paths — progress re-read through the [`ProfileCache`] —
//! reproduce the uninterrupted run bit-identically (locked by
//! `rust/tests/service_e2e.rs`). A job that was mid-flight when the
//! process died simply restarts its phase loop; completed chunks come
//! back as warm cache hits. Failures are deliberately *not* persisted:
//! a restart retries the job from its last checkpoint.
//!
//! Concurrency: executor threads share one [`ProfileCache`] (safe for
//! concurrent clients — see [`crate::dse::cache`]'s advisory-lock notes)
//! and one [`Coalescer`], so N jobs asking for the same cold chunk
//! trigger exactly one phase-A contraction; `/v1/stats` aggregates both
//! counters across every job the process has run.
//!
//! * [`jobs`] — specs, registry, and the job runner ([`Service::run_next`]);
//! * [`http`] — the request router (pure, testable) and the TCP loop.

mod http;
mod jobs;

pub use http::{handle_request, serve, spawn_listener};
pub use jobs::{JobKind, JobState, ResultFetch, Submit};

use std::path::PathBuf;
use std::sync::Mutex;

use crate::dse::cache::{CacheConfig, ProfileCache};
use crate::dse::coalesce::Coalescer;
use crate::runtime::{auto_factory, EngineFactory, HostEngineFactory};

/// Server configuration (the `serve` subcommand's knobs).
pub struct ServiceConfig {
    /// Job specs, checkpoints and results live here. Required.
    pub state_dir: PathBuf,
    /// Profile-cache directory; defaults to `<state_dir>/cache`.
    pub cache_dir: Option<PathBuf>,
    /// Optional on-disk cache budget (see `--cache-budget`).
    pub cache_budget: Option<u64>,
    /// Worker threads per job's profile phase (0 = auto).
    pub threads: usize,
    /// Engine selector: "host" forces the pure-Rust mirror, anything
    /// else auto-detects (PJRT when built in, host otherwise).
    pub engine: String,
    /// Optional bearer token (`--auth-token`). When set, every HTTP
    /// request must carry `Authorization: Bearer <token>` or it is
    /// rejected with 401 before routing; the comparison is
    /// constant-time so response latency leaks nothing about a prefix
    /// match.
    pub auth_token: Option<String>,
}

/// The resident exploration service: one shared cache + coalescer, a
/// job registry, and durable per-job state under `state_dir`. `Sync` —
/// wrap in an `Arc` and share it between executor threads and the
/// listener.
pub struct Service {
    pub(crate) cfg: ServiceConfig,
    pub(crate) cache: ProfileCache,
    pub(crate) coalescer: Coalescer,
    pub(crate) state: Mutex<jobs::Registry>,
}

impl Service {
    /// Open (or re-open) a service over `cfg.state_dir`: creates the
    /// directory and the cache, then re-queues every persisted job spec
    /// that has no result yet — the restart-resume half of the job
    /// contract.
    pub fn open(cfg: ServiceConfig) -> crate::Result<Service> {
        std::fs::create_dir_all(&cfg.state_dir)?;
        let cache_dir = cfg.cache_dir.clone().unwrap_or_else(|| cfg.state_dir.join("cache"));
        let cache = ProfileCache::open_with(
            &cache_dir,
            CacheConfig { budget_bytes: cfg.cache_budget, ..CacheConfig::default() },
        )?;
        let state = Mutex::new(jobs::Registry::scan(&cfg.state_dir)?);
        Ok(Service { cfg, cache, coalescer: Coalescer::new(), state })
    }

    /// The shared profile cache (process-wide counters).
    pub fn cache(&self) -> &ProfileCache {
        &self.cache
    }

    /// The shared cross-job request coalescer.
    pub fn coalescer(&self) -> &Coalescer {
        &self.coalescer
    }

    /// Build a fresh engine factory per job run — factories are cheap;
    /// the engines themselves come from the per-thread worker pools.
    pub(crate) fn factory(&self) -> Box<dyn EngineFactory> {
        match self.cfg.engine.as_str() {
            "host" => Box::new(HostEngineFactory),
            _ => auto_factory(crate::experiments::common::ARTIFACTS_DIR),
        }
    }
}
