//! The std-only HTTP/1.1 surface: a pure request router (testable
//! without sockets) and a thin `TcpListener` loop around it.
//!
//! Endpoints (all JSON bodies):
//!
//! * `POST /v1/sweep`  `{preset?, cluster?, threads?, trace?}` → 202 `{job}`
//! * `POST /v1/search` `{space?, cluster?, threads?, seed?, max_evals?}` → 202 `{job}`
//! * `GET /v1/jobs/<id>` → `{id, kind, state, done, total, detail}`
//! * `GET /v1/jobs/<id>/result` → the persisted result (409 while pending)
//! * `GET /v1/stats` → process-total cache + coalescer counters
//!
//! Deliberately minimal: one request per connection (`Connection:
//! close`), no chunked bodies, no TLS — the server is a trusted-network
//! lab tool, not an internet-facing daemon. With `--auth-token TOKEN`
//! every request additionally needs `Authorization: Bearer TOKEN`
//! (compared in constant time) or it is 401'd before routing.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

use super::jobs::{ResultFetch, Submit};
use super::Service;
use crate::configfmt::{parse, Json};
use crate::testkit::parse_seed;

/// Route one request. Pure: status code + JSON payload out, no I/O —
/// the unit tests drive this directly and the TCP loop stays trivial.
pub fn handle_request(service: &Service, method: &str, path: &str, body: &str) -> (u16, String) {
    match (method, path) {
        ("POST", "/v1/sweep") => submit_sweep(service, body),
        ("POST", "/v1/search") => submit_search(service, body),
        (_, "/v1/sweep" | "/v1/search") => (405, err_json("use POST")),
        ("GET", "/v1/stats") => (200, service.stats_json().to_string()),
        (_, "/v1/stats") => (405, err_json("use GET")),
        // xrlint: allow(panic, "slice start is the literal prefix length, guarded by starts_with")
        ("GET", p) if p.starts_with("/v1/jobs/") => jobs_get(service, &p["/v1/jobs/".len()..]),
        (_, p) if p.starts_with("/v1/jobs/") => (405, err_json("use GET")),
        _ => (404, err_json("no such endpoint")),
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

fn body_json(body: &str) -> Result<Json, String> {
    let trimmed = body.trim();
    if trimmed.is_empty() {
        return Ok(Json::obj(Vec::new()));
    }
    parse(trimmed).map_err(|e| format!("request body: {e}"))
}

fn get_str(doc: &Json, key: &str, default: &str) -> Result<String, String> {
    match doc.get(key) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(j) => {
            j.as_str().map(str::to_string).ok_or_else(|| format!("field `{key}` must be a string"))
        }
    }
}

fn get_usize(doc: &Json, key: &str, default: usize) -> Result<usize, String> {
    match doc.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(j) => j.as_usize().ok_or_else(|| format!("field `{key}` must be a whole number")),
    }
}

/// Seeds accept a JSON number or a (hex) string — u64 does not fit an
/// f64 number losslessly.
fn get_seed(doc: &Json, key: &str, default: u64) -> Result<u64, String> {
    match doc.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Str(s)) => {
            parse_seed(s).ok_or_else(|| format!("field `{key}` must be a u64 (decimal or 0x hex)"))
        }
        Some(j) => j
            .as_usize()
            .map(|v| v as u64)
            .ok_or_else(|| format!("field `{key}` must be a u64 (decimal or 0x hex)")),
    }
}

fn submit_sweep(service: &Service, body: &str) -> (u16, String) {
    let parsed = (|| {
        let doc = body_json(body)?;
        let preset = get_str(&doc, "preset", "fig7")?;
        let cluster = get_str(&doc, "cluster", "5ai")?;
        let threads = get_usize(&doc, "threads", 0)?;
        let trace = match doc.get("trace") {
            None | Some(Json::Null) => None,
            Some(j) => Some(
                j.as_str().map(str::to_string).ok_or("field `trace` must be a string")?,
            ),
        };
        Ok::<_, String>((preset, cluster, threads, trace))
    })();
    let (preset, cluster, threads, trace) = match parsed {
        Ok(p) => p,
        Err(msg) => return (400, err_json(&msg)),
    };
    match service.submit_sweep(&preset, &cluster, threads, trace.as_deref()) {
        Ok(Submit::Accepted(id)) => (202, Json::obj(vec![("job", Json::Num(id as f64))]).to_string()),
        Ok(Submit::Rejected(msg)) => (400, err_json(&msg)),
        Err(e) => (500, err_json(&format!("{e:#}"))),
    }
}

fn submit_search(service: &Service, body: &str) -> (u16, String) {
    let parsed = (|| {
        let doc = body_json(body)?;
        let space = get_str(&doc, "space", "fig7")?;
        let cluster = get_str(&doc, "cluster", "5ai")?;
        let threads = get_usize(&doc, "threads", 0)?;
        let seed = get_seed(&doc, "seed", 0xC0FFEE)?;
        let max_evals = get_usize(&doc, "max_evals", 0)?;
        Ok::<_, String>((space, cluster, threads, seed, max_evals))
    })();
    let (space, cluster, threads, seed, max_evals) = match parsed {
        Ok(p) => p,
        Err(msg) => return (400, err_json(&msg)),
    };
    match service.submit_search(&space, &cluster, threads, seed, max_evals) {
        Ok(Submit::Accepted(id)) => (202, Json::obj(vec![("job", Json::Num(id as f64))]).to_string()),
        Ok(Submit::Rejected(msg)) => (400, err_json(&msg)),
        Err(e) => (500, err_json(&format!("{e:#}"))),
    }
}

fn jobs_get(service: &Service, rest: &str) -> (u16, String) {
    let (idpart, want_result) = match rest.strip_suffix("/result") {
        Some(p) => (p, true),
        None => (rest, false),
    };
    let Ok(id) = idpart.parse::<u64>() else { return (404, err_json("bad job id")) };
    if !want_result {
        return match service.job_status(id) {
            Some(j) => (200, j.to_string()),
            None => (404, err_json(&format!("no job {id}"))),
        };
    }
    match service.job_result(id) {
        ResultFetch::Unknown => (404, err_json(&format!("no job {id}"))),
        ResultFetch::Pending(state) => (
            409,
            Json::obj(vec![
                ("error", Json::Str(format!("job {id} not finished"))),
                ("state", Json::Str(state.to_string())),
            ])
            .to_string(),
        ),
        ResultFetch::Failed(detail) => (500, err_json(&detail)),
        ResultFetch::Ready(text) => (200, text),
    }
}

/// Bind `addr` and serve connections on a background thread. Returns
/// the bound address (pass port 0 to let the OS pick — the e2e tests
/// do). One thread per connection: requests are tiny and the expensive
/// work happens on the executor threads, not here.
pub fn spawn_listener(service: Arc<Service>, addr: &str) -> crate::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { continue };
            let svc = Arc::clone(&service);
            std::thread::spawn(move || {
                let _ = handle_connection(&svc, stream);
            });
        }
    });
    Ok(local)
}

/// Run the full server: the listener plus `executors` job-runner
/// threads looping [`Service::run_next`]. Blocks forever (the serve
/// subcommand's terminal state); errors only on a failed bind.
pub fn serve(service: Arc<Service>, addr: &str, executors: usize) -> crate::Result<()> {
    let local = spawn_listener(Arc::clone(&service), addr)?;
    println!(
        "[serve] listening on http://{local} ({} executor(s), state in {})",
        executors.max(1),
        service.cfg.state_dir.display()
    );
    let mut handles = Vec::new();
    for _ in 0..executors.max(1) {
        let svc = Arc::clone(&service);
        handles.push(std::thread::spawn(move || loop {
            match svc.run_next(None) {
                Ok(true) => {}
                Ok(false) => std::thread::sleep(std::time::Duration::from_millis(50)),
                Err(e) => {
                    eprintln!("[serve] executor error: {e:#}");
                    std::thread::sleep(std::time::Duration::from_millis(200));
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_connection(service: &Service, mut stream: TcpStream) -> std::io::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > 64 * 1024 {
            return respond(&mut stream, 431, &err_json("headers too large"));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(());
        }
        // xrlint: allow(panic, "n <= chunk.len() by the read contract")
        buf.extend_from_slice(&chunk[..n]);
    };
    // xrlint: allow(panic, "header_end < buf.len() from the windows() scan above")
    let head = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    // A malformed request line is the client's fault, never ours: 400,
    // not a 404-for-garbage and never a worker panic.
    let (method, path) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v))
            if !m.is_empty() && p.starts_with('/') && v.starts_with("HTTP/") =>
        {
            (m.to_string(), p.to_string())
        }
        _ => return respond(&mut stream, 400, &err_json("malformed request line")),
    };
    let mut content_length = 0usize;
    let mut authorization: Option<String> = None;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = match v.trim().parse() {
                    Ok(n) => n,
                    Err(_) => {
                        return respond(&mut stream, 400, &err_json("invalid content-length"))
                    }
                };
            } else if k.trim().eq_ignore_ascii_case("authorization") {
                authorization = Some(v.trim().to_string());
            }
        }
    }
    // Auth gate, before routing AND before the body read: an
    // unauthenticated client must not be able to make the server buffer
    // a megabyte of body it will never parse.
    if let Some(expected) = service.cfg.auth_token.as_deref() {
        let supplied = authorization.as_deref().and_then(|v| v.strip_prefix("Bearer "));
        let ok = match supplied {
            Some(token) => token_eq(token.trim(), expected),
            None => false,
        };
        if !ok {
            return respond(&mut stream, 401, &err_json("missing or invalid bearer token"));
        }
    }
    if content_length > 1 << 20 {
        return respond(&mut stream, 413, &err_json("body too large"));
    }
    // xrlint: allow(panic, "header_end + 4 <= buf.len(): the CRLFCRLF terminator was found")
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            // Peer hung up mid-body: reject, don't hand a prefix to the
            // JSON layer as if it were the whole request.
            return respond(&mut stream, 400, &err_json("truncated body"));
        }
        // xrlint: allow(panic, "n <= chunk.len() by the read contract")
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let body = String::from_utf8_lossy(&body).into_owned();
    let (status, payload) = handle_request(service, &method, &path, &body);
    respond(&mut stream, status, &payload)
}

/// Constant-time token comparison: every byte of both strings is
/// examined regardless of where they first differ, so the 401 latency
/// does not leak how long a correct prefix the attacker has guessed.
fn token_eq(supplied: &str, expected: &str) -> bool {
    let (a, b) = (supplied.as_bytes(), expected.as_bytes());
    let mut diff = a.len() ^ b.len();
    for i in 0..a.len().max(b.len()) {
        let x = a.get(i).copied().unwrap_or(0);
        let y = b.get(i).copied().unwrap_or(0);
        diff |= usize::from(x ^ y);
    }
    diff == 0
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        _ => "Internal Server Error",
    };
    let challenge = if status == 401 { "WWW-Authenticate: Bearer\r\n" } else { "" };
    let resp = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: \
         {}\r\n{challenge}Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::ServiceConfig;

    fn open(tag: &str) -> (Service, std::path::PathBuf) {
        let dir = crate::testkit::test_dir(tag);
        std::fs::remove_dir_all(&dir).ok();
        let svc = Service::open(ServiceConfig {
            state_dir: dir.clone(),
            cache_dir: None,
            cache_budget: None,
            threads: 1,
            engine: "host".to_string(),
            auth_token: None,
        })
        .unwrap();
        (svc, dir)
    }

    #[test]
    fn router_handles_submissions_status_and_errors() {
        let (svc, dir) = open("svc_router");
        // Unknown endpoint and wrong methods.
        assert_eq!(handle_request(&svc, "GET", "/nope", "").0, 404);
        assert_eq!(handle_request(&svc, "GET", "/v1/sweep", "").0, 405);
        assert_eq!(handle_request(&svc, "POST", "/v1/stats", "").0, 405);
        // Bad submissions are 400 with a message, not queued jobs.
        assert_eq!(handle_request(&svc, "POST", "/v1/sweep", r#"{"preset":"nope"}"#).0, 400);
        assert_eq!(handle_request(&svc, "POST", "/v1/sweep", r#"{"cluster":"zz"}"#).0, 400);
        assert_eq!(handle_request(&svc, "POST", "/v1/sweep", r#"{"trace":"x"}"#).0, 400);
        assert_eq!(handle_request(&svc, "POST", "/v1/search", r#"{"space":"zz"}"#).0, 400);
        assert_eq!(handle_request(&svc, "POST", "/v1/sweep", "{not json").0, 400);
        // A good submission queues and is visible.
        let (code, body) = handle_request(&svc, "POST", "/v1/sweep", r#"{"preset":"fig7"}"#);
        assert_eq!(code, 202, "{body}");
        let id = parse(&body).unwrap().get("job").and_then(Json::as_usize).unwrap();
        let (code, status) = handle_request(&svc, "GET", &format!("/v1/jobs/{id}"), "");
        assert_eq!(code, 200);
        let status = parse(&status).unwrap();
        assert_eq!(status.get("state").and_then(Json::as_str), Some("queued"));
        assert_eq!(status.get("kind").and_then(Json::as_str), Some("sweep"));
        // Result of a pending job is a 409, of an unknown job a 404.
        assert_eq!(handle_request(&svc, "GET", &format!("/v1/jobs/{id}/result"), "").0, 409);
        assert_eq!(handle_request(&svc, "GET", "/v1/jobs/999/result", "").0, 404);
        assert_eq!(handle_request(&svc, "GET", "/v1/jobs/xx", "").0, 404);
        // Stats always answer.
        let (code, stats) = handle_request(&svc, "GET", "/v1/stats", "");
        assert_eq!(code, 200);
        let stats = parse(&stats).unwrap();
        assert!(stats.get("cache").is_some() && stats.get("coalescer").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn token_comparison_matches_exactly_and_only_exactly() {
        assert!(token_eq("s3cret", "s3cret"));
        assert!(!token_eq("s3cret", "s3creT"));
        assert!(!token_eq("s3cre", "s3cret")); // prefix, shorter
        assert!(!token_eq("s3cret!", "s3cret")); // prefix, longer
        assert!(!token_eq("", "s3cret"));
        assert!(token_eq("", "")); // vacuous but must not panic
    }

    #[test]
    fn search_submission_accepts_hex_seeds() {
        let (svc, dir) = open("svc_seed");
        let (code, body) = handle_request(
            &svc,
            "POST",
            "/v1/search",
            r#"{"space":"fig7","seed":"0xDEADBEEFDEADBEEF","max_evals":5}"#,
        );
        assert_eq!(code, 202, "{body}");
        assert_eq!(handle_request(&svc, "POST", "/v1/search", r#"{"seed":"zz"}"#).0, 400);
        std::fs::remove_dir_all(&dir).ok();
    }
}
