//! Job specs, the durable registry, and the job runner.
//!
//! Specs are digest-sealed JSON envelopes like every other persisted
//! artifact in this repo; the runner builds each job's problem the exact
//! same way the CLI does (same presets, same grids, same drivers), so a
//! job's result is bit-identical to the one-shot CLI run — locked by
//! `rust/tests/service_e2e.rs`.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::PoisonError;

use super::Service;
use crate::carbon::{CiTrace, FabGrid};
use crate::configfmt::{parse, Json};
use crate::dse::cache::{atomic_write, splice_digest, strip_and_verify_digest};
use crate::dse::grid::{ScenarioGrid, YEAR_S};
use crate::dse::search::{
    read_checkpoint, ReplayEvaluator, SearchConfig, SearchDriver, SimulatorEvaluator,
    SpaceEvaluator,
};
use crate::dse::space::SearchSpace;
use crate::dse::sweep::{read_sweep_checkpoint, write_sweep_checkpoint, SweepConfig, SweepDriver};
use crate::experiments::common::{provisioning_request, rows_request};
use crate::experiments::{search_fig7, sweep_fig7, trace_study};
use crate::matrixform::EvalRequest;
use crate::report::{
    search_archive_table, search_table, sweep_best_table, sweep_table, trace_table, Table,
};
use crate::testkit::parse_seed;
use crate::workloads::{cluster_workloads, top10_apps, Cluster};

/// What a job runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Exhaustive multi-scenario sweep (a `sweep --preset` run).
    Sweep,
    /// Adaptive Pareto-guided search (a `sweep --search` run).
    Search,
}

impl JobKind {
    /// Wire label.
    pub fn label(self) -> &'static str {
        match self {
            JobKind::Sweep => "sweep",
            JobKind::Search => "search",
        }
    }
}

/// Job lifecycle. Only specs and results persist — `Running` reverts to
/// queued on restart (the checkpoint carries the progress), and `Failed`
/// reverts to queued too (a restart retries from the last checkpoint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting for an executor.
    Queued,
    /// An executor is driving it.
    Running,
    /// Result persisted under the state directory.
    Done,
    /// The run errored; the detail string says why. In-memory only.
    Failed,
}

impl JobState {
    /// Wire label.
    pub fn label(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// A submitted job, exactly as persisted. One flat struct for both
/// kinds; the fields the other kind ignores stay at their defaults.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// Registry id (also the state-file stem).
    pub id: u64,
    /// Sweep or search.
    pub kind: JobKind,
    /// Sweep preset (fig7|fig10|lifetime|fig11|ci|trace).
    pub preset: String,
    /// Search space (fig7|expanded).
    pub space: String,
    /// Workload cluster name.
    pub cluster: String,
    /// Profile-phase worker threads (0 = auto).
    pub threads: usize,
    /// Search seed.
    pub seed: u64,
    /// Search evaluation budget (0 = uncapped).
    pub max_evals: usize,
    /// Named CI trace (trace preset only).
    pub trace: Option<String>,
}

impl JobSpec {
    /// Render the digest-sealed envelope.
    pub fn to_json_string(&self) -> String {
        let body = Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("kind", Json::Str(self.kind.label().to_string())),
            ("preset", Json::Str(self.preset.clone())),
            ("space", Json::Str(self.space.clone())),
            ("cluster", Json::Str(self.cluster.clone())),
            ("threads", Json::Num(self.threads as f64)),
            // Hex string: seeds are u64 and `Json::Num` is an f64.
            ("seed", Json::Str(format!("{:#018x}", self.seed))),
            ("max_evals", Json::Num(self.max_evals as f64)),
            (
                "trace",
                match &self.trace {
                    Some(t) => Json::Str(t.clone()),
                    None => Json::Null,
                },
            ),
        ])
        .to_string();
        splice_digest(&body)
    }

    /// Parse and validate an envelope (integrity digest first). Any
    /// defect is a typed error, never a partial spec.
    pub fn from_json_str(text: &str) -> crate::Result<JobSpec> {
        let mut doc = parse(text).map_err(|e| anyhow::anyhow!("job spec: {e}"))?;
        strip_and_verify_digest(&mut doc, "job spec")?;
        let bad = |f: &str| anyhow::anyhow!("job spec: missing or invalid field `{f}`");
        let id = doc.get("id").and_then(Json::as_usize).ok_or_else(|| bad("id"))? as u64;
        let kind = match doc.get("kind").and_then(Json::as_str) {
            Some("sweep") => JobKind::Sweep,
            Some("search") => JobKind::Search,
            _ => return Err(bad("kind")),
        };
        let text_field = |f: &str| {
            doc.get(f).and_then(Json::as_str).map(str::to_string).ok_or_else(|| bad(f))
        };
        let preset = text_field("preset")?;
        let space = text_field("space")?;
        let cluster = text_field("cluster")?;
        let threads = doc.get("threads").and_then(Json::as_usize).ok_or_else(|| bad("threads"))?;
        let seed = doc
            .get("seed")
            .and_then(Json::as_str)
            .and_then(parse_seed)
            .ok_or_else(|| bad("seed"))?;
        let max_evals =
            doc.get("max_evals").and_then(Json::as_usize).ok_or_else(|| bad("max_evals"))?;
        let trace = match doc.get("trace") {
            None | Some(Json::Null) => None,
            Some(j) => Some(j.as_str().ok_or_else(|| bad("trace"))?.to_string()),
        };
        Ok(JobSpec { id, kind, preset, space, cluster, threads, seed, max_evals, trace })
    }
}

/// In-memory view of one job.
pub(super) struct Entry {
    pub(super) spec: JobSpec,
    pub(super) state: JobState,
    /// Progress: driver units done (chunks or evaluations).
    pub(super) done: usize,
    /// Progress denominator (0 = unknown/uncapped).
    pub(super) total: usize,
    /// Human-readable phase or failure detail.
    pub(super) detail: String,
}

/// The job table plus the FIFO of runnable ids.
pub(super) struct Registry {
    next_id: u64,
    queue: VecDeque<u64>,
    jobs: BTreeMap<u64, Entry>,
}

impl Registry {
    // xrverify: model(job_registry)
    // Fenced: the restart-resume persistence protocol (scan here,
    // enqueue/finish below) verified exhaustively by
    // tools/xrverify/model_registry.py — a crash injected between every
    // pair of persistence steps still yields no lost and no duplicated
    // job. Editing fenced code without re-reviewing the model is a V001
    // finding.

    /// Rebuild the registry from the state directory: every persisted
    /// spec becomes an entry; specs without a result re-queue in id
    /// order (the restart-resume contract). A corrupt spec is an error —
    /// silently dropping a submitted job would be worse than refusing
    /// to start.
    pub(super) fn scan(dir: &Path) -> crate::Result<Registry> {
        let mut jobs: BTreeMap<u64, Entry> = BTreeMap::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for entry in rd.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                let Some(stem) =
                    name.strip_prefix("job_").and_then(|r| r.strip_suffix(".spec.json"))
                else {
                    continue;
                };
                let Ok(id) = stem.parse::<u64>() else { continue };
                let text = std::fs::read_to_string(entry.path())?;
                let spec = JobSpec::from_json_str(&text)?;
                if spec.id != id {
                    anyhow::bail!("job spec {name} carries id {} (file/spec mismatch)", spec.id);
                }
                let finished = dir.join(format!("job_{id}.result.json")).exists();
                jobs.insert(
                    id,
                    Entry {
                        spec,
                        state: if finished { JobState::Done } else { JobState::Queued },
                        done: 0,
                        total: 0,
                        detail: if finished { "result on disk".to_string() } else { String::new() },
                    },
                );
            }
        }
        let queue: VecDeque<u64> = jobs
            .iter()
            .filter(|(_, e)| e.state == JobState::Queued)
            .map(|(&id, _)| id)
            .collect();
        let next_id = jobs.keys().next_back().map(|&id| id + 1).unwrap_or(1);
        Ok(Registry { next_id, queue, jobs })
    }
    // xrverify: endmodel(job_registry)
}

/// Submission verdict: accepted with an id, or rejected with a client
/// error (the router's 400).
pub enum Submit {
    /// Job queued under this id.
    Accepted(u64),
    /// Request invalid — message for the client.
    Rejected(String),
}

/// Result-fetch verdict, mapped to a status code by the router.
pub enum ResultFetch {
    /// No such job (404).
    Unknown,
    /// Job exists but has no result yet; carries the state label (409).
    Pending(&'static str),
    /// Job failed; carries the error detail (500).
    Failed(String),
    /// The persisted result JSON, verbatim (200).
    Ready(String),
}

/// How one `run_next` call left its job.
enum Step {
    Finished,
    Paused,
}

const SWEEP_PRESETS: &[&str] = &["fig7", "fig10", "lifetime", "fig11", "ci", "trace"];
const SEARCH_SPACES: &[&str] = &["fig7", "expanded"];

impl Service {
    fn spec_path(&self, id: u64) -> PathBuf {
        self.cfg.state_dir.join(format!("job_{id}.spec.json"))
    }

    fn ckpt_path(&self, id: u64) -> PathBuf {
        self.cfg.state_dir.join(format!("job_{id}.ckpt.json"))
    }

    fn result_path(&self, id: u64) -> PathBuf {
        self.cfg.state_dir.join(format!("job_{id}.result.json"))
    }

    /// Queue a sweep job. Validation happens here, at submit time —
    /// a bad preset/cluster/trace is a client error, not a job that
    /// fails minutes later.
    pub fn submit_sweep(
        &self,
        preset: &str,
        cluster: &str,
        threads: usize,
        trace: Option<&str>,
    ) -> crate::Result<Submit> {
        if !SWEEP_PRESETS.contains(&preset) {
            return Ok(Submit::Rejected(format!(
                "unknown sweep preset '{preset}' ({})",
                SWEEP_PRESETS.join("|")
            )));
        }
        if Cluster::parse(cluster).is_none() {
            return Ok(Submit::Rejected(format!("unknown cluster '{cluster}'")));
        }
        if let Some(name) = trace {
            if preset != "trace" {
                return Ok(Submit::Rejected("trace requires preset 'trace'".to_string()));
            }
            if CiTrace::by_name(name).is_none() {
                return Ok(Submit::Rejected(format!(
                    "unknown trace '{name}' (known: {})",
                    CiTrace::preset_names().join(", ")
                )));
            }
        }
        let spec = JobSpec {
            id: 0,
            kind: JobKind::Sweep,
            preset: preset.to_string(),
            space: String::new(),
            cluster: cluster.to_string(),
            threads,
            seed: 0,
            max_evals: 0,
            trace: trace.map(str::to_string),
        };
        Ok(Submit::Accepted(self.enqueue(spec)?))
    }

    /// Queue a search job.
    pub fn submit_search(
        &self,
        space: &str,
        cluster: &str,
        threads: usize,
        seed: u64,
        max_evals: usize,
    ) -> crate::Result<Submit> {
        if !SEARCH_SPACES.contains(&space) {
            return Ok(Submit::Rejected(format!(
                "unknown search space '{space}' ({})",
                SEARCH_SPACES.join("|")
            )));
        }
        if Cluster::parse(cluster).is_none() {
            return Ok(Submit::Rejected(format!("unknown cluster '{cluster}'")));
        }
        let spec = JobSpec {
            id: 0,
            kind: JobKind::Search,
            preset: String::new(),
            space: space.to_string(),
            cluster: cluster.to_string(),
            threads,
            seed,
            max_evals,
            trace: None,
        };
        Ok(Submit::Accepted(self.enqueue(spec)?))
    }

    // xrverify: model(job_registry)
    /// Assign an id, persist the spec (before the entry becomes visible
    /// — a job the registry knows about must survive a crash), enqueue.
    ///
    /// The spec write happens *between* two short registry critical
    /// sections, never under the lock: the registry lock sits on every
    /// status/submit poll path, so disk latency must not ride on it.
    /// An id claimed here but never inserted (write failed) is just a
    /// gap in the sequence; a spec written but not inserted (crash
    /// in between) is re-queued by the restart scan like any other
    /// persisted job.
    fn enqueue(&self, mut spec: JobSpec) -> crate::Result<u64> {
        let id = {
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            let id = st.next_id;
            st.next_id += 1;
            id
        };
        spec.id = id;
        atomic_write(&self.spec_path(id), &spec.to_json_string())?;
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.jobs.insert(
            id,
            Entry { spec, state: JobState::Queued, done: 0, total: 0, detail: String::new() },
        );
        st.queue.push_back(id);
        Ok(id)
    }
    // xrverify: endmodel(job_registry)

    /// Status JSON for one job, `None` for an unknown id.
    pub fn job_status(&self, id: u64) -> Option<Json> {
        let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        let e = st.jobs.get(&id)?;
        Some(Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("kind", Json::Str(e.spec.kind.label().to_string())),
            ("state", Json::Str(e.state.label().to_string())),
            ("done", Json::Num(e.done as f64)),
            ("total", Json::Num(e.total as f64)),
            ("detail", Json::Str(e.detail.clone())),
        ]))
    }

    /// Fetch a job's persisted result.
    pub fn job_result(&self, id: u64) -> ResultFetch {
        let (state, detail) = {
            let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            match st.jobs.get(&id) {
                None => return ResultFetch::Unknown,
                Some(e) => (e.state, e.detail.clone()),
            }
        };
        match state {
            JobState::Done => match std::fs::read_to_string(self.result_path(id)) {
                Ok(text) => ResultFetch::Ready(text),
                Err(e) => ResultFetch::Failed(format!("result file unreadable: {e}")),
            },
            JobState::Failed => ResultFetch::Failed(detail),
            other => ResultFetch::Pending(other.label()),
        }
    }

    /// Process-lifetime cache + coalescer counters, aggregated across
    /// every job this instance has run.
    pub fn stats_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        let c = self.cache.stats();
        let co = self.coalescer.stats();
        Json::obj(vec![
            (
                "cache",
                Json::obj(vec![
                    ("hits", n(c.hits)),
                    ("mem_hits", n(c.mem_hits)),
                    ("misses", n(c.misses)),
                    ("rejected", n(c.rejected)),
                    ("writes", n(c.writes)),
                    ("write_errors", n(c.write_errors)),
                    ("evictions", n(c.evictions)),
                    ("contractions_avoided", n(c.contractions_avoided())),
                ]),
            ),
            (
                "coalescer",
                Json::obj(vec![
                    ("requests", n(co.requests as usize)),
                    ("led", n(co.led as usize)),
                    ("lead_cache_hits", n(co.lead_cache_hits as usize)),
                    ("computed", n(co.computed as usize)),
                    ("lead_failures", n(co.lead_failures as usize)),
                    ("waited", n(co.waited as usize)),
                    ("served_from_wait", n(co.served_from_wait as usize)),
                    ("failed_waits", n(co.failed_waits as usize)),
                    ("coalesced_avoided", n(co.coalesced_avoided() as usize)),
                ]),
            ),
        ])
    }

    fn set_progress(&self, id: u64, done: usize, total: usize, detail: &str) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(e) = st.jobs.get_mut(&id) {
            e.done = done;
            e.total = total;
            e.detail = detail.to_string();
        }
    }

    /// Pop and drive the lowest queued job. `max_steps` caps driver
    /// steps for this call (tests use it to exercise the kill/resume
    /// path deterministically); an uncapped call runs the job to
    /// completion. Returns `false` when the queue was empty. Job errors
    /// are recorded on the entry, never propagated — one bad job must
    /// not kill an executor thread.
    pub fn run_next(&self, max_steps: Option<usize>) -> crate::Result<bool> {
        let spec = {
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            let Some(id) = st.queue.pop_front() else { return Ok(false) };
            // xrlint: allow(panic, "queue ids are inserted into jobs in the same critical section")
            let e = st.jobs.get_mut(&id).expect("queued job has an entry");
            e.state = JobState::Running;
            e.spec.clone()
        };
        let id = spec.id;
        let ran = match spec.kind {
            JobKind::Sweep => self.drive_sweep(&spec, max_steps),
            JobKind::Search => self.drive_search(&spec, max_steps),
        };
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        // xrlint: allow(panic, "entries are never removed while a job runs")
        let e = st.jobs.get_mut(&id).expect("running job has an entry");
        match ran {
            Ok(Step::Finished) => e.state = JobState::Done,
            Ok(Step::Paused) => {
                e.state = JobState::Queued;
                st.queue.push_back(id);
            }
            Err(err) => {
                e.state = JobState::Failed;
                e.detail = format!("{err:#}");
            }
        }
        Ok(true)
    }

    fn drive_sweep(&self, spec: &JobSpec, max_steps: Option<usize>) -> crate::Result<Step> {
        let factory = self.factory();
        let cluster = Cluster::parse(&spec.cluster)
            .ok_or_else(|| anyhow::anyhow!("unknown cluster '{}'", spec.cluster))?;
        let (base, grid) = sweep_problem(spec, cluster)?;
        let cfg = SweepConfig { threads: spec.threads };
        let ckpt = self.ckpt_path(spec.id);
        // Resume from the job's own checkpoint when one exists —
        // progress itself comes back through the shared profile cache.
        let mut driver = if ckpt.exists() {
            let ck = read_sweep_checkpoint(&ckpt)?;
            SweepDriver::resume(factory.as_ref(), &base, &grid, &cfg, &ck)?
        } else {
            SweepDriver::new(factory.as_ref(), &base, &grid, &cfg)
        };
        self.set_progress(spec.id, driver.chunks_done(), driver.total_chunks(), "phase A");
        let before = self.cache.stats();
        let mut steps = 0usize;
        loop {
            let done = driver.step_with(factory.as_ref(), Some(&self.cache), Some(&self.coalescer))?;
            write_sweep_checkpoint(&ckpt, &driver.checkpoint())?;
            self.set_progress(spec.id, driver.chunks_done(), driver.total_chunks(), "phase A");
            steps += 1;
            if done {
                break;
            }
            if max_steps.is_some_and(|cap| steps >= cap) {
                return Ok(Step::Paused);
            }
        }
        let outcome = driver.outcome(Some(self.cache.stats().since(&before)));
        let mut tables = Vec::new();
        match spec.preset.as_str() {
            "fig7" => {
                let mut t = sweep_table(&outcome);
                t.title = format!("Fig 7 sweep [{}] — {}", cluster.label(), t.title);
                tables.push(t);
            }
            "trace" => {
                tables.push(sweep_table(&outcome));
                tables.push(trace_table(&outcome));
            }
            _ => tables.push(sweep_table(&outcome)),
        }
        tables.push(sweep_best_table(&outcome));
        self.finish(spec, &tables)?;
        Ok(Step::Finished)
    }

    fn drive_search(&self, spec: &JobSpec, max_steps: Option<usize>) -> crate::Result<Step> {
        let factory = self.factory();
        let cluster = Cluster::parse(&spec.cluster)
            .ok_or_else(|| anyhow::anyhow!("unknown cluster '{}'", spec.cluster))?;
        let cfg = SearchConfig {
            threads: spec.threads,
            seed: spec.seed,
            max_evals: spec.max_evals,
            ..SearchConfig::default()
        };
        match spec.space.as_str() {
            // The exhaustive anchor stays a CLI concern: the service
            // runs the search itself (the anchor is a correctness
            // cross-check, not part of the job's deliverable).
            "fig7" => {
                let space = sweep_fig7::profile_cluster(cluster);
                let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
                let sspace = SearchSpace::fig7_grid();
                let evaluator = ReplayEvaluator::new(&space.rows);
                self.search_loop(
                    spec, &cfg, &sspace, &evaluator, &space.base, &grid,
                    factory.as_ref(), max_steps,
                )
            }
            "expanded" => {
                let sspace = SearchSpace::expanded_2d3d();
                let workloads = cluster_workloads(cluster);
                let evaluator =
                    SimulatorEvaluator { workloads: workloads.clone(), fab: FabGrid::Coal };
                let base: EvalRequest = rows_request(Vec::new(), &workloads, YEAR_S, 1.0);
                let grid = search_fig7::expanded_grid();
                self.search_loop(
                    spec, &cfg, &sspace, &evaluator, &base, &grid,
                    factory.as_ref(), max_steps,
                )
            }
            other => anyhow::bail!("unknown search space '{other}'"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn search_loop(
        &self,
        spec: &JobSpec,
        cfg: &SearchConfig,
        sspace: &SearchSpace,
        evaluator: &dyn SpaceEvaluator,
        base: &EvalRequest,
        grid: &ScenarioGrid,
        factory: &dyn crate::runtime::EngineFactory,
        max_steps: Option<usize>,
    ) -> crate::Result<Step> {
        let ckpt = self.ckpt_path(spec.id);
        let mut driver = if ckpt.exists() {
            let ck = read_checkpoint(&ckpt)?;
            SearchDriver::resume(sspace, cfg, &ck)?
        } else {
            SearchDriver::new(sspace, cfg)
        };
        let mut steps = 0usize;
        loop {
            // Always step at least once: a no-op step on a resumed-
            // finished driver still binds the engine label the outcome
            // reports.
            let done = driver.step(factory, sspace, evaluator, base, grid, Some(&self.cache))?;
            atomic_write(&ckpt, &driver.checkpoint_string())?;
            self.set_progress(spec.id, driver.evaluations(), spec.max_evals, "search");
            steps += 1;
            if done {
                break;
            }
            if max_steps.is_some_and(|cap| steps >= cap) {
                return Ok(Step::Paused);
            }
        }
        let outcome = driver.outcome(sspace, grid);
        let tables = vec![search_table(&outcome), search_archive_table(&outcome)];
        self.finish(spec, &tables)?;
        Ok(Step::Finished)
    }

    // xrverify: model(job_registry)
    /// Persist the result (tables as structured JSON *and* rendered
    /// text) and retire the checkpoint — the spec+result pair is the
    /// job's durable record.
    fn finish(&self, spec: &JobSpec, tables: &[Table]) -> crate::Result<()> {
        let body = Json::obj(vec![
            ("id", Json::Num(spec.id as f64)),
            ("kind", Json::Str(spec.kind.label().to_string())),
            ("tables", Json::Arr(tables.iter().map(Table::to_json).collect())),
            ("rendered", Json::Arr(tables.iter().map(|t| Json::Str(t.render())).collect())),
        ]);
        atomic_write(&self.result_path(spec.id), &body.to_string())?;
        std::fs::remove_file(self.ckpt_path(spec.id)).ok();
        Ok(())
    }
    // xrverify: endmodel(job_registry)
}

/// Build a sweep preset's problem exactly as `xrcarbon sweep` does —
/// same base request, same scenario grid, chunk-for-chunk the same
/// content keys, which is what makes service jobs and CLI runs share
/// cache entries and coalesce with each other.
fn sweep_problem(spec: &JobSpec, cluster: Cluster) -> crate::Result<(EvalRequest, ScenarioGrid)> {
    match spec.preset.as_str() {
        "fig7" => {
            let space = sweep_fig7::profile_cluster(cluster);
            let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
            Ok((space.base, grid))
        }
        "fig10" | "lifetime" => {
            let space = sweep_fig7::profile_cluster(cluster);
            Ok((space.base, ScenarioGrid::lifetime_decades(3, 8)))
        }
        "ci" => {
            let space = sweep_fig7::profile_cluster(cluster);
            let mut base = space.base;
            base.lifetime_s = 2.0 * YEAR_S;
            Ok((base, ScenarioGrid::use_grids()))
        }
        "fig11" => {
            let apps = top10_apps();
            let base = provisioning_request(
                // xrlint: allow(panic, "top10_apps always returns 10 entries")
                &apps[..4],
                &crate::soc::VrSoc::default(),
                2.0 * YEAR_S,
                true,
            );
            Ok((base, ScenarioGrid::fig11()))
        }
        "trace" => {
            let space = sweep_fig7::profile_cluster(cluster);
            let mut base = space.base;
            base.lifetime_s = 2.0 * YEAR_S;
            let grid = match &spec.trace {
                Some(name) => {
                    let trace = CiTrace::by_name(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown trace '{name}'"))?;
                    ScenarioGrid::new().with_trace(&format!("trace={name}"), trace)
                }
                None => trace_study::trace_grid(),
            };
            Ok((base, grid))
        }
        other => anyhow::bail!("unknown sweep preset '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: JobKind) -> JobSpec {
        JobSpec {
            id: 7,
            kind,
            preset: "fig7".to_string(),
            space: "expanded".to_string(),
            cluster: "5ai".to_string(),
            threads: 2,
            seed: 0xDEAD_BEEF_DEAD_BEEF,
            max_evals: 40,
            trace: Some("diurnal-renewable".to_string()),
        }
    }

    #[test]
    fn job_spec_round_trips_through_the_sealed_envelope() {
        for kind in [JobKind::Sweep, JobKind::Search] {
            let s = spec(kind);
            let text = s.to_json_string();
            assert_eq!(JobSpec::from_json_str(&text).unwrap(), s);
        }
        let mut s = spec(JobKind::Sweep);
        s.trace = None;
        assert_eq!(JobSpec::from_json_str(&s.to_json_string()).unwrap(), s);
        // Large seeds survive (u64 does not fit an f64 JSON number).
        let got = JobSpec::from_json_str(&spec(JobKind::Search).to_json_string()).unwrap();
        assert_eq!(got.seed, 0xDEAD_BEEF_DEAD_BEEF);
    }

    #[test]
    fn tampered_spec_is_rejected() {
        let text = spec(JobKind::Sweep).to_json_string();
        let bent = text.replace("\"5ai\"", "\"10xr\"");
        assert!(JobSpec::from_json_str(&bent).is_err());
    }

    #[test]
    fn registry_scan_requeues_unfinished_specs_in_id_order() {
        let dir = crate::testkit::test_dir("svc_registry");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        for id in [3u64, 1, 2] {
            let s = JobSpec { id, ..spec(JobKind::Sweep) };
            std::fs::write(dir.join(format!("job_{id}.spec.json")), s.to_json_string()).unwrap();
        }
        // Job 2 already has a result: it must come back Done, unqueued.
        std::fs::write(dir.join("job_2.result.json"), "{}").unwrap();
        let reg = Registry::scan(&dir).unwrap();
        assert_eq!(reg.next_id, 4);
        assert_eq!(reg.queue, VecDeque::from(vec![1, 3]));
        assert_eq!(reg.jobs[&2].state, JobState::Done);
        assert_eq!(reg.jobs[&1].state, JobState::Queued);
        std::fs::remove_dir_all(&dir).ok();
    }
}
