//! Reporting: aligned tables, CSV, normalization and ASCII charts.
//!
//! Every experiment regenerates its paper figure as (a) an aligned text
//! table with the paper's rows/series, (b) an optional CSV dump, and (c)
//! an ASCII bar/line rendering for quick visual shape checks in the
//! terminal.

mod search;
mod sweep;
mod table;

pub use search::{search_archive_table, search_table};
pub use sweep::{sweep_best_table, sweep_table, trace_table};
pub use table::{ascii_bars, ascii_series, normalize_to, write_csv, Table};
