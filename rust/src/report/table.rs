//! Table/CSV/ASCII-chart primitives.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Title printed above the table.
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: row from display values.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                let _ = write!(s, "{:>width$}", c, width = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Structured JSON view — `{title, headers, rows}` with every cell
    /// the exact string `render`/`to_csv` would emit. The service layer
    /// returns this next to the rendered text so HTTP clients get the
    /// same numbers machine-readably.
    pub fn to_json(&self) -> crate::configfmt::Json {
        use crate::configfmt::Json;
        let strs = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect());
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("headers", strs(&self.headers)),
            ("rows", Json::Arr(self.rows.iter().map(|r| strs(r)).collect())),
        ])
    }

    /// CSV rendering (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Write a table as CSV to a path, creating parent directories.
pub fn write_csv(table: &Table, path: &str) -> crate::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, table.to_csv())?;
    Ok(())
}

/// Normalize values to a reference entry (the paper's "normalized to X").
pub fn normalize_to(values: &[f64], reference: f64) -> Vec<f64> {
    assert!(reference != 0.0 && reference.is_finite(), "bad normalization reference");
    values.iter().map(|v| v / reference).collect()
}

/// Horizontal ASCII bar chart (one bar per labeled value).
pub fn ascii_bars(labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let max = values.iter().cloned().fold(0.0f64, f64::max).max(1e-300);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / max) * width as f64).round().max(0.0) as usize;
        let _ = writeln!(out, "{:>lw$} | {} {:.4}", l, "#".repeat(n), v, lw = lw);
    }
    out
}

/// ASCII line chart for a (x, series...) set, log-x friendly: renders each
/// series as a row of scaled glyphs. Minimal but enough for shape checks.
pub fn ascii_series(x_labels: &[String], series: &[(&str, Vec<f64>)], width: usize) -> String {
    let mut out = String::new();
    let max = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().cloned())
        .fold(0.0f64, f64::max)
        .max(1e-300);
    for (name, ys) in series {
        assert_eq!(ys.len(), x_labels.len());
        let _ = write!(out, "{name:>10} |");
        for &y in ys {
            let n = ((y / max) * 9.0).round() as usize;
            let _ = write!(out, "{}", n.min(9));
        }
        let _ = writeln!(out);
    }
    let _ = write!(out, "{:>10} |", "x");
    let _ = writeln!(out, "{}", x_labels.iter().map(|l| l.chars().next().unwrap_or(' ')).collect::<String>());
    let _ = writeln!(out, "(digits = value scaled 0-9 of max; width hint {width})");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "v"]);
        t.row(&["a".into(), "1.5".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn json_view_matches_cells() {
        let mut t = Table::new("demo", &["name", "v"]);
        t.row(&["a".into(), "1.5".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").and_then(|v| v.as_str()), Some("demo"));
        let headers = j.get("headers").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(headers.len(), 2);
        let rows = j.get("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].as_arr().unwrap()[1].as_str(), Some("1.5"));
        // Deterministic rendering (sorted keys) — stable for clients.
        assert!(j.to_string().starts_with("{\"headers\""));
    }

    #[test]
    fn csv_quotes_specials() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn normalization() {
        let n = normalize_to(&[2.0, 4.0, 1.0], 2.0);
        assert_eq!(n, vec![1.0, 2.0, 0.5]);
    }

    #[test]
    fn bars_scale_to_max() {
        let s = ascii_bars(&["a".into(), "b".into()], &[1.0, 2.0], 10);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].matches('#').count() == 10);
        assert!(lines[0].matches('#').count() == 5);
    }

    #[test]
    fn series_renders_rows() {
        let s = ascii_series(
            &["1".into(), "2".into(), "3".into()],
            &[("a", vec![1.0, 2.0, 3.0]), ("b", vec![3.0, 2.0, 1.0])],
            30,
        );
        assert!(s.contains('a'));
        assert!(s.lines().count() >= 4);
    }
}
