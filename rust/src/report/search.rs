//! Rendering [`SearchOutcome`]s: the run-summary table (coverage,
//! convergence, incumbent) and the pooled Pareto-archive table the CLI
//! `sweep --search` subcommand prints.

use crate::dse::search::SearchOutcome;

use super::Table;

/// Search run summary: one row for the incumbent optimum plus the
/// coverage/convergence counters in the title.
pub fn search_table(out: &SearchOutcome) -> Table {
    let coverage = if out.space_size == 0 {
        0.0
    } else {
        100.0 * out.evaluations as f64 / out.space_size as f64
    };
    let mut t = Table::new(
        &format!(
            "Adaptive search — {} of {} candidates evaluated ({:.1}%), {} generation(s), {}, {} engine, {} thread(s)",
            out.evaluations,
            out.space_size,
            coverage,
            out.generations,
            if out.converged { "converged" } else { "budget-stopped" },
            out.engine,
            out.threads
        ),
        &["scenario", "optimal design", "tCDP [g*s]"],
    );
    match &out.best {
        Some(b) => t.row(&[b.scenario_label.clone(), b.name.clone(), format!("{:.3e}", b.tcdp)]),
        None => t.row(&["-".into(), "no feasible design".into(), "-".into()]),
    }
    t
}

/// Pooled Pareto archive: one row per non-dominated `(scenario, design)`
/// objective pair, ascending `F₁`.
pub fn search_archive_table(out: &SearchOutcome) -> Table {
    let mut t = Table::new(
        "Search archive — pooled Pareto front of (F1 = C_op*D, F2 = C_emb*D)",
        &["scenario", "design", "F1 [g*s]", "F2 [g*s]", "tCDP [g*s]"],
    );
    for a in &out.archive {
        t.row(&[
            a.scenario_label.clone(),
            a.name.clone(),
            format!("{:.3e}", a.f1),
            format!("{:.3e}", a.f2),
            format!("{:.3e}", a.tcdp),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::search::{search, SearchConfig};
    use crate::dse::space::{DesignPoint, SearchSpace};
    use crate::dse::ScenarioGrid;
    use crate::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
    use crate::runtime::HostEngineFactory;

    fn outcome() -> SearchOutcome {
        let space = SearchSpace {
            mac: vec![128, 512, 2048, 4096],
            sram: vec![1 << 20, 4 << 20, 16 << 20],
            stacking: vec![false],
            clock: vec![1.0e9],
        };
        let row = |p: &DesignPoint| {
            let m = p.num_macs as f64;
            ConfigRow {
                name: p.label.clone(),
                f_clk: 1e9,
                d_k: vec![10.0 / m],
                e_dyn: vec![1e-3 * m.sqrt()],
                leak_w: 0.0,
                c_comp: vec![0.4 * m, 0.0, 50.0],
            }
        };
        let base = EvalRequest {
            tasks: TaskMatrix::single_task("t", vec!["k".into()], &[1.0]),
            configs: Vec::new(),
            online: vec![1.0, 1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        };
        let grid = ScenarioGrid::new().with_lifetime("a", 1e5).with_lifetime("b", 1e7);
        search(
            &HostEngineFactory,
            &space,
            &row,
            &base,
            &grid,
            &SearchConfig { init_points_per_axis: 3, ..SearchConfig::default() },
        )
        .unwrap()
    }

    #[test]
    fn summary_table_reports_coverage_and_best() {
        let out = outcome();
        let t = search_table(&out);
        assert_eq!(t.len(), 1);
        let rendered = t.render();
        assert!(rendered.contains("candidates evaluated"));
        assert!(rendered.contains("host"));
        assert!(rendered.contains(&out.best.as_ref().unwrap().name));
    }

    #[test]
    fn archive_table_has_one_row_per_front_point() {
        let out = outcome();
        let t = search_archive_table(&out);
        assert_eq!(t.len(), out.archive.len());
        assert!(!out.archive.is_empty());
        let rendered = t.render();
        assert!(rendered.contains(&out.archive[0].name));
    }
}
