//! Rendering [`SweepOutcome`]s: the per-scenario statistics table and the
//! cross-scenario best/argmin table the CLI `sweep` subcommand prints.

use crate::dse::sweep::SweepOutcome;
use crate::matrixform::MetricRow;

use super::Table;

/// Per-scenario `ExploreStats` table, one row per scenario in grid order.
/// When the sweep ran against a profile cache the title carries this
/// run's hit/miss delta and the contractions avoided.
pub fn sweep_table(out: &SweepOutcome) -> Table {
    let cache = match &out.cache {
        Some(cs) => {
            let mut s = format!(
                ", cache: {} hit(s) / {} miss(es) ({} rejected), {} contraction(s) avoided",
                cs.hits,
                cs.misses,
                cs.rejected,
                cs.contractions_avoided()
            );
            if cs.mem_hits > 0 {
                s.push_str(&format!(" [{} from memory]", cs.mem_hits));
            }
            if cs.evictions > 0 {
                s.push_str(&format!(", {} evicted", cs.evictions));
            }
            s
        }
        None => String::new(),
    };
    let mut t = Table::new(
        &format!(
            "Scenario sweep — {} scenarios, {} profile chunk(s), {} work items, {} engine, {} thread(s){}",
            out.scenarios.len(),
            out.profile_chunks,
            out.items,
            out.engine,
            out.threads,
            cache
        ),
        &["scenario", "feasible", "best tCDP", "mean", "p5", "p95", "optimal design"],
    );
    for s in &out.scenarios {
        let st = &s.outcome.stats;
        let best_design = s
            .outcome
            .optimal
            .get("tCDP")
            .map(|&i| s.outcome.result.names[i].clone())
            .unwrap_or_else(|| "-".to_string());
        t.row(&[
            s.label.clone(),
            st.feasible.to_string(),
            format!("{:.3e}", st.best),
            format!("{:.3e}", st.mean),
            format!("{:.3e}", st.p5),
            format!("{:.3e}", st.p95),
            best_design,
        ]);
    }
    t
}

/// Cross-scenario argmin table: the single feasible (scenario, design)
/// pair minimizing tCDP over the whole sweep, with its carbon split.
pub fn sweep_best_table(out: &SweepOutcome) -> Table {
    let mut t = Table::new(
        "Cross-scenario optimum (feasible argmin of tCDP)",
        &["scenario", "design", "tCDP [g*s]", "C_op [g]", "C_emb [g]", "delay [s]"],
    );
    if let Some((si, ci, v)) = out.best() {
        let s = &out.scenarios[si];
        let r = &s.outcome.result;
        t.row(&[
            s.label.clone(),
            r.names[ci].clone(),
            format!("{v:.3e}"),
            format!("{:.3e}", r.metric(MetricRow::COp, ci)),
            format!("{:.3e}", r.metric(MetricRow::CEmb, ci)),
            format!("{:.3e}", r.metric(MetricRow::Delay, ci)),
        ]);
    }
    t
}

/// Trace-scenario table: one row per scenario that carried a
/// carbon-intensity trace, comparing the trace-averaged optimum against
/// the static mean-CI collapse of the same trace. Because operational
/// carbon is linear in CI, the delta is pure f32 rounding — the column is
/// a built-in sanity check; the real signal is the swing in best tCDP
/// *across* rows (renewable vs coal grids). Empty when the sweep had no
/// trace axis (the CLI skips printing it then).
pub fn trace_table(out: &SweepOutcome) -> Table {
    let mut t = Table::new(
        "Trace scenarios — trace vs static mean-CI collapse",
        &[
            "scenario",
            "segments",
            "mean CI [g/kWh]",
            "CI range [g/kWh]",
            "best tCDP (trace)",
            "best tCDP (static)",
            "delta",
        ],
    );
    for s in &out.scenarios {
        let Some(meta) = &s.trace else { continue };
        let best = s.outcome.stats.best;
        let delta = if best.is_finite() && meta.static_best_tcdp.is_finite() && best != 0.0 {
            format!("{:+.2e}%", (best - meta.static_best_tcdp) / best * 100.0)
        } else {
            "-".to_string()
        };
        t.row(&[
            s.label.clone(),
            meta.segments.to_string(),
            format!("{:.1}", meta.mean_ci_g_per_kwh),
            format!("{:.1}..{:.1}", meta.min_ci_g_per_kwh, meta.max_ci_g_per_kwh),
            format!("{best:.3e}"),
            format!("{:.3e}", meta.static_best_tcdp),
            delta,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::CiTrace;
    use crate::dse::grid::ScenarioGrid;
    use crate::dse::sweep::{sweep, SweepConfig};
    use crate::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
    use crate::runtime::HostEngineFactory;

    fn outcome() -> SweepOutcome {
        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[5.0]);
        let req = EvalRequest {
            tasks,
            configs: (0..3)
                .map(|i| ConfigRow {
                    name: format!("c{i}"),
                    f_clk: 1e9,
                    d_k: vec![(i + 1) as f64 * 1e-3],
                    e_dyn: vec![0.02],
                    leak_w: 0.0,
                    c_comp: vec![50.0 * (i + 1) as f64],
                })
                .collect(),
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        };
        let grid = ScenarioGrid::new().with_lifetime("a", 1e5).with_lifetime("b", 1e7);
        sweep(&HostEngineFactory, &req, &grid, &SweepConfig::default()).unwrap()
    }

    #[test]
    fn sweep_table_has_one_row_per_scenario() {
        let out = outcome();
        let t = sweep_table(&out);
        assert_eq!(t.len(), 2);
        let rendered = t.render();
        assert!(rendered.contains("a"));
        assert!(rendered.contains("host"));
    }

    #[test]
    fn sweep_table_reports_cache_stats_when_present() {
        let mut out = outcome();
        assert!(out.cache.is_none());
        assert!(!sweep_table(&out).title.contains("cache:"));
        out.cache = Some(crate::runtime::CacheStats {
            hits: 3,
            misses: 1,
            rejected: 1,
            writes: 1,
            ..crate::runtime::CacheStats::default()
        });
        let title = sweep_table(&out).title;
        assert!(title.contains("cache: 3 hit(s) / 1 miss(es) (1 rejected)"), "{title}");
        assert!(title.contains("3 contraction(s) avoided"), "{title}");
        // Memory hits and evictions appear only when nonzero.
        assert!(!title.contains("from memory"), "{title}");
        assert!(!title.contains("evicted"), "{title}");
        out.cache = Some(crate::runtime::CacheStats {
            hits: 3,
            mem_hits: 2,
            evictions: 4,
            ..crate::runtime::CacheStats::default()
        });
        let title = sweep_table(&out).title;
        assert!(title.contains("[2 from memory]"), "{title}");
        assert!(title.contains("4 evicted"), "{title}");
    }

    #[test]
    fn trace_table_lists_only_trace_scenarios() {
        let out = outcome();
        // No trace axis → empty table.
        assert_eq!(trace_table(&out).len(), 0);

        let tasks = TaskMatrix::single_task("t", vec!["k".into()], &[5.0]);
        let req = EvalRequest {
            tasks,
            configs: vec![ConfigRow {
                name: "c0".into(),
                f_clk: 1e9,
                d_k: vec![1e-3],
                e_dyn: vec![0.02],
                leak_w: 0.0,
                c_comp: vec![50.0],
            }],
            online: vec![1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        };
        let grid = ScenarioGrid::new()
            .with_lifetime("1y", 1e7)
            .with_trace("trace=world", CiTrace::diurnal_world());
        let out = sweep(&HostEngineFactory, &req, &grid, &SweepConfig::default()).unwrap();
        let t = trace_table(&out);
        assert_eq!(t.len(), 1, "one trace scenario, one row");
        let rendered = t.render();
        assert!(rendered.contains("trace=world"), "{rendered}");
        assert!(rendered.contains("24"), "{rendered}");
    }

    #[test]
    fn best_table_names_the_global_optimum() {
        let out = outcome();
        let (si, ci, _) = out.best().unwrap();
        let t = sweep_best_table(&out);
        assert_eq!(t.len(), 1);
        let rendered = t.render();
        assert!(rendered.contains(&out.scenarios[si].outcome.result.names[ci]));
    }
}
