//! Analytical accelerator performance/power simulator (paper §4.4, Fig 6).
//!
//! The paper evaluates candidate hardware with "an accelerator simulator
//! based on a scaled-up version of Sumbul et al's work \[CICC'22\]": a
//! neural network goes in, the simulator extracts the operators and
//! reports TOPS, latency, utilization and energy for a specified hardware
//! configuration. That simulator is proprietary, so this module implements
//! the closest analytical equivalent:
//!
//! * [`ops`] — operator model: each layer reduces to MAC count, weight
//!   bytes and activation bytes;
//! * [`networks`] — the twelve Table 3 AI/XR workloads as operator lists
//!   built from first principles (layer shapes);
//! * [`config`] — hardware configuration (MAC count, on-chip SRAM, clock,
//!   voltage, memory interface) and its die area / embodied carbon;
//! * [`simulator`] — the roofline-style performance and energy model
//!   (MAC-array utilization from layer shape, working-set-driven DRAM
//!   traffic, double-buffered compute/memory overlap);
//! * [`stacking`] — 3D F2F-stacked SRAM variants (§5.6, Fig 15a).

pub mod config;
pub mod networks;
pub mod ops;
pub mod simulator;
pub mod stacking;

pub use config::{AcceleratorConfig, MemoryInterface, production_accelerators};
pub use networks::{network, Workload};
pub use ops::{OpGraph, OpKind};
pub use simulator::{simulate, KernelProfile};
pub use stacking::{stacked_configs, StackedDesign};
