//! The Table 3 AI/XR workload suite as operator graphs.
//!
//! Layer lists are built from the published architectures (ResNet /
//! GoogleNet / MobileNet-V2 / SegNet / UNet / HRNet / FAN / ...) at the
//! paper's use-case resolutions. These are first-principles
//! reconstructions — aggregate MAC counts land on the published numbers
//! (e.g. ResNet-50 ≈ 4.1 GMACs at 224²) — not framework exports; the
//! simulator only needs per-layer MACs/bytes/shapes.

use super::ops::{conv2d, conv3d, deconv2d, dwconv, eltwise, fc, OpGraph};

/// The Table 3 workloads (plus the three SR resolutions of Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// ResNet-18 object classification (AI).
    Rn18,
    /// ResNet-50 object classification (AI).
    Rn50,
    /// ResNet-152 object classification (AI).
    Rn152,
    /// GoogleNet object classification (AI).
    Gn,
    /// MobileNet-V2 object detection backbone (AI).
    Mn2,
    /// SegNet eye tracking (XR).
    Et,
    /// 3-D aggregation depth estimation (XR).
    Agg3d,
    /// High-resolution net, depth for augmented calls (XR).
    Hrn,
    /// EmoFAN emotion detection (XR).
    EFan,
    /// Joint-location-predictor hand tracking (XR).
    Jlp,
    /// Plain UNet segmentation/denoising trunk (XR).
    Unet,
    /// UNet + Feature-Align image denoising (XR).
    Dn,
    /// Burst super-resolution at 256×256 (XR).
    Sr256,
    /// Burst super-resolution at 512×512 (XR).
    Sr512,
    /// Burst super-resolution at 1024×1024 (XR).
    Sr1024,
}

impl Workload {
    /// Every workload, Table 3 order (SR expanded per Table 4).
    pub const ALL: [Workload; 15] = [
        Workload::Rn18,
        Workload::Rn50,
        Workload::Rn152,
        Workload::Gn,
        Workload::Mn2,
        Workload::Et,
        Workload::Agg3d,
        Workload::Hrn,
        Workload::EFan,
        Workload::Jlp,
        Workload::Unet,
        Workload::Dn,
        Workload::Sr256,
        Workload::Sr512,
        Workload::Sr1024,
    ];

    /// Table 3 abbreviation.
    pub fn label(self) -> &'static str {
        match self {
            Workload::Rn18 => "RN-18",
            Workload::Rn50 => "RN-50",
            Workload::Rn152 => "RN-152",
            Workload::Gn => "GN",
            Workload::Mn2 => "MN2",
            Workload::Et => "ET",
            Workload::Agg3d => "3D-Agg",
            Workload::Hrn => "HRN",
            Workload::EFan => "E-FAN",
            Workload::Jlp => "JLP",
            Workload::Unet => "UNet",
            Workload::Dn => "DN",
            Workload::Sr256 => "SR-256",
            Workload::Sr512 => "SR-512",
            Workload::Sr1024 => "SR-1024",
        }
    }

    /// True for the paper's XR category (Table 3).
    pub fn is_xr(self) -> bool {
        !matches!(
            self,
            Workload::Rn18 | Workload::Rn50 | Workload::Rn152 | Workload::Gn | Workload::Mn2
        )
    }

    /// Parse a Table 3 abbreviation (case-insensitive).
    pub fn parse(s: &str) -> Option<Workload> {
        let up = s.to_ascii_uppercase();
        Workload::ALL.into_iter().find(|w| w.label().eq_ignore_ascii_case(&up))
    }
}

/// Build the operator graph for a workload.
pub fn network(w: Workload) -> OpGraph {
    match w {
        Workload::Rn18 => resnet("RN-18", &[2, 2, 2, 2], false),
        Workload::Rn50 => resnet("RN-50", &[3, 4, 6, 3], true),
        Workload::Rn152 => resnet("RN-152", &[3, 8, 36, 3], true),
        Workload::Gn => googlenet(),
        Workload::Mn2 => mobilenet_v2(),
        Workload::Et => segnet_et(),
        Workload::Agg3d => agg3d(),
        Workload::Hrn => hrnet(),
        Workload::EFan => emofan(),
        Workload::Jlp => jlp(),
        Workload::Unet => unet_plain(),
        Workload::Dn => unet_dn(),
        Workload::Sr256 => superres("SR-256", 256),
        Workload::Sr512 => superres("SR-512", 512),
        Workload::Sr1024 => superres("SR-1024", 1024),
    }
}

/// ResNet family at 224². `bottleneck` selects the 1-3-1 block (RN-50+).
fn resnet(name: &str, blocks: &[usize; 4], bottleneck: bool) -> OpGraph {
    let mut ops = vec![conv2d("stem", 224, 224, 3, 64, 7, 2)];
    ops.push(eltwise("stem-pool", 112 * 112 * 64));
    let widths = [64u32, 128, 256, 512];
    let mut h = 56u32; // after stem + maxpool
    let expansion = if bottleneck { 4 } else { 1 };
    let mut cin = 64u32;
    for (stage, (&n, &width)) in blocks.iter().zip(widths.iter()).enumerate() {
        for b in 0..n {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            if stride == 2 {
                h /= 2;
            }
            let cout = width * expansion;
            if bottleneck {
                ops.push(conv2d(&format!("s{stage}b{b}-1x1a"), h * stride, h * stride, cin, width, 1, stride));
                ops.push(conv2d(&format!("s{stage}b{b}-3x3"), h, h, width, width, 3, 1));
                ops.push(conv2d(&format!("s{stage}b{b}-1x1b"), h, h, width, cout, 1, 1));
            } else {
                ops.push(conv2d(&format!("s{stage}b{b}-3x3a"), h * stride, h * stride, cin, width, 3, stride));
                ops.push(conv2d(&format!("s{stage}b{b}-3x3b"), h, h, width, cout, 3, 1));
            }
            if cin != cout || stride == 2 {
                ops.push(conv2d(&format!("s{stage}b{b}-proj"), h * stride, h * stride, cin, cout, 1, stride));
            }
            ops.push(eltwise(&format!("s{stage}b{b}-add"), (h * h * cout) as u64));
            cin = cout;
        }
    }
    ops.push(fc("fc", cin, 1000));
    OpGraph { name: name.to_string(), ops }
}

/// GoogleNet (Inception-v1) approximation at 224²: stem + 9 inception
/// modules with the published channel mixes.
fn googlenet() -> OpGraph {
    let mut ops = vec![
        conv2d("stem-7x7", 224, 224, 3, 64, 7, 2),
        conv2d("stem-3x3r", 56, 56, 64, 64, 1, 1),
        conv2d("stem-3x3", 56, 56, 64, 192, 3, 1),
    ];
    // (h, cin, [b1, b3r, b3, b5r, b5, pool_proj])
    let modules: [(u32, u32, [u32; 6]); 9] = [
        (28, 192, [64, 96, 128, 16, 32, 32]),
        (28, 256, [128, 128, 192, 32, 96, 64]),
        (14, 480, [192, 96, 208, 16, 48, 64]),
        (14, 512, [160, 112, 224, 24, 64, 64]),
        (14, 512, [128, 128, 256, 24, 64, 64]),
        (14, 512, [112, 144, 288, 32, 64, 64]),
        (14, 528, [256, 160, 320, 32, 128, 128]),
        (7, 832, [256, 160, 320, 32, 128, 128]),
        (7, 832, [384, 192, 384, 48, 128, 128]),
    ];
    for (i, (h, cin, b)) in modules.iter().enumerate() {
        let tag = format!("inc{i}");
        ops.push(conv2d(&format!("{tag}-1x1"), *h, *h, *cin, b[0], 1, 1));
        ops.push(conv2d(&format!("{tag}-3x3r"), *h, *h, *cin, b[1], 1, 1));
        ops.push(conv2d(&format!("{tag}-3x3"), *h, *h, b[1], b[2], 3, 1));
        ops.push(conv2d(&format!("{tag}-5x5r"), *h, *h, *cin, b[3], 1, 1));
        ops.push(conv2d(&format!("{tag}-5x5"), *h, *h, b[3], b[4], 5, 1));
        ops.push(conv2d(&format!("{tag}-pool"), *h, *h, *cin, b[5], 1, 1));
    }
    ops.push(fc("fc", 1024, 1000));
    OpGraph { name: "GN".to_string(), ops }
}

/// MobileNet-V2 at 224²: inverted residual stages.
fn mobilenet_v2() -> OpGraph {
    let mut ops = vec![conv2d("stem", 224, 224, 3, 32, 3, 2)];
    // (t expansion, c out, n repeats, s stride) per the paper.
    let cfg: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut h = 112u32;
    let mut cin = 32u32;
    for (si, &(t, c, n, s)) in cfg.iter().enumerate() {
        for b in 0..n {
            let stride = if b == 0 { s } else { 1 };
            let hidden = cin * t;
            let tag = format!("ir{si}.{b}");
            if t != 1 {
                ops.push(conv2d(&format!("{tag}-expand"), h, h, cin, hidden, 1, 1));
            }
            if stride == 2 {
                h /= 2;
            }
            ops.push(dwconv(&format!("{tag}-dw"), h * stride, h * stride, hidden, 3, stride));
            ops.push(conv2d(&format!("{tag}-project"), h, h, hidden, c, 1, 1));
            cin = c;
        }
    }
    ops.push(conv2d("head", 7, 7, 320, 1280, 1, 1));
    ops.push(fc("fc", 1280, 1000));
    OpGraph { name: "MN2".to_string(), ops }
}

/// SegNet eye-tracking variant: VGG-ish encoder/decoder at 320×240 on a
/// near-eye camera crop, thinned channels (eye tracking runs at high rate
/// on a tiny power budget).
fn segnet_et() -> OpGraph {
    let mut ops = Vec::new();
    let (w, h) = (320u32, 240u32);
    let enc = [(32u32, 1u32), (64, 2), (128, 2), (256, 2)];
    let mut cin = 1u32; // IR camera, single channel
    let (mut cw, mut ch) = (w, h);
    for (i, &(c, down)) in enc.iter().enumerate() {
        ops.push(conv2d(&format!("enc{i}a"), cw, ch, cin, c, 3, down));
        cw /= down;
        ch /= down;
        ops.push(conv2d(&format!("enc{i}b"), cw, ch, c, c, 3, 1));
        cin = c;
    }
    for (i, &(c, up)) in enc.iter().rev().enumerate() {
        let cout = if i + 1 < enc.len() { enc[enc.len() - 2 - i].0 } else { 16 };
        ops.push(deconv2d(&format!("dec{i}"), cw, ch, cin, c, 3, up));
        cw *= up;
        ch *= up;
        ops.push(conv2d(&format!("dec{i}b"), cw, ch, c, cout, 3, 1));
        cin = cout;
    }
    ops.push(conv2d("seg-head", w, h, cin, 4, 1, 1)); // pupil/iris/sclera/bg
    OpGraph { name: "ET".to_string(), ops }
}

/// Temporally-consistent depth: 2-D feature extraction + 3-D cost-volume
/// aggregation at 160×120 with 24 depth hypotheses.
fn agg3d() -> OpGraph {
    let mut ops = vec![
        conv2d("feat-a", 320, 240, 3, 32, 3, 2),
        conv2d("feat-b", 160, 120, 32, 32, 3, 1),
        conv2d("feat-c", 160, 120, 32, 32, 3, 1),
    ];
    for i in 0..4 {
        ops.push(conv3d(&format!("agg{i}"), 160, 120, 24, if i == 0 { 16 } else { 16 }, 16, 3));
    }
    ops.push(conv3d("agg-out", 160, 120, 24, 16, 1, 3));
    ops.push(eltwise("softargmax", 160 * 120 * 24));
    OpGraph { name: "3D-Agg".to_string(), ops }
}

/// HRNet-W18-ish: parallel multi-resolution branches at 256×192 input
/// (the depth-for-augmented-calls use case keeps a high-res stream alive).
fn hrnet() -> OpGraph {
    let mut ops = vec![
        conv2d("stem-a", 256, 192, 3, 64, 3, 2),
        conv2d("stem-b", 128, 96, 64, 64, 3, 1),
    ];
    // Branch resolutions and widths (HRNet-W18).
    let branches = [(64u32, 48u32, 18u32), (32, 24, 36), (16, 12, 72), (8, 6, 144)];
    // 3 multi-resolution stages, 4 blocks each, on every active branch.
    for stage in 0..3 {
        let active = stage + 2; // stage0 -> 2 branches, ... stage2 -> 4
        for (bi, &(bw, bh, c)) in branches.iter().take(active).enumerate() {
            for blk in 0..4 {
                ops.push(conv2d(&format!("s{stage}br{bi}blk{blk}a"), bw * 4, bh * 4, c, c, 3, 1));
                ops.push(conv2d(&format!("s{stage}br{bi}blk{blk}b"), bw * 4, bh * 4, c, c, 3, 1));
            }
            // Fusion convs to the neighbouring resolution.
            if bi + 1 < active {
                let (nw, nh, nc) = branches[bi + 1];
                ops.push(conv2d(&format!("s{stage}fuse{bi}"), nw * 4, nh * 4, c, nc, 3, 2));
            }
        }
    }
    ops.push(conv2d("head", 256, 192, 18, 1, 1, 1));
    OpGraph { name: "HRN".to_string(), ops }
}

/// EmoFAN: FAN-style hourglass on a 128² face crop + valence/arousal head.
fn emofan() -> OpGraph {
    let mut ops = vec![conv2d("stem", 128, 128, 3, 64, 7, 2)];
    let mut h = 64u32;
    let mut cin = 64u32;
    // Hourglass down path.
    for i in 0..3 {
        let c = 128 + 64 * i as u32;
        ops.push(conv2d(&format!("hg-down{i}"), h, h, cin, c, 3, 2));
        h /= 2;
        cin = c;
    }
    // Bottleneck residuals.
    for i in 0..2 {
        ops.push(conv2d(&format!("hg-mid{i}"), h, h, cin, cin, 3, 1));
    }
    // Up path.
    for i in 0..3 {
        let c = if i < 2 { 128 + 64 * (1 - i as u32) } else { 68 };
        ops.push(deconv2d(&format!("hg-up{i}"), h, h, cin, c, 3, 2));
        h *= 2;
        cin = c;
    }
    ops.push(conv2d("heatmap", 64, 64, 68, 68, 1, 1));
    ops.push(fc("emotion-head", 68 * 8 * 8, 256));
    ops.push(fc("va-out", 256, 2));
    OpGraph { name: "E-FAN".to_string(), ops }
}

/// Joint-location predictor (hand tracking): small regression CNN on a
/// 128² hand crop from the egocentric RGB-D stream, 21 joints.
fn jlp() -> OpGraph {
    let mut ops = vec![conv2d("stem", 128, 128, 4, 32, 3, 2)];
    let widths = [64u32, 128, 192];
    let mut h = 64u32;
    let mut cin = 32u32;
    for (i, &c) in widths.iter().enumerate() {
        ops.push(conv2d(&format!("b{i}a"), h, h, cin, c, 3, 2));
        h /= 2;
        ops.push(conv2d(&format!("b{i}b"), h, h, c, c, 3, 1));
        cin = c;
    }
    ops.push(fc("fc1", cin * 8 * 8, 512));
    ops.push(fc("joints", 512, 21 * 3));
    OpGraph { name: "JLP".to_string(), ops }
}

/// Plain UNet trunk at 256×256 (the Table 4 "UNet" kernel without the
/// Feature-Align burst stage).
fn unet_plain() -> OpGraph {
    let mut ops = Vec::new();
    let widths = [24u32, 48, 96, 192];
    let mut h = 256u32;
    let mut cin = 3u32;
    for (i, &c) in widths.iter().enumerate() {
        ops.push(conv2d(&format!("enc{i}a"), h, h, cin, c, 3, 1));
        ops.push(conv2d(&format!("enc{i}b"), h, h, c, c, 3, 1));
        if i + 1 < widths.len() {
            ops.push(eltwise(&format!("pool{i}"), (h / 2 * h / 2 * c) as u64));
            h /= 2;
        }
        cin = c;
    }
    for (i, &c) in widths.iter().rev().skip(1).enumerate() {
        ops.push(deconv2d(&format!("up{i}"), h, h, cin, c, 2, 2));
        h *= 2;
        ops.push(conv2d(&format!("dec{i}a"), h, h, c * 2, c, 3, 1));
        ops.push(conv2d(&format!("dec{i}b"), h, h, c, c, 3, 1));
        cin = c;
    }
    ops.push(conv2d("out", 256, 256, 24, 3, 3, 1));
    OpGraph { name: "UNet".to_string(), ops }
}

/// UNet + Feature-Align denoiser at 512×512 (burst denoise for
/// low-light passthrough).
fn unet_dn() -> OpGraph {
    let mut ops = Vec::new();
    let widths = [32u32, 64, 128, 256];
    let mut h = 512u32;
    let mut cin = 4u32; // packed Bayer
    // Feature-align pre-stage (KD-distilled alignment of 4 burst frames).
    ops.push(conv2d("align-a", 512, 512, 16, 32, 3, 1));
    ops.push(conv2d("align-b", 512, 512, 32, 16, 3, 1));
    for (i, &c) in widths.iter().enumerate() {
        ops.push(conv2d(&format!("enc{i}a"), h, h, cin, c, 3, 1));
        ops.push(conv2d(&format!("enc{i}b"), h, h, c, c, 3, 1));
        if i + 1 < widths.len() {
            ops.push(eltwise(&format!("pool{i}"), (h / 2 * h / 2 * c) as u64));
            h /= 2;
        }
        cin = c;
    }
    for (i, &c) in widths.iter().rev().skip(1).enumerate() {
        ops.push(deconv2d(&format!("up{i}"), h, h, cin, c, 2, 2));
        h *= 2;
        // Skip connection doubles input channels.
        ops.push(conv2d(&format!("dec{i}a"), h, h, c * 2, c, 3, 1));
        ops.push(conv2d(&format!("dec{i}b"), h, h, c, c, 3, 1));
        cin = c;
    }
    ops.push(conv2d("out", 512, 512, 32, 4, 3, 1));
    OpGraph { name: "DN".to_string(), ops }
}

/// Burst super-resolution (deep-burst-SR style): shallow feature
/// extraction per frame, fusion, reconstruction trunk at the input
/// resolution, one pixel-shuffle 2× upsample to the named **output**
/// resolution (`size×size` is the delivered frame, as in the paper's
/// SR(512×512) passthrough use case).
fn superres(name: &str, size: u32) -> OpGraph {
    let inres = size / 2;
    let mut ops = vec![
        conv2d("feat", inres, inres, 3, 24, 3, 1),
        conv2d("fuse", inres, inres, 24 * 2, 32, 3, 1), // burst fusion (2 eff. frames)
    ];
    for i in 0..4 {
        ops.push(conv2d(&format!("res{i}a"), inres, inres, 32, 32, 3, 1));
        ops.push(conv2d(&format!("res{i}b"), inres, inres, 32, 32, 3, 1));
    }
    // Pixel-shuffle 2x upsampler.
    ops.push(conv2d("ps", inres, inres, 32, 128, 3, 1));
    ops.push(eltwise("shuffle", size as u64 * size as u64 * 32));
    ops.push(conv2d("out", size, size, 32, 3, 3, 1));
    OpGraph { name: name.to_string(), ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_macs_near_published() {
        // Published: ~4.1 GMACs at 224^2.
        let g = network(Workload::Rn50);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((3.2..5.2).contains(&gmacs), "RN-50 GMACs = {gmacs}");
    }

    #[test]
    fn resnet18_macs_near_published() {
        // Published: ~1.8 GMACs.
        let gmacs = network(Workload::Rn18).total_macs() as f64 / 1e9;
        assert!((1.3..2.6).contains(&gmacs), "RN-18 GMACs = {gmacs}");
    }

    #[test]
    fn resnet152_macs_near_published() {
        // Published: ~11.5 GMACs.
        let gmacs = network(Workload::Rn152).total_macs() as f64 / 1e9;
        assert!((9.0..14.5).contains(&gmacs), "RN-152 GMACs = {gmacs}");
    }

    #[test]
    fn mobilenet_is_light() {
        // Published: ~0.3 GMACs; must be far lighter than ResNet-18.
        let mn2 = network(Workload::Mn2).total_macs();
        let rn18 = network(Workload::Rn18).total_macs();
        assert!((mn2 as f64 / 1e9) < 0.8, "MN2 GMACs = {}", mn2 as f64 / 1e9);
        assert!(rn18 > mn2 * 3);
    }

    #[test]
    fn googlenet_macs_near_published() {
        // Published: ~1.5 GMACs.
        let gmacs = network(Workload::Gn).total_macs() as f64 / 1e9;
        assert!((1.0..2.4).contains(&gmacs), "GN GMACs = {gmacs}");
    }

    #[test]
    fn resnet_depth_ordering() {
        let m18 = network(Workload::Rn18).total_macs();
        let m50 = network(Workload::Rn50).total_macs();
        let m152 = network(Workload::Rn152).total_macs();
        assert!(m18 < m50 && m50 < m152);
    }

    #[test]
    fn sr_scales_quadratically_with_resolution() {
        let s256 = network(Workload::Sr256).total_macs() as f64;
        let s512 = network(Workload::Sr512).total_macs() as f64;
        let s1024 = network(Workload::Sr1024).total_macs() as f64;
        assert!((s512 / s256 - 4.0).abs() < 0.4, "ratio={}", s512 / s256);
        assert!((s1024 / s512 - 4.0).abs() < 0.4);
    }

    #[test]
    fn sr1024_has_huge_activations() {
        // The §5.6 motivation: SR's working set dwarfs on-chip SRAM.
        let g = network(Workload::Sr1024);
        assert!(g.peak_activation_bytes() > 16 * 1024 * 1024);
    }

    #[test]
    fn all_networks_build_and_are_nonempty() {
        for w in Workload::ALL {
            let g = network(w);
            assert!(!g.ops.is_empty(), "{} empty", w.label());
            assert!(g.total_macs() > 0, "{} zero macs", w.label());
            assert!(g.total_weight_bytes() > 0, "{} zero weights", w.label());
        }
    }

    #[test]
    fn xr_category_matches_table3() {
        assert!(!Workload::Rn50.is_xr());
        assert!(!Workload::Mn2.is_xr());
        assert!(Workload::Et.is_xr());
        assert!(Workload::Sr512.is_xr());
        assert!(Workload::EFan.is_xr());
    }

    #[test]
    fn labels_roundtrip() {
        for w in Workload::ALL {
            assert_eq!(Workload::parse(w.label()), Some(w));
        }
        assert_eq!(Workload::parse("not-a-net"), None);
    }

    #[test]
    fn depthwise_layers_present_in_mn2() {
        let g = network(Workload::Mn2);
        let dw = g.ops.iter().filter(|o| o.kind == super::super::ops::OpKind::DepthwiseConv).count();
        assert!(dw >= 10, "expected many depthwise layers, got {dw}");
    }
}
