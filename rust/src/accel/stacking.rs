//! 3-D stacked accelerator variants (§5.6, Fig 15a).
//!
//! The paper compares the 2-D baseline A-4 (off-chip memory over an
//! energy-hungry LPDDR interface) against six 3-D configurations that
//! stack SRAM dies on the logic die with face-to-face hybrid bonding:
//! `K ∈ {1K, 2K}` MAC arrays × `M ∈ {4, 8, 16}` MB stacked SRAM.

use super::config::AcceleratorConfig;

/// A named 3-D design point.
#[derive(Debug, Clone)]
pub struct StackedDesign {
    /// Paper-style label ("3D_2K_16M").
    pub label: String,
    /// The configuration (stacked SRAM, F2F interface).
    pub config: AcceleratorConfig,
}

/// The 2-D baseline of Fig 15a: A-4 (1K MACs, 2 MB on-die, LPDDR).
pub fn baseline_2d() -> AcceleratorConfig {
    let mut a4 = AcceleratorConfig::new_2d("2D_1K_2M", 1024, 2 * 1024 * 1024);
    a4.freq_hz = 1.2e9;
    a4
}

/// The six 3-D configurations of Fig 15a.
pub fn stacked_configs() -> Vec<StackedDesign> {
    let mut out = Vec::new();
    for &k in &[1024u32, 2048] {
        for &mb in &[4u64, 8, 16] {
            let label = format!("3D_{}K_{}M", k / 1024, mb);
            let mut cfg = AcceleratorConfig::new_3d(&label, k, mb * 1024 * 1024);
            cfg.freq_hz = 1.2e9;
            cfg.arrays = k / 1024; // Fig 15a: K counts 1024-MAC arrays
            out.push(StackedDesign { label, config: cfg });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::networks::{network, Workload};
    use crate::accel::simulate;
    use crate::carbon::FabGrid;

    #[test]
    fn six_configs_with_paper_labels() {
        let cfgs = stacked_configs();
        assert_eq!(cfgs.len(), 6);
        let labels: Vec<&str> = cfgs.iter().map(|c| c.label.as_str()).collect();
        assert!(labels.contains(&"3D_2K_16M"));
        assert!(labels.contains(&"3D_1K_4M"));
    }

    #[test]
    fn stacked_embodied_exceeds_baseline() {
        // More silicon -> more embodied carbon than the lean 2-D baseline.
        let base = baseline_2d().embodied_g(FabGrid::Coal);
        for d in stacked_configs() {
            assert!(
                d.config.embodied_g(FabGrid::Coal) > base,
                "{} embodied below 2D baseline",
                d.label
            );
        }
    }

    #[test]
    fn stacked_wins_operationally_on_sr() {
        // §5.6: for SR the 3-D configs cut energy (and usually latency).
        let base = baseline_2d();
        let g = network(Workload::Sr512);
        let pb = simulate(&base, &g);
        let d = &stacked_configs()[5]; // 3D_2K_16M
        let ps = simulate(&d.config, &g);
        assert!(ps.energy_j() < pb.energy_j() * 0.7, "{} vs {}", ps.energy_j(), pb.energy_j());
        assert!(ps.delay_s < pb.delay_s);
    }

    #[test]
    fn footprint_stays_within_form_factor() {
        // Stacking grows capacity without growing the 2-D outline much —
        // the XR form-factor argument.
        let base = baseline_2d().chip_design(FabGrid::Coal);
        for d in stacked_configs() {
            let des = d.config.chip_design(FabGrid::Coal);
            assert!(
                des.footprint_cm2() < base.footprint_cm2() * 1.6,
                "{} footprint {} vs base {}",
                d.label,
                des.footprint_cm2(),
                base.footprint_cm2()
            );
        }
    }
}
