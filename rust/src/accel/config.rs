//! Hardware configuration of a candidate accelerator and its die/embodied
//! model. The DSE of §5.1 sweeps `num_macs × sram_bytes` over an 11×11
//! grid (121 configurations); §5.3's A-1..A-4 are four named points
//! produced by the same model.

use crate::carbon::{ChipDesign, Die, FabGrid, ProcessNode, YieldModel};

/// Off-array memory system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemoryInterface {
    /// Conventional off-chip LPDDR-class DRAM (2-D baseline of §5.6).
    Lpddr {
        /// Sustained bandwidth, bytes/s.
        bw_bytes_per_s: f64,
        /// Access energy, pJ/byte.
        pj_per_byte: f64,
    },
    /// Face-to-face 3-D stacked SRAM (hybrid-bond) — high bandwidth, low
    /// access energy, capacity bounded by the stacked dies.
    Stacked3d {
        /// Sustained bandwidth, bytes/s.
        bw_bytes_per_s: f64,
        /// Access energy, pJ/byte.
        pj_per_byte: f64,
    },
}

impl MemoryInterface {
    /// Paper-typical LPDDR5-class interface for a mobile SoC.
    pub fn lpddr() -> Self {
        MemoryInterface::Lpddr { bw_bytes_per_s: 12.8e9, pj_per_byte: 80.0 }
    }

    /// Paper-typical F2F hybrid-bond interface (Yang et al., IEEE Micro'22).
    pub fn f2f() -> Self {
        MemoryInterface::Stacked3d { bw_bytes_per_s: 256.0e9, pj_per_byte: 4.0 }
    }

    /// Sustained bandwidth, bytes/s.
    pub fn bandwidth(&self) -> f64 {
        match *self {
            MemoryInterface::Lpddr { bw_bytes_per_s, .. } => bw_bytes_per_s,
            MemoryInterface::Stacked3d { bw_bytes_per_s, .. } => bw_bytes_per_s,
        }
    }

    /// Access energy, J/byte.
    pub fn j_per_byte(&self) -> f64 {
        match *self {
            MemoryInterface::Lpddr { pj_per_byte, .. } => pj_per_byte * 1e-12,
            MemoryInterface::Stacked3d { pj_per_byte, .. } => pj_per_byte * 1e-12,
        }
    }
}

/// One candidate accelerator configuration.
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    /// Name ("A-2", "K1024_M4", "3D_2K_16M", ...).
    pub name: String,
    /// Total MAC units (arranged as a rows×cols array by the simulator).
    pub num_macs: u32,
    /// On-chip (or on-stack) SRAM, bytes.
    pub sram_bytes: u64,
    /// Clock, Hz.
    pub freq_hz: f64,
    /// Supply scaling vs nominal (energy scales with `voltage_scale²`; the
    /// low-voltage A-3 point uses < 1).
    pub voltage_scale: f64,
    /// Technology node.
    pub node: ProcessNode,
    /// Backing memory.
    pub mem: MemoryInterface,
    /// True if the SRAM lives on stacked dies (3-D design, §5.6); affects
    /// the die partitioning in [`Self::chip_design`].
    pub stacked_sram: bool,
    /// Number of independent MAC arrays the units are organized into
    /// (Fig 15a's "K MAC arrays"). Latency-critical single-inference work
    /// only exploits extra arrays on large spatial operators — see
    /// `simulator::ARRAY_PARALLEL_BYTES`.
    pub arrays: u32,
}

/// Per-MAC silicon area at 7 nm, mm² (int8 MAC + local regs + share of NoC).
pub const MAC_AREA_MM2_7NM: f64 = 0.002;
/// SRAM macro area at 7 nm, mm² per MB.
pub const SRAM_AREA_MM2_PER_MB_7NM: f64 = 0.5;
/// Fixed area for IO, PLLs, DMA and control, mm².
pub const BASE_AREA_MM2: f64 = 2.5;
/// Whole-die overhead (power grid, spacing, test) multiplier.
pub const AREA_OVERHEAD: f64 = 1.2;

impl AcceleratorConfig {
    /// A 2-D design with LPDDR backing at nominal voltage, 1 GHz, 7 nm.
    pub fn new_2d(name: &str, num_macs: u32, sram_bytes: u64) -> Self {
        AcceleratorConfig {
            name: name.to_string(),
            num_macs,
            sram_bytes,
            freq_hz: 1.0e9,
            voltage_scale: 1.0,
            node: ProcessNode::N7,
            mem: MemoryInterface::lpddr(),
            stacked_sram: false,
            arrays: 1,
        }
    }

    /// A 3-D design: SRAM on stacked dies behind the F2F hybrid-bond
    /// interface (§5.6), nominal voltage, 1 GHz, 7 nm. The stacked-die
    /// partitioning (and its Murphy-yield advantage) comes from
    /// [`Self::chip_design`].
    pub fn new_3d(name: &str, num_macs: u32, sram_bytes: u64) -> Self {
        AcceleratorConfig {
            stacked_sram: true,
            mem: MemoryInterface::f2f(),
            ..AcceleratorConfig::new_2d(name, num_macs, sram_bytes)
        }
    }

    /// Logic-area (MAC array + base) in mm² at this config's node.
    pub fn logic_area_mm2(&self) -> f64 {
        let density = self.node.params().density_vs_7nm;
        (self.num_macs as f64 * MAC_AREA_MM2_7NM + BASE_AREA_MM2) / density
    }

    /// SRAM area in mm² at this config's node.
    pub fn sram_area_mm2(&self) -> f64 {
        let density = self.node.params().density_vs_7nm;
        let mb = self.sram_bytes as f64 / (1024.0 * 1024.0);
        mb * SRAM_AREA_MM2_PER_MB_7NM / density
    }

    /// Die partitioning for the embodied model: monolithic (logic + SRAM on
    /// one die) for 2-D designs; logic die + stacked SRAM dies (≤ 8 MB per
    /// die) for 3-D designs. Yield follows the Murphy model at the node's
    /// defect density — this is what gives chiplet/3-D designs their yield
    /// advantage.
    pub fn chip_design(&self, fab: FabGrid) -> ChipDesign {
        let y = YieldModel::Murphy { d0: self.node.params().defect_density_per_cm2 };
        let mut dies = Vec::new();
        if self.stacked_sram {
            dies.push(Die::new(
                &format!("{}-logic", self.name),
                self.logic_area_mm2() * AREA_OVERHEAD / 100.0,
                self.node,
                y,
            ));
            // Stacked SRAM in up-to-8 MB dies.
            let mut remaining_mb = self.sram_bytes as f64 / (1024.0 * 1024.0);
            let density = self.node.params().density_vs_7nm;
            let mut i = 0;
            while remaining_mb > 1e-9 {
                let mb = remaining_mb.min(8.0);
                let area_mm2 = mb * SRAM_AREA_MM2_PER_MB_7NM / density * AREA_OVERHEAD;
                dies.push(Die::new(&format!("{}-sram{i}", self.name), area_mm2 / 100.0, self.node, y));
                remaining_mb -= mb;
                i += 1;
            }
        } else {
            let area_mm2 = (self.logic_area_mm2() + self.sram_area_mm2()) * AREA_OVERHEAD;
            dies.push(Die::new(&self.name, area_mm2 / 100.0, self.node, y));
        }
        ChipDesign {
            name: self.name.clone(),
            dies,
            fab_grid: fab,
            // Paper §5.6 excludes TSV/stacking process carbon (no data).
            packaging_overhead: 0.0,
        }
    }

    /// Embodied carbon (gCO₂e) on the given fab grid.
    pub fn embodied_g(&self, fab: FabGrid) -> f64 {
        self.chip_design(fab).embodied_g()
    }

    /// Leakage power, W (scales with provisioned silicon).
    pub fn leakage_w(&self) -> f64 {
        let mb = self.sram_bytes as f64 / (1024.0 * 1024.0);
        (self.num_macs as f64 * 3e-6 + mb * 2e-3) * self.voltage_scale
    }

    /// Peak int8 throughput, TOPS (2 ops per MAC per cycle).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.num_macs as f64 * self.freq_hz / 1e12
    }

    /// MAC array shape (rows × cols): rows is the reduction (dot-product)
    /// dimension, cols the output-channel/pixel dimension. `rows` is the
    /// largest power of two ≤ √num_macs so the array stays square-ish.
    pub fn array_shape(&self) -> (u32, u32) {
        let sqrt = (self.num_macs as f64).sqrt();
        let mut rows = 1u32;
        while (rows * 2) as f64 <= sqrt {
            rows *= 2;
        }
        let cols = self.num_macs / rows;
        (rows, cols.max(1))
    }
}

/// The four "real-production" accelerators of §5.3 (Figs 1, 9, 10).
///
/// * **A-1** — small, efficient: 512 MACs / 4 MB @ 0.8× V. Lowest
///   embodied and lowest energy (the paper's CEP/CE²P/C²EP winner).
/// * **A-2** — big: 4096 MACs / 16 MB @ 1.3 GHz. Fastest, highest embodied.
/// * **A-3** — mid, low-voltage: 2048 MACs / 12 MB @ 0.6 GHz, 0.8× V.
///   Energy-efficient; task performance within ~1 % of A-4.
/// * **A-4** — mid, lean: 1024 MACs / 2.5 MB @ 1.2 GHz. Low embodied, but
///   higher operational energy than A-3.
pub fn production_accelerators() -> [AcceleratorConfig; 4] {
    let mut a1 = AcceleratorConfig::new_2d("A-1", 512, 4 * 1024 * 1024);
    a1.voltage_scale = 0.8;
    let mut a2 = AcceleratorConfig::new_2d("A-2", 4096, 16 * 1024 * 1024);
    a2.freq_hz = 1.3e9;
    let mut a3 = AcceleratorConfig::new_2d("A-3", 2048, 12 * 1024 * 1024);
    a3.freq_hz = 0.6e9;
    a3.voltage_scale = 0.8;
    let mut a4 = AcceleratorConfig::new_2d("A-4", 1024, 2 * 1024 * 1024 + 512 * 1024);
    a4.freq_hz = 1.2e9;
    [a1, a2, a3, a4]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn array_shape_squareish() {
        assert_eq!(AcceleratorConfig::new_2d("x", 4096, 0).array_shape(), (64, 64));
        assert_eq!(AcceleratorConfig::new_2d("x", 1024, 0).array_shape(), (32, 32));
        assert_eq!(AcceleratorConfig::new_2d("x", 2048, 0).array_shape(), (32, 64));
        assert_eq!(AcceleratorConfig::new_2d("x", 512, 0).array_shape(), (16, 32));
    }

    #[test]
    fn peak_tops() {
        let a = AcceleratorConfig::new_2d("x", 4096, 0);
        assert!((a.peak_tops() - 8.192).abs() < 1e-9);
    }

    #[test]
    fn embodied_ordering_matches_fig9() {
        let [a1, a2, a3, a4] = production_accelerators();
        let g = FabGrid::Coal;
        let (e1, e2, e3, e4) = (a1.embodied_g(g), a2.embodied_g(g), a3.embodied_g(g), a4.embodied_g(g));
        // A-2 highest; A-1 lowest; paper: A-1 ≈ 4x lower than A-2, ≈ 3x
        // lower than A-3 (loose bands — our fab constants are calibrated,
        // not identical).
        assert!(e2 > e3 && e3 > e4 && e4 > e1, "e1={e1} e2={e2} e3={e3} e4={e4}");
        let r21 = e2 / e1;
        assert!((2.5..6.5).contains(&r21), "A-2/A-1 embodied ratio = {r21}");
        let r31 = e3 / e1;
        assert!((1.5..4.5).contains(&r31), "A-3/A-1 embodied ratio = {r31}");
    }

    #[test]
    fn stacked_design_splits_dies() {
        let mut c = AcceleratorConfig::new_2d("3D_2K_16M", 2048, 16 * 1024 * 1024);
        c.stacked_sram = true;
        c.mem = MemoryInterface::f2f();
        let d = c.chip_design(FabGrid::Coal);
        // logic + two 8 MB SRAM dies.
        assert_eq!(d.dies.len(), 3, "{:?}", d.dies);
        // Footprint is the largest die, not the sum (form-factor win).
        assert!(d.footprint_cm2() < d.total_area_cm2());
    }

    #[test]
    fn murphy_yield_makes_3d_embodied_sublinear() {
        // Same total silicon, split into stacked dies -> better yield ->
        // less embodied carbon than a monolithic die of the summed area.
        let mono = AcceleratorConfig::new_2d("mono", 2048, 16 * 1024 * 1024);
        let mut stacked = mono.clone();
        stacked.stacked_sram = true;
        let (em, es) = (mono.embodied_g(FabGrid::Coal), stacked.embodied_g(FabGrid::Coal));
        assert!(es < em, "stacked {es} !< mono {em}");
    }

    #[test]
    fn leakage_scales_with_provisioning() {
        let small = AcceleratorConfig::new_2d("s", 512, 2 * 1024 * 1024);
        let big = AcceleratorConfig::new_2d("b", 4096, 16 * 1024 * 1024);
        assert!(big.leakage_w() > small.leakage_w() * 3.0);
    }

    #[test]
    fn mem_interface_constants() {
        assert!(MemoryInterface::f2f().bandwidth() > MemoryInterface::lpddr().bandwidth() * 5.0);
        assert!(MemoryInterface::f2f().j_per_byte() < MemoryInterface::lpddr().j_per_byte() / 5.0);
    }
}
