//! Roofline-style performance/energy model (the Fig 6 simulator).
//!
//! Per operator: the MAC array achieves a utilization determined by how
//! the layer's reduction depth and output width map onto the physical
//! rows×cols array (ceiling effects); DRAM traffic follows a working-set
//! model (weights resident if the model fits, inter-layer activations
//! spill past the activation budget); compute and memory are
//! double-buffered, so operator latency is `max(compute, memory)`.
//! Energy sums MAC, SRAM, DRAM and leakage contributions.

use super::config::AcceleratorConfig;
use super::ops::{OpGraph, OpKind};

/// Dynamic energy per int8 MAC at 7 nm and nominal voltage, J.
pub const MAC_ENERGY_J: f64 = 0.3e-12;
/// On-chip SRAM access energy, J/byte.
pub const SRAM_ENERGY_J_PER_BYTE: f64 = 1.0e-12;
/// Fixed per-operator overhead (pipeline fill/drain, descriptor setup),
/// cycles.
pub const OP_OVERHEAD_CYCLES: f64 = 500.0;
/// Operators whose output tensor is at least this large can be tiled
/// across multiple MAC arrays; smaller operators run on one array and do
/// not benefit from the Fig 15a multi-array configurations at batch 1.
pub const ARRAY_PARALLEL_BYTES: u64 = 1024 * 1024;

/// Simulator output for one network on one configuration (Fig 6's
/// "TOPS / latency / utilization / energy").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelProfile {
    /// End-to-end latency for one inference, s.
    pub delay_s: f64,
    /// Dynamic energy for one inference, J.
    pub dynamic_j: f64,
    /// Leakage energy for one inference, J.
    pub leakage_j: f64,
    /// Average MAC-array utilization (0..1), MAC-time weighted.
    pub utilization: f64,
    /// Effective throughput, TOPS.
    pub effective_tops: f64,
    /// DRAM (or stacked-memory) traffic for one inference, bytes.
    pub dram_bytes: f64,
}

impl KernelProfile {
    /// Total energy (dynamic + leakage), J.
    pub fn energy_j(&self) -> f64 {
        self.dynamic_j + self.leakage_j
    }

    /// Average power over the inference, W.
    pub fn avg_power_w(&self) -> f64 {
        if self.delay_s <= 0.0 {
            0.0
        } else {
            self.energy_j() / self.delay_s
        }
    }
}

/// Dimension-mapping efficiency: how much of a physical dimension `d` is
/// used when a logical extent `n` is folded onto it (`n/(⌈n/d⌉·d)`).
fn dim_efficiency(n: u32, d: u32) -> f64 {
    if n == 0 || d == 0 {
        return 0.0;
    }
    let n = n as f64;
    let d = d as f64;
    n / ((n / d).ceil() * d)
}

/// MAC-array utilization for one operator on a rows×cols array.
///
/// Rows carry the reduction (dot-product) dimension; columns carry output
/// channels, folded with output pixels, so convolutional layers keep the
/// column dimension busy while FC layers pay the ceiling on `cout` alone.
fn op_utilization(kind: OpKind, reduction: u32, out_channels: u32, rows: u32, cols: u32) -> f64 {
    match kind {
        OpKind::Elementwise => 0.0,
        OpKind::FullyConnected => dim_efficiency(reduction, rows) * dim_efficiency(out_channels, cols),
        // Spatial ops fold pixels onto spare columns, so the column side is
        // limited only by the channel ceiling within one fold group.
        _ => {
            let row_eff = dim_efficiency(reduction, rows);
            let col_eff = dim_efficiency(out_channels.min(cols), out_channels.min(cols).max(1)).max(
                // folding pixels: at least one full group unless cout tiny
                (out_channels as f64 / cols as f64).min(1.0).max(0.25),
            );
            row_eff * col_eff
        }
    }
}

/// Simulate one network on one configuration.
pub fn simulate(cfg: &AcceleratorConfig, graph: &OpGraph) -> KernelProfile {
    // Utilization is governed by a single array's shape; extra arrays add
    // throughput only on tileable (large-output) operators.
    let arrays = cfg.arrays.max(1);
    let per_array = AcceleratorConfig { num_macs: cfg.num_macs / arrays, ..cfg.clone() };
    let (rows, cols) = per_array.array_shape();
    let freq = cfg.freq_hz;
    let v2 = cfg.voltage_scale * cfg.voltage_scale;
    let bw = cfg.mem.bandwidth();
    let e_dram = cfg.mem.j_per_byte();
    let leak_w = cfg.leakage_w();

    // Working-set budgets. Weights are kept resident if the whole model
    // fits in half the SRAM; activations get whatever the resident weights
    // leave behind (streamed weights only need a small staging buffer).
    let total_weights = graph.total_weight_bytes() as f64;
    let weights_resident = total_weights <= cfg.sram_bytes as f64 / 2.0;
    let a_budget = if weights_resident {
        cfg.sram_bytes as f64 - total_weights
    } else {
        cfg.sram_bytes as f64 * 0.75
    };

    let mut delay_s = 0.0;
    let mut dynamic_j = 0.0;
    let mut dram_bytes = 0.0;
    let mut weighted_util = 0.0;
    let mut util_weight = 0.0;

    for op in &graph.ops {
        let util = op_utilization(op.kind, op.reduction, op.out_channels, rows, cols);
        let arrays_eff = if op.out_bytes >= ARRAY_PARALLEL_BYTES { arrays } else { 1 };
        let active_macs = (per_array.num_macs * arrays_eff) as f64;
        let compute_cycles = if op.macs == 0 || util <= 0.0 {
            // Pure data-movement op: one pass over the bytes at SRAM width.
            (op.in_bytes as f64 / (cols as f64 * 16.0)).max(1.0)
        } else {
            op.macs as f64 / (active_macs * util) + OP_OVERHEAD_CYCLES
        };
        let compute_s = compute_cycles / freq;

        // DRAM traffic: streaming weights unless resident; each inter-layer
        // tensor that exceeds the activation budget makes a DRAM round trip
        // (the producer writes the overflow, the consumer reads it back —
        // we attribute the read side to this op's input and the write side
        // to its output).
        let w_traffic = if weights_resident { op.weight_bytes as f64 * 0.02 } else { op.weight_bytes as f64 };
        let a_traffic = (op.in_bytes as f64 - a_budget).max(0.0) + (op.out_bytes as f64 - a_budget).max(0.0);
        let op_dram = w_traffic + a_traffic;
        let mem_s = op_dram / bw;

        let op_s = compute_s.max(mem_s);
        delay_s += op_s;
        dram_bytes += op_dram;

        // Dynamic energy: MACs + one SRAM pass over all operands + DRAM.
        let sram_traffic = (op.in_bytes + op.out_bytes + op.weight_bytes) as f64;
        dynamic_j += op.macs as f64 * MAC_ENERGY_J * v2
            + sram_traffic * SRAM_ENERGY_J_PER_BYTE * v2
            + op_dram * e_dram;

        if op.macs > 0 {
            weighted_util += util * op.macs as f64;
            util_weight += op.macs as f64;
        }
    }

    let leakage_j = leak_w * delay_s;
    let utilization = if util_weight > 0.0 { weighted_util / util_weight } else { 0.0 };
    let effective_tops = if delay_s > 0.0 { 2.0 * graph.total_macs() as f64 / delay_s / 1e12 } else { 0.0 };

    KernelProfile { delay_s, dynamic_j, leakage_j, utilization, effective_tops, dram_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::production_accelerators;
    use crate::accel::networks::{network, Workload};

    fn total_suite_delay(cfg: &AcceleratorConfig) -> f64 {
        Workload::ALL.iter().map(|&w| simulate(cfg, &network(w)).delay_s).sum()
    }

    #[test]
    fn bigger_array_is_faster() {
        let small = AcceleratorConfig::new_2d("s", 512, 4 * 1024 * 1024);
        let big = AcceleratorConfig::new_2d("b", 4096, 4 * 1024 * 1024);
        let g = network(Workload::Rn50);
        assert!(simulate(&big, &g).delay_s < simulate(&small, &g).delay_s);
    }

    #[test]
    fn fig9_performance_ordering() {
        let [a1, a2, a3, a4] = production_accelerators();
        let (d1, d2, d3, d4) = (
            total_suite_delay(&a1),
            total_suite_delay(&a2),
            total_suite_delay(&a3),
            total_suite_delay(&a4),
        );
        // Paper: A-2 ≈ 4x faster than A-3/A-4, ≈ 5.5x faster than A-1;
        // A-3 and A-4 within a few percent of each other.
        assert!(d2 < d3 && d2 < d4 && d2 < d1, "d1={d1} d2={d2} d3={d3} d4={d4}");
        let r12 = d1 / d2;
        assert!((3.0..9.0).contains(&r12), "A-1/A-2 delay ratio = {r12}");
        let r32 = d3 / d2;
        assert!((2.0..6.5).contains(&r32), "A-3/A-2 delay ratio = {r32}");
        let a34 = (d3 - d4).abs() / d4;
        assert!(a34 < 0.35, "A-3 vs A-4 delta = {a34}");
    }

    #[test]
    fn low_voltage_config_saves_energy() {
        let [_, _, a3, a4] = production_accelerators();
        let g = network(Workload::Rn50);
        let (p3, p4) = (simulate(&a3, &g), simulate(&a4, &g));
        assert!(p3.energy_j() < p4.energy_j(), "A-3 {} !< A-4 {}", p3.energy_j(), p4.energy_j());
    }

    #[test]
    fn more_sram_cuts_dram_traffic() {
        let lean = AcceleratorConfig::new_2d("lean", 1024, 1024 * 1024);
        let fat = AcceleratorConfig::new_2d("fat", 1024, 32 * 1024 * 1024);
        let g = network(Workload::Sr512);
        let (pl, pf) = (simulate(&lean, &g), simulate(&fat, &g));
        assert!(pf.dram_bytes < pl.dram_bytes * 0.8, "fat={} lean={}", pf.dram_bytes, pl.dram_bytes);
        assert!(pf.energy_j() < pl.energy_j());
    }

    #[test]
    fn stacked_memory_helps_memory_bound_kernels() {
        use crate::accel::config::MemoryInterface;
        let mut flat = AcceleratorConfig::new_2d("2d", 1024, 2 * 1024 * 1024);
        flat.freq_hz = 1.2e9;
        let mut stacked = flat.clone();
        stacked.name = "3d".into();
        stacked.sram_bytes = 16 * 1024 * 1024;
        stacked.stacked_sram = true;
        stacked.mem = MemoryInterface::f2f();
        let g = network(Workload::Sr1024);
        let (pf, ps) = (simulate(&flat, &g), simulate(&stacked, &g));
        assert!(ps.delay_s < pf.delay_s, "3d {} !< 2d {}", ps.delay_s, pf.delay_s);
        assert!(ps.energy_j() < pf.energy_j() * 0.7);
    }

    #[test]
    fn utilization_is_bounded() {
        for cfg in production_accelerators() {
            for w in Workload::ALL {
                let p = simulate(&cfg, &network(w));
                assert!((0.0..=1.0).contains(&p.utilization), "{} on {} util={}", w.label(), cfg.name, p.utilization);
                assert!(p.delay_s > 0.0 && p.energy_j() > 0.0);
                assert!(p.effective_tops <= cfg.peak_tops() * 1.001);
            }
        }
    }

    #[test]
    fn depthwise_hurts_wide_arrays_more() {
        let wide = AcceleratorConfig::new_2d("wide", 4096, 8 * 1024 * 1024);
        let narrow = AcceleratorConfig::new_2d("narrow", 512, 8 * 1024 * 1024);
        let g = network(Workload::Mn2);
        let (pw, pn) = (simulate(&wide, &g), simulate(&narrow, &g));
        assert!(pw.utilization < pn.utilization, "wide {} !< narrow {}", pw.utilization, pn.utilization);
    }

    #[test]
    fn dim_efficiency_sane() {
        assert!((dim_efficiency(64, 64) - 1.0).abs() < 1e-12);
        assert!((dim_efficiency(65, 64) - 65.0 / 128.0).abs() < 1e-12);
        assert!((dim_efficiency(9, 64) - 9.0 / 64.0).abs() < 1e-12);
        assert_eq!(dim_efficiency(0, 64), 0.0);
    }
}
