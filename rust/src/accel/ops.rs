//! Operator model: the simulator reduces every network layer to MAC count,
//! weight footprint and activation footprint (Fig 6's "extract operators"
//! stage). Shapes are NCHW; datatypes are int8-equivalent (1 byte) as in
//! edge inference accelerators.

/// Operator category — determines how the MAC array maps the computation
/// and therefore the utilization model in [`super::simulator`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense 2-D convolution.
    Conv2d,
    /// Depthwise convolution (no cross-channel reduction — maps poorly to
    /// wide MAC arrays, the classic MobileNet effect).
    DepthwiseConv,
    /// Fully connected / matmul.
    FullyConnected,
    /// Transposed convolution (decoder upsampling in SegNet/UNet/SR).
    Deconv2d,
    /// 3-D convolution (cost-volume aggregation in depth estimation).
    Conv3d,
    /// Elementwise / activation / pooling — negligible MACs but real
    /// activation traffic.
    Elementwise,
}

/// One operator instance with its reduced costs.
#[derive(Debug, Clone)]
pub struct Op {
    /// Layer name for reports.
    pub name: String,
    /// Operator category.
    pub kind: OpKind,
    /// Multiply-accumulate operations.
    pub macs: u64,
    /// Weight bytes (int8).
    pub weight_bytes: u64,
    /// Input activation bytes.
    pub in_bytes: u64,
    /// Output activation bytes.
    pub out_bytes: u64,
    /// Reduction depth: the dot-product length the array can exploit
    /// (Cin·kh·kw for dense conv; kh·kw for depthwise).
    pub reduction: u32,
    /// Output channels (the array's broadcast dimension).
    pub out_channels: u32,
}

/// A whole network as an ordered operator list.
#[derive(Debug, Clone)]
pub struct OpGraph {
    /// Network name (Table 3 abbreviation).
    pub name: String,
    /// Operators in execution order.
    pub ops: Vec<Op>,
}

impl OpGraph {
    /// Total MACs over the graph.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs).sum()
    }

    /// Total weight bytes (the model's parameter footprint).
    pub fn total_weight_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.weight_bytes).sum()
    }

    /// Largest single-layer activation working set (in + out), bytes.
    pub fn peak_activation_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.in_bytes + o.out_bytes).max().unwrap_or(0)
    }
}

/// Dense conv2d: `out = (H/s, W/s, Cout)`, MACs = H/s·W/s·Cout·Cin·k².
pub fn conv2d(name: &str, h: u32, w: u32, cin: u32, cout: u32, k: u32, stride: u32) -> Op {
    assert!(stride >= 1 && k >= 1 && cin >= 1 && cout >= 1);
    let oh = (h / stride).max(1) as u64;
    let ow = (w / stride).max(1) as u64;
    let macs = oh * ow * cout as u64 * cin as u64 * (k * k) as u64;
    Op {
        name: name.to_string(),
        kind: OpKind::Conv2d,
        macs,
        weight_bytes: cin as u64 * cout as u64 * (k * k) as u64,
        in_bytes: h as u64 * w as u64 * cin as u64,
        out_bytes: oh * ow * cout as u64,
        reduction: cin * k * k,
        out_channels: cout,
    }
}

/// Depthwise conv: one filter per channel.
pub fn dwconv(name: &str, h: u32, w: u32, c: u32, k: u32, stride: u32) -> Op {
    let oh = (h / stride).max(1) as u64;
    let ow = (w / stride).max(1) as u64;
    let macs = oh * ow * c as u64 * (k * k) as u64;
    Op {
        name: name.to_string(),
        kind: OpKind::DepthwiseConv,
        macs,
        weight_bytes: c as u64 * (k * k) as u64,
        in_bytes: h as u64 * w as u64 * c as u64,
        out_bytes: oh * ow * c as u64,
        reduction: k * k,
        out_channels: c,
    }
}

/// Fully connected `cin → cout`.
pub fn fc(name: &str, cin: u32, cout: u32) -> Op {
    Op {
        name: name.to_string(),
        kind: OpKind::FullyConnected,
        macs: cin as u64 * cout as u64,
        weight_bytes: cin as u64 * cout as u64,
        in_bytes: cin as u64,
        out_bytes: cout as u64,
        reduction: cin,
        out_channels: cout,
    }
}

/// Transposed conv upsampling by `up`, kernel k.
pub fn deconv2d(name: &str, h: u32, w: u32, cin: u32, cout: u32, k: u32, up: u32) -> Op {
    let oh = (h * up) as u64;
    let ow = (w * up) as u64;
    let macs = oh * ow * cout as u64 * cin as u64 * (k * k) as u64 / (up * up) as u64;
    Op {
        name: name.to_string(),
        kind: OpKind::Deconv2d,
        macs,
        weight_bytes: cin as u64 * cout as u64 * (k * k) as u64,
        in_bytes: h as u64 * w as u64 * cin as u64,
        out_bytes: oh * ow * cout as u64,
        reduction: cin * k * k,
        out_channels: cout,
    }
}

/// 3-D convolution over a cost volume of depth `d`.
pub fn conv3d(name: &str, h: u32, w: u32, d: u32, cin: u32, cout: u32, k: u32) -> Op {
    let vox = h as u64 * w as u64 * d as u64;
    let macs = vox * cout as u64 * cin as u64 * (k as u64).pow(3);
    Op {
        name: name.to_string(),
        kind: OpKind::Conv3d,
        macs,
        weight_bytes: cin as u64 * cout as u64 * (k as u64).pow(3),
        in_bytes: vox * cin as u64,
        out_bytes: vox * cout as u64,
        reduction: cin * k * k * k,
        out_channels: cout,
    }
}

/// Elementwise / pool / norm stage: zero MACs, pure activation traffic.
pub fn eltwise(name: &str, bytes: u64) -> Op {
    Op {
        name: name.to_string(),
        kind: OpKind::Elementwise,
        macs: 0,
        weight_bytes: 0,
        in_bytes: bytes,
        out_bytes: bytes,
        reduction: 1,
        out_channels: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_macs_formula() {
        // 224x224x3 -> 7x7x64 stride2: 112*112*64*3*49.
        let op = conv2d("c1", 224, 224, 3, 64, 7, 2);
        assert_eq!(op.macs, 112 * 112 * 64 * 3 * 49);
        assert_eq!(op.weight_bytes, 3 * 64 * 49);
        assert_eq!(op.out_bytes, 112 * 112 * 64);
        assert_eq!(op.reduction, 3 * 49);
    }

    #[test]
    fn depthwise_has_no_channel_reduction() {
        let op = dwconv("dw", 56, 56, 128, 3, 1);
        assert_eq!(op.reduction, 9);
        assert_eq!(op.macs, 56 * 56 * 128 * 9);
        assert_eq!(op.weight_bytes, 128 * 9);
    }

    #[test]
    fn fc_is_square_in_weights() {
        let op = fc("fc", 2048, 1000);
        assert_eq!(op.macs, op.weight_bytes);
        assert_eq!(op.macs, 2048 * 1000);
    }

    #[test]
    fn deconv_upsamples_output() {
        let op = deconv2d("up", 28, 28, 64, 32, 4, 2);
        assert_eq!(op.out_bytes, 56 * 56 * 32);
    }

    #[test]
    fn conv3d_cubic_kernel() {
        let op = conv3d("agg", 64, 64, 24, 16, 16, 3);
        assert_eq!(op.reduction, 16 * 27);
        assert_eq!(op.macs, 64 * 64 * 24 * 16 * 16 * 27);
    }

    #[test]
    fn graph_aggregates() {
        let g = OpGraph {
            name: "tiny".into(),
            ops: vec![conv2d("a", 8, 8, 4, 8, 3, 1), fc("b", 128, 10)],
        };
        assert_eq!(g.total_macs(), 8 * 8 * 8 * 4 * 9 + 1280);
        assert_eq!(g.total_weight_bytes(), 4 * 8 * 9 + 1280);
        assert!(g.peak_activation_bytes() >= 8 * 8 * 4);
    }
}
