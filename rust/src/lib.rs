//! # xrcarbon — carbon-efficient design space exploration for XR systems
//!
//! Reproduction of *"Design Space Exploration and Optimization for
//! Carbon-Efficient Extended Reality Systems"* (CS.AR 2023): a holistic
//! framework that co-optimizes **embodied** and **operational** carbon with
//! performance/power/area, built around the paper's figure-of-merit
//! **tCDP = C_total × task-execution-delay**.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — design-space enumeration, constraint filtering,
//!   β-scalarization / Pareto sweeps, plus every substrate the paper's
//!   evaluation needs (ACT carbon model, accelerator simulator, CPU/SoC
//!   retrospective databases, VR fleet telemetry generator, 3D stacking).
//! * **L2 (JAX, build time)** — the §3.3 matrix formalization as a batched
//!   computation graph, AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (Pallas, build time)** — the blocked metric-evaluation kernel the
//!   L2 graph calls.
//!
//! At run time `runtime::PjrtEngine` (behind the `pjrt` cargo feature)
//! loads the HLO artifacts through the PJRT CPU client (`xla` crate) and
//! the coordinator streams batches of candidate hardware configurations
//! through it; [`runtime::HostEngine`] is a pure-Rust mirror used for
//! cross-checking and as a fallback. Evaluation is two-phase: the engine
//! contracts each config chunk into a scenario-invariant
//! [`matrixform::DesignProfile`] (phase A) and a
//! [`carbon::ScenarioOverlay`] folds the scenario knobs in (phase B),
//! bit-identical to the fused graph. Multi-scenario studies run through
//! [`dse::sweep`], which profiles chunks once across worker threads
//! (each owning a private engine built by a [`runtime::EngineFactory`])
//! and fans only cheap overlays across the scenario grid. Profiles
//! persist across processes through the content-addressed
//! [`dse::cache::ProfileCache`] (warm-start sweeps perform zero engine
//! contractions, bit-identically), and [`dse::search`] checkpoints its
//! generation loop so interrupted searches resume bit-identically.
//! [`service`] packages all of the above as a resident exploration
//! server: jobs submitted over a std-only HTTP surface are persisted
//! checkpoints, so a killed server resumes every in-flight job.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod accel;
pub mod bench;
pub mod carbon;
pub mod cli;
pub mod configfmt;
pub mod dse;
pub mod experiments;
pub mod matrixform;
pub mod report;
pub mod runtime;
pub mod service;
pub mod soc;
pub mod testkit;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
