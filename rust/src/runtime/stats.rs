//! Cache-stats surface: how much engine work the persistent profile
//! cache saved.
//!
//! The expensive unit of runtime work is one phase-A engine contraction
//! of a config chunk (O(C×T×K)). The [`crate::dse::cache::ProfileCache`]
//! counts its outcomes through a [`CacheCounters`] (atomic, shared across
//! sweep worker threads) and surfaces immutable [`CacheStats`] snapshots;
//! `dse::sweep` attaches the per-run delta to its outcome so reports and
//! benches can prove "zero contractions on a warm cache" rather than
//! assert it.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Immutable cache statistics (a [`CacheCounters`] snapshot or delta).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Profile chunks served from the cache (each one phase-A engine
    /// contraction avoided).
    pub hits: usize,
    /// Subset of `hits` served by the in-memory LRU layer without
    /// touching the disk envelope at all (no read, no parse).
    pub mem_hits: usize,
    /// Lookups that fell through to the engine (absent entries, read
    /// errors, plus rejected ones).
    pub misses: usize,
    /// Subset of `misses` that found an entry but rejected it
    /// (corrupted, stale schema, key/shape/payload mismatch) — rejected
    /// entries are recomputed, never trusted.
    pub rejected: usize,
    /// Profiles written back after a miss.
    pub writes: usize,
    /// Write-backs that failed (disk full, permissions). The sweep
    /// degrades to uncached behavior instead of failing — the computed
    /// profile is still used, it just is not persisted.
    pub write_errors: usize,
    /// On-disk entries removed by the size-budget eviction policy.
    pub evictions: usize,
}

impl CacheStats {
    /// Engine contractions the cache avoided (one per hit).
    pub fn contractions_avoided(&self) -> usize {
        self.hits
    }

    /// Total lookups.
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Counter-wise difference `self − earlier` (for per-run deltas over
    /// a long-lived cache). Saturates at zero per field.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            mem_hits: self.mem_hits.saturating_sub(earlier.mem_hits),
            misses: self.misses.saturating_sub(earlier.misses),
            rejected: self.rejected.saturating_sub(earlier.rejected),
            writes: self.writes.saturating_sub(earlier.writes),
            write_errors: self.write_errors.saturating_sub(earlier.write_errors),
            evictions: self.evictions.saturating_sub(earlier.evictions),
        }
    }
}

/// Thread-safe hit/miss/write counters backing a profile cache.
#[derive(Debug, Default)]
pub struct CacheCounters {
    hits: AtomicUsize,
    mem_hits: AtomicUsize,
    misses: AtomicUsize,
    rejected: AtomicUsize,
    writes: AtomicUsize,
    write_errors: AtomicUsize,
    evictions: AtomicUsize,
}

impl CacheCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        CacheCounters::default()
    }

    /// Record a cache hit (one contraction avoided).
    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a hit served by the in-memory LRU layer (counts as a hit
    /// *and* a memory hit).
    pub fn record_mem_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.mem_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a miss on an absent entry.
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a miss on a present-but-rejected entry (counts as a miss
    /// *and* a rejection).
    pub fn record_rejected(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a write-back.
    pub fn record_write(&self) {
        self.writes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a failed write-back.
    pub fn record_write_error(&self) {
        self.write_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one entry evicted from the on-disk store.
    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Immutable snapshot of the current counts.
    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            mem_hits: self.mem_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            write_errors: self.write_errors.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = CacheCounters::new();
        c.record_hit();
        c.record_hit();
        c.record_mem_hit();
        c.record_miss();
        c.record_rejected();
        c.record_write();
        c.record_write_error();
        c.record_eviction();
        let s = c.snapshot();
        assert_eq!(s.hits, 3); // two disk hits + one memory hit
        assert_eq!(s.mem_hits, 1);
        assert_eq!(s.misses, 2); // absent + rejected
        assert_eq!(s.rejected, 1);
        assert_eq!(s.writes, 1);
        assert_eq!(s.write_errors, 1);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.contractions_avoided(), 3);
        assert_eq!(s.lookups(), 5);
    }

    #[test]
    fn since_computes_per_run_deltas() {
        let c = CacheCounters::new();
        c.record_miss();
        c.record_write();
        let before = c.snapshot();
        c.record_hit();
        c.record_mem_hit();
        c.record_eviction();
        let delta = c.snapshot().since(&before);
        assert_eq!(
            delta,
            CacheStats { hits: 2, mem_hits: 1, evictions: 1, ..CacheStats::default() }
        );
        // Saturating: an impossible negative delta clamps to zero.
        assert_eq!(before.since(&c.snapshot()).hits, 0);
    }

    #[test]
    fn counters_are_shareable_across_threads() {
        let c = CacheCounters::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..100 {
                        c.record_hit();
                    }
                });
            }
        });
        assert_eq!(c.snapshot().hits, 400);
    }
}
