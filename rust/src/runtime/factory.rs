//! Per-worker engine construction for parallel sweeps.
//!
//! [`Engine`]s are intentionally `!Send` (the PJRT client is `Rc`-based),
//! so a multi-threaded sweep cannot share one engine across workers. An
//! [`EngineFactory`] is the `Send + Sync` recipe each worker thread
//! invokes once to obtain its own private engine; the factory crosses the
//! thread boundary, the engines never do.

use super::engine::Engine;
use super::host::HostEngine;

/// Thread-safe recipe for building per-worker engines.
pub trait EngineFactory: Send + Sync {
    /// Construct a fresh engine owned by the calling thread.
    fn build(&self) -> crate::Result<Box<dyn Engine>>;

    /// Label naming the engines this factory produces ("host", "pjrt").
    fn label(&self) -> &'static str;

    /// Opt into the persistent [`WorkerPool`](super::WorkerPool): return a
    /// shareable clone of this recipe and `dse::sweep::fan_out` will run
    /// on long-lived pooled workers instead of per-call scoped threads.
    ///
    /// The default is `None` (scoped spawning), which is always correct.
    /// Implementations returning `Some` must hand back a recipe whose
    /// `build()` produces engines indistinguishable from this factory's —
    /// pooled workers cache engines across batches, so a stale recipe
    /// would silently evaluate with stale state.
    fn shared(&self) -> Option<std::sync::Arc<dyn EngineFactory>> {
        None
    }

    /// Key identifying this factory's engine configuration in the pool
    /// registry: two factories with equal identities must build
    /// interchangeable engines (they may be handed each other's pooled
    /// workers). Defaults to [`label`](Self::label); factories with
    /// per-instance state (e.g. an artifacts directory) must fold that
    /// state into the identity.
    fn pool_identity(&self) -> String {
        self.label().to_string()
    }
}

/// Factory for the pure-Rust [`HostEngine`]; always available and free to
/// construct, so parallel sweeps default to it when artifacts are absent.
#[derive(Debug, Clone, Copy, Default)]
pub struct HostEngineFactory;

impl EngineFactory for HostEngineFactory {
    fn build(&self) -> crate::Result<Box<dyn Engine>> {
        Ok(Box::new(HostEngine::new()))
    }

    fn label(&self) -> &'static str {
        "host"
    }

    fn shared(&self) -> Option<std::sync::Arc<dyn EngineFactory>> {
        // Stateless: any `HostEngineFactory` is the same recipe.
        Some(std::sync::Arc::new(HostEngineFactory))
    }
}

/// Factory constructing one PJRT engine — and therefore one PJRT CPU
/// client and executable cache — per worker thread, all loading the same
/// artifacts directory.
#[cfg(feature = "pjrt")]
pub struct PjrtEngineFactory {
    artifacts_dir: String,
}

#[cfg(feature = "pjrt")]
impl PjrtEngineFactory {
    /// Probe-load the artifacts once up front so a sweep fails fast on a
    /// bad directory instead of inside every worker.
    pub fn new(artifacts_dir: &str) -> crate::Result<Self> {
        super::PjrtEngine::load(artifacts_dir)?;
        Ok(PjrtEngineFactory { artifacts_dir: artifacts_dir.to_string() })
    }
}

#[cfg(feature = "pjrt")]
impl EngineFactory for PjrtEngineFactory {
    fn build(&self) -> crate::Result<Box<dyn Engine>> {
        Ok(Box::new(super::PjrtEngine::load(&self.artifacts_dir)?))
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn shared(&self) -> Option<std::sync::Arc<dyn EngineFactory>> {
        Some(std::sync::Arc::new(PjrtEngineFactory {
            artifacts_dir: self.artifacts_dir.clone(),
        }))
    }

    fn pool_identity(&self) -> String {
        // Engines are artifact-dir-specific; pools must be too.
        format!("pjrt:{}", self.artifacts_dir)
    }
}

/// Best available factory: PJRT when the `pjrt` feature is enabled and
/// the artifacts load, host fallback otherwise.
pub fn auto_factory(artifacts_dir: &str) -> Box<dyn EngineFactory> {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(f) = PjrtEngineFactory::new(artifacts_dir) {
            return Box::new(f);
        }
    }
    let _ = artifacts_dir;
    Box::new(HostEngineFactory)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_factory_builds_host_engines() {
        let f = HostEngineFactory;
        assert_eq!(f.label(), "host");
        let engine = f.build().unwrap();
        assert_eq!(engine.name(), "host");
    }

    #[test]
    fn factories_cross_threads_engines_do_not_need_to() {
        // The whole point: a factory is shared across workers, each of
        // which builds and uses an engine locally.
        let f = HostEngineFactory;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let f = &f;
                    s.spawn(move || f.build().map(|e| e.name()).unwrap())
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), "host");
            }
        });
    }

    #[test]
    fn auto_factory_falls_back_to_host() {
        let f = auto_factory("definitely/not/an/artifacts/dir");
        assert_eq!(f.label(), "host");
    }
}
