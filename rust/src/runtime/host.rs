//! Pure-Rust mirror of the Layer-2 evaluation graph.
//!
//! Arithmetic is done in f32 in the same order as the JAX reference
//! (`python/compile/kernels/ref.py`) so PJRT-vs-host differences stay at
//! rounding level; the integration tests assert ≤ 1e-5 relative error.

use super::engine::{Engine, RawOutput, RawProfile};
use crate::matrixform::{PackedProblem, J_PAD, K_PAD, NUM_METRICS, T_PAD};

/// Host (no-XLA) engine.
#[derive(Debug, Default)]
pub struct HostEngine {
    _private: (),
}

impl HostEngine {
    /// Create a host engine.
    pub fn new() -> Self {
        HostEngine { _private: () }
    }
}

/// The Layer-1 hot loop for one config row: per-task energy/delay
/// contraction (K accumulation in f32, matching XLA's row-major dot).
/// Shared by the fused `execute` and the phase-A `profile` so the two
/// paths stay bit-identical by construction.
#[inline]
fn contract_tasks(p: &PackedProblem, ci: usize) -> ([f32; T_PAD], [f32; T_PAD]) {
    let f_clk = p.f_clk[ci];
    let mut e_task = [0.0f32; T_PAD];
    let mut d_task = [0.0f32; T_PAD];
    for ti in 0..T_PAD {
        let mut e_acc = 0.0f32;
        let mut d_acc = 0.0f32;
        for ki in 0..K_PAD {
            let n = p.n[ti * K_PAD + ki];
            let e_k = (p.p_leak[ci * K_PAD + ki] + p.p_dyn[ci * K_PAD + ki]) / f_clk;
            e_acc += e_k * n;
            d_acc += p.d_k[ci * K_PAD + ki] * n;
        }
        e_task[ti] = e_acc;
        d_task[ti] = d_acc;
    }
    (e_task, d_task)
}

impl Engine for HostEngine {
    // The carbon/feasibility arithmetic below is mirrored in
    // `carbon/overlay.rs::ScenarioOverlay::apply` (phase B); keep the two
    // in lockstep — the bit-identity property tests fail otherwise.
    fn execute(&mut self, p: &PackedProblem) -> crate::Result<RawOutput> {
        let c_pad = p.c_pad;
        let (ci_use, lifetime, beta, p_max) = (
            p.scalars[0],
            p.scalars[1],
            p.scalars[2],
            p.scalars[3],
        );

        let mut metrics = vec![0.0f32; NUM_METRICS * c_pad];
        let mut d_task_out = vec![0.0f32; c_pad * T_PAD];

        for ci in 0..c_pad {
            let (e_task, d_task) = contract_tasks(p, ci);
            let energy: f32 = e_task.iter().sum();
            let delay: f32 = d_task.iter().sum();

            let c_op = ci_use * energy;
            let mut c_emb_overall = 0.0f32;
            for ji in 0..J_PAD {
                c_emb_overall += p.c_comp[ci * J_PAD + ji] * p.online[ji];
            }
            let c_emb = c_emb_overall * delay / lifetime;

            let c_total = c_op + c_emb;
            let tcdp = (c_op + beta * c_emb) * delay;
            let edp = energy * delay;
            let cdp = c_emb * delay;
            let cep = c_emb * energy;
            let ce2p = cep * energy;
            let c2ep = c_emb * cep;

            let mut qos_ok = true;
            for ti in 0..T_PAD {
                if !(d_task[ti] <= p.qos[ti]) {
                    qos_ok = false;
                }
            }
            let avg_power = energy / delay.max(1e-30);
            let feasible = if qos_ok && avg_power <= p_max { 1.0 } else { 0.0 };

            let rows = [
                energy, delay, c_op, c_emb, c_total, tcdp, edp, cdp, cep, ce2p, c2ep, feasible,
            ];
            for (row, v) in rows.iter().enumerate() {
                metrics[row * c_pad + ci] = *v;
            }
            d_task_out[ci * T_PAD..(ci + 1) * T_PAD].copy_from_slice(&d_task);
        }

        Ok(RawOutput { metrics, d_task: d_task_out })
    }

    /// Phase A only: the O(C×T×K) contraction without the carbon math —
    /// multi-scenario sweeps run this once and apply cheap overlays.
    fn profile(&mut self, p: &PackedProblem) -> crate::Result<RawProfile> {
        let c_pad = p.c_pad;
        let mut energy = vec![0.0f32; c_pad];
        let mut delay = vec![0.0f32; c_pad];
        let mut d_task_out = vec![0.0f32; c_pad * T_PAD];
        for ci in 0..c_pad {
            let (e_task, d_task) = contract_tasks(p, ci);
            energy[ci] = e_task.iter().sum();
            delay[ci] = d_task.iter().sum();
            d_task_out[ci * T_PAD..(ci + 1) * T_PAD].copy_from_slice(&d_task);
        }
        Ok(RawProfile { energy, delay, d_task: d_task_out })
    }

    fn name(&self) -> &'static str {
        "host"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, EvalRequest, MetricRow, TaskMatrix};
    use crate::runtime::evaluate;

    fn request() -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[10.0, 5.0]);
        EvalRequest {
            tasks: tm,
            configs: vec![
                ConfigRow {
                    name: "fast".into(),
                    f_clk: 1e9,
                    d_k: vec![1e-3, 2e-3],
                    e_dyn: vec![0.05, 0.10],
                    leak_w: 0.02,
                    c_comp: vec![500.0, 100.0],
                },
                ConfigRow {
                    name: "slow".into(),
                    f_clk: 5e8,
                    d_k: vec![4e-3, 8e-3],
                    e_dyn: vec![0.02, 0.04],
                    leak_w: 0.01,
                    c_comp: vec![120.0, 30.0],
                },
            ],
            online: vec![1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 3.0e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    #[test]
    fn matches_hand_calculation() {
        let req = request();
        let mut eng = HostEngine::new();
        let res = evaluate(&mut eng, &req).unwrap();
        // Config "fast": delay = 10*1e-3 + 5*2e-3 = 0.02 s.
        let d = res.metric(MetricRow::Delay, 0);
        assert!((d - 0.02).abs() < 1e-8, "delay={d}");
        // Energy: e_k = leak*d + e_dyn: k0: .02*1e-3+.05, k1: .02*2e-3+.10.
        let e_expect = 10.0 * (0.02 * 1e-3 + 0.05) + 5.0 * (0.02 * 2e-3 + 0.10);
        let e = res.metric(MetricRow::Energy, 0);
        assert!((e - e_expect).abs() / e_expect < 1e-6, "energy={e} expect={e_expect}");
        // Carbon terms.
        let c_op = res.metric(MetricRow::COp, 0);
        assert!((c_op - 1.2e-4 * e_expect).abs() < 1e-9);
        let c_emb = res.metric(MetricRow::CEmb, 0);
        assert!((c_emb - 600.0 * 0.02 / 3.0e6).abs() < 1e-9);
        let tcdp = res.metric(MetricRow::Tcdp, 0);
        assert!((tcdp - (c_op + c_emb) * 0.02).abs() < 1e-10);
    }

    #[test]
    fn qos_marks_infeasible() {
        let mut req = request();
        req.qos = vec![0.03]; // fast (0.02) passes, slow (0.08) fails
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        assert_eq!(res.metric(MetricRow::Feasible, 0), 1.0);
        assert_eq!(res.metric(MetricRow::Feasible, 1), 0.0);
        assert_eq!(res.argmin_feasible(MetricRow::Tcdp), Some(0));
    }

    #[test]
    fn power_cap_marks_infeasible() {
        let mut req = request();
        // fast: E/D ≈ 0.55/0.02*?... compute: avg power = e/d.
        let res0 = evaluate(&mut HostEngine::new(), &req).unwrap();
        let p_fast = res0.metric(MetricRow::Energy, 0) / res0.metric(MetricRow::Delay, 0);
        let p_slow = res0.metric(MetricRow::Energy, 1) / res0.metric(MetricRow::Delay, 1);
        let cap = (p_fast.min(p_slow) + p_fast.max(p_slow)) / 2.0;
        req.p_max_w = cap;
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        let feas: Vec<f64> = (0..2).map(|i| res.metric(MetricRow::Feasible, i)).collect();
        assert_eq!(feas.iter().filter(|&&f| f == 1.0).count(), 1);
    }

    #[test]
    fn provisioning_mask_respected() {
        let mut req = request();
        req.online = vec![1.0, 0.0];
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        let c_emb = res.metric(MetricRow::CEmb, 0);
        assert!((c_emb - 500.0 * 0.02 / 3.0e6).abs() < 1e-9);
    }

    #[test]
    fn profile_rows_match_fused_invariant_rows() {
        // Phase A must reproduce the fused graph's energy/delay/d_task
        // bit-for-bit (shared contraction), padding rows included.
        let packed = PackedProblem::from_request(&request());
        let mut eng = HostEngine::new();
        let fused = eng.execute(&packed).unwrap();
        let prof = eng.profile(&packed).unwrap();
        for ci in 0..packed.c_pad {
            assert_eq!(prof.energy[ci].to_bits(), fused.metrics[ci].to_bits());
            assert_eq!(
                prof.delay[ci].to_bits(),
                fused.metrics[packed.c_pad + ci].to_bits()
            );
        }
        assert_eq!(prof.d_task.len(), fused.d_task.len());
        for (a, b) in prof.d_task.iter().zip(&fused.d_task) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn beta_scales_tcdp_only() {
        let mut req = request();
        req.beta = 0.0;
        let r0 = evaluate(&mut HostEngine::new(), &req).unwrap();
        req.beta = 2.0;
        let r2 = evaluate(&mut HostEngine::new(), &req).unwrap();
        assert!(r2.metric(MetricRow::Tcdp, 0) > r0.metric(MetricRow::Tcdp, 0));
        assert_eq!(r2.metric(MetricRow::Cdp, 0), r0.metric(MetricRow::Cdp, 0));
    }
}
