//! Pure-Rust mirror of the Layer-2 evaluation graph.
//!
//! Arithmetic is done in f32 in the same order as the JAX reference
//! (`python/compile/kernels/ref.py`) so PJRT-vs-host differences stay at
//! rounding level; the integration tests assert ≤ 1e-5 relative error.
//!
//! The phase-A contraction runs in two interchangeable shapes:
//!
//! * [`contract_tasks`] — the original scalar kernel, one config at a
//!   time. Kept as the bit-identity **oracle** (and the remainder path
//!   for batch sizes that are not a multiple of [`LANES`], which the
//!   `C_VARIANTS` padding currently never produces).
//! * [`contract_tasks_block`] — the lane-parallel kernel: [`LANES`] = 8
//!   adjacent configs advance together through fixed `[f32; LANES]`
//!   accumulator arrays over the columnar `[K_PAD × c_pad]` tensors
//!   (`PackedProblem::{p_leak_col, p_dyn_col, d_k_col}`). Each lane is an
//!   independent config whose K-accumulation runs in exactly the scalar
//!   kernel's f32 order, so the block kernel is **bit-identical by
//!   construction** — no `unsafe`, no intrinsics; the fixed-size lane
//!   loops are written for the autovectorizer. Locked by
//!   `rust/tests/hotloop_props.rs::prop_lane_kernel_bit_identical_to_scalar`.

use super::engine::{Engine, RawOutput, RawProfile};
use crate::matrixform::{PackedProblem, J_PAD, K_PAD, NUM_METRICS, T_PAD};

/// Lane width of the blocked phase-A kernel: 8 f32 lanes fill one AVX2
/// register (and two NEON registers), and both `C_VARIANTS` are multiples
/// of it, so full sweeps never hit the scalar remainder path.
pub const LANES: usize = 8;

/// Host (no-XLA) engine.
#[derive(Debug)]
pub struct HostEngine {
    /// Use the lane-blocked kernel (`false` = scalar oracle).
    lanes: bool,
}

impl Default for HostEngine {
    fn default() -> Self {
        HostEngine::new()
    }
}

impl HostEngine {
    /// Create a host engine (lane-blocked contraction kernel).
    pub fn new() -> Self {
        HostEngine { lanes: true }
    }

    /// Reference engine that keeps every contraction on the scalar
    /// kernel. Output is bit-identical to [`HostEngine::new`] — the
    /// property tests and `benches/bench_hotloop.rs` use it to prove
    /// (and price) exactly that.
    pub fn scalar_oracle() -> Self {
        HostEngine { lanes: false }
    }
}

// xrlint: region(bit-identical)
/// The Layer-1 hot loop for one config row: per-task energy/delay
/// contraction (K accumulation in f32, matching XLA's row-major dot).
/// Shared by the fused `execute` and the phase-A `profile` so the two
/// paths stay bit-identical by construction.
#[inline]
fn contract_tasks(p: &PackedProblem, ci: usize) -> ([f32; T_PAD], [f32; T_PAD]) {
    let f_clk = p.f_clk[ci];
    let mut e_task = [0.0f32; T_PAD];
    let mut d_task = [0.0f32; T_PAD];
    for ti in 0..T_PAD {
        let mut e_acc = 0.0f32;
        let mut d_acc = 0.0f32;
        for ki in 0..K_PAD {
            let n = p.n[ti * K_PAD + ki];
            let e_k = (p.p_leak[ci * K_PAD + ki] + p.p_dyn[ci * K_PAD + ki]) / f_clk;
            e_acc += e_k * n;
            d_acc += p.d_k[ci * K_PAD + ki] * n;
        }
        e_task[ti] = e_acc;
        d_task[ti] = d_acc;
    }
    (e_task, d_task)
}

/// Lane-parallel contraction of the config block `[c0, c0 + LANES)`:
/// per lane `l` (config `c0 + l`) the operations and their order are
/// exactly [`contract_tasks`]'s — `e_k = (p_leak + p_dyn) / f_clk`,
/// `e += e_k·n`, `d += d_k·n`, `ki` ascending — on the same f32 inputs
/// (the columnar tensors are bit-exact transposes), so every lane is
/// bit-identical to the scalar kernel while the compiler vectorizes
/// across lanes.
#[inline]
fn contract_tasks_block(
    p: &PackedProblem,
    c0: usize,
) -> ([[f32; LANES]; T_PAD], [[f32; LANES]; T_PAD]) {
    let c_pad = p.c_pad;
    let mut f_clk = [0.0f32; LANES];
    f_clk.copy_from_slice(&p.f_clk[c0..c0 + LANES]);
    let mut e_task = [[0.0f32; LANES]; T_PAD];
    let mut d_task = [[0.0f32; LANES]; T_PAD];
    for ti in 0..T_PAD {
        let mut e_acc = [0.0f32; LANES];
        let mut d_acc = [0.0f32; LANES];
        for ki in 0..K_PAD {
            let n = p.n[ti * K_PAD + ki];
            let pl = &p.p_leak_col[ki * c_pad + c0..ki * c_pad + c0 + LANES];
            let pd = &p.p_dyn_col[ki * c_pad + c0..ki * c_pad + c0 + LANES];
            let dk = &p.d_k_col[ki * c_pad + c0..ki * c_pad + c0 + LANES];
            for l in 0..LANES {
                let e_k = (pl[l] + pd[l]) / f_clk[l];
                e_acc[l] += e_k * n;
                d_acc[l] += dk[l] * n;
            }
        }
        e_task[ti] = e_acc;
        d_task[ti] = d_acc;
    }
    (e_task, d_task)
}

/// Extract one lane of a blocked contraction as the `[f32; T_PAD]` shape
/// the downstream carbon math consumes (a pure shuffle, no arithmetic).
#[inline]
fn lane(blk: &[[f32; LANES]; T_PAD], l: usize) -> [f32; T_PAD] {
    let mut out = [0.0f32; T_PAD];
    for (o, row) in out.iter_mut().zip(blk) {
        *o = row[l];
    }
    out
}

/// Fold one config's contracted `e_task`/`d_task` into the metric rows:
/// the carbon/feasibility arithmetic of the fused graph, shared by the
/// scalar and lane paths so blocking cannot perturb it. Mirrored in
/// `carbon/overlay.rs::ScenarioOverlay` (phase B); keep the two in
/// lockstep — the bit-identity property tests fail otherwise.
#[inline]
fn fold_carbon(
    p: &PackedProblem,
    ci: usize,
    e_task: &[f32; T_PAD],
    d_task: &[f32; T_PAD],
    metrics: &mut [f32],
    d_task_out: &mut [f32],
) {
    let c_pad = p.c_pad;
    let (ci_use, lifetime, beta, p_max) =
        (p.scalars[0], p.scalars[1], p.scalars[2], p.scalars[3]);
    let energy: f32 = e_task.iter().sum();
    let delay: f32 = d_task.iter().sum();

    let c_op = ci_use * energy;
    let mut c_emb_overall = 0.0f32;
    for ji in 0..J_PAD {
        c_emb_overall += p.c_comp[ci * J_PAD + ji] * p.online[ji];
    }
    let c_emb = c_emb_overall * delay / lifetime;

    let c_total = c_op + c_emb;
    let tcdp = (c_op + beta * c_emb) * delay;
    let edp = energy * delay;
    let cdp = c_emb * delay;
    let cep = c_emb * energy;
    let ce2p = cep * energy;
    let c2ep = c_emb * cep;

    let mut qos_ok = true;
    for ti in 0..T_PAD {
        if !(d_task[ti] <= p.qos[ti]) {
            qos_ok = false;
        }
    }
    let avg_power = energy / delay.max(1e-30);
    let feasible = if qos_ok && avg_power <= p_max { 1.0 } else { 0.0 };

    let rows = [
        energy, delay, c_op, c_emb, c_total, tcdp, edp, cdp, cep, ce2p, c2ep, feasible,
    ];
    for (row, v) in rows.iter().enumerate() {
        metrics[row * c_pad + ci] = *v;
    }
    d_task_out[ci * T_PAD..(ci + 1) * T_PAD].copy_from_slice(d_task);
}

impl Engine for HostEngine {
    fn execute(&mut self, p: &PackedProblem) -> crate::Result<RawOutput> {
        let c_pad = p.c_pad;
        let mut metrics = vec![0.0f32; NUM_METRICS * c_pad];
        let mut d_task_out = vec![0.0f32; c_pad * T_PAD];

        let full = if self.lanes { c_pad - c_pad % LANES } else { 0 };
        let mut ci = 0;
        while ci < full {
            let (e_blk, d_blk) = contract_tasks_block(p, ci);
            for l in 0..LANES {
                let (e_task, d_task) = (lane(&e_blk, l), lane(&d_blk, l));
                fold_carbon(p, ci + l, &e_task, &d_task, &mut metrics, &mut d_task_out);
            }
            ci += LANES;
        }
        while ci < c_pad {
            let (e_task, d_task) = contract_tasks(p, ci);
            fold_carbon(p, ci, &e_task, &d_task, &mut metrics, &mut d_task_out);
            ci += 1;
        }

        Ok(RawOutput { metrics, d_task: d_task_out })
    }

    /// Phase A only: the O(C×T×K) contraction without the carbon math —
    /// multi-scenario sweeps run this once and apply cheap overlays.
    fn profile(&mut self, p: &PackedProblem) -> crate::Result<RawProfile> {
        let c_pad = p.c_pad;
        let mut energy = vec![0.0f32; c_pad];
        let mut delay = vec![0.0f32; c_pad];
        let mut d_task_out = vec![0.0f32; c_pad * T_PAD];

        let full = if self.lanes { c_pad - c_pad % LANES } else { 0 };
        let mut ci = 0;
        while ci < full {
            let (e_blk, d_blk) = contract_tasks_block(p, ci);
            for l in 0..LANES {
                let (e_task, d_task) = (lane(&e_blk, l), lane(&d_blk, l));
                energy[ci + l] = e_task.iter().sum();
                delay[ci + l] = d_task.iter().sum();
                d_task_out[(ci + l) * T_PAD..(ci + l + 1) * T_PAD].copy_from_slice(&d_task);
            }
            ci += LANES;
        }
        while ci < c_pad {
            let (e_task, d_task) = contract_tasks(p, ci);
            energy[ci] = e_task.iter().sum();
            delay[ci] = d_task.iter().sum();
            d_task_out[ci * T_PAD..(ci + 1) * T_PAD].copy_from_slice(&d_task);
            ci += 1;
        }
        Ok(RawProfile { energy, delay, d_task: d_task_out })
    }

    fn name(&self) -> &'static str {
        "host"
    }
}
// xrlint: endregion(bit-identical)

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::{ConfigRow, EvalRequest, MetricRow, TaskMatrix};
    use crate::runtime::evaluate;

    fn request() -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[10.0, 5.0]);
        EvalRequest {
            tasks: tm,
            configs: vec![
                ConfigRow {
                    name: "fast".into(),
                    f_clk: 1e9,
                    d_k: vec![1e-3, 2e-3],
                    e_dyn: vec![0.05, 0.10],
                    leak_w: 0.02,
                    c_comp: vec![500.0, 100.0],
                },
                ConfigRow {
                    name: "slow".into(),
                    f_clk: 5e8,
                    d_k: vec![4e-3, 8e-3],
                    e_dyn: vec![0.02, 0.04],
                    leak_w: 0.01,
                    c_comp: vec![120.0, 30.0],
                },
            ],
            online: vec![1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1.2e-4,
            lifetime_s: 3.0e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    #[test]
    fn matches_hand_calculation() {
        let req = request();
        let mut eng = HostEngine::new();
        let res = evaluate(&mut eng, &req).unwrap();
        // Config "fast": delay = 10*1e-3 + 5*2e-3 = 0.02 s.
        let d = res.metric(MetricRow::Delay, 0);
        assert!((d - 0.02).abs() < 1e-8, "delay={d}");
        // Energy: e_k = leak*d + e_dyn: k0: .02*1e-3+.05, k1: .02*2e-3+.10.
        let e_expect = 10.0 * (0.02 * 1e-3 + 0.05) + 5.0 * (0.02 * 2e-3 + 0.10);
        let e = res.metric(MetricRow::Energy, 0);
        assert!((e - e_expect).abs() / e_expect < 1e-6, "energy={e} expect={e_expect}");
        // Carbon terms.
        let c_op = res.metric(MetricRow::COp, 0);
        assert!((c_op - 1.2e-4 * e_expect).abs() < 1e-9);
        let c_emb = res.metric(MetricRow::CEmb, 0);
        assert!((c_emb - 600.0 * 0.02 / 3.0e6).abs() < 1e-9);
        let tcdp = res.metric(MetricRow::Tcdp, 0);
        assert!((tcdp - (c_op + c_emb) * 0.02).abs() < 1e-10);
    }

    #[test]
    fn qos_marks_infeasible() {
        let mut req = request();
        req.qos = vec![0.03]; // fast (0.02) passes, slow (0.08) fails
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        assert_eq!(res.metric(MetricRow::Feasible, 0), 1.0);
        assert_eq!(res.metric(MetricRow::Feasible, 1), 0.0);
        assert_eq!(res.argmin_feasible(MetricRow::Tcdp), Some(0));
    }

    #[test]
    fn power_cap_marks_infeasible() {
        let mut req = request();
        // fast: E/D ≈ 0.55/0.02*?... compute: avg power = e/d.
        let res0 = evaluate(&mut HostEngine::new(), &req).unwrap();
        let p_fast = res0.metric(MetricRow::Energy, 0) / res0.metric(MetricRow::Delay, 0);
        let p_slow = res0.metric(MetricRow::Energy, 1) / res0.metric(MetricRow::Delay, 1);
        let cap = (p_fast.min(p_slow) + p_fast.max(p_slow)) / 2.0;
        req.p_max_w = cap;
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        let feas: Vec<f64> = (0..2).map(|i| res.metric(MetricRow::Feasible, i)).collect();
        assert_eq!(feas.iter().filter(|&&f| f == 1.0).count(), 1);
    }

    #[test]
    fn provisioning_mask_respected() {
        let mut req = request();
        req.online = vec![1.0, 0.0];
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        let c_emb = res.metric(MetricRow::CEmb, 0);
        assert!((c_emb - 500.0 * 0.02 / 3.0e6).abs() < 1e-9);
    }

    #[test]
    fn profile_rows_match_fused_invariant_rows() {
        // Phase A must reproduce the fused graph's energy/delay/d_task
        // bit-for-bit (shared contraction), padding rows included.
        let packed = PackedProblem::from_request(&request());
        let mut eng = HostEngine::new();
        let fused = eng.execute(&packed).unwrap();
        let prof = eng.profile(&packed).unwrap();
        for ci in 0..packed.c_pad {
            assert_eq!(prof.energy[ci].to_bits(), fused.metrics[ci].to_bits());
            assert_eq!(
                prof.delay[ci].to_bits(),
                fused.metrics[packed.c_pad + ci].to_bits()
            );
        }
        assert_eq!(prof.d_task.len(), fused.d_task.len());
        for (a, b) in prof.d_task.iter().zip(&fused.d_task) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn lane_kernel_bit_identical_to_scalar_oracle() {
        // The blocked kernel's whole contract in one smoke check (the
        // randomized-shape version lives in tests/hotloop_props.rs).
        let packed = PackedProblem::from_request(&request());
        let mut fast = HostEngine::new();
        let mut oracle = HostEngine::scalar_oracle();
        let a = fast.profile(&packed).unwrap();
        let b = oracle.profile(&packed).unwrap();
        for (x, y) in a.energy.iter().zip(&b.energy) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.delay.iter().zip(&b.delay) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.d_task.iter().zip(&b.d_task) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let fa = fast.execute(&packed).unwrap();
        let fb = oracle.execute(&packed).unwrap();
        for (x, y) in fa.metrics.iter().zip(&fb.metrics) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn beta_scales_tcdp_only() {
        let mut req = request();
        req.beta = 0.0;
        let r0 = evaluate(&mut HostEngine::new(), &req).unwrap();
        req.beta = 2.0;
        let r2 = evaluate(&mut HostEngine::new(), &req).unwrap();
        assert!(r2.metric(MetricRow::Tcdp, 0) > r0.metric(MetricRow::Tcdp, 0));
        assert_eq!(r2.metric(MetricRow::Cdp, 0), r0.metric(MetricRow::Cdp, 0));
    }
}
