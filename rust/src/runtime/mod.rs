//! Execution engines for the batched metric evaluation.
//!
//! Two interchangeable [`Engine`] implementations:
//!
//! * `PjrtEngine` (behind the `pjrt` feature) — the production path: loads
//!   the AOT HLO-text artifacts (`artifacts/dse_metrics_c*.hlo.txt`)
//!   through the `xla` crate's PJRT CPU client, compiles each variant
//!   **once**, caches the executables and streams packed batches through
//!   them. Python is never on this path.
//! * [`HostEngine`] — a pure-Rust f32 mirror of the Layer-2 graph, used to
//!   cross-check PJRT numerics in integration tests and as a fallback when
//!   artifacts are absent (or the `pjrt` feature is off).
//!
//! Engines are `!Send` by design; parallel sweeps construct one engine per
//! worker thread through an [`EngineFactory`] instead of sharing one.
//! Factories that opt in via [`EngineFactory::shared`] run on a persistent
//! per-thread [`WorkerPool`] that keeps workers and their engines alive
//! across fan-outs; the rest fall back to per-call scoped spawning.
//!
//! Evaluation is two-phase: [`Engine::profile`] contracts a packed batch
//! into its scenario-invariant [`DesignProfile`] (phase A — the only part
//! that touches the Layer-1/Layer-2 hot loop) and a
//! [`crate::carbon::ScenarioOverlay`] folds the scenario knobs in (phase
//! B, pure Rust, bit-identical to the fused graph). [`evaluate`] is the
//! profile+overlay composition; [`evaluate_fused`] keeps the single-phase
//! path as the reference oracle.

mod engine;
mod factory;
mod host;
#[cfg(feature = "pjrt")]
mod pjrt;
mod pool;
mod stats;

pub use engine::{Engine, RawOutput, RawProfile};
pub use factory::{auto_factory, EngineFactory, HostEngineFactory};
#[cfg(feature = "pjrt")]
pub use factory::PjrtEngineFactory;
pub use host::{HostEngine, LANES};
pub use pool::{shared_pool, ScopedSpawn, WorkerPool};
pub use stats::{CacheCounters, CacheStats};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use crate::carbon::ScenarioOverlay;
use crate::matrixform::{DesignProfile, EvalRequest, EvalResult, PackedProblem};

/// Evaluate a request on any engine as the two-phase composition:
/// pack → profile (phase A, the engine hot loop) → scenario overlay
/// (phase B, pure Rust) → unpack. On the host engine this is
/// bit-identical to the fused [`evaluate_fused`] path — locked by
/// `coordinator_props.rs::prop_two_phase_evaluate_bit_identical_to_fused`;
/// on PJRT the overlay recomputes the carbon rows in Rust and stays
/// within the existing ≤ 1e-5 pjrt-vs-host envelope.
pub fn evaluate(engine: &mut dyn Engine, req: &EvalRequest) -> crate::Result<EvalResult> {
    let packed = PackedProblem::from_request(req);
    let raw = engine.profile(&packed)?;
    let profile = DesignProfile::from_parts(&packed, raw.energy, raw.delay, raw.d_task);
    Ok(ScenarioOverlay::from_packed(&packed).apply(&profile))
}

/// Fused single-phase reference path (pack → execute → unpack): the
/// engine folds the scenario into the graph itself. Kept as the
/// bit-identity oracle for the two-phase pipeline and as the per-scenario
/// baseline `dse::sweep::sweep_fused` benches against.
pub fn evaluate_fused(engine: &mut dyn Engine, req: &EvalRequest) -> crate::Result<EvalResult> {
    let packed = PackedProblem::from_request(req);
    let raw = engine.execute(&packed)?;
    Ok(packed.unpack(&raw.metrics, &raw.d_task))
}

/// Phase A entry point: pack a request and contract it into a
/// scenario-invariant [`DesignProfile`] (the scenario half of `req` is
/// ignored — profiles depend only on tasks and configs).
pub fn profile_request(
    engine: &mut dyn Engine,
    req: &EvalRequest,
) -> crate::Result<DesignProfile> {
    let packed = PackedProblem::from_request(req);
    let raw = engine.profile(&packed)?;
    Ok(DesignProfile::from_parts(&packed, raw.energy, raw.delay, raw.d_task))
}

/// Build the best available engine: PJRT if the feature is enabled and the
/// artifacts directory exists and loads, host fallback otherwise. Returns
/// the engine and a label naming which path was taken.
pub fn auto_engine(artifacts_dir: &str) -> (Box<dyn Engine>, &'static str) {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(e) = PjrtEngine::load(artifacts_dir) {
            return (Box::new(e), "pjrt");
        }
    }
    let _ = artifacts_dir;
    (Box::new(HostEngine::new()), "host")
}
