//! Execution engines for the batched metric evaluation.
//!
//! Two interchangeable [`Engine`] implementations:
//!
//! * `PjrtEngine` (behind the `pjrt` feature) — the production path: loads
//!   the AOT HLO-text artifacts (`artifacts/dse_metrics_c*.hlo.txt`)
//!   through the `xla` crate's PJRT CPU client, compiles each variant
//!   **once**, caches the executables and streams packed batches through
//!   them. Python is never on this path.
//! * [`HostEngine`] — a pure-Rust f32 mirror of the Layer-2 graph, used to
//!   cross-check PJRT numerics in integration tests and as a fallback when
//!   artifacts are absent (or the `pjrt` feature is off).
//!
//! Engines are `!Send` by design; parallel sweeps construct one engine per
//! worker thread through an [`EngineFactory`] instead of sharing one.

mod engine;
mod factory;
mod host;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use engine::{Engine, RawOutput};
pub use factory::{auto_factory, EngineFactory, HostEngineFactory};
#[cfg(feature = "pjrt")]
pub use factory::PjrtEngineFactory;
pub use host::HostEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use crate::matrixform::{EvalRequest, EvalResult, PackedProblem};

/// Evaluate a request on any engine (pack → execute → unpack).
pub fn evaluate(engine: &mut dyn Engine, req: &EvalRequest) -> crate::Result<EvalResult> {
    let packed = PackedProblem::from_request(req);
    let raw = engine.execute(&packed)?;
    Ok(packed.unpack(&raw.metrics, &raw.d_task))
}

/// Build the best available engine: PJRT if the feature is enabled and the
/// artifacts directory exists and loads, host fallback otherwise. Returns
/// the engine and a label naming which path was taken.
pub fn auto_engine(artifacts_dir: &str) -> (Box<dyn Engine>, &'static str) {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(e) = PjrtEngine::load(artifacts_dir) {
            return (Box::new(e), "pjrt");
        }
    }
    let _ = artifacts_dir;
    (Box::new(HostEngine::new()), "host")
}
