//! Persistent worker pool for parallel fan-outs.
//!
//! Before PR 7 every `dse::sweep::fan_out` call spawned fresh
//! `std::thread::scope` workers and built fresh engines — paid once per
//! profile-chunk batch, per fused sweep, per trace segment fan-out and
//! per search generation. A [`WorkerPool`] amortizes both costs: it
//! spawns its worker threads once, each worker lazily builds **one**
//! long-lived engine from a shared [`EngineFactory`] recipe, and batches
//! of type-erased tasks stream through an MPMC job channel. Engines are
//! `!Send` by design, so they are born and die on their worker thread;
//! only the factory and the task closures cross threads.
//!
//! Scheduling contract (shared with the scoped-spawn fallback in
//! `dse::sweep`):
//!
//! * **Order-preserving** — results return indexed by item, so the
//!   caller's merge order is independent of worker count and of which
//!   worker ran what. Deterministic engines therefore make the whole
//!   fan-out deterministic across thread counts and schedulers.
//! * **Fail-fast** — the first task error flips a per-batch abort flag;
//!   workers check it before starting each item and skip instead of
//!   draining the queue. The error reported is the one with the
//!   **lowest item index** among failures, so error selection is
//!   deterministic too.
//! * **Panic-transparent** — a panicking task poisons nothing: the
//!   worker catches the unwind, discards its (possibly wedged) engine
//!   for a lazy rebuild, and the coordinator re-raises the original
//!   payload after the batch drains.
//!
//! Pools are cached per calling thread in a registry keyed by
//! `(factory identity, worker count)` — see [`shared_pool`] — so
//! repeated sweeps and every generation of a search reuse the same
//! threads and engines. Factories opt in by implementing
//! [`EngineFactory::shared`]; those that return `None` (the default,
//! e.g. ad-hoc test factories or the [`ScopedSpawn`] adapter) keep the
//! per-call scoped spawning.

use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::engine::Engine;
use super::factory::EngineFactory;

/// One type-erased unit of pool work: runs on a worker's engine, returns
/// an erased result the typed [`WorkerPool::fan_out`] wrapper downcasts.
type Task = Box<dyn FnOnce(&mut dyn Engine) -> crate::Result<Box<dyn Any + Send>> + Send>;

/// A task envelope queued to the workers.
struct Envelope {
    idx: usize,
    task: Task,
    abort: Arc<AtomicBool>,
    reply: Sender<Reply>,
}

/// What a worker sends back for one envelope (exactly one per envelope,
/// which is what lets the collector count replies instead of guessing).
enum Reply {
    Done(usize, crate::Result<Box<dyn Any + Send>>),
    Skipped(usize),
    Panicked(usize, Box<dyn Any + Send>),
}

/// A persistent pool of worker threads, each owning one lazily-built,
/// long-lived engine. See the module docs for the scheduling contract.
pub struct WorkerPool {
    job_tx: Option<Sender<Envelope>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    engines_built: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `workers` threads (at least one) sharing `factory` as their
    /// engine recipe. Engines are built lazily on first use, so an idle
    /// pool costs threads but no engine state.
    pub fn new(factory: Arc<dyn EngineFactory>, workers: usize) -> Self {
        let workers = workers.max(1);
        let (job_tx, job_rx) = channel::<Envelope>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let engines_built = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&job_rx);
                let factory = Arc::clone(&factory);
                let built = Arc::clone(&engines_built);
                std::thread::spawn(move || worker_loop(factory, rx, built))
            })
            .collect();
        WorkerPool { job_tx: Some(job_tx), handles, workers, engines_built }
    }

    /// Worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Engines built over the pool's lifetime — stays at ≤ `workers`
    /// across arbitrarily many batches unless a panic forced a rebuild;
    /// the reuse the pool exists for, and what the tests assert.
    pub fn engines_built(&self) -> usize {
        self.engines_built.load(Ordering::Relaxed)
    }

    /// Run `f` over every item on the pool's workers; results return in
    /// item order, with the `dse::sweep::fan_out` thread-count
    /// convention (`min(workers, items)` reported as threads used).
    pub fn fan_out<T, R, F>(&self, items: Vec<T>, f: F) -> crate::Result<(Vec<R>, usize)>
    where
        T: Send + Sync + 'static,
        R: Send + 'static,
        F: Fn(&mut dyn Engine, &T) -> crate::Result<R> + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Ok((Vec::new(), 1));
        }
        let items = Arc::new(items);
        let f = Arc::new(f);
        let abort = Arc::new(AtomicBool::new(false));
        let (reply_tx, reply_rx) = channel::<Reply>();
        // xrlint: allow(panic, "job_tx is only taken in Drop; fan_out needs &self")
        let tx = self.job_tx.as_ref().expect("pool channel alive until drop");
        for idx in 0..n {
            let items = Arc::clone(&items);
            let f = Arc::clone(&f);
            let task: Task = Box::new(move |engine| {
                // xrlint: allow(panic, "idx < items.len() by the 0..n loop")
                f(engine, &items[idx]).map(|r| Box::new(r) as Box<dyn Any + Send>)
            });
            let env =
                Envelope { idx, task, abort: Arc::clone(&abort), reply: reply_tx.clone() };
            tx.send(env).map_err(|_| anyhow::anyhow!("worker pool is shut down"))?;
        }
        drop(reply_tx);

        // xrverify: model(worker_pool)
        // Fenced: the collector protocol verified exhaustively by
        // tools/xrverify/model_pool.py (2 workers × 3 envelopes, failures
        // injected; every interleaving): exactly one reply per envelope,
        // lowest-indexed error wins, slot-indexed merge. Editing fenced
        // code without re-reviewing the model is a V001 finding.
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        let mut first_panic: Option<(usize, Box<dyn Any + Send>)> = None;
        for _ in 0..n {
            let reply = reply_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker pool lost its workers mid-batch"))?;
            match reply {
                Reply::Done(i, Ok(boxed)) => {
                    // xrlint: allow(panic, "the task closure above boxes exactly an R")
                    let v = boxed.downcast::<R>().expect("pool task returned a foreign type");
                    // xrlint: allow(panic, "workers echo the idx they were sent, idx < n")
                    slots[i] = Some(*v);
                }
                Reply::Done(i, Err(e)) => {
                    if first_err.as_ref().is_none_or(|(j, _)| i < *j) {
                        first_err = Some((i, e));
                    }
                }
                Reply::Skipped(_) => {}
                Reply::Panicked(i, payload) => {
                    if first_panic.as_ref().is_none_or(|(j, _)| i < *j) {
                        first_panic = Some((i, payload));
                    }
                }
            }
        }
        if let Some((_, payload)) = first_panic {
            resume_unwind(payload);
        }
        if let Some((_, e)) = first_err {
            return Err(e);
        }
        // xrlint: allow(panic, "n replies received and panics/errors returned early above")
        let out = slots.into_iter().map(|s| s.expect("work item left unevaluated")).collect();
        Ok((out, self.workers.min(n)))
        // xrverify: endmodel(worker_pool)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channel is the shutdown signal: workers drain
        // what is queued, see the disconnect and exit.
        self.job_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// xrverify: model(worker_pool)
fn worker_loop(
    factory: Arc<dyn EngineFactory>,
    jobs: Arc<Mutex<Receiver<Envelope>>>,
    engines_built: Arc<AtomicUsize>,
) {
    let mut engine: Option<Box<dyn Engine>> = None;
    loop {
        let env = {
            let guard = match jobs.lock() {
                Ok(g) => g,
                // A sibling panicked *outside* catch_unwind while holding
                // the lock — unreachable in practice, but exiting beats
                // propagating poison forever.
                Err(_) => return,
            };
            match guard.recv() {
                Ok(env) => env,
                Err(_) => return, // pool dropped: no more jobs, ever
            }
        };
        if env.abort.load(Ordering::Relaxed) {
            // Fail-fast: a sibling already failed this batch; skip
            // instead of draining the queue.
            let _ = env.reply.send(Reply::Skipped(env.idx));
            continue;
        }
        if engine.is_none() {
            match factory.build() {
                Ok(e) => {
                    engines_built.fetch_add(1, Ordering::Relaxed);
                    engine = Some(e);
                }
                Err(e) => {
                    env.abort.store(true, Ordering::Relaxed);
                    let _ = env.reply.send(Reply::Done(env.idx, Err(e)));
                    continue;
                }
            }
        }
        // xrlint: allow(panic, "the match above either filled `engine` or continued")
        let eng = engine.as_mut().expect("engine built above");
        match catch_unwind(AssertUnwindSafe(|| (env.task)(eng.as_mut()))) {
            Ok(res) => {
                if res.is_err() {
                    env.abort.store(true, Ordering::Relaxed);
                }
                let _ = env.reply.send(Reply::Done(env.idx, res));
            }
            Err(payload) => {
                // The engine may be mid-mutation; discard it and rebuild
                // lazily on the next task.
                engine = None;
                env.abort.store(true, Ordering::Relaxed);
                let _ = env.reply.send(Reply::Panicked(env.idx, payload));
            }
        }
    }
}
// xrverify: endmodel(worker_pool)

thread_local! {
    /// Per-thread pool registry. Thread-local (not global) so parallel
    /// test threads and independent coordinators never contend for — or
    /// observe — each other's pools, and `Rc` keeps the handles cheap.
    static REGISTRY: RefCell<HashMap<(String, usize), Rc<WorkerPool>>> =
        RefCell::new(HashMap::new());
}

/// The calling thread's persistent pool for `factory`, sized to
/// `workers` — or `None` when the factory opts out of pooling
/// ([`EngineFactory::shared`] returns `None`), in which case callers
/// fall back to per-call scoped spawning. Pools are created on first use
/// and live until the thread exits, so every later fan-out with the same
/// `(identity, workers)` reuses the same threads and engines.
pub fn shared_pool(factory: &dyn EngineFactory, workers: usize) -> Option<Rc<WorkerPool>> {
    let recipe = factory.shared()?;
    let key = (factory.pool_identity(), workers.max(1));
    Some(REGISTRY.with(|reg| {
        Rc::clone(
            reg.borrow_mut()
                .entry(key)
                .or_insert_with(|| Rc::new(WorkerPool::new(recipe, workers))),
        )
    }))
}

/// Adapter that forces the scoped-spawn scheduler: engine construction
/// delegates to the inner factory, but [`EngineFactory::shared`] stays
/// `None` (the trait default), so `dse::sweep::fan_out` never pools it.
/// The pool-vs-spawn bench (`benches/bench_hotloop.rs`) and the
/// scheduler bit-identity property tests use it as the spawn baseline.
#[derive(Debug, Clone, Copy)]
pub struct ScopedSpawn<F>(pub F);

impl<F: EngineFactory> EngineFactory for ScopedSpawn<F> {
    fn build(&self) -> crate::Result<Box<dyn Engine>> {
        self.0.build()
    }

    fn label(&self) -> &'static str {
        self.0.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostEngineFactory;

    #[test]
    fn pool_preserves_order_and_reuses_engines_across_batches() {
        let pool = WorkerPool::new(Arc::new(HostEngineFactory), 3);
        for round in 0..4u32 {
            let items: Vec<usize> = (0..17).collect();
            let (out, used) =
                pool.fan_out(items, move |_eng, &i: &usize| Ok(i * 2 + round as usize)).unwrap();
            assert_eq!(used, 3);
            assert_eq!(out, (0..17).map(|i| i * 2 + round as usize).collect::<Vec<_>>());
        }
        // Four batches, still at most one engine per worker.
        let built = pool.engines_built();
        assert!(built >= 1 && built <= 3, "engines_built={built}");
    }

    #[test]
    fn pool_reports_lowest_indexed_error_and_skips_after_abort() {
        let pool = WorkerPool::new(Arc::new(HostEngineFactory), 2);
        let processed = Arc::new(AtomicUsize::new(0));
        let p = Arc::clone(&processed);
        let items: Vec<usize> = (0..200).collect();
        let err = pool
            .fan_out(items, move |_eng, &i: &usize| {
                p.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(200));
                if i % 7 == 3 {
                    anyhow::bail!("task {i} failed");
                }
                Ok(i)
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "task 3 failed");
        // Fail-fast: nowhere near the full queue was drained.
        assert!(processed.load(Ordering::SeqCst) < 100);
        // The pool stays usable for the next batch.
        let (out, _) = pool.fan_out(vec![5usize], |_eng, &i: &usize| Ok(i + 1)).unwrap();
        assert_eq!(out, vec![6]);
    }

    #[test]
    fn pool_resurfaces_task_panics_and_recovers() {
        let pool = Rc::new(WorkerPool::new(Arc::new(HostEngineFactory), 2));
        let p = Rc::clone(&pool);
        let caught = catch_unwind(AssertUnwindSafe(move || {
            let _ = p.fan_out(vec![0usize, 1, 2], |_eng, &i: &usize| {
                if i == 1 {
                    panic!("task panic marker");
                }
                Ok(i)
            });
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "task panic marker");
        // The worker discarded its engine and rebuilt; the pool lives on.
        let (out, _) = pool.fan_out(vec![7usize], |_eng, &i: &usize| Ok(i)).unwrap();
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn shared_pool_registry_reuses_by_identity_and_size() {
        let a = shared_pool(&HostEngineFactory, 2).expect("host factory pools");
        let b = shared_pool(&HostEngineFactory, 2).expect("host factory pools");
        assert!(Rc::ptr_eq(&a, &b), "same (identity, size) must share one pool");
        let c = shared_pool(&HostEngineFactory, 3).expect("host factory pools");
        assert!(!Rc::ptr_eq(&a, &c), "different sizes are different pools");
        assert_eq!(a.workers(), 2);
        assert_eq!(c.workers(), 3);
    }

    #[test]
    fn scoped_spawn_adapter_opts_out_of_pooling() {
        let f = ScopedSpawn(HostEngineFactory);
        assert_eq!(f.label(), "host");
        assert!(f.shared().is_none());
        assert!(shared_pool(&f, 2).is_none());
        assert_eq!(f.build().unwrap().name(), "host");
    }

    #[test]
    fn zero_items_is_a_no_op() {
        let pool = WorkerPool::new(Arc::new(HostEngineFactory), 2);
        let (out, used) = pool.fan_out(Vec::<usize>::new(), |_eng, &i: &usize| Ok(i)).unwrap();
        assert!(out.is_empty());
        assert_eq!(used, 1);
        assert_eq!(pool.engines_built(), 0, "no items, no engines");
    }
}
