//! The engine abstraction the coordinator schedules batches onto.

use crate::matrixform::PackedProblem;

/// Raw (still padded) engine output buffers.
#[derive(Debug, Clone)]
pub struct RawOutput {
    /// `[12 × c_pad]` metric rows.
    pub metrics: Vec<f32>,
    /// `[c_pad × T_PAD]` per-task delays.
    pub d_task: Vec<f32>,
}

/// Raw (still padded) scenario-invariant profile buffers — phase A of the
/// two-phase pipeline. These are exactly the fused graph's invariant
/// rows: total energy, total delay and the per-task delays; everything
/// scenario-dependent is left to the overlay.
#[derive(Debug, Clone)]
pub struct RawProfile {
    /// `[c_pad]` total energy per config, J.
    pub energy: Vec<f32>,
    /// `[c_pad]` total delay per config, s.
    pub delay: Vec<f32>,
    /// `[c_pad × T_PAD]` per-task delays, s.
    pub d_task: Vec<f32>,
}

/// A batched metric evaluator.
///
/// Not `Send`: the PJRT client is `Rc`-based, so engines stay on the
/// coordinating thread; the coordinator parallelizes batch *assembly*
/// (accelerator simulation) instead.
pub trait Engine {
    /// Execute one packed batch through the fused (single-phase) graph.
    fn execute(&mut self, p: &PackedProblem) -> crate::Result<RawOutput>;

    /// Phase A: contract one packed batch into its scenario-invariant
    /// profile. The default runs the fused graph and keeps the invariant
    /// rows (the energy/delay/d_task outputs do not depend on the packed
    /// scenario scalars); engines with a cheaper direct contraction
    /// override it.
    fn profile(&mut self, p: &PackedProblem) -> crate::Result<RawProfile> {
        let raw = self.execute(p)?;
        let c = p.c_pad;
        Ok(RawProfile {
            energy: raw.metrics[..c].to_vec(),     // MetricRow::Energy
            delay: raw.metrics[c..2 * c].to_vec(), // MetricRow::Delay
            d_task: raw.d_task,
        })
    }

    /// Engine label for logs/reports ("pjrt", "host").
    fn name(&self) -> &'static str;
}
