//! The engine abstraction the coordinator schedules batches onto.

use crate::matrixform::PackedProblem;

/// Raw (still padded) engine output buffers.
#[derive(Debug, Clone)]
pub struct RawOutput {
    /// `[12 × c_pad]` metric rows.
    pub metrics: Vec<f32>,
    /// `[c_pad × T_PAD]` per-task delays.
    pub d_task: Vec<f32>,
}

/// A batched metric evaluator.
///
/// Not `Send`: the PJRT client is `Rc`-based, so engines stay on the
/// coordinating thread; the coordinator parallelizes batch *assembly*
/// (accelerator simulation) instead.
pub trait Engine {
    /// Execute one packed batch.
    fn execute(&mut self, p: &PackedProblem) -> crate::Result<RawOutput>;

    /// Engine label for logs/reports ("pjrt", "host").
    fn name(&self) -> &'static str;
}
