//! PJRT engine: loads the AOT HLO-text artifacts and executes them on the
//! XLA CPU client (`xla` crate). Python never runs on this path.
//!
//! One executable is compiled per config-batch variant (C = 128 / 1024)
//! at engine construction and cached for the process lifetime; each
//! `execute` call only builds input literals and runs.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context};

use super::engine::{Engine, RawOutput};
use crate::configfmt::{parse, Json};
use crate::matrixform::{PackedProblem, J_PAD, K_PAD, NUM_METRICS, T_PAD};

/// PJRT-backed engine with per-variant executable cache.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    executables: HashMap<usize, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Load every variant listed in `artifacts/manifest.json`, compile and
    /// cache. Fails if the manifest is missing/stale or any artifact does
    /// not parse.
    pub fn load(artifacts_dir: &str) -> crate::Result<Self> {
        let dir = Path::new(artifacts_dir);
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = parse(&text).context("parsing artifact manifest")?;

        // Contract checks: shape constants must match this build.
        let want = [("t", T_PAD), ("k", K_PAD), ("j", J_PAD), ("num_metrics", NUM_METRICS)];
        for (key, expect) in want {
            let got = manifest.get(key).and_then(Json::as_i64).unwrap_or(-1);
            if got != expect as i64 {
                bail!("artifact manifest {key}={got}, runtime expects {expect}; re-run `make artifacts`");
            }
        }

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        let variants = manifest
            .get("variants")
            .and_then(Json::as_obj)
            .context("manifest missing variants")?;
        for (c_str, entry) in variants {
            let c: usize = c_str.parse().context("bad variant key")?;
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .context("variant missing file")?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-UTF8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling variant C={c}"))?;
            executables.insert(c, exe);
        }
        if executables.is_empty() {
            bail!("no artifact variants found in {artifacts_dir}");
        }
        Ok(PjrtEngine { client, executables })
    }

    /// Variants available (sorted).
    pub fn variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.executables.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn literal_2d(data: &[f32], rows: usize, cols: usize) -> crate::Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        // Single-copy construction (perf: `vec1(..).reshape(..)` costs a
        // second literal allocation + copy on the hot path — see
        // EXPERIMENTS.md §Perf).
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[rows, cols],
            bytes,
        )?)
    }
}

impl Engine for PjrtEngine {
    fn execute(&mut self, p: &PackedProblem) -> crate::Result<RawOutput> {
        let exe = self
            .executables
            .get(&p.c_pad)
            .with_context(|| format!("no artifact variant for C={}", p.c_pad))?;

        let inputs = [
            Self::literal_2d(&p.n, T_PAD, K_PAD)?,
            Self::literal_2d(&p.p_leak, p.c_pad, K_PAD)?,
            Self::literal_2d(&p.p_dyn, p.c_pad, K_PAD)?,
            Self::literal_2d(&p.f_clk, p.c_pad, 1)?,
            Self::literal_2d(&p.d_k, p.c_pad, K_PAD)?,
            Self::literal_2d(&p.c_comp, p.c_pad, J_PAD)?,
            xla::Literal::vec1(&p.online),
            xla::Literal::vec1(&p.qos),
            xla::Literal::vec1(&p.scalars),
        ];

        let result = exe.execute::<xla::Literal>(&inputs)?;
        let out = result[0][0].to_literal_sync()?;
        let (metrics_lit, d_task_lit) = out.to_tuple2()?;
        let metrics = metrics_lit.to_vec::<f32>()?;
        let d_task = d_task_lit.to_vec::<f32>()?;
        if metrics.len() != NUM_METRICS * p.c_pad || d_task.len() != p.c_pad * T_PAD {
            bail!(
                "artifact output shape mismatch: metrics={} d_task={} for C={}",
                metrics.len(),
                d_task.len(),
                p.c_pad
            );
        }
        Ok(RawOutput { metrics, d_task })
    }

    // Phase A (`Engine::profile`) uses the trait default: there is no
    // separate profile artifact, so the default runs the fused executable
    // and keeps its scenario-invariant rows (energy, delay, per-task
    // delays — none depend on the packed scenario scalars). The
    // scenario-dependent rows are discarded; the Rust overlay recomputes
    // them per scenario, so multi-scenario sweeps pay the XLA dispatch
    // only once per config chunk. Note the overlay's Rust f32 arithmetic
    // may differ from the compiled HLO's carbon rows by ULPs (XLA is free
    // to fuse/reassociate); the strict bit-identity contract is proven on
    // the host engine, and PJRT stays inside the existing ≤1e-5
    // pjrt-vs-host envelope.

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// PJRT tests live in `rust/tests/pjrt_vs_host.rs` (integration) because
// they need the artifacts built by `make artifacts`.
