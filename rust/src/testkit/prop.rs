//! Miniature property-test runner.
//!
//! `proptest` is not present in the offline registry, so coordinator
//! invariants are checked with this deterministic stand-in: a generator
//! function receives a seeded [`Rng`] and produces a case; the property is
//! run for `cases` iterations and the first failing case (with its
//! iteration index and debug rendering) is reported. No shrinking — cases
//! are kept small by construction instead.

use super::Rng;

/// Configuration for [`forall_cfg`].
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of generated cases.
    pub cases: usize,
    /// Base seed; each case uses a fork of this stream.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 256, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cases` generated inputs with the default config.
///
/// Panics (test-failure style) on the first counterexample.
pub fn forall<T, G, P>(gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    forall_cfg(PropConfig::default(), gen, prop)
}

/// Run `prop` over generated inputs with an explicit config.
pub fn forall_cfg<T, G, P>(cfg: PropConfig, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut root = Rng::new(cfg.seed);
    for i in 0..cfg.cases {
        let mut case_rng = root.fork(i as u64);
        let case = gen(&mut case_rng);
        if !prop(&case) {
            panic!(
                "property failed at case {}/{} (seed {:#x}):\n{:#?}",
                i, cfg.cases, cfg.seed, case
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        forall_cfg(
            PropConfig { cases: 64, seed: 1 },
            |r| r.below(100),
            |&x| {
                count.set(count.get() + 1);
                x < 100
            },
        );
        assert_eq!(count.get(), 64);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(|r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed: u64| {
            let out = std::cell::RefCell::new(Vec::new());
            forall_cfg(
                PropConfig { cases: 16, seed },
                |r| r.below(1000),
                |&x| {
                    out.borrow_mut().push(x);
                    true
                },
            );
            out.into_inner()
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }
}
