//! Miniature property-test runner.
//!
//! `proptest` is not present in the offline registry, so coordinator
//! invariants are checked with this deterministic stand-in: a generator
//! function receives a seeded [`Rng`] and produces a case; the property is
//! run for `cases` iterations and the first failing case (with its
//! iteration index and debug rendering) is reported. No shrinking — cases
//! are kept small by construction instead.
//!
//! ## Replaying failures
//!
//! Every failure report names the seed that produced it, and the
//! `XRCARBON_TEST_SEED` environment variable overrides the seed of every
//! `forall`/`forall_cfg` run (both the default and explicitly configured
//! seeds), so any `prop_*` failure replays with
//!
//! ```text
//! XRCARBON_TEST_SEED=0x… cargo test -q prop_name
//! ```
//!
//! The hint is printed on *any* panic inside the generator or property —
//! `assert!` failures inside a property included, not just `false`
//! returns — via a panic-aware drop guard.

use super::Rng;

/// Environment variable that overrides every property-test seed.
pub const SEED_ENV: &str = "XRCARBON_TEST_SEED";

/// Configuration for [`forall_cfg`].
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of generated cases.
    pub cases: usize,
    /// Base seed; each case uses a fork of this stream. Overridden by
    /// [`SEED_ENV`] when set.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 256, seed: 0xC0FFEE }
    }
}

/// Parse a seed value: decimal ("48879") or hex with prefix ("0xBEEF").
pub fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// The seed a run will actually use: the [`SEED_ENV`] override when set
/// (and parseable), the configured seed otherwise.
fn effective_seed(configured: u64) -> u64 {
    std::env::var(SEED_ENV).ok().and_then(|v| parse_seed(&v)).unwrap_or(configured)
}

/// Prints the replay recipe if dropped while panicking — this is what
/// makes `assert!`-style failures inside a property replayable, not just
/// `false` returns.
struct ReplayHint {
    seed: u64,
    case: usize,
}

impl Drop for ReplayHint {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "property failed at case {} (seed {:#x}) — replay with {}={:#x}",
                self.case, self.seed, SEED_ENV, self.seed
            );
        }
    }
}

/// Run `prop` over `cases` generated inputs with the default config.
///
/// Panics (test-failure style) on the first counterexample.
pub fn forall<T, G, P>(gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    forall_cfg(PropConfig::default(), gen, prop)
}

/// Run `prop` over generated inputs with an explicit config.
pub fn forall_cfg<T, G, P>(cfg: PropConfig, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let seed = effective_seed(cfg.seed);
    let mut root = Rng::new(seed);
    for i in 0..cfg.cases {
        let hint = ReplayHint { seed, case: i };
        let mut case_rng = root.fork(i as u64);
        let case = gen(&mut case_rng);
        let ok = prop(&case);
        // Disarm before the explicit panic below — the guard is for
        // panics *inside* gen/prop, where no report exists yet.
        std::mem::forget(hint);
        if !ok {
            panic!(
                "property failed at case {}/{} (seed {:#x}; replay with {}={:#x}):\n{:#?}",
                i, cfg.cases, seed, SEED_ENV, seed, case
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        forall_cfg(
            PropConfig { cases: 64, seed: 1 },
            |r| r.below(100),
            |&x| {
                count.set(count.get() + 1);
                x < 100
            },
        );
        assert_eq!(count.get(), 64);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(|r| r.below(10), |&x| x < 5);
    }

    #[test]
    #[should_panic(expected = "XRCARBON_TEST_SEED")]
    fn failure_message_names_the_replay_env_var() {
        forall_cfg(PropConfig { cases: 8, seed: 99 }, |r| r.below(10), |_| false);
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed: u64| {
            let out = std::cell::RefCell::new(Vec::new());
            forall_cfg(
                PropConfig { cases: 16, seed },
                |r| r.below(1000),
                |&x| {
                    out.borrow_mut().push(x);
                    true
                },
            );
            out.into_inner()
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }

    #[test]
    fn parse_seed_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("48879"), Some(48879));
        assert_eq!(parse_seed("0xBEEF"), Some(0xBEEF));
        assert_eq!(parse_seed("0XbeEf"), Some(0xBEEF));
        assert_eq!(parse_seed(" 7 "), Some(7));
        assert_eq!(parse_seed("0x"), None);
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed(""), None);
    }

    #[test]
    fn env_override_only_applies_when_parseable() {
        // Pure-logic check (no env mutation — tests run in parallel):
        // effective_seed falls back to the configured value when the
        // variable is unset, which is the only state we can rely on here;
        // the parse path is covered by parse_seed_accepts_decimal_and_hex.
        if std::env::var(SEED_ENV).is_err() {
            assert_eq!(super::effective_seed(1234), 1234);
        }
    }
}
