//! Deterministic randomness and a small property-testing harness.
//!
//! The offline crate registry available to this build does not include
//! `rand` or `proptest`, so this module provides the two pieces the rest of
//! the crate needs: a fast, seedable, high-quality PRNG
//! ([`Rng`], SplitMix64 + xoshiro256\*\*) and a miniature property-test
//! runner ([`forall`], [`forall_cfg`]) with deterministic case generation
//! and first-failure reporting. All fleet-telemetry synthesis in
//! [`crate::workloads`] is seeded through this module so every experiment
//! is exactly reproducible. Property-test seeds can be overridden with
//! the `XRCARBON_TEST_SEED` environment variable ([`SEED_ENV`]) to replay
//! a reported failure.

mod prng;
mod prop;

pub use prng::{Rng, RngState};
pub use prop::{forall, forall_cfg, parse_seed, PropConfig, SEED_ENV};

/// Unique scratch directory for tests: `$TMPDIR/xrcarbon_<tag>_<pid>_<n>`
/// with a process-wide counter — collision-free across parallel tests in
/// one binary and across binaries, with no wall clock or RNG involved
/// (both are banned from deterministic test paths). The caller creates
/// and removes it.
pub fn test_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("xrcarbon_{tag}_{}_{n}", std::process::id()))
}
