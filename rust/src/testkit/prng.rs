//! xoshiro256** PRNG seeded via SplitMix64, plus the sampling helpers the
//! fleet-telemetry generator and the property tester need (uniforms,
//! normals, truncated normals, Zipf, categorical).

/// Deterministic, seedable PRNG (xoshiro256**).
///
/// Not cryptographic — used only for synthetic workload generation and
/// property-test case generation, where reproducibility is the point.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

/// Serializable [`Rng`] snapshot (see [`Rng::state`]). The spare normal
/// deviate travels as raw `f64` bits so restoration is bit-exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RngState {
    /// xoshiro256** state words.
    pub s: [u64; 4],
    /// Cached Box–Muller pair member, `f64::to_bits` encoded.
    pub gauss_spare_bits: Option<u64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Snapshot the full generator state for checkpointing: the four
    /// xoshiro words plus the cached Box–Muller spare (as raw bits, so
    /// the round-trip is bit-exact). `from_state` restores a generator
    /// that continues the stream identically.
    pub fn state(&self) -> RngState {
        RngState { s: self.s, gauss_spare_bits: self.gauss_spare.map(f64::to_bits) }
    }

    /// Rebuild a generator from a [`Self::state`] snapshot.
    pub fn from_state(state: RngState) -> Rng {
        Rng { s: state.s, gauss_spare: state.gauss_spare_bits.map(f64::from_bits) }
    }

    /// Derive an independent child stream (stable for a given label).
    pub fn fork(&mut self, label: u64) -> Rng {
        let mixed = self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(mixed)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Normal truncated (by resampling) to [lo, hi].
    pub fn truncated_normal(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo < hi);
        for _ in 0..64 {
            let x = self.normal(mean, std);
            if x >= lo && x <= hi {
                return x;
            }
        }
        // Pathological parameters: fall back to clamping.
        self.normal(mean, std).clamp(lo, hi)
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (popularity law for
    /// the VR app catalog; rank 0 is the most popular item).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over the (small) support; n is ~100 in our use.
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
        }
        let target = self.f64() * total;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            if acc >= target {
                return k - 1;
            }
        }
        n - 1
    }

    /// Draw an index from an unnormalized non-negative weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical weights must not all be zero");
        let target = self.f64() * total;
        let mut acc = 0.0;
        for (i, w) in weights.iter().enumerate() {
            acc += w;
            if acc >= target {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_covers_support() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn truncated_normal_respects_bounds() {
        let mut r = Rng::new(8);
        for _ in 0..5000 {
            let x = r.truncated_normal(0.7, 0.2, 0.0, 1.0);
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_is_head_heavy() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 50];
        for _ in 0..50_000 {
            counts[r.zipf(50, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[49] * 5);
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_stream_bit_identically() {
        let mut a = Rng::new(0xCACHE);
        // Burn an odd number of gauss draws so the spare is populated.
        for _ in 0..7 {
            a.gauss();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.gauss().to_bits(), b.gauss().to_bits());
        }
        // And a fresh generator's state round-trips too (no spare).
        let fresh = Rng::new(5);
        assert_eq!(fresh.state().gauss_spare_bits, None);
        assert_eq!(Rng::from_state(fresh.state()).state(), fresh.state());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(12);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
