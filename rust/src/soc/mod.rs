//! Retrospective hardware databases and the VR SoC model (paper §2, §4.2).
//!
//! * [`cpu_db`] — Intel/AMD server-class CPUs released 2012–2021 with the
//!   performance/TDP/die data behind Fig 2(a);
//! * [`soc_db`] — Qualcomm Snapdragon mobile SoCs 2016–2020 behind
//!   Fig 2(b);
//! * [`vr_soc`] — the production VR headset SoC of Table 5 (octa-core
//!   CPU, gold/silver clusters) and its per-component embodied-carbon
//!   vector used by the provisioning studies (Figs 11/13).
//!
//! The spec entries are approximate public data (die sizes from teardowns,
//! scores from public benchmark databases, TLP-scaled where the paper's
//! application suite would not use all cores); the *orderings* the paper
//! reports (which part is EDP/CDP/CEP-optimal) are reproduced and locked
//! by tests.

pub mod cpu_db;
pub mod soc_db;
pub mod vr_soc;

pub use cpu_db::{server_cpus, CpuSpec, Vendor};
pub use soc_db::{mobile_socs, SocSpec};
pub use vr_soc::{CoreKind, VrSoc};
