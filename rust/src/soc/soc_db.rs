//! Qualcomm Snapdragon mobile SoC retrospective database (Fig 2b).
//!
//! Die areas from public teardowns; performance is a CenturionMark-style
//! score. Samsung-fabbed parts (10/14 nm generation) assume the Korea
//! grid, TSMC-fabbed 7 nm parts the Taiwan grid, per the paper's
//! fab-location methodology. A fixed 85 % yield matches the paper's
//! mobile-SoC assumption (§4.2).

use crate::carbon::{ChipDesign, FabGrid, MetricInputs, ProcessNode, YieldModel};

/// One mobile SoC entry.
#[derive(Debug, Clone)]
pub struct SocSpec {
    /// Marketing name.
    pub name: &'static str,
    /// Release year.
    pub year: u32,
    /// CenturionMark-style performance score (higher better).
    pub score: f64,
    /// Sustained TDP, W.
    pub tdp_w: f64,
    /// Die area, cm².
    pub die_cm2: f64,
    /// Process node.
    pub node: ProcessNode,
    /// Fab grid (Samsung → Korea, TSMC → Taiwan).
    pub fab: FabGrid,
}

impl SocSpec {
    /// Embodied carbon at the paper's fixed 85 % mobile yield, gCO₂e.
    pub fn embodied_g(&self) -> f64 {
        ChipDesign::monolithic(self.name, self.die_cm2, self.node, YieldModel::Fixed(0.85), self.fab)
            .embodied_g()
    }

    /// `E = TDP / Performance` proxy.
    pub fn energy_proxy(&self) -> f64 {
        self.tdp_w / self.score
    }

    /// `D = 1 / Performance` proxy.
    pub fn delay_proxy(&self) -> f64 {
        1.0 / self.score
    }

    /// Metric inputs for the Fig 2(b) comparison.
    pub fn metric_inputs(&self, use_ci_g_per_unit: f64) -> MetricInputs {
        MetricInputs {
            energy_j: self.energy_proxy(),
            delay_s: self.delay_proxy(),
            c_operational_g: use_ci_g_per_unit * self.energy_proxy(),
            c_embodied_g: self.embodied_g(),
        }
    }
}

/// The Fig 2(b) Snapdragon set (2016–2020), oldest first.
pub fn mobile_socs() -> Vec<SocSpec> {
    vec![
        SocSpec {
            name: "Snapdragon-821",
            year: 2016,
            score: 82.0,
            tdp_w: 5.0,
            die_cm2: 1.13,
            node: ProcessNode::N14,
            fab: FabGrid::Korea,
        },
        SocSpec {
            name: "Snapdragon-835",
            year: 2017,
            score: 115.0,
            tdp_w: 5.0,
            die_cm2: 0.723,
            node: ProcessNode::N10,
            fab: FabGrid::Korea,
        },
        SocSpec {
            name: "Snapdragon-845",
            year: 2018,
            score: 128.0,
            tdp_w: 5.0,
            die_cm2: 0.94,
            node: ProcessNode::N10,
            fab: FabGrid::Korea,
        },
        SocSpec {
            name: "Snapdragon-855",
            year: 2019,
            score: 140.0,
            tdp_w: 4.5,
            die_cm2: 0.73,
            node: ProcessNode::N7,
            fab: FabGrid::Taiwan,
        },
        SocSpec {
            name: "Snapdragon-865",
            year: 2020,
            score: 158.0,
            tdp_w: 5.0,
            die_cm2: 0.835,
            node: ProcessNode::N7,
            fab: FabGrid::Taiwan,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::metrics::argmin;

    #[test]
    fn edp_optimal_is_sd865() {
        // §2.1: "EDP-optimal SoC—Snapdragon 865".
        let socs = mobile_socs();
        let edp: Vec<f64> = socs.iter().map(|s| s.metric_inputs(1.0).metrics().edp).collect();
        assert_eq!(socs[argmin(&edp).unwrap()].name, "Snapdragon-865");
    }

    #[test]
    fn cdp_optimal_is_sd835() {
        // §2.1: "CDP-optimal SoC—Snapdragon 835".
        let socs = mobile_socs();
        let cdp: Vec<f64> = socs.iter().map(|s| s.metric_inputs(1.0).metrics().cdp).collect();
        assert_eq!(socs[argmin(&cdp).unwrap()].name, "Snapdragon-835");
    }

    #[test]
    fn cep_optimal_is_sd855() {
        // §2.1: "Snapdragon 855 is CEP-optimal".
        let socs = mobile_socs();
        let cep: Vec<f64> = socs.iter().map(|s| s.metric_inputs(1.0).metrics().cep).collect();
        assert_eq!(socs[argmin(&cep).unwrap()].name, "Snapdragon-855");
    }

    #[test]
    fn embodied_trend_rises_with_node_advance() {
        // §2.1: "there is an increasing embodied carbon trend as process
        // technology advances" — per-area carbon grows 10 nm → 7 nm, so the
        // similar-sized 855 carries more embodied carbon than the 835.
        let socs = mobile_socs();
        let sd835 = socs.iter().find(|s| s.name == "Snapdragon-835").unwrap();
        let sd855 = socs.iter().find(|s| s.name == "Snapdragon-855").unwrap();
        assert!(sd855.embodied_g() > sd835.embodied_g());
    }

    #[test]
    fn embodied_values_are_gram_scale() {
        for s in mobile_socs() {
            let g = s.embodied_g();
            assert!((500.0..5000.0).contains(&g), "{} embodied = {g} g", s.name);
        }
    }
}
