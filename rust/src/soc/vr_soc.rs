//! The production VR headset SoC (Table 5, §4.2) and its provisioning
//! model (Figs 4, 11, 13).
//!
//! Per the paper: a 7 nm Snapdragon-class SoC, 2.25 cm² die, octa-core CPU
//! occupying 20 % of the die — gold (big) cores ⅔ of the CPU area, silver
//! (little) cores ⅓ — 85 % fixed yield, coal fab grid. The GPU is modeled
//! at 25 % of the die (typical mobile floorplans); the remainder covers
//! modem, ISP, DSP, memory controllers.

use crate::carbon::{embodied_carbon, FabGrid, ProcessNode};

/// Core class in the octa-core CPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreKind {
    /// Performance ("gold") core — the application cores.
    Gold,
    /// Efficiency ("silver") core — auxiliary/system services.
    Silver,
}

/// The Table 5 VR SoC model.
#[derive(Debug, Clone, Copy)]
pub struct VrSoc {
    /// Total die area, cm² (Table 5: 2.25).
    pub die_cm2: f64,
    /// CPU block area, cm² (Table 5: 0.45).
    pub cpu_cm2: f64,
    /// GPU block area, cm².
    pub gpu_cm2: f64,
    /// Fixed yield (§4.2: 85 %).
    pub yield_frac: f64,
    /// Fab grid (§4.2: coal).
    pub fab: FabGrid,
    /// Process node (§4.2: 7 nm).
    pub node: ProcessNode,
    /// Headset TDP, W (Fig 4: 8.3 W).
    pub tdp_w: f64,
}

impl Default for VrSoc {
    fn default() -> Self {
        VrSoc {
            die_cm2: 2.25,
            cpu_cm2: 0.45,
            gpu_cm2: 0.5625, // 25% of die
            yield_frac: 0.85,
            fab: FabGrid::Coal,
            node: ProcessNode::N7,
            tdp_w: 8.3,
        }
    }
}

impl VrSoc {
    /// Number of gold cores (octa-core: 4 + 4).
    pub const GOLD_CORES: usize = 4;
    /// Number of silver cores.
    pub const SILVER_CORES: usize = 4;

    /// Area of one core, cm². Gold cluster is ⅔ of the CPU area across 4
    /// cores; silver cluster the remaining ⅓.
    pub fn core_area_cm2(&self, kind: CoreKind) -> f64 {
        match kind {
            CoreKind::Gold => self.cpu_cm2 * (2.0 / 3.0) / Self::GOLD_CORES as f64,
            CoreKind::Silver => self.cpu_cm2 * (1.0 / 3.0) / Self::SILVER_CORES as f64,
        }
    }

    /// Embodied carbon of one core, gCO₂e.
    pub fn core_embodied_g(&self, kind: CoreKind) -> f64 {
        embodied_carbon(self.node, self.fab, self.core_area_cm2(kind), self.yield_frac)
    }

    /// Embodied carbon of the whole gold cluster (Table 5: 895.89 g).
    pub fn gold_cluster_g(&self) -> f64 {
        self.core_embodied_g(CoreKind::Gold) * Self::GOLD_CORES as f64
    }

    /// Embodied carbon of the whole silver cluster (Table 5: 447.94 g).
    pub fn silver_cluster_g(&self) -> f64 {
        self.core_embodied_g(CoreKind::Silver) * Self::SILVER_CORES as f64
    }

    /// Embodied carbon of the GPU block, gCO₂e.
    pub fn gpu_g(&self) -> f64 {
        embodied_carbon(self.node, self.fab, self.gpu_cm2, self.yield_frac)
    }

    /// Embodied carbon of the full die, gCO₂e.
    pub fn die_g(&self) -> f64 {
        embodied_carbon(self.node, self.fab, self.die_cm2, self.yield_frac)
    }

    /// Per-component embodied-carbon vector in the §3.3.3 layout used by
    /// the provisioning optimizer: `[gold×4, silver×4, gpu, rest]`
    /// (10 components).
    pub fn component_vector_g(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(10);
        for _ in 0..Self::GOLD_CORES {
            v.push(self.core_embodied_g(CoreKind::Gold));
        }
        for _ in 0..Self::SILVER_CORES {
            v.push(self.core_embodied_g(CoreKind::Silver));
        }
        v.push(self.gpu_g());
        let rest_cm2 = self.die_cm2 - self.cpu_cm2 - self.gpu_cm2;
        v.push(embodied_carbon(self.node, self.fab, rest_cm2, self.yield_frac));
        v
    }

    /// Online mask for a core-count configuration: `gold_on` gold cores and
    /// `silver_on` silver cores enabled, GPU and uncore always on.
    pub fn core_mask(&self, gold_on: usize, silver_on: usize) -> Vec<f64> {
        assert!(gold_on <= Self::GOLD_CORES && silver_on <= Self::SILVER_CORES);
        let mut m = Vec::with_capacity(10);
        for i in 0..Self::GOLD_CORES {
            m.push(if i < gold_on { 1.0 } else { 0.0 });
        }
        for i in 0..Self::SILVER_CORES {
            m.push(if i < silver_on { 1.0 } else { 0.0 });
        }
        m.push(1.0); // GPU
        m.push(1.0); // uncore
        m
    }

    /// CPU-only embodied carbon for a provisioned core count, gCO₂e.
    pub fn provisioned_cpu_g(&self, gold_on: usize, silver_on: usize) -> f64 {
        self.core_embodied_g(CoreKind::Gold) * gold_on as f64
            + self.core_embodied_g(CoreKind::Silver) * silver_on as f64
    }

    /// Split a total enabled-core count into (gold, silver) the way the
    /// paper's scheduler does: application cores (gold) first up to 4, then
    /// silver service cores. At least one of each remains online.
    pub fn split_cores(total: usize) -> (usize, usize) {
        assert!((2..=8).contains(&total), "core count must be 2..=8");
        let gold = total.saturating_sub(4).max(1).min(4);
        // Fill silver with the remainder, bounded to 4.
        let silver = (total - gold).min(4);
        // If silver hit its cap, give the slack back to gold.
        let gold = (total - silver).min(4);
        (gold, silver)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_cluster_values() {
        let soc = VrSoc::default();
        assert!((soc.gold_cluster_g() - 895.89).abs() < 0.5, "gold={}", soc.gold_cluster_g());
        assert!((soc.silver_cluster_g() - 447.94).abs() < 0.3, "silver={}", soc.silver_cluster_g());
    }

    #[test]
    fn core_areas_match_table5() {
        let soc = VrSoc::default();
        assert!((soc.core_area_cm2(CoreKind::Gold) * 4.0 - 0.3).abs() < 1e-12);
        assert!((soc.core_area_cm2(CoreKind::Silver) * 4.0 - 0.15).abs() < 1e-12);
    }

    #[test]
    fn component_vector_sums_to_die() {
        let soc = VrSoc::default();
        let sum: f64 = soc.component_vector_g().iter().sum();
        assert!((sum - soc.die_g()).abs() < 1e-6, "sum={sum} die={}", soc.die_g());
    }

    #[test]
    fn full_mask_recovers_full_cpu() {
        let soc = VrSoc::default();
        let full = soc.provisioned_cpu_g(4, 4);
        assert!((full - (soc.gold_cluster_g() + soc.silver_cluster_g())).abs() < 1e-9);
        // Halving the cores halves the respective cluster's carbon.
        let half = soc.provisioned_cpu_g(2, 2);
        assert!((half - full / 2.0).abs() < 1e-9);
    }

    #[test]
    fn split_cores_policy() {
        // 8 -> 4+4; 5 -> 4 app cores need at least 1, services keep rest.
        assert_eq!(VrSoc::split_cores(8), (4, 4));
        assert_eq!(VrSoc::split_cores(7), (3, 4));
        assert_eq!(VrSoc::split_cores(6), (2, 4));
        assert_eq!(VrSoc::split_cores(5), (1, 4));
        assert_eq!(VrSoc::split_cores(4), (1, 3));
        assert_eq!(VrSoc::split_cores(2), (1, 1));
    }

    #[test]
    fn mask_matches_split() {
        let soc = VrSoc::default();
        let m = soc.core_mask(2, 3);
        assert_eq!(m.len(), 10);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 2 + 3 + 2);
    }

    #[test]
    #[should_panic(expected = "core count")]
    fn split_cores_rejects_out_of_range() {
        VrSoc::split_cores(9);
    }
}
