//! Server-class CPU retrospective database (Fig 2a).
//!
//! Each entry carries the data the paper's analysis needs: an
//! application-level performance score (CPUMark-style; chiplet many-core
//! parts are TLP-scaled because the paper's workloads do not scale to 128
//! threads — absolute PassMark numbers are noted per entry), TDP, die
//! partitioning and process node. Operational energy follows the paper's
//! estimate `E = TDP / Performance`.

use crate::carbon::{ChipDesign, Die, FabGrid, MetricInputs, ProcessNode, YieldModel};

/// CPU vendor (fab-grid assumption follows the paper: US grid for Intel,
/// Taiwan for AMD compute dies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vendor {
    /// Intel (fabbed in US fabs).
    Intel,
    /// AMD (TSMC compute dies; GloFo/US-class IO dies).
    Amd,
}

/// One retrospective CPU entry.
#[derive(Debug, Clone)]
pub struct CpuSpec {
    /// Marketing name.
    pub name: &'static str,
    /// Vendor.
    pub vendor: Vendor,
    /// Release year.
    pub year: u32,
    /// Application-level performance score (CPUMark-style; higher better).
    pub score: f64,
    /// Thermal design power, W.
    pub tdp_w: f64,
    /// Compute dies: `(count, area_cm2, node)`.
    pub compute_dies: (u32, f64, ProcessNode),
    /// Optional IO die `(area_cm2, node)` for chiplet parts.
    pub io_die: Option<(f64, ProcessNode)>,
}

impl CpuSpec {
    /// Fab grid for the compute dies per the paper's assumption.
    pub fn fab_grid(&self) -> FabGrid {
        match self.vendor {
            Vendor::Intel => FabGrid::UnitedStates,
            Vendor::Amd => FabGrid::Taiwan,
        }
    }

    /// Die-level design with Murphy yield at each node's defect density.
    pub fn chip_design(&self) -> ChipDesign {
        let mut dies = Vec::new();
        let (n, area, node) = self.compute_dies;
        let y = YieldModel::Murphy { d0: node.params().defect_density_per_cm2 };
        for i in 0..n {
            dies.push(Die::new(&format!("{}-die{i}", self.name), area, node, y));
        }
        if let Some((io_area, io_node)) = self.io_die {
            let yi = YieldModel::Murphy { d0: io_node.params().defect_density_per_cm2 };
            dies.push(Die::new(&format!("{}-io", self.name), io_area, io_node, yi));
        }
        ChipDesign {
            name: self.name.to_string(),
            dies,
            fab_grid: self.fab_grid(),
            packaging_overhead: 0.0,
        }
    }

    /// Embodied carbon, gCO₂e.
    pub fn embodied_g(&self) -> f64 {
        self.chip_design().embodied_g()
    }

    /// Paper's operational-energy proxy `E = TDP / Performance`
    /// (arbitrary units, consistent across the comparison).
    pub fn energy_proxy(&self) -> f64 {
        self.tdp_w / self.score
    }

    /// Delay proxy `D = 1 / Performance`.
    pub fn delay_proxy(&self) -> f64 {
        1.0 / self.score
    }

    /// Metric inputs on a given use grid (operational carbon from the
    /// energy proxy — consistent relative comparison, as in Fig 2).
    pub fn metric_inputs(&self, use_ci_g_per_unit: f64) -> MetricInputs {
        MetricInputs {
            energy_j: self.energy_proxy(),
            delay_s: self.delay_proxy(),
            c_operational_g: use_ci_g_per_unit * self.energy_proxy(),
            c_embodied_g: self.embodied_g(),
        }
    }
}

/// The Fig 2(a) CPU set, oldest first. Die areas from public teardowns /
/// WikiChip; scores are CPUMark-style application-level values (chiplet
/// parts TLP-scaled: EPYC 7702's raw PassMark ≈ 71k, scaled to 40k for
/// the paper's ~32-thread application mix).
pub fn server_cpus() -> Vec<CpuSpec> {
    vec![
        CpuSpec {
            name: "E5-2670",
            vendor: Vendor::Intel,
            year: 2012,
            score: 9_800.0,
            tdp_w: 115.0,
            compute_dies: (1, 4.16, ProcessNode::N32),
            io_die: None,
        },
        CpuSpec {
            name: "E5-2680",
            vendor: Vendor::Intel,
            year: 2012,
            score: 10_700.0,
            tdp_w: 130.0,
            compute_dies: (1, 4.16, ProcessNode::N32),
            io_die: None,
        },
        CpuSpec {
            name: "E5-2699v4",
            vendor: Vendor::Intel,
            year: 2016,
            score: 22_000.0,
            tdp_w: 145.0,
            compute_dies: (1, 4.56, ProcessNode::N14),
            io_die: None,
        },
        CpuSpec {
            name: "EPYC-7351P",
            vendor: Vendor::Amd,
            year: 2017,
            score: 14_000.0,
            tdp_w: 155.0,
            // The paper treats the 7351P as the "larger monolithic die"
            // comparison point for the chiplet analysis.
            compute_dies: (1, 4.26, ProcessNode::N14),
            io_die: None,
        },
        CpuSpec {
            name: "Platinum-8280",
            vendor: Vendor::Intel,
            year: 2019,
            score: 30_000.0,
            tdp_w: 205.0,
            compute_dies: (1, 6.94, ProcessNode::N14),
            io_die: None,
        },
        CpuSpec {
            name: "E-2234",
            vendor: Vendor::Intel,
            year: 2019,
            score: 7_800.0,
            tdp_w: 71.0,
            compute_dies: (1, 2.00, ProcessNode::N14),
            io_die: None,
        },
        CpuSpec {
            name: "EPYC-7702",
            vendor: Vendor::Amd,
            year: 2019,
            score: 40_000.0,
            tdp_w: 200.0,
            compute_dies: (8, 0.74, ProcessNode::N7),
            io_die: Some((4.16, ProcessNode::N14)),
        },
        CpuSpec {
            name: "EPYC-7413",
            vendor: Vendor::Amd,
            year: 2021,
            score: 26_000.0,
            tdp_w: 180.0,
            compute_dies: (4, 0.81, ProcessNode::N7),
            io_die: Some((4.16, ProcessNode::N14)),
        },
        CpuSpec {
            name: "EPYC-7543",
            vendor: Vendor::Amd,
            year: 2021,
            score: 38_000.0,
            tdp_w: 225.0,
            compute_dies: (8, 0.81, ProcessNode::N7),
            io_die: Some((4.16, ProcessNode::N14)),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::carbon::metrics::argmin;

    fn by_name<'a>(cpus: &'a [CpuSpec], name: &str) -> &'a CpuSpec {
        cpus.iter().find(|c| c.name == name).unwrap()
    }

    #[test]
    fn edp_optimal_is_epyc_7702() {
        // Paper §2.1: "the EDP-optimal CPU—AMD EPYC 7702".
        let cpus = server_cpus();
        let edp: Vec<f64> = cpus.iter().map(|c| c.metric_inputs(1.0).metrics().edp).collect();
        assert_eq!(cpus[argmin(&edp).unwrap()].name, "EPYC-7702");
    }

    #[test]
    fn cdp_optimal_is_e5_2680() {
        // Paper §2.1: "The CDP-optimal CPU—Intel E5-2680".
        let cpus = server_cpus();
        let cdp: Vec<f64> = cpus.iter().map(|c| c.metric_inputs(1.0).metrics().cdp).collect();
        assert_eq!(cpus[argmin(&cdp).unwrap()].name, "E5-2680");
    }

    #[test]
    fn cep_optimal_is_e_2234() {
        // Paper §2.1: "Intel E-2234 CPU is CEP-optimal".
        let cpus = server_cpus();
        let cep: Vec<f64> = cpus.iter().map(|c| c.metric_inputs(1.0).metrics().cep).collect();
        assert_eq!(cpus[argmin(&cep).unwrap()].name, "E-2234");
    }

    #[test]
    fn chiplet_epyc_beats_monolithic_on_embodied_per_score() {
        // Fig 2a discussion: chiplet EPYCs amortize embodied carbon better
        // than the large-die 7351P.
        let cpus = server_cpus();
        let c7702 = by_name(&cpus, "EPYC-7702");
        let c7351 = by_name(&cpus, "EPYC-7351P");
        assert!(c7702.embodied_g() / c7702.score < c7351.embodied_g() / c7351.score);
    }

    #[test]
    fn newer_cpus_have_lower_energy_proxy() {
        // §2.1: "the latest released CPUs and SoCs exhibit higher
        // performance and lower operational energy."
        let cpus = server_cpus();
        let oldest = by_name(&cpus, "E5-2670");
        let newest = by_name(&cpus, "EPYC-7702");
        assert!(newest.energy_proxy() < oldest.energy_proxy() / 2.0);
    }

    #[test]
    fn embodied_values_are_plausible_kg_scale() {
        for c in server_cpus() {
            let kg = c.embodied_g() / 1000.0;
            assert!((0.5..60.0).contains(&kg), "{} embodied = {kg} kg", c.name);
        }
    }

    #[test]
    fn chip_designs_have_expected_die_counts() {
        let cpus = server_cpus();
        assert_eq!(by_name(&cpus, "EPYC-7702").chip_design().dies.len(), 9);
        assert_eq!(by_name(&cpus, "E5-2680").chip_design().dies.len(), 1);
    }
}
