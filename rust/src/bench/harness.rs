//! Timing harness: warmup then fixed-duration sampling.

use std::time::{Duration, Instant};

/// Statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Samples collected.
    pub samples: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Items/s if `throughput_items` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    /// One-line report.
    pub fn report(&self) -> String {
        let tp = self
            .throughput
            .map(|t| format!("  {:>12.0} items/s", t))
            .unwrap_or_default();
        format!(
            "bench {:40} mean {:>12?}  p50 {:>12?}  p95 {:>12?}  n={}{}",
            self.name, self.mean, self.p50, self.p95, self.samples, tp
        )
    }

    /// Machine-readable JSON object: `{name, samples, mean_ns, p50_ns,
    /// p95_ns, throughput}` (throughput in items/s, `null` when unset).
    pub fn to_json(&self) -> String {
        let tp = self
            .throughput
            .map(|t| format!("{t:.3}"))
            .unwrap_or_else(|| "null".to_string());
        format!(
            "{{\"name\":\"{}\",\"samples\":{},\"mean_ns\":{},\"p50_ns\":{},\"p95_ns\":{},\"throughput\":{}}}",
            self.name.replace('\\', "\\\\").replace('"', "\\\""),
            self.samples,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
            tp
        )
    }
}

/// Write a bench suite as a JSON array — the CI artifact format
/// (`BENCH_*.json`), one object per benchmark in run order.
pub fn write_json(results: &[BenchResult], path: &str) -> std::io::Result<()> {
    let rows: Vec<String> = results.iter().map(BenchResult::to_json).collect();
    std::fs::write(path, format!("[\n  {}\n]\n", rows.join(",\n  ")))
}

/// Builder-style bench runner.
pub struct Bencher {
    name: String,
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    throughput_items: Option<u64>,
}

impl Bencher {
    /// New bencher with defaults (0.3 s warmup, 1.5 s measurement).
    pub fn new(name: &str) -> Self {
        Bencher {
            name: name.to_string(),
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_samples: 2000,
            throughput_items: None,
        }
    }

    /// Set items-per-iteration for throughput reporting.
    pub fn throughput(mut self, items: u64) -> Self {
        self.throughput_items = Some(items);
        self
    }

    /// Shrink the measurement window (for slow end-to-end benches).
    pub fn quick(mut self) -> Self {
        self.warmup = Duration::from_millis(50);
        self.measure = Duration::from_millis(400);
        self
    }

    /// Apply [`Self::quick`] when `XRCARBON_BENCH_QUICK` is set in the
    /// environment — the short sampling mode CI runs benches under.
    pub fn quick_if_env(self) -> Self {
        if std::env::var_os("XRCARBON_BENCH_QUICK").is_some() {
            self.quick()
        } else {
            self
        }
    }

    /// Run the closure repeatedly and report stats. The closure's return
    /// value is black-boxed to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len().max(1);
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let p50 = samples.get(n / 2).copied().unwrap_or_default();
        let p95 = samples.get((n as f64 * 0.95) as usize % n).copied().unwrap_or_default();
        let throughput = self
            .throughput_items
            .map(|items| items as f64 / mean.as_secs_f64());
        BenchResult { name: self.name, samples: n, mean, p50, p95, throughput }
    }
}

/// Run a named closure benchmark, print its report line, return stats.
pub fn run<T, F: FnMut() -> T>(name: &str, f: F) -> BenchResult {
    let r = Bencher::new(name).run(f);
    println!("{}", r.report());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_orders_percentiles() {
        let r = Bencher::new("noop").quick().run(|| 1 + 1);
        assert!(r.samples > 10);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_computed() {
        let r = Bencher::new("tp").quick().throughput(1000).run(|| {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn report_contains_name() {
        let r = Bencher::new("my-bench").quick().run(|| ());
        assert!(r.report().contains("my-bench"));
    }

    #[test]
    fn json_is_parseable_and_complete() {
        let r = Bencher::new("json\"bench").quick().throughput(10).run(|| 1 + 1);
        let v = crate::configfmt::parse(&r.to_json()).expect("valid JSON");
        assert_eq!(
            v.get("name").and_then(crate::configfmt::Json::as_str),
            Some("json\"bench")
        );
        assert!(v.get("mean_ns").and_then(crate::configfmt::Json::as_i64).unwrap() > 0);
        assert!(v.get("p95_ns").is_some());
        assert!(v.get("throughput").is_some());
    }

    #[test]
    fn write_json_emits_an_array() {
        let a = Bencher::new("a").quick().run(|| ());
        let b = Bencher::new("b").quick().run(|| ());
        let dir = std::env::temp_dir().join("xrcarbon_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        write_json(&[a, b], path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::configfmt::parse(&text).expect("valid JSON");
        let arr = v.as_arr().expect("array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].get("name").and_then(crate::configfmt::Json::as_str), Some("b"));
    }
}
