//! Miniature benchmark harness (offline substitute for `criterion`).
//!
//! `rust/benches/*.rs` are `harness = false` binaries built on this:
//! warmup, timed sampling, robust statistics (mean/p50/p95), optional
//! throughput, a one-line-per-benchmark report compatible with
//! `cargo bench` output expectations, and a machine-readable JSON dump
//! ([`write_json`], the `BENCH_*.json` CI artifacts). Set
//! `XRCARBON_BENCH_QUICK=1` for the short sampling mode.

mod harness;

pub use harness::{run, write_json, BenchResult, Bencher};
