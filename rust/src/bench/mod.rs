//! Miniature benchmark harness (offline substitute for `criterion`).
//!
//! `rust/benches/*.rs` are `harness = false` binaries built on this:
//! warmup, timed sampling, robust statistics (mean/p50/p95), optional
//! throughput, and a one-line-per-benchmark report compatible with
//! `cargo bench` output expectations.

mod harness;

pub use harness::{run, BenchResult, Bencher};
