//! Experiment regenerators — one module per paper figure/table.
//!
//! Each module exposes a `run(...)` returning the figure's data (typed
//! rows usable by tests) plus a rendered [`crate::report::Table`]. The
//! CLI (`xrcarbon figN`) and the per-figure benches call the same entry
//! points; `rust/tests/experiments_e2e.rs` locks the paper's qualitative
//! claims.

pub mod common;
pub mod fig01_metric_comparison;
pub mod fig02_retrospective;
pub mod fig03_fleet_categories;
pub mod fig04_power_embodied;
pub mod fig07_dse_clusters;
pub mod fig08_tcdp_vs_edp;
pub mod fig09_accelerators;
pub mod fig10_lifetime_crossover;
pub mod fig11_provisioning_savings;
pub mod fig12_tlp_breakdown;
pub mod fig13_core_configs;
pub mod fig14_replacement;
pub mod fig15_stacking;
pub mod fig16_stacking_kernels;
pub mod search_fig7;
pub mod sweep_fig7;
pub mod table5_vr_soc;
pub mod trace_study;
