//! Fig 7: the headline DSE — carbon efficiency (tCDP) of tailor-designed
//! accelerators per workload cluster, under three embodied-to-total
//! carbon scenarios (98 % / 65 % / 25 %), best vs average with p5/p95.
//!
//! tCDP values are reported **per kernel** (divided by cluster size) so
//! clusters of different cardinality compare on carbon efficiency rather
//! than task size, then normalized to the All-cluster optimum (the
//! paper's normalization baseline).

use crate::carbon::FabGrid;
use crate::dse::{design_grid, explore, lifetime_for_ratio, profile_configs, profiles_to_rows};
use crate::report::Table;
use crate::runtime::Engine;
use crate::workloads::{cluster_workloads, Cluster};

use super::common::{default_use_grid, rows_request, suite_task};

/// One (scenario, cluster) cell of Fig 7.
#[derive(Debug, Clone)]
pub struct Fig07Cell {
    /// Cluster.
    pub cluster: Cluster,
    /// Best (tailor-designed optimum) per-kernel tCDP, normalized to All.
    pub best: f64,
    /// Average design's per-kernel tCDP, normalized to All.
    pub mean: f64,
    /// p5 / p95 normalized.
    pub p5: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Optimal design label.
    pub best_design: String,
}

/// One scenario panel (a Fig 7 sub-figure).
#[derive(Debug, Clone)]
pub struct Fig07Panel {
    /// Embodied-to-total ratio this panel was calibrated for.
    pub ratio: f64,
    /// Calibrated operational lifetime, s.
    pub lifetime_s: f64,
    /// Per-cluster cells (Fig 7 x-axis order).
    pub cells: Vec<Fig07Cell>,
}

/// Full Fig 7 output.
pub struct Fig07 {
    /// The three panels (98 %, 65 %, 25 %).
    pub panels: Vec<Fig07Panel>,
    /// Rendered table.
    pub table: Table,
}

/// The three embodied-carbon scenarios of the paper.
pub const RATIOS: [f64; 3] = [0.98, 0.65, 0.25];

/// Run the full exploration (121 configs × 5 clusters × 3 scenarios).
pub fn run(engine: &mut dyn Engine) -> crate::Result<Fig07> {
    let grid = design_grid();
    let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();
    let ci = default_use_grid().g_per_joule();

    // Profile each cluster's kernels once across the whole grid.
    let mut panels = Vec::new();
    let mut table = Table::new(
        "Fig 7 — per-kernel tCDP of tailor-designed accelerators (normalized to All optimum)",
        &["scenario", "cluster", "best", "mean", "p5", "p95", "optimal design"],
    );

    // All-cluster rows calibrate the scenario lifetimes.
    let all_workloads = cluster_workloads(Cluster::All);
    let all_profiles = profile_configs(&configs, &all_workloads);
    let all_rows = profiles_to_rows(&configs, &all_profiles, FabGrid::Coal);
    let all_tasks = suite_task(&all_workloads);

    for &ratio in &RATIOS {
        let lifetime_s = lifetime_for_ratio(&all_rows, &all_tasks, ratio, ci);
        let mut cells = Vec::new();
        let mut all_best_per_kernel = f64::NAN;
        for cluster in Cluster::ALL {
            let workloads = cluster_workloads(cluster);
            let rows = if cluster == Cluster::All {
                all_rows.clone()
            } else {
                let profiles = profile_configs(&configs, &workloads);
                profiles_to_rows(&configs, &profiles, FabGrid::Coal)
            };
            let req = rows_request(rows, &workloads, lifetime_s, 1.0);
            let out = explore(engine, &req)?;
            let kn = workloads.len() as f64;
            let best = out.stats.best / kn;
            if cluster == Cluster::All {
                all_best_per_kernel = best;
            }
            let norm = all_best_per_kernel;
            let best_idx = out.optimal["tCDP"];
            cells.push(Fig07Cell {
                cluster,
                best: best / norm,
                mean: out.stats.mean / kn / norm,
                p5: out.stats.p5 / kn / norm,
                p95: out.stats.p95 / kn / norm,
                best_design: out.result.names[best_idx].clone(),
            });
        }
        for c in &cells {
            table.row(&[
                format!("{:.0}% embodied", ratio * 100.0),
                c.cluster.label().to_string(),
                format!("{:.3}", c.best),
                format!("{:.3}", c.mean),
                format!("{:.3}", c.p5),
                format!("{:.3}", c.p95),
                c.best_design.clone(),
            ]);
        }
        panels.push(Fig07Panel { ratio, lifetime_s, cells });
    }
    Ok(Fig07 { panels, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    // The full 3×5×121 exploration is exercised in
    // rust/tests/experiments_e2e.rs and the fig7 bench; here we lock the
    // single-panel behaviour cheaply (98% scenario only).
    #[test]
    fn specialization_wins_when_embodied_dominates() {
        let mut ctx = Ctx::host();
        let f = run(ctx.engine.as_mut()).unwrap();
        assert_eq!(f.panels.len(), 3);
        let p98 = &f.panels[0];
        assert_eq!(p98.cells[0].best, 1.0, "All normalizes to itself");
        let ai5 = p98.cells.iter().find(|c| c.cluster == Cluster::Ai5).unwrap();
        // Paper: 5-AI tailor-designed is ~7.3x more carbon-efficient than
        // the All design (98% embodied). Require a clear win.
        assert!(ai5.best < 0.55, "5 AI best = {} (want < 0.55x of All)", ai5.best);
        // Best-vs-average headroom is large (paper: up to ~10x).
        assert!(ai5.mean / ai5.best > 2.0, "best-vs-mean = {}", ai5.mean / ai5.best);
    }

    #[test]
    fn lifetimes_grow_as_embodied_share_falls() {
        let mut ctx = Ctx::host();
        let f = run(ctx.engine.as_mut()).unwrap();
        assert!(f.panels[0].lifetime_s < f.panels[1].lifetime_s);
        assert!(f.panels[1].lifetime_s < f.panels[2].lifetime_s);
    }
}
