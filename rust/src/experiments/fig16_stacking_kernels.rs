//! Fig 16: per-XR-kernel carbon efficiency of the six 3-D configurations
//! normalized to the 2-D baseline, in the 98 % and 6 % embodied cases.

use crate::accel::stacking::{baseline_2d, stacked_configs};
use crate::accel::Workload;
use crate::carbon::FabGrid;
use crate::dse::{lifetime_for_ratio, profile_configs, profiles_to_rows};
use crate::matrixform::MetricRow;
use crate::report::Table;
use crate::runtime::Engine;

use super::common::{default_use_grid, rows_request, suite_task};

/// The XR kernels of the Fig 16 study.
pub const KERNELS: [Workload; 5] = [
    Workload::Hrn,
    Workload::Agg3d,
    Workload::Dn,
    Workload::Sr512,
    Workload::Sr1024,
];

/// The two scenarios.
pub const RATIOS: [f64; 2] = [0.98, 0.06];

/// One (scenario, kernel) result.
#[derive(Debug, Clone)]
pub struct Fig16Cell {
    /// Kernel.
    pub kernel: Workload,
    /// Embodied ratio.
    pub ratio: f64,
    /// Gains over 2D per config label (baseline first, gain 1.0).
    pub gains: Vec<(String, f64)>,
    /// Optimal config label.
    pub optimal: String,
}

/// Fig 16 output.
pub struct Fig16 {
    /// All cells.
    pub cells: Vec<Fig16Cell>,
    /// Rendered table.
    pub table: Table,
}

/// Run the per-kernel study.
pub fn run(engine: &mut dyn Engine) -> crate::Result<Fig16> {
    let mut configs = vec![baseline_2d()];
    configs.extend(stacked_configs().into_iter().map(|d| d.config));
    let ci = default_use_grid().g_per_joule();

    let mut cells = Vec::new();
    let mut table = Table::new(
        "Fig 16 — 3D vs 2D carbon efficiency per XR kernel (gain over 2D; * = optimal)",
        &["scenario", "kernel", "best config", "best gain"],
    );
    for &ratio in &RATIOS {
        for &kernel in &KERNELS {
            let workloads = [kernel];
            let profiles = profile_configs(&configs, &workloads);
            let rows = profiles_to_rows(&configs, &profiles, FabGrid::Coal);
            let tasks = suite_task(&workloads);
            let lifetime = lifetime_for_ratio(&rows[..1], &tasks, ratio, ci);
            let req = rows_request(rows, &workloads, lifetime, 1.0);
            let res = crate::dse::batching::evaluate_chunked(engine, &req)?;
            let base = res.metric(MetricRow::Tcdp, 0);
            let gains: Vec<(String, f64)> = (0..res.c)
                .map(|i| (res.names[i].clone(), base / res.metric(MetricRow::Tcdp, i)))
                .collect();
            let (optimal, best_gain) = gains
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(n, g)| (n.clone(), *g))
                .unwrap();
            table.row(&[
                format!("{:.0}% embodied", ratio * 100.0),
                kernel.label().to_string(),
                optimal.clone(),
                format!("{best_gain:.2}x"),
            ]);
            cells.push(Fig16Cell { kernel, ratio, gains, optimal });
        }
    }
    Ok(Fig16 { cells, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    fn fig16() -> Fig16 {
        run(Ctx::host().engine.as_mut()).unwrap()
    }

    fn cell<'a>(f: &'a Fig16, kernel: Workload, ratio: f64) -> &'a Fig16Cell {
        f.cells
            .iter()
            .find(|c| c.kernel == kernel && c.ratio == ratio)
            .unwrap()
    }

    #[test]
    fn embodied_case_keeps_2d_competitive() {
        // Paper: at 98% embodied the 2D baseline wins for some kernels
        // (HRN / 3D-Agg / SR-1024) — 3D gains are limited everywhere.
        let f = fig16();
        let wins_2d = KERNELS
            .iter()
            .filter(|&&k| cell(&f, k, 0.98).optimal.starts_with("2D"))
            .count();
        assert!(wins_2d >= 1, "expected 2D to win at least one kernel at 98%");
        for &k in &KERNELS {
            let best = cell(&f, k, 0.98).gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
            assert!(best < 4.0, "{}: 98% gain {best} suspiciously high", k.label());
        }
    }

    #[test]
    fn operational_case_shifts_to_3d() {
        // Paper: at 6% embodied, 3D reaps up to 7.9x; the optimum is a
        // stacked config for every kernel.
        let f = fig16();
        for &k in &KERNELS {
            let c = cell(&f, k, 0.06);
            assert!(c.optimal.starts_with("3D_"), "{}: optimal {}", k.label(), c.optimal);
        }
        let sr1024 = cell(&f, Workload::Sr1024, 0.06);
        let best = sr1024.gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
        assert!(best > 1.5, "SR-1024 @6%: best gain {best}");
    }

    #[test]
    fn memory_hungry_kernels_want_big_stacks() {
        // SR-1024's optimum at 6% embodied uses the largest stacked SRAM.
        let f = fig16();
        let c = cell(&f, Workload::Sr1024, 0.06);
        assert!(
            c.optimal.contains("16M") || c.optimal.contains("8M"),
            "SR-1024 optimal = {}",
            c.optimal
        );
    }
}
