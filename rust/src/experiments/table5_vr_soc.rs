//! Table 5: area and embodied-carbon estimates for the production VR SoC
//! CPU clusters (the 7 nm calibration anchor of the whole carbon model).

use crate::report::Table;
use crate::soc::VrSoc;

/// Table 5 output.
pub struct Table5 {
    /// Gold-cluster embodied carbon, g (paper: 895.89).
    pub gold_g: f64,
    /// Silver-cluster embodied carbon, g (paper: 447.94).
    pub silver_g: f64,
    /// Rendered table.
    pub table: Table,
}

/// Regenerate Table 5.
pub fn run() -> Table5 {
    let soc = VrSoc::default();
    let mut table = Table::new("Table 5 — VR SoC area and embodied carbon", &["parameter", "value"]);
    table.row(&["Total die area (cm2)".into(), format!("{:.2}", soc.die_cm2)]);
    table.row(&["CPU (cm2)".into(), format!("{:.2}", soc.cpu_cm2)]);
    table.row(&["CPU gold (cm2)".into(), format!("{:.2}", soc.cpu_cm2 * 2.0 / 3.0)]);
    table.row(&["CPU silver (cm2)".into(), format!("{:.2}", soc.cpu_cm2 / 3.0)]);
    table.row(&["CPU gold embodied (gCO2e)".into(), format!("{:.2}", soc.gold_cluster_g())]);
    table.row(&["CPU silver embodied (gCO2e)".into(), format!("{:.2}", soc.silver_cluster_g())]);
    Table5 { gold_g: soc.gold_cluster_g(), silver_g: soc.silver_cluster_g(), table }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_values() {
        let t = run();
        assert!((t.gold_g - 895.89).abs() < 0.5, "gold = {}", t.gold_g);
        assert!((t.silver_g - 447.94).abs() < 0.3, "silver = {}", t.silver_g);
        assert_eq!(t.table.len(), 6);
    }
}
