//! Fig 15: carbon efficiency of 3-D stacked accelerator configurations
//! versus the 2-D baseline for SR(512×512), under embodied-dominant
//! (80 %) and operational-dominant (6 %) scenarios.

use crate::accel::stacking::{baseline_2d, stacked_configs};
use crate::accel::Workload;
use crate::carbon::FabGrid;
use crate::dse::{lifetime_for_ratio, profile_configs, profiles_to_rows};
use crate::matrixform::MetricRow;
use crate::report::Table;
use crate::runtime::Engine;

use super::common::{default_use_grid, rows_request, suite_task};

/// One scenario's gains.
#[derive(Debug, Clone)]
pub struct Fig15Panel {
    /// Embodied-to-total ratio of the scenario.
    pub ratio: f64,
    /// `(config label, carbon-efficiency gain over 2D)` — gain =
    /// tCDP(2D)/tCDP(config).
    pub gains: Vec<(String, f64)>,
}

/// Fig 15 output.
pub struct Fig15 {
    /// Config labels (2D baseline first).
    pub labels: Vec<String>,
    /// The 80 % and 6 % panels.
    pub panels: Vec<Fig15Panel>,
    /// Rendered table.
    pub table: Table,
}

/// The paper's two Fig 15(b) scenarios.
pub const RATIOS: [f64; 2] = [0.80, 0.06];

/// Run Fig 15 on a single workload (SR-512 in the paper).
pub fn run(engine: &mut dyn Engine, workload: Workload) -> crate::Result<Fig15> {
    let mut configs = vec![baseline_2d()];
    configs.extend(stacked_configs().into_iter().map(|d| d.config));
    let labels: Vec<String> = configs.iter().map(|c| c.name.clone()).collect();

    let workloads = [workload];
    let profiles = profile_configs(&configs, &workloads);
    let rows = profiles_to_rows(&configs, &profiles, FabGrid::Coal);
    let ci = default_use_grid().g_per_joule();
    let tasks = suite_task(&workloads);

    let mut panels = Vec::new();
    let mut table = Table::new(
        &format!(
            "Fig 15 — 3D stacking carbon-efficiency gain over 2D baseline ({})",
            workload.label()
        ),
        &["config", "gain @80% emb", "gain @6% emb"],
    );
    for &ratio in &RATIOS {
        // Calibrate the scenario on the 2-D baseline row.
        let lifetime = lifetime_for_ratio(&rows[..1], &tasks, ratio, ci);
        let req = rows_request(rows.clone(), &workloads, lifetime, 1.0);
        let res = crate::dse::batching::evaluate_chunked(engine, &req)?;
        let base_tcdp = res.metric(MetricRow::Tcdp, 0);
        let gains: Vec<(String, f64)> = (0..res.c)
            .map(|i| (res.names[i].clone(), base_tcdp / res.metric(MetricRow::Tcdp, i)))
            .collect();
        panels.push(Fig15Panel { ratio, gains });
    }
    for (i, label) in labels.iter().enumerate() {
        table.row(&[
            label.clone(),
            format!("{:.2}x", panels[0].gains[i].1),
            format!("{:.2}x", panels[1].gains[i].1),
        ]);
    }
    Ok(Fig15 { labels, panels, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    fn fig15() -> Fig15 {
        run(Ctx::host().engine.as_mut(), Workload::Sr512).unwrap()
    }

    #[test]
    fn operational_dominance_favors_3d_strongly() {
        // Paper: up to 6.9x for SR-512 in the 6% embodied case.
        let f = fig15();
        let op_panel = &f.panels[1];
        let best = op_panel.gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
        assert!(best > 1.8, "best 3D gain @6% = {best}x");
        // The best design is a stacked one.
        let (name, _) = op_panel
            .gains
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        assert!(name.starts_with("3D_"), "best design = {name}");
    }

    #[test]
    fn embodied_dominance_tempers_the_gains() {
        // Paper: 1.08–1.8x in the 80% embodied case — much smaller than
        // the operational-dominant gains.
        let f = fig15();
        let emb_best = f.panels[0].gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
        let op_best = f.panels[1].gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
        assert!(op_best > emb_best, "op {op_best} !> emb {emb_best}");
    }

    #[test]
    fn baseline_gain_is_one() {
        let f = fig15();
        for p in &f.panels {
            assert!((p.gains[0].1 - 1.0).abs() < 1e-9);
        }
        assert_eq!(f.labels.len(), 7);
    }
}
