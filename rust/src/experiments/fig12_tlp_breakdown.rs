//! Fig 12: thread-level-parallelism time breakdown for the four profiled
//! VR applications on the octa-core CPU.

use crate::report::Table;
use crate::workloads::apps::fig12_apps;
use crate::workloads::{generate_fleet, FleetConfig};

/// Fig 12 output.
pub struct Fig12 {
    /// `(app, model TLP, fleet-observed TLP, busy-core time fractions)`.
    pub rows: Vec<(String, f64, f64, [f64; 9])>,
    /// Average TLP across the four apps.
    pub avg_tlp: f64,
    /// Rendered table.
    pub table: Table,
}

/// Run Fig 12: per-app model distributions cross-checked against the
/// synthetic fleet's observed TLP.
pub fn run(cfg: &FleetConfig) -> Fig12 {
    let fleet = generate_fleet(cfg);
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 12 — TLP time breakdown (octa-core; fractions of wall time)",
        &["app", "TLP", "fleet TLP", "0", "1-2", "3-4", "5-6", "7-8"],
    );
    let mut tlp_sum = 0.0;
    for app in fig12_apps() {
        let observed = fleet
            .apps
            .iter()
            .find(|a| a.name == app.name)
            .map(|a| a.tlp.average())
            .unwrap_or(f64::NAN);
        let f = app.tlp.frac;
        let buckets = [f[0], f[1] + f[2], f[3] + f[4], f[5] + f[6], f[7] + f[8]];
        table.row(&[
            app.name.to_string(),
            format!("{:.2}", app.tlp.average()),
            format!("{observed:.2}"),
            format!("{:.2}", buckets[0]),
            format!("{:.2}", buckets[1]),
            format!("{:.2}", buckets[2]),
            format!("{:.2}", buckets[3]),
            format!("{:.2}", buckets[4]),
        ]);
        tlp_sum += app.tlp.average();
        rows.push((app.name.to_string(), app.tlp.average(), observed, f));
    }
    let avg_tlp = tlp_sum / rows.len() as f64;
    Fig12 { rows, avg_tlp, table }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig12() -> Fig12 {
        run(&FleetConfig { devices: 150, days: 10, ..Default::default() })
    }

    #[test]
    fn tlp_range_matches_paper() {
        // Paper: "TLP ranges from 3.52 to 4.15 ... 3.9 average TLP."
        let f = fig12();
        for (name, tlp, _, _) in &f.rows {
            assert!((3.4..4.3).contains(tlp), "{name}: TLP = {tlp}");
        }
        assert!((3.7..4.1).contains(&f.avg_tlp), "avg = {}", f.avg_tlp);
    }

    #[test]
    fn fleet_observation_tracks_model() {
        let f = fig12();
        for (name, model, observed, _) in &f.rows {
            assert!(
                (model - observed).abs() < 0.4,
                "{name}: model {model} vs fleet {observed}"
            );
        }
    }

    #[test]
    fn fractions_sum_to_one() {
        let f = fig12();
        for (name, _, _, frac) in &f.rows {
            let s: f64 = frac.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{name}: fractions sum {s}");
        }
    }
}
