//! Fig 1: the metric-choice problem — EDP/CDP vs CEP/CE²P/C²EP pick
//! different accelerators among the four production designs.

use crate::accel::{production_accelerators, Workload};
use crate::carbon::MetricKind;
use crate::dse::explore;
use crate::report::Table;

use super::common::{whole_life_request, Ctx};

/// Fig 1 data: per metric, the optimal accelerator and the normalized
/// per-accelerator values.
pub struct Fig01 {
    /// Accelerator names (A-1..A-4).
    pub names: Vec<String>,
    /// `(metric label, normalized values, optimal index)`.
    pub metrics: Vec<(&'static str, Vec<f64>, usize)>,
    /// Rendered table.
    pub table: Table,
}

/// Run Fig 1 over the full Table 3 kernel suite at a one-million-inference
/// operational life.
pub fn run(ctx: &mut Ctx) -> crate::Result<Fig01> {
    let configs = production_accelerators().to_vec();
    let req = whole_life_request(&configs, &Workload::ALL, 1e6);
    let out = explore(ctx.engine.as_mut(), &req)?;

    let names: Vec<String> = out.result.names.clone();
    let mut table = Table::new(
        "Fig 1 — accelerator ranking per figure-of-merit (normalized to best; * = optimal)",
        &["metric", &names[0], &names[1], &names[2], &names[3]],
    );
    let mut metrics = Vec::new();
    for kind in MetricKind::ALL {
        let row = out.result.row(crate::dse::explore::metric_row(kind)).to_vec();
        let best_idx = out.optimal[kind.label()];
        let best = row[best_idx];
        let norm: Vec<f64> = row.iter().map(|v| v / best).collect();
        let mut cells = vec![kind.label().to_string()];
        for (i, v) in norm.iter().enumerate() {
            let star = if i == best_idx { "*" } else { "" };
            cells.push(format!("{v:.2}{star}"));
        }
        table.row(&cells);
        metrics.push((kind.label(), norm, best_idx));
    }
    Ok(Fig01 { names, metrics, table })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimal_name(f: &Fig01, metric: &str) -> String {
        let (_, _, idx) = f.metrics.iter().find(|(m, _, _)| *m == metric).unwrap();
        f.names[*idx].clone()
    }

    #[test]
    fn fig1_optima_match_paper() {
        // Paper: "Accelerator A-2 is EDP and CDP optimal; A-1 is CEP,
        // CE2P, and C2EP optimal."
        let f = run(&mut Ctx::host()).unwrap();
        assert_eq!(optimal_name(&f, "EDP"), "A-2");
        assert_eq!(optimal_name(&f, "CDP"), "A-2");
        assert_eq!(optimal_name(&f, "CEP"), "A-1");
        assert_eq!(optimal_name(&f, "CE2P"), "A-1");
        assert_eq!(optimal_name(&f, "C2EP"), "A-1");
    }

    #[test]
    fn table_has_six_metric_rows() {
        let f = run(&mut Ctx::host()).unwrap();
        assert_eq!(f.metrics.len(), 6);
        assert_eq!(f.table.len(), 6);
    }
}
