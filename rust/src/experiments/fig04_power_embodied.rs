//! Fig 4: per-app power vs TDP (p5/p95 bars) and the utilized/unused
//! embodied-carbon split driven by hardware utilization.

use crate::report::Table;
use crate::soc::VrSoc;
use crate::workloads::{generate_fleet, FleetConfig};

/// Per-app Fig 4 row.
pub struct Fig04Row {
    /// App name.
    pub name: String,
    /// Power as fraction of TDP: (p5, mean, p95).
    pub power_frac: (f64, f64, f64),
    /// CPU+GPU embodied carbon attributed as used, g.
    pub utilized_g: f64,
    /// Embodied carbon idle/over-provisioned, g.
    pub unused_g: f64,
}

/// Fig 4 output.
pub struct Fig04 {
    /// Top-10 rows.
    pub rows: Vec<Fig04Row>,
    /// Mean unused share across the top 10.
    pub mean_unused_share: f64,
    /// Rendered table.
    pub table: Table,
}

/// Run Fig 4 from the fleet trace and the Table 5 SoC.
pub fn run(cfg: &FleetConfig, soc: &VrSoc) -> Fig04 {
    let fleet = generate_fleet(cfg);
    let cpu_g = soc.gold_cluster_g() + soc.silver_cluster_g();
    let gpu_g = soc.gpu_g();

    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 4 — top-10 app power (fraction of TDP) and embodied split",
        &["app", "p5", "mean", "p95", "utilized g", "unused g", "unused %"],
    );
    let mut unused_acc = 0.0;
    for a in fleet.apps.iter().take(10) {
        // Utilization: CPU busy-core share; GPU busy fraction (Fig 4's
        // "active time of the hardware over the total application runtime").
        let cpu_util = a.tlp.mean_busy_cores() / 8.0;
        let utilized = cpu_g * cpu_util + gpu_g * a.gpu_util;
        let total = cpu_g + gpu_g;
        let unused = total - utilized;
        unused_acc += unused / total;
        table.row(&[
            a.name.clone(),
            format!("{:.2}", a.power_frac.0),
            format!("{:.2}", a.power_frac.1),
            format!("{:.2}", a.power_frac.2),
            format!("{utilized:.0}"),
            format!("{unused:.0}"),
            format!("{:.0}%", unused / total * 100.0),
        ]);
        rows.push(Fig04Row {
            name: a.name.clone(),
            power_frac: a.power_frac,
            utilized_g: utilized,
            unused_g: unused,
        });
    }
    let mean_unused_share = unused_acc / rows.len() as f64;
    Fig04 { rows, mean_unused_share, table }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig4() -> Fig04 {
        run(&FleetConfig { devices: 150, days: 10, ..Default::default() }, &VrSoc::default())
    }

    #[test]
    fn unused_embodied_exceeds_half() {
        // Paper §1/§2.2: "over 60%" unused embodied carbon.
        let f = fig4();
        assert!(
            f.mean_unused_share > 0.5,
            "mean unused share = {}",
            f.mean_unused_share
        );
    }

    #[test]
    fn power_near_70pct_tdp() {
        let f = fig4();
        let mean: f64 = f.rows.iter().map(|r| r.power_frac.1).sum::<f64>() / f.rows.len() as f64;
        assert!((0.6..0.8).contains(&mean), "mean power frac = {mean}");
        for r in &f.rows {
            assert!(r.power_frac.0 <= r.power_frac.1 && r.power_frac.1 <= r.power_frac.2);
        }
    }

    #[test]
    fn split_sums_to_cpu_plus_gpu() {
        let f = fig4();
        let soc = VrSoc::default();
        let total = soc.gold_cluster_g() + soc.silver_cluster_g() + soc.gpu_g();
        for r in &f.rows {
            assert!((r.utilized_g + r.unused_g - total).abs() < 1e-6);
        }
    }
}
