//! Fig 7 as a single [`ScenarioGrid`] run: the three embodied-share
//! scenarios over the 121-point MAC×SRAM grid for one workload cluster,
//! evaluated by the parallel sweep coordinator ([`crate::dse::sweep`])
//! with one engine per worker thread.
//!
//! `fig07_dse_clusters` remains the faithful per-panel reproduction; this
//! entry is the scaling substrate — the same numbers for one cluster,
//! produced by the (scenario × config-chunk) fan-out path.

use std::path::Path;

use crate::carbon::FabGrid;
use crate::dse::cache::ProfileCache;
use crate::dse::grid::ScenarioGrid;
use crate::dse::sweep::{
    sweep_fused, sweep_resumable, sweep_with_cache, SweepCheckpoint, SweepConfig, SweepOutcome,
};
use crate::dse::{design_grid, profile_configs, profiles_to_rows};
use crate::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
use crate::report::{sweep_table, Table};
use crate::runtime::EngineFactory;
use crate::workloads::{cluster_workloads, Cluster};

use super::common::{default_use_grid, rows_request, suite_task};

/// A profiled cluster design space ready for scenario sweeps.
pub struct ClusterSpace {
    /// Profiled §3.3 rows for the 121-point grid.
    pub rows: Vec<ConfigRow>,
    /// The cluster's suite task matrix.
    pub tasks: TaskMatrix,
    /// Base request (lifetime placeholder — scenarios override it).
    pub base: EvalRequest,
    /// Use-phase carbon intensity, g/J.
    pub ci_use_g_per_j: f64,
}

/// Profile the 121-point grid on a cluster's kernels and assemble the
/// base request scenario sweeps rewrite.
pub fn profile_cluster(cluster: Cluster) -> ClusterSpace {
    let grid = design_grid();
    let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();
    let workloads = cluster_workloads(cluster);
    let profiles = profile_configs(&configs, &workloads);
    let rows = profiles_to_rows(&configs, &profiles, FabGrid::Coal);
    let tasks = suite_task(&workloads);
    let ci = default_use_grid().g_per_joule();
    // Lifetime 1.0 is a placeholder: every preset scenario overrides it.
    let base = rows_request(rows.clone(), &workloads, 1.0, 1.0);
    ClusterSpace { rows, tasks, base, ci_use_g_per_j: ci }
}

/// Full sweep output.
pub struct SweepFig7 {
    /// Cluster the space was profiled on.
    pub cluster: Cluster,
    /// The aggregated sweep outcome (scenarios in 98 %→25 % order).
    pub outcome: SweepOutcome,
    /// Rendered per-scenario table.
    pub table: Table,
}

/// Run the Fig 7 sweep for one cluster on `threads` workers (0 = auto).
/// Two-phase: the 121-config space is profiled once, the three
/// embodied-share scenarios are cheap overlays over the cached profile.
pub fn run(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    threads: usize,
) -> crate::Result<SweepFig7> {
    run_cached(factory, cluster, threads, None)
}

/// Warm-start variant of [`run`]: phase A consults a persistent
/// [`ProfileCache`] before touching the engine. On a warm cache the
/// sweep performs **zero** engine contractions and is bit-identical to
/// the cold run; the outcome's `cache` field (and the rendered table
/// title) carry the hit/miss proof.
pub fn run_cached(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    threads: usize,
    cache: Option<&ProfileCache>,
) -> crate::Result<SweepFig7> {
    run_resumable(factory, cluster, threads, cache, None, None)
}

/// [`run_cached`] with sweep-phase checkpoint/resume plumbing: when a
/// cache is in play, phase-A progress is checkpointed to `save_to` after
/// every step and `resume_from` continues an interrupted run
/// bit-identically (completed chunks come back from the cache). Without
/// a cache the checkpoint options are rejected — per-chunk resume is
/// meaningless if the profiles were never persisted.
pub fn run_resumable(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    threads: usize,
    cache: Option<&ProfileCache>,
    resume_from: Option<&SweepCheckpoint>,
    save_to: Option<&Path>,
) -> crate::Result<SweepFig7> {
    let space = profile_cluster(cluster);
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
    let cfg = SweepConfig { threads };
    let outcome = match cache {
        Some(cache) => {
            sweep_resumable(factory, &space.base, &grid, &cfg, cache, resume_from, save_to)?
        }
        None => {
            if resume_from.is_some() || save_to.is_some() {
                anyhow::bail!("sweep checkpoint/resume requires a profile cache (--cache-dir)");
            }
            sweep_with_cache(factory, &space.base, &grid, &cfg, None)?
        }
    };
    let mut table = sweep_table(&outcome);
    table.title = format!("Fig 7 sweep [{}] — {}", cluster.label(), table.title);
    Ok(SweepFig7 { cluster, outcome, table })
}

/// PR 1-style fused reference run: the engine re-contracts the space once
/// per scenario. Same numbers as [`run`] bit-for-bit; kept for the
/// fused-vs-two-phase benchmark and as an equality oracle in tests.
pub fn run_fused(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    threads: usize,
) -> crate::Result<SweepFig7> {
    let space = profile_cluster(cluster);
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
    let outcome = sweep_fused(factory, &space.base, &grid, &SweepConfig { threads })?;
    let mut table = sweep_table(&outcome);
    table.title = format!("Fig 7 sweep (fused) [{}] — {}", cluster.label(), table.title);
    Ok(SweepFig7 { cluster, outcome, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::sweep::sweep;
    use crate::dse::sweep_sequential;
    use crate::runtime::{HostEngine, HostEngineFactory};

    #[test]
    fn sweep_reproduces_fig7_scenarios_for_one_cluster() {
        let f = run(&HostEngineFactory, Cluster::Ai5, 4).unwrap();
        assert_eq!(f.outcome.scenarios.len(), 3);
        for s in &f.outcome.scenarios {
            // Unconstrained space: all 121 designs feasible everywhere.
            assert_eq!(s.outcome.stats.feasible, 121);
            assert!(s.outcome.stats.best > 0.0 && s.outcome.stats.best.is_finite());
        }
        // 98% embodied (shortest lifetime) is the costliest scenario.
        let best: Vec<f64> = f.outcome.scenarios.iter().map(|s| s.outcome.stats.best).collect();
        assert!(best[0] > best[1] && best[1] > best[2], "best tCDP not ordered: {best:?}");
        assert_eq!(f.table.len(), 3);
    }

    #[test]
    fn warm_cached_fig7_sweep_is_bit_identical_with_zero_contractions() {
        let dir = crate::testkit::test_dir("fig7_cache");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ProfileCache::open(&dir).unwrap();

        let plain = run(&HostEngineFactory, Cluster::Ai5, 2).unwrap();
        let cold = run_cached(&HostEngineFactory, Cluster::Ai5, 2, Some(&cache)).unwrap();
        let warm = run_cached(&HostEngineFactory, Cluster::Ai5, 2, Some(&cache)).unwrap();
        for (a, b) in [(&plain, &cold), (&cold, &warm)] {
            for (x, y) in a.outcome.scenarios.iter().zip(&b.outcome.scenarios) {
                assert_eq!(x.label, y.label);
                assert_eq!(x.outcome.result.metrics, y.outcome.result.metrics);
                assert_eq!(x.outcome.optimal, y.outcome.optimal);
            }
        }
        // 121 configs = one chunk: cold misses it once, warm avoids the
        // contraction entirely.
        let cs = cold.outcome.cache.unwrap();
        assert_eq!((cs.hits, cs.misses, cs.writes), (0, 1, 1));
        let ws = warm.outcome.cache.unwrap();
        assert_eq!((ws.hits, ws.misses), (1, 0));
        assert_eq!(ws.contractions_avoided(), warm.outcome.profile_chunks);
        assert!(warm.table.title.contains("1 contraction(s) avoided"), "{}", warm.table.title);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resumable_fig7_sweep_checkpoints_and_reproduces() {
        let dir = crate::testkit::test_dir("fig7_resume");
        std::fs::remove_dir_all(&dir).ok();
        let cache = ProfileCache::open(&dir).unwrap();
        let ckpt = dir.join("sweep_fig7.ckpt.json");

        let plain = run(&HostEngineFactory, Cluster::Ai5, 2).unwrap();
        let saved = run_resumable(
            &HostEngineFactory,
            Cluster::Ai5,
            2,
            Some(&cache),
            None,
            Some(ckpt.as_path()),
        )
        .unwrap();
        for (a, b) in plain.outcome.scenarios.iter().zip(&saved.outcome.scenarios) {
            assert_eq!(a.outcome.result.metrics, b.outcome.result.metrics);
        }
        let ck = crate::dse::read_sweep_checkpoint(&ckpt).unwrap();
        assert_eq!((ck.chunks_done, ck.total_chunks), (1, 1));
        let resumed = run_resumable(
            &HostEngineFactory,
            Cluster::Ai5,
            2,
            Some(&cache),
            Some(&ck),
            Some(ckpt.as_path()),
        )
        .unwrap();
        for (a, b) in plain.outcome.scenarios.iter().zip(&resumed.outcome.scenarios) {
            assert_eq!(a.outcome.result.metrics, b.outcome.result.metrics);
        }
        assert_eq!(resumed.outcome.cache.unwrap().misses, 0);
        // Checkpoints without a cache are rejected, not silently dropped.
        assert!(run_resumable(&HostEngineFactory, Cluster::Ai5, 2, None, Some(&ck), None)
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn two_phase_fig7_matches_fused_reference() {
        // Profile-once + overlays equals the per-scenario fused fan-out
        // bit-for-bit on the real profiled design space.
        let two = run(&HostEngineFactory, Cluster::Ai5, 2).unwrap();
        let fused = run_fused(&HostEngineFactory, Cluster::Ai5, 2).unwrap();
        assert_eq!(two.outcome.scenarios.len(), fused.outcome.scenarios.len());
        for (a, b) in two.outcome.scenarios.iter().zip(&fused.outcome.scenarios) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.outcome.result.metrics, b.outcome.result.metrics);
            assert_eq!(a.outcome.result.d_task, b.outcome.result.d_task);
            assert_eq!(a.outcome.optimal, b.outcome.optimal);
        }
        // The whole point: one engine pass instead of one per scenario.
        assert_eq!(two.outcome.profile_chunks, 1);
        assert_eq!(fused.outcome.items, 3);
    }

    #[test]
    fn parallel_fig7_sweep_matches_sequential() {
        let space = profile_cluster(Cluster::Xr5);
        let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
        let par =
            sweep(&HostEngineFactory, &space.base, &grid, &SweepConfig { threads: 4 }).unwrap();
        let seq = sweep_sequential(&mut HostEngine::new(), &space.base, &grid).unwrap();
        for (a, b) in par.scenarios.iter().zip(&seq.scenarios) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.outcome.result.metrics, b.outcome.result.metrics);
            assert_eq!(a.outcome.optimal, b.outcome.optimal);
        }
    }
}
