//! Fig 11: embodied and total life-cycle carbon savings from provisioning
//! the VR CPU's core count per application (paper: ≤ 50 % embodied
//! savings, ≈ 33 % average; ≈ 12.5 % average total, ≤ 21 %).

use crate::matrixform::MetricRow;
use crate::report::Table;
use crate::runtime::Engine;
use crate::soc::VrSoc;
use crate::workloads::apps::top10_apps;

use super::common::provisioning_request;
use super::fig13_core_configs::vr_operational_lifetime_s;

/// One Fig 11 bar pair.
#[derive(Debug, Clone)]
pub struct Fig11Row {
    /// App name.
    pub app: String,
    /// Optimal core count (QoS-preserving).
    pub cores: usize,
    /// CPU embodied-carbon saving vs the 8-core configuration (0..1).
    pub embodied_saving: f64,
    /// Total life-cycle carbon saving vs 8-core (0..1).
    pub total_saving: f64,
}

/// Fig 11 output.
pub struct Fig11 {
    /// Per-app rows.
    pub rows: Vec<Fig11Row>,
    /// Mean embodied saving.
    pub mean_embodied_saving: f64,
    /// Mean total saving.
    pub mean_total_saving: f64,
    /// Rendered table.
    pub table: Table,
}

/// Run Fig 11 over the top-10 apps.
pub fn run(engine: &mut dyn Engine) -> crate::Result<Fig11> {
    let soc = VrSoc::default();
    let lifetime_s = vr_operational_lifetime_s();
    let full_cpu = soc.provisioned_cpu_g(4, 4);

    let mut rows = Vec::new();
    for app in top10_apps() {
        let apps = vec![app.clone()];
        let req = provisioning_request(&apps, &soc, lifetime_s, true);
        let res = crate::runtime::evaluate(engine, &req)?;
        let idx = res
            .argmin_feasible(MetricRow::Tcdp)
            .ok_or_else(|| anyhow::anyhow!("{}: infeasible", app.name))?;
        let cores = idx + 2;
        let (gold, silver) = VrSoc::split_cores(cores);
        let provisioned_cpu = soc.provisioned_cpu_g(gold, silver);
        let embodied_saving = 1.0 - provisioned_cpu / full_cpu;
        // Total life-cycle carbon: compare the whole-device carbon of the
        // provisioned optimum vs the 8-core config for this app's window.
        let total_opt = res.metric(MetricRow::CTotal, idx);
        let total_full = res.metric(MetricRow::CTotal, res.c - 1); // 8-core row
        let total_saving = 1.0 - total_opt / total_full;
        rows.push(Fig11Row { app: app.name.to_string(), cores, embodied_saving, total_saving });
    }

    let mean_embodied_saving =
        rows.iter().map(|r| r.embodied_saving).sum::<f64>() / rows.len() as f64;
    let mean_total_saving = rows.iter().map(|r| r.total_saving).sum::<f64>() / rows.len() as f64;

    let mut table = Table::new(
        "Fig 11 — carbon savings from CPU core provisioning (vs 8-core)",
        &["app", "cores", "embodied saving", "total saving"],
    );
    for r in &rows {
        table.row(&[
            r.app.clone(),
            r.cores.to_string(),
            format!("{:.0}%", r.embodied_saving * 100.0),
            format!("{:.1}%", r.total_saving * 100.0),
        ]);
    }
    table.row(&[
        "average".into(),
        "-".into(),
        format!("{:.0}%", mean_embodied_saving * 100.0),
        format!("{:.1}%", mean_total_saving * 100.0),
    ]);

    Ok(Fig11 { rows, mean_embodied_saving, mean_total_saving, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    fn fig11() -> Fig11 {
        run(Ctx::host().engine.as_mut()).unwrap()
    }

    #[test]
    fn embodied_savings_match_paper_band() {
        // Paper: up to 50% embodied savings, average ≈ 33%.
        let f = fig11();
        let max = f.rows.iter().map(|r| r.embodied_saving).fold(0.0f64, f64::max);
        assert!((0.4..0.75).contains(&max), "max embodied saving = {max}");
        assert!(
            (0.2..0.5).contains(&f.mean_embodied_saving),
            "mean embodied saving = {}",
            f.mean_embodied_saving
        );
    }

    #[test]
    fn total_savings_match_paper_band() {
        // Paper: average ≈ 12.5% total life-cycle improvement, ≤ 21%.
        let f = fig11();
        assert!(
            (0.03..0.25).contains(&f.mean_total_saving),
            "mean total saving = {}",
            f.mean_total_saving
        );
        let max = f.rows.iter().map(|r| r.total_saving).fold(0.0f64, f64::max);
        assert!(max < 0.35, "max total saving = {max}");
    }

    #[test]
    fn savings_never_negative() {
        let f = fig11();
        for r in &f.rows {
            assert!(r.embodied_saving >= 0.0, "{}: {}", r.app, r.embodied_saving);
            assert!(r.total_saving >= -1e-9, "{}: {}", r.app, r.total_saving);
        }
    }
}
