//! Shared experiment plumbing: engine construction, cluster requests,
//! profiled design spaces and VR-provisioning requests.

use crate::accel::{AcceleratorConfig, Workload};
use crate::carbon::{FabGrid, UseGrid};
use crate::dse::{profile_configs, profiles_to_rows};
use crate::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
use crate::runtime::{auto_engine, Engine, HostEngine};
use crate::soc::VrSoc;
use crate::workloads::apps::{VrApp, QOS_FPS};

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Engine + provenance label.
pub struct Ctx {
    /// The evaluation engine.
    pub engine: Box<dyn Engine>,
    /// "pjrt" or "host".
    pub backend: &'static str,
}

impl Ctx {
    /// PJRT when artifacts exist, host otherwise.
    pub fn auto() -> Ctx {
        let (engine, backend) = auto_engine(ARTIFACTS_DIR);
        Ctx { engine, backend }
    }

    /// Force the host mirror (unit tests).
    pub fn host() -> Ctx {
        Ctx { engine: Box::new(HostEngine::new()), backend: "host" }
    }
}

/// Default use-phase grid for the XR studies.
pub fn default_use_grid() -> UseGrid {
    UseGrid::WorldAverage
}

/// Single-task request skeleton over a kernel set: one "suite" task
/// invoking every kernel once (per-kernel weighting is a knob, not needed
/// for the cluster studies).
pub fn suite_task(workloads: &[Workload]) -> TaskMatrix {
    let kernels: Vec<String> = workloads.iter().map(|w| w.label().to_string()).collect();
    let calls = vec![1.0; kernels.len()];
    TaskMatrix::single_task("suite", kernels, &calls)
}

/// Profile `configs` on `workloads` and assemble an [`EvalRequest`].
pub fn profiled_request(
    configs: &[AcceleratorConfig],
    workloads: &[Workload],
    lifetime_s: f64,
    beta: f64,
) -> EvalRequest {
    let profiles = profile_configs(configs, workloads);
    let rows = profiles_to_rows(configs, &profiles, FabGrid::Coal);
    rows_request(rows, workloads, lifetime_s, beta)
}

/// Assemble a request from pre-built rows.
pub fn rows_request(
    rows: Vec<ConfigRow>,
    workloads: &[Workload],
    lifetime_s: f64,
    beta: f64,
) -> EvalRequest {
    EvalRequest {
        tasks: suite_task(workloads),
        configs: rows,
        online: vec![1.0, 1.0, 1.0],
        qos: vec![f64::INFINITY],
        ci_use_g_per_j: default_use_grid().g_per_joule(),
        lifetime_s,
        beta,
        p_max_w: f64::INFINITY,
    }
}

/// Whole-operational-life formulation (Fig 10): the task is the device's
/// entire service (`n_inf` runs of the suite); `c_comp` is rescaled per
/// config so the amortized embodied carbon equals the full embodied
/// carbon (`L = 1`, `c_comp = emb / D_total`).
pub fn whole_life_request(
    configs: &[AcceleratorConfig],
    workloads: &[Workload],
    n_inf: f64,
) -> EvalRequest {
    let profiles = profile_configs(configs, workloads);
    let mut rows = profiles_to_rows(configs, &profiles, FabGrid::Coal);
    let kernels = workloads.len();
    let tasks = TaskMatrix::single_task(
        "life",
        workloads.iter().map(|w| w.label().to_string()).collect(),
        &vec![n_inf; kernels],
    );
    for row in &mut rows {
        let suite_delay: f64 = row.d_k.iter().sum::<f64>() * n_inf;
        let emb: f64 = row.c_comp.iter().sum();
        row.c_comp = vec![emb / suite_delay, 0.0, 0.0];
    }
    EvalRequest {
        tasks,
        configs: rows,
        online: vec![1.0, 1.0, 1.0],
        qos: vec![f64::INFINITY],
        ci_use_g_per_j: default_use_grid().g_per_joule(),
        lifetime_s: 1.0,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

/// VR provisioning model (Figs 11/13): how an app behaves on a given
/// enabled-core count.
pub struct AppOnCores {
    /// Seconds per frame.
    pub frame_delay_s: f64,
    /// Joules per frame.
    pub frame_energy_j: f64,
}

/// CPU share of an app's total power draw (the rest is GPU + display +
/// uncore, which provisioning does not change).
pub const CPU_POWER_SHARE: f64 = 0.4;

/// Evaluate the scheduling model for `app` with `cores` enabled on `soc`.
pub fn app_on_cores(app: &VrApp, soc: &VrSoc, cores: usize) -> AppOnCores {
    let slow = app.tlp.slowdown(cores);
    let fps = app.fps_all_cores / slow;
    let p8 = app.power_frac_mean * soc.tdp_w;
    let busy8: f64 = app.tlp.mean_busy_cores();
    let busy_c: f64 = app
        .tlp
        .frac
        .iter()
        .enumerate()
        .map(|(i, &f)| f * (i.min(cores)) as f64)
        .sum();
    let p = p8 * (1.0 - CPU_POWER_SHARE) + p8 * CPU_POWER_SHARE * busy_c / busy8.max(1e-9);
    AppOnCores { frame_delay_s: 1.0 / fps, frame_energy_j: p / fps }
}

/// Build the Fig 13 request: configs = core counts 2..=8, kernels/tasks =
/// the given apps. The paper's framing: the *task* is one hour of headset
/// use per app (energy = measured power × wall-clock) while the *delay*
/// metric is the reciprocal of the measured frame rate; the CPU cluster's
/// embodied carbon is the provisioning knob. `c_comp` is pre-scaled per
/// config so the amortized embodied term equals
/// `CPU_emb(config) × 3600 s / lifetime` regardless of frame delay.
/// `enforce_qos` adds the per-app 72 FPS bound.
pub fn provisioning_request(
    apps: &[VrApp],
    soc: &VrSoc,
    lifetime_s: f64,
    enforce_qos: bool,
) -> EvalRequest {
    let kernels: Vec<String> = apps.iter().map(|a| a.name.to_string()).collect();
    let mut tasks = TaskMatrix::new(kernels.clone(), kernels.clone());
    for i in 0..apps.len() {
        tasks.set(i, i, 1.0);
    }
    let window_s = 3600.0;
    // §5.4 scopes the provisioning study to the CPU ("carbon efficiency of
    // real-production VR CPUs") — the first 8 components of the SoC vector.
    let comp: Vec<f64> = soc.component_vector_g()[..8].to_vec();
    let configs = (2..=8usize)
        .map(|cores| {
            let (gold, silver) = VrSoc::split_cores(cores);
            let mask = &soc.core_mask(gold, silver)[..8];
            let emb_cfg: f64 = comp.iter().zip(mask).map(|(c, m)| c * m).sum();
            let d_k: Vec<f64> =
                apps.iter().map(|a| app_on_cores(a, soc, cores).frame_delay_s).collect();
            // Per-app hour energy at this config's average power.
            let e_dyn: Vec<f64> = apps
                .iter()
                .map(|a| {
                    let m = app_on_cores(a, soc, cores);
                    m.frame_energy_j / m.frame_delay_s * window_s
                })
                .collect();
            // Rescale so C_emb = emb_cfg * (window/lifetime) for a task of
            // total delay sum(d_k): c_comp * sum_d / L == emb * window / L.
            let sum_d: f64 = d_k.iter().sum();
            ConfigRow {
                name: format!("{cores}-core"),
                f_clk: 2.0e9,
                d_k,
                e_dyn,
                leak_w: 0.0, // leakage folded into the per-frame power model
                c_comp: vec![emb_cfg * window_s / sum_d, 0.0, 0.0],
            }
        })
        .collect();
    let qos = if enforce_qos {
        vec![1.0 / QOS_FPS; apps.len()]
    } else {
        vec![f64::INFINITY; apps.len()]
    };
    EvalRequest {
        tasks,
        configs,
        online: vec![1.0, 1.0, 1.0],
        qos,
        ci_use_g_per_j: default_use_grid().g_per_joule(),
        lifetime_s,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::production_accelerators;
    use crate::workloads::apps::top10_apps;

    #[test]
    fn suite_task_shape() {
        let t = suite_task(&[Workload::Rn18, Workload::Sr256]);
        assert_eq!(t.num_tasks(), 1);
        assert_eq!(t.num_kernels(), 2);
        assert_eq!(t.get(0, 1), 1.0);
    }

    #[test]
    fn whole_life_embodied_equals_full_embodied() {
        use crate::matrixform::MetricRow;
        use crate::runtime::evaluate;
        let configs = production_accelerators().to_vec();
        let req = whole_life_request(&configs, &[Workload::Rn18], 1000.0);
        let res = evaluate(&mut HostEngine::new(), &req).unwrap();
        for (i, cfg) in configs.iter().enumerate() {
            let c_emb = res.metric(MetricRow::CEmb, i);
            let expect = cfg.embodied_g(FabGrid::Coal);
            assert!(
                (c_emb - expect).abs() / expect < 1e-3,
                "{}: amortized {} != full {}",
                cfg.name,
                c_emb,
                expect
            );
        }
    }

    #[test]
    fn fewer_cores_lower_power_higher_delay() {
        let soc = VrSoc::default();
        let app = &top10_apps()[0];
        let eight = app_on_cores(app, &soc, 8);
        let three = app_on_cores(app, &soc, 3);
        assert!(three.frame_delay_s > eight.frame_delay_s);
        let p8 = eight.frame_energy_j / eight.frame_delay_s;
        let p3 = three.frame_energy_j / three.frame_delay_s;
        assert!(p3 < p8, "power should drop with fewer cores: {p3} vs {p8}");
    }

    #[test]
    fn provisioning_request_is_coherent() {
        let soc = VrSoc::default();
        let apps = top10_apps();
        let req = provisioning_request(&apps[..4], &soc, 3.0e6, true);
        req.validate();
        assert_eq!(req.configs.len(), 7);
        // 8-core config carries the full CPU embodied carbon.
        let full: f64 = req.configs.last().unwrap().c_comp.iter().sum();
        let two: f64 = req.configs[0].c_comp.iter().sum();
        assert!(full > two);
    }
}
