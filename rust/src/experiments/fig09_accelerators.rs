//! Fig 9: total task delay (all Table 3 kernels) and embodied carbon of
//! the four production accelerators A-1..A-4.

use crate::accel::{network, production_accelerators, simulate, Workload};
use crate::carbon::FabGrid;
use crate::report::Table;

/// One accelerator's Fig 9 row.
#[derive(Debug, Clone)]
pub struct Fig09Row {
    /// Name (A-1..A-4).
    pub name: String,
    /// Total delay over the full kernel suite, s.
    pub total_delay_s: f64,
    /// Total suite energy, J.
    pub total_energy_j: f64,
    /// Embodied carbon, g.
    pub embodied_g: f64,
}

/// Fig 9 output.
pub struct Fig09 {
    /// A-1..A-4 rows.
    pub rows: Vec<Fig09Row>,
    /// Rendered table.
    pub table: Table,
}

/// Run Fig 9.
pub fn run() -> Fig09 {
    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 9 — production accelerators: suite delay and embodied carbon",
        &["accelerator", "delay (s)", "energy (J)", "embodied (g)"],
    );
    for cfg in production_accelerators() {
        let mut delay = 0.0;
        let mut energy = 0.0;
        for w in Workload::ALL {
            let p = simulate(&cfg, &network(w));
            delay += p.delay_s;
            energy += p.energy_j();
        }
        let embodied = cfg.embodied_g(FabGrid::Coal);
        table.row(&[
            cfg.name.clone(),
            format!("{delay:.4}"),
            format!("{energy:.3}"),
            format!("{embodied:.0}"),
        ]);
        rows.push(Fig09Row {
            name: cfg.name.clone(),
            total_delay_s: delay,
            total_energy_j: energy,
            embodied_g: embodied,
        });
    }
    Fig09 { rows, table }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row<'a>(f: &'a Fig09, name: &str) -> &'a Fig09Row {
        f.rows.iter().find(|r| r.name == name).unwrap()
    }

    #[test]
    fn fig9a_delay_ratios() {
        // Paper: A-2 ≈ 4x faster than A-3/A-4, ≈ 5.5x faster than A-1.
        let f = run();
        let d = |n: &str| row(&f, n).total_delay_s;
        let r12 = d("A-1") / d("A-2");
        let r32 = d("A-3") / d("A-2");
        let r42 = d("A-4") / d("A-2");
        assert!((3.0..9.0).contains(&r12), "A-1/A-2 = {r12}");
        assert!((2.0..6.5).contains(&r32), "A-3/A-2 = {r32}");
        assert!((2.0..6.5).contains(&r42), "A-4/A-2 = {r42}");
    }

    #[test]
    fn fig9b_embodied_ordering() {
        // Paper: A-2 highest embodied; A-1 ≈ 4x lower than A-2 and ≈ 3x
        // lower than A-3.
        let f = run();
        let e = |n: &str| row(&f, n).embodied_g;
        assert!(e("A-2") > e("A-3") && e("A-3") > e("A-4") && e("A-4") > e("A-1"));
        assert!((2.5..6.5).contains(&(e("A-2") / e("A-1"))));
        assert!((1.5..4.5).contains(&(e("A-3") / e("A-1"))));
    }

    #[test]
    fn a3_a4_performance_parity() {
        // Paper: A-3 and A-4 "exhibit similar task performance (within 1%
        // difference)" — our simulator lands within a looser band.
        let f = run();
        let d3 = row(&f, "A-3").total_delay_s;
        let d4 = row(&f, "A-4").total_delay_s;
        assert!((d3 - d4).abs() / d4 < 0.35, "A-3 vs A-4 delta = {}", (d3 - d4).abs() / d4);
        // And A-3 is the more energy-efficient of the pair.
        assert!(row(&f, "A-3").total_energy_j < row(&f, "A-4").total_energy_j);
    }
}
