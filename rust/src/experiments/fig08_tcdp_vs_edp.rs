//! Fig 8: carbon-efficiency benefit of designing with tCDP versus the
//! carbon-oblivious EDP — per cluster, the tCDP of the EDP-chosen design
//! divided by the tCDP of the tCDP-chosen design (paper: 1.2–6.9×).

use crate::carbon::FabGrid;
use crate::dse::{design_grid, explore, lifetime_for_ratio, profile_configs, profiles_to_rows};
use crate::matrixform::MetricRow;
use crate::report::Table;
use crate::runtime::Engine;
use crate::workloads::{cluster_workloads, Cluster};

use super::common::{default_use_grid, rows_request, suite_task};

/// One cluster's Fig 8 bar.
#[derive(Debug, Clone)]
pub struct Fig08Row {
    /// Cluster.
    pub cluster: Cluster,
    /// tCDP(EDP-optimal design) / tCDP(tCDP-optimal design).
    pub gain: f64,
    /// The two design labels.
    pub edp_design: String,
    /// tCDP-chosen design.
    pub tcdp_design: String,
}

/// Fig 8 output.
pub struct Fig08 {
    /// Per-cluster gains.
    pub rows: Vec<Fig08Row>,
    /// Rendered table.
    pub table: Table,
}

/// Run at the embodied-dominant scenario (98 % embodied), where the
/// metric choice matters most.
pub fn run(engine: &mut dyn Engine) -> crate::Result<Fig08> {
    let grid = design_grid();
    let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();
    let ci = default_use_grid().g_per_joule();

    let all_workloads = cluster_workloads(Cluster::All);
    let all_profiles = profile_configs(&configs, &all_workloads);
    let all_rows = profiles_to_rows(&configs, &all_profiles, FabGrid::Coal);
    let lifetime_s = lifetime_for_ratio(&all_rows, &suite_task(&all_workloads), 0.98, ci);

    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig 8 — tCDP-designed vs EDP-designed carbon efficiency (98% embodied)",
        &["cluster", "EDP-design", "tCDP-design", "gain x"],
    );
    for cluster in Cluster::ALL {
        let workloads = cluster_workloads(cluster);
        let crows = if cluster == Cluster::All {
            all_rows.clone()
        } else {
            let profiles = profile_configs(&configs, &workloads);
            profiles_to_rows(&configs, &profiles, FabGrid::Coal)
        };
        let req = rows_request(crows, &workloads, lifetime_s, 1.0);
        let out = explore(engine, &req)?;
        let edp_idx = out.optimal["EDP"];
        let tcdp_idx = out.optimal["tCDP"];
        let gain = out.result.metric(MetricRow::Tcdp, edp_idx)
            / out.result.metric(MetricRow::Tcdp, tcdp_idx);
        table.row(&[
            cluster.label().to_string(),
            out.result.names[edp_idx].clone(),
            out.result.names[tcdp_idx].clone(),
            format!("{gain:.2}"),
        ]);
        rows.push(Fig08Row {
            cluster,
            gain,
            edp_design: out.result.names[edp_idx].clone(),
            tcdp_design: out.result.names[tcdp_idx].clone(),
        });
    }
    Ok(Fig08 { rows, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    #[test]
    fn tcdp_designs_beat_edp_designs() {
        let mut ctx = Ctx::host();
        let f = run(ctx.engine.as_mut()).unwrap();
        assert_eq!(f.rows.len(), 5);
        for r in &f.rows {
            assert!(r.gain >= 1.0, "{}: gain {} < 1", r.cluster.label(), r.gain);
        }
        // Paper range 1.2–6.9x: at least one cluster shows a clear win.
        let max = f.rows.iter().map(|r| r.gain).fold(0.0f64, f64::max);
        assert!(max > 1.3, "max gain = {max}");
    }
}
