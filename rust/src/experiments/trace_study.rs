//! Trace study: how much the operational-carbon optimum swings when the
//! use-phase grid varies over time instead of sitting at a static annual
//! average.
//!
//! The study sweeps one profiled cluster space across the named
//! carbon-intensity traces ([`ScenarioGrid::traces`]) plus a
//! fleet-weighted regional mix derived from the synthetic deployment
//! telemetry ([`fleet_mix_trace`]). Two findings the tables make visible:
//!
//! 1. **Across grids** the best-design tCDP swings by the ratio of the
//!    traces' mean intensities (renewable-heavy vs coal-heavy is a ~4×
//!    operational-carbon spread) — the actionable design signal.
//! 2. **Within one grid** the trace-averaged result matches its static
//!    mean-CI collapse to f32 rounding, because operational carbon is
//!    linear in CI. The delta column of [`trace_table`] is therefore a
//!    built-in correctness check, not a finding.

use crate::carbon::{CiTrace, FleetCohort, FleetMix};
use crate::dse::cache::ProfileCache;
use crate::dse::grid::{ScenarioGrid, YEAR_S};
use crate::dse::sweep::{sweep_with_cache, SweepConfig, SweepOutcome};
use crate::report::{sweep_table, trace_table, Table};
use crate::runtime::EngineFactory;
use crate::workloads::{regional_usage_shares, Cluster, FleetConfig};

use super::sweep_fig7::profile_cluster;

/// Flatten the deployed fleet into one usage-weighted carbon-intensity
/// trace: devices are split over four grid regions (US-like, renewable-
/// heavy, world-average, coal-heavy) by [`regional_usage_shares`], each
/// region carries its own diurnal trace, and the [`FleetMix`] weights the
/// regional traces by usage share.
pub fn fleet_mix_trace(cfg: &FleetConfig) -> CiTrace {
    let shares = regional_usage_shares(cfg, 4);
    let regional = [
        ("us", CiTrace::diurnal(380.0, 0.30, 19.0)),
        ("renewable", CiTrace::diurnal_renewable()),
        ("world", CiTrace::diurnal_world()),
        ("coal", CiTrace::diurnal_coal()),
    ];
    let cohorts: Vec<FleetCohort> = shares
        .iter()
        .zip(regional)
        .filter(|(&share, _)| share > 0.0)
        .map(|(&share, (label, trace))| FleetCohort { label: label.to_string(), share, trace })
        .collect();
    FleetMix::new(cohorts).flatten()
}

/// The study's scenario grid: the named trace presets plus the
/// fleet-weighted regional mix for the default synthetic fleet.
pub fn trace_grid() -> ScenarioGrid {
    ScenarioGrid::traces()
        .with_trace("trace=fleet-mix", fleet_mix_trace(&FleetConfig::default()))
}

/// Full study output.
pub struct TraceStudy {
    /// Cluster the space was profiled on.
    pub cluster: Cluster,
    /// The aggregated sweep outcome (trace scenarios in preset order).
    pub outcome: SweepOutcome,
    /// Rendered per-scenario stats table.
    pub table: Table,
    /// Rendered trace-vs-static comparison table.
    pub traces: Table,
}

/// Run the trace study for one cluster on `threads` workers (0 = auto).
/// The 121-config space is profiled once; every trace segment of every
/// scenario is a cheap overlay over the same cached profile.
pub fn run(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    threads: usize,
) -> crate::Result<TraceStudy> {
    run_cached(factory, cluster, threads, None)
}

/// Warm-start variant of [`run`]: phase A consults a persistent
/// [`ProfileCache`]. On a warm cache the whole multi-trace sweep performs
/// zero engine contractions — the trace fan-out multiplies phase-B
/// overlays, never phase-A profiling.
pub fn run_cached(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    threads: usize,
    cache: Option<&ProfileCache>,
) -> crate::Result<TraceStudy> {
    let space = profile_cluster(cluster);
    let mut base = space.base.clone();
    // A mid-range device lifetime so neither carbon term dominates.
    base.lifetime_s = 2.0 * YEAR_S;
    let grid = trace_grid();
    let outcome = sweep_with_cache(factory, &base, &grid, &SweepConfig { threads }, cache)?;
    let mut table = sweep_table(&outcome);
    table.title = format!("Trace study [{}] — {}", cluster.label(), table.title);
    let traces = trace_table(&outcome);
    Ok(TraceStudy { cluster, outcome, table, traces })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostEngineFactory;

    fn best(study: &TraceStudy, label: &str) -> f64 {
        study
            .outcome
            .scenarios
            .iter()
            .find(|s| s.label == label)
            .unwrap_or_else(|| panic!("scenario {label} missing"))
            .outcome
            .stats
            .best
    }

    #[test]
    fn operational_carbon_swings_across_grids() {
        let study = run(&HostEngineFactory, Cluster::Ai5, 2).unwrap();
        assert_eq!(study.outcome.scenarios.len(), 7);
        let renewable = best(&study, "trace=diurnal-renewable");
        let world = best(&study, "trace=diurnal-world");
        let coal = best(&study, "trace=diurnal-coal");
        assert!(
            renewable < world && world < coal,
            "best tCDP not ordered by grid intensity: {renewable} < {world} < {coal}"
        );
        // The fleet mix blends all four regions, so it sits inside the
        // renewable..coal envelope.
        let mix = best(&study, "trace=fleet-mix");
        assert!(renewable < mix && mix < coal, "fleet mix {mix} outside envelope");
        assert_eq!(study.table.len(), 7);
        assert_eq!(study.traces.len(), 7);
    }

    #[test]
    fn every_scenario_carries_trace_metadata_with_tiny_static_delta() {
        let study = run(&HostEngineFactory, Cluster::Ai5, 2).unwrap();
        for s in &study.outcome.scenarios {
            let meta = s.trace.unwrap_or_else(|| panic!("{} has no trace meta", s.label));
            assert!(meta.segments >= 1, "{}", s.label);
            assert!(meta.min_ci_g_per_kwh <= meta.mean_ci_g_per_kwh, "{}", s.label);
            assert!(meta.mean_ci_g_per_kwh <= meta.max_ci_g_per_kwh, "{}", s.label);
            // c_op is linear in CI, so trace-average == static mean-CI
            // collapse up to f32 rounding in the overlay.
            let best = s.outcome.stats.best;
            let rel = (best - meta.static_best_tcdp).abs() / best;
            assert!(rel < 1e-4, "{}: trace {best} vs static {}", s.label, meta.static_best_tcdp);
            assert_eq!(s.outcome.stats.feasible, meta.static_feasible, "{}", s.label);
        }
    }

    #[test]
    fn fleet_mix_trace_is_deterministic_and_blended() {
        let cfg = FleetConfig::default();
        let a = fleet_mix_trace(&cfg);
        let b = fleet_mix_trace(&cfg);
        assert_eq!(a.segments(), b.segments());
        assert_eq!(a.len(), 96, "4 regions x 24 hourly segments");
        // The blend sits strictly between the cleanest and dirtiest
        // regional means.
        let mean = a.mean_g_per_kwh();
        assert!(
            mean > CiTrace::diurnal_renewable().mean_g_per_kwh()
                && mean < CiTrace::diurnal_coal().mean_g_per_kwh(),
            "blended mean {mean} outside regional envelope"
        );
    }
}
