//! Fig 14: the carbon-optimal hardware replacement period versus daily
//! usage (1 h / 3 h / 12 h), with the 1.21×/year energy-efficiency
//! improvement of newer hardware.

use crate::carbon::replacement::{sweep_lifetimes, ReplacementScenario};
use crate::carbon::UseGrid;
use crate::report::Table;
use crate::soc::VrSoc;

/// One usage panel.
#[derive(Debug, Clone)]
pub struct Fig14Panel {
    /// Daily usage, hours.
    pub hours_per_day: f64,
    /// `(lifetime years, total carbon g)` per candidate.
    pub sweep: Vec<(f64, f64)>,
    /// Optimal lifetime, years.
    pub optimal_years: f64,
    /// Savings of the optimum vs the worst candidate (0..1).
    pub savings_vs_worst: f64,
}

/// Fig 14 output.
pub struct Fig14 {
    /// Panels for 1 h / 3 h / 12 h daily use.
    pub panels: Vec<Fig14Panel>,
    /// Rendered table.
    pub table: Table,
}

/// The VR headset scenario: Table 5 CPU-block embodied carbon (the
/// paper's own calibration scope) and the Snapdragon TDP while active.
pub fn headset_scenario(hours: f64) -> ReplacementScenario {
    let soc = VrSoc::default();
    ReplacementScenario {
        embodied_g: soc.gold_cluster_g() + soc.silver_cluster_g(),
        active_power_w: soc.tdp_w,
        hours_per_day: hours,
        grid: UseGrid::WorldAverage,
        annual_efficiency_gain: 1.21,
        horizon_years: 10.0,
    }
}

/// Candidate lifetimes (years).
pub const CANDIDATES: [f64; 5] = [1.0, 2.0, 3.0, 4.0, 5.0];

/// Run the three usage panels.
pub fn run() -> Fig14 {
    let mut panels = Vec::new();
    let mut table = Table::new(
        "Fig 14 — total carbon over a 10-year horizon by replacement period (g, * = optimal)",
        &["use h/day", "1y", "2y", "3y", "4y", "5y", "optimal"],
    );
    for hours in [1.0, 3.0, 12.0] {
        let s = headset_scenario(hours);
        let sweep = sweep_lifetimes(&s, &CANDIDATES);
        let (opt_years, opt_c) = sweep
            .iter()
            .cloned()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        let worst = sweep.iter().map(|&(_, c)| c).fold(0.0f64, f64::max);
        let mut cells = vec![format!("{hours:.0}")];
        for &(lt, c) in &sweep {
            cells.push(format!("{c:.0}{}", if lt == opt_years { "*" } else { "" }));
        }
        cells.push(format!("{opt_years:.0}y"));
        table.row(&cells);
        panels.push(Fig14Panel {
            hours_per_day: hours,
            sweep,
            optimal_years: opt_years,
            savings_vs_worst: 1.0 - opt_c / worst,
        });
    }
    Fig14 { panels, table }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_lifetime_shrinks_with_usage() {
        // Paper: 1h -> 5 years, 3h -> 3 years, 12h -> 2 years.
        let f = run();
        let opts: Vec<f64> = f.panels.iter().map(|p| p.optimal_years).collect();
        assert_eq!(opts[0], 5.0, "1h/day optimum");
        assert!((2.0..=4.0).contains(&opts[1]), "3h/day optimum = {}", opts[1]);
        assert!(opts[2] <= 3.0, "12h/day optimum = {}", opts[2]);
        assert!(opts[0] >= opts[1] && opts[1] >= opts[2]);
    }

    #[test]
    fn savings_are_substantial() {
        // Paper reports 20–50% savings between optimal and worst periods.
        let f = run();
        for p in &f.panels {
            assert!(
                p.savings_vs_worst > 0.05,
                "{}h: savings {}",
                p.hours_per_day,
                p.savings_vs_worst
            );
        }
        // Light use shows the largest spread (embodied-dominated).
        assert!(f.panels[0].savings_vs_worst > 0.3, "1h savings = {}", f.panels[0].savings_vs_worst);
    }

    #[test]
    fn sweep_covers_all_candidates() {
        let f = run();
        for p in &f.panels {
            assert_eq!(p.sweep.len(), CANDIDATES.len());
        }
    }
}
