//! Fig 13: carbon-efficient optimal CPU core-count configuration per VR
//! application (stars), via the matrix formalization over core-count
//! configs. Single apps keep the 72 FPS QoS bound; "All Apps" optimizes
//! the collective tCDP of the four-application mix.

use crate::matrixform::MetricRow;
use crate::report::Table;
use crate::runtime::Engine;
use crate::soc::VrSoc;
use crate::workloads::apps::{fig12_apps, VrApp};

use super::common::provisioning_request;

/// Amortization window for the provisioning studies: the paper's VR
/// assumption is 1 h daily for 3 years; embodied carbon concentrates on
/// those ~1100 operational hours.
pub fn vr_operational_lifetime_s() -> f64 {
    crate::carbon::operational::operational_lifetime_s(1.0, 3.0)
}

/// One Fig 13 row.
#[derive(Debug, Clone)]
pub struct Fig13Row {
    /// Workload label ("G-2", ..., "All Apps").
    pub workload: String,
    /// Optimal enabled-core count.
    pub optimal_cores: usize,
    /// tCDP per core count (index 0 = 2 cores).
    pub tcdp_by_cores: Vec<f64>,
}

/// Fig 13 output.
pub struct Fig13 {
    /// Per-workload rows.
    pub rows: Vec<Fig13Row>,
    /// Rendered table.
    pub table: Table,
}

fn single_app_row(
    engine: &mut dyn Engine,
    app: &VrApp,
    soc: &VrSoc,
    lifetime_s: f64,
) -> crate::Result<Fig13Row> {
    let apps = vec![app.clone()];
    let req = provisioning_request(&apps, soc, lifetime_s, true);
    let res = crate::runtime::evaluate(engine, &req)?;
    let idx = res
        .argmin_feasible(MetricRow::Tcdp)
        .ok_or_else(|| anyhow::anyhow!("{}: no feasible core config", app.name))?;
    Ok(Fig13Row {
        workload: app.name.to_string(),
        optimal_cores: idx + 2,
        tcdp_by_cores: res.row(MetricRow::Tcdp).to_vec(),
    })
}

/// Run Fig 13 for the four profiled apps plus the collective "All Apps".
pub fn run(engine: &mut dyn Engine) -> crate::Result<Fig13> {
    let soc = VrSoc::default();
    let lifetime_s = vr_operational_lifetime_s();
    let apps = fig12_apps();

    let mut rows = Vec::new();
    // Collective mix first (paper's "All Apps" bar).
    let req = provisioning_request(&apps, &soc, lifetime_s, false);
    let res = crate::runtime::evaluate(engine, &req)?;
    let idx = res.argmin_feasible(MetricRow::Tcdp).expect("unconstrained");
    rows.push(Fig13Row {
        workload: "All Apps".into(),
        optimal_cores: idx + 2,
        tcdp_by_cores: res.row(MetricRow::Tcdp).to_vec(),
    });
    for app in &apps {
        rows.push(single_app_row(engine, app, &soc, lifetime_s)?);
    }

    let mut table = Table::new(
        "Fig 13 — carbon-efficient core configuration (tCDP per config; * = optimal)",
        &["workload", "2", "3", "4", "5", "6", "7", "8"],
    );
    for r in &rows {
        let norm = r.tcdp_by_cores.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut cells = vec![r.workload.clone()];
        for (i, v) in r.tcdp_by_cores.iter().enumerate() {
            let star = if i + 2 == r.optimal_cores { "*" } else { "" };
            cells.push(format!("{:.3}{}", v / norm, star));
        }
        table.row(&cells);
    }
    Ok(Fig13 { rows, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    fn optimal(f: &Fig13, name: &str) -> usize {
        f.rows.iter().find(|r| r.workload == name).unwrap().optimal_cores
    }

    #[test]
    fn fig13_stars_match_paper() {
        // Paper: "optimal carbon-efficient 5-core CPU configuration for
        // All Apps, 4-core for G-2 and M-1, 7-core for B-1 & S-1, and
        // 6-core for SG-1."
        let f = run(Ctx::host().engine.as_mut()).unwrap();
        assert_eq!(optimal(&f, "G-2"), 4);
        assert_eq!(optimal(&f, "M-1"), 4);
        assert_eq!(optimal(&f, "B-1 & S-1"), 7);
        assert_eq!(optimal(&f, "SG-1"), 6);
        assert_eq!(optimal(&f, "All Apps"), 5);
    }

    #[test]
    fn tcdp_curves_cover_all_configs() {
        let f = run(Ctx::host().engine.as_mut()).unwrap();
        for r in &f.rows {
            assert_eq!(r.tcdp_by_cores.len(), 7, "{}", r.workload);
            assert!(r.tcdp_by_cores.iter().all(|&v| v > 0.0));
        }
    }
}
