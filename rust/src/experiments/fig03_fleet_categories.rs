//! Fig 3: top-100 VR app categorization and top-10 compute-cycle share,
//! from the synthetic fleet trace (DESIGN.md §4 substitution).

use crate::report::Table;
use crate::workloads::{generate_fleet, FleetConfig, FleetSummary};

/// Fig 3 output.
pub struct Fig03 {
    /// The aggregated fleet.
    pub summary: FleetSummary,
    /// Rendered table.
    pub table: Table,
}

/// Run the fleet aggregation.
pub fn run(cfg: &FleetConfig) -> Fig03 {
    let summary = generate_fleet(cfg);
    let mut table = Table::new(
        "Fig 3 — app category share of fleet compute cycles",
        &["category", "cycle share"],
    );
    for (label, share) in ["G", "SG", "B", "M"].iter().zip(summary.category_share.iter()) {
        table.row(&[label.to_string(), format!("{:.1}%", share * 100.0)]);
    }
    table.row(&["top-10 apps".into(), format!("{:.1}%", summary.top10_cycle_share * 100.0)]);
    Fig03 { summary, table }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top10_share_exceeds_85pct() {
        let f = run(&FleetConfig::default());
        assert!(
            f.summary.top10_cycle_share > 0.82,
            "top-10 share = {}",
            f.summary.top10_cycle_share
        );
    }

    #[test]
    fn gaming_then_social() {
        let f = run(&FleetConfig::default());
        let [g, sg, ..] = f.summary.category_share;
        assert!(g > sg);
        assert_eq!(f.table.len(), 5);
    }
}
