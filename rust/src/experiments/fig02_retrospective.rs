//! Fig 2: retrospective CPU/SoC carbon analysis with EDP, CDP, CEP
//! (normalized to E5-2670 / Snapdragon-835, stars at metric optima).

use crate::carbon::metrics::argmin;
use crate::carbon::MetricKind;
use crate::report::Table;
use crate::soc::{mobile_socs, server_cpus};

/// One Fig 2 panel.
pub struct Fig02Panel {
    /// Part names.
    pub names: Vec<String>,
    /// `(metric, normalized values, optimal index)`.
    pub metrics: Vec<(&'static str, Vec<f64>, usize)>,
    /// Rendered table.
    pub table: Table,
}

const PANEL_METRICS: [MetricKind; 3] = [MetricKind::Edp, MetricKind::Cdp, MetricKind::Cep];

fn panel(
    title: &str,
    names: Vec<String>,
    inputs: Vec<crate::carbon::MetricInputs>,
    normalize_to: &str,
) -> Fig02Panel {
    let ref_idx = names.iter().position(|n| n == normalize_to).expect("norm reference");
    let mut headers: Vec<&str> = vec!["metric"];
    let name_strs: Vec<String> = names.clone();
    for n in &name_strs {
        headers.push(n);
    }
    let mut table = Table::new(title, &headers);
    let mut metrics = Vec::new();
    for kind in PANEL_METRICS {
        let vals: Vec<f64> = inputs.iter().map(|i| kind.value(&i.metrics())).collect();
        let best = argmin(&vals).unwrap();
        let norm: Vec<f64> = vals.iter().map(|v| v / vals[ref_idx]).collect();
        let mut cells = vec![kind.label().to_string()];
        for (i, v) in norm.iter().enumerate() {
            cells.push(format!("{v:.3}{}", if i == best { "*" } else { "" }));
        }
        table.row(&cells);
        metrics.push((kind.label(), norm, best));
    }
    Fig02Panel { names, metrics, table }
}

/// Fig 2(a): server CPUs 2012–2021.
pub fn run_cpus() -> Fig02Panel {
    let cpus = server_cpus();
    let names: Vec<String> = cpus.iter().map(|c| c.name.to_string()).collect();
    let inputs: Vec<_> = cpus.iter().map(|c| c.metric_inputs(1.0)).collect();
    panel("Fig 2a — server CPUs (normalized to E5-2670)", names, inputs, "E5-2670")
}

/// Fig 2(b): Snapdragon SoCs 2016–2020.
pub fn run_socs() -> Fig02Panel {
    let socs = mobile_socs();
    let names: Vec<String> = socs.iter().map(|s| s.name.to_string()).collect();
    let inputs: Vec<_> = socs.iter().map(|s| s.metric_inputs(1.0)).collect();
    panel(
        "Fig 2b — Snapdragon SoCs (normalized to Snapdragon-835)",
        names,
        inputs,
        "Snapdragon-835",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn star(p: &Fig02Panel, metric: &str) -> String {
        let (_, _, idx) = p.metrics.iter().find(|(m, _, _)| *m == metric).unwrap();
        p.names[*idx].clone()
    }

    #[test]
    fn cpu_stars_match_paper() {
        let p = run_cpus();
        assert_eq!(star(&p, "EDP"), "EPYC-7702");
        assert_eq!(star(&p, "CDP"), "E5-2680");
        assert_eq!(star(&p, "CEP"), "E-2234");
    }

    #[test]
    fn soc_stars_match_paper() {
        let p = run_socs();
        assert_eq!(star(&p, "EDP"), "Snapdragon-865");
        assert_eq!(star(&p, "CDP"), "Snapdragon-835");
        assert_eq!(star(&p, "CEP"), "Snapdragon-855");
    }

    #[test]
    fn normalization_reference_is_one() {
        let p = run_cpus();
        let ref_idx = p.names.iter().position(|n| n == "E5-2670").unwrap();
        for (_, norm, _) in &p.metrics {
            assert!((norm[ref_idx] - 1.0).abs() < 1e-12);
        }
    }
}
