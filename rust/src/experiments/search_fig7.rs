//! Adaptive search anchored on Fig 7, plus the expanded 2-D/3-D space.
//!
//! Two entries:
//!
//! * [`run`] — the correctness anchor: the same 121-point grid and
//!   embodied-share scenarios as [`super::sweep_fig7`], explored by
//!   [`crate::dse::search`] instead of exhaustively. The search must
//!   reproduce the exhaustive feasible-tCDP optimum **exactly** (per-
//!   config arithmetic is batch-position-independent, so the tCDP values
//!   are bit-comparable) while evaluating well under the full grid —
//!   locked at ≤ 60 % by `rust/tests/experiments_e2e.rs`.
//! * [`run_expanded`] — the scaling payoff: the ~10k-point
//!   [`SearchSpace::expanded_2d3d`] space (MAC × SRAM × 2-D/3-D × clock)
//!   that exhaustive profiling cannot afford. On XR workloads the §5.6
//!   stacking win emerges from search: the optimum is a 3-D stacked
//!   design, found after evaluating a few percent of the space.

use std::path::Path;

use crate::carbon::FabGrid;
use crate::dse::cache::ProfileCache;
use crate::dse::grid::{ScenarioGrid, YEAR_S};
use crate::dse::search::{
    search_resumable, ReplayEvaluator, SearchCheckpoint, SearchConfig, SearchOutcome,
    SimulatorEvaluator,
};
use crate::dse::space::SearchSpace;
use crate::dse::sweep::{sweep_with_cache, SweepConfig, SweepOutcome};
use crate::matrixform::EvalRequest;
use crate::report::{search_archive_table, search_table, Table};
use crate::runtime::EngineFactory;
use crate::workloads::{cluster_workloads, Cluster};

use super::common::rows_request;
use super::sweep_fig7::profile_cluster;

/// Anchor output: exhaustive reference + search outcome on one cluster.
pub struct SearchFig7 {
    /// Cluster the spaces were profiled on.
    pub cluster: Cluster,
    /// Exhaustive 121-point sweep (the reference the search must hit).
    pub exhaustive: SweepOutcome,
    /// Adaptive search over the same space and scenarios.
    pub outcome: SearchOutcome,
    /// Comparison table (exhaustive vs search optimum, evaluations).
    pub table: Table,
}

/// Run the Fig 7 anchor: exhaustive sweep and adaptive search over the
/// identical 121-point space and embodied-share scenario grid. `cfg`
/// carries the search knobs (seed, budget, threads); its `threads` also
/// drive the exhaustive reference sweep.
pub fn run(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    cfg: &SearchConfig,
) -> crate::Result<SearchFig7> {
    run_resumable(factory, cluster, cfg, None, None, None)
}

/// [`run`] with checkpoint/cache plumbing: resume the search phase from
/// a [`SearchCheckpoint`], persist one after every generation
/// (`save_to`), and front every profile phase — the exhaustive
/// reference's and the search generations' — with a [`ProfileCache`].
/// The exhaustive reference is recomputed either way (it is the
/// correctness anchor, not part of the resumable state; on a warm cache
/// it costs zero engine contractions); the search outcome is
/// bit-identical to an uninterrupted run.
pub fn run_resumable(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    cfg: &SearchConfig,
    resume_from: Option<&SearchCheckpoint>,
    save_to: Option<&Path>,
    cache: Option<&ProfileCache>,
) -> crate::Result<SearchFig7> {
    let space = profile_cluster(cluster);
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
    let exhaustive = sweep_with_cache(
        factory,
        &space.base,
        &grid,
        &SweepConfig { threads: cfg.threads },
        cache,
    )?;

    // The exhaustive reference already profiled the whole grid; the
    // search replays those rows instead of re-running the simulator
    // (bit-identical — rows are keyed by the shared grid labels).
    let sspace = SearchSpace::fig7_grid();
    let evaluator = ReplayEvaluator::new(&space.rows);
    let outcome = search_resumable(
        factory,
        &sspace,
        &evaluator,
        &space.base,
        &grid,
        cfg,
        resume_from,
        save_to,
        cache,
    )?;

    let mut table = Table::new(
        &format!(
            "Fig 7 search anchor [{}] — {} of {} grid points evaluated",
            cluster.label(),
            outcome.evaluations,
            outcome.space_size
        ),
        &["path", "scenario", "optimal design", "tCDP [g*s]", "evaluations"],
    );
    if let Some((si, ci, v)) = exhaustive.best() {
        table.row(&[
            "exhaustive".into(),
            exhaustive.scenarios[si].label.clone(),
            exhaustive.scenarios[si].outcome.result.names[ci].clone(),
            format!("{v:.3e}"),
            outcome.space_size.to_string(),
        ]);
    }
    if let Some(b) = &outcome.best {
        table.row(&[
            "search".into(),
            b.scenario_label.clone(),
            b.name.clone(),
            format!("{:.3e}", b.tcdp),
            outcome.evaluations.to_string(),
        ]);
    }
    Ok(SearchFig7 { cluster, exhaustive, outcome, table })
}

/// Expanded-space output.
pub struct SearchExpanded {
    /// Cluster the candidates are profiled on.
    pub cluster: Cluster,
    /// The search outcome over [`SearchSpace::expanded_2d3d`].
    pub outcome: SearchOutcome,
    /// Summary table.
    pub table: Table,
    /// Archive table (pooled Pareto front).
    pub archive_table: Table,
}

/// The expanded-space scenario grid: a heavy-use year of operational
/// life against a hundredth of it (operational- vs embodied-leaning),
/// fixed lifetimes — no calibration pass over the space is needed (or
/// affordable) at this scale.
pub fn expanded_grid() -> ScenarioGrid {
    ScenarioGrid::new()
        .with_lifetime("LT=1y", YEAR_S)
        .with_lifetime("LT=1y/100", YEAR_S / 100.0)
}

/// Search the expanded 2-D/3-D space on a cluster's kernels.
pub fn run_expanded(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    cfg: &SearchConfig,
) -> crate::Result<SearchExpanded> {
    run_expanded_resumable(factory, cluster, cfg, None, None, None)
}

/// [`run_expanded`] with checkpoint/cache plumbing — on the ~10k-point
/// space the per-generation simulator work is the expensive part, which
/// is exactly what resuming from a checkpoint skips; a profile cache
/// additionally serves exact re-runs from disk.
pub fn run_expanded_resumable(
    factory: &dyn EngineFactory,
    cluster: Cluster,
    cfg: &SearchConfig,
    resume_from: Option<&SearchCheckpoint>,
    save_to: Option<&Path>,
    cache: Option<&ProfileCache>,
) -> crate::Result<SearchExpanded> {
    let sspace = SearchSpace::expanded_2d3d();
    let workloads = cluster_workloads(cluster);
    let evaluator = SimulatorEvaluator { workloads: workloads.clone(), fab: FabGrid::Coal };
    // Shell request: the search fills configs per generation.
    let base: EvalRequest = rows_request(Vec::new(), &workloads, YEAR_S, 1.0);
    let outcome = search_resumable(
        factory,
        &sspace,
        &evaluator,
        &base,
        &expanded_grid(),
        cfg,
        resume_from,
        save_to,
        cache,
    )?;
    let mut table = search_table(&outcome);
    table.title = format!("Expanded 2-D/3-D space [{}] — {}", cluster.label(), table.title);
    let archive_table = search_archive_table(&outcome);
    Ok(SearchExpanded { cluster, outcome, table, archive_table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::search::exhaustive_front;
    use crate::runtime::HostEngineFactory;

    fn two_threads() -> SearchConfig {
        SearchConfig { threads: 2, ..SearchConfig::default() }
    }

    #[test]
    fn anchor_search_matches_exhaustive_optimum_exactly() {
        let f = run(&HostEngineFactory, Cluster::Ai5, &two_threads()).unwrap();
        let (esi, eci, etcdp) = f.exhaustive.best().expect("exhaustive optimum");
        let best = f.outcome.best.as_ref().expect("search optimum");
        assert_eq!(best.name, f.exhaustive.scenarios[esi].outcome.result.names[eci]);
        assert_eq!(best.scenario_label, f.exhaustive.scenarios[esi].label);
        assert_eq!(best.tcdp.to_bits(), etcdp.to_bits());
        assert!(f.outcome.converged);
        assert_eq!(f.outcome.space_size, 121);
        assert_eq!(f.table.len(), 2);
    }

    #[test]
    fn anchor_search_stays_under_60_percent_of_grid() {
        let f = run(&HostEngineFactory, Cluster::Ai5, &two_threads()).unwrap();
        assert!(
            f.outcome.evaluations * 10 <= f.outcome.space_size * 6,
            "evaluated {}/{}",
            f.outcome.evaluations,
            f.outcome.space_size
        );
    }

    #[test]
    fn checkpointed_anchor_run_matches_plain_run() {
        let dir = crate::testkit::test_dir("fig7_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fig7.ckpt.json");

        let plain = run(&HostEngineFactory, Cluster::Ai5, &two_threads()).unwrap();
        let saved = run_resumable(
            &HostEngineFactory,
            Cluster::Ai5,
            &two_threads(),
            None,
            Some(path.as_path()),
            None,
        )
        .unwrap();
        assert_eq!(plain.outcome.best, saved.outcome.best);
        assert_eq!(plain.outcome.archive, saved.outcome.archive);
        assert_eq!(plain.outcome.evaluations, saved.outcome.evaluations);
        assert_eq!(plain.outcome.generations, saved.outcome.generations);

        // The sink left a finished checkpoint; resuming from it
        // reproduces the outcome without re-evaluating a single point.
        let ck = crate::dse::search::read_checkpoint(&path).unwrap();
        assert!(ck.done);
        assert_eq!(ck.evaluated.len(), plain.outcome.evaluations);
        let resumed = run_resumable(
            &HostEngineFactory,
            Cluster::Ai5,
            &two_threads(),
            Some(&ck),
            None,
            None,
        )
        .unwrap();
        assert_eq!(plain.outcome.best, resumed.outcome.best);
        assert_eq!(plain.outcome.archive, resumed.outcome.archive);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn anchor_archive_is_subset_of_exhaustive_front() {
        let f = run(&HostEngineFactory, Cluster::Ai5, &two_threads()).unwrap();
        let front = exhaustive_front(&f.exhaustive);
        assert!(!f.outcome.archive.is_empty());
        for a in &f.outcome.archive {
            assert!(
                front.contains(&(a.scenario, a.name.clone())),
                "({}, {}) not on exhaustive front",
                a.scenario_label,
                a.name
            );
        }
    }
}
