//! Fig 10: carbon-efficiency of A-1..A-4 versus operational lifetime in
//! number of inferences (10³..10⁸) — the embodied/operational crossover
//! study. Carbon efficiency = 1 / tCDP, normalized to A-1 at 10³.

use crate::accel::{production_accelerators, Workload};
use crate::matrixform::MetricRow;
use crate::report::Table;
use crate::runtime::Engine;

use super::common::whole_life_request;

/// Fig 10 uses a coal-heavy use grid (operational-carbon-dominant end of
/// Table 1) so the embodied/operational crossovers land inside the
/// paper's 10³..10⁸ inference axis on our accelerator energy scale.
pub fn fig10_use_grid() -> crate::carbon::UseGrid {
    crate::carbon::UseGrid::Coal
}

/// Fig 10 output.
pub struct Fig10 {
    /// Inference-count axis.
    pub n_inf: Vec<f64>,
    /// Per-accelerator normalized carbon-efficiency series (A-1..A-4).
    pub series: Vec<(String, Vec<f64>)>,
    /// Per-accelerator operational-carbon share series (for the §5.3
    /// dominance-shift discussion).
    pub op_share: Vec<(String, Vec<f64>)>,
    /// Rendered table.
    pub table: Table,
}

/// Default axis: 10³..10⁸, half-decade steps.
pub fn default_axis() -> Vec<f64> {
    (0..11).map(|i| 10f64.powf(3.0 + 0.5 * i as f64)).collect()
}

/// Run the sweep.
pub fn run(engine: &mut dyn Engine, axis: &[f64]) -> crate::Result<Fig10> {
    let configs = production_accelerators().to_vec();
    let mut eff: Vec<Vec<f64>> = vec![Vec::new(); configs.len()];
    let mut share: Vec<Vec<f64>> = vec![Vec::new(); configs.len()];

    for &n in axis {
        let mut req = whole_life_request(&configs, &Workload::ALL, n);
        req.ci_use_g_per_j = fig10_use_grid().g_per_joule();
        let res = crate::dse::batching::evaluate_chunked(engine, &req)?;
        for i in 0..configs.len() {
            let tcdp = res.metric(MetricRow::Tcdp, i);
            eff[i].push(1.0 / tcdp);
            let c_op = res.metric(MetricRow::COp, i);
            let c_emb = res.metric(MetricRow::CEmb, i);
            share[i].push(c_op / (c_op + c_emb));
        }
    }

    // Normalize to A-1 at the first axis point.
    let norm = eff[0][0];
    for s in &mut eff {
        for v in s.iter_mut() {
            *v /= norm;
        }
    }

    let mut table = Table::new(
        "Fig 10 — carbon efficiency vs operational lifetime (norm. A-1 @ 1e3)",
        &["inferences", "A-1", "A-2", "A-3", "A-4"],
    );
    for (xi, &n) in axis.iter().enumerate() {
        table.row(&[
            format!("{n:.0e}"),
            format!("{:.3e}", eff[0][xi]),
            format!("{:.3e}", eff[1][xi]),
            format!("{:.3e}", eff[2][xi]),
            format!("{:.3e}", eff[3][xi]),
        ]);
    }

    let names: Vec<String> = configs.iter().map(|c| c.name.clone()).collect();
    Ok(Fig10 {
        n_inf: axis.to_vec(),
        series: names.iter().cloned().zip(eff).collect(),
        op_share: names.into_iter().zip(share).collect(),
        table,
    })
}

/// Index of the best accelerator at one axis point.
pub fn best_at(f: &Fig10, xi: usize) -> usize {
    let mut best = 0;
    for i in 1..f.series.len() {
        if f.series[i].1[xi] > f.series[best].1[xi] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::common::Ctx;

    fn fig10() -> Fig10 {
        run(Ctx::host().engine.as_mut(), &default_axis()).unwrap()
    }

    #[test]
    fn short_life_favors_low_embodied_long_life_favors_performance() {
        let f = fig10();
        // §5.3 pairwise claims: at 1e3 the low-embodied A-1 beats the
        // bigger A-3; by 1e8 the carbon-efficient point has switched to
        // A-3 (the paper's A-1→A-3 inflection), and A-2 is globally best.
        let series = |name: &str| &f.series.iter().find(|(n, _)| n == name).unwrap().1;
        let (a1, a3) = (series("A-1"), series("A-3"));
        assert!(a1[0] > a3[0], "at 1e3: A-1 {} !> A-3 {}", a1[0], a3[0]);
        let last = f.n_inf.len() - 1;
        assert!(a3[last] > a1[last] * 2.0, "at 1e8: A-3 should dominate A-1");
        assert_eq!(f.series[best_at(&f, last)].0, "A-2");
    }

    #[test]
    fn a2_a4_crossover_exists() {
        // Paper: below ~1e5 A-2 and A-4 are comparable (A-4's 4x lower
        // embodied offsets performance); beyond, A-2 pulls away.
        let f = fig10();
        let a2 = &f.series.iter().find(|(n, _)| n == "A-2").unwrap().1;
        let a4 = &f.series.iter().find(|(n, _)| n == "A-4").unwrap().1;
        let first_ratio = a2[0] / a4[0];
        let last_ratio = a2[a2.len() - 1] / a4[a4.len() - 1];
        assert!(first_ratio < 1.6, "at 1e3, A-2/A-4 = {first_ratio}");
        assert!(last_ratio > 2.0, "at 1e8, A-2/A-4 = {last_ratio}");
    }

    #[test]
    fn operational_share_rises_with_lifetime() {
        let f = fig10();
        for (name, shares) in &f.op_share {
            assert!(
                shares.first().unwrap() < shares.last().unwrap(),
                "{name}: op share not rising"
            );
            // §5.3: A-3 moves from ~20% to ~70% dominance within 1e6..1e7.
            if name == "A-3" {
                assert!(*shares.first().unwrap() < 0.3, "A-3 early share {}", shares[0]);
                assert!(*shares.last().unwrap() > 0.7, "A-3 late share");
            }
        }
    }

    #[test]
    fn efficiency_monotone_nonincreasing_along_axis_is_false() {
        // Sanity: raw (unnormalized-per-inference) efficiency falls with
        // more inferences (more total carbon·delay); the *relative* story
        // is what Fig 10 shows. Just assert the series are positive.
        let f = fig10();
        for (_, s) in &f.series {
            assert!(s.iter().all(|&v| v > 0.0));
        }
    }
}
