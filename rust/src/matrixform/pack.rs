//! Packing an [`EvalRequest`] into the fixed AOT artifact shapes.
//!
//! Padding rules (the runtime contract, mirrored in
//! `python/compile/model.py`):
//! * `N`, `d_k`, `p_leak`, `p_dyn`, `c_comp` pad with zeros (inert rows);
//! * `f_clk` pads with 1.0 (avoids 0/0 in the energy division);
//! * `qos` pads with +∞ (never constrains phantom tasks);
//! * config rows beyond the logical batch are zeros → zero metrics.
//!
//! The per-kernel tensors are carried in two layouts: the row-major
//! `[c_pad × K_PAD]` arrays the AOT artifacts consume, and a columnar
//! (config-transposed) `[K_PAD × c_pad]` view (`p_leak_col`, `p_dyn_col`,
//! `d_k_col`) built once at packing time for the host engine's
//! lane-blocked phase-A kernel: with configs contiguous per kernel row, a
//! block of `LANES` adjacent configs loads as one contiguous slice per
//! `ki` (see `runtime/host.rs::contract_tasks_block`). The columnar view
//! is a pure transpose of the padded row-major data — same f32 bits, no
//! re-quantization — so either layout contracts bit-identically.

use super::types::{EvalRequest, EvalResult};

/// Padded task dimension (must match `model.T_PAD`).
pub const T_PAD: usize = 8;
/// Padded kernel dimension (must match `model.K_PAD`).
pub const K_PAD: usize = 32;
/// Padded component dimension (must match `model.J_PAD`).
pub const J_PAD: usize = 16;
/// Metric row count.
pub const NUM_METRICS: usize = 12;
/// Config-batch variants compiled into artifacts.
pub const C_VARIANTS: [usize; 2] = [128, 1024];

/// A padded, f32, artifact-shaped problem.
#[derive(Debug, Clone)]
pub struct PackedProblem {
    /// `[T_PAD × K_PAD]`.
    pub n: Vec<f32>,
    /// `[c_pad × K_PAD]`.
    pub p_leak: Vec<f32>,
    /// `[c_pad × K_PAD]`.
    pub p_dyn: Vec<f32>,
    /// `[c_pad × 1]`.
    pub f_clk: Vec<f32>,
    /// `[c_pad × K_PAD]`.
    pub d_k: Vec<f32>,
    /// Columnar view of `p_leak`: `[K_PAD × c_pad]` (configs contiguous).
    pub p_leak_col: Vec<f32>,
    /// Columnar view of `p_dyn`: `[K_PAD × c_pad]`.
    pub p_dyn_col: Vec<f32>,
    /// Columnar view of `d_k`: `[K_PAD × c_pad]`.
    pub d_k_col: Vec<f32>,
    /// `[c_pad × J_PAD]`.
    pub c_comp: Vec<f32>,
    /// `[J_PAD]`.
    pub online: Vec<f32>,
    /// `[T_PAD]`.
    pub qos: Vec<f32>,
    /// `[ci_use, lifetime_s, beta, p_max]`.
    pub scalars: [f32; 4],
    /// Padded batch size (one of `C_VARIANTS`).
    pub c_pad: usize,
    /// Logical batch size.
    pub c: usize,
    /// Logical task count.
    pub t: usize,
    /// Logical kernel count.
    pub k: usize,
    /// Config names (logical batch order).
    pub names: Vec<String>,
}

/// Smallest artifact variant that fits `c` configs.
pub fn variant_for(c: usize) -> Option<usize> {
    C_VARIANTS.iter().copied().find(|&v| v >= c)
}

impl PackedProblem {
    /// Pack a validated request. Requests larger than the largest variant
    /// must be split by the coordinator (`dse::batching`).
    pub fn from_request(req: &EvalRequest) -> Self {
        req.validate();
        let t = req.tasks.num_tasks();
        let k = req.tasks.num_kernels();
        let c = req.configs.len();
        assert!(t <= T_PAD, "too many tasks ({t} > {T_PAD})");
        assert!(k <= K_PAD, "too many kernels ({k} > {K_PAD})");
        let j = req.online.len();
        assert!(j <= J_PAD, "too many components ({j} > {J_PAD})");
        let c_pad = variant_for(c)
            .unwrap_or_else(|| panic!("batch of {c} exceeds largest variant; split it"));

        let mut n = vec![0.0f32; T_PAD * K_PAD];
        for ti in 0..t {
            for ki in 0..k {
                n[ti * K_PAD + ki] = req.tasks.get(ti, ki) as f32;
            }
        }

        let mut p_leak = vec![0.0f32; c_pad * K_PAD];
        let mut p_dyn = vec![0.0f32; c_pad * K_PAD];
        let mut d_k = vec![0.0f32; c_pad * K_PAD];
        let mut f_clk = vec![1.0f32; c_pad];
        let mut c_comp = vec![0.0f32; c_pad * J_PAD];
        let mut names = Vec::with_capacity(c);
        for (ci, cfg) in req.configs.iter().enumerate() {
            let pl = cfg.p_leak();
            let pd = cfg.p_dyn();
            for ki in 0..k {
                p_leak[ci * K_PAD + ki] = pl[ki] as f32;
                p_dyn[ci * K_PAD + ki] = pd[ki] as f32;
                d_k[ci * K_PAD + ki] = cfg.d_k[ki] as f32;
            }
            f_clk[ci] = cfg.f_clk as f32;
            for ji in 0..j {
                c_comp[ci * J_PAD + ji] = cfg.c_comp[ji] as f32;
            }
            names.push(cfg.name.clone());
        }

        // Columnar transpose for the lane-blocked kernel. Padding
        // configs (ci >= c) are all-zero in the row-major arrays, so the
        // zero-initialized columns already carry them.
        let mut p_leak_col = vec![0.0f32; K_PAD * c_pad];
        let mut p_dyn_col = vec![0.0f32; K_PAD * c_pad];
        let mut d_k_col = vec![0.0f32; K_PAD * c_pad];
        for ci in 0..c {
            for ki in 0..K_PAD {
                p_leak_col[ki * c_pad + ci] = p_leak[ci * K_PAD + ki];
                p_dyn_col[ki * c_pad + ci] = p_dyn[ci * K_PAD + ki];
                d_k_col[ki * c_pad + ci] = d_k[ci * K_PAD + ki];
            }
        }

        let mut online = vec![0.0f32; J_PAD];
        for ji in 0..j {
            online[ji] = req.online[ji] as f32;
        }
        let mut qos = vec![f32::INFINITY; T_PAD];
        for ti in 0..t {
            qos[ti] = req.qos[ti] as f32;
        }

        PackedProblem {
            n,
            p_leak,
            p_dyn,
            f_clk,
            d_k,
            p_leak_col,
            p_dyn_col,
            d_k_col,
            c_comp,
            online,
            qos,
            scalars: [
                req.ci_use_g_per_j as f32,
                req.lifetime_s as f32,
                req.beta as f32,
                req.p_max_w as f32,
            ],
            c_pad,
            c,
            t,
            k,
            names,
        }
    }

    /// Unpack raw engine output (`metrics [12 × c_pad]`, `d_task
    /// [c_pad × T_PAD]`) into a logical-size [`EvalResult`].
    pub fn unpack(&self, metrics_pad: &[f32], d_task_pad: &[f32]) -> EvalResult {
        assert_eq!(metrics_pad.len(), NUM_METRICS * self.c_pad, "bad metrics buffer");
        assert_eq!(d_task_pad.len(), self.c_pad * T_PAD, "bad d_task buffer");
        let mut metrics = vec![0.0f64; NUM_METRICS * self.c];
        for row in 0..NUM_METRICS {
            for ci in 0..self.c {
                metrics[row * self.c + ci] = metrics_pad[row * self.c_pad + ci] as f64;
            }
        }
        let mut d_task = vec![0.0f64; self.c * self.t];
        for ci in 0..self.c {
            for ti in 0..self.t {
                d_task[ci * self.t + ti] = d_task_pad[ci * T_PAD + ti] as f64;
            }
        }
        EvalResult { names: self.names.clone(), metrics, d_task, c: self.c, t: self.t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrixform::types::{ConfigRow, TaskMatrix};

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[3.0, 1.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![1e-3, 2e-3],
                    e_dyn: vec![0.01, 0.02],
                    leak_w: 0.1,
                    c_comp: vec![10.0, 20.0],
                })
                .collect(),
            online: vec![1.0, 1.0],
            qos: vec![f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    #[test]
    fn pads_to_smallest_variant() {
        assert_eq!(PackedProblem::from_request(&request(5)).c_pad, 128);
        assert_eq!(PackedProblem::from_request(&request(128)).c_pad, 128);
        assert_eq!(PackedProblem::from_request(&request(129)).c_pad, 1024);
    }

    #[test]
    #[should_panic(expected = "exceeds largest variant")]
    fn oversized_batch_panics() {
        PackedProblem::from_request(&request(1025));
    }

    #[test]
    fn padding_values_follow_contract() {
        let p = PackedProblem::from_request(&request(3));
        // f_clk pad = 1.0.
        assert_eq!(p.f_clk[3], 1.0);
        assert_eq!(p.f_clk[127], 1.0);
        // d_k pad = 0.
        assert_eq!(p.d_k[3 * K_PAD], 0.0);
        // qos pad = inf.
        assert_eq!(p.qos[1], f32::INFINITY);
        // N pad rows = 0.
        assert_eq!(p.n[1 * K_PAD], 0.0);
        // Logical entries present.
        assert_eq!(p.n[0], 3.0);
        assert_eq!(p.d_k[0], 1e-3);
        assert_eq!(p.c_comp[1], 20.0);
        assert_eq!(p.online[1], 1.0);
        assert_eq!(p.online[2], 0.0);
    }

    #[test]
    fn columnar_view_is_an_exact_transpose() {
        let p = PackedProblem::from_request(&request(3));
        assert_eq!(p.p_leak_col.len(), K_PAD * p.c_pad);
        assert_eq!(p.p_dyn_col.len(), K_PAD * p.c_pad);
        assert_eq!(p.d_k_col.len(), K_PAD * p.c_pad);
        for ci in 0..p.c_pad {
            for ki in 0..K_PAD {
                assert_eq!(
                    p.p_leak_col[ki * p.c_pad + ci].to_bits(),
                    p.p_leak[ci * K_PAD + ki].to_bits()
                );
                assert_eq!(
                    p.p_dyn_col[ki * p.c_pad + ci].to_bits(),
                    p.p_dyn[ci * K_PAD + ki].to_bits()
                );
                assert_eq!(
                    p.d_k_col[ki * p.c_pad + ci].to_bits(),
                    p.d_k[ci * K_PAD + ki].to_bits()
                );
            }
        }
    }

    #[test]
    fn unpack_strips_padding() {
        let p = PackedProblem::from_request(&request(3));
        let mut metrics = vec![0.0f32; NUM_METRICS * 128];
        for row in 0..NUM_METRICS {
            for ci in 0..128 {
                metrics[row * 128 + ci] = (row * 1000 + ci) as f32;
            }
        }
        let d_task = vec![7.0f32; 128 * T_PAD];
        let res = p.unpack(&metrics, &d_task);
        assert_eq!(res.c, 3);
        assert_eq!(res.metrics.len(), NUM_METRICS * 3);
        assert_eq!(res.metric(crate::matrixform::MetricRow::Delay, 2), 1002.0);
        assert_eq!(res.d_task.len(), 3);
        assert_eq!(res.task_delay(1, 0), 7.0);
    }
}
