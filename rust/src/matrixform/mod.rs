//! The §3.3 matrix formalization as typed data (Table 2).
//!
//! The coordinator assembles evaluation batches here: a task matrix `N`
//! (kernel calls per task), per-config rows (kernel delays, power terms,
//! component embodied carbon), constraint vectors and the four scalars —
//! then packs everything, zero-padded, into the fixed shapes the AOT
//! artifacts expect (`T=8, K=32, J=16, C ∈ {128, 1024}`).

mod pack;
mod profile;
mod types;

pub use pack::{PackedProblem, C_VARIANTS, J_PAD, K_PAD, NUM_METRICS, T_PAD};
pub use profile::{DesignProfile, ProfileRequest};
pub use types::{ConfigRow, EvalRequest, EvalResult, MetricRow, TaskMatrix};
