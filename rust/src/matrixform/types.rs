//! Evaluation-request types: the paper's N / D / E / C matrices.

/// The task matrix `N` — kernel calls per task (§3.3, Table 2). A zero
/// entry means the kernel is not part of that task.
#[derive(Debug, Clone)]
pub struct TaskMatrix {
    /// Task names (rows).
    pub tasks: Vec<String>,
    /// Kernel names (columns).
    pub kernels: Vec<String>,
    /// Row-major `[tasks × kernels]` call counts.
    pub n: Vec<f64>,
}

impl TaskMatrix {
    /// All-zero matrix.
    pub fn new(tasks: Vec<String>, kernels: Vec<String>) -> Self {
        let n = vec![0.0; tasks.len() * kernels.len()];
        TaskMatrix { tasks, kernels, n }
    }

    /// Single-task helper: one task invoking each kernel `calls` times.
    pub fn single_task(name: &str, kernels: Vec<String>, calls: &[f64]) -> Self {
        assert_eq!(kernels.len(), calls.len());
        TaskMatrix { tasks: vec![name.to_string()], kernels, n: calls.to_vec() }
    }

    /// Set `N[task, kernel] = calls`.
    pub fn set(&mut self, task: usize, kernel: usize, calls: f64) {
        assert!(task < self.tasks.len() && kernel < self.kernels.len());
        assert!(calls >= 0.0, "negative call count");
        let k = self.kernels.len();
        self.n[task * k + kernel] = calls;
    }

    /// Read `N[task, kernel]`.
    pub fn get(&self, task: usize, kernel: usize) -> f64 {
        self.n[task * self.kernels.len() + kernel]
    }

    /// Number of tasks (rows).
    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Number of kernels (columns).
    pub fn num_kernels(&self) -> usize {
        self.kernels.len()
    }
}

/// One candidate hardware configuration's row data.
///
/// The paper's per-kernel "power over clock" formulation is encoded
/// physically: `p_leak[k] = leak_w · d_k[k] · f_clk` and
/// `p_dyn[k] = e_dyn[k] · f_clk`, so that
/// `(P_leak + P_dyn) / f_clk = leak_w·d + e_dyn` — leakage energy plus
/// dynamic energy per kernel call, in joules.
#[derive(Debug, Clone)]
pub struct ConfigRow {
    /// Config name.
    pub name: String,
    /// Clock, Hz.
    pub f_clk: f64,
    /// Per-kernel delay, s (one entry per kernel column).
    pub d_k: Vec<f64>,
    /// Per-kernel dynamic energy per call, J.
    pub e_dyn: Vec<f64>,
    /// Constant leakage power, W.
    pub leak_w: f64,
    /// Per-component embodied carbon, g (provisioning vector, §3.3.3).
    pub c_comp: Vec<f64>,
}

impl ConfigRow {
    /// The paper-form `P_leak` vector (see type docs).
    pub fn p_leak(&self) -> Vec<f64> {
        self.d_k.iter().map(|d| self.leak_w * d * self.f_clk).collect()
    }

    /// The paper-form `P_dyn` vector.
    pub fn p_dyn(&self) -> Vec<f64> {
        self.e_dyn.iter().map(|e| e * self.f_clk).collect()
    }

    /// Total embodied carbon with all components online, g.
    pub fn embodied_total_g(&self) -> f64 {
        self.c_comp.iter().sum()
    }
}

/// A full evaluation request over a batch of configurations.
#[derive(Debug, Clone)]
pub struct EvalRequest {
    /// Task matrix `N`.
    pub tasks: TaskMatrix,
    /// Candidate configurations (each with `d_k`/`e_dyn` matching the
    /// kernel columns of `tasks` and `c_comp` of a common length `J`).
    pub configs: Vec<ConfigRow>,
    /// Component online mask (length = `c_comp` length).
    pub online: Vec<f64>,
    /// Per-task delay bounds, s (`f64::INFINITY` = unconstrained).
    pub qos: Vec<f64>,
    /// Use-phase carbon intensity, g/J.
    pub ci_use_g_per_j: f64,
    /// Operational lifetime (LT − D_idle), s.
    pub lifetime_s: f64,
    /// β of the scalarized objective (1 = exact tCDP).
    pub beta: f64,
    /// Average-power cap, W (`f64::INFINITY` = unconstrained).
    pub p_max_w: f64,
}

impl EvalRequest {
    /// Validate dimension coherence; panics with a precise message.
    pub fn validate(&self) {
        let k = self.tasks.num_kernels();
        let t = self.tasks.num_tasks();
        assert!(!self.configs.is_empty(), "no configs in request");
        let j = self.configs[0].c_comp.len();
        for c in &self.configs {
            assert_eq!(c.d_k.len(), k, "{}: d_k len != kernels", c.name);
            assert_eq!(c.e_dyn.len(), k, "{}: e_dyn len != kernels", c.name);
            assert_eq!(c.c_comp.len(), j, "{}: c_comp len mismatch", c.name);
            assert!(c.f_clk > 0.0, "{}: non-positive clock", c.name);
        }
        assert_eq!(self.online.len(), j, "online mask len != components");
        assert_eq!(self.qos.len(), t, "qos len != tasks");
        assert!(self.lifetime_s > 0.0, "non-positive lifetime");
        assert!(self.beta >= 0.0, "negative beta");
    }
}

/// Row indices of the metrics matrix produced by the runtime (must match
/// `python/compile/kernels/ref.py::METRIC_ROWS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricRow {
    /// ||E||₁, J.
    Energy = 0,
    /// ||D||₁, s.
    Delay = 1,
    /// Operational carbon, g.
    COp = 2,
    /// Amortized embodied carbon, g.
    CEmb = 3,
    /// Total carbon, g.
    CTotal = 4,
    /// (C_op + β·C_emb)·D.
    Tcdp = 5,
    /// E·D.
    Edp = 6,
    /// C_emb·D.
    Cdp = 7,
    /// C_emb·E.
    Cep = 8,
    /// C_emb·E².
    Ce2p = 9,
    /// C_emb²·E.
    C2ep = 10,
    /// Constraint mask.
    Feasible = 11,
}

/// Unpacked evaluation result for the logical (unpadded) batch.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Config names, batch order.
    pub names: Vec<String>,
    /// `[12 × C]` metric rows (row-major, logical C).
    pub metrics: Vec<f64>,
    /// `[C × T]` per-task delays.
    pub d_task: Vec<f64>,
    /// Logical batch size.
    pub c: usize,
    /// Logical task count.
    pub t: usize,
}

impl EvalResult {
    /// Result of evaluating zero configs: every buffer empty, metric
    /// rows zero-length. `merge`/`summarize` compose with it naturally
    /// (no feasible designs, no optima) — the well-defined outcome of an
    /// empty request instead of a panic in the pack layer.
    pub fn empty(t: usize) -> EvalResult {
        EvalResult { names: Vec::new(), metrics: Vec::new(), d_task: Vec::new(), c: 0, t }
    }

    /// Metric value for one config.
    pub fn metric(&self, row: MetricRow, config: usize) -> f64 {
        assert!(config < self.c);
        self.metrics[row as usize * self.c + config]
    }

    /// All values of one metric row.
    pub fn row(&self, row: MetricRow) -> &[f64] {
        &self.metrics[row as usize * self.c..(row as usize + 1) * self.c]
    }

    /// Per-task delay for one config.
    pub fn task_delay(&self, config: usize, task: usize) -> f64 {
        assert!(config < self.c && task < self.t);
        self.d_task[config * self.t + task]
    }

    /// Index of the feasible config minimizing a metric row.
    pub fn argmin_feasible(&self, row: MetricRow) -> Option<usize> {
        let vals = self.row(row);
        let feas = self.row(MetricRow::Feasible);
        // Manual scan: argmin over configs with feasible == 1.
        let mut best: Option<(usize, f64)> = None;
        for i in 0..self.c {
            if feas[i] < 0.5 || !vals[i].is_finite() {
                continue;
            }
            match best {
                Some((_, bv)) if bv <= vals[i] => {}
                _ => best = Some((i, vals[i])),
            }
        }
        best.map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_request() -> EvalRequest {
        let mut tm = TaskMatrix::new(
            vec!["t0".into(), "t1".into()],
            vec!["k0".into(), "k1".into(), "k2".into()],
        );
        tm.set(0, 0, 5.0);
        tm.set(1, 2, 2.0);
        EvalRequest {
            tasks: tm,
            configs: vec![ConfigRow {
                name: "c0".into(),
                f_clk: 1e9,
                d_k: vec![1e-3, 2e-3, 3e-3],
                e_dyn: vec![1e-2, 2e-2, 3e-2],
                leak_w: 0.05,
                c_comp: vec![100.0, 50.0],
            }],
            online: vec![1.0, 1.0],
            qos: vec![f64::INFINITY, f64::INFINITY],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }

    #[test]
    fn task_matrix_set_get() {
        let r = tiny_request();
        assert_eq!(r.tasks.get(0, 0), 5.0);
        assert_eq!(r.tasks.get(0, 1), 0.0);
        assert_eq!(r.tasks.get(1, 2), 2.0);
    }

    #[test]
    fn paper_form_power_encoding_roundtrips() {
        // (p_leak + p_dyn) / f_clk must equal leak_w*d + e_dyn.
        let r = tiny_request();
        let c = &r.configs[0];
        let pl = c.p_leak();
        let pd = c.p_dyn();
        for k in 0..3 {
            let energy = (pl[k] + pd[k]) / c.f_clk;
            let expect = c.leak_w * c.d_k[k] + c.e_dyn[k];
            assert!((energy - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn validate_accepts_coherent_request() {
        tiny_request().validate();
    }

    #[test]
    #[should_panic(expected = "qos len")]
    fn validate_rejects_bad_qos() {
        let mut r = tiny_request();
        r.qos = vec![1.0];
        r.validate();
    }

    #[test]
    #[should_panic(expected = "d_k len")]
    fn validate_rejects_bad_kernel_dim() {
        let mut r = tiny_request();
        r.configs[0].d_k.pop();
        r.validate();
    }

    #[test]
    fn eval_result_accessors() {
        let res = EvalResult {
            names: vec!["a".into(), "b".into()],
            metrics: {
                let mut m = vec![0.0; 24];
                m[MetricRow::Tcdp as usize * 2] = 3.0; // a
                m[MetricRow::Tcdp as usize * 2 + 1] = 1.0; // b
                m[MetricRow::Feasible as usize * 2] = 1.0;
                m[MetricRow::Feasible as usize * 2 + 1] = 1.0;
                m
            },
            d_task: vec![0.5, 0.6],
            c: 2,
            t: 1,
        };
        assert_eq!(res.metric(MetricRow::Tcdp, 0), 3.0);
        assert_eq!(res.argmin_feasible(MetricRow::Tcdp), Some(1));
        assert_eq!(res.task_delay(1, 0), 0.6);
    }

    #[test]
    fn argmin_skips_infeasible() {
        let mut metrics = vec![0.0; 24];
        metrics[MetricRow::Tcdp as usize * 2] = 5.0;
        metrics[MetricRow::Tcdp as usize * 2 + 1] = 1.0;
        metrics[MetricRow::Feasible as usize * 2] = 1.0; // only config 0 feasible
        let res = EvalResult {
            names: vec!["a".into(), "b".into()],
            metrics,
            d_task: vec![0.0, 0.0],
            c: 2,
            t: 1,
        };
        assert_eq!(res.argmin_feasible(MetricRow::Tcdp), Some(0));
    }
}
