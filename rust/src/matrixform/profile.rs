//! Scenario-invariant design profiles — phase A of the two-phase
//! evaluation pipeline.
//!
//! An [`super::EvalRequest`] mixes two very different kinds of input: the
//! *design space* (task matrix `N`, per-config kernel delays/energies and
//! component embodied-carbon rows — the expensive O(C×T×K) contraction)
//! and the *scenario* (`ci_use`, `lifetime`, `β`, `qos`, `p_max`,
//! `online` — a handful of scalars folded in with O(C) arithmetic).
//! Multi-scenario sweeps re-run the same design space under many
//! scenarios, so the pipeline splits here:
//!
//! * [`ProfileRequest`] — the scenario-invariant half of a request;
//! * [`DesignProfile`] — the engine's contraction of one packed batch
//!   into per-config totals (energy, delay), per-task delays and the
//!   per-provisioning-group `c_comp` row, all still padded f32 so that a
//!   [`crate::carbon::ScenarioOverlay`] (phase B) reproduces the fused
//!   engine's arithmetic bit-for-bit.

use super::pack::{PackedProblem, J_PAD, NUM_METRICS, T_PAD};
use super::types::{ConfigRow, EvalRequest, EvalResult, TaskMatrix};

/// The scenario-invariant half of an [`EvalRequest`]: the design space
/// and its task matrix, without any scenario knobs.
#[derive(Debug, Clone)]
pub struct ProfileRequest {
    /// Task matrix `N`.
    pub tasks: TaskMatrix,
    /// Candidate configurations.
    pub configs: Vec<ConfigRow>,
}

impl ProfileRequest {
    /// Strip the scenario half off a full request.
    pub fn from_eval(req: &EvalRequest) -> Self {
        ProfileRequest { tasks: req.tasks.clone(), configs: req.configs.clone() }
    }

    /// Neutral [`EvalRequest`] used for packing: the scenario knobs are
    /// inert placeholders (profiling reads only the design-space tensors,
    /// which pack identically under every scenario).
    pub fn to_eval(&self) -> EvalRequest {
        self.chunk_eval(self.configs.clone())
    }

    /// Neutral request over one chunk of this space's configs — same
    /// inert scenario knobs as [`Self::to_eval`] without cloning the
    /// whole config list (chunk builders hand ownership in directly).
    pub fn chunk_eval(&self, configs: Vec<ConfigRow>) -> EvalRequest {
        let j = configs.first().map(|c| c.c_comp.len()).unwrap_or(0);
        EvalRequest {
            tasks: self.tasks.clone(),
            configs,
            online: vec![1.0; j],
            qos: vec![f64::INFINITY; self.tasks.num_tasks()],
            ci_use_g_per_j: 0.0,
            lifetime_s: 1.0,
            beta: 1.0,
            p_max_w: f64::INFINITY,
        }
    }
}

/// One packed batch contracted into scenario-invariant per-config data
/// (phase A output). Holds everything a scenario overlay needs — the f32
/// values are exactly the ones the fused engine computes internally, so
/// overlay composition is bit-identical to the fused path.
#[derive(Debug, Clone)]
pub struct DesignProfile {
    /// `[c_pad]` total energy per config, J (||E||₁ in f32).
    pub energy: Vec<f32>,
    /// `[c_pad]` total delay per config, s (||D||₁ in f32).
    pub delay: Vec<f32>,
    /// `[c_pad × T_PAD]` per-task delays, s.
    pub d_task: Vec<f32>,
    /// `[c_pad × J_PAD]` per-provisioning-group embodied carbon, g
    /// (copied from the packed batch; the overlay's `online` mask
    /// contracts it per scenario).
    pub c_comp: Vec<f32>,
    /// Padded batch size.
    pub c_pad: usize,
    /// Logical batch size.
    pub c: usize,
    /// Logical task count.
    pub t: usize,
    /// Config names (logical batch order).
    pub names: Vec<String>,
}

impl DesignProfile {
    /// Assemble a profile from a packed batch and the engine's raw
    /// scenario-invariant buffers.
    pub fn from_parts(
        packed: &PackedProblem,
        energy: Vec<f32>,
        delay: Vec<f32>,
        d_task: Vec<f32>,
    ) -> Self {
        assert_eq!(energy.len(), packed.c_pad, "bad energy buffer");
        assert_eq!(delay.len(), packed.c_pad, "bad delay buffer");
        assert_eq!(d_task.len(), packed.c_pad * T_PAD, "bad d_task buffer");
        DesignProfile {
            energy,
            delay,
            d_task,
            c_comp: packed.c_comp.clone(),
            c_pad: packed.c_pad,
            c: packed.c,
            t: packed.t,
            names: packed.names.clone(),
        }
    }

    /// Unpack overlay-produced padded metric rows (plus this profile's
    /// per-task delays) into a logical-size [`EvalResult`] — the same
    /// stripping `PackedProblem::unpack` applies to fused output.
    pub fn unpack(&self, metrics_pad: &[f32]) -> EvalResult {
        assert_eq!(metrics_pad.len(), NUM_METRICS * self.c_pad, "bad metrics buffer");
        let mut metrics = vec![0.0f64; NUM_METRICS * self.c];
        for row in 0..NUM_METRICS {
            for ci in 0..self.c {
                metrics[row * self.c + ci] = metrics_pad[row * self.c_pad + ci] as f64;
            }
        }
        let mut d_task = vec![0.0f64; self.c * self.t];
        for ci in 0..self.c {
            for ti in 0..self.t {
                d_task[ci * self.t + ti] = self.d_task[ci * T_PAD + ti] as f64;
            }
        }
        EvalResult { names: self.names.clone(), metrics, d_task, c: self.c, t: self.t }
    }

    /// Total embodied carbon of one config with all components online, g
    /// (f32 row sum in component order).
    pub fn embodied_total(&self, config: usize) -> f32 {
        assert!(config < self.c);
        self.c_comp[config * J_PAD..(config + 1) * J_PAD].iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn request(c: usize) -> EvalRequest {
        let tm = TaskMatrix::single_task("t", vec!["k0".into(), "k1".into()], &[3.0, 1.0]);
        EvalRequest {
            tasks: tm,
            configs: (0..c)
                .map(|i| ConfigRow {
                    name: format!("cfg{i}"),
                    f_clk: 1e9,
                    d_k: vec![1e-3, 2e-3],
                    e_dyn: vec![0.01, 0.02],
                    leak_w: 0.1,
                    c_comp: vec![10.0, 20.0],
                })
                .collect(),
            online: vec![1.0, 0.0],
            qos: vec![0.5],
            ci_use_g_per_j: 1e-4,
            lifetime_s: 1e6,
            beta: 2.0,
            p_max_w: 30.0,
        }
    }

    #[test]
    fn profile_request_strips_scenario_half() {
        let req = request(3);
        let p = ProfileRequest::from_eval(&req);
        assert_eq!(p.configs.len(), 3);
        let neutral = p.to_eval();
        neutral.validate();
        // Scenario knobs are inert, the design space is untouched.
        assert_eq!(neutral.qos, vec![f64::INFINITY]);
        assert_eq!(neutral.online, vec![1.0, 1.0]);
        assert_eq!(neutral.configs.len(), 3);
        assert_eq!(neutral.tasks.get(0, 0), 3.0);
    }

    #[test]
    fn from_parts_copies_packing_metadata() {
        let packed = PackedProblem::from_request(&request(5));
        let c_pad = packed.c_pad;
        let prof = DesignProfile::from_parts(
            &packed,
            vec![1.0; c_pad],
            vec![2.0; c_pad],
            vec![0.5; c_pad * T_PAD],
        );
        assert_eq!(prof.c, 5);
        assert_eq!(prof.c_pad, c_pad);
        assert_eq!(prof.names[4], "cfg4");
        assert_eq!(prof.c_comp.len(), c_pad * J_PAD);
        assert!((prof.embodied_total(0) - 30.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "bad energy buffer")]
    fn from_parts_rejects_bad_buffers() {
        let packed = PackedProblem::from_request(&request(2));
        DesignProfile::from_parts(&packed, vec![1.0; 3], vec![], vec![]);
    }

    #[test]
    fn unpack_strips_padding_like_packed_problem() {
        let packed = PackedProblem::from_request(&request(3));
        let c_pad = packed.c_pad;
        let mut d_task = vec![0.0f32; c_pad * T_PAD];
        for ci in 0..c_pad {
            d_task[ci * T_PAD] = 7.0 + ci as f32;
        }
        let prof =
            DesignProfile::from_parts(&packed, vec![1.0; c_pad], vec![2.0; c_pad], d_task);
        let mut metrics = vec![0.0f32; NUM_METRICS * c_pad];
        for row in 0..NUM_METRICS {
            for ci in 0..c_pad {
                metrics[row * c_pad + ci] = (row * 1000 + ci) as f32;
            }
        }
        let res = prof.unpack(&metrics);
        assert_eq!(res.c, 3);
        assert_eq!(res.metric(crate::matrixform::MetricRow::Delay, 2), 1002.0);
        assert_eq!(res.task_delay(1, 0), 8.0);
    }
}
