//! JSON value type, recursive-descent parser and writer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64, like JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("JSON error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset in the input.
    pub pos: usize,
    /// Description.
    pub msg: String,
}

impl Json {
    /// Typed accessors (None on type mismatch).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number. `None` unless the value is an exact
    /// integer representable as `i64` — non-integral numbers (`2.7`),
    /// NaN/∞ and out-of-range magnitudes are rejected rather than
    /// rounded or saturated, so counters round-tripped through cache
    /// envelopes and checkpoints can never silently drift.
    pub fn as_i64(&self) -> Option<i64> {
        match self.as_f64() {
            // 2^63 is exactly representable as f64; i64 covers
            // [-2^63, 2^63) so the upper bound is strict. fract() is NaN
            // for NaN/∞, which fails the == 0.0 test.
            Some(f) if f.fract() == 0.0 && f >= -(2f64.powi(63)) && f < 2f64.powi(63) => {
                Some(f as i64)
            }
            _ => None,
        }
    }

    /// Narrowing integer view: `Some` only for exact integers (per
    /// [`Self::as_i64`]) that also fit `usize` — the shared accessor for
    /// counters in cache envelopes and checkpoints.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object view.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    // JSON has no Infinity/NaN; emit null like JS JSON.stringify.
                    write!(f, "null")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document (must consume the whole input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Rng};

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1, "k": 32, "num_metrics": 12,
          "variants": {"128": {"file": "dse_metrics_c128.hlo.txt"}}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_i64(), Some(1));
        let f = v.get("variants").unwrap().get("128").unwrap().get("file").unwrap();
        assert_eq!(f.as_str(), Some("dse_metrics_c128.hlo.txt"));
    }

    #[test]
    fn as_i64_is_strict() {
        // Exact integers pass.
        assert_eq!(Json::Num(0.0).as_i64(), Some(0));
        assert_eq!(Json::Num(-7.0).as_i64(), Some(-7));
        assert_eq!(Json::Num(2f64.powi(32)).as_i64(), Some(1i64 << 32));
        assert_eq!(Json::Num(-(2f64.powi(63))).as_i64(), Some(i64::MIN));
        // Non-integral numbers are rejected, not rounded.
        assert_eq!(Json::Num(2.7).as_i64(), None);
        assert_eq!(Json::Num(-0.5).as_i64(), None);
        // Out-of-i64-range magnitudes are rejected, not saturated.
        assert_eq!(Json::Num(2f64.powi(63)).as_i64(), None);
        assert_eq!(Json::Num(1e300).as_i64(), None);
        assert_eq!(Json::Num(-1e300).as_i64(), None);
        // Non-finite and non-numeric values are rejected.
        assert_eq!(Json::Num(f64::NAN).as_i64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_i64(), None);
        assert_eq!(Json::Str("3".into()).as_i64(), None);
        // Parsed documents behave the same.
        assert_eq!(parse("3.0001").unwrap().as_i64(), None);
        assert_eq!(parse("42").unwrap().as_i64(), Some(42));
        // The usize view additionally rejects negatives.
        assert_eq!(Json::Num(42.0).as_usize(), Some(42));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(2.5).as_usize(), None);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let v = parse("\"caf\u{e9} \\u0041 \\\\ \\\"\"").unwrap();
        assert_eq!(v.as_str(), Some("café A \\ \""));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_carries_position() {
        let e = parse("[1, x]").unwrap_err();
        assert_eq!(e.pos, 4);
    }

    fn gen_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.chance(0.5)),
            2 => Json::Num((r.range(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = r.below(8);
                Json::Str((0..n).map(|_| (b'a' + r.below(26) as u8) as char).collect())
            }
            4 => Json::Arr((0..r.below(4)).map(|_| gen_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_print_parse_roundtrip() {
        forall(|r| gen_json(r, 3), |v| parse(&v.to_string()).as_ref() == Ok(v));
    }
}
