//! Little-endian binary envelopes with a digest trailer — the fast
//! on-disk sidecar format for bulk `f32` payloads.
//!
//! The JSON envelopes (`configfmt::json`) stay the readable source of
//! truth, but they cost ~10 bytes per `f32` (bit patterns rendered as
//! decimal integers) and a full parse on every warm read. A binary
//! envelope stores the same bits raw: 4 bytes per value plus a small
//! header, read back with bounds-checked cursor scans instead of a
//! recursive-descent parse.
//!
//! Layout: `magic (4 bytes) · schema (u32) · body · digest (16 bytes)`
//! where the trailing digest is [`digest128`] over *everything before
//! it* (magic and schema included). Readers verify magic, schema and the
//! digest before handing out a cursor; every `take_*` is bounds-checked
//! and returns `None` past the end, so truncated or corrupted envelopes
//! fail validation instead of panicking — the same reject-and-recompute
//! trust model as the JSON envelopes.

use super::digest::digest128;

/// Append-only builder for a binary envelope.
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    /// Start an envelope with its magic and schema version.
    pub fn new(magic: [u8; 4], schema: u32) -> Self {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&magic);
        buf.extend_from_slice(&schema.to_le_bytes());
        BinWriter { buf }
    }

    /// Append one `u32`.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append one `u64`.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed `f32` buffer as raw bit patterns.
    pub fn put_f32_bits(&mut self, xs: &[f32]) {
        self.put_u32(xs.len() as u32);
        self.buf.reserve(xs.len() * 4);
        for x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// Seal the envelope: append the 128-bit digest of everything
    /// written so far and return the bytes.
    pub fn finish(mut self) -> Vec<u8> {
        let (hi, lo) = digest128(&self.buf);
        self.buf.extend_from_slice(&hi.to_le_bytes());
        self.buf.extend_from_slice(&lo.to_le_bytes());
        self.buf
    }
}

/// Bounds-checked cursor over a digest-verified binary envelope.
pub struct BinReader<'a> {
    body: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    /// Open an envelope: verify magic, schema and the digest trailer.
    /// `None` on any mismatch — the caller treats the envelope as
    /// corrupt and falls back / recomputes.
    pub fn open(bytes: &'a [u8], magic: [u8; 4], schema: u32) -> Option<BinReader<'a>> {
        // magic + schema + digest is the smallest possible envelope.
        if bytes.len() < 4 + 4 + 16 {
            return None;
        }
        let (body, trailer) = bytes.split_at(bytes.len() - 16);
        let (hi, lo) = digest128(body);
        if trailer[..8] != hi.to_le_bytes() || trailer[8..] != lo.to_le_bytes() {
            return None;
        }
        if body[..4] != magic {
            return None;
        }
        let got_schema = u32::from_le_bytes(body[4..8].try_into().ok()?);
        if got_schema != schema {
            return None;
        }
        Some(BinReader { body, pos: 8 })
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.body.len() {
            return None;
        }
        let out = &self.body[self.pos..end];
        self.pos = end;
        Some(out)
    }

    /// Read one `u32`.
    pub fn take_u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    /// Read one `u64`.
    pub fn take_u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn take_str(&mut self) -> Option<String> {
        let len = self.take_u32()? as usize;
        std::str::from_utf8(self.take(len)?).ok().map(str::to_string)
    }

    /// Read a length-prefixed `f32` buffer; `None` unless its length is
    /// exactly `expect_len` (buffer shapes are part of validation).
    pub fn take_f32_bits(&mut self, expect_len: usize) -> Option<Vec<f32>> {
        let len = self.take_u32()? as usize;
        if len != expect_len {
            return None;
        }
        let raw = self.take(len * 4)?;
        Some(
            raw.chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                .collect(),
        )
    }

    /// True once the cursor consumed the whole body — envelopes with
    /// trailing garbage inside the digested region are rejected by
    /// requiring this after the last field.
    pub fn at_end(&self) -> bool {
        self.pos == self.body.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MAGIC: [u8; 4] = *b"XRTB";

    fn sample() -> Vec<u8> {
        let mut w = BinWriter::new(MAGIC, 3);
        w.put_u64(0xDEAD_BEEF);
        w.put_str("host");
        w.put_f32_bits(&[1.5, f32::NAN, -0.0]);
        w.finish()
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let bytes = sample();
        let mut r = BinReader::open(&bytes, MAGIC, 3).expect("valid envelope");
        assert_eq!(r.take_u64(), Some(0xDEAD_BEEF));
        assert_eq!(r.take_str().as_deref(), Some("host"));
        let xs = r.take_f32_bits(3).unwrap();
        assert_eq!(xs[0].to_bits(), 1.5f32.to_bits());
        assert_eq!(xs[1].to_bits(), f32::NAN.to_bits());
        assert_eq!(xs[2].to_bits(), (-0.0f32).to_bits());
        assert!(r.at_end());
    }

    #[test]
    fn corruption_truncation_and_mismatches_are_rejected() {
        let bytes = sample();
        // Truncation anywhere breaks the digest (or the minimum size).
        for cut in [0, 7, bytes.len() / 2, bytes.len() - 1] {
            assert!(BinReader::open(&bytes[..cut], MAGIC, 3).is_none(), "cut={cut}");
        }
        // Any flipped byte breaks the digest.
        for i in [0usize, 4, 9, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(BinReader::open(&bad, MAGIC, 3).is_none(), "flip at {i}");
        }
        // Wrong magic / schema on an otherwise-intact envelope.
        assert!(BinReader::open(&bytes, *b"NOPE", 3).is_none());
        assert!(BinReader::open(&bytes, MAGIC, 4).is_none());
    }

    #[test]
    fn cursor_is_bounds_checked_and_shape_strict() {
        let bytes = sample();
        let mut r = BinReader::open(&bytes, MAGIC, 3).unwrap();
        r.take_u64().unwrap();
        r.take_str().unwrap();
        // Wrong expected length is a shape violation, not a read.
        assert!(r.take_f32_bits(2).is_none());
        // Reads past the end return None instead of panicking.
        let mut r = BinReader::open(&bytes, MAGIC, 3).unwrap();
        r.take_u64().unwrap();
        r.take_str().unwrap();
        r.take_f32_bits(3).unwrap();
        assert!(r.at_end());
        assert!(r.take_u32().is_none());
    }
}
