//! Shared 128-bit content hashing for envelopes, cache keys and
//! checkpoint digests.
//!
//! Two independently-seeded FNV-1a streams fed the same bytes — a cheap,
//! dependency-free 128-bit content hash (collision odds are negligible at
//! cache scale, and colliding entries would still have to pass the shape
//! checks of whichever envelope consumed them). One hash core serves the
//! profile-cache keys, the search/sweep checkpoint digests and the binary
//! sidecar trailers — one implementation, not four.

const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Streaming 128-bit FNV-1a content hasher.
pub struct ContentHasher {
    a: u64,
    b: u64,
}

impl ContentHasher {
    /// Fresh hasher. Offset bases: the standard FNV-1a basis and a second
    /// stream seeded from it (any fixed distinct constant works).
    pub fn new() -> Self {
        ContentHasher { a: 0xCBF2_9CE4_8422_2325, b: 0x9AE1_6A3B_2F90_404F }
    }

    /// Feed raw bytes.
    pub fn write(&mut self, bytes: &[u8]) {
        for &byte in bytes {
            self.a = (self.a ^ byte as u64).wrapping_mul(FNV_PRIME);
            self.b = (self.b ^ byte as u64).wrapping_mul(FNV_PRIME).rotate_left(1);
        }
    }

    /// Feed one `u64` (little-endian bytes).
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Feed an `f32` buffer as length + raw bit patterns.
    pub fn write_f32s(&mut self, xs: &[f32]) {
        self.write_u64(xs.len() as u64);
        for x in xs {
            self.write(&x.to_bits().to_le_bytes());
        }
    }

    /// Feed an `f64` buffer as length + raw bit patterns.
    pub fn write_f64s(&mut self, xs: &[f64]) {
        self.write_u64(xs.len() as u64);
        for x in xs {
            self.write(&x.to_bits().to_le_bytes());
        }
    }

    /// Feed a string as length + UTF-8 bytes (length prefix keeps
    /// concatenated fields unambiguous).
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    /// The two 64-bit stream states `(hi, lo)`.
    pub fn finish128(self) -> (u64, u64) {
        (self.a, self.b)
    }

    /// Fixed-width lowercase hex rendering of [`Self::finish128`]
    /// (32 chars) — the canonical digest form in JSON envelopes.
    pub fn finish_hex(self) -> String {
        let (hi, lo) = self.finish128();
        format!("{hi:016x}{lo:016x}")
    }
}

impl Default for ContentHasher {
    fn default() -> Self {
        ContentHasher::new()
    }
}

/// Digest a byte slice in one call.
pub fn digest128(bytes: &[u8]) -> (u64, u64) {
    let mut h = ContentHasher::new();
    h.write(bytes);
    h.finish128()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_is_stable_and_content_sensitive() {
        let d = |s: &str| {
            let mut h = ContentHasher::new();
            h.write_str(s);
            h.finish_hex()
        };
        assert_eq!(d("abc"), d("abc"));
        assert_ne!(d("abc"), d("abd"));
        assert_eq!(d("x").len(), 32);
        // Length prefixes keep concatenations unambiguous.
        let mut h1 = ContentHasher::new();
        h1.write_str("ab");
        h1.write_str("c");
        let mut h2 = ContentHasher::new();
        h2.write_str("a");
        h2.write_str("bc");
        assert_ne!(h1.finish_hex(), h2.finish_hex());
    }

    #[test]
    fn f32_and_f64_streams_hash_bit_patterns() {
        let mut h1 = ContentHasher::new();
        h1.write_f32s(&[0.0, -0.0]);
        let mut h2 = ContentHasher::new();
        h2.write_f32s(&[0.0, 0.0]);
        // -0.0 and 0.0 compare equal but have different bits: the hash
        // must see the bits (bit-exact round-trips key on bits).
        assert_ne!(h1.finish_hex(), h2.finish_hex());
        let mut h3 = ContentHasher::new();
        h3.write_f64s(&[f64::NAN]);
        let mut h4 = ContentHasher::new();
        h4.write_f64s(&[f64::NAN]);
        assert_eq!(h3.finish_hex(), h4.finish_hex());
    }

    #[test]
    fn one_shot_matches_streaming() {
        let (hi, lo) = digest128(b"hello");
        let mut h = ContentHasher::new();
        h.write(b"hello");
        assert_eq!(h.finish128(), (hi, lo));
    }
}
