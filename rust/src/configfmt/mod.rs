//! Minimal JSON support (offline substitute for `serde_json`).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), experiment
//! configuration files and machine-readable result dumps. Implements the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) with precise error positions; no serde-style derive —
//! callers navigate the [`Json`] tree with the typed accessors.

mod json;

pub use json::{parse, Json, JsonError};
