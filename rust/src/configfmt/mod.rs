//! Serialization formats and content hashing (offline substitutes for
//! `serde_json` and friends).
//!
//! * [`json`](self) — full JSON grammar (objects, arrays, strings with
//!   escapes, numbers, bools, null) with precise error positions; no
//!   serde-style derive — callers navigate the [`Json`] tree with the
//!   typed accessors. Used for the artifact manifest
//!   (`artifacts/manifest.json`), experiment configuration files,
//!   machine-readable result dumps, and the cache/checkpoint envelopes.
//! * binary envelopes ([`BinWriter`]/[`BinReader`]) — little-endian
//!   payloads with a digest trailer: the fast sidecar format for bulk
//!   `f32` buffers (the profile cache's warm-read path).
//! * [`ContentHasher`] — the shared 128-bit FNV-1a hash core behind
//!   cache keys, checkpoint digests and binary-envelope trailers.

mod bin;
mod digest;
mod json;

pub use bin::{BinReader, BinWriter};
pub use digest::{digest128, ContentHasher};
pub use json::{parse, Json, JsonError};
