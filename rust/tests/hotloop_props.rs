//! Property tests locking the PR 7 hot-loop optimizations to their
//! baselines, bit for bit, over randomized problems:
//!
//! * the lane-blocked phase-A contraction kernel vs the per-config
//!   scalar oracle (`HostEngine::scalar_oracle`), across shapes that
//!   exercise both `C_VARIANTS` paddings and the scalar remainder;
//! * `ScenarioOverlay::apply_batch` (shared-scratch, hoisted embodied
//!   fold) vs one `apply` per overlay, with and without a shared
//!   `online` mask, reusing one scratch across differently-sized
//!   batches;
//! * the persistent worker-pool scheduler vs the scoped-spawn scheduler
//!   vs the sequential reference, across thread counts below, equal to
//!   and above the chunk count (including trace scenarios).
//!
//! "Bit-identical" is literal: raw f32 buffers compare by `to_bits`,
//! unpacked f64 results by exact equality.

use xrcarbon::carbon::{CiTrace, OverlayScratch, ScenarioOverlay};
use xrcarbon::dse::sweep::{sweep, sweep_sequential, SweepConfig, SweepOutcome};
use xrcarbon::dse::ScenarioGrid;
use xrcarbon::matrixform::{ConfigRow, EvalRequest, PackedProblem, TaskMatrix};
use xrcarbon::runtime::{profile_request, Engine, HostEngine, HostEngineFactory, ScopedSpawn};
use xrcarbon::testkit::{forall_cfg, PropConfig, Rng};

/// Randomized request up to the full padded shape (8 tasks × 32
/// kernels); `c` picks the 128-config variant most of the time and the
/// 1024-config variant (129+) otherwise, so both artifact paddings and
/// the lane kernel's remainder handling get traffic.
fn gen_request(r: &mut Rng) -> EvalRequest {
    let t = r.below(8) + 1;
    let k = r.below(32) + 1;
    let c = if r.chance(0.3) { 129 + r.below(200) } else { r.below(128) + 1 };
    let j = r.below(8) + 1;
    let mut tasks = TaskMatrix::new(
        (0..t).map(|i| format!("t{i}")).collect(),
        (0..k).map(|i| format!("k{i}")).collect(),
    );
    for ti in 0..t {
        for ki in 0..k {
            if r.chance(0.6) {
                tasks.set(ti, ki, r.below(30) as f64);
            }
        }
    }
    EvalRequest {
        tasks,
        configs: (0..c)
            .map(|i| ConfigRow {
                name: format!("cfg{i}"),
                f_clk: r.range(1e8, 2e9),
                d_k: (0..k).map(|_| r.range(1e-5, 1e-1)).collect(),
                e_dyn: (0..k).map(|_| r.range(1e-4, 1.0)).collect(),
                leak_w: r.range(0.0, 0.2),
                c_comp: (0..j).map(|_| r.range(0.0, 1000.0)).collect(),
            })
            .collect(),
        online: (0..j).map(|_| if r.chance(0.8) { 1.0 } else { 0.0 }).collect(),
        qos: (0..t)
            .map(|_| if r.chance(0.3) { r.range(0.1, 100.0) } else { f64::INFINITY })
            .collect(),
        ci_use_g_per_j: r.range(1e-5, 1e-3),
        lifetime_s: r.range(1e4, 1e8),
        beta: r.range(0.0, 4.0),
        p_max_w: if r.chance(0.4) { r.range(0.5, 100.0) } else { f64::INFINITY },
    }
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_lane_kernel_bit_identical_to_scalar_oracle() {
    forall_cfg(
        PropConfig { cases: 24, seed: 71 },
        gen_request,
        |req| {
            let packed = PackedProblem::from_request(req);
            let mut lanes = HostEngine::new();
            let mut scalar = HostEngine::scalar_oracle();
            // Phase A (profile): the raw padded buffers, full range —
            // padding lanes included.
            let a = lanes.profile(&packed).unwrap();
            let b = scalar.profile(&packed).unwrap();
            // Fused path (execute): the carbon fold over the lane
            // results must match too.
            let x = lanes.execute(&packed).unwrap();
            let y = scalar.execute(&packed).unwrap();
            bits_eq(&a.energy, &b.energy)
                && bits_eq(&a.delay, &b.delay)
                && bits_eq(&a.d_task, &b.d_task)
                && bits_eq(&x.metrics, &y.metrics)
                && bits_eq(&x.d_task, &y.d_task)
        },
    );
}

#[test]
fn prop_apply_batch_bit_identical_to_apply() {
    // One scratch reused across every case (and so across batch sizes
    // and profile shapes) — reuse must never leak state between calls.
    // RefCell because the property closure is `Fn`, not `FnMut`.
    let scratch = std::cell::RefCell::new(OverlayScratch::new());
    forall_cfg(
        PropConfig { cases: 24, seed: 72 },
        |r| {
            let base = gen_request(r);
            let s = r.below(6) + 1;
            let shared_mask = r.chance(0.5);
            let overlays: Vec<EvalRequest> = (0..s)
                .map(|_| {
                    let mut req = base.clone();
                    req.configs = Vec::new();
                    req.ci_use_g_per_j = r.range(1e-5, 1e-3);
                    req.lifetime_s = r.range(1e4, 1e8);
                    req.beta = r.range(0.0, 4.0);
                    req.p_max_w = if r.chance(0.4) { r.range(0.5, 100.0) } else { f64::INFINITY };
                    for q in req.qos.iter_mut() {
                        if r.chance(0.3) {
                            *q = r.range(0.1, 100.0);
                        }
                    }
                    if !shared_mask {
                        for o in req.online.iter_mut() {
                            *o = if r.chance(0.7) { 1.0 } else { 0.0 };
                        }
                    }
                    req
                })
                .collect();
            (base, overlays)
        },
        |(base, overlay_reqs)| {
            let prof = profile_request(&mut HostEngine::new(), base).unwrap();
            let overlays: Vec<ScenarioOverlay> =
                overlay_reqs.iter().map(ScenarioOverlay::from_request).collect();
            let batched =
                ScenarioOverlay::apply_batch(&overlays, &prof, &mut scratch.borrow_mut());
            batched.len() == overlays.len()
                && overlays.iter().zip(&batched).all(|(ov, got)| {
                    let want = ov.apply(&prof);
                    want.names == got.names
                        && want.metrics == got.metrics
                        && want.d_task == got.d_task
                })
        },
    );
}

/// Exact-equality outcome comparison (the same fields the unit tests'
/// `assert_outcomes_identical` checks, as a predicate).
fn outcomes_identical(a: &SweepOutcome, b: &SweepOutcome) -> bool {
    a.scenarios.len() == b.scenarios.len()
        && a.scenarios.iter().zip(&b.scenarios).all(|(x, y)| {
            x.label == y.label
                && x.outcome.result.names == y.outcome.result.names
                && x.outcome.result.metrics == y.outcome.result.metrics
                && x.outcome.result.d_task == y.outcome.result.d_task
                && x.outcome.optimal == y.outcome.optimal
                && x.outcome.stats.best.to_bits() == y.outcome.stats.best.to_bits()
                && x.outcome.stats.mean.to_bits() == y.outcome.stats.mean.to_bits()
                && x.outcome.stats.feasible == y.outcome.stats.feasible
        })
}

#[test]
fn prop_pool_scheduler_bit_identical_across_thread_counts() {
    forall_cfg(
        PropConfig { cases: 6, seed: 73 },
        |r| {
            let mut req = gen_request(r);
            // 40..=300 configs: 1 to 3 profile chunks, so some thread
            // counts under- and some oversubscribe the chunk count.
            let c = 40 + r.below(261);
            let proto = req.configs[0].clone();
            req.configs = (0..c)
                .map(|i| ConfigRow { name: format!("cfg{i}"), ..proto.clone() })
                .collect();
            for (i, cfg) in req.configs.iter_mut().enumerate() {
                cfg.f_clk = 1e9 + i as f64 * 1e5;
                for d in cfg.d_k.iter_mut() {
                    *d *= 1.0 + (i % 9) as f64 * 0.1;
                }
            }
            req
        },
        |req| {
            let grid = ScenarioGrid::new()
                .with_lifetime("short", 1e5)
                .with_beta("b=2", 2.0)
                .with_trace("trace=flat", CiTrace::flat(440.0));
            let reference = sweep_sequential(&mut HostEngine::new(), req, &grid).unwrap();
            // Thread counts below, at and above the chunk count (1–3
            // chunks); 7 oversubscribes every space this test builds.
            [1usize, 2, 3, 7].iter().all(|&threads| {
                let cfg = SweepConfig { threads };
                let pooled = sweep(&HostEngineFactory, req, &grid, &cfg).unwrap();
                let spawned =
                    sweep(&ScopedSpawn(HostEngineFactory), req, &grid, &cfg).unwrap();
                outcomes_identical(&reference, &pooled)
                    && outcomes_identical(&reference, &spawned)
            })
        },
    );
}
